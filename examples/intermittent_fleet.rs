//! Multi-tenant platform under pressure: several concurrent FL jobs with
//! intermittent heterogeneous fleets share one small cluster — the §5.5
//! scenario where the JIT scheduler's *priorities* (not just its timers)
//! matter: jobs whose deadlines come first win containers; later-deadline
//! aggregators are deferred or preempted (checkpointing partial aggregates
//! to the MQ) and resume without losing fused work.
//!
//! Run: `cargo run --release --example intermittent_fleet`
//! Flags: --jobs N --parties N --rounds N --capacity N --twait SECS

use fljit::coordinator::job::FlJobSpec;
use fljit::coordinator::platform::{Platform, PlatformConfig};
use fljit::party::FleetKind;
use fljit::util::table::Table;
use fljit::workloads::Workload;

fn main() {
    let args = fljit::util::cli::Args::from_env();
    let n_jobs = args.get_usize("jobs", 6);
    let parties = args.get_usize("parties", 200);
    let rounds = args.get_u64("rounds", 8) as u32;
    let capacity = args.get_usize("capacity", 6);
    let t_wait = args.get_f64("twait", 240.0);
    let seed = args.get_u64("seed", 17);

    let workloads = [
        Workload::cifar100_effnet(),
        Workload::rvlcdip_vgg16(),
        Workload::inat_inception(),
    ];

    let mut cfg = PlatformConfig {
        seed,
        ..Default::default()
    };
    cfg.cluster.capacity = capacity;
    let mut platform = Platform::new(cfg);
    for i in 0..n_jobs {
        let mut spec = FlJobSpec::new(
            workloads[i % workloads.len()].clone(),
            FleetKind::IntermittentHeterogeneous,
            parties,
            rounds,
        );
        spec.t_wait_secs = t_wait;
        spec.name = format!("tenant-{i}-{}", spec.workload.name);
        platform.admit(spec, "jit");
    }

    println!(
        "{n_jobs} intermittent JIT jobs × {parties} parties × {rounds} rounds \
         sharing a {capacity}-container cluster (t_wait {t_wait}s)\n"
    );
    let reports = platform.run();

    let mut t = Table::new(
        "multi-tenant JIT under contention",
        &[
            "job",
            "rounds",
            "mean latency (s)",
            "p95 latency (s)",
            "container-s",
            "deployments",
            "fused",
        ],
    );
    for (i, r) in reports.iter().enumerate() {
        t.row(vec![
            format!("tenant-{i} ({})", r.workload),
            r.rounds.len().to_string(),
            format!("{:.2}", r.mean_latency_secs()),
            format!("{:.2}", r.latency_p95()),
            format!("{:.0}", r.total_container_seconds()),
            r.deployments.to_string(),
            r.updates_fused.to_string(),
        ]);
    }
    t.print();

    let all_done = reports.iter().all(|r| r.rounds.len() == rounds as usize);
    let total_fused: u64 = reports.iter().map(|r| r.updates_fused).sum();
    println!(
        "\nall jobs completed: {all_done}; {total_fused} updates fused across tenants \
         (work conserved through any preemptions)."
    );
    assert!(all_done, "every tenant must finish under contention");
    assert_eq!(total_fused, (n_jobs * parties * rounds as usize) as u64);
}
