//! Scale sweep (§6.4-6.5 "scalability"): party counts 10 → 10 000 across
//! all four paper strategies in simulated time, printing how mean
//! aggregation latency and container-seconds grow with the fleet.
//!
//! Run: `cargo run --release --example scale_sweep`
//! Flags: --workload cifar100|rvlcdip|inat --fleet active-hetero|...
//!        --rounds N --seed S

use fljit::coordinator::job::FlJobSpec;
use fljit::coordinator::platform::run_scenario;
use fljit::coordinator::strategies::paper_strategies;
use fljit::party::FleetKind;
use fljit::util::table::Table;
use fljit::workloads::Workload;

fn main() {
    let args = fljit::util::cli::Args::from_env();
    let workload = Workload::by_name(args.get_or("workload", "cifar100-effnet"))
        .expect("unknown workload");
    let fleet =
        FleetKind::parse(args.get_or("fleet", "active-hetero")).expect("unknown fleet kind");
    let rounds = args.get_u64("rounds", 20) as u32;
    let seed = args.get_u64("seed", 7);

    println!(
        "scale sweep: {} / {} / {} rounds per cell\n",
        workload.name,
        fleet.name(),
        rounds
    );
    let mut lat = Table::new(
        "mean aggregation latency (s) vs fleet size",
        &["# parties", "JIT", "Batch λ", "Eager λ", "Eager AO"],
    );
    let mut cost = Table::new(
        "container-seconds vs fleet size",
        &["# parties", "JIT", "Batch λ", "Eager λ", "Eager AO"],
    );
    for n in [10usize, 100, 1000, 10000] {
        let spec = FlJobSpec::new(workload.clone(), fleet, n, rounds);
        let mut lrow = vec![n.to_string()];
        let mut crow = vec![n.to_string()];
        for s in paper_strategies() {
            let r = run_scenario(&spec, s, seed);
            lrow.push(format!("{:.2}", r.mean_latency_secs()));
            crow.push(format!("{:.0}", r.total_container_seconds()));
        }
        lat.row(lrow);
        cost.row(crow);
    }
    lat.print();
    println!();
    cost.print();
    println!(
        "\nreading: JIT tracks eager latency at every scale while its cost\n\
         column grows like lazy's — the paper's central claim (§6.4-6.5)."
    );
}
