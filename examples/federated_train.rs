//! End-to-end validation (DESIGN.md §5 "e2e"): full-stack federated
//! training on the *live* platform — the same event-driven `Strategy`
//! implementations as the simulator, paced by the wall clock, with party
//! updates flowing through the zero-copy MQ.
//!
//! With the XLA artifacts built (`make artifacts`, `--features xla`) the
//! parties run real local training (L1 Pallas kernels → L2 JAX graphs →
//! AOT HLO → L3 Rust platform; Python never runs here) and the example
//! asserts the global eval loss drops. Without artifacts it falls back to
//! the synthetic-training backend so the live control plane (JIT deferral
//! vs always-on busy seconds over MQ traffic) is still exercised — that is
//! what CI runs.
//!
//! Run: `cargo run --release --example federated_train`
//! Flags: --parties N --rounds N --minibatches {2,4,8,16,32}
//!        --alpha A --seed S --backend {xla|synth}

use fljit::coordinator::job::FlJobSpec;
use fljit::coordinator::live::PartyBackend;
use fljit::coordinator::session::{JobOutcome, Session};
use fljit::party::FleetKind;
use fljit::util::json::Json;
use fljit::workloads::Workload;

fn main() {
    fljit::util::logging::init_from_env();
    let args = fljit::util::cli::Args::from_env();
    let want_xla = match args.get("backend") {
        Some("synth") => false,
        Some("xla") => true,
        Some(other) => {
            eprintln!("unknown backend {other:?} (xla | synth)");
            std::process::exit(2);
        }
        None => {
            fljit::runtime::xla_enabled()
                && fljit::runtime::default_artifact_dir()
                    .join("manifest.json")
                    .exists()
        }
    };
    let backend = if want_xla {
        PartyBackend::XlaThreads
    } else {
        println!("(artifacts not available — using the synthetic-training backend)");
        PartyBackend::SynthThreads
    };
    let n_parties = args.get_usize("parties", 8);
    let rounds = args.get_u64("rounds", if want_xla { 40 } else { 6 }) as u32;
    let minibatches = args.get_usize("minibatches", 8);
    let lr = args.get_f64("lr", if want_xla { 0.08 } else { 0.3 }) as f32;
    let alpha = args.get_f64("alpha", 0.5);
    let seed = args.get_u64("seed", 42);

    // one wall-clock session per strategy, identical job spec
    let run_strategy = |strategy: &str| -> JobOutcome {
        let spec = FlJobSpec::new(
            Workload::mlp_live(),
            FleetKind::ActiveHomogeneous,
            n_parties,
            rounds,
        );
        let mut s = Session::wall()
            .backend(backend)
            .minibatches(minibatches)
            .lr(lr)
            .alpha(alpha)
            .seed(seed);
        let h = s.job(spec, strategy);
        match s.run() {
            Ok(rep) => rep.job(h).clone(),
            Err(e) => {
                eprintln!("live run failed: {e:#}");
                std::process::exit(1);
            }
        }
    };

    println!(
        "federated_train: {n_parties} parties × {rounds} rounds under 'jit', live MQ path"
    );

    let jit = run_strategy("jit");

    println!("\nround  latency(ms)  complete(s)");
    for r in &jit.records {
        println!(
            "{:>5}  {:>11.1}  {:>11.2}",
            r.round,
            r.latency_secs * 1e3,
            r.complete_secs
        );
    }
    if !jit.stats.is_empty() {
        println!("\nround  train-loss  eval-loss  eval-acc");
        for s in &jit.stats {
            println!(
                "{:>5}  {:>10.4}  {:>9.4}  {:>8.3}",
                s.round, s.train_loss, s.eval_loss, s.eval_acc
            );
        }
        let first = jit.stats.first().unwrap();
        let last = jit.stats.last().unwrap();
        println!(
            "\nloss curve: {:.4} -> {:.4}   accuracy: {:.3} -> {:.3}",
            first.eval_loss, last.eval_loss, first.eval_acc, last.eval_acc
        );
        assert!(
            last.eval_loss < first.eval_loss,
            "training must reduce the global loss"
        );
    }

    println!("\nre-running the identical job under 'eager-ao'…");
    let ao = run_strategy("eager-ao");

    let savings = (1.0 - jit.container_seconds / ao.container_seconds.max(1e-12)) * 100.0;
    println!(
        "aggregator busy seconds: JIT {:.3}cs vs always-on {:.3}cs -> {:.1}% saved",
        jit.container_seconds, ao.container_seconds, savings
    );
    println!(
        "mean aggregation latency: JIT {:.1} ms vs always-on {:.1} ms",
        jit.mean_latency_secs() * 1e3,
        ao.mean_latency_secs() * 1e3
    );
    if jit.t_pair_secs > 0.0 {
        println!(
            "t_pair (measured on the XLA fusion path, §5.4): {:.2} ms",
            jit.t_pair_secs * 1e3
        );
    }
    assert!(
        jit.container_seconds < ao.container_seconds,
        "JIT must be cheaper than always-on: {} !< {}",
        jit.container_seconds,
        ao.container_seconds
    );

    let curve = Json::arr(jit.stats.iter().map(|s| {
        Json::obj(vec![
            ("round", Json::num(s.round as f64)),
            ("train_loss", Json::num(s.train_loss as f64)),
            ("eval_loss", Json::num(s.eval_loss as f64)),
            ("eval_acc", Json::num(s.eval_acc as f64)),
        ])
    }));
    let out = Json::obj(vec![
        ("backend", Json::str(if want_xla { "xla" } else { "synth" })),
        ("jit_busy_secs", Json::num(jit.container_seconds)),
        ("ao_busy_secs", Json::num(ao.container_seconds)),
        ("savings_pct", Json::num(savings)),
        ("jit_mean_latency_secs", Json::num(jit.mean_latency_secs())),
        ("ao_mean_latency_secs", Json::num(ao.mean_latency_secs())),
        ("t_pair_secs", Json::num(jit.t_pair_secs)),
        ("curve", curve),
    ]);
    fljit::bench::dump("federated_train", &out);
}
