//! End-to-end validation (DESIGN.md §5 "e2e"): full-stack federated
//! training on a real (synthetic non-IID) workload, proving all three
//! layers compose:
//!
//!   L1 Pallas fusion kernels → L2 JAX train/eval graphs → AOT HLO text →
//!   L3 Rust platform (party threads, periodicity estimator, JIT deferral,
//!   XLA aggregation) — Python never runs here.
//!
//! Eight parties train an MLP classifier on Dirichlet-skewed shards for
//! 40+ rounds under the JIT policy, then the same job re-runs under
//! always-on accounting for the savings comparison. The loss curve and the
//! busy-second comparison are recorded in EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example federated_train`
//! Flags: --parties N --rounds N --minibatches {2,4,8,16,32} --alpha A

use fljit::coordinator::live::{run_live, LiveConfig, LiveStrategy};
use fljit::util::json::Json;

fn main() {
    fljit::util::logging::init_from_env();
    let args = fljit::util::cli::Args::from_env();
    let base = LiveConfig {
        n_parties: args.get_usize("parties", 8),
        rounds: args.get_u64("rounds", 40) as u32,
        minibatches: args.get_usize("minibatches", 8),
        lr: args.get_f64("lr", 0.08) as f32,
        alpha: args.get_f64("alpha", 0.5),
        seed: args.get_u64("seed", 42),
        mu: args.get_f64("mu", 0.0) as f32,
        extra_epoch_ms: args.get_u64("extra-epoch-ms", 250),
        strategy: LiveStrategy::Jit { margin: 0.15 },
    };

    println!(
        "federated_train: {} parties × {} rounds, {} minibatches/epoch, non-IID α={}",
        base.n_parties, base.rounds, base.minibatches, base.alpha
    );

    let jit = match run_live(&base) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("failed (run `make artifacts` first): {e:#}");
            std::process::exit(1);
        }
    };

    println!("\nround  train-loss  eval-loss  eval-acc  defer(ms)  latency(ms)");
    for r in &jit.rounds {
        println!(
            "{:>5}  {:>10.4}  {:>9.4}  {:>8.3}  {:>9.1}  {:>11.1}",
            r.round,
            r.train_loss,
            r.eval_loss,
            r.eval_acc,
            r.defer_secs * 1e3,
            r.agg_latency_secs * 1e3
        );
    }
    let first = jit.rounds.first().unwrap();
    let last = jit.rounds.last().unwrap();
    println!(
        "\nloss curve: {:.4} -> {:.4}   accuracy: {:.3} -> {:.3}",
        first.eval_loss, last.eval_loss, first.eval_acc, last.eval_acc
    );
    assert!(
        last.eval_loss < first.eval_loss,
        "training must reduce the global loss"
    );

    println!("\nre-running the identical job with always-on accounting…");
    let ao = run_live(&LiveConfig {
        strategy: LiveStrategy::EagerAlwaysOn,
        ..base.clone()
    })
    .expect("always-on run");

    let savings = (1.0 - jit.total_busy_secs / ao.total_busy_secs) * 100.0;
    println!(
        "\naggregator busy seconds: JIT {:.2}s vs always-on {:.2}s -> {:.1}% saved",
        jit.total_busy_secs, ao.total_busy_secs, savings
    );
    println!(
        "mean aggregation latency: JIT {:.1} ms vs always-on {:.1} ms",
        jit.mean_latency_secs() * 1e3,
        ao.mean_latency_secs() * 1e3
    );
    println!(
        "t_pair (XLA path): {:.2} ms; final accuracy {:.3}",
        jit.t_pair_secs * 1e3,
        jit.final_acc
    );

    // dump the loss curve for EXPERIMENTS.md
    let curve = Json::arr(jit.rounds.iter().map(|r| {
        Json::obj(vec![
            ("round", Json::num(r.round as f64)),
            ("train_loss", Json::num(r.train_loss as f64)),
            ("eval_loss", Json::num(r.eval_loss as f64)),
            ("eval_acc", Json::num(r.eval_acc as f64)),
            ("defer_secs", Json::num(r.defer_secs)),
            ("agg_latency_secs", Json::num(r.agg_latency_secs)),
        ])
    }));
    let out = Json::obj(vec![
        ("jit_busy_secs", Json::num(jit.total_busy_secs)),
        ("ao_busy_secs", Json::num(ao.total_busy_secs)),
        ("savings_pct", Json::num(savings)),
        ("t_pair_secs", Json::num(jit.t_pair_secs)),
        ("curve", curve),
    ]);
    fljit::bench::dump("federated_train", &out);
}
