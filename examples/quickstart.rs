//! Quickstart: the paper's Fig 2 story in two acts.
//!
//! 1. **Simulated timeline** — six parties send updates over ~20 s; we run
//!    all five aggregation design options (§3) and print the latency /
//!    container-seconds comparison.
//! 2. **Live round** — the *same* JIT `Strategy` implementation drives a
//!    wall-clock job: party threads publish updates into the zero-copy
//!    MQ, the wall driver sleeps to the JIT deadline, and the aggregator
//!    folds the topic log (with real XLA training when the artifacts are
//!    built — `--backend xla`; synthetic training otherwise).
//!
//! Run: `cargo run --release --example quickstart`

use fljit::coordinator::job::FlJobSpec;
use fljit::coordinator::live::PartyBackend;
use fljit::coordinator::session::{Session, SessionEvent};
use fljit::coordinator::timeline;
use fljit::party::FleetKind;
use fljit::workloads::Workload;

fn main() {
    fljit::util::logging::init_from_env();
    let args = fljit::util::cli::Args::from_env();
    let seed = args.get_u64("seed", 7);

    println!("—— Act 1: the Fig 2 scenario (simulated) ——————————————\n");
    let reports = timeline::run_fig2(seed);
    print!("{}", timeline::render(&reports));
    println!(
        "§3 arithmetic check: the always-on aggregator is busy 6 s of a 21 s\n\
         round -> idle {:.1}% — exactly the waste JIT reclaims.\n",
        timeline::eager_ao_idle_fraction(6.0, 21.0) * 100.0
    );

    println!("—— Act 2: one live federated job (wall clock + MQ) ————\n");
    let backend = match args.get("backend") {
        Some("xla") => PartyBackend::XlaThreads,
        _ => PartyBackend::SynthThreads,
    };
    let spec = FlJobSpec::new(
        Workload::mlp_live(),
        FleetKind::ActiveHomogeneous,
        args.get_usize("parties", 4),
        args.get_u64("rounds", 6) as u32,
    );
    let mut session = Session::wall()
        .backend(backend)
        .minibatches(4)
        .seed(seed);
    let job = session.job(spec, args.get_or("strategy", "jit"));
    // the streaming observer channel: rounds print as they fuse, while
    // the session runs on a worker thread
    let events = session.events();
    let worker = std::thread::spawn(move || session.run());
    for ev in events.iter() {
        if let SessionEvent::RoundFused {
            round,
            latency_secs,
            at_secs,
            ..
        } = ev
        {
            println!(
                "round {round} fused at t={at_secs:.2}s (agg latency {:.1} ms)",
                latency_secs * 1e3
            );
        }
    }
    match worker.join().expect("session thread") {
        Ok(report) => {
            let o = report.job(job);
            println!("\nround  agg-latency(ms)  complete(s)");
            for r in &o.records {
                println!(
                    "{:>5}  {:>15.1}  {:>11.2}",
                    r.round,
                    r.latency_secs * 1e3,
                    r.complete_secs
                );
            }
            for s in &o.stats {
                println!(
                    "round {}: eval_loss={:.4} eval_acc={:.3}",
                    s.round, s.eval_loss, s.eval_acc
                );
            }
            println!(
                "\naggregator busy {:.3} container-seconds over {:.2} s wall — \
                 the rest was JIT-deferred and free for other jobs.",
                o.container_seconds,
                report.summary().wall_secs
            );
        }
        Err(e) => {
            eprintln!("live act failed: {e:#}");
            std::process::exit(1);
        }
    }
}
