//! Quickstart: the paper's Fig 2 story in two acts.
//!
//! 1. **Simulated timeline** — six parties send updates over ~20 s; we run
//!    all five aggregation design options (§3) and print the latency /
//!    container-seconds comparison.
//! 2. **Live round** — the same JIT policy drives *real* aggregation: four
//!    parties train a real MLP through the AOT train artifacts and the
//!    aggregator fuses their updates through the Pallas-kernel XLA
//!    artifacts, deferring deployment until `t_rnd − t_agg`.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use fljit::coordinator::live::{run_live, LiveConfig, LiveStrategy};
use fljit::coordinator::timeline;

fn main() {
    fljit::util::logging::init_from_env();
    let args = fljit::util::cli::Args::from_env();
    let seed = args.get_u64("seed", 7);

    println!("—— Act 1: the Fig 2 scenario (simulated) ——————————————\n");
    let reports = timeline::run_fig2(seed);
    print!("{}", timeline::render(&reports));
    println!(
        "§3 arithmetic check: the always-on aggregator is busy 6 s of a 21 s\n\
         round -> idle {:.1}% — exactly the waste JIT reclaims.\n",
        timeline::eager_ao_idle_fraction(6.0, 21.0) * 100.0
    );

    println!("—— Act 2: one live federated job (real XLA fusion) ————\n");
    let cfg = LiveConfig {
        n_parties: args.get_usize("parties", 4),
        rounds: args.get_u64("rounds", 6) as u32,
        minibatches: 4,
        extra_epoch_ms: 300, // emulate heavier local datasets (DESIGN.md §3)
        strategy: LiveStrategy::Jit { margin: 0.15 },
        seed,
        ..Default::default()
    };
    match run_live(&cfg) {
        Ok(report) => {
            println!(
                "t_pair (measured on the XLA fusion path, §5.4): {:.2} ms",
                report.t_pair_secs * 1e3
            );
            println!("round  eval-loss  eval-acc  defer(ms)  agg-latency(ms)  busy(ms)");
            for r in &report.rounds {
                println!(
                    "{:>5}  {:>9.4}  {:>8.3}  {:>9.1}  {:>15.1}  {:>8.1}",
                    r.round,
                    r.eval_loss,
                    r.eval_acc,
                    r.defer_secs * 1e3,
                    r.agg_latency_secs * 1e3,
                    r.agg_busy_secs * 1e3
                );
            }
            println!(
                "\naggregator busy {:.2} s of {:.2} s wall — the rest was \
                 JIT-deferred and free for other jobs.",
                report.total_busy_secs, report.total_secs
            );
        }
        Err(e) => {
            eprintln!("live act skipped (run `make artifacts` first): {e:#}");
            std::process::exit(1);
        }
    }
}
