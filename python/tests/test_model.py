"""L2 correctness: fusion graphs and the MLP training substrate."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref

TILE = 128
SEEDS = st.integers(min_value=0, max_value=2**31 - 1)


def init_params(seed=0, i=model.IN_DIM, h=model.HIDDEN, c=model.CLASSES):
    r = np.random.default_rng(seed)
    out = []
    for name, shape in model.param_shapes(i, h, c):
        if name.startswith("w"):
            fan_in = shape[0]
            out.append((r.standard_normal(shape) / np.sqrt(fan_in)).astype(np.float32))
        else:
            out.append(np.zeros(shape, dtype=np.float32))
    return [jnp.array(p) for p in out]


def synth_batch(seed, b, i=model.IN_DIM, c=model.CLASSES):
    """Linearly-separable-ish synthetic classification batch."""
    r = np.random.default_rng(seed)
    proto = r.standard_normal((c, i)).astype(np.float32)
    labels = r.integers(0, c, size=b)
    x = proto[labels] + 0.3 * r.standard_normal((b, i)).astype(np.float32)
    y = np.zeros((b, c), dtype=np.float32)
    y[np.arange(b), labels] = 1.0
    return jnp.array(x), jnp.array(y)


# --- fusion graphs ----------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(k=st.integers(2, 8), seed=SEEDS)
def test_fuse_k_is_weighted_mean(k, seed):
    r = np.random.default_rng(seed)
    u = r.standard_normal((k, 2 * TILE)).astype(np.float32)
    w = r.uniform(0.5, 4.0, size=k).astype(np.float32)
    (got,) = model.fuse_k(jnp.array(u), jnp.array(w))
    want = ref.weighted_mean(jnp.array(u), jnp.array(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_fuse_pair_weighted_mean_of_pair():
    r = np.random.default_rng(3)
    a = r.standard_normal(4 * TILE).astype(np.float32)
    b = r.standard_normal(4 * TILE).astype(np.float32)
    (got,) = model.fuse_pair(
        jnp.array(a), jnp.array(b),
        jnp.array([3.0], dtype=np.float32), jnp.array([1.0], dtype=np.float32),
    )
    np.testing.assert_allclose(np.asarray(got), (3 * a + b) / 4, rtol=1e-5, atol=1e-5)


def test_fedprox_fuse_interpolates():
    r = np.random.default_rng(4)
    u = r.standard_normal((4, TILE)).astype(np.float32)
    w = np.ones(4, dtype=np.float32)
    g = r.standard_normal(TILE).astype(np.float32)
    (half,) = model.fedprox_fuse(
        jnp.array(u), jnp.array(w), jnp.array(g), jnp.array([0.5], dtype=np.float32)
    )
    mean = u.mean(axis=0)
    np.testing.assert_allclose(np.asarray(half), 0.5 * mean + 0.5 * g, rtol=1e-4, atol=1e-4)


# --- training substrate -----------------------------------------------------


def test_train_step_decreases_loss():
    params = init_params(0)
    x, y = synth_batch(1, 64)
    lr = jnp.array([0.1], dtype=jnp.float32)
    losses = []
    for _ in range(30):
        *params, loss = model.train_step(*params, x, y, lr)
        losses.append(float(loss[0]))
    assert losses[-1] < losses[0] * 0.7, f"loss did not decrease: {losses[0]} -> {losses[-1]}"


def test_train_epoch_matches_repeated_train_step():
    params = init_params(5)
    n, b = 4, 32
    xs, ys = [], []
    for j in range(n):
        x, y = synth_batch(100 + j, b)
        xs.append(x)
        ys.append(y)
    xs_stacked = jnp.stack(xs)
    ys_stacked = jnp.stack(ys)
    lr = jnp.array([0.05], dtype=jnp.float32)

    *epoch_params, epoch_loss = model.train_epoch(*params, xs_stacked, ys_stacked, lr)

    step_params = list(params)
    step_losses = []
    for j in range(n):
        *step_params, loss = model.train_step(*step_params, xs[j], ys[j], lr)
        step_losses.append(float(loss[0]))

    for a, bp in zip(epoch_params, step_params):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bp), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(epoch_loss[0]), np.mean(step_losses), rtol=1e-5)


def test_eval_step_counts_correct():
    params = init_params(0)
    x, y = synth_batch(2, 256)
    lr = jnp.array([0.1], dtype=jnp.float32)
    loss0, correct0 = model.eval_step(*params, x, y)
    for _ in range(40):
        *params, _ = model.train_step(*params, x, y, lr)
    loss1, correct1 = model.eval_step(*params, x, y)
    assert float(loss1[0]) < float(loss0[0])
    assert float(correct1[0]) >= float(correct0[0])
    assert 0.0 <= float(correct1[0]) <= 256.0


def test_param_shapes_flattened_size():
    total = sum(int(np.prod(s)) for _, s in model.param_shapes())
    # i*h + h + h*h + h + h*c + c with defaults 64/256/10
    i, h, c = model.IN_DIM, model.HIDDEN, model.CLASSES
    assert total == i * h + h + h * h + h + h * c + c
