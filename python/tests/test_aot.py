"""AOT pipeline: lowering produces parseable HLO text and a sound manifest."""

import json
import os

import jax
import jax.numpy as jnp

from compile import aot, model


def test_to_hlo_text_contains_entry():
    lowered = jax.jit(lambda x: (x * 2.0,)).lower(
        jax.ShapeDtypeStruct((8,), jnp.float32)
    )
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[8]" in text


def test_emit_single_artifact(tmp_path):
    b = aot.Builder(str(tmp_path))
    b.emit(
        "fuse_pair_tiny",
        model.fuse_pair,
        [aot.spec(2048), aot.spec(2048), aot.spec(1), aot.spec(1)],
        1,
        {"kind": "pair_merge", "d": 2048},
    )
    b.write_manifest()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["version"] == 1
    [e] = manifest["artifacts"]
    assert e["name"] == "fuse_pair_tiny"
    assert e["n_outputs"] == 1
    assert e["inputs"][0]["dims"] == [2048]
    hlo = (tmp_path / e["file"]).read_text()
    assert "ENTRY" in hlo


def test_repo_manifest_if_built():
    """If `make artifacts` has run, validate the real manifest."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest_path = os.path.join(art, "manifest.json")
    if not os.path.exists(manifest_path):
        import pytest

        pytest.skip("artifacts not built")
    manifest = json.loads(open(manifest_path).read())
    names = {e["name"] for e in manifest["artifacts"]}
    # the Rust runtime hard-depends on these entry points
    for required in (
        "pair_merge_d65536",
        "fuse_k8_d65536",
        "fedprox_k8_d65536",
        "train_step_b32",
        "train_epoch_n8_b32",
        "eval_b256",
    ):
        assert required in names, f"missing artifact {required}"
    for e in manifest["artifacts"]:
        assert os.path.exists(os.path.join(art, e["file"])), e["file"]
        assert e["n_outputs"] >= 1
