"""L1 correctness: Pallas kernels vs pure-jnp oracle (ref.py).

This is the core correctness signal for the aggregation hot path.
Hypothesis sweeps flattened sizes, fan-in K, weight scales and value
magnitudes; every case asserts allclose between the interpret-mode Pallas
kernel and its mathematical definition.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import fused_agg, ref

# Small tile so hypothesis can sweep several grid sizes cheaply.
TILE = 128


def rng(seed):
    return np.random.default_rng(seed)


# --- strategies ------------------------------------------------------------

d_strategy = st.sampled_from([TILE, 2 * TILE, 4 * TILE, 8 * TILE])
k_strategy = st.integers(min_value=1, max_value=16)
seed_strategy = st.integers(min_value=0, max_value=2**31 - 1)
scale_strategy = st.sampled_from([1e-3, 1.0, 1e3])


@settings(max_examples=30, deadline=None)
@given(d=d_strategy, seed=seed_strategy, scale=scale_strategy)
def test_pair_merge_matches_ref(d, seed, scale):
    r = rng(seed)
    a = (r.standard_normal(d) * scale).astype(np.float32)
    b = (r.standard_normal(d) * scale).astype(np.float32)
    wa = np.array([r.uniform(0.1, 10.0)], dtype=np.float32)
    wb = np.array([r.uniform(0.1, 10.0)], dtype=np.float32)
    got = fused_agg.pair_merge(jnp.array(a), jnp.array(b), jnp.array(wa), jnp.array(wb), tile=TILE)
    want = ref.pair_merge(jnp.array(a), jnp.array(b), wa[0], wb[0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5 * scale)


@settings(max_examples=30, deadline=None)
@given(d=d_strategy, k=k_strategy, seed=seed_strategy, scale=scale_strategy)
def test_fused_weighted_sum_matches_ref(d, k, seed, scale):
    r = rng(seed)
    u = (r.standard_normal((k, d)) * scale).astype(np.float32)
    w = r.uniform(0.1, 5.0, size=k).astype(np.float32)
    got = fused_agg.fused_weighted_sum(jnp.array(u), jnp.array(w), tile=TILE)
    want = ref.fused_weighted_sum(jnp.array(u), jnp.array(w))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4 * scale * k
    )


@settings(max_examples=30, deadline=None)
@given(d=d_strategy, k=k_strategy, seed=seed_strategy)
def test_fedprox_merge_matches_ref(d, k, seed):
    r = rng(seed)
    u = r.standard_normal((k, d)).astype(np.float32)
    g = r.standard_normal(d).astype(np.float32)
    w = r.uniform(0.1, 5.0, size=k).astype(np.float32)
    mu = np.array([r.uniform(0.0, 1.0)], dtype=np.float32)
    got = fused_agg.fedprox_merge(
        jnp.array(u), jnp.array(w), jnp.array(g), jnp.array(mu), tile=TILE
    )
    want = ref.fedprox_merge(jnp.array(u), jnp.array(w), jnp.array(g), mu[0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


# --- algebraic invariants (mirror the Rust property tests) -----------------


@settings(max_examples=20, deadline=None)
@given(d=d_strategy, seed=seed_strategy)
def test_pair_merge_commutative(d, seed):
    r = rng(seed)
    a = r.standard_normal(d).astype(np.float32)
    b = r.standard_normal(d).astype(np.float32)
    wa = np.array([r.uniform(0.1, 10.0)], dtype=np.float32)
    wb = np.array([r.uniform(0.1, 10.0)], dtype=np.float32)
    ab = fused_agg.pair_merge(jnp.array(a), jnp.array(b), jnp.array(wa), jnp.array(wb), tile=TILE)
    ba = fused_agg.pair_merge(jnp.array(b), jnp.array(a), jnp.array(wb), jnp.array(wa), tile=TILE)
    np.testing.assert_allclose(np.asarray(ab), np.asarray(ba), rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(d=st.sampled_from([TILE, 4 * TILE]), k=st.integers(2, 8), seed=seed_strategy)
def test_chained_pair_merge_equals_weighted_mean(d, k, seed):
    """Sequential pair-merging (eager aggregation, §2.1) must equal the
    one-shot K-way weighted mean (batched/JIT aggregation)."""
    r = rng(seed)
    u = r.standard_normal((k, d)).astype(np.float32)
    w = r.uniform(0.5, 3.0, size=k).astype(np.float32)
    acc = jnp.array(u[0])
    w_acc = float(w[0])
    for j in range(1, k):
        acc = fused_agg.pair_merge(
            acc,
            jnp.array(u[j]),
            jnp.array([w_acc], dtype=np.float32),
            jnp.array([w[j]], dtype=np.float32),
            tile=TILE,
        )
        w_acc += float(w[j])
    want = ref.weighted_mean(jnp.array(u), jnp.array(w))
    np.testing.assert_allclose(np.asarray(acc), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_fedprox_mu_zero_is_weighted_mean():
    r = rng(7)
    u = r.standard_normal((4, TILE)).astype(np.float32)
    w = r.uniform(0.5, 2.0, size=4).astype(np.float32)
    g = r.standard_normal(TILE).astype(np.float32)
    got = fused_agg.fedprox_merge(
        jnp.array(u), jnp.array(w), jnp.array(g), jnp.array([0.0], dtype=np.float32), tile=TILE
    )
    want = ref.weighted_mean(jnp.array(u), jnp.array(w))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_fedprox_mu_one_is_global():
    r = rng(8)
    u = r.standard_normal((4, TILE)).astype(np.float32)
    w = r.uniform(0.5, 2.0, size=4).astype(np.float32)
    g = r.standard_normal(TILE).astype(np.float32)
    got = fused_agg.fedprox_merge(
        jnp.array(u), jnp.array(w), jnp.array(g), jnp.array([1.0], dtype=np.float32), tile=TILE
    )
    np.testing.assert_allclose(np.asarray(got), g, rtol=1e-6, atol=1e-6)


def test_bad_tiling_rejected():
    a = jnp.zeros((TILE + 1,), jnp.float32)
    w = jnp.ones((1,), jnp.float32)
    with pytest.raises(ValueError):
        fused_agg.pair_merge(a, a, w, w, tile=TILE)


def test_vmem_footprint_budget():
    """DESIGN.md §Perf: K=16 at the default tile stays under 4 MiB of VMEM."""
    assert fused_agg.vmem_footprint_bytes(16, fused_agg.DEFAULT_TILE) <= 4 * 1024 * 1024
