"""Kernel behaviours the Rust runtime's chunker relies on.

rust/src/runtime XlaFusion pads the last D-chunk with zeros and pads the
K-row slab with zero-*weight* rows. Both conventions must be exactly
neutral in the kernels.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import fused_agg, ref

TILE = 128


@settings(max_examples=20, deadline=None)
@given(
    k_real=st.integers(1, 7),
    seed=st.integers(0, 2**31 - 1),
)
def test_zero_weight_row_padding_is_neutral(k_real, seed):
    """fuse over k_real rows == fuse over 8 rows where the extra rows have
    weight 0 (and arbitrary garbage content)."""
    r = np.random.default_rng(seed)
    k_pad = 8
    u_real = r.standard_normal((k_real, TILE)).astype(np.float32)
    w_real = r.uniform(0.5, 4.0, size=k_real).astype(np.float32)
    garbage = r.standard_normal((k_pad - k_real, TILE)).astype(np.float32) * 1e3
    u_pad = np.concatenate([u_real, garbage])
    w_pad = np.concatenate([w_real, np.zeros(k_pad - k_real, dtype=np.float32)])

    got = fused_agg.fused_weighted_sum(jnp.array(u_pad), jnp.array(w_pad), tile=TILE)
    want = ref.fused_weighted_sum(jnp.array(u_real), jnp.array(w_real))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(n_real=st.integers(1, TILE - 1), seed=st.integers(0, 2**31 - 1))
def test_zero_tail_padding_passes_through_pair_merge(n_real, seed):
    """pair_merge on zero-padded tails returns the weighted mean on the
    real prefix and zeros on the tail (the Rust chunker slices the prefix
    back out)."""
    r = np.random.default_rng(seed)
    a = np.zeros(TILE, dtype=np.float32)
    b = np.zeros(TILE, dtype=np.float32)
    a[:n_real] = r.standard_normal(n_real).astype(np.float32)
    b[:n_real] = r.standard_normal(n_real).astype(np.float32)
    wa = np.array([2.0], dtype=np.float32)
    wb = np.array([3.0], dtype=np.float32)
    got = np.asarray(
        fused_agg.pair_merge(jnp.array(a), jnp.array(b), jnp.array(wa), jnp.array(wb), tile=TILE)
    )
    want = (2.0 * a[:n_real] + 3.0 * b[:n_real]) / 5.0
    np.testing.assert_allclose(got[:n_real], want, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got[n_real:], 0.0, atol=1e-7)


def test_k1_single_update_is_identity_mean():
    r = np.random.default_rng(0)
    u = r.standard_normal((1, TILE)).astype(np.float32)
    w = np.array([4.2], dtype=np.float32)
    s = fused_agg.fused_weighted_sum(jnp.array(u), jnp.array(w), tile=TILE)
    np.testing.assert_allclose(np.asarray(s) / w[0], u[0], rtol=1e-5, atol=1e-5)
