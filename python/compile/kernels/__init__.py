"""Layer-1 Pallas kernels (fused_agg) and their pure-jnp oracles (ref)."""

from . import fused_agg, ref  # noqa: F401
