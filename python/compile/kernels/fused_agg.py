"""Layer-1 Pallas kernels: the FL aggregation hot spot.

The paper (§2.1) defines aggregation of flattened model updates as a
coordinate-wise function over layer vectors:

    M1 ⊕ M2 = [f(M1[i], M2[i]) for i in 1..n]

These kernels implement the three fused forms the platform needs:

  * ``pair_merge``          — running weighted mean of a pair of updates;
                              this is the unit whose cost is the paper's
                              ``t_pair`` (§5.4, calibrated offline).
  * ``fused_weighted_sum``  — K-way weighted sum over a (K, D) block of
                              updates; the data-parallel inner step of
                              FedAvg / FedSGD aggregation.
  * ``fedprox_merge``       — K-way weighted mean pulled toward the current
                              global model with proximal coefficient ``mu``
                              (server-side merge used for FedProx jobs).

Hardware adaptation (DESIGN.md §4): the computation is element-wise
streaming arithmetic (VPU work, no MXU).  Updates are flattened to
``D``-vectors and the grid tiles ``D`` into ``TILE``-sized blocks so that a
(K, TILE) slab of updates streams through VMEM per grid step — the TPU
analogue of the paper's "how many updates fit into accelerator memory" term
in the C_agg estimate.  Accumulation is always f32.

All kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode is the correctness (and the
only runnable) path on this image.  Real-TPU performance is *estimated*
from the BlockSpec footprint in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile of the flattened-update axis. 8 KiB of f32 per update row —
# small enough that K=8 rows + accumulator stay well under a 4 MiB VMEM
# budget, large enough to amortize grid overhead. Must divide D.
DEFAULT_TILE = 2048

# All artifacts use interpret mode (see module docstring).
INTERPRET = True


def _resolve_tile(d: int, tile: int) -> int:
    """Pick the effective tile: D must be a multiple of it.

    Updates smaller than the requested tile run as a single grid step
    (tile = D); larger updates must be tile-aligned — the AOT shapes and the
    Rust chunker only ever produce aligned sizes.
    """
    if d % tile == 0:
        return tile
    if d < tile:
        return d
    raise ValueError(f"flattened size D={d} must be a multiple of tile={tile}")


# ---------------------------------------------------------------------------
# pair_merge: out = (wa * a + wb * b) / (wa + wb)
# ---------------------------------------------------------------------------


def _pair_merge_kernel(wa_ref, wb_ref, a_ref, b_ref, out_ref):
    """Running weighted mean of two update tiles.

    ``wa``/``wb`` are (1,)-shaped weights replicated across the grid. The
    merge keeps a running weighted mean rather than a weighted sum so that a
    chain of pair-merges (the sequential aggregation of §2.1) is numerically
    a single weighted average regardless of arrival order.
    """
    wa = wa_ref[0]
    wb = wb_ref[0]
    inv = 1.0 / (wa + wb)
    out_ref[...] = (a_ref[...] * wa + b_ref[...] * wb) * inv


@functools.partial(jax.jit, static_argnames=("tile",))
def pair_merge(a: jax.Array, b: jax.Array, wa: jax.Array, wb: jax.Array, *, tile: int = DEFAULT_TILE) -> jax.Array:
    """Weighted mean of updates ``a`` and ``b`` with weights ``wa``, ``wb``.

    a, b: f32[D]; wa, wb: f32[1]. Returns f32[D].
    """
    (d,) = a.shape
    tile = _resolve_tile(d, tile)
    grid = (d // tile,)
    return pl.pallas_call(
        _pair_merge_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=INTERPRET,
    )(wa, wb, a, b)


# ---------------------------------------------------------------------------
# fused_weighted_sum: out = sum_k w[k] * U[k, :]
# ---------------------------------------------------------------------------


def _weighted_sum_kernel(w_ref, u_ref, out_ref):
    """K-way weighted sum over a (K, TILE) slab.

    One pass over the slab: arithmetic intensity 2·K flop per 4·K bytes —
    memory-bound, so the schedule is a single HBM→VMEM stream per tile.
    """
    u = u_ref[...]  # (K, TILE)
    w = w_ref[...]  # (K,)
    out_ref[...] = jnp.sum(u * w[:, None], axis=0)


@functools.partial(jax.jit, static_argnames=("tile",))
def fused_weighted_sum(u: jax.Array, w: jax.Array, *, tile: int = DEFAULT_TILE) -> jax.Array:
    """``sum_k w[k] * u[k, :]`` for u: f32[K, D], w: f32[K] → f32[D]."""
    k, d = u.shape
    tile = _resolve_tile(d, tile)
    grid = (d // tile,)
    return pl.pallas_call(
        _weighted_sum_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k, tile), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=INTERPRET,
    )(w, u)


# ---------------------------------------------------------------------------
# fedprox_merge: out = (1 - mu) * weighted_mean(U, w) + mu * g
# ---------------------------------------------------------------------------


def _fedprox_kernel(w_ref, mu_ref, u_ref, g_ref, out_ref):
    """Weighted mean of K updates with a proximal pull toward the global model."""
    u = u_ref[...]  # (K, TILE)
    w = w_ref[...]  # (K,)
    mu = mu_ref[0]
    inv = 1.0 / jnp.sum(w)
    mean = jnp.sum(u * w[:, None], axis=0) * inv
    out_ref[...] = (1.0 - mu) * mean + mu * g_ref[...]


@functools.partial(jax.jit, static_argnames=("tile",))
def fedprox_merge(
    u: jax.Array, w: jax.Array, g: jax.Array, mu: jax.Array, *, tile: int = DEFAULT_TILE
) -> jax.Array:
    """FedProx server merge. u: f32[K,D], w: f32[K], g: f32[D], mu: f32[1]."""
    k, d = u.shape
    tile = _resolve_tile(d, tile)
    grid = (d // tile,)
    return pl.pallas_call(
        _fedprox_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((k, tile), lambda i: (0, i)),
            pl.BlockSpec((tile,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((d,), jnp.float32),
        interpret=INTERPRET,
    )(w, mu, u, g)


def vmem_footprint_bytes(k: int, tile: int = DEFAULT_TILE) -> int:
    """Estimated VMEM bytes resident per grid step of the K-way kernels.

    (K input rows + 1 global row + 1 output row) × tile × 4B + weights.
    Used by DESIGN.md §Perf to check the ≤4 MiB budget.
    """
    return (k + 2) * tile * 4 + (k + 1) * 4
