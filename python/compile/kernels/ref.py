"""Pure-jnp oracles for the Layer-1 Pallas kernels.

Each function here is the mathematical definition of the corresponding
kernel in ``fused_agg.py``; pytest/hypothesis assert allclose between the
two across shapes, weights and magnitudes (python/tests/test_kernel.py).
These oracles are also the ground truth mirrored by the pure-Rust fusion
path (rust/src/fusion), giving a three-way consistency check:
pallas == jnp == rust.
"""

from __future__ import annotations

import jax.numpy as jnp


def pair_merge(a, b, wa, wb):
    """Weighted mean of a pair: (wa*a + wb*b) / (wa + wb)."""
    wa = jnp.asarray(wa).reshape(())
    wb = jnp.asarray(wb).reshape(())
    return (a * wa + b * wb) / (wa + wb)


def fused_weighted_sum(u, w):
    """sum_k w[k] * u[k, :]."""
    return jnp.einsum("kd,k->d", u, w)


def weighted_mean(u, w):
    """Weighted mean over K updates (FedAvg fusion)."""
    return fused_weighted_sum(u, w) / jnp.sum(w)


def fedprox_merge(u, w, g, mu):
    """(1 - mu) * weighted_mean(U, w) + mu * g."""
    mu = jnp.asarray(mu).reshape(())
    return (1.0 - mu) * weighted_mean(u, w) + mu * g
