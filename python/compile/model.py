"""Layer-2 JAX graphs: fusion entry points + party-side local training.

Two families of build-time graphs, both AOT-lowered to HLO text by
``aot.py`` and executed from Rust via PJRT (rust/src/runtime):

Fusion graphs (the aggregator's compute, calling the L1 Pallas kernels):
  * ``fuse_pair``     — running weighted mean of two updates (t_pair unit).
  * ``fuse_k``        — FedAvg/FedSGD K-way weighted mean.
  * ``fedprox_fuse``  — FedProx server merge with proximal coefficient mu.

Training graphs (the *party-side substrate*: real local training for the
end-to-end example and for the periodicity/linearity measurements of
Figs 3-4):
  * ``train_step``    — one SGD minibatch step of an MLP classifier.
  * ``train_epoch``   — lax.scan over the minibatches of one local epoch.
  * ``eval_step``     — loss + #correct on a held-out batch.

The MLP is I -> H -> H -> C with ReLU and softmax cross-entropy. All
functions return flat tuples of arrays (return_tuple=True at lowering), so
the Rust side can decompose results without pytree knowledge.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import fused_agg

# Default MLP architecture for the end-to-end example. ~85k parameters:
# small enough that 8 parties x 100+ federated rounds of *real* training
# run in CPU-minutes (DESIGN.md §3 records the scale substitution), large
# enough to exercise multi-layer flatten/unflatten on the Rust side.
IN_DIM = 64
HIDDEN = 256
CLASSES = 10


def param_shapes(i: int = IN_DIM, h: int = HIDDEN, c: int = CLASSES):
    """(name, shape) table for the MLP parameters, in flattened order.

    Rust mirrors this ordering in workloads::mlp_layout.
    """
    return [
        ("w1", (i, h)),
        ("b1", (h,)),
        ("w2", (h, h)),
        ("b2", (h,)),
        ("w3", (h, c)),
        ("b3", (c,)),
    ]


# ---------------------------------------------------------------------------
# Fusion graphs (call the Pallas kernels)
# ---------------------------------------------------------------------------


def fuse_pair(a, b, wa, wb):
    """Weighted mean of two flattened updates. a,b: f32[D]; wa,wb: f32[1]."""
    return (fused_agg.pair_merge(a, b, wa, wb),)


def fuse_k(u, w):
    """FedAvg/FedSGD K-way fusion: weighted mean over u: f32[K,D], w: f32[K]."""
    s = fused_agg.fused_weighted_sum(u, w)
    return (s / jnp.sum(w),)


def fedprox_fuse(u, w, g, mu):
    """FedProx server merge: (1-mu)*weighted_mean(u,w) + mu*g."""
    return (fused_agg.fedprox_merge(u, w, g, mu),)


# ---------------------------------------------------------------------------
# MLP forward / loss
# ---------------------------------------------------------------------------


def _forward(params, x):
    w1, b1, w2, b2, w3, b3 = params
    h1 = jax.nn.relu(x @ w1 + b1)
    h2 = jax.nn.relu(h1 @ w2 + b2)
    return h2 @ w3 + b3


def _loss(params, x, y):
    """Stable softmax cross-entropy. y is one-hot f32[B, C]."""
    logits = _forward(params, x)
    logz = jax.scipy.special.logsumexp(logits, axis=-1, keepdims=True)
    logprob = logits - logz
    return -jnp.mean(jnp.sum(y * logprob, axis=-1))


# ---------------------------------------------------------------------------
# Training graphs
# ---------------------------------------------------------------------------


def train_step(w1, b1, w2, b2, w3, b3, x, y, lr):
    """One SGD minibatch step.

    x: f32[B, I]; y: one-hot f32[B, C]; lr: f32[1].
    Returns (w1', b1', w2', b2', w3', b3', loss[1]).
    """
    params = (w1, b1, w2, b2, w3, b3)
    loss, grads = jax.value_and_grad(_loss)(params, x, y)
    step = lr[0]
    new = tuple(p - step * g for p, g in zip(params, grads))
    return (*new, loss.reshape((1,)))


def train_epoch(w1, b1, w2, b2, w3, b3, xs, ys, lr):
    """One local epoch: scan train_step over N minibatches.

    xs: f32[N, B, I]; ys: f32[N, B, C]. Returns updated params + mean loss.
    Using lax.scan (not a Python loop) keeps the lowered HLO size O(1) in N
    and lets XLA pipeline the minibatches (DESIGN.md §Perf L2).
    """
    params = (w1, b1, w2, b2, w3, b3)

    def body(p, xy):
        x, y = xy
        loss, grads = jax.value_and_grad(_loss)(p, x, y)
        step = lr[0]
        return tuple(pi - step * gi for pi, gi in zip(p, grads)), loss

    new, losses = jax.lax.scan(body, params, (xs, ys))
    return (*new, jnp.mean(losses).reshape((1,)))


def eval_step(w1, b1, w2, b2, w3, b3, x, y):
    """Evaluation: (loss[1], n_correct[1]) on a batch."""
    params = (w1, b1, w2, b2, w3, b3)
    logits = _forward(params, x)
    logz = jax.scipy.special.logsumexp(logits, axis=-1, keepdims=True)
    logprob = logits - logz
    loss = -jnp.mean(jnp.sum(y * logprob, axis=-1))
    correct = jnp.sum(
        (jnp.argmax(logits, axis=-1) == jnp.argmax(y, axis=-1)).astype(jnp.float32)
    )
    return (loss.reshape((1,)), correct.reshape((1,)))
