"""AOT lowering: JAX graphs -> HLO *text* artifacts + manifest.json.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``). The HLO *text* parser reassigns ids, so text
round-trips cleanly. See /opt/xla-example/README.md.

Run once at build time (``make artifacts``); Python never runs on the
request path. The manifest lists, for every artifact, the entry name, file,
input shapes/dtypes, output arity and the lowering parameters, so the Rust
runtime (rust/src/runtime) can validate call sites at load time.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*dims):
    return jax.ShapeDtypeStruct(tuple(dims), F32)


class Builder:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.entries = []
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, arg_specs, n_outputs: int, meta: dict):
        """Lower ``fn`` at ``arg_specs`` and write ``<name>.hlo.txt``."""
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        self.entries.append(
            {
                "name": name,
                "file": fname,
                "inputs": [
                    {"dtype": "f32", "dims": list(s.shape)} for s in arg_specs
                ],
                "n_outputs": n_outputs,
                "meta": meta,
            }
        )
        print(f"  {name}: {len(text)} chars, {len(arg_specs)} inputs")

    def write_manifest(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump({"version": 1, "artifacts": self.entries}, f, indent=1)
        print(f"wrote {path} ({len(self.entries)} artifacts)")


def build_all(out_dir: str) -> None:
    b = Builder(out_dir)
    i, h, c = model.IN_DIM, model.HIDDEN, model.CLASSES
    pshapes = [s for (_, s) in model.param_shapes()]

    # --- fusion graphs -----------------------------------------------------
    for d in (65536, 1048576):
        b.emit(
            f"pair_merge_d{d}",
            model.fuse_pair,
            [spec(d), spec(d), spec(1), spec(1)],
            1,
            {"kind": "pair_merge", "d": d},
        )
    for k, d in ((8, 65536), (16, 65536), (8, 262144)):
        b.emit(
            f"fuse_k{k}_d{d}",
            model.fuse_k,
            [spec(k, d), spec(k)],
            1,
            {"kind": "fuse_k", "k": k, "d": d},
        )
    for k, d in ((8, 65536),):
        b.emit(
            f"fedprox_k{k}_d{d}",
            model.fedprox_fuse,
            [spec(k, d), spec(k), spec(d), spec(1)],
            1,
            {"kind": "fedprox", "k": k, "d": d},
        )

    # --- training graphs ---------------------------------------------------
    params = [spec(*s) for s in pshapes]
    for bsz in (16, 32, 64, 128):
        b.emit(
            f"train_step_b{bsz}",
            model.train_step,
            params + [spec(bsz, i), spec(bsz, c), spec(1)],
            7,
            {"kind": "train_step", "b": bsz, "i": i, "h": h, "c": c},
        )
    for n in (2, 4, 8, 16, 32):
        bsz = 32
        b.emit(
            f"train_epoch_n{n}_b{bsz}",
            model.train_epoch,
            params + [spec(n, bsz, i), spec(n, bsz, c), spec(1)],
            7,
            {"kind": "train_epoch", "n": n, "b": bsz, "i": i, "h": h, "c": c},
        )
    b.emit(
        "eval_b256",
        model.eval_step,
        params + [spec(256, i), spec(256, c)],
        2,
        {"kind": "eval", "b": 256, "i": i, "h": h, "c": c},
    )

    b.write_manifest()


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    build_all(args.out)


if __name__ == "__main__":
    main()
