"""Repo-root pytest hook: make `python/` importable so the suites can be
run either as `pytest python/tests/` (from the repo root) or `pytest
tests/` (from `python/`)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
