//! Regenerates **Fig 7**: mean aggregation latency with intermittent
//! heterogeneous parties — 3 workloads × {10,100,1000,10000} parties ×
//! {JIT, Batch λ, Eager λ, Eager AO}, 50 rounds each.
//!
//! Run: cargo bench --bench fig7_latency_intermittent
//! Env: FLJIT_BENCH_ROUNDS, FLJIT_BENCH_MAX_PARTIES to shrink the grid.

use fljit::bench::figs::LatencyGrid;
use fljit::party::FleetKind;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() {
    let grid = LatencyGrid {
        fleet: FleetKind::IntermittentHeterogeneous,
        rounds: env_usize("FLJIT_BENCH_ROUNDS", 50) as u32,
        seed: 0xF19,
        max_parties: env_usize("FLJIT_BENCH_MAX_PARTIES", 10000),
    };
    let t0 = std::time::Instant::now();
    let (tables, json) = grid.run();
    for t in tables {
        t.print();
        println!();
    }
    fljit::bench::dump("fig7", &json);
    println!("fig7 grid regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    println!(
        "expected shape (paper §6.4): JIT ≈ Eager λ ≈ Eager AO (low), Batch λ\n\
         highest; latency grows only mildly with fleet size."
    );
}
