//! Bench: the aggregation hot path — `t_pair` calibration (§5.4) across
//! the model zoo on the pure-Rust fusion engine, K-way weighted means
//! (fresh-alloc vs pooled scratch buffers), and the tree reduction
//! (persistent worker pool vs per-call thread spawn). Prints achieved GB/s
//! against the streaming roofline (pair merge touches 3 vectors: 2 reads +
//! 1 write) and writes every row to `BENCH_fusion.json` so the perf
//! trajectory is tracked across PRs.
//!
//! Run: cargo bench --bench fusion_hot_path

use fljit::bench::time_median;
use fljit::fusion::{self, ScratchPool, WorkerPool};
use fljit::model::{zoo, ModelSpec, ModelUpdate};
use fljit::util::json::Json;
use fljit::util::rng::Rng;
use fljit::util::table::Table;

fn row_json(case: &str, detail: &str, median_secs: f64, throughput: Option<(&str, f64)>) -> Json {
    let mut pairs = vec![
        ("case", Json::str(case)),
        ("detail", Json::str(detail)),
        ("median_secs", Json::num(median_secs)),
    ];
    if let Some((unit, v)) = throughput {
        pairs.push(("throughput", Json::num(v)));
        pairs.push(("throughput_unit", Json::str(unit)));
    }
    Json::obj(pairs)
}

fn main() {
    let reps = 7;
    let mut rng = Rng::new(42);
    let mut json_rows: Vec<Json> = Vec::new();

    // ------------------------------------------------------------------
    // 1) pair merge (t_pair, §5.4) across the zoo
    // ------------------------------------------------------------------
    let mut t = Table::new(
        "fusion hot path — pair merge (t_pair, §5.4)",
        &["model", "MB", "median t_pair (ms)", "best (ms)", "GB/s (median)"],
    );
    for name in zoo::all_names() {
        let spec = zoo::by_name(name).unwrap();
        let a = ModelUpdate::random(&spec, &mut rng, 1.0);
        let b = ModelUpdate::random(&spec, &mut rng, 1.0);
        let mut acc = a.data.clone();
        fusion::pair_merge_into(&mut acc, 1.0, &b.data, 1.0); // warm
        let (med, best) = time_median(reps, || {
            fusion::pair_merge_into(&mut acc, 2.0, &b.data, 1.0);
        });
        let mb = spec.size_bytes() as f64 / 1e6;
        let gbps = 3.0 * mb / 1e3 / med;
        t.row(vec![
            name.to_string(),
            format!("{:.1}", mb),
            format!("{:.2}", med * 1e3),
            format!("{:.2}", best * 1e3),
            format!("{:.2}", gbps),
        ]);
        json_rows.push(row_json("pair_merge", name, med, Some(("GB/s", gbps))));
    }
    t.print();

    // ------------------------------------------------------------------
    // 2) K-way fold: pair-merge chain vs cache-blocked weighted sum
    // ------------------------------------------------------------------
    let mut t2 = Table::new(
        "K-way fusion (EfficientNet-B7 updates, preallocated buffers)",
        &["K", "pair-chain (ms)", "blocked fold (ms)", "speedup", "fold GB/s"],
    );
    let spec = zoo::efficientnet_b7();
    let dim = spec.total_params();
    let mut out = vec![0.0f32; dim];
    for k in [2usize, 4, 8, 16] {
        let updates: Vec<ModelUpdate> = (0..k)
            .map(|i| ModelUpdate::random(&spec, &mut rng, 1.0 + i as f32))
            .collect();
        let views: Vec<&[f32]> = updates.iter().map(|u| u.data.as_slice()).collect();
        let ws: Vec<f32> = updates.iter().map(|u| u.weight).collect();
        // before: sequential pair merges (eager-style chain)
        let (chain_med, _) = time_median(5, || {
            out.copy_from_slice(&updates[0].data);
            let mut w_acc = ws[0];
            for (u, &w) in views[1..].iter().zip(&ws[1..]) {
                fusion::pair_merge_into(&mut out, w_acc, u, w);
                w_acc += w;
            }
            std::hint::black_box(out[0]);
        });
        // after: one cache-blocked pass
        let (fold_med, _) = time_median(5, || {
            fusion::wsum_blocked_into(&mut out, &views, &ws);
            std::hint::black_box(out[0]);
        });
        let gb = (k + 1) as f64 * spec.size_bytes() as f64 / 1e9;
        t2.row(vec![
            k.to_string(),
            format!("{:.1}", chain_med * 1e3),
            format!("{:.1}", fold_med * 1e3),
            format!("{:.2}x", chain_med / fold_med),
            format!("{:.2}", gb / fold_med),
        ]);
        json_rows.push(row_json(
            "kway_fold",
            &format!("k={k}"),
            fold_med,
            Some(("GB/s", gb / fold_med)),
        ));
    }
    t2.print();
    drop(out);

    // ------------------------------------------------------------------
    // 3) weighted mean: fresh allocation vs pooled scratch buffer
    // ------------------------------------------------------------------
    let mut t3 = Table::new(
        "weighted_mean — fresh Vec per call vs pooled scratch (K=8)",
        &["model", "fresh (ms)", "pooled (ms)", "speedup"],
    );
    let scratch = ScratchPool::global();
    for name in ["efficientnet-b7", "vgg16"] {
        let spec = zoo::by_name(name).unwrap();
        let updates: Vec<ModelUpdate> = (0..8)
            .map(|i| ModelUpdate::random(&spec, &mut rng, 1.0 + i as f32))
            .collect();
        let views: Vec<&[f32]> = updates.iter().map(|u| u.data.as_slice()).collect();
        let ws: Vec<f32> = updates.iter().map(|u| u.weight).collect();
        let (fresh_med, _) = time_median(5, || {
            let m = fusion::weighted_mean(&views, &ws);
            std::hint::black_box(m[0]);
        });
        drop(fusion::weighted_mean_pooled(scratch, &views, &ws)); // warm the pool
        let (pooled_med, _) = time_median(5, || {
            let m = fusion::weighted_mean_pooled(scratch, &views, &ws);
            std::hint::black_box(m[0]);
        });
        t3.row(vec![
            name.to_string(),
            format!("{:.1}", fresh_med * 1e3),
            format!("{:.1}", pooled_med * 1e3),
            format!("{:.2}x", fresh_med / pooled_med),
        ]);
        json_rows.push(row_json(
            "weighted_mean_pooled",
            name,
            pooled_med,
            Some(("speedup_vs_fresh", fresh_med / pooled_med)),
        ));
        json_rows.push(row_json("weighted_mean_fresh", name, fresh_med, None));
    }
    t3.print();

    // ------------------------------------------------------------------
    // 4) tree_reduce: persistent pool vs per-call thread spawn
    // ------------------------------------------------------------------
    // 2 MB updates keep K=128 in a ~256 MB working set; at these sizes the
    // per-shard work is small enough that spawn + page-fault overhead is
    // the dominant term the pool removes (the K ≥ 64 acceptance band).
    let spec = ModelSpec::new("synthetic-512k", vec![("flat", 512 * 1024)]);
    let shards = WorkerPool::global().threads().clamp(2, 8);
    let mut t4 = Table::new(
        &format!("tree_reduce — worker pool vs per-call spawn ({shards} shards, 2 MB updates)"),
        &["K", "spawn (ms)", "pool (ms)", "speedup"],
    );
    for k in [16usize, 64, 128] {
        let updates: Vec<ModelUpdate> = (0..k)
            .map(|i| ModelUpdate::random(&spec, &mut rng, 1.0 + (i % 7) as f32))
            .collect();
        // warm both paths (page in the data, fill the scratch pool)
        std::hint::black_box(fusion::tree_reduce_spawning(&updates, shards).weight);
        std::hint::black_box(fusion::tree_reduce(&updates, shards).weight);
        let (spawn_med, _) = time_median(5, || {
            let agg = fusion::tree_reduce_spawning(&updates, shards);
            std::hint::black_box(agg.weight);
        });
        let (pool_med, _) = time_median(5, || {
            let agg = fusion::tree_reduce(&updates, shards);
            std::hint::black_box(agg.weight);
        });
        t4.row(vec![
            k.to_string(),
            format!("{:.2}", spawn_med * 1e3),
            format!("{:.2}", pool_med * 1e3),
            format!("{:.2}x", spawn_med / pool_med),
        ]);
        json_rows.push(row_json(
            "tree_reduce_pool",
            &format!("k={k}"),
            pool_med,
            Some(("speedup_vs_spawn", spawn_med / pool_med)),
        ));
        json_rows.push(row_json("tree_reduce_spawn", &format!("k={k}"), spawn_med, None));
    }
    t4.print();

    // tree reduction wall time on a real zoo model (threads share DRAM bw)
    let spec = zoo::efficientnet_b7();
    let mut t5 = Table::new(
        "tree_reduce wall time (K=16, EfficientNet-B7, pooled)",
        &["shards", "median (ms)"],
    );
    let updates: Vec<ModelUpdate> = (0..16)
        .map(|i| ModelUpdate::random(&spec, &mut rng, 1.0 + i as f32))
        .collect();
    for shards in [1usize, 2, 4, 8] {
        let (med, _) = time_median(3, || {
            let agg = fusion::tree_reduce(&updates, shards);
            std::hint::black_box(agg.weight);
        });
        t5.row(vec![shards.to_string(), format!("{:.1}", med * 1e3)]);
        json_rows.push(row_json(
            "tree_reduce_scaling",
            &format!("shards={shards}"),
            med,
            None,
        ));
    }
    t5.print();
    println!("note: fusion is memory-bound; GB/s ≈ sustained stream bandwidth is the roofline.");

    let out = Json::obj(vec![
        ("bench", Json::str("fusion_hot_path")),
        ("rows", Json::Arr(json_rows)),
    ]);
    match std::fs::write("BENCH_fusion.json", out.pretty()) {
        Ok(()) => eprintln!("[rows written to BENCH_fusion.json]"),
        Err(e) => eprintln!("warn: could not write BENCH_fusion.json: {e}"),
    }
}
