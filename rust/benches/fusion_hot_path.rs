//! Bench: the aggregation hot path — `t_pair` calibration (§5.4) across
//! the model zoo on the pure-Rust fusion engine, plus K-way weighted means
//! and the tree reduction. Prints achieved GB/s against the streaming
//! roofline (pair merge touches 3 vectors: 2 reads + 1 write).
//!
//! Run: cargo bench --bench fusion_hot_path

use fljit::bench::time_median;
use fljit::fusion;
use fljit::model::{zoo, ModelUpdate};
use fljit::util::rng::Rng;
use fljit::util::table::Table;

fn main() {
    let reps = 7;
    let mut rng = Rng::new(42);

    let mut t = Table::new(
        "fusion hot path — pair merge (t_pair, §5.4)",
        &["model", "MB", "median t_pair (ms)", "best (ms)", "GB/s (median)"],
    );
    for name in zoo::all_names() {
        let spec = zoo::by_name(name).unwrap();
        let a = ModelUpdate::random(&spec, &mut rng, 1.0);
        let b = ModelUpdate::random(&spec, &mut rng, 1.0);
        let mut acc = a.data.clone();
        fusion::pair_merge_into(&mut acc, 1.0, &b.data, 1.0); // warm
        let (med, best) = time_median(reps, || {
            fusion::pair_merge_into(&mut acc, 2.0, &b.data, 1.0);
        });
        let mb = spec.size_bytes() as f64 / 1e6;
        t.row(vec![
            name.to_string(),
            format!("{:.1}", mb),
            format!("{:.2}", med * 1e3),
            format!("{:.2}", best * 1e3),
            format!("{:.2}", 3.0 * mb / 1e3 / med),
        ]);
    }
    t.print();

    // K-way fold: the §Perf L3 optimization — pair-merge chain (3 vectors
    // of DRAM traffic per update) vs the cache-blocked weighted sum
    // (~(K+1)/K vectors per update). Buffers preallocated so the bench
    // measures fusion math, not page faults.
    let mut t2 = Table::new(
        "K-way fusion (EfficientNet-B7 updates, preallocated buffers)",
        &["K", "pair-chain (ms)", "blocked fold (ms)", "speedup", "fold GB/s"],
    );
    let spec = zoo::efficientnet_b7();
    let dim = spec.total_params();
    let mut out = vec![0.0f32; dim];
    for k in [2usize, 4, 8, 16] {
        let updates: Vec<ModelUpdate> = (0..k)
            .map(|i| ModelUpdate::random(&spec, &mut rng, 1.0 + i as f32))
            .collect();
        let views: Vec<&[f32]> = updates.iter().map(|u| u.data.as_slice()).collect();
        let ws: Vec<f32> = updates.iter().map(|u| u.weight).collect();
        // before: sequential pair merges (eager-style chain)
        let (chain_med, _) = time_median(5, || {
            out.copy_from_slice(&updates[0].data);
            let mut w_acc = ws[0];
            for (u, &w) in views[1..].iter().zip(&ws[1..]) {
                fusion::pair_merge_into(&mut out, w_acc, u, w);
                w_acc += w;
            }
            std::hint::black_box(out[0]);
        });
        // after: one cache-blocked pass
        let (fold_med, _) = time_median(5, || {
            fusion::wsum_blocked_into(&mut out, &views, &ws);
            std::hint::black_box(out[0]);
        });
        let gb = (k + 1) as f64 * spec.size_bytes() as f64 / 1e9;
        t2.row(vec![
            k.to_string(),
            format!("{:.1}", chain_med * 1e3),
            format!("{:.1}", fold_med * 1e3),
            format!("{:.2}x", chain_med / fold_med),
            format!("{:.2}", gb / fold_med),
        ]);
    }
    t2.print();

    // tree reduction wall time (threads share DRAM bandwidth)
    let mut t3 = Table::new(
        "tree_reduce wall time (K=16, EfficientNet-B7)",
        &["shards", "median (ms)"],
    );
    let updates: Vec<ModelUpdate> = (0..16)
        .map(|i| ModelUpdate::random(&spec, &mut rng, 1.0 + i as f32))
        .collect();
    for shards in [1usize, 2, 4, 8] {
        let (med, _) = time_median(3, || {
            let agg = fusion::tree_reduce(&updates, shards);
            std::hint::black_box(agg.weight);
        });
        t3.row(vec![shards.to_string(), format!("{:.1}", med * 1e3)]);
    }
    t3.print();
    println!("note: fusion is memory-bound; GB/s ≈ sustained stream bandwidth is the roofline.");
}
