//! Bench: the multi-tenant broker sweep at full grid — 12 Poisson job
//! arrivals (one pinned at 10k parties) on a 96-container cluster, the
//! same trace replayed under every cross-job arbitration policy, with
//! per-job solo baselines for latency inflation. Every row lands in
//! `BENCH_broker.json` so the per-policy utilization / container-second
//! allocations are tracked across PRs.
//!
//! Run: cargo bench --bench broker_sweep
//! Tiny grids: cargo bench --bench broker_sweep -- --jobs 4 --max-parties 100

use fljit::bench::broker::{run_sweep, SweepConfig};
use fljit::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cfg = SweepConfig::from_args(&args);
    let t0 = std::time::Instant::now();
    let (tables, json) = run_sweep(&cfg);
    for t in &tables {
        t.print();
    }
    eprintln!("[sweep wall time: {:.2}s]", t0.elapsed().as_secs_f64());
    match std::fs::write("BENCH_broker.json", json.pretty()) {
        Ok(()) => eprintln!("[rows written to BENCH_broker.json]"),
        Err(e) => eprintln!("warn: could not write BENCH_broker.json: {e}"),
    }
}
