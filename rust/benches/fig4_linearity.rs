//! Regenerates **Fig 4**: minibatch time ∝ batch size and epoch time ∝
//! dataset size — real training sweeps over the `train_step_b{16..128}`
//! and `train_epoch_n{2..32}` artifacts, with OLS fits whose R² should be
//! ≈1 (the linearity claim of §4.2 that powers the §5.3 regression
//! fallback).
//!
//! Requires `make artifacts`. Run: cargo bench --bench fig4_linearity

fn main() {
    let reps = std::env::var("FLJIT_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(15);
    match fljit::bench::figs::fig4(reps, 42) {
        Ok((table, json)) => {
            table.print();
            fljit::bench::dump("fig4", &json);
            println!(
                "\nexpected shape (paper Fig 4): both sweeps are straight\n\
                 lines — R² close to 1 validates predicting unseen epoch\n\
                 times by linear regression (§4.2, §5.3)."
            );
        }
        Err(e) => {
            eprintln!("fig4 requires artifacts (`make artifacts`): {e:#}");
            std::process::exit(1);
        }
    }
}
