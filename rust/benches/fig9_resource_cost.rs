//! Regenerates **Fig 9** (the paper's main table): total container-seconds,
//! projected US$ cost (Azure rate $0.0002692/cs) and JIT's savings vs
//! Batch λ / Eager λ / Eager AO — 3 workloads × {active-homogeneous,
//! active-heterogeneous, intermittent-heterogeneous} × {10,100,1000,10000}
//! parties, 50 rounds each.
//!
//! Run: cargo bench --bench fig9_resource_cost
//! Env: FLJIT_BENCH_ROUNDS, FLJIT_BENCH_MAX_PARTIES to shrink the grid.

use fljit::bench::figs::ResourceGrid;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() {
    let grid = ResourceGrid {
        rounds: env_usize("FLJIT_BENCH_ROUNDS", 50) as u32,
        max_parties: env_usize("FLJIT_BENCH_MAX_PARTIES", 10000),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let (tables, json) = grid.run();
    for t in tables {
        t.print();
        println!();
    }
    fljit::bench::dump("fig9", &json);
    println!("fig9 grid regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    println!(
        "expected shape (paper §6.5): JIT ≤ Batch λ < Eager λ ≪ Eager AO;\n\
         savings ≈30-55% vs Batch λ at small fleets (parity at 10k — see\n\
         EXPERIMENTS.md deviations), 60-95% vs Eager λ, 94%+ vs AO and\n\
         >99% for intermittent fleets."
    );
}
