//! Regenerates **Fig 3**: minibatch and epoch times are ~constant across
//! repetitions — measured on *real* training through the AOT train
//! artifacts (L2 `train_step`/`train_epoch` on the PJRT CPU client). The
//! periodicity claim (§4.1) is a small coefficient of variation.
//!
//! Requires `make artifacts`. Run: cargo bench --bench fig3_periodicity

fn main() {
    let reps = std::env::var("FLJIT_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30);
    match fljit::bench::figs::fig3(reps, 42) {
        Ok((table, json)) => {
            table.print();
            fljit::bench::dump("fig3", &json);
            println!(
                "\nexpected shape (paper Fig 3): CV ≪ 1 — per-epoch and\n\
                 per-minibatch times are stable when data and hardware are\n\
                 fixed, which is what makes update arrivals predictable."
            );
        }
        Err(e) => {
            eprintln!("fig3 requires artifacts (`make artifacts`): {e:#}");
            std::process::exit(1);
        }
    }
}
