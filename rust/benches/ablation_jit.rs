//! Ablation bench (DESIGN.md §7): the design choices behind JIT
//! aggregation, each swept in isolation on a fixed scenario
//! (CIFAR100/EfficientNet-B7, 100 active heterogeneous parties, 20 rounds):
//!
//! * **safety margin** on the defer point `t_rnd − t_agg·(1+margin)` —
//!   latency insurance vs wasted container idle;
//! * **opportunism** (§5.5 priorities) on/off for intermittent fleets;
//! * **δ** — the scheduling-decision interval;
//! * **batch trigger size** for the Batch λ baseline (context for the
//!   paper's 2/10/100/100 choices).
//!
//! Run: cargo bench --bench ablation_jit

use fljit::coordinator::job::FlJobSpec;
use fljit::coordinator::platform::{Platform, PlatformConfig};
use fljit::metrics::JobReport;
use fljit::party::FleetKind;
use fljit::sim::secs;
use fljit::util::table::Table;
use fljit::workloads::Workload;

fn run(spec: &FlJobSpec, strategy: &str, mutate: impl FnOnce(&mut PlatformConfig)) -> JobReport {
    let mut cfg = PlatformConfig {
        seed: 0xAB1A,
        ..Default::default()
    };
    mutate(&mut cfg);
    let mut p = Platform::new(cfg);
    p.admit(spec.clone(), strategy);
    p.run().remove(0)
}

fn main() {
    let spec = FlJobSpec::new(
        Workload::cifar100_effnet(),
        FleetKind::ActiveHeterogeneous,
        100,
        20,
    );

    let mut t = Table::new(
        "ablation: JIT safety margin (t_rnd − t_agg·(1+m))",
        &["margin", "mean latency (s)", "p95 (s)", "container-s"],
    );
    for m in [0.0, 0.05, 0.10, 0.25, 0.50, 1.0] {
        let r = run(&spec, "jit", |c| c.jit_margin = Some(m));
        t.row(vec![
            format!("{m:.2}"),
            format!("{:.2}", r.mean_latency_secs()),
            format!("{:.2}", r.latency_p95()),
            format!("{:.0}", r.total_container_seconds()),
        ]);
    }
    t.print();
    println!();

    let mut spec_i = FlJobSpec::new(
        Workload::cifar100_effnet(),
        FleetKind::IntermittentHeterogeneous,
        200,
        10,
    );
    spec_i.t_wait_secs = 300.0;
    let mut t2 = Table::new(
        "ablation: opportunistic early start (§5.5) — intermittent fleet",
        &["opportunism", "mean latency (s)", "container-s", "deployments"],
    );
    for opp in [true, false] {
        let r = run(&spec_i, "jit", |c| c.opportunistic = opp);
        t2.row(vec![
            opp.to_string(),
            format!("{:.2}", r.mean_latency_secs()),
            format!("{:.0}", r.total_container_seconds()),
            r.deployments.to_string(),
        ]);
    }
    t2.print();
    println!();

    let mut t3 = Table::new(
        "ablation: scheduling interval δ (§5.5)",
        &["δ (s)", "mean latency (s)", "container-s"],
    );
    for delta in [0.1, 0.5, 2.0, 5.0, 15.0] {
        let r = run(&spec, "jit", |c| c.cluster.delta_tick = secs(delta));
        t3.row(vec![
            format!("{delta}"),
            format!("{:.2}", r.mean_latency_secs()),
            format!("{:.0}", r.total_container_seconds()),
        ]);
    }
    t3.print();
    println!();

    let mut t4 = Table::new(
        "ablation: Batch λ trigger size (paper uses 10 at 100 parties)",
        &["batch", "mean latency (s)", "container-s", "deployments"],
    );
    for b in [2usize, 5, 10, 25, 50, 100] {
        let r = run(&spec, "batched", |c| c.batch_override = Some(b));
        t4.row(vec![
            b.to_string(),
            format!("{:.2}", r.mean_latency_secs()),
            format!("{:.0}", r.total_container_seconds()),
            r.deployments.to_string(),
        ]);
    }
    t4.print();
    println!(
        "\nreading: small margins buy latency insurance almost for free;\n\
         opportunism trims latency without extra deployments; δ only\n\
         matters when it approaches the deferral window; batch size trades\n\
         deployments against tail latency — the paper's trigger choices\n\
         sit near the knee."
    );
}
