//! Bench: L3 coordinator hot paths — the discrete-event engine, the
//! cluster's indexed δ-tick scheduler, and a full 10k-party scenario.
//! Targets (DESIGN.md §Perf L3): ≥1M events/s through the engine; the
//! whole Fig 9 worst cell in low single-digit seconds.
//!
//! Run: cargo bench --bench scheduler_hot_path

use fljit::bench::time_median;
use fljit::cluster::{Cluster, ClusterConfig, TaskSpec};
use fljit::coordinator::job::FlJobSpec;
use fljit::coordinator::platform::run_scenario;
use fljit::party::FleetKind;
use fljit::sim::{secs, EventKind, EventQueue};
use fljit::util::table::Table;
use fljit::workloads::Workload;

fn main() {
    let mut t = Table::new(
        "L3 scheduler hot paths",
        &["case", "median", "throughput"],
    );

    // 1) raw event engine
    let n_events = 1_000_000u64;
    let (med, _) = time_median(3, || {
        let mut q = EventQueue::new();
        for i in 0..n_events {
            q.schedule_at((i * 7) % 10_000_000, EventKind::Custom { tag: i });
        }
        while q.next().is_some() {}
    });
    t.row(vec![
        format!("event engine ({n_events} sched+pop)"),
        format!("{:.1} ms", med * 1e3),
        format!("{:.2} M ev/s", n_events as f64 / med / 1e6),
    ]);

    // 2) cluster tick with a deep pending queue (indexed scheduler)
    let (med, _) = time_median(3, || {
        let mut q = EventQueue::new();
        let mut c = Cluster::new(ClusterConfig {
            capacity: 64,
            ..Default::default()
        });
        for i in 0..10_000usize {
            let task = c.submit(TaskSpec {
                job: i % 16,
                round: 0,
                priority: (i as i64 * 37) % 100_000,
                cold_start: secs(0.1),
                state_load: secs(0.1),
                checkpoint: secs(0.1),
                keep_alive: false,
            });
            c.push_work(&mut q, task, &[secs(0.5)]);
            c.request_finish(&mut q, task);
        }
        let mut ticks = 0u64;
        while ticks < 20_000 {
            c.on_tick(&mut q);
            ticks += 1;
            if q.next().is_none() {
                break;
            }
        }
    });
    t.row(vec![
        "cluster: 10k tasks through 64 slots".into(),
        format!("{:.1} ms", med * 1e3),
        "-".into(),
    ]);

    // 3) full worst-case Fig 9 cell: 10k intermittent parties × 50 rounds
    let spec = FlJobSpec::new(
        Workload::rvlcdip_vgg16(),
        FleetKind::IntermittentHeterogeneous,
        10_000,
        50,
    );
    for strat in ["jit", "eager-serverless", "eager-ao"] {
        let (med, _) = time_median(1, || {
            let r = run_scenario(&spec, strat, 7);
            std::hint::black_box(r.updates_fused);
        });
        t.row(vec![
            format!("10k-party × 50-round cell ({strat})"),
            format!("{:.2} s", med),
            format!("{:.0}k updates/s", 500.0 / med),
        ]);
    }
    t.print();
}
