//! Bench: L3 coordinator hot paths — the discrete-event engine (binary
//! heap vs two-level bucket queue, plain and cancel-heavy), the cluster's
//! indexed δ-tick scheduler, and a full 10k-party scenario cell swept both
//! sequentially and in parallel on the worker pool.
//! Targets (DESIGN.md §Perf L3): ≥1M events/s through the engine; the
//! whole Fig 9 worst cell in low single-digit seconds. Every row lands in
//! `BENCH_scheduler.json` so the perf trajectory is tracked across PRs.
//!
//! Run: cargo bench --bench scheduler_hot_path

use fljit::bench::figs::run_cells;
use fljit::bench::time_median;
use fljit::cluster::{Cluster, ClusterConfig, TaskSpec};
use fljit::coordinator::job::FlJobSpec;
use fljit::coordinator::platform::run_scenario;
use fljit::party::FleetKind;
use fljit::sim::{secs, EventKind, EventQueue, QueueKind};
use fljit::util::json::Json;
use fljit::util::table::Table;
use fljit::workloads::Workload;

fn row_json(case: &str, median_secs: f64, throughput: Option<(&str, f64)>) -> Json {
    let mut pairs = vec![
        ("case", Json::str(case)),
        ("median_secs", Json::num(median_secs)),
    ];
    if let Some((unit, v)) = throughput {
        pairs.push(("throughput", Json::num(v)));
        pairs.push(("throughput_unit", Json::str(unit)));
    }
    Json::obj(pairs)
}

fn main() {
    let mut t = Table::new("L3 scheduler hot paths", &["case", "median", "throughput"]);
    let mut json_rows: Vec<Json> = Vec::new();

    // 1) raw event engine: heap vs bucket backend
    let n_events = 1_000_000u64;
    for kind in [QueueKind::Heap, QueueKind::Bucket] {
        let (med, _) = time_median(3, || {
            let mut q = EventQueue::with_kind(kind);
            for i in 0..n_events {
                q.schedule_at((i * 7) % 10_000_000, EventKind::Custom { tag: i });
            }
            while q.next().is_some() {}
        });
        let evps = n_events as f64 / med;
        t.row(vec![
            format!("event engine {kind:?} ({n_events} sched+pop)"),
            format!("{:.1} ms", med * 1e3),
            format!("{:.2} M ev/s", evps / 1e6),
        ]);
        json_rows.push(row_json(
            &format!("engine_{kind:?}").to_lowercase(),
            med,
            Some(("events_per_sec", evps)),
        ));
    }

    // 1b) cancel-heavy profile: schedule, cancel half, drain the rest —
    // the JIT deadline-timer pattern (most timers are canceled by quorum)
    for kind in [QueueKind::Heap, QueueKind::Bucket] {
        let (med, _) = time_median(3, || {
            let mut q = EventQueue::with_kind(kind);
            let mut ids = Vec::with_capacity(n_events as usize / 2);
            for i in 0..n_events {
                let id = q.schedule_at((i * 7) % 10_000_000, EventKind::Custom { tag: i });
                if i % 2 == 0 {
                    ids.push(id);
                }
            }
            for id in ids {
                q.cancel(id);
            }
            while q.next().is_some() {}
        });
        let evps = n_events as f64 / med;
        t.row(vec![
            format!("cancel-heavy {kind:?} (1M sched, 500k cancel)"),
            format!("{:.1} ms", med * 1e3),
            format!("{:.2} M ev/s", evps / 1e6),
        ]);
        json_rows.push(row_json(
            &format!("cancel_heavy_{kind:?}").to_lowercase(),
            med,
            Some(("events_per_sec", evps)),
        ));
    }

    // 2) cluster tick with a deep pending queue (indexed scheduler)
    let (med, _) = time_median(3, || {
        let mut q = EventQueue::new();
        let mut c = Cluster::new(ClusterConfig {
            capacity: 64,
            ..Default::default()
        });
        for i in 0..10_000usize {
            let task = c.submit(TaskSpec {
                job: i % 16,
                round: 0,
                priority: (i as i64 * 37) % 100_000,
                cold_start: secs(0.1),
                state_load: secs(0.1),
                checkpoint: secs(0.1),
                keep_alive: false,
            });
            c.push_work(&mut q, task, &[secs(0.5)]);
            c.request_finish(&mut q, task);
        }
        let mut ticks = 0u64;
        while ticks < 20_000 {
            c.on_tick(&mut q);
            ticks += 1;
            if q.next().is_none() {
                break;
            }
        }
    });
    t.row(vec![
        "cluster: 10k tasks through 64 slots".into(),
        format!("{:.1} ms", med * 1e3),
        "-".into(),
    ]);
    json_rows.push(row_json("cluster_10k_tasks", med, None));

    // 3) full worst-case Fig 9 cell: 10k intermittent parties × 50 rounds
    let spec = FlJobSpec::new(
        Workload::rvlcdip_vgg16(),
        FleetKind::IntermittentHeterogeneous,
        10_000,
        50,
    );
    for strat in ["jit", "eager-serverless", "eager-ao"] {
        let (med, _) = time_median(1, || {
            let r = run_scenario(&spec, strat, 7);
            std::hint::black_box(r.updates_fused);
        });
        t.row(vec![
            format!("10k-party × 50-round cell ({strat})"),
            format!("{:.2} s", med),
            format!("{:.0}k updates/s", 500.0 / med),
        ]);
        json_rows.push(row_json(
            &format!("cell_10k_{strat}"),
            med,
            Some(("k_updates_per_sec", 500.0 / med)),
        ));
    }

    // 4) the same three cells swept in parallel on the worker pool — the
    // Fig 7/8/9 grid path after this PR
    let (med, _) = time_median(1, || {
        let cells = ["jit", "eager-serverless", "eager-ao"]
            .iter()
            .map(|s| (spec.clone(), *s, 7u64))
            .collect();
        let reports = run_cells(cells);
        std::hint::black_box(reports.len());
    });
    t.row(vec![
        "3 × 10k-party cells via worker pool".into(),
        format!("{:.2} s", med),
        format!("{:.0}k updates/s", 3.0 * 500.0 / med),
    ]);
    json_rows.push(row_json(
        "cells_10k_parallel_x3",
        med,
        Some(("k_updates_per_sec", 3.0 * 500.0 / med)),
    ));

    t.print();
    let out = Json::obj(vec![
        ("bench", Json::str("scheduler_hot_path")),
        ("rows", Json::Arr(json_rows)),
    ]);
    match std::fs::write("BENCH_scheduler.json", out.pretty()) {
        Ok(()) => eprintln!("[rows written to BENCH_scheduler.json]"),
        Err(e) => eprintln!("warn: could not write BENCH_scheduler.json: {e}"),
    }
}
