//! Regenerates **Fig 8**: mean aggregation latency with active
//! heterogeneous parties — the grid where training-time *prediction* does
//! the work (periodicity + linearity, §4): JIT must match eager latency
//! despite deploying just in time.
//!
//! Run: cargo bench --bench fig8_latency_active
//! Env: FLJIT_BENCH_ROUNDS, FLJIT_BENCH_MAX_PARTIES to shrink the grid.

use fljit::bench::figs::LatencyGrid;
use fljit::party::FleetKind;

fn env_usize(k: &str, d: usize) -> usize {
    std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
}

fn main() {
    let grid = LatencyGrid {
        fleet: FleetKind::ActiveHeterogeneous,
        rounds: env_usize("FLJIT_BENCH_ROUNDS", 50) as u32,
        seed: 0xF19,
        max_parties: env_usize("FLJIT_BENCH_MAX_PARTIES", 10000),
    };
    let t0 = std::time::Instant::now();
    let (tables, json) = grid.run();
    for t in tables {
        t.print();
        println!();
    }
    fljit::bench::dump("fig8", &json);
    println!("fig8 grid regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    println!(
        "expected shape (paper §6.4): JIT ≈ Eager (validation of the\n\
         training-time estimation thesis); Batch λ worst."
    );
}
