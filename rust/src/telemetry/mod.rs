//! Telemetry: structured spans, a metrics registry, and exporters.
//!
//! The paper's whole argument is measured in observables — aggregation
//! latency (§6.2), container-seconds, deployment counts (Figs 7–9) — but
//! the platform could only report them *after* a run via `Report`. This
//! subsystem makes a running mix observable: a lock-cheap [`Registry`] of
//! named counters, gauges and fixed-bucket histograms with per-job /
//! per-strategy label scoping, plus structured [`SpanKind`] spans
//! (`round`, `fuse`, `checkpoint`, `deploy`, `preempt`, `admission_wait`,
//! `party_wait`, `recovery`) recorded as begin/end pairs.
//!
//! **Time regime neutrality.** The registry never reads a clock: every
//! record call takes its timestamp *in* as a [`Time`] (µs). Simulation
//! passes virtual time, the wall regime passes wall time — same API, same
//! exporters. That is also what keeps telemetry strictly passive: it
//! touches no rng stream and schedules no events, so an enabled registry
//! produces bit-identical `Report`s to a disabled one (pinned by
//! `tests/telemetry.rs`).
//!
//! **No-op fast path.** A [`Registry`] is a clone-cheap handle around
//! `Option<Arc<..>>`; the default (disabled) registry is `None` and every
//! record call is a single branch. Enabled registries take one short
//! mutex per record — fine for control-plane rates (rounds, deploys,
//! folds), which is all we instrument.
//!
//! Exporters live in [`export`]: Prometheus-style text exposition, a
//! JSONL trace (one span/metric sample per line, written live when a
//! telemetry dir is configured), and a Chrome `trace_event` JSON file for
//! flamegraph-style round timelines (open in `chrome://tracing` or
//! <https://ui.perfetto.dev>).

pub mod export;

use std::collections::BTreeMap;
use std::fs;
use std::io::{self, BufWriter, Write as _};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::sim::{to_secs, Time};

// ---------------------------------------------------------------------------
// spans
// ---------------------------------------------------------------------------

/// The structured span vocabulary. One enum, not free-form strings, so
/// exporters and the `fljit top` summary agree on names.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SpanKind {
    /// One aggregation round, start → fuse.
    Round,
    /// The data-plane fold + finalize of one round.
    Fuse,
    /// A §5.5 checkpoint write.
    Checkpoint,
    /// A container deployment (cluster ledger entry).
    Deploy,
    /// A preemption decision (instantaneous).
    Preempt,
    /// Admission-queue wait, job arrival → release.
    AdmissionWait,
    /// One party's round latency, round start → update arrival.
    PartyWait,
    /// Durable data-plane recovery: WAL open → replay complete
    /// (`detail` = records recovered).
    Recovery,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Round => "round",
            SpanKind::Fuse => "fuse",
            SpanKind::Checkpoint => "checkpoint",
            SpanKind::Deploy => "deploy",
            SpanKind::Preempt => "preempt",
            SpanKind::AdmissionWait => "admission_wait",
            SpanKind::PartyWait => "party_wait",
            SpanKind::Recovery => "recovery",
        }
    }

    pub const ALL: [SpanKind; 8] = [
        SpanKind::Round,
        SpanKind::Fuse,
        SpanKind::Checkpoint,
        SpanKind::Deploy,
        SpanKind::Preempt,
        SpanKind::AdmissionWait,
        SpanKind::PartyWait,
        SpanKind::Recovery,
    ];
}

/// Begin or end of a span.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanPhase {
    Begin,
    End,
}

/// One recorded span edge. Begin/end pairs share the identity key
/// `(kind, job, round, detail)`; `detail` disambiguates within a round
/// (party id for `party_wait`, task id for `deploy`/`preempt`, 0
/// otherwise).
#[derive(Clone, Debug)]
pub struct SpanEvent {
    pub kind: SpanKind,
    pub job: usize,
    pub round: u32,
    pub detail: u64,
    pub phase: SpanPhase,
    pub at: Time,
}

// ---------------------------------------------------------------------------
// label scoping
// ---------------------------------------------------------------------------

/// Per-job / per-strategy label scope attached to metric samples.
#[derive(Clone, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub struct Scope {
    pub job: Option<usize>,
    pub strategy: Option<String>,
}

impl Scope {
    /// Unscoped (process/global metrics).
    pub fn none() -> Scope {
        Scope::default()
    }

    pub fn job(job: usize) -> Scope {
        Scope {
            job: Some(job),
            strategy: None,
        }
    }

    pub fn job_strategy(job: usize, strategy: &str) -> Scope {
        Scope {
            job: Some(job),
            strategy: Some(strategy.to_string()),
        }
    }

    /// A raw labelled scope for subsystems outside the job axis (e.g. MQ
    /// topics). Rendered as `key="value"`.
    pub fn label(key: &str, value: &str) -> Scope {
        Scope {
            job: None,
            strategy: Some(format!("{key}\u{0}{value}")),
        }
    }

    /// Prometheus-style label string, `{}`-less: `job="0",strategy="jit"`.
    /// Empty for an unscoped metric.
    pub fn render(&self) -> String {
        let mut parts = Vec::new();
        if let Some(j) = self.job {
            parts.push(format!("job=\"{j}\""));
        }
        if let Some(s) = &self.strategy {
            match s.split_once('\u{0}') {
                Some((k, v)) => parts.push(format!("{k}=\"{v}\"")),
                None => parts.push(format!("strategy=\"{s}\"")),
            }
        }
        parts.join(",")
    }
}

// ---------------------------------------------------------------------------
// metrics
// ---------------------------------------------------------------------------

/// A fixed-bucket histogram (Prometheus `le` semantics: cumulative at
/// export, per-bucket counts internally; the last implicit bucket is
/// `+Inf`).
#[derive(Clone, Debug)]
pub struct Histogram {
    /// Upper bounds, ascending. Counts has `bounds.len() + 1` slots.
    pub bounds: Vec<f64>,
    pub counts: Vec<u64>,
    pub sum: f64,
    pub count: u64,
}

impl Histogram {
    fn new(bounds: &[f64]) -> Histogram {
        Histogram {
            bounds: bounds.to_vec(),
            counts: vec![0; bounds.len() + 1],
            sum: 0.0,
            count: 0,
        }
    }

    fn observe(&mut self, v: f64) {
        let slot = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[slot] += 1;
        self.sum += v;
        self.count += 1;
    }
}

/// Default buckets for latency-shaped observations, in seconds.
pub const LATENCY_BUCKETS_SECS: [f64; 11] = [
    0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 10.0, 60.0, 600.0,
];

/// Metric identity: name + rendered label scope.
pub type Key = (String, String);

#[derive(Default)]
struct State {
    counters: BTreeMap<Key, u64>,
    gauges: BTreeMap<Key, f64>,
    histograms: BTreeMap<Key, Histogram>,
    spans: Vec<SpanEvent>,
}

struct Inner {
    state: Mutex<State>,
    /// Live JSONL writer (one span event per line), when a telemetry dir
    /// is configured. Metric samples are appended at export time.
    jsonl: Mutex<Option<BufWriter<fs::File>>>,
    dir: Option<PathBuf>,
}

/// The telemetry handle threaded through the platform. Clone-cheap;
/// `Registry::disabled()` (the default everywhere) makes every record
/// call a single `None` check.
#[derive(Clone, Default)]
pub struct Registry(Option<Arc<Inner>>);

impl Registry {
    /// The no-op registry: nothing is recorded, nothing is allocated.
    pub fn disabled() -> Registry {
        Registry(None)
    }

    /// An in-memory registry (exporters can still dump it on demand).
    pub fn enabled() -> Registry {
        Registry(Some(Arc::new(Inner {
            state: Mutex::new(State::default()),
            jsonl: Mutex::new(None),
            dir: None,
        })))
    }

    /// An enabled registry that also streams span events to
    /// `<dir>/telemetry.jsonl` as they are recorded (the directory is
    /// created; the file is truncated).
    pub fn with_dir<P: AsRef<Path>>(dir: P) -> io::Result<Registry> {
        let dir = dir.as_ref().to_path_buf();
        fs::create_dir_all(&dir)?;
        let f = fs::File::create(dir.join("telemetry.jsonl"))?;
        Ok(Registry(Some(Arc::new(Inner {
            state: Mutex::new(State::default()),
            jsonl: Mutex::new(Some(BufWriter::new(f))),
            dir: Some(dir),
        }))))
    }

    /// True when records are kept (the one branch on every call site).
    pub fn on(&self) -> bool {
        self.0.is_some()
    }

    /// The configured export directory, if any.
    pub fn dir(&self) -> Option<PathBuf> {
        self.0.as_ref().and_then(|i| i.dir.clone())
    }

    // -- metrics ---------------------------------------------------------

    pub fn counter_add(&self, name: &str, scope: &Scope, v: u64) {
        let Some(inner) = &self.0 else { return };
        let mut st = inner.state.lock().unwrap();
        *st.counters
            .entry((name.to_string(), scope.render()))
            .or_insert(0) += v;
    }

    pub fn gauge_set(&self, name: &str, scope: &Scope, v: f64) {
        let Some(inner) = &self.0 else { return };
        let mut st = inner.state.lock().unwrap();
        st.gauges.insert((name.to_string(), scope.render()), v);
    }

    /// Observe into a fixed-bucket histogram; buckets are fixed by the
    /// *first* observation of a (name, scope) pair.
    pub fn histogram_observe(&self, name: &str, scope: &Scope, v: f64, bounds: &[f64]) {
        let Some(inner) = &self.0 else { return };
        let mut st = inner.state.lock().unwrap();
        st.histograms
            .entry((name.to_string(), scope.render()))
            .or_insert_with(|| Histogram::new(bounds))
            .observe(v);
    }

    // -- spans -----------------------------------------------------------

    pub fn span_begin(&self, kind: SpanKind, job: usize, round: u32, detail: u64, at: Time) {
        self.span(SpanEvent {
            kind,
            job,
            round,
            detail,
            phase: SpanPhase::Begin,
            at,
        });
    }

    pub fn span_end(&self, kind: SpanKind, job: usize, round: u32, detail: u64, at: Time) {
        self.span(SpanEvent {
            kind,
            job,
            round,
            detail,
            phase: SpanPhase::End,
            at,
        });
    }

    /// An instantaneous span: begin and end at the same stamp (preempt
    /// decisions, checkpoint writes in virtual time).
    pub fn span_instant(&self, kind: SpanKind, job: usize, round: u32, detail: u64, at: Time) {
        self.span_begin(kind, job, round, detail, at);
        self.span_end(kind, job, round, detail, at);
    }

    fn span(&self, ev: SpanEvent) {
        let Some(inner) = &self.0 else { return };
        if let Some(w) = inner.jsonl.lock().unwrap().as_mut() {
            let _ = writeln!(w, "{}", export::span_line(&ev).print());
        }
        inner.state.lock().unwrap().spans.push(ev);
    }

    // -- snapshots (exporters) -------------------------------------------

    pub(crate) fn snapshot(
        &self,
    ) -> (
        BTreeMap<Key, u64>,
        BTreeMap<Key, f64>,
        BTreeMap<Key, Histogram>,
        Vec<SpanEvent>,
    ) {
        match &self.0 {
            None => Default::default(),
            Some(inner) => {
                let st = inner.state.lock().unwrap();
                (
                    st.counters.clone(),
                    st.gauges.clone(),
                    st.histograms.clone(),
                    st.spans.clone(),
                )
            }
        }
    }

    /// Append lines to the live JSONL (exporters use this for final
    /// metric samples) and flush it.
    pub(crate) fn jsonl_append(&self, lines: &[String]) {
        let Some(inner) = &self.0 else { return };
        if let Some(w) = inner.jsonl.lock().unwrap().as_mut() {
            for l in lines {
                let _ = writeln!(w, "{l}");
            }
            let _ = w.flush();
        }
    }

    /// Flush the live JSONL stream (no-op when disabled / in-memory).
    pub fn flush(&self) {
        let Some(inner) = &self.0 else { return };
        if let Some(w) = inner.jsonl.lock().unwrap().as_mut() {
            let _ = w.flush();
        }
    }
}

impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Registry({})", if self.on() { "on" } else { "off" })
    }
}

/// Helper: seconds between two µs stamps (for histogram observations of
/// span durations).
pub fn span_secs(begin: Time, end: Time) -> f64 {
    to_secs(end.saturating_sub(begin))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_registry_records_nothing() {
        let r = Registry::disabled();
        assert!(!r.on());
        r.counter_add("c", &Scope::none(), 3);
        r.gauge_set("g", &Scope::job(1), 2.5);
        r.histogram_observe("h", &Scope::none(), 0.1, &LATENCY_BUCKETS_SECS);
        r.span_begin(SpanKind::Round, 0, 0, 0, 0);
        let (c, g, h, s) = r.snapshot();
        assert!(c.is_empty() && g.is_empty() && h.is_empty() && s.is_empty());
    }

    #[test]
    fn counters_gauges_and_scopes_accumulate() {
        let r = Registry::enabled();
        let s0 = Scope::job_strategy(0, "jit");
        let s1 = Scope::job_strategy(1, "lazy");
        r.counter_add("rounds_total", &s0, 1);
        r.counter_add("rounds_total", &s0, 2);
        r.counter_add("rounds_total", &s1, 5);
        r.gauge_set("depth", &Scope::label("topic", "job0/models"), 7.0);
        let (c, g, _, _) = r.snapshot();
        assert_eq!(
            c[&("rounds_total".into(), "job=\"0\",strategy=\"jit\"".into())],
            3
        );
        assert_eq!(
            c[&("rounds_total".into(), "job=\"1\",strategy=\"lazy\"".into())],
            5
        );
        assert_eq!(g[&("depth".into(), "topic=\"job0/models\"".into())], 7.0);
    }

    #[test]
    fn histogram_buckets_are_fixed_and_cumulative_at_export() {
        let r = Registry::enabled();
        let sc = Scope::none();
        for v in [0.0005, 0.003, 0.003, 0.2, 1e9] {
            r.histogram_observe("lat", &sc, v, &LATENCY_BUCKETS_SECS);
        }
        let (_, _, h, _) = r.snapshot();
        let hist = &h[&("lat".into(), String::new())];
        assert_eq!(hist.count, 5);
        assert_eq!(hist.counts[0], 1); // <= 1ms
        assert_eq!(hist.counts[1], 2); // <= 5ms
        assert_eq!(*hist.counts.last().unwrap(), 1); // +Inf overflow
        assert!((hist.sum - (0.0005 + 0.003 + 0.003 + 0.2 + 1e9)).abs() < 1e-6);
    }

    #[test]
    fn span_pairs_share_an_identity_key() {
        let r = Registry::enabled();
        r.span_begin(SpanKind::Round, 2, 4, 0, 1_000);
        r.span_end(SpanKind::Round, 2, 4, 0, 9_000);
        r.span_instant(SpanKind::Preempt, 2, 4, 17, 5_000);
        let (_, _, _, spans) = r.snapshot();
        assert_eq!(spans.len(), 4);
        assert_eq!(spans[0].phase, SpanPhase::Begin);
        assert_eq!(spans[1].phase, SpanPhase::End);
        assert_eq!(spans[1].at - spans[0].at, 8_000);
        assert_eq!(spans[2].detail, 17);
    }

    #[test]
    fn scope_rendering_matches_prometheus_label_syntax() {
        assert_eq!(Scope::none().render(), "");
        assert_eq!(Scope::job(3).render(), "job=\"3\"");
        assert_eq!(
            Scope::job_strategy(0, "async-stale").render(),
            "job=\"0\",strategy=\"async-stale\""
        );
        assert_eq!(
            Scope::label("topic", "job0/round1/updates").render(),
            "topic=\"job0/round1/updates\""
        );
    }
}
