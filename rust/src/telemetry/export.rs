//! Telemetry exporters: Prometheus text exposition, JSONL trace, Chrome
//! `trace_event` JSON.
//!
//! All three read one [`Registry`] snapshot, so a single run can be
//! inspected as a scrape (`exposition.prom`), replayed line-by-line
//! (`telemetry.jsonl`), or opened as a flamegraph-style round timeline
//! (`trace.json` in `chrome://tracing` / <https://ui.perfetto.dev> —
//! jobs map to processes, span kinds to tracks).
//!
//! At export time the process-global fusion pool stats
//! ([`crate::fusion::pool::pool_stats`]) are sampled into the registry
//! as gauges (`fusion_pool_tasks_total`, `fusion_scratch_reuse_ratio`,
//! …) — the `WorkerPool`/`ScratchPool` are `OnceLock` singletons shared
//! by every session in the process, so their counters live beside the
//! pools, not in any one registry.

use std::fs;
use std::io;
use std::path::Path;

use crate::sim::to_secs;
use crate::util::json::Json;

use super::{Registry, Scope, SpanEvent, SpanPhase};

/// File names written by [`write_all`] under the telemetry dir.
pub const JSONL_FILE: &str = "telemetry.jsonl";
pub const EXPOSITION_FILE: &str = "exposition.prom";
pub const CHROME_TRACE_FILE: &str = "trace.json";

// ---------------------------------------------------------------------------
// JSONL
// ---------------------------------------------------------------------------

/// One span event as a JSONL line (`kind: "span"`). Written live by the
/// registry as spans are recorded.
pub fn span_line(ev: &SpanEvent) -> Json {
    Json::obj(vec![
        ("kind", Json::str("span")),
        ("span", Json::str(ev.kind.name())),
        (
            "phase",
            Json::str(match ev.phase {
                SpanPhase::Begin => "B",
                SpanPhase::End => "E",
            }),
        ),
        ("job", Json::num(ev.job as f64)),
        ("round", Json::num(ev.round as f64)),
        ("detail", Json::num(ev.detail as f64)),
        ("at_us", Json::num(ev.at as f64)),
    ])
}

/// Metric samples as JSONL lines (`kind: "counter" | "gauge" |
/// "histogram"`) — appended to the live stream at export time so the
/// file carries both the span timeline and the final metric state.
pub fn metric_lines(reg: &Registry) -> Vec<String> {
    let (counters, gauges, histograms, _) = reg.snapshot();
    let mut out = Vec::new();
    for ((name, labels), v) in &counters {
        out.push(
            Json::obj(vec![
                ("kind", Json::str("counter")),
                ("name", Json::str(name)),
                ("labels", Json::str(labels)),
                ("value", Json::num(*v as f64)),
            ])
            .print(),
        );
    }
    for ((name, labels), v) in &gauges {
        out.push(
            Json::obj(vec![
                ("kind", Json::str("gauge")),
                ("name", Json::str(name)),
                ("labels", Json::str(labels)),
                ("value", Json::num(*v)),
            ])
            .print(),
        );
    }
    for ((name, labels), h) in &histograms {
        out.push(
            Json::obj(vec![
                ("kind", Json::str("histogram")),
                ("name", Json::str(name)),
                ("labels", Json::str(labels)),
                ("sum", Json::num(h.sum)),
                ("count", Json::num(h.count as f64)),
                (
                    "bounds",
                    Json::arr(h.bounds.iter().map(|b| Json::num(*b))),
                ),
                (
                    "counts",
                    Json::arr(h.counts.iter().map(|c| Json::num(*c as f64))),
                ),
            ])
            .print(),
        );
    }
    out
}

// ---------------------------------------------------------------------------
// Prometheus text exposition
// ---------------------------------------------------------------------------

fn metric_line(name: &str, labels: &str, extra: &str, value: f64) -> String {
    let all = match (labels.is_empty(), extra.is_empty()) {
        (true, true) => String::new(),
        (false, true) => format!("{{{labels}}}"),
        (true, false) => format!("{{{extra}}}"),
        (false, false) => format!("{{{labels},{extra}}}"),
    };
    let v = if value.fract() == 0.0 && value.abs() < 9.0e15 {
        format!("{}", value as i64)
    } else {
        format!("{value}")
    };
    format!("{name}{all} {v}")
}

/// The full registry as Prometheus text exposition format (0.0.4):
/// `# TYPE` headers, one sample per line, histograms expanded into
/// cumulative `_bucket{le=..}` series plus `_sum`/`_count`.
pub fn prometheus_exposition(reg: &Registry) -> String {
    let (counters, gauges, histograms, _) = reg.snapshot();
    let mut out = String::new();
    let mut last_name = String::new();
    for ((name, labels), v) in &counters {
        if *name != last_name {
            out.push_str(&format!("# TYPE {name} counter\n"));
            last_name = name.clone();
        }
        out.push_str(&metric_line(name, labels, "", *v as f64));
        out.push('\n');
    }
    last_name.clear();
    for ((name, labels), v) in &gauges {
        if *name != last_name {
            out.push_str(&format!("# TYPE {name} gauge\n"));
            last_name = name.clone();
        }
        out.push_str(&metric_line(name, labels, "", *v));
        out.push('\n');
    }
    last_name.clear();
    for ((name, labels), h) in &histograms {
        if *name != last_name {
            out.push_str(&format!("# TYPE {name} histogram\n"));
            last_name = name.clone();
        }
        let mut cum = 0u64;
        for (i, b) in h.bounds.iter().enumerate() {
            cum += h.counts[i];
            let le = format!("le=\"{b}\"");
            out.push_str(&metric_line(
                &format!("{name}_bucket"),
                labels,
                &le,
                cum as f64,
            ));
            out.push('\n');
        }
        cum += h.counts[h.bounds.len()];
        out.push_str(&metric_line(
            &format!("{name}_bucket"),
            labels,
            "le=\"+Inf\"",
            cum as f64,
        ));
        out.push('\n');
        out.push_str(&metric_line(&format!("{name}_sum"), labels, "", h.sum));
        out.push('\n');
        out.push_str(&metric_line(
            &format!("{name}_count"),
            labels,
            "",
            h.count as f64,
        ));
        out.push('\n');
    }
    out
}

// ---------------------------------------------------------------------------
// Chrome trace_event
// ---------------------------------------------------------------------------

/// The span timeline as a Chrome `trace_event` JSON document: complete
/// (`"ph": "X"`) events with µs timestamps, `pid` = job id, `tid` = the
/// span kind's track. Unmatched begins export as zero-duration events so
/// a crashed run still renders.
pub fn chrome_trace(reg: &Registry) -> Json {
    let (_, _, _, spans) = reg.snapshot();
    // pair begin/end by identity key, FIFO within a key
    use std::collections::BTreeMap;
    let mut open: BTreeMap<(u8, usize, u32, u64), Vec<&SpanEvent>> = BTreeMap::new();
    let kind_ix = |ev: &SpanEvent| ev.kind as u8;
    let mut events = Vec::new();
    let mut complete = |b: &SpanEvent, end_at: u64, events: &mut Vec<Json>| {
        events.push(Json::obj(vec![
            ("name", Json::str(&format!("{} r{}", b.kind.name(), b.round))),
            ("cat", Json::str(b.kind.name())),
            ("ph", Json::str("X")),
            ("ts", Json::num(b.at as f64)),
            ("dur", Json::num(end_at.saturating_sub(b.at) as f64)),
            ("pid", Json::num(b.job as f64)),
            ("tid", Json::num(kind_ix(b) as f64)),
            (
                "args",
                Json::obj(vec![
                    ("round", Json::num(b.round as f64)),
                    ("detail", Json::num(b.detail as f64)),
                ]),
            ),
        ]));
    };
    for ev in &spans {
        let key = (kind_ix(ev), ev.job, ev.round, ev.detail);
        match ev.phase {
            SpanPhase::Begin => open.entry(key).or_default().push(ev),
            SpanPhase::End => {
                if let Some(b) = open.get_mut(&key).and_then(|v| {
                    if v.is_empty() {
                        None
                    } else {
                        Some(v.remove(0))
                    }
                }) {
                    complete(b, ev.at, &mut events);
                }
            }
        }
    }
    for stack in open.values() {
        for b in stack {
            complete(b, b.at, &mut events);
        }
    }
    // process names so the viewer shows "job N" instead of bare pids
    let jobs: std::collections::BTreeSet<usize> = spans.iter().map(|s| s.job).collect();
    for j in jobs {
        events.push(Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::num(j as f64)),
            ("tid", Json::num(0.0)),
            (
                "args",
                Json::obj(vec![("name", Json::str(&format!("job {j}")))]),
            ),
        ]));
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", Json::str("ms")),
    ])
}

// ---------------------------------------------------------------------------
// the one-call export
// ---------------------------------------------------------------------------

/// Sample the process-global fusion pool counters into `reg` as gauges.
/// Called by [`write_all`]; callable directly for in-memory registries.
pub fn sample_pool_stats(reg: &Registry) {
    if !reg.on() {
        return;
    }
    let st = crate::fusion::pool::pool_stats();
    let sc = Scope::none();
    reg.gauge_set("fusion_pool_tasks_total", &sc, st.tasks_run as f64);
    reg.gauge_set("fusion_pool_threads", &sc, st.threads as f64);
    reg.gauge_set("fusion_scratch_takes_total", &sc, (st.scratch_hits + st.scratch_misses) as f64);
    reg.gauge_set("fusion_scratch_reuse_hits", &sc, st.scratch_hits as f64);
    reg.gauge_set("fusion_scratch_fresh_allocs", &sc, st.scratch_misses as f64);
    let takes = st.scratch_hits + st.scratch_misses;
    let ratio = if takes == 0 {
        0.0
    } else {
        st.scratch_hits as f64 / takes as f64
    };
    reg.gauge_set("fusion_scratch_reuse_ratio", &sc, ratio);
}

/// Write every export format under `dir`: flush + finalize the JSONL
/// (appending final metric samples), the Prometheus exposition, and the
/// Chrome trace. Also samples the fusion pool stats first, so the dumps
/// carry fold throughput and scratch reuse.
pub fn write_all<P: AsRef<Path>>(reg: &Registry, dir: P) -> io::Result<()> {
    let dir = dir.as_ref();
    fs::create_dir_all(dir)?;
    sample_pool_stats(reg);
    // JSONL: the registry streamed spans here live if it was opened with
    // `with_dir`; append the metric state and flush. An in-memory
    // registry writes the whole file from the snapshot instead.
    let lines = metric_lines(reg);
    if reg.dir().as_deref() == Some(dir) {
        reg.jsonl_append(&lines);
    } else {
        let (_, _, _, spans) = reg.snapshot();
        let mut all: Vec<String> = spans.iter().map(|ev| span_line(ev).print()).collect();
        all.extend(lines);
        fs::write(dir.join(JSONL_FILE), all.join("\n") + "\n")?;
    }
    fs::write(dir.join(EXPOSITION_FILE), prometheus_exposition(reg))?;
    fs::write(dir.join(CHROME_TRACE_FILE), chrome_trace(reg).pretty())?;
    Ok(())
}

// ---------------------------------------------------------------------------
// `fljit top`: summarize a telemetry dir
// ---------------------------------------------------------------------------

/// Per-job aggregates distilled from a JSONL trace, for the `fljit top`
/// live summary.
#[derive(Clone, Debug, Default)]
pub struct JobTop {
    pub job: usize,
    pub rounds: u64,
    pub round_secs_sum: f64,
    pub fuses: u64,
    pub checkpoints: u64,
    pub deploys: u64,
    pub preempts: u64,
    pub admission_wait_secs: f64,
    pub party_waits: u64,
    pub party_wait_secs_sum: f64,
    pub last_at_secs: f64,
    /// Learned arrival-lag quantiles from the adaptive policy's gauges
    /// (`adaptive_arrival_p{50,90,99}_secs`); 0.0 until the job's first
    /// adaptive round completes (or forever, with adaptation off).
    pub arrival_p50_secs: f64,
    pub arrival_p90_secs: f64,
    pub arrival_p99_secs: f64,
    /// Current learned fuse-deadline defer (`adaptive_deadline_secs`).
    pub deadline_secs: f64,
}

impl JobTop {
    pub fn mean_round_secs(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.round_secs_sum / self.rounds as f64
        }
    }

    pub fn mean_party_wait_secs(&self) -> f64 {
        if self.party_waits == 0 {
            0.0
        } else {
            self.party_wait_secs_sum / self.party_waits as f64
        }
    }
}

/// Parse a `telemetry.jsonl` body into per-job aggregates (ignores
/// malformed lines — the file may be mid-write on a live run).
pub fn summarize_jsonl(body: &str) -> Vec<JobTop> {
    use std::collections::BTreeMap;
    let mut begins: BTreeMap<(String, usize, u32, u64), Vec<u64>> = BTreeMap::new();
    let mut tops: BTreeMap<usize, JobTop> = BTreeMap::new();
    for line in body.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let Ok(v) = Json::parse(line) else { continue };
        if v.get("kind").as_str() == Some("gauge") {
            // adaptive-policy gauges carry the job in their label string
            // (`job="N",strategy="..."`) rather than a span's job field
            let (Some(name), Some(labels), Some(value)) = (
                v.get("name").as_str(),
                v.get("labels").as_str(),
                v.get("value").as_f64(),
            ) else {
                continue;
            };
            let Some(job) = labels
                .split(',')
                .find_map(|l| l.strip_prefix("job=\""))
                .and_then(|rest| rest.strip_suffix('"').or(rest.split('"').next()))
                .and_then(|n| n.parse::<usize>().ok())
            else {
                continue;
            };
            let top = tops.entry(job).or_insert_with(|| JobTop {
                job,
                ..JobTop::default()
            });
            match name {
                "adaptive_arrival_p50_secs" => top.arrival_p50_secs = value,
                "adaptive_arrival_p90_secs" => top.arrival_p90_secs = value,
                "adaptive_arrival_p99_secs" => top.arrival_p99_secs = value,
                "adaptive_deadline_secs" => top.deadline_secs = value,
                _ => {}
            }
            continue;
        }
        if v.get("kind").as_str() != Some("span") {
            continue;
        }
        let (Some(span), Some(phase), Some(job), Some(at)) = (
            v.get("span").as_str().map(String::from),
            v.get("phase").as_str().map(String::from),
            v.get("job").as_usize(),
            v.get("at_us").as_u64(),
        ) else {
            continue;
        };
        let round = v.get("round").as_u64().unwrap_or(0) as u32;
        let detail = v.get("detail").as_u64().unwrap_or(0);
        let top = tops.entry(job).or_insert_with(|| JobTop {
            job,
            ..JobTop::default()
        });
        top.last_at_secs = top.last_at_secs.max(to_secs(at));
        let key = (span.clone(), job, round, detail);
        if phase == "B" {
            begins.entry(key).or_default().push(at);
            continue;
        }
        let dur = begins
            .get_mut(&key)
            .and_then(|v| if v.is_empty() { None } else { Some(v.remove(0)) })
            .map(|b| to_secs(at.saturating_sub(b)))
            .unwrap_or(0.0);
        match span.as_str() {
            "round" => {
                top.rounds += 1;
                top.round_secs_sum += dur;
            }
            "fuse" => top.fuses += 1,
            "checkpoint" => top.checkpoints += 1,
            "deploy" => top.deploys += 1,
            "preempt" => top.preempts += 1,
            "admission_wait" => top.admission_wait_secs += dur,
            "party_wait" => {
                top.party_waits += 1;
                top.party_wait_secs_sum += dur;
            }
            _ => {}
        }
    }
    tops.into_values().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{Registry, Scope, SpanKind, LATENCY_BUCKETS_SECS};

    fn filled() -> Registry {
        let r = Registry::enabled();
        r.counter_add("rounds_total", &Scope::job_strategy(0, "jit"), 3);
        r.gauge_set("depth", &Scope::label("topic", "job0/models"), 2.0);
        r.histogram_observe(
            "round_latency_secs",
            &Scope::job(0),
            0.25,
            &LATENCY_BUCKETS_SECS,
        );
        r.span_begin(SpanKind::Round, 0, 1, 0, 1_000_000);
        r.span_end(SpanKind::Round, 0, 1, 0, 3_500_000);
        r.span_instant(SpanKind::Preempt, 0, 1, 4, 2_000_000);
        r
    }

    #[test]
    fn exposition_has_type_headers_and_histogram_series() {
        let text = prometheus_exposition(&filled());
        assert!(text.contains("# TYPE rounds_total counter"));
        assert!(text.contains("rounds_total{job=\"0\",strategy=\"jit\"} 3"));
        assert!(text.contains("# TYPE depth gauge"));
        assert!(text.contains("depth{topic=\"job0/models\"} 2"));
        assert!(text.contains("round_latency_secs_bucket{job=\"0\",le=\"0.5\"} 1"));
        assert!(text.contains("round_latency_secs_bucket{job=\"0\",le=\"+Inf\"} 1"));
        assert!(text.contains("round_latency_secs_count{job=\"0\"} 1"));
    }

    #[test]
    fn chrome_trace_pairs_spans_into_complete_events() {
        let doc = chrome_trace(&filled());
        let evs = doc.get("traceEvents").as_arr().unwrap();
        let round = evs
            .iter()
            .find(|e| e.get("cat").as_str() == Some("round"))
            .unwrap();
        assert_eq!(round.get("ph").as_str(), Some("X"));
        assert_eq!(round.get("ts").as_u64(), Some(1_000_000));
        assert_eq!(round.get("dur").as_u64(), Some(2_500_000));
        assert_eq!(round.get("pid").as_u64(), Some(0));
        let preempt = evs
            .iter()
            .find(|e| e.get("cat").as_str() == Some("preempt"))
            .unwrap();
        assert_eq!(preempt.get("dur").as_u64(), Some(0));
        assert!(evs
            .iter()
            .any(|e| e.get("ph").as_str() == Some("M")), "process_name metadata");
    }

    #[test]
    fn jsonl_lines_parse_and_summarize() {
        let r = filled();
        let mut body: Vec<String> = {
            let (_, _, _, spans) = r.snapshot();
            spans.iter().map(|ev| span_line(ev).print()).collect()
        };
        body.extend(metric_lines(&r));
        for line in &body {
            Json::parse(line).expect("every JSONL line parses");
        }
        let tops = summarize_jsonl(&body.join("\n"));
        assert_eq!(tops.len(), 1);
        assert_eq!(tops[0].rounds, 1);
        assert!((tops[0].mean_round_secs() - 2.5).abs() < 1e-9);
        assert_eq!(tops[0].preempts, 1);
    }

    #[test]
    fn summarize_picks_up_adaptive_gauges() {
        let body = [
            r#"{"kind":"span","span":"fuse","phase":"E","job":2,"round":0,"detail":0,"at_us":5}"#,
            r#"{"kind":"gauge","name":"adaptive_arrival_p50_secs","labels":"job=\"2\",strategy=\"jit\"","value":1.5}"#,
            r#"{"kind":"gauge","name":"adaptive_arrival_p90_secs","labels":"job=\"2\",strategy=\"jit\"","value":3.25}"#,
            r#"{"kind":"gauge","name":"adaptive_arrival_p99_secs","labels":"job=\"2\",strategy=\"jit\"","value":4.0}"#,
            r#"{"kind":"gauge","name":"adaptive_deadline_secs","labels":"job=\"2\",strategy=\"jit\"","value":2.75}"#,
            r#"{"kind":"gauge","name":"fusion_pool_threads","labels":"","value":8}"#,
        ]
        .join("\n");
        let tops = summarize_jsonl(&body);
        assert_eq!(tops.len(), 1, "unscoped gauges must not invent jobs");
        let t = &tops[0];
        assert_eq!(t.job, 2);
        assert_eq!(t.fuses, 1);
        assert!((t.arrival_p50_secs - 1.5).abs() < 1e-12);
        assert!((t.arrival_p90_secs - 3.25).abs() < 1e-12);
        assert!((t.arrival_p99_secs - 4.0).abs() < 1e-12);
        assert!((t.deadline_secs - 2.75).abs() < 1e-12);
    }

    #[test]
    fn summarize_skips_malformed_lines() {
        let body = "garbage\n{\"kind\":\"span\",\"span\":\"fuse\",\"phase\":\"E\",\"job\":1,\"round\":0,\"detail\":0,\"at_us\":5}\n{half";
        let tops = summarize_jsonl(body);
        assert_eq!(tops.len(), 1);
        assert_eq!(tops[0].fuses, 1);
    }
}
