//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! The bridge between L3 (this crate) and the build-time L1/L2 python
//! layers. `make artifacts` writes `artifacts/*.hlo.txt` plus
//! `manifest.json`; this module loads the manifest, compiles each entry on
//! the PJRT CPU client on first use, and exposes typed call helpers:
//!
//! * [`XlaFusion`] — model-update fusion through the Pallas-kernel-bearing
//!   artifacts (`pair_merge_*`, `fuse_k*`, `fedprox_*`), chunking arbitrary
//!   model sizes over the fixed artifact shapes;
//! * [`Trainer`] — real local training for emulated parties
//!   (`train_step_*`, `train_epoch_*`, `eval_*`).
//!
//! HLO **text** is the interchange format (not serialized protos): jax≥0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids. See python/compile/aot.py and
//! /opt/xla-example/README.md.
//!
//! The PJRT bridge needs the `xla` (xla_extension) crate, which the
//! offline image does not ship. It is gated behind the `xla` cargo
//! feature: without it this module still exposes the same types and
//! signatures (manifest loading, parameter layout, host-side `Trainer`
//! state) but every method that would execute an artifact returns a clear
//! error. Use [`xla_enabled`] to branch.

#[cfg(feature = "xla")]
use std::cell::RefCell;
#[cfg(feature = "xla")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Whether this build carries the PJRT/XLA runtime.
pub fn xla_enabled() -> bool {
    cfg!(feature = "xla")
}

/// One artifact's manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    /// Input dims (all f32).
    pub inputs: Vec<Vec<usize>>,
    pub n_outputs: usize,
    pub meta: Json,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let mut artifacts = Vec::new();
        for e in v.get("artifacts").as_arr().unwrap_or(&[]) {
            let inputs = e
                .get("inputs")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|i| {
                    i.get("dims")
                        .as_arr()
                        .unwrap_or(&[])
                        .iter()
                        .filter_map(|d| d.as_usize())
                        .collect()
                })
                .collect();
            artifacts.push(ArtifactInfo {
                name: e.get("name").as_str().unwrap_or_default().to_string(),
                file: e.get("file").as_str().unwrap_or_default().to_string(),
                inputs,
                n_outputs: e.get("n_outputs").as_usize().unwrap_or(1),
                meta: e.get("meta").clone(),
            });
        }
        if artifacts.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(Manifest { artifacts })
    }

    pub fn find(&self, name: &str) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| a.name == name)
    }
}

/// Locate the artifacts directory: $FLJIT_ARTIFACTS, ./artifacts, or
/// relative to the crate root.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(p) = std::env::var("FLJIT_ARTIFACTS") {
        return PathBuf::from(p);
    }
    for cand in ["artifacts", concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")] {
        let p = PathBuf::from(cand);
        if p.join("manifest.json").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

/// PJRT CPU runtime with a lazily compiled executable cache.
///
/// Not `Send`: PJRT client handles are thread-local by construction here;
/// each live-party thread builds its own `Runtime`.
#[cfg(feature = "xla")]
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    exes: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

/// Stub runtime for builds without the `xla` feature: constructors fail
/// with a clear error, so every caller degrades gracefully.
#[cfg(not(feature = "xla"))]
pub struct Runtime {
    pub dir: PathBuf,
    pub manifest: Manifest,
}

#[cfg(not(feature = "xla"))]
impl Runtime {
    pub fn new(dir: &Path) -> Result<Runtime> {
        let _ = dir;
        bail!(
            "fljit was built without the `xla` feature; the PJRT/XLA runtime \
             is unavailable (rebuild with `--features xla` and the vendored \
             xla_extension crate)"
        )
    }

    pub fn with_default_dir() -> Result<Runtime> {
        Self::new(&default_artifact_dir())
    }
}

#[cfg(feature = "xla")]
impl Runtime {
    pub fn new(dir: &Path) -> Result<Runtime> {
        // Quiet the TfrtCpuClient created/destroyed info lines unless the
        // user asked for them.
        if std::env::var("TF_CPP_MIN_LOG_LEVEL").is_err() {
            std::env::set_var("TF_CPP_MIN_LOG_LEVEL", "1");
        }
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        Ok(Runtime {
            client,
            dir: dir.to_path_buf(),
            manifest,
            exes: RefCell::new(HashMap::new()),
        })
    }

    pub fn with_default_dir() -> Result<Runtime> {
        Self::new(&default_artifact_dir())
    }

    fn executable(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.borrow().get(name) {
            return Ok(exe.clone());
        }
        let info = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        let path = self.dir.join(&info.file);
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("parse HLO {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let rc = std::rc::Rc::new(exe);
        self.exes.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Execute `name` on literals; returns the decomposed output tuple.
    pub fn call(&self, name: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let info = self
            .manifest
            .find(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        if args.len() != info.inputs.len() {
            bail!(
                "artifact '{name}' expects {} inputs, got {}",
                info.inputs.len(),
                args.len()
            );
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let mut lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result of {name}: {e:?}"))?;
        let parts = lit
            .decompose_tuple()
            .map_err(|e| anyhow!("decompose result of {name}: {e:?}"))?;
        if parts.len() != info.n_outputs {
            bail!(
                "artifact '{name}': expected {} outputs, got {}",
                info.n_outputs,
                parts.len()
            );
        }
        Ok(parts)
    }

    /// Build an f32 literal of the given shape.
    pub fn literal(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
        let numel: usize = dims.iter().product();
        if numel != data.len() {
            bail!("literal shape {:?} != data len {}", dims, data.len());
        }
        let flat = xla::Literal::vec1(data);
        if dims.len() == 1 {
            return Ok(flat);
        }
        let dims_i64: Vec<i64> = dims.iter().map(|d| *d as i64).collect();
        flat.reshape(&dims_i64)
            .map_err(|e| anyhow!("reshape: {e:?}"))
    }

    pub fn to_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
        lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }
}

// ---------------------------------------------------------------------------
// fusion through the artifacts
// ---------------------------------------------------------------------------

/// XLA-backed fusion: the request-path compute of the aggregator, running
/// the Pallas-kernel artifacts. Mirrors `fusion::` pure-Rust math; the
/// integration tests pin both to agree.
pub struct XlaFusion<'r> {
    pub rt: &'r Runtime,
    /// Chunk width — must match a `pair_merge_d{D}` / `fuse_k{K}_d{D}` pair.
    pub chunk: usize,
    pub k: usize,
}

impl<'r> XlaFusion<'r> {
    pub fn new(rt: &'r Runtime) -> XlaFusion<'r> {
        XlaFusion {
            rt,
            chunk: 65536,
            k: 8,
        }
    }
}

/// Stub fusion for builds without the `xla` feature. Unreachable in
/// practice (the stub `Runtime` cannot be constructed) but keeps every
/// caller compiling with identical signatures.
#[cfg(not(feature = "xla"))]
impl XlaFusion<'_> {
    pub fn pair_merge(
        &self,
        _acc: &mut [f32],
        _w_acc: f32,
        _upd: &[f32],
        _w_upd: f32,
    ) -> Result<()> {
        bail!("XLA fusion unavailable: built without the `xla` feature")
    }

    pub fn weighted_mean(&self, _updates: &[&[f32]], _w: &[f32]) -> Result<Vec<f32>> {
        bail!("XLA fusion unavailable: built without the `xla` feature")
    }

    pub fn fedprox(
        &self,
        _updates: &[&[f32]],
        _w: &[f32],
        _global: &[f32],
        _mu: f32,
    ) -> Result<Vec<f32>> {
        bail!("XLA fusion unavailable: built without the `xla` feature")
    }
}

#[cfg(feature = "xla")]
impl<'r> XlaFusion<'r> {
    fn pair_name(&self) -> String {
        format!("pair_merge_d{}", self.chunk)
    }

    fn fuse_name(&self) -> String {
        format!("fuse_k{}_d{}", self.k, self.chunk)
    }

    /// acc ← weighted mean of (acc, w_acc) and (upd, w_upd), chunked.
    pub fn pair_merge(&self, acc: &mut [f32], w_acc: f32, upd: &[f32], w_upd: f32) -> Result<()> {
        anyhow::ensure!(acc.len() == upd.len(), "length mismatch");
        let name = self.pair_name();
        let d = self.chunk;
        let wa = xla::Literal::vec1(&[w_acc]);
        let wb = xla::Literal::vec1(&[w_upd]);
        // Chunk staging buffers come from the global scratch pool and are
        // reused across chunks and calls — no per-chunk allocations.
        let scratch = crate::fusion::ScratchPool::global();
        let mut a_chunk = scratch.take(d);
        let mut b_chunk = scratch.take(d);
        let mut off = 0;
        while off < acc.len() {
            let end = (off + d).min(acc.len());
            a_chunk[..end - off].copy_from_slice(&acc[off..end]);
            b_chunk[..end - off].copy_from_slice(&upd[off..end]);
            if end - off < d {
                // zero the padding lanes so the artifact sees clean input
                a_chunk[end - off..].fill(0.0);
                b_chunk[end - off..].fill(0.0);
            }
            let out = self.rt.call(
                &name,
                &[
                    Runtime::literal(&a_chunk, &[d])?,
                    Runtime::literal(&b_chunk, &[d])?,
                    wa.reshape(&[1]).map_err(|e| anyhow!("{e:?}"))?,
                    wb.reshape(&[1]).map_err(|e| anyhow!("{e:?}"))?,
                ],
            )?;
            let merged = Runtime::to_vec(&out[0])?;
            acc[off..end].copy_from_slice(&merged[..end - off]);
            off = end;
        }
        Ok(())
    }

    /// Weighted mean over arbitrary K and D by grouping rows in `k`-blocks
    /// (zero-weight padding) and folding level by level on the partial
    /// means. Intermediate group means live in pooled scratch buffers that
    /// recycle as each level drops; only the final result detaches.
    pub fn weighted_mean(&self, updates: &[&[f32]], w: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(!updates.is_empty(), "no updates");
        anyhow::ensure!(updates.len() == w.len(), "weights mismatch");
        anyhow::ensure!(self.k >= 2, "fuse fan-in k must be ≥ 2, got {}", self.k);
        if updates.len() == 1 {
            return Ok(updates[0].to_vec());
        }
        let dim = updates[0].len();
        let mut groups: Vec<(crate::fusion::ScratchBuf<'static>, f32)> = updates
            .chunks(self.k)
            .zip(w.chunks(self.k))
            .map(|(rows, ws)| Ok((self.fuse_group(rows, ws, dim)?, ws.iter().sum::<f32>())))
            .collect::<Result<_>>()?;
        while groups.len() > 1 {
            let mut next = Vec::with_capacity(groups.len().div_ceil(self.k));
            for chunk in groups.chunks(self.k) {
                let views: Vec<&[f32]> = chunk.iter().map(|(g, _)| &**g).collect();
                let ws: Vec<f32> = chunk.iter().map(|(_, gw)| *gw).collect();
                next.push((self.fuse_group(&views, &ws, dim)?, ws.iter().sum::<f32>()));
            }
            groups = next; // the previous level's buffers return to the pool
        }
        Ok(groups.pop().expect("at least one group").0.detach())
    }

    /// One fuse_k call per D-chunk for ≤ k rows; the mean lands in a
    /// pooled scratch buffer.
    fn fuse_group(
        &self,
        rows: &[&[f32]],
        w: &[f32],
        dim: usize,
    ) -> Result<crate::fusion::ScratchBuf<'static>> {
        let name = self.fuse_name();
        let k = self.k;
        let d = self.chunk;
        let mut wk = vec![0.0f32; k];
        wk[..w.len()].copy_from_slice(w);
        let w_lit = Runtime::literal(&wk, &[k])?;
        let scratch = crate::fusion::ScratchPool::global();
        let mut out = scratch.take(dim);
        let mut slab = scratch.take(k * d);
        let mut off = 0;
        while off < dim {
            let end = (off + d).min(dim);
            // pack the (k, d) slab, zero-padded
            slab.fill(0.0);
            for (r, row) in rows.iter().enumerate() {
                slab[r * d..r * d + (end - off)].copy_from_slice(&row[off..end]);
            }
            let res = self.rt.call(
                &name,
                &[Runtime::literal(&slab, &[k, d])?, w_lit.reshape(&[k as i64]).map_err(|e| anyhow!("{e:?}"))?],
            )?;
            let mean = Runtime::to_vec(&res[0])?;
            out[off..end].copy_from_slice(&mean[..end - off]);
            off = end;
        }
        Ok(out)
    }

    /// FedProx merge via the `fedprox_k{K}_d{D}` artifact (single group) or
    /// weighted_mean + host-side pull for larger fan-in.
    pub fn fedprox(&self, updates: &[&[f32]], w: &[f32], global: &[f32], mu: f32) -> Result<Vec<f32>> {
        let mut mean = self.weighted_mean(updates, w)?;
        for (m, &g) in mean.iter_mut().zip(global.iter()) {
            *m = (1.0 - mu) * *m + mu * g;
        }
        Ok(mean)
    }
}

// ---------------------------------------------------------------------------
// real local training (party substrate)
// ---------------------------------------------------------------------------

/// MLP dimensions baked into the training artifacts.
pub const MLP_IN: usize = 64;
pub const MLP_HIDDEN: usize = 256;
pub const MLP_CLASSES: usize = 10;

/// Parameter shapes in artifact order (mirrors python param_shapes()).
pub fn mlp_param_dims() -> Vec<Vec<usize>> {
    vec![
        vec![MLP_IN, MLP_HIDDEN],
        vec![MLP_HIDDEN],
        vec![MLP_HIDDEN, MLP_HIDDEN],
        vec![MLP_HIDDEN],
        vec![MLP_HIDDEN, MLP_CLASSES],
        vec![MLP_CLASSES],
    ]
}

/// Real training session over the AOT train artifacts.
pub struct Trainer<'r> {
    /// Runtime the train/eval artifacts execute on.
    pub rt: &'r Runtime,
    /// Current parameters, flattened per tensor.
    pub params: Vec<Vec<f32>>,
}

impl<'r> Trainer<'r> {
    /// He-initialized parameters from a seed (host-side init keeps the
    /// artifacts purely functional).
    pub fn init(rt: &'r Runtime, seed: u64) -> Trainer<'r> {
        let mut rng = crate::util::rng::Rng::new(seed);
        let params = mlp_param_dims()
            .iter()
            .map(|dims| {
                let numel: usize = dims.iter().product();
                if dims.len() == 2 {
                    let scale = (2.0 / dims[0] as f64).sqrt();
                    (0..numel).map(|_| (rng.normal() * scale) as f32).collect()
                } else {
                    vec![0.0f32; numel]
                }
            })
            .collect();
        Trainer { rt, params }
    }

    pub fn from_params(rt: &'r Runtime, params: Vec<Vec<f32>>) -> Trainer<'r> {
        Trainer { rt, params }
    }

    /// Flatten parameters into a single update vector (ModelSpec order).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for p in &self.params {
            out.extend_from_slice(p);
        }
        out
    }

    /// Load parameters from a flattened global model.
    pub fn unflatten(&mut self, flat: &[f32]) {
        let mut off = 0;
        for (p, dims) in self.params.iter_mut().zip(mlp_param_dims()) {
            let numel: usize = dims.iter().product();
            p.copy_from_slice(&flat[off..off + numel]);
            off += numel;
        }
        assert_eq!(off, flat.len(), "flattened length mismatch");
    }
}

/// Stub training methods for builds without the `xla` feature.
#[cfg(not(feature = "xla"))]
impl Trainer<'_> {
    pub fn step(&mut self, _b: usize, _x: &[f32], _y: &[f32], _lr: f32) -> Result<f32> {
        bail!("XLA training unavailable: built without the `xla` feature")
    }

    pub fn epoch(&mut self, _n: usize, _xs: &[f32], _ys: &[f32], _lr: f32) -> Result<f32> {
        bail!("XLA training unavailable: built without the `xla` feature")
    }

    pub fn eval(&self, _x: &[f32], _y: &[f32]) -> Result<(f32, f32)> {
        bail!("XLA evaluation unavailable: built without the `xla` feature")
    }
}

#[cfg(feature = "xla")]
impl Trainer<'_> {
    fn param_literals(&self) -> Result<Vec<xla::Literal>> {
        mlp_param_dims()
            .iter()
            .zip(self.params.iter())
            .map(|(dims, p)| Runtime::literal(p, dims))
            .collect()
    }

    /// One SGD minibatch step. x: [b, IN] flattened; y one-hot [b, CLASSES].
    /// Returns the minibatch loss.
    pub fn step(&mut self, b: usize, x: &[f32], y: &[f32], lr: f32) -> Result<f32> {
        let name = format!("train_step_b{b}");
        let mut args = self.param_literals()?;
        args.push(Runtime::literal(x, &[b, MLP_IN])?);
        args.push(Runtime::literal(y, &[b, MLP_CLASSES])?);
        args.push(Runtime::literal(&[lr], &[1])?);
        let out = self.rt.call(&name, &args)?;
        for (i, lit) in out[..6].iter().enumerate() {
            self.params[i] = Runtime::to_vec(lit)?;
        }
        Ok(Runtime::to_vec(&out[6])?[0])
    }

    /// One local epoch over n minibatches of 32 via the scan artifact.
    pub fn epoch(&mut self, n: usize, xs: &[f32], ys: &[f32], lr: f32) -> Result<f32> {
        let name = format!("train_epoch_n{n}_b32");
        let mut args = self.param_literals()?;
        args.push(Runtime::literal(xs, &[n, 32, MLP_IN])?);
        args.push(Runtime::literal(ys, &[n, 32, MLP_CLASSES])?);
        args.push(Runtime::literal(&[lr], &[1])?);
        let out = self.rt.call(&name, &args)?;
        for (i, lit) in out[..6].iter().enumerate() {
            self.params[i] = Runtime::to_vec(lit)?;
        }
        Ok(Runtime::to_vec(&out[6])?[0])
    }

    /// Evaluate on a 256-sample batch → (loss, accuracy).
    pub fn eval(&self, x: &[f32], y: &[f32]) -> Result<(f32, f32)> {
        let mut args = self.param_literals()?;
        args.push(Runtime::literal(x, &[256, MLP_IN])?);
        args.push(Runtime::literal(y, &[256, MLP_CLASSES])?);
        let out = self.rt.call("eval_b256", &args)?;
        let loss = Runtime::to_vec(&out[0])?[0];
        let correct = Runtime::to_vec(&out[1])?[0];
        Ok((loss, correct / 256.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let text = r#"{"version":1,"artifacts":[
            {"name":"a","file":"a.hlo.txt","inputs":[{"dtype":"f32","dims":[8]}],
             "n_outputs":1,"meta":{"kind":"pair_merge","d":8}}]}"#;
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = m.find("a").unwrap();
        assert_eq!(a.inputs, vec![vec![8]]);
        assert_eq!(a.n_outputs, 1);
        assert_eq!(a.meta.get("kind").as_str(), Some("pair_merge"));
        assert!(m.find("zzz").is_none());
    }

    #[test]
    fn manifest_rejects_empty() {
        assert!(Manifest::parse(r#"{"artifacts":[]}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn mlp_dims_consistent_with_zoo() {
        let total: usize = mlp_param_dims()
            .iter()
            .map(|d| d.iter().product::<usize>())
            .sum();
        assert_eq!(total, crate::model::zoo::mlp_default().total_params());
    }
}
