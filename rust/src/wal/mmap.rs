//! Minimal memory-mapped file wrapper over raw `mmap(2)`.
//!
//! The container builds with no external crates beyond the vendored
//! workspace members, so this speaks the libc ABI directly (std already
//! links libc on unix). Only what the WAL needs: map a file shared
//! read/write at a fixed capacity, read it back, flush dirty pages with
//! `msync`, unmap on drop. Non-unix targets get a heap-buffer fallback
//! with write-through to the file — same API and aliasing discipline, no
//! page-cache zero-copy (the repo's primary target is linux).

use std::fs::{File, OpenOptions};
use std::io;
use std::path::{Path, PathBuf};

#[cfg(unix)]
mod sys {
    use std::os::raw::{c_int, c_void};

    pub const PROT_READ: c_int = 1;
    pub const PROT_WRITE: c_int = 2;
    pub const MAP_SHARED: c_int = 1;
    #[cfg(target_os = "macos")]
    pub const MS_SYNC: c_int = 0x10;
    #[cfg(not(target_os = "macos"))]
    pub const MS_SYNC: c_int = 4;

    extern "C" {
        pub fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: c_int,
            flags: c_int,
            fd: c_int,
            offset: i64,
        ) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> c_int;
        pub fn msync(addr: *mut c_void, len: usize, flags: c_int) -> c_int;
    }
}

/// A file mapped shared into the address space at a fixed length.
///
/// Writes go through [`write_at`](MmapFile::write_at) (single appender,
/// serialized by the WAL's lock); reads through
/// [`as_slice`](MmapFile::as_slice). Readers only ever dereference bytes
/// below the published append cursor, writers only ever touch bytes at or
/// above it, and the cursor is published under the same lock — so the
/// `&self` raw-pointer writes never race a live read.
pub struct MmapFile {
    /// Base of the mapping (unix) or of a leaked heap buffer (fallback).
    ptr: *mut u8,
    len: usize,
    file: File,
    path: PathBuf,
    writable: bool,
}

// SAFETY: the mapping itself is plain memory; all mutation is serialized
// by the owning WAL's mutex (see type-level comment).
unsafe impl Send for MmapFile {}
unsafe impl Sync for MmapFile {}

impl std::fmt::Debug for MmapFile {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("MmapFile")
            .field("path", &self.path)
            .field("len", &self.len)
            .field("writable", &self.writable)
            .finish()
    }
}

impl MmapFile {
    /// Open (create if missing) `path`, grow it to exactly `len` bytes
    /// (new bytes read as zero — the WAL's end-of-log sentinel), and map
    /// it shared read+write.
    pub fn create_rw(path: &Path, len: usize) -> io::Result<MmapFile> {
        let file = OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .open(path)?;
        file.set_len(len as u64)?;
        Self::map(file, path, len, true)
    }

    /// Map an existing file read-only at its current on-disk length.
    pub fn open_ro(path: &Path) -> io::Result<MmapFile> {
        let file = OpenOptions::new().read(true).open(path)?;
        let len = file.metadata()?.len() as usize;
        Self::map(file, path, len, false)
    }

    #[cfg(unix)]
    fn map(file: File, path: &Path, len: usize, writable: bool) -> io::Result<MmapFile> {
        use std::os::unix::io::AsRawFd;
        let ptr = if len == 0 {
            std::ptr::null_mut()
        } else {
            let prot = if writable {
                sys::PROT_READ | sys::PROT_WRITE
            } else {
                sys::PROT_READ
            };
            let p = unsafe {
                sys::mmap(
                    std::ptr::null_mut(),
                    len,
                    prot,
                    sys::MAP_SHARED,
                    file.as_raw_fd(),
                    0,
                )
            };
            if p as isize == -1 {
                return Err(io::Error::last_os_error());
            }
            p as *mut u8
        };
        Ok(MmapFile {
            ptr,
            len,
            file,
            path: path.to_path_buf(),
            writable,
        })
    }

    #[cfg(not(unix))]
    fn map(mut file: File, path: &Path, len: usize, writable: bool) -> io::Result<MmapFile> {
        use std::io::{Read, Seek, SeekFrom};
        let mut buf = vec![0u8; len].into_boxed_slice();
        file.seek(SeekFrom::Start(0))?;
        let mut read = 0;
        while read < len {
            let n = file.read(&mut buf[read..])?;
            if n == 0 {
                break;
            }
            read += n;
        }
        let ptr = if len == 0 {
            std::ptr::null_mut()
        } else {
            Box::into_raw(buf) as *mut u8
        };
        Ok(MmapFile {
            ptr,
            len,
            file,
            path: path.to_path_buf(),
            writable,
        })
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The whole mapping as a byte slice.
    pub fn as_slice(&self) -> &[u8] {
        if self.len == 0 {
            &[]
        } else {
            // SAFETY: ptr..ptr+len is live for the life of self; mutation
            // discipline is documented on the type.
            unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
        }
    }

    /// Write `bytes` at `off`. Callers serialize via the WAL lock.
    pub fn write_at(&self, off: usize, bytes: &[u8]) {
        assert!(self.writable, "write to read-only mapping");
        assert!(off + bytes.len() <= self.len, "mmap write out of bounds");
        if bytes.is_empty() {
            return;
        }
        // SAFETY: in-bounds (asserted above); serialized by the WAL lock;
        // readers never dereference past the append cursor.
        unsafe {
            std::ptr::copy_nonoverlapping(bytes.as_ptr(), self.ptr.add(off), bytes.len());
        }
        #[cfg(not(unix))]
        {
            use std::io::{Seek, SeekFrom, Write};
            let mut f = &self.file;
            let _ = f.seek(SeekFrom::Start(off as u64));
            let _ = f.write_all(bytes);
        }
    }

    /// Flush dirty pages of the whole mapping to the file (`msync` with
    /// `MS_SYNC`). Syncing the full range keeps the address page-aligned
    /// on every page size.
    pub fn sync(&self) -> io::Result<()> {
        #[cfg(unix)]
        {
            if self.len == 0 {
                return Ok(());
            }
            let rc = unsafe {
                sys::msync(
                    self.ptr as *mut std::os::raw::c_void,
                    self.len,
                    sys::MS_SYNC,
                )
            };
            if rc != 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }
        #[cfg(not(unix))]
        {
            self.file.sync_data()
        }
    }

    /// Shrink the backing file to `len` bytes (sealing a segment at its
    /// used length). The mapping itself stays at full size; callers must
    /// not touch bytes past the new end afterwards.
    pub fn truncate_file(&self, len: usize) -> io::Result<()> {
        self.file.set_len(len as u64)
    }
}

impl Drop for MmapFile {
    fn drop(&mut self) {
        if self.ptr.is_null() {
            return;
        }
        #[cfg(unix)]
        unsafe {
            sys::munmap(self.ptr as *mut std::os::raw::c_void, self.len);
        }
        #[cfg(not(unix))]
        unsafe {
            drop(Box::from_raw(std::slice::from_raw_parts_mut(
                self.ptr, self.len,
            )));
        }
    }
}
