//! Durable data plane: a segmented, memory-mapped, append-only log.
//!
//! The paper's JIT scheduler kills and revives aggregators mid-job and
//! leans on §5.5 checkpoints plus a replayable update log to make that
//! safe. This module is the storage engine under [`crate::mq`]: every
//! queue mutation (produce, checkpoint, commit, topic drop) becomes one
//! **length-prefixed, CRC32-framed record** appended to a preallocated,
//! `mmap`-backed segment file, so a `kill -9` at any instruction boundary
//! leaves a log that recovers to exactly the acknowledged prefix.
//!
//! Layout per segment (`NNNNNNNNNNNN.wal`, fixed-capacity, zero-filled):
//!
//! ```text
//! [magic "FLJITWAL" | version u32 | reserved u32]          16-byte header
//! [len u32 | crc32(body) u32 | body | pad→4B] ...          frames
//! [zeros...]                                               unwritten tail
//! ```
//!
//! * `len == 0` (the preallocated zero-fill) marks end-of-data — no
//!   scan-past-the-end ambiguity.
//! * Frames are 4-byte aligned and inline `f32` payload data lands
//!   4-byte aligned inside the body, so recovery hands back
//!   **zero-copy** [`MappedSlice`] views straight into the mapping.
//! * Recovery distinguishes a **torn tail** (a partially written final
//!   record: frame overruns the written region, or CRC mismatch with
//!   nothing but zeros after it) — truncated and logged — from
//!   **mid-log corruption** (bad frame with real data after it), which
//!   is a hard [`WalError::Corrupt`] naming segment and offset: no
//!   silent skips.
//!
//! Durability knob: [`FsyncPolicy`] — `msync` every append, every N
//! appends, or never (OS page cache only). A SIGKILL'd process survives
//! all three (dirty pages belong to the kernel, not the process); the
//! policy only changes the window lost to power failure. Segments are
//! sealed (synced + truncated to used length) on rollover.

mod mmap;

pub use mmap::MmapFile;

use std::fmt;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::mq::{BucketMeta, CheckpointState, Message, Payload};

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3, reflected) — hand-rolled, no crates in the container.
// ---------------------------------------------------------------------------

const fn crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc_table();

/// CRC32 (IEEE) of `data` — the frame checksum, also reused by
/// `fljit recover` to fingerprint recovered models.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = !0u32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// ---------------------------------------------------------------------------
// errors
// ---------------------------------------------------------------------------

/// WAL failure: I/O, or an unambiguously corrupt record.
#[derive(Debug)]
pub enum WalError {
    Io(io::Error),
    /// A frame that cannot be a torn tail: bad CRC / impossible length /
    /// undecodable body with real data after it. Recovery refuses to
    /// skip it — that would silently drop acknowledged writes.
    Corrupt {
        segment: PathBuf,
        offset: usize,
        detail: String,
    },
}

impl fmt::Display for WalError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WalError::Io(e) => write!(f, "wal io error: {e}"),
            WalError::Corrupt {
                segment,
                offset,
                detail,
            } => write!(
                f,
                "wal corrupt record in {} at byte {offset}: {detail}",
                segment.display()
            ),
        }
    }
}

impl std::error::Error for WalError {}

impl From<io::Error> for WalError {
    fn from(e: io::Error) -> Self {
        WalError::Io(e)
    }
}

// ---------------------------------------------------------------------------
// config
// ---------------------------------------------------------------------------

/// When to force dirty log pages to stable storage.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// `msync(MS_SYNC)` after every append — survives power loss, pays
    /// a storage round-trip per record.
    Always,
    /// Sync every N appends — bounded power-loss window of N records.
    EveryN(u32),
    /// Never sync explicitly; the OS flushes on its own schedule.
    /// Still survives `kill -9` (page cache outlives the process) —
    /// only power loss can lose the tail.
    OsOnly,
}

impl Default for FsyncPolicy {
    fn default() -> Self {
        FsyncPolicy::EveryN(128)
    }
}

impl FsyncPolicy {
    /// Parse a CLI spelling: `always`, `os`, or `every=N` / bare `N`.
    pub fn parse(s: &str) -> Result<FsyncPolicy, String> {
        match s {
            "always" => Ok(FsyncPolicy::Always),
            "os" | "never" => Ok(FsyncPolicy::OsOnly),
            other => {
                let n = other.strip_prefix("every=").unwrap_or(other);
                n.parse::<u32>()
                    .ok()
                    .filter(|&n| n > 0)
                    .map(FsyncPolicy::EveryN)
                    .ok_or_else(|| {
                        format!("bad fsync policy {other:?} (want always|os|every=N)")
                    })
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            FsyncPolicy::Always => "always".into(),
            FsyncPolicy::EveryN(n) => format!("every={n}"),
            FsyncPolicy::OsOnly => "os".into(),
        }
    }
}

/// Where and how the log lives on disk.
#[derive(Clone, Debug)]
pub struct WalConfig {
    pub dir: PathBuf,
    /// Segment capacity; a record larger than this gets a dedicated
    /// exactly-sized segment.
    pub segment_bytes: usize,
    pub fsync: FsyncPolicy,
}

impl WalConfig {
    pub fn new<P: Into<PathBuf>>(dir: P) -> WalConfig {
        WalConfig {
            dir: dir.into(),
            segment_bytes: 64 << 20,
            fsync: FsyncPolicy::default(),
        }
    }

    pub fn segment_bytes(mut self, n: usize) -> WalConfig {
        self.segment_bytes = n.max(MIN_SEGMENT_BYTES);
        self
    }

    pub fn fsync(mut self, p: FsyncPolicy) -> WalConfig {
        self.fsync = p;
        self
    }
}

// ---------------------------------------------------------------------------
// records
// ---------------------------------------------------------------------------

/// A decoded log record (recovery output).
#[derive(Clone, Debug, PartialEq)]
pub enum Record {
    Produce { topic: String, msg: Message },
    Checkpoint { slot: String, state: CheckpointState },
    Commit { topic: String, group: String, offset: u64 },
    DropTopic { topic: String },
    ClearCheckpoint { slot: String },
}

/// A borrowed record for appends — no payload clone on the produce path.
#[derive(Clone, Copy, Debug)]
pub enum RecordRef<'a> {
    Produce { topic: &'a str, msg: &'a Message },
    Checkpoint { slot: &'a str, state: &'a CheckpointState },
    Commit { topic: &'a str, group: &'a str, offset: u64 },
    DropTopic { topic: &'a str },
    ClearCheckpoint { slot: &'a str },
}

const KIND_PRODUCE: u32 = 0;
const KIND_CHECKPOINT: u32 = 1;
const KIND_COMMIT: u32 = 2;
const KIND_DROP_TOPIC: u32 = 3;
const KIND_CLEAR_CKPT: u32 = 4;

const PAYLOAD_INLINE: u32 = 0;
const PAYLOAD_REF: u32 = 1;
const PAYLOAD_SIM: u32 = 2;

// ---------------------------------------------------------------------------
// zero-copy payload view
// ---------------------------------------------------------------------------

/// An `f32` slice living inside a mapped segment: recovery's zero-copy
/// answer to `Payload::Inline`. Holds the mapping alive via `Arc`; the
/// byte offset is 4-aligned by the frame layout (checked at
/// construction — misaligned data falls back to an owned copy).
#[derive(Clone)]
pub struct MappedSlice {
    map: Arc<MmapFile>,
    byte_off: usize,
    len: usize,
}

impl MappedSlice {
    fn new(map: Arc<MmapFile>, byte_off: usize, len: usize) -> Option<MappedSlice> {
        let end = byte_off.checked_add(len.checked_mul(4)?)?;
        if end > map.len() || byte_off % 4 != 0 {
            return None;
        }
        Some(MappedSlice { map, byte_off, len })
    }

    /// Number of `f32`s.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The mapped data. Zero-copy: points into the segment mapping.
    pub fn as_f32s(&self) -> &[f32] {
        if self.len == 0 {
            return &[];
        }
        let bytes = &self.map.as_slice()[self.byte_off..self.byte_off + self.len * 4];
        // SAFETY: in-bounds and 4-aligned (checked in `new`); f32 has no
        // invalid bit patterns; the region is a sealed prefix of the log
        // that no writer revisits.
        unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const f32, self.len) }
    }
}

impl fmt::Debug for MappedSlice {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("MappedSlice")
            .field("segment", &self.map.path())
            .field("byte_off", &self.byte_off)
            .field("len", &self.len)
            .finish()
    }
}

impl PartialEq for MappedSlice {
    fn eq(&self, other: &Self) -> bool {
        self.as_f32s() == other.as_f32s()
    }
}

// ---------------------------------------------------------------------------
// encode / decode
// ---------------------------------------------------------------------------

const MAGIC: &[u8; 8] = b"FLJITWAL";
const VERSION: u32 = 1;
const HEADER_LEN: usize = 16;
const FRAME_HEADER: usize = 8;
const MIN_SEGMENT_BYTES: usize = 4096;

fn pad4(n: usize) -> usize {
    (n + 3) & !3
}

struct Enc {
    b: Vec<u8>,
}

impl Enc {
    fn new() -> Enc {
        Enc { b: Vec::new() }
    }

    fn u32(&mut self, v: u32) {
        self.b.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.b.extend_from_slice(&v.to_le_bytes());
    }

    fn f32(&mut self, v: f32) {
        self.b.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.b.extend_from_slice(s.as_bytes());
        while self.b.len() % 4 != 0 {
            self.b.push(0);
        }
    }

    fn f32s(&mut self, v: &[f32]) {
        self.u32(v.len() as u32);
        debug_assert_eq!(self.b.len() % 4, 0, "f32 data must land 4-aligned");
        for x in v {
            self.f32(*x);
        }
    }
}

fn encode_record(rec: RecordRef<'_>) -> Vec<u8> {
    let mut e = Enc::new();
    match rec {
        RecordRef::Produce { topic, msg } => {
            e.u32(KIND_PRODUCE);
            e.str(topic);
            e.u64(msg.party as u64);
            e.u32(msg.round);
            e.f32(msg.weight);
            e.u64(msg.enqueued_at);
            match &msg.payload {
                Payload::Inline(v) => {
                    e.u32(PAYLOAD_INLINE);
                    e.f32s(v);
                }
                Payload::Mapped(m) => {
                    e.u32(PAYLOAD_INLINE);
                    e.f32s(m.as_f32s());
                }
                Payload::Ref { key, size_bytes } => {
                    e.u32(PAYLOAD_REF);
                    e.str(key);
                    e.u64(*size_bytes);
                }
                Payload::Sim { size_bytes } => {
                    e.u32(PAYLOAD_SIM);
                    e.u64(*size_bytes);
                }
            }
        }
        RecordRef::Checkpoint { slot, state } => {
            e.u32(KIND_CHECKPOINT);
            e.str(slot);
            match &state.acc {
                Some(acc) => {
                    e.u32(1);
                    e.f32s(acc);
                }
                None => e.u32(0),
            }
            e.f32(state.weight);
            e.u64(state.n_merged as u64);
            e.u64(state.consumed_to as u64);
            e.u64(state.saved_at);
            // trailing bucket section (sharded fold plane) — decoders
            // tolerate its absence, so pre-tree logs stay readable
            e.u32(state.buckets.len() as u32);
            for b in &state.buckets {
                e.u32(b.bucket);
                e.u32(b.folds);
                e.f32(b.weight);
            }
        }
        RecordRef::Commit {
            topic,
            group,
            offset,
        } => {
            e.u32(KIND_COMMIT);
            e.str(topic);
            e.str(group);
            e.u64(offset);
        }
        RecordRef::DropTopic { topic } => {
            e.u32(KIND_DROP_TOPIC);
            e.str(topic);
        }
        RecordRef::ClearCheckpoint { slot } => {
            e.u32(KIND_CLEAR_CKPT);
            e.str(slot);
        }
    }
    e.b
}

struct Dec<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.pos + n > self.b.len() {
            return Err(format!(
                "body truncated: want {n} bytes at {}, have {}",
                self.pos,
                self.b.len() - self.pos
            ));
        }
        let out = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    fn u32(&mut self) -> Result<u32, String> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, String> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    fn str(&mut self) -> Result<String, String> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        let s = std::str::from_utf8(bytes)
            .map_err(|_| "non-utf8 string".to_string())?
            .to_string();
        self.take(pad4(n) - n)?;
        Ok(s)
    }

    /// Decode a counted f32 run: zero-copy [`MappedSlice`] when the
    /// absolute position is 4-aligned, owned copy otherwise.
    fn f32_run(
        &mut self,
        map: &Arc<MmapFile>,
        body_abs: usize,
    ) -> Result<Result<MappedSlice, Vec<f32>>, String> {
        let n = self.u32()? as usize;
        let abs = body_abs + self.pos;
        let bytes = self.take(n.checked_mul(4).ok_or("f32 count overflow")?)?;
        if let Some(m) = MappedSlice::new(Arc::clone(map), abs, n) {
            Ok(Ok(m))
        } else {
            let mut v = Vec::with_capacity(n);
            for chunk in bytes.chunks_exact(4) {
                v.push(f32::from_le_bytes(chunk.try_into().unwrap()));
            }
            Ok(Err(v))
        }
    }
}

/// `body_abs`: absolute byte offset of the body inside the segment, so
/// mapped payload views can be anchored.
fn decode_record(
    body: &[u8],
    map: &Arc<MmapFile>,
    body_abs: usize,
) -> Result<Record, String> {
    let mut d = Dec::new(body);
    let kind = d.u32()?;
    match kind {
        KIND_PRODUCE => {
            let topic = d.str()?;
            let party = d.u64()? as usize;
            let round = d.u32()?;
            let weight = d.f32()?;
            let enqueued_at = d.u64()?;
            let payload = match d.u32()? {
                PAYLOAD_INLINE => match d.f32_run(map, body_abs)? {
                    Ok(m) => Payload::Mapped(m),
                    Err(v) => Payload::Inline(v),
                },
                PAYLOAD_REF => Payload::Ref {
                    key: d.str()?,
                    size_bytes: d.u64()?,
                },
                PAYLOAD_SIM => Payload::Sim {
                    size_bytes: d.u64()?,
                },
                t => return Err(format!("unknown payload tag {t}")),
            };
            Ok(Record::Produce {
                topic,
                msg: Message {
                    party,
                    round,
                    weight,
                    enqueued_at,
                    payload,
                },
            })
        }
        KIND_CHECKPOINT => {
            let slot = d.str()?;
            let acc = if d.u32()? != 0 {
                // Checkpoints are latest-wins singletons: an owned copy
                // keeps them alive across segment GC, and the copy cost
                // is one accumulator per recovery.
                Some(match d.f32_run(map, body_abs)? {
                    Ok(m) => m.as_f32s().to_vec(),
                    Err(v) => v,
                })
            } else {
                None
            };
            let weight = d.f32()?;
            let n_merged = d.u64()? as usize;
            let consumed_to = d.u64()? as usize;
            let saved_at = d.u64()?;
            // a pre-tree record ends here; the bucket section is
            // trailing and optional (legacy logs decode to no metas)
            let mut buckets = Vec::new();
            if d.remaining() >= 4 {
                let n = d.u32()? as usize;
                buckets.reserve(n);
                for _ in 0..n {
                    buckets.push(BucketMeta {
                        bucket: d.u32()?,
                        folds: d.u32()?,
                        weight: d.f32()?,
                    });
                }
            }
            Ok(Record::Checkpoint {
                slot,
                state: CheckpointState {
                    acc,
                    weight,
                    n_merged,
                    consumed_to,
                    saved_at,
                    buckets,
                },
            })
        }
        KIND_COMMIT => Ok(Record::Commit {
            topic: d.str()?,
            group: d.str()?,
            offset: d.u64()?,
        }),
        KIND_DROP_TOPIC => Ok(Record::DropTopic { topic: d.str()? }),
        KIND_CLEAR_CKPT => Ok(Record::ClearCheckpoint { slot: d.str()? }),
        k => Err(format!("unknown record kind {k}")),
    }
}

// ---------------------------------------------------------------------------
// the log
// ---------------------------------------------------------------------------

/// Append/roll/sync counters, exported as `wal_*` telemetry by the MQ.
#[derive(Clone, Debug, Default)]
pub struct WalStats {
    pub records_appended: u64,
    pub bytes_appended: u64,
    pub fsyncs: u64,
    pub segments_rolled: u64,
    /// Total segments on disk (sealed + active).
    pub segments: u64,
}

/// What one append did (telemetry feed for the MQ's `wal_*` counters).
#[derive(Clone, Copy, Debug)]
pub struct AppendInfo {
    /// Frame bytes written (header + body + padding).
    pub bytes: usize,
    /// This append triggered an `msync`.
    pub synced: bool,
    /// This append rolled to a fresh segment.
    pub rolled: bool,
    /// Total segments after the append.
    pub segments: u64,
}

/// What recovery found and did.
#[derive(Clone, Debug, Default)]
pub struct RecoveryReport {
    pub segments: usize,
    pub records: u64,
    /// Frame bytes scanned (headers + bodies + padding).
    pub bytes: u64,
    /// A partially written final record was found and truncated away.
    pub torn_tail: bool,
    pub truncated_bytes: u64,
    pub elapsed_secs: f64,
}

struct Inner {
    active: Arc<MmapFile>,
    active_index: u64,
    used: usize,
    appends_since_sync: u32,
    stats: WalStats,
}

/// The segmented append-only log. One instance per data dir; interior
/// mutability so the MQ can append behind `&self` from per-topic locks
/// (lock order: topic/checkpoint lock → WAL lock, never the reverse).
pub struct Wal {
    cfg: WalConfig,
    inner: Mutex<Inner>,
}

impl fmt::Debug for Wal {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Wal").field("dir", &self.cfg.dir).finish()
    }
}

fn segment_path(dir: &Path, index: u64) -> PathBuf {
    dir.join(format!("{index:012}.wal"))
}

fn list_segments(dir: &Path) -> Result<Vec<(u64, PathBuf)>, WalError> {
    let mut out = Vec::new();
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if let Some(stem) = name.strip_suffix(".wal") {
            if stem.len() == 12 && stem.bytes().all(|b| b.is_ascii_digit()) {
                out.push((stem.parse::<u64>().unwrap(), entry.path()));
            }
        }
    }
    out.sort();
    Ok(out)
}

fn write_header(map: &MmapFile) {
    let mut h = [0u8; HEADER_LEN];
    h[..8].copy_from_slice(MAGIC);
    h[8..12].copy_from_slice(&VERSION.to_le_bytes());
    map.write_at(0, &h);
}

fn header_ok(bytes: &[u8]) -> bool {
    bytes.len() >= HEADER_LEN
        && &bytes[..8] == MAGIC
        && u32::from_le_bytes(bytes[8..12].try_into().unwrap()) == VERSION
}

/// One scanned frame (diagnostics: `fljit recover` and the recovery
/// edge-case tests locate frames to inspect or corrupt through this).
#[derive(Clone, Debug)]
pub struct FrameInfo {
    /// Byte offset of the frame (its length prefix) in the segment.
    pub offset: usize,
    /// Body length (unpadded).
    pub len: usize,
    pub crc_ok: bool,
    /// First body word (the record kind) if readable.
    pub kind: Option<u32>,
}

/// Walk a segment's frames without decoding bodies. Stops at the
/// end-of-data sentinel or the first frame that doesn't fit.
pub fn list_frames(path: &Path) -> Result<Vec<FrameInfo>, WalError> {
    let map = MmapFile::open_ro(path)?;
    let bytes = map.as_slice();
    let mut out = Vec::new();
    if !header_ok(bytes) {
        return Ok(out);
    }
    let mut off = HEADER_LEN;
    while off + FRAME_HEADER <= bytes.len() {
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        if len == 0 {
            break;
        }
        let crc = u32::from_le_bytes(bytes[off + 4..off + 8].try_into().unwrap());
        let body_end = off + FRAME_HEADER + len;
        if body_end > bytes.len() {
            out.push(FrameInfo {
                offset: off,
                len,
                crc_ok: false,
                kind: None,
            });
            break;
        }
        let body = &bytes[off + FRAME_HEADER..body_end];
        out.push(FrameInfo {
            offset: off,
            len,
            crc_ok: crc32(body) == crc,
            kind: (len >= 4).then(|| u32::from_le_bytes(body[..4].try_into().unwrap())),
        });
        off += FRAME_HEADER + pad4(len);
    }
    Ok(out)
}

struct ScanOut {
    records: Vec<Record>,
    used: usize,
    torn: Option<usize>,
    frames: u64,
    bytes: u64,
}

/// Scan one segment's frames into records. `is_last` selects torn-tail
/// handling (truncate) over hard corruption errors.
fn scan_segment(map: &Arc<MmapFile>, is_last: bool) -> Result<ScanOut, WalError> {
    let bytes = map.as_slice();
    let path = map.path().to_path_buf();
    let corrupt = |offset: usize, detail: String| WalError::Corrupt {
        segment: path.clone(),
        offset,
        detail,
    };
    let mut out = ScanOut {
        records: Vec::new(),
        used: HEADER_LEN,
        torn: None,
        frames: 0,
        bytes: 0,
    };
    let mut off = HEADER_LEN;
    loop {
        if off + FRAME_HEADER > bytes.len() {
            // Ran off the end without a sentinel: full segment, clean.
            break;
        }
        let len = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()) as usize;
        if len == 0 {
            break;
        }
        let crc = u32::from_le_bytes(bytes[off + 4..off + 8].try_into().unwrap());
        let frame_end = off + FRAME_HEADER + pad4(len);
        if off + FRAME_HEADER + len > bytes.len() {
            // Frame overruns the segment: only a torn final write can
            // look like this in the last segment.
            if is_last {
                out.torn = Some(off);
                break;
            }
            return Err(corrupt(off, format!("frame length {len} overruns segment")));
        }
        let body = &bytes[off + FRAME_HEADER..off + FRAME_HEADER + len];
        if crc32(body) != crc {
            let tail_zero = bytes[frame_end.min(bytes.len())..].iter().all(|&b| b == 0);
            if is_last && tail_zero {
                // Nothing after it: the classic torn tail.
                out.torn = Some(off);
                break;
            }
            return Err(corrupt(
                off,
                format!(
                    "crc mismatch (stored {crc:#010x}, computed {:#010x}) with live data after the frame",
                    crc32(body)
                ),
            ));
        }
        let rec = decode_record(body, map, off + FRAME_HEADER)
            .map_err(|detail| corrupt(off, detail))?;
        out.records.push(rec);
        out.frames += 1;
        out.bytes += (FRAME_HEADER + pad4(len)) as u64;
        off = frame_end;
        out.used = off;
    }
    Ok(out)
}

impl Wal {
    /// Open (or create) the log in `cfg.dir`, replaying every record.
    /// Returns the ready-to-append log, the records in file order, and
    /// the recovery report (torn-tail truncation already applied).
    pub fn open(cfg: WalConfig) -> Result<(Wal, Vec<Record>, RecoveryReport), WalError> {
        let t0 = std::time::Instant::now();
        std::fs::create_dir_all(&cfg.dir)?;
        let segs = list_segments(&cfg.dir)?;
        let mut report = RecoveryReport::default();
        let mut records = Vec::new();

        let (active, active_index, used) = if segs.is_empty() {
            let map = Arc::new(MmapFile::create_rw(
                &segment_path(&cfg.dir, 0),
                cfg.segment_bytes.max(MIN_SEGMENT_BYTES),
            )?);
            write_header(&map);
            (map, 0u64, HEADER_LEN)
        } else {
            report.segments = segs.len();
            let last = segs.len() - 1;
            let mut active = None;
            for (i, (index, path)) in segs.iter().enumerate() {
                let is_last = i == last;
                let map = if is_last {
                    // Reopen the tail RW at full capacity (a sealed-then-
                    // crashed tail may sit truncated below capacity; the
                    // grow zero-fills, preserving the sentinel).
                    let on_disk = std::fs::metadata(path)?.len() as usize;
                    let cap = pad4(on_disk.max(cfg.segment_bytes.max(MIN_SEGMENT_BYTES)));
                    Arc::new(MmapFile::create_rw(path, cap)?)
                } else {
                    Arc::new(MmapFile::open_ro(path)?)
                };
                if !header_ok(map.as_slice()) {
                    let blank = map.as_slice().iter().all(|&b| b == 0);
                    if is_last && blank {
                        // Crash between segment creation and header
                        // write: an empty shell, reinitialize it.
                        write_header(&map);
                        active = Some((map, *index, HEADER_LEN));
                        continue;
                    }
                    return Err(WalError::Corrupt {
                        segment: path.clone(),
                        offset: 0,
                        detail: "bad segment header".into(),
                    });
                }
                let mut scan = scan_segment(&map, is_last)?;
                records.append(&mut scan.records);
                report.records += scan.frames;
                report.bytes += scan.bytes;
                if let Some(torn_at) = scan.torn {
                    report.torn_tail = true;
                    report.truncated_bytes = (map.len() - torn_at) as u64;
                    // Zero the torn frame so the sentinel is clean for
                    // the appends that follow.
                    map.write_at(torn_at, &vec![0u8; map.len() - torn_at]);
                    map.sync()?;
                }
                if is_last {
                    active = Some((map, *index, scan.used));
                }
            }
            active.expect("last segment always yields the active map")
        };

        report.elapsed_secs = t0.elapsed().as_secs_f64();
        let stats = WalStats {
            segments: active_index + 1,
            ..WalStats::default()
        };
        let wal = Wal {
            cfg,
            inner: Mutex::new(Inner {
                active,
                active_index,
                used,
                appends_since_sync: 0,
                stats,
            }),
        };
        Ok((wal, records, report))
    }

    pub fn dir(&self) -> &Path {
        &self.cfg.dir
    }

    pub fn fsync_policy(&self) -> FsyncPolicy {
        self.cfg.fsync
    }

    pub fn stats(&self) -> WalStats {
        self.inner.lock().unwrap().stats.clone()
    }

    /// Append one record (frame + optional sync per policy).
    pub fn append(&self, rec: RecordRef<'_>) -> Result<AppendInfo, WalError> {
        let body = encode_record(rec);
        let frame = FRAME_HEADER + pad4(body.len());
        let mut inner = self.inner.lock().unwrap();
        let mut rolled = false;
        if inner.used + frame > inner.active.len() {
            self.roll(&mut inner, frame)?;
            rolled = true;
        }
        let off = inner.used;
        let map = Arc::clone(&inner.active);
        // Body and CRC first, length prefix last: a record only becomes
        // visible to recovery once its length word is non-zero, so a
        // torn write can at worst leave a frame the CRC check rejects.
        map.write_at(off + 4, &crc32(&body).to_le_bytes());
        map.write_at(off + FRAME_HEADER, &body);
        map.write_at(off, &(body.len() as u32).to_le_bytes());
        inner.used = off + frame;
        inner.stats.records_appended += 1;
        inner.stats.bytes_appended += frame as u64;
        let sync_now = match self.cfg.fsync {
            FsyncPolicy::Always => true,
            FsyncPolicy::EveryN(n) => {
                inner.appends_since_sync += 1;
                inner.appends_since_sync >= n
            }
            FsyncPolicy::OsOnly => false,
        };
        if sync_now {
            map.sync()?;
            inner.appends_since_sync = 0;
            inner.stats.fsyncs += 1;
        }
        Ok(AppendInfo {
            bytes: frame,
            synced: sync_now,
            rolled,
            segments: inner.active_index + 1,
        })
    }

    fn roll(&self, inner: &mut Inner, need: usize) -> Result<(), WalError> {
        // Seal: flush and shrink the old segment to its used length.
        inner.active.sync()?;
        inner.active.truncate_file(inner.used)?;
        let next = inner.active_index + 1;
        let cap = pad4((HEADER_LEN + need).max(self.cfg.segment_bytes.max(MIN_SEGMENT_BYTES)));
        let map = Arc::new(MmapFile::create_rw(&segment_path(&self.cfg.dir, next), cap)?);
        write_header(&map);
        inner.active = map;
        inner.active_index = next;
        inner.used = HEADER_LEN;
        inner.appends_since_sync = 0;
        inner.stats.segments_rolled += 1;
        inner.stats.segments = next + 1;
        Ok(())
    }

    /// Force-flush the active segment regardless of policy.
    pub fn flush(&self) -> Result<(), WalError> {
        let mut inner = self.inner.lock().unwrap();
        inner.active.sync()?;
        inner.appends_since_sync = 0;
        inner.stats.fsyncs += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Seek, SeekFrom, Write};

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fljit_wal_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn produce(topic: &str, party: usize, payload: Payload) -> Record {
        Record::Produce {
            topic: topic.into(),
            msg: Message {
                party,
                round: 3,
                weight: 2.5,
                enqueued_at: 777,
                payload,
            },
        }
    }

    fn append_owned(wal: &Wal, rec: &Record) {
        let r = match rec {
            Record::Produce { topic, msg } => RecordRef::Produce { topic, msg },
            Record::Checkpoint { slot, state } => RecordRef::Checkpoint { slot, state },
            Record::Commit {
                topic,
                group,
                offset,
            } => RecordRef::Commit {
                topic,
                group,
                offset: *offset,
            },
            Record::DropTopic { topic } => RecordRef::DropTopic { topic },
            Record::ClearCheckpoint { slot } => RecordRef::ClearCheckpoint { slot },
        };
        wal.append(r).unwrap();
    }

    #[test]
    fn crc32_known_answer() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn fsync_policy_parses_and_names() {
        assert_eq!(FsyncPolicy::parse("always").unwrap(), FsyncPolicy::Always);
        assert_eq!(FsyncPolicy::parse("os").unwrap(), FsyncPolicy::OsOnly);
        assert_eq!(
            FsyncPolicy::parse("every=16").unwrap(),
            FsyncPolicy::EveryN(16)
        );
        assert_eq!(FsyncPolicy::parse("8").unwrap(), FsyncPolicy::EveryN(8));
        assert!(FsyncPolicy::parse("every=0").is_err());
        assert!(FsyncPolicy::parse("sometimes").is_err());
        assert_eq!(FsyncPolicy::EveryN(16).name(), "every=16");
    }

    #[test]
    fn all_record_kinds_roundtrip() {
        let dir = tmp("roundtrip");
        let recs = vec![
            produce("t", 1, Payload::Inline(vec![1.0, -2.0, 3.5])),
            produce(
                "t",
                2,
                Payload::Ref {
                    key: "blob/7".into(),
                    size_bytes: 4096,
                },
            ),
            produce("u", 3, Payload::Sim { size_bytes: 100 }),
            Record::Checkpoint {
                slot: "job0/round3/ckpt".into(),
                state: CheckpointState {
                    acc: Some(vec![0.5, 0.25]),
                    weight: 4.0,
                    n_merged: 2,
                    consumed_to: 2,
                    saved_at: 999,
                    buckets: vec![
                        BucketMeta {
                            bucket: 3,
                            weight: 1.5,
                            folds: 1,
                        },
                        BucketMeta {
                            bucket: 9,
                            weight: 2.5,
                            folds: 1,
                        },
                    ],
                },
            },
            Record::Commit {
                topic: "t".into(),
                group: "agg".into(),
                offset: 2,
            },
            Record::DropTopic { topic: "u".into() },
            Record::ClearCheckpoint {
                slot: "job0/round3/ckpt".into(),
            },
        ];
        {
            let (wal, replay, report) = Wal::open(WalConfig::new(&dir)).unwrap();
            assert!(replay.is_empty(), "fresh dir replays nothing");
            assert!(!report.torn_tail);
            for r in &recs {
                append_owned(&wal, r);
            }
        }
        let (_wal, replay, report) = Wal::open(WalConfig::new(&dir)).unwrap();
        assert_eq!(report.records, recs.len() as u64);
        assert_eq!(replay, recs, "decode(encode(x)) == x for every kind");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recovered_inline_payloads_are_mapped_zero_copy() {
        let dir = tmp("mapped");
        let data = vec![1.0f32, 2.0, 3.0, 4.0];
        {
            let (wal, _, _) = Wal::open(WalConfig::new(&dir)).unwrap();
            append_owned(&wal, &produce("t", 0, Payload::Inline(data.clone())));
        }
        let (_wal, replay, _) = Wal::open(WalConfig::new(&dir)).unwrap();
        let Record::Produce { msg, .. } = &replay[0] else {
            panic!("expected produce");
        };
        match &msg.payload {
            Payload::Mapped(m) => {
                assert_eq!(m.as_f32s(), &data[..]);
                assert_eq!(m.as_f32s().as_ptr() as usize % 4, 0, "aligned view");
            }
            p => panic!("expected mapped payload, got {p:?}"),
        }
        assert_eq!(msg.payload.size_bytes(), 16);
        assert_eq!(msg.payload.data().unwrap(), &data[..]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rollover_spreads_records_across_segments() {
        let dir = tmp("roll");
        let n = 64;
        {
            let (wal, _, _) = Wal::open(
                WalConfig::new(&dir).segment_bytes(MIN_SEGMENT_BYTES),
            )
            .unwrap();
            for p in 0..n {
                append_owned(&wal, &produce("t", p, Payload::Inline(vec![p as f32; 64])));
            }
            assert!(wal.stats().segments_rolled > 0, "tiny segments must roll");
        }
        assert!(
            list_segments(&dir).unwrap().len() > 1,
            "multiple segment files on disk"
        );
        let (_wal, replay, report) = Wal::open(
            WalConfig::new(&dir).segment_bytes(MIN_SEGMENT_BYTES),
        )
        .unwrap();
        assert_eq!(replay.len(), n);
        assert!(!report.torn_tail);
        for (p, rec) in replay.iter().enumerate() {
            let Record::Produce { msg, .. } = rec else {
                panic!()
            };
            assert_eq!(msg.party, p, "file order == append order across segments");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn oversized_record_gets_dedicated_segment() {
        let dir = tmp("oversize");
        let big = vec![7.0f32; 8192]; // 32 KiB body > 4 KiB segment
        {
            let (wal, _, _) = Wal::open(
                WalConfig::new(&dir).segment_bytes(MIN_SEGMENT_BYTES),
            )
            .unwrap();
            append_owned(&wal, &produce("t", 0, Payload::Inline(vec![1.0; 4])));
            append_owned(&wal, &produce("t", 1, Payload::Inline(big.clone())));
        }
        let (_wal, replay, _) = Wal::open(
            WalConfig::new(&dir).segment_bytes(MIN_SEGMENT_BYTES),
        )
        .unwrap();
        assert_eq!(replay.len(), 2);
        let Record::Produce { msg, .. } = &replay[1] else {
            panic!()
        };
        assert_eq!(msg.payload.data().unwrap(), &big[..]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_final_record_is_truncated_and_log_stays_usable() {
        let dir = tmp("torn");
        {
            let (wal, _, _) = Wal::open(WalConfig::new(&dir)).unwrap();
            for p in 0..3 {
                append_owned(&wal, &produce("t", p, Payload::Inline(vec![p as f32; 8])));
            }
        }
        // Corrupt the LAST frame's body; everything after it is still
        // the preallocated zero fill, so this is indistinguishable from
        // a torn final write.
        let seg = segment_path(&dir, 0);
        let frames = list_frames(&seg).unwrap();
        assert_eq!(frames.len(), 3);
        let last = frames.last().unwrap();
        {
            let mut f = std::fs::OpenOptions::new().write(true).open(&seg).unwrap();
            f.seek(SeekFrom::Start((last.offset + FRAME_HEADER + 4) as u64))
                .unwrap();
            f.write_all(&[0xAB, 0xCD]).unwrap();
        }
        let (wal, replay, report) = Wal::open(WalConfig::new(&dir)).unwrap();
        assert!(report.torn_tail, "must report the truncation");
        assert!(report.truncated_bytes > 0);
        assert_eq!(replay.len(), 2, "only the intact prefix survives");
        // The log keeps working where the torn frame used to be.
        append_owned(&wal, &produce("t", 9, Payload::Inline(vec![9.0; 8])));
        drop(wal);
        let (_wal, replay, report) = Wal::open(WalConfig::new(&dir)).unwrap();
        assert!(!report.torn_tail);
        assert_eq!(replay.len(), 3);
        let Record::Produce { msg, .. } = &replay[2] else {
            panic!()
        };
        assert_eq!(msg.party, 9);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mid_log_corruption_is_a_hard_error_not_a_skip() {
        let dir = tmp("corrupt");
        {
            let (wal, _, _) = Wal::open(WalConfig::new(&dir)).unwrap();
            for p in 0..3 {
                append_owned(&wal, &produce("t", p, Payload::Inline(vec![p as f32; 8])));
            }
        }
        let seg = segment_path(&dir, 0);
        let first = &list_frames(&seg).unwrap()[0];
        {
            let mut f = std::fs::OpenOptions::new().write(true).open(&seg).unwrap();
            f.seek(SeekFrom::Start((first.offset + FRAME_HEADER + 4) as u64))
                .unwrap();
            f.write_all(&[0xAB, 0xCD]).unwrap();
        }
        let err = Wal::open(WalConfig::new(&dir)).unwrap_err();
        match err {
            WalError::Corrupt {
                segment, offset, ..
            } => {
                assert_eq!(segment, seg);
                assert_eq!(offset, first.offset, "error names the bad frame");
            }
            e => panic!("expected corrupt error, got {e}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fsync_policy_controls_sync_cadence() {
        for (policy, expect) in [
            (FsyncPolicy::Always, 10u64),
            (FsyncPolicy::EveryN(4), 2),
            (FsyncPolicy::OsOnly, 0),
        ] {
            let dir = tmp(&format!("fsync_{}", policy.name().replace('=', "_")));
            let (wal, _, _) = Wal::open(WalConfig::new(&dir).fsync(policy)).unwrap();
            for p in 0..10 {
                append_owned(&wal, &produce("t", p, Payload::Sim { size_bytes: 8 }));
            }
            assert_eq!(wal.stats().fsyncs, expect, "policy {}", policy.name());
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}
