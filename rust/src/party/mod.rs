//! Party emulation: who trains, on what, and when updates arrive.
//!
//! §6.1: "Parties were emulated, and distributed over four datacenters …
//! We actually had parties running training to emulate realistic federated
//! learning." This module is that emulation layer:
//!
//! * [`HardwareProfile`] / [`PartyProfile`] — heterogeneity (§2.3): vCPU
//!   count (1 or 2) and RAM (2/4/6/8 GB) drawn randomly for heterogeneous
//!   fleets, equal slices for homogeneous ones; dataset sizes are non-IID.
//! * [`Fleet::arrival_offsets`] — per-round update arrival times: active
//!   parties are *periodic* (epoch time × small lognormal jitter + transfer
//!   time, §4.1/§4.3); intermittent parties draw uniformly within the
//!   `t_wait` window (§6.3 "random update scheme").
//! * [`PartyInfo`] extraction — what each party reports to the estimator
//!   (§5.2), with a reporting-probability knob to exercise the regression
//!   fallback path.
//!
//! Real training (the end-to-end example) lives in `coordinator::live`,
//! which drives `runtime::Trainer` per party thread; this module supplies
//! its data partitions via [`synth_party_dataset`].

use crate::estimator::{Mode, PartyInfo};
use crate::sim::Time;
use crate::util::rng::Rng;

/// Party compute capability.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HardwareProfile {
    pub vcpus: u32,
    pub ram_gb: u32,
    /// Normalized speed multiplier (1.0 = the homogeneous baseline).
    pub speed: f64,
}

impl HardwareProfile {
    pub fn score(&self) -> f64 {
        self.vcpus as f64 * self.speed
    }
}

/// Fleet composition (§6.3 experiment axes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FleetKind {
    ActiveHomogeneous,
    ActiveHeterogeneous,
    IntermittentHeterogeneous,
}

impl FleetKind {
    /// Parse a fleet-kind name. Every [`name`](FleetKind::name) spelling
    /// is accepted, so `parse(name())` round-trips — the on-disk
    /// `JobTrace` format depends on this.
    pub fn parse(s: &str) -> Option<FleetKind> {
        match s {
            "active-homog" | "active-homogeneous" => Some(FleetKind::ActiveHomogeneous),
            "active-hetero" | "active-heterogeneous" => Some(FleetKind::ActiveHeterogeneous),
            "intermittent" | "intermittent-hetero" | "intermittent-heterogeneous" => {
                Some(FleetKind::IntermittentHeterogeneous)
            }
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FleetKind::ActiveHomogeneous => "active-homog",
            FleetKind::ActiveHeterogeneous => "active-hetero",
            FleetKind::IntermittentHeterogeneous => "intermittent-hetero",
        }
    }

    pub fn mode(&self) -> Mode {
        match self {
            FleetKind::IntermittentHeterogeneous => Mode::Intermittent,
            _ => Mode::Active,
        }
    }
}

/// One emulated party.
#[derive(Clone, Debug)]
pub struct PartyProfile {
    pub id: usize,
    pub mode: Mode,
    pub hardware: HardwareProfile,
    /// Local dataset size (items); non-IID across the fleet.
    pub dataset_items: f64,
    /// True mean epoch time (seconds) — ground truth the estimator tries
    /// to predict.
    pub epoch_secs: f64,
    /// Round-to-round jitter (lognormal sigma) on the epoch time.
    pub jitter_sigma: f64,
    /// Party↔aggregator bandwidths, bytes/s.
    pub bw_up: f64,
    pub bw_down: f64,
}

impl PartyProfile {
    /// Transfer time for a model of `model_bytes` (down + up, §5.3).
    pub fn comm_secs(&self, model_bytes: u64) -> f64 {
        model_bytes as f64 / self.bw_down + model_bytes as f64 / self.bw_up
    }

    /// Draw the actual update arrival offset for one round.
    pub fn draw_arrival(&self, model_bytes: u64, t_wait: f64, rng: &mut Rng) -> f64 {
        match self.mode {
            Mode::Active => {
                let train = self.epoch_secs * rng.lognormal(0.0, self.jitter_sigma);
                train + self.comm_secs(model_bytes)
            }
            // §6.3: "each participant would send their model update at a
            // random time" within the allotted round window.
            Mode::Intermittent => {
                rng.range_f64(0.05, 0.98) * t_wait
            }
        }
    }

    /// What this party reports to the platform (§5.2). With probability
    /// `1 - report_prob` the timing fields are withheld, exercising the
    /// linear-regression fallback of §5.3.
    pub fn info(&self, report_prob: f64, rng: &mut Rng) -> PartyInfo {
        let reports = rng.bool(report_prob);
        PartyInfo {
            mode: self.mode,
            t_epoch: if reports { Some(self.epoch_secs) } else { None },
            t_minibatch: if reports {
                Some(self.epoch_secs / (self.dataset_items / 32.0).max(1.0))
            } else {
                None
            },
            dataset_items: Some(self.dataset_items),
            hw_score: Some(self.hardware.score()),
            bw_up: self.bw_up,
            bw_down: self.bw_down,
        }
    }
}

/// A job's whole fleet.
#[derive(Clone, Debug)]
pub struct Fleet {
    pub kind: FleetKind,
    pub parties: Vec<PartyProfile>,
}

/// Generation parameters tying a fleet to a workload's timing scale.
#[derive(Clone, Copy, Debug)]
pub struct FleetParams {
    /// Mean epoch time on baseline hardware with the mean data slice.
    pub base_epoch_secs: f64,
    /// Lognormal jitter sigma on per-round epoch times (periodicity noise;
    /// Fig 3 shows this is small in practice).
    pub jitter_sigma: f64,
    /// Party↔DC bandwidth range, bytes/s (4 emulated datacenters).
    pub bw_lo: f64,
    pub bw_hi: f64,
}

impl Default for FleetParams {
    fn default() -> Self {
        FleetParams {
            base_epoch_secs: 30.0,
            jitter_sigma: 0.015,
            bw_lo: 40e6,
            bw_hi: 120e6,
        }
    }
}

impl Fleet {
    /// Generate a fleet per §6.3: homogeneous = equal 2-vCPU parties and
    /// equal non-IID slices; heterogeneous = 1-or-2 vCPUs, 2/4/6/8 GB RAM,
    /// Dirichlet-skewed dataset sizes.
    pub fn generate(kind: FleetKind, n: usize, params: FleetParams, rng: &mut Rng) -> Fleet {
        let hetero = kind != FleetKind::ActiveHomogeneous;
        let mode = kind.mode();
        // Dataset shares: equal for homogeneous, Dirichlet(2.0) for
        // heterogeneous (moderate skew — every party still has data).
        let shares: Vec<f64> = if hetero {
            rng.dirichlet(2.0, n)
        } else {
            vec![1.0 / n as f64; n]
        };
        let parties = (0..n)
            .map(|id| {
                let hardware = if hetero {
                    let vcpus = if rng.bool(0.5) { 1 } else { 2 };
                    let ram_gb = *rng.choose(&[2u32, 4, 6, 8]);
                    HardwareProfile {
                        vcpus,
                        ram_gb,
                        speed: (vcpus as f64 / 2.0) * rng.range_f64(0.85, 1.15),
                    }
                } else {
                    HardwareProfile {
                        vcpus: 2,
                        ram_gb: 4,
                        speed: 1.0,
                    }
                };
                // epoch time scales with data share (linearity, §4.2) and
                // inversely with hardware speed
                let rel_data = shares[id] * n as f64;
                let epoch_secs = params.base_epoch_secs * rel_data / hardware.speed;
                let bw = rng.range_f64(params.bw_lo, params.bw_hi);
                PartyProfile {
                    id,
                    mode,
                    hardware,
                    dataset_items: 320.0 * rel_data,
                    epoch_secs,
                    jitter_sigma: params.jitter_sigma,
                    bw_up: bw,
                    bw_down: bw * rng.range_f64(1.0, 2.0),
                }
            })
            .collect();
        Fleet { kind, parties }
    }

    pub fn len(&self) -> usize {
        self.parties.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parties.is_empty()
    }

    /// Actual arrival offsets (micros from round start) for one round.
    pub fn arrival_offsets(&self, model_bytes: u64, t_wait: f64, rng: &mut Rng) -> Vec<Time> {
        self.parties
            .iter()
            .map(|p| crate::sim::secs(p.draw_arrival(model_bytes, t_wait, rng)))
            .collect()
    }

    /// PartyInfos for the estimator.
    pub fn infos(&self, report_prob: f64, rng: &mut Rng) -> Vec<PartyInfo> {
        self.parties.iter().map(|p| p.info(report_prob, rng)).collect()
    }
}

/// Synthetic non-IID classification shard for *real* training parties:
/// class prototypes + Gaussian noise, labels drawn from a per-party
/// Dirichlet distribution (the standard label-skew construction).
/// Returns (x, y_onehot) with x: [items×in_dim], y: [items×classes].
pub fn synth_party_dataset(
    party: usize,
    items: usize,
    in_dim: usize,
    classes: usize,
    alpha: f64,
    seed: u64,
) -> (Vec<f32>, Vec<f32>) {
    // Shared prototypes across all parties (same underlying task).
    let mut proto_rng = Rng::new(seed);
    let protos: Vec<Vec<f32>> = (0..classes)
        .map(|_| (0..in_dim).map(|_| proto_rng.normal() as f32).collect())
        .collect();
    let mut rng = Rng::new(seed ^ (party as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let label_dist = rng.dirichlet(alpha, classes);
    // cumulative for sampling
    let mut cdf = vec![0.0; classes];
    let mut acc = 0.0;
    for (i, p) in label_dist.iter().enumerate() {
        acc += p;
        cdf[i] = acc;
    }
    let mut x = Vec::with_capacity(items * in_dim);
    let mut y = vec![0.0f32; items * classes];
    for i in 0..items {
        let u = rng.f64();
        let label = cdf.iter().position(|&c| u <= c).unwrap_or(classes - 1);
        for d in 0..in_dim {
            x.push(protos[label][d] + 0.35 * rng.normal() as f32);
        }
        y[i * classes + label] = 1.0;
    }
    (x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_kind_name_parse_roundtrips() {
        for k in [
            FleetKind::ActiveHomogeneous,
            FleetKind::ActiveHeterogeneous,
            FleetKind::IntermittentHeterogeneous,
        ] {
            assert_eq!(FleetKind::parse(k.name()), Some(k), "{:?}", k.name());
        }
        assert!(FleetKind::parse("bogus").is_none());
    }

    #[test]
    fn homogeneous_fleet_is_uniform() {
        let mut rng = Rng::new(1);
        let f = Fleet::generate(
            FleetKind::ActiveHomogeneous,
            16,
            FleetParams::default(),
            &mut rng,
        );
        assert_eq!(f.len(), 16);
        for p in &f.parties {
            assert_eq!(p.hardware.vcpus, 2);
            assert!((p.epoch_secs - 30.0).abs() < 1e-9);
            assert_eq!(p.mode, Mode::Active);
        }
    }

    #[test]
    fn heterogeneous_fleet_varies() {
        let mut rng = Rng::new(2);
        let f = Fleet::generate(
            FleetKind::ActiveHeterogeneous,
            64,
            FleetParams::default(),
            &mut rng,
        );
        let vcpus: std::collections::BTreeSet<u32> =
            f.parties.iter().map(|p| p.hardware.vcpus).collect();
        assert_eq!(vcpus, [1u32, 2].into_iter().collect());
        let epochs: Vec<f64> = f.parties.iter().map(|p| p.epoch_secs).collect();
        let s = crate::util::stats::Summary::of(&epochs);
        assert!(s.cv() > 0.2, "heterogeneous fleet should spread, cv={}", s.cv());
        // data shares sum to the fleet total
        let total: f64 = f.parties.iter().map(|p| p.dataset_items).sum();
        assert!((total - 320.0 * 64.0).abs() / total < 1e-9);
    }

    #[test]
    fn active_arrivals_track_epoch_time() {
        let mut rng = Rng::new(3);
        let f = Fleet::generate(
            FleetKind::ActiveHomogeneous,
            8,
            FleetParams::default(),
            &mut rng,
        );
        let offs = f.arrival_offsets(100_000_000, 600.0, &mut rng);
        for (&t, p) in offs.iter().zip(&f.parties) {
            let secs = crate::sim::to_secs(t);
            let expect = p.epoch_secs + p.comm_secs(100_000_000);
            assert!(
                (secs - expect).abs() / expect < 0.1,
                "arrival {secs} vs expected {expect}"
            );
        }
    }

    #[test]
    fn intermittent_arrivals_fill_window() {
        let mut rng = Rng::new(4);
        let f = Fleet::generate(
            FleetKind::IntermittentHeterogeneous,
            200,
            FleetParams::default(),
            &mut rng,
        );
        let offs = f.arrival_offsets(1_000_000, 600.0, &mut rng);
        let secs: Vec<f64> = offs.iter().map(|&t| crate::sim::to_secs(t)).collect();
        let min = secs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = secs.iter().cloned().fold(0.0, f64::max);
        assert!(min >= 0.0 && max <= 600.0);
        assert!(max - min > 300.0, "arrivals should spread over the window");
    }

    #[test]
    fn report_prob_controls_fallback_path() {
        let mut rng = Rng::new(5);
        let f = Fleet::generate(
            FleetKind::ActiveHeterogeneous,
            100,
            FleetParams::default(),
            &mut rng,
        );
        let full = f.infos(1.0, &mut rng);
        assert!(full.iter().all(|i| i.t_epoch.is_some()));
        let none = f.infos(0.0, &mut rng);
        assert!(none.iter().all(|i| i.t_epoch.is_none()));
        assert!(none.iter().all(|i| i.hw_score.is_some()));
    }

    #[test]
    fn synth_dataset_shapes_and_skew() {
        let (x, y) = synth_party_dataset(3, 128, 64, 10, 0.3, 42);
        assert_eq!(x.len(), 128 * 64);
        assert_eq!(y.len(), 128 * 10);
        // one-hot rows
        for i in 0..128 {
            let row = &y[i * 10..(i + 1) * 10];
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        }
        // low alpha -> skewed labels
        let mut counts = [0usize; 10];
        for i in 0..128 {
            let label = y[i * 10..(i + 1) * 10].iter().position(|&v| v == 1.0).unwrap();
            counts[label] += 1;
        }
        let max = *counts.iter().max().unwrap();
        assert!(max > 25, "expected label skew, counts={counts:?}");
        // deterministic per (party, seed)
        let (x2, _) = synth_party_dataset(3, 128, 64, 10, 0.3, 42);
        assert_eq!(x, x2);
        let (x3, _) = synth_party_dataset(4, 128, 64, 10, 0.3, 42);
        assert_ne!(x, x3);
    }
}
