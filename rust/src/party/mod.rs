//! Party emulation: who trains, on what, and when updates arrive.
//!
//! §6.1: "Parties were emulated, and distributed over four datacenters …
//! We actually had parties running training to emulate realistic federated
//! learning." This module is that emulation layer:
//!
//! * [`HardwareProfile`] / [`PartyProfile`] — heterogeneity (§2.3): vCPU
//!   count (1 or 2) and RAM (2/4/6/8 GB) drawn randomly for heterogeneous
//!   fleets, equal slices for homogeneous ones; dataset sizes are non-IID.
//! * [`Fleet::arrival_offsets`] — per-round update arrival times: active
//!   parties are *periodic* (epoch time × small lognormal jitter + transfer
//!   time, §4.1/§4.3); intermittent parties draw uniformly within the
//!   `t_wait` window (§6.3 "random update scheme").
//! * [`PartyInfo`] extraction — what each party reports to the estimator
//!   (§5.2), with a reporting-probability knob to exercise the regression
//!   fallback path.
//!
//! Real training (the end-to-end example) lives in `coordinator::live`,
//! which drives `runtime::Trainer` per party thread; this module supplies
//! its data partitions via [`synth_party_dataset`].

use crate::estimator::{Mode, PartyInfo};
use crate::sim::Time;
use crate::util::rng::Rng;

/// Party compute capability.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HardwareProfile {
    pub vcpus: u32,
    pub ram_gb: u32,
    /// Normalized speed multiplier (1.0 = the homogeneous baseline).
    pub speed: f64,
}

impl HardwareProfile {
    pub fn score(&self) -> f64 {
        self.vcpus as f64 * self.speed
    }
}

/// Fleet composition (§6.3 experiment axes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FleetKind {
    ActiveHomogeneous,
    ActiveHeterogeneous,
    IntermittentHeterogeneous,
}

impl FleetKind {
    /// Parse a fleet-kind name. Every [`name`](FleetKind::name) spelling
    /// is accepted, so `parse(name())` round-trips — the on-disk
    /// `JobTrace` format depends on this.
    pub fn parse(s: &str) -> Option<FleetKind> {
        match s {
            "active-homog" | "active-homogeneous" => Some(FleetKind::ActiveHomogeneous),
            "active-hetero" | "active-heterogeneous" => Some(FleetKind::ActiveHeterogeneous),
            "intermittent" | "intermittent-hetero" | "intermittent-heterogeneous" => {
                Some(FleetKind::IntermittentHeterogeneous)
            }
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FleetKind::ActiveHomogeneous => "active-homog",
            FleetKind::ActiveHeterogeneous => "active-hetero",
            FleetKind::IntermittentHeterogeneous => "intermittent-hetero",
        }
    }

    pub fn mode(&self) -> Mode {
        match self {
            FleetKind::IntermittentHeterogeneous => Mode::Intermittent,
            _ => Mode::Active,
        }
    }
}

/// One emulated party.
#[derive(Clone, Debug)]
pub struct PartyProfile {
    pub id: usize,
    pub mode: Mode,
    pub hardware: HardwareProfile,
    /// Local dataset size (items); non-IID across the fleet.
    pub dataset_items: f64,
    /// True mean epoch time (seconds) — ground truth the estimator tries
    /// to predict.
    pub epoch_secs: f64,
    /// Round-to-round jitter (lognormal sigma) on the epoch time.
    pub jitter_sigma: f64,
    /// Party↔aggregator bandwidths, bytes/s.
    pub bw_up: f64,
    pub bw_down: f64,
}

impl PartyProfile {
    /// Transfer time for a model of `model_bytes` (down + up, §5.3).
    pub fn comm_secs(&self, model_bytes: u64) -> f64 {
        model_bytes as f64 / self.bw_down + model_bytes as f64 / self.bw_up
    }

    /// Draw the actual update arrival offset for one round.
    pub fn draw_arrival(&self, model_bytes: u64, t_wait: f64, rng: &mut Rng) -> f64 {
        let (train, comm) = self.draw_split(model_bytes, t_wait, rng);
        train + comm
    }

    /// The same draw, split into (train, transfer) so the fault layer can
    /// stretch the two components independently. Consumes exactly the rng
    /// draws `draw_arrival` always consumed.
    pub fn draw_split(&self, model_bytes: u64, t_wait: f64, rng: &mut Rng) -> (f64, f64) {
        match self.mode {
            Mode::Active => {
                let train = self.epoch_secs * rng.lognormal(0.0, self.jitter_sigma);
                (train, self.comm_secs(model_bytes))
            }
            // §6.3: "each participant would send their model update at a
            // random time" within the allotted round window.
            Mode::Intermittent => (rng.range_f64(0.05, 0.98) * t_wait, 0.0),
        }
    }

    /// What this party reports to the platform (§5.2). With probability
    /// `1 - report_prob` the timing fields are withheld, exercising the
    /// linear-regression fallback of §5.3.
    pub fn info(&self, report_prob: f64, rng: &mut Rng) -> PartyInfo {
        let reports = rng.bool(report_prob);
        PartyInfo {
            mode: self.mode,
            t_epoch: if reports { Some(self.epoch_secs) } else { None },
            t_minibatch: if reports {
                Some(self.epoch_secs / (self.dataset_items / 32.0).max(1.0))
            } else {
                None
            },
            dataset_items: Some(self.dataset_items),
            hw_score: Some(self.hardware.score()),
            bw_up: self.bw_up,
            bw_down: self.bw_down,
        }
    }
}

/// Fault-injection knobs for a hostile fleet. Implemented once, here in
/// the fleet layer, so the simulator and the live drivers inject the
/// *identical* faults from the same seeded rng stream: the engine draws
/// [`Fleet::faulty_arrival_offsets`] per round in both regimes.
///
/// All knobs default to "off"; [`FleetFaults::is_none`] gates a fast path
/// that consumes exactly the fault-free rng stream, so zero-fault runs
/// stay bit-identical to pre-fault-layer seeds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FleetFaults {
    /// Per-party, per-round probability of a heavy-tailed compute stall.
    pub straggler_prob: f64,
    /// Pareto shape of the stall multiplier (≥ 1×, inverse-CDF draw);
    /// smaller alpha = heavier tail.
    pub straggler_alpha: f64,
    /// Lognormal sigma stretching the upload (transfer) time; 0 = off.
    pub upload_tail_sigma: f64,
    /// Base per-party, per-round dropout probability.
    pub dropout_prob: f64,
    /// Rounds a dropped party stays out before it rejoins.
    pub rejoin_after: u32,
    /// Diurnal availability wave: extra dropout probability amplitude
    /// (0..1) riding a per-party-phased cosine over the round index.
    pub diurnal_amplitude: f64,
    /// Diurnal wave period, in rounds.
    pub diurnal_period_rounds: u32,
    /// Non-IID weight skew: redraw the fleet's dataset shares from
    /// Dirichlet(alpha) at generation time (lower = more skew).
    pub weight_skew_alpha: Option<f64>,
    /// Round reporting deadline (seconds from round start). Arrivals
    /// drawn beyond it are cut at the source for drop-at-deadline
    /// strategies, or delivered late (and weight-decayed) for
    /// `async-stale`.
    pub straggler_cutoff_secs: Option<f64>,
    /// Quorum floor as a fraction of the spec quorum: a round whose
    /// expected on-time arrivals fall below the floor is skipped
    /// (starvation) instead of hanging on an unreachable quorum.
    pub quorum_floor_frac: f64,
}

impl Default for FleetFaults {
    fn default() -> Self {
        FleetFaults {
            straggler_prob: 0.0,
            straggler_alpha: 1.5,
            upload_tail_sigma: 0.0,
            dropout_prob: 0.0,
            rejoin_after: 1,
            diurnal_amplitude: 0.0,
            diurnal_period_rounds: 8,
            weight_skew_alpha: None,
            straggler_cutoff_secs: None,
            quorum_floor_frac: 0.5,
        }
    }
}

impl FleetFaults {
    /// The fault-free configuration (every knob off).
    pub fn none() -> FleetFaults {
        FleetFaults::default()
    }

    /// True when no knob injects anything — the engine then consumes the
    /// plain fault-free rng stream (bit-compat with pre-fault seeds).
    pub fn is_none(&self) -> bool {
        self.straggler_prob == 0.0
            && self.upload_tail_sigma == 0.0
            && self.dropout_prob == 0.0
            && self.diurnal_amplitude == 0.0
            && self.weight_skew_alpha.is_none()
            && self.straggler_cutoff_secs.is_none()
    }

    /// Named fault scenarios for the robustness matrix (`fljit
    /// robustness`) and the CI smoke. `cutoff` scales with the workload's
    /// epoch time, so callers pass the spec's base epoch seconds.
    pub fn scenario(name: &str, base_epoch_secs: f64) -> Option<FleetFaults> {
        match name {
            "baseline" => Some(FleetFaults::none()),
            // heavy-tailed stragglers + a reporting deadline: the cell
            // where drop-at-deadline loses data and async-stale decays it
            "stragglers" => Some(FleetFaults {
                straggler_prob: 0.35,
                straggler_alpha: 1.1,
                upload_tail_sigma: 0.4,
                straggler_cutoff_secs: Some(base_epoch_secs * 2.0),
                ..FleetFaults::default()
            }),
            // mid-round churn: parties vanish for a couple of rounds
            "dropout" => Some(FleetFaults {
                dropout_prob: 0.25,
                rejoin_after: 2,
                ..FleetFaults::default()
            }),
            // availability waves: dropout swells and ebbs over rounds
            "diurnal" => Some(FleetFaults {
                dropout_prob: 0.05,
                diurnal_amplitude: 0.6,
                diurnal_period_rounds: 4,
                rejoin_after: 1,
                ..FleetFaults::default()
            }),
            // non-IID weight skew + mild stragglers
            "skew" => Some(FleetFaults {
                weight_skew_alpha: Some(0.3),
                straggler_prob: 0.1,
                straggler_alpha: 1.5,
                ..FleetFaults::default()
            }),
            _ => None,
        }
    }

    /// All scenario names, in matrix order.
    pub fn all_scenarios() -> &'static [&'static str] {
        &["baseline", "stragglers", "dropout", "diurnal", "skew"]
    }

    /// Effective dropout probability for `party` in `round`: the base
    /// rate plus the diurnal wave (per-party phase spreads the wave so
    /// the whole fleet doesn't blink in lockstep).
    pub fn dropout_at(&self, round: u32, party: usize, n: usize) -> f64 {
        let wave = if self.diurnal_amplitude > 0.0 {
            let period = self.diurnal_period_rounds.max(1) as f64;
            let phase = party as f64 / n.max(1) as f64;
            let x = 2.0 * std::f64::consts::PI * (round as f64 / period + phase);
            self.diurnal_amplitude * 0.5 * (1.0 - x.cos())
        } else {
            0.0
        };
        (self.dropout_prob + wave).clamp(0.0, 0.95)
    }
}

/// Per-job fault bookkeeping that evolves round to round (who is dropped
/// out and until when). Owned by the `JobEngine` so the §5.5 resume
/// replay reconstructs it deterministically.
#[derive(Clone, Debug)]
pub struct FaultState {
    /// Party `p` is out until round `out_until[p]` (exclusive).
    out_until: Vec<u32>,
}

impl FaultState {
    pub fn new(n: usize) -> FaultState {
        FaultState {
            out_until: vec![0; n],
        }
    }
}

/// One round's fault-aware arrival draw, indexed by party id.
#[derive(Clone, Debug)]
pub struct RoundDraw {
    /// Drawn arrival offsets (µs from round start) — meaningful only for
    /// present parties, but always drawn for all of them so the rng
    /// stream length is state-independent.
    pub offsets: Vec<Time>,
    /// False while the party is dropped out (it neither trains nor
    /// publishes this round).
    pub present: Vec<bool>,
    /// False when the drawn offset exceeds the straggler cutoff: the
    /// update misses the round's reporting deadline.
    pub on_time: Vec<bool>,
}

impl RoundDraw {
    /// Parties expected to arrive before the reporting deadline — the
    /// round's effective quorum ceiling.
    pub fn expected_on_time(&self) -> usize {
        self.present
            .iter()
            .zip(&self.on_time)
            .filter(|(&p, &o)| p && o)
            .count()
    }
}

/// A job's whole fleet.
#[derive(Clone, Debug)]
pub struct Fleet {
    pub kind: FleetKind,
    pub parties: Vec<PartyProfile>,
}

/// Generation parameters tying a fleet to a workload's timing scale.
#[derive(Clone, Copy, Debug)]
pub struct FleetParams {
    /// Mean epoch time on baseline hardware with the mean data slice.
    pub base_epoch_secs: f64,
    /// Lognormal jitter sigma on per-round epoch times (periodicity noise;
    /// Fig 3 shows this is small in practice).
    pub jitter_sigma: f64,
    /// Party↔DC bandwidth range, bytes/s (4 emulated datacenters).
    pub bw_lo: f64,
    pub bw_hi: f64,
}

impl Default for FleetParams {
    fn default() -> Self {
        FleetParams {
            base_epoch_secs: 30.0,
            jitter_sigma: 0.015,
            bw_lo: 40e6,
            bw_hi: 120e6,
        }
    }
}

impl Fleet {
    /// Generate a fleet per §6.3: homogeneous = equal 2-vCPU parties and
    /// equal non-IID slices; heterogeneous = 1-or-2 vCPUs, 2/4/6/8 GB RAM,
    /// Dirichlet-skewed dataset sizes.
    pub fn generate(kind: FleetKind, n: usize, params: FleetParams, rng: &mut Rng) -> Fleet {
        let hetero = kind != FleetKind::ActiveHomogeneous;
        let mode = kind.mode();
        // Dataset shares: equal for homogeneous, Dirichlet(2.0) for
        // heterogeneous (moderate skew — every party still has data).
        let shares: Vec<f64> = if hetero {
            rng.dirichlet(2.0, n)
        } else {
            vec![1.0 / n as f64; n]
        };
        let parties = (0..n)
            .map(|id| {
                let hardware = if hetero {
                    let vcpus = if rng.bool(0.5) { 1 } else { 2 };
                    let ram_gb = *rng.choose(&[2u32, 4, 6, 8]);
                    HardwareProfile {
                        vcpus,
                        ram_gb,
                        speed: (vcpus as f64 / 2.0) * rng.range_f64(0.85, 1.15),
                    }
                } else {
                    HardwareProfile {
                        vcpus: 2,
                        ram_gb: 4,
                        speed: 1.0,
                    }
                };
                // epoch time scales with data share (linearity, §4.2) and
                // inversely with hardware speed
                let rel_data = shares[id] * n as f64;
                let epoch_secs = params.base_epoch_secs * rel_data / hardware.speed;
                let bw = rng.range_f64(params.bw_lo, params.bw_hi);
                PartyProfile {
                    id,
                    mode,
                    hardware,
                    dataset_items: 320.0 * rel_data,
                    epoch_secs,
                    jitter_sigma: params.jitter_sigma,
                    bw_up: bw,
                    bw_down: bw * rng.range_f64(1.0, 2.0),
                }
            })
            .collect();
        Fleet { kind, parties }
    }

    pub fn len(&self) -> usize {
        self.parties.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parties.is_empty()
    }

    /// Actual arrival offsets (micros from round start) for one round.
    pub fn arrival_offsets(&self, model_bytes: u64, t_wait: f64, rng: &mut Rng) -> Vec<Time> {
        self.parties
            .iter()
            .map(|p| crate::sim::secs(p.draw_arrival(model_bytes, t_wait, rng)))
            .collect()
    }

    /// PartyInfos for the estimator.
    pub fn infos(&self, report_prob: f64, rng: &mut Rng) -> Vec<PartyInfo> {
        self.parties.iter().map(|p| p.info(report_prob, rng)).collect()
    }

    /// Fault-aware arrival draw for one round. With `faults.is_none()`
    /// this consumes *exactly* the [`arrival_offsets`](Fleet::arrival_offsets)
    /// stream (bit-compat); otherwise every party consumes a fixed number
    /// of extra draws per round regardless of its dropout state, so the
    /// stream stays deterministic and replayable for the §5.5 resume
    /// fast-forward.
    pub fn faulty_arrival_offsets(
        &self,
        model_bytes: u64,
        t_wait: f64,
        faults: &FleetFaults,
        round: u32,
        state: &mut FaultState,
        rng: &mut Rng,
    ) -> RoundDraw {
        let n = self.parties.len();
        if faults.is_none() {
            return RoundDraw {
                offsets: self.arrival_offsets(model_bytes, t_wait, rng),
                present: vec![true; n],
                on_time: vec![true; n],
            };
        }
        debug_assert_eq!(state.out_until.len(), n);
        let mut offsets = Vec::with_capacity(n);
        let mut present = Vec::with_capacity(n);
        let mut on_time = Vec::with_capacity(n);
        for p in &self.parties {
            // unconditional draws: the stream shape never depends on
            // dropout state, only the per-call count is fixed
            let drop_u = rng.f64();
            let tail_u = rng.f64();
            let sev_u = rng.f64();
            let up_mult = if faults.upload_tail_sigma > 0.0 {
                rng.lognormal(0.0, faults.upload_tail_sigma)
            } else {
                1.0
            };
            let (train, comm) = p.draw_split(model_bytes, t_wait, rng);
            // Pareto(alpha, x_m = 1) via inverse CDF: multiplier ≥ 1
            let tail_mult = if tail_u < faults.straggler_prob {
                (1.0 - sev_u).max(1e-12).powf(-1.0 / faults.straggler_alpha.max(0.05))
            } else {
                1.0
            };
            let off_secs = train * tail_mult + comm * up_mult;
            let here = if state.out_until[p.id] > round {
                false // still dropped out, rejoins later
            } else if drop_u < faults.dropout_at(round, p.id, n) {
                state.out_until[p.id] = round + 1 + faults.rejoin_after;
                false
            } else {
                true
            };
            offsets.push(crate::sim::secs(off_secs));
            present.push(here);
            on_time.push(
                faults
                    .straggler_cutoff_secs
                    .map_or(true, |c| off_secs <= c),
            );
        }
        RoundDraw {
            offsets,
            present,
            on_time,
        }
    }

    /// Apply non-IID weight skew: redraw the dataset shares from
    /// Dirichlet(alpha), keeping the fleet's data total constant. Called
    /// at fleet generation time (deterministic per engine seed); the
    /// skewed `dataset_items` flow into fold weights and estimator
    /// linearity exactly like generated ones.
    pub fn apply_weight_skew(&mut self, alpha: f64, rng: &mut Rng) {
        let n = self.parties.len();
        if n == 0 {
            return;
        }
        let shares = rng.dirichlet(alpha, n);
        for (p, share) in self.parties.iter_mut().zip(shares) {
            p.dataset_items = 320.0 * share * n as f64;
        }
    }
}

/// Synthetic non-IID classification shard for *real* training parties:
/// class prototypes + Gaussian noise, labels drawn from a per-party
/// Dirichlet distribution (the standard label-skew construction).
/// Returns (x, y_onehot) with x: [items×in_dim], y: [items×classes].
pub fn synth_party_dataset(
    party: usize,
    items: usize,
    in_dim: usize,
    classes: usize,
    alpha: f64,
    seed: u64,
) -> (Vec<f32>, Vec<f32>) {
    // Shared prototypes across all parties (same underlying task).
    let mut proto_rng = Rng::new(seed);
    let protos: Vec<Vec<f32>> = (0..classes)
        .map(|_| (0..in_dim).map(|_| proto_rng.normal() as f32).collect())
        .collect();
    let mut rng = Rng::new(seed ^ (party as u64).wrapping_mul(0x9E3779B97F4A7C15));
    let label_dist = rng.dirichlet(alpha, classes);
    // cumulative for sampling
    let mut cdf = vec![0.0; classes];
    let mut acc = 0.0;
    for (i, p) in label_dist.iter().enumerate() {
        acc += p;
        cdf[i] = acc;
    }
    let mut x = Vec::with_capacity(items * in_dim);
    let mut y = vec![0.0f32; items * classes];
    for i in 0..items {
        let u = rng.f64();
        let label = cdf.iter().position(|&c| u <= c).unwrap_or(classes - 1);
        for d in 0..in_dim {
            x.push(protos[label][d] + 0.35 * rng.normal() as f32);
        }
        y[i * classes + label] = 1.0;
    }
    (x, y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_kind_name_parse_roundtrips() {
        for k in [
            FleetKind::ActiveHomogeneous,
            FleetKind::ActiveHeterogeneous,
            FleetKind::IntermittentHeterogeneous,
        ] {
            assert_eq!(FleetKind::parse(k.name()), Some(k), "{:?}", k.name());
        }
        assert!(FleetKind::parse("bogus").is_none());
    }

    #[test]
    fn homogeneous_fleet_is_uniform() {
        let mut rng = Rng::new(1);
        let f = Fleet::generate(
            FleetKind::ActiveHomogeneous,
            16,
            FleetParams::default(),
            &mut rng,
        );
        assert_eq!(f.len(), 16);
        for p in &f.parties {
            assert_eq!(p.hardware.vcpus, 2);
            assert!((p.epoch_secs - 30.0).abs() < 1e-9);
            assert_eq!(p.mode, Mode::Active);
        }
    }

    #[test]
    fn heterogeneous_fleet_varies() {
        let mut rng = Rng::new(2);
        let f = Fleet::generate(
            FleetKind::ActiveHeterogeneous,
            64,
            FleetParams::default(),
            &mut rng,
        );
        let vcpus: std::collections::BTreeSet<u32> =
            f.parties.iter().map(|p| p.hardware.vcpus).collect();
        assert_eq!(vcpus, [1u32, 2].into_iter().collect());
        let epochs: Vec<f64> = f.parties.iter().map(|p| p.epoch_secs).collect();
        let s = crate::util::stats::Summary::of(&epochs);
        assert!(s.cv() > 0.2, "heterogeneous fleet should spread, cv={}", s.cv());
        // data shares sum to the fleet total
        let total: f64 = f.parties.iter().map(|p| p.dataset_items).sum();
        assert!((total - 320.0 * 64.0).abs() / total < 1e-9);
    }

    #[test]
    fn active_arrivals_track_epoch_time() {
        let mut rng = Rng::new(3);
        let f = Fleet::generate(
            FleetKind::ActiveHomogeneous,
            8,
            FleetParams::default(),
            &mut rng,
        );
        let offs = f.arrival_offsets(100_000_000, 600.0, &mut rng);
        for (&t, p) in offs.iter().zip(&f.parties) {
            let secs = crate::sim::to_secs(t);
            let expect = p.epoch_secs + p.comm_secs(100_000_000);
            assert!(
                (secs - expect).abs() / expect < 0.1,
                "arrival {secs} vs expected {expect}"
            );
        }
    }

    #[test]
    fn intermittent_arrivals_fill_window() {
        let mut rng = Rng::new(4);
        let f = Fleet::generate(
            FleetKind::IntermittentHeterogeneous,
            200,
            FleetParams::default(),
            &mut rng,
        );
        let offs = f.arrival_offsets(1_000_000, 600.0, &mut rng);
        let secs: Vec<f64> = offs.iter().map(|&t| crate::sim::to_secs(t)).collect();
        let min = secs.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = secs.iter().cloned().fold(0.0, f64::max);
        assert!(min >= 0.0 && max <= 600.0);
        assert!(max - min > 300.0, "arrivals should spread over the window");
    }

    #[test]
    fn report_prob_controls_fallback_path() {
        let mut rng = Rng::new(5);
        let f = Fleet::generate(
            FleetKind::ActiveHeterogeneous,
            100,
            FleetParams::default(),
            &mut rng,
        );
        let full = f.infos(1.0, &mut rng);
        assert!(full.iter().all(|i| i.t_epoch.is_some()));
        let none = f.infos(0.0, &mut rng);
        assert!(none.iter().all(|i| i.t_epoch.is_none()));
        assert!(none.iter().all(|i| i.hw_score.is_some()));
    }

    fn test_fleet(kind: FleetKind, n: usize, seed: u64) -> (Fleet, Rng) {
        let mut rng = Rng::new(seed);
        let f = Fleet::generate(kind, n, FleetParams::default(), &mut rng);
        (f, rng)
    }

    #[test]
    fn no_faults_path_is_bit_identical_to_plain_offsets() {
        let (f, mut rng) = test_fleet(FleetKind::ActiveHeterogeneous, 12, 21);
        let mut rng2 = rng.clone();
        let plain = f.arrival_offsets(1_000_000, 600.0, &mut rng);
        let mut st = FaultState::new(12);
        let draw = f.faulty_arrival_offsets(
            1_000_000,
            600.0,
            &FleetFaults::none(),
            0,
            &mut st,
            &mut rng2,
        );
        assert_eq!(plain, draw.offsets);
        assert!(draw.present.iter().all(|&p| p));
        assert!(draw.on_time.iter().all(|&o| o));
        // the rng streams stay aligned after the draw
        assert_eq!(rng.next_u64(), rng2.next_u64());
    }

    #[test]
    fn faulty_draws_are_deterministic_per_seed() {
        let faults = FleetFaults::scenario("stragglers", 30.0).unwrap();
        let run = |seed: u64| {
            let (f, mut rng) = test_fleet(FleetKind::ActiveHomogeneous, 10, seed);
            let mut st = FaultState::new(10);
            (0..5)
                .map(|r| {
                    f.faulty_arrival_offsets(1_000_000, 600.0, &faults, r, &mut st, &mut rng)
                        .offsets
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(7), run(7), "same seed, same fault draws");
        assert_ne!(run(7), run(8), "different seeds differ");
    }

    #[test]
    fn dropout_keeps_parties_out_until_rejoin() {
        let faults = FleetFaults {
            dropout_prob: 0.5,
            rejoin_after: 2,
            ..FleetFaults::default()
        };
        let (f, mut rng) = test_fleet(FleetKind::ActiveHomogeneous, 40, 3);
        let mut st = FaultState::new(40);
        let d0 = f.faulty_arrival_offsets(1_000_000, 600.0, &faults, 0, &mut st, &mut rng);
        let dropped: Vec<usize> =
            (0..40).filter(|&p| !d0.present[p]).collect();
        assert!(!dropped.is_empty(), "p=0.5 over 40 parties must drop some");
        // out for rounds 1..=2 (rejoin_after = 2), back in round 3
        for r in 1..=2 {
            let d = f.faulty_arrival_offsets(1_000_000, 600.0, &faults, r, &mut st, &mut rng);
            for &p in &dropped {
                assert!(!d.present[p], "party {p} must stay out in round {r}");
            }
        }
        let d3 = f.faulty_arrival_offsets(1_000_000, 600.0, &faults, 3, &mut st, &mut rng);
        // rejoined parties are eligible again (present unless re-dropped)
        let back = dropped.iter().filter(|&&p| d3.present[p]).count();
        assert!(back > 0, "some dropped parties must rejoin in round 3");
    }

    #[test]
    fn straggler_tail_stretches_arrivals_and_cutoff_marks_them() {
        let faults = FleetFaults {
            straggler_prob: 1.0,
            straggler_alpha: 1.1,
            straggler_cutoff_secs: Some(60.0),
            ..FleetFaults::default()
        };
        let (f, mut rng) = test_fleet(FleetKind::ActiveHomogeneous, 64, 5);
        let mut st = FaultState::new(64);
        let d = f.faulty_arrival_offsets(1_000_000, 600.0, &faults, 0, &mut st, &mut rng);
        let secs: Vec<f64> = d.offsets.iter().map(|&t| crate::sim::to_secs(t)).collect();
        // every party stalls ≥ its base (~30s) and the heavy tail pushes
        // a meaningful fraction past the 60s deadline
        let late = (0..64).filter(|&p| !d.on_time[p]).count();
        assert!(late > 0, "alpha=1.1 must push arrivals past the cutoff");
        assert!(late < 64, "not everyone stalls past 2× the epoch");
        for (p, &s) in secs.iter().enumerate() {
            assert!(s > 0.0);
            assert_eq!(d.on_time[p], s <= 60.0, "party {p}: {s}");
        }
        assert_eq!(d.expected_on_time(), 64 - late);
    }

    #[test]
    fn diurnal_wave_modulates_dropout_over_rounds() {
        let faults = FleetFaults {
            diurnal_amplitude: 0.8,
            diurnal_period_rounds: 4,
            ..FleetFaults::default()
        };
        // the wave peaks mid-period and vanishes at the trough
        let peak = faults.dropout_at(2, 0, 1);
        let trough = faults.dropout_at(0, 0, 1);
        assert!(peak > 0.7, "peak={peak}");
        assert!(trough < 0.01, "trough={trough}");
        // per-party phase spreads the wave across the fleet
        assert!(
            (faults.dropout_at(0, 0, 4) - faults.dropout_at(0, 2, 4)).abs() > 0.1,
            "phased parties must see different availability"
        );
    }

    #[test]
    fn weight_skew_preserves_total_and_skews_shares() {
        let (mut f, mut rng) = test_fleet(FleetKind::ActiveHomogeneous, 32, 9);
        let before: f64 = f.parties.iter().map(|p| p.dataset_items).sum();
        f.apply_weight_skew(0.2, &mut rng);
        let after: f64 = f.parties.iter().map(|p| p.dataset_items).sum();
        assert!((before - after).abs() / before < 1e-9, "total preserved");
        let items: Vec<f64> = f.parties.iter().map(|p| p.dataset_items).collect();
        let s = crate::util::stats::Summary::of(&items);
        assert!(s.cv() > 0.5, "alpha=0.2 must skew hard, cv={}", s.cv());
    }

    #[test]
    fn scenarios_resolve_and_baseline_is_none() {
        for name in FleetFaults::all_scenarios() {
            let f = FleetFaults::scenario(name, 30.0).unwrap_or_else(|| panic!("{name}"));
            assert_eq!(f.is_none(), *name == "baseline", "{name}");
        }
        assert!(FleetFaults::scenario("bogus", 30.0).is_none());
    }

    #[test]
    fn synth_dataset_shapes_and_skew() {
        let (x, y) = synth_party_dataset(3, 128, 64, 10, 0.3, 42);
        assert_eq!(x.len(), 128 * 64);
        assert_eq!(y.len(), 128 * 10);
        // one-hot rows
        for i in 0..128 {
            let row = &y[i * 10..(i + 1) * 10];
            assert!((row.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        }
        // low alpha -> skewed labels
        let mut counts = [0usize; 10];
        for i in 0..128 {
            let label = y[i * 10..(i + 1) * 10].iter().position(|&v| v == 1.0).unwrap();
            counts[label] += 1;
        }
        let max = *counts.iter().max().unwrap();
        assert!(max > 25, "expected label skew, counts={counts:?}");
        // deterministic per (party, seed)
        let (x2, _) = synth_party_dataset(3, 128, 64, 10, 0.3, 42);
        assert_eq!(x, x2);
        let (x3, _) = synth_party_dataset(4, 128, 64, 10, 0.3, 42);
        assert_ne!(x, x3);
    }
}
