//! Persistent fusion worker pool + reusable scratch buffers.
//!
//! The §Perf hot-path problem this solves: `tree_reduce` used to spawn
//! fresh OS threads on *every call* (`std::thread::scope`), and every
//! aggregation round allocated model-sized `Vec<f32>`s (66–138 MB for the
//! zoo models) for partial sums and outputs. At 10k-party × 50-round × 4-
//! strategy sweep scale, thread spawn + page-fault cost dominates the
//! fusion math itself. This module provides:
//!
//! * [`WorkerPool`] — a fixed set of long-lived worker threads fed through
//!   a channel. `run_all` executes a batch of borrowed (non-`'static`)
//!   closures with the *caller participating* in the drain, so the pool is
//!   deadlock-free even when nested or sized to one thread, and every
//!   borrow is provably dead before `run_all` returns (the lifetime
//!   erasure below is sound for exactly that reason).
//! * [`ScratchPool`] — a free-list of reusable `Vec<f32>` buffers handed
//!   out as RAII [`ScratchBuf`]s. After warm-up, taking a model-sized
//!   buffer is a pop + `resize`, not an allocation.
//!
//! Both have process-wide singletons ([`WorkerPool::global`],
//! [`ScratchPool::global`]) shared by `fusion`, `runtime`,
//! `coordinator::live` and the `bench::figs` scenario sweeps.

use std::cell::Cell;
use std::collections::VecDeque;
use std::ops::{Deref, DerefMut};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex, OnceLock};

// ---------------------------------------------------------------------------
// process-global pool telemetry
// ---------------------------------------------------------------------------
//
// The worker/scratch pools are `OnceLock` process singletons shared by
// every session, so their throughput counters live beside them rather
// than in any one `telemetry::Registry`. Exporters sample these at dump
// time (`telemetry::export::sample_pool_stats`). Relaxed ordering: the
// counts are monotone and read only for reporting.

static POOL_TASKS_RUN: AtomicU64 = AtomicU64::new(0);
static SCRATCH_TAKE_HITS: AtomicU64 = AtomicU64::new(0);
static SCRATCH_TAKE_MISSES: AtomicU64 = AtomicU64::new(0);

/// A point-in-time sample of the process-global pool counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    /// Tasks executed through any [`WorkerPool::run_all`] (fold
    /// throughput proxy: one task per reduce shard / sweep cell).
    pub tasks_run: u64,
    /// [`ScratchPool::take`] calls served from a parked buffer.
    pub scratch_hits: u64,
    /// [`ScratchPool::take`] calls that had to allocate fresh.
    pub scratch_misses: u64,
    /// Worker count of the global pool (0 until first use).
    pub threads: usize,
}

/// Sample the process-global pool counters (never resets them).
pub fn pool_stats() -> PoolStats {
    PoolStats {
        tasks_run: POOL_TASKS_RUN.load(Ordering::Relaxed),
        scratch_hits: SCRATCH_TAKE_HITS.load(Ordering::Relaxed),
        scratch_misses: SCRATCH_TAKE_MISSES.load(Ordering::Relaxed),
        threads: std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(0),
    }
}

thread_local! {
    /// Id of the [`WorkerPool`] this thread is a worker of (0 = none).
    /// `run_all` re-entered on a worker of the same pool runs its tasks
    /// inline instead of queueing helper jobs — workers therefore never
    /// block on a latch, which is what makes the protocol deadlock-free.
    static WORKER_OF_POOL: Cell<usize> = const { Cell::new(0) };
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A boxed task for [`WorkerPool::run_all`]: may borrow from the caller's
/// stack (`'env`), must send its result back across threads.
pub type Task<'env, R> = Box<dyn FnOnce() -> R + Send + 'env>;

/// Counts outstanding helper jobs; `wait` returns when all checked in.
struct Latch {
    state: Mutex<usize>,
    cv: Condvar,
}

impl Latch {
    fn new(n: usize) -> Latch {
        Latch {
            state: Mutex::new(n),
            cv: Condvar::new(),
        }
    }

    fn count_down(&self) {
        let mut n = self.state.lock().unwrap();
        *n -= 1;
        if *n == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut n = self.state.lock().unwrap();
        while *n > 0 {
            n = self.cv.wait(n).unwrap();
        }
    }
}

/// Work shared between the caller and the pool helpers for one `run_all`.
struct Batch<'env, R> {
    queue: Mutex<VecDeque<(usize, Task<'env, R>)>>,
    results: Mutex<Vec<Option<R>>>,
    panicked: AtomicBool,
}

impl<R: Send> Batch<'_, R> {
    /// Pop and run tasks until the queue is empty. Panics inside a task are
    /// caught so pool workers survive; the flag re-raises on the caller.
    fn drain(&self) {
        loop {
            let next = self.queue.lock().unwrap().pop_front();
            let Some((i, task)) = next else { break };
            match catch_unwind(AssertUnwindSafe(task)) {
                Ok(r) => self.results.lock().unwrap()[i] = Some(r),
                Err(_) => self.panicked.store(true, Ordering::SeqCst),
            }
        }
    }
}

/// A fixed-size pool of persistent worker threads.
///
/// Threads are spawned once (at construction) and reused across every
/// `run_all` call — the replacement for per-call `thread::scope` spawns on
/// the fusion and sweep hot paths.
pub struct WorkerPool {
    tx: mpsc::Sender<Job>,
    n_threads: usize,
    /// Unique pool id for the reentrancy check (see [`WORKER_OF_POOL`]).
    id: usize,
}

impl WorkerPool {
    /// Spawn `n_threads` persistent workers (at least one).
    pub fn new(n_threads: usize) -> WorkerPool {
        static NEXT_ID: AtomicUsize = AtomicUsize::new(1);
        let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
        let n = n_threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..n {
            let rx = Arc::clone(&rx);
            std::thread::Builder::new()
                .name(format!("fljit-pool-{i}"))
                .spawn(move || {
                    WORKER_OF_POOL.with(|w| w.set(id));
                    loop {
                        // Hold the lock only for the dequeue, never while
                        // running the job.
                        let job = rx.lock().unwrap().recv();
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // pool dropped
                        }
                    }
                })
                .expect("spawn fljit pool worker");
        }
        WorkerPool {
            tx,
            n_threads: n,
            id,
        }
    }

    /// Worker count (parallelism available to `run_all`).
    pub fn threads(&self) -> usize {
        self.n_threads
    }

    /// Process-wide pool sized to the machine, created on first use and
    /// reused for every subsequent fusion call and scenario sweep.
    pub fn global() -> &'static WorkerPool {
        static POOL: OnceLock<WorkerPool> = OnceLock::new();
        POOL.get_or_init(|| {
            let n = std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(4);
            WorkerPool::new(n)
        })
    }

    /// Run every task (possibly borrowing from the caller's stack) and
    /// return their results in task order. The caller thread drains the
    /// shared queue alongside up to `threads()` pool helpers, and a call
    /// made *from* one of this pool's workers (a nested `run_all`) runs
    /// its tasks inline — so workers never block, every queued helper job
    /// eventually runs, and same-pool nesting cannot deadlock. (Cyclic
    /// waits across two *different* pools are still the caller's problem.)
    ///
    /// Panics (after all tasks settle) if any task panicked.
    pub fn run_all<'env, R: Send>(&self, tasks: Vec<Task<'env, R>>) -> Vec<R> {
        let n = tasks.len();
        if n == 0 {
            return Vec::new();
        }
        POOL_TASKS_RUN.fetch_add(n as u64, Ordering::Relaxed);
        // Reentrancy: a task already running on one of this pool's workers
        // must not wait on further helper jobs (the queued helpers could
        // only ever run on workers that are themselves blocked waiting).
        // Run nested batches inline — the outer call already owns the
        // parallelism.
        if WORKER_OF_POOL.with(|w| w.get()) == self.id {
            return tasks.into_iter().map(|t| t()).collect();
        }
        let batch = Batch {
            queue: Mutex::new(tasks.into_iter().enumerate().collect()),
            results: Mutex::new((0..n).map(|_| None).collect()),
            panicked: AtomicBool::new(false),
        };
        // One task runs on the caller anyway; helpers beyond n-1 are waste.
        let n_helpers = self.n_threads.min(n - 1);
        let latch = Arc::new(Latch::new(n_helpers));
        {
            let batch_ref: &Batch<'env, R> = &batch;
            for _ in 0..n_helpers {
                let latch = Arc::clone(&latch);
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    batch_ref.drain();
                    // After this point the helper touches only the Arc'd
                    // latch, never the caller's stack.
                    latch.count_down();
                });
                // SAFETY: lifetime erasure to feed the 'static channel. The
                // job borrows `batch` on this stack frame; `latch.wait()`
                // below does not return until every helper has finished
                // `drain` and checked in, so the borrow never outlives the
                // frame. The latch itself is Arc-owned, so a helper
                // finishing its `count_down` after `wait` returns touches
                // only memory it co-owns.
                let job: Job = unsafe { std::mem::transmute(job) };
                if let Err(e) = self.tx.send(job) {
                    // Channel closed (pool being torn down): degrade to
                    // running the helper inline.
                    (e.0)();
                }
            }
            batch_ref.drain(); // caller participates
            latch.wait();
        }
        if batch.panicked.load(Ordering::SeqCst) {
            panic!("WorkerPool task panicked");
        }
        batch
            .results
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|r| r.expect("task drained without a result"))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// scratch buffers
// ---------------------------------------------------------------------------

/// Free-list of reusable `f32` buffers. `take` pops (or allocates) a
/// buffer and returns it as an RAII guard that puts it back on drop, so
/// steady-state aggregation rounds perform zero model-sized allocations.
#[derive(Default)]
pub struct ScratchPool {
    free: Mutex<Vec<Vec<f32>>>,
}

impl ScratchPool {
    pub fn new() -> ScratchPool {
        ScratchPool::default()
    }

    /// Process-wide scratch pool.
    pub fn global() -> &'static ScratchPool {
        static POOL: OnceLock<ScratchPool> = OnceLock::new();
        POOL.get_or_init(ScratchPool::new)
    }

    /// A buffer of exactly `len` elements with **unspecified contents**
    /// (zeroed only where the buffer had to grow) — scratch semantics:
    /// every consumer fully overwrites, so reuse pays no memset. Reuses
    /// the largest pooled buffer when one exists (capacity is retained
    /// across rounds).
    pub fn take(&self, len: usize) -> ScratchBuf<'_> {
        let popped = {
            let mut free = self.free.lock().unwrap();
            // Largest-first keeps big (model-sized) buffers circulating
            // instead of repeatedly growing small ones.
            free.pop()
        };
        match &popped {
            Some(_) => SCRATCH_TAKE_HITS.fetch_add(1, Ordering::Relaxed),
            None => SCRATCH_TAKE_MISSES.fetch_add(1, Ordering::Relaxed),
        };
        let mut v = popped.unwrap_or_default();
        if v.len() >= len {
            v.truncate(len);
        } else {
            v.resize(len, 0.0);
        }
        ScratchBuf {
            v,
            pool: Some(self),
        }
    }

    /// Buffers currently parked in the free list (test/inspection hook).
    pub fn parked(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    fn put(&self, v: Vec<f32>) {
        if v.capacity() == 0 {
            return;
        }
        let mut free = self.free.lock().unwrap();
        // Keep the free list sorted by capacity so `take` pops the largest.
        let at = free
            .binary_search_by_key(&v.capacity(), |b| b.capacity())
            .unwrap_or_else(|i| i);
        free.insert(at, v);
    }
}

/// RAII scratch buffer: derefs to `[f32]`, returns to its pool on drop.
pub struct ScratchBuf<'p> {
    v: Vec<f32>,
    pool: Option<&'p ScratchPool>,
}

impl ScratchBuf<'_> {
    /// Detach the buffer from the pool, keeping the allocation.
    pub fn detach(mut self) -> Vec<f32> {
        self.pool = None;
        std::mem::take(&mut self.v)
    }
}

impl Deref for ScratchBuf<'_> {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.v
    }
}

impl DerefMut for ScratchBuf<'_> {
    fn deref_mut(&mut self) -> &mut [f32] {
        &mut self.v
    }
}

impl Drop for ScratchBuf<'_> {
    fn drop(&mut self) {
        if let Some(pool) = self.pool {
            pool.put(std::mem::take(&mut self.v));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_all_preserves_task_order() {
        let pool = WorkerPool::new(4);
        let tasks: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..64)
            .map(|i| Box::new(move || i * i) as Box<dyn FnOnce() -> usize + Send>)
            .collect();
        let got = pool.run_all(tasks);
        assert_eq!(got, (0..64).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn run_all_borrows_caller_data() {
        let pool = WorkerPool::new(2);
        let data: Vec<u64> = (0..1000).collect();
        let chunks: Vec<&[u64]> = data.chunks(100).collect();
        let tasks: Vec<Box<dyn FnOnce() -> u64 + Send>> = chunks
            .into_iter()
            .map(|c| Box::new(move || c.iter().sum::<u64>()) as Box<dyn FnOnce() -> u64 + Send>)
            .collect();
        let sums = pool.run_all(tasks);
        assert_eq!(sums.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    #[test]
    fn run_all_single_thread_pool_completes() {
        let pool = WorkerPool::new(1);
        let tasks: Vec<Box<dyn FnOnce() -> u32 + Send>> = (0..16)
            .map(|i| Box::new(move || i + 1) as Box<dyn FnOnce() -> u32 + Send>)
            .collect();
        assert_eq!(pool.run_all(tasks).iter().sum::<u32>(), (1..=16).sum());
    }

    #[test]
    fn run_all_nested_does_not_deadlock() {
        let pool = WorkerPool::new(2);
        let outer: Vec<Box<dyn FnOnce() -> u32 + Send>> = (0..4)
            .map(|i| {
                Box::new(move || {
                    let inner: Vec<Box<dyn FnOnce() -> u32 + Send>> = (0..4)
                        .map(|j| Box::new(move || i * 10 + j) as Box<dyn FnOnce() -> u32 + Send>)
                        .collect();
                    WorkerPool::global().run_all(inner).into_iter().sum()
                }) as Box<dyn FnOnce() -> u32 + Send>
            })
            .collect();
        let total: u32 = pool.run_all(outer).into_iter().sum();
        let want: u32 = (0..4u32).map(|i| (0..4).map(|j| i * 10 + j).sum::<u32>()).sum();
        assert_eq!(total, want);
    }

    #[test]
    fn run_all_nested_on_the_same_single_thread_pool_does_not_deadlock() {
        // The adversarial shape: every outer task re-enters the SAME pool,
        // and the pool has one worker. Reentrant calls must run inline
        // rather than queue helper jobs behind a blocked worker.
        let pool = Arc::new(WorkerPool::new(1));
        let outer: Vec<Box<dyn FnOnce() -> u32 + Send>> = (0..3)
            .map(|i| {
                let pool = Arc::clone(&pool);
                Box::new(move || {
                    let inner: Vec<Box<dyn FnOnce() -> u32 + Send>> = (0..3)
                        .map(|j| Box::new(move || i * 10 + j) as Box<dyn FnOnce() -> u32 + Send>)
                        .collect();
                    pool.run_all(inner).into_iter().sum()
                }) as Box<dyn FnOnce() -> u32 + Send>
            })
            .collect();
        let total: u32 = pool.run_all(outer).into_iter().sum();
        let want: u32 = (0..3u32).map(|i| (0..3).map(|j| i * 10 + j).sum::<u32>()).sum();
        assert_eq!(total, want);
    }

    #[test]
    #[should_panic(expected = "WorkerPool task panicked")]
    fn run_all_propagates_panics() {
        let pool = WorkerPool::new(2);
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..4)
            .map(|i| {
                Box::new(move || {
                    if i == 2 {
                        panic!("boom");
                    }
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        pool.run_all(tasks);
    }

    #[test]
    fn pool_survives_a_panicking_batch() {
        let pool = WorkerPool::new(2);
        let bad: Vec<Box<dyn FnOnce() + Send>> =
            vec![Box::new(|| panic!("first batch dies"))];
        assert!(catch_unwind(AssertUnwindSafe(|| pool.run_all(bad))).is_err());
        // Workers caught the panic and are still serving.
        let ok: Vec<Box<dyn FnOnce() -> u32 + Send>> = (0..8)
            .map(|i| Box::new(move || i) as Box<dyn FnOnce() -> u32 + Send>)
            .collect();
        assert_eq!(pool.run_all(ok).len(), 8);
    }

    #[test]
    fn scratch_buffers_are_reused_not_reallocated() {
        let pool = ScratchPool::new();
        let ptr = {
            let mut b = pool.take(1 << 16);
            assert_eq!(b.len(), 1 << 16);
            assert_eq!(b[0], 0.0, "freshly grown buffers are zeroed");
            b[0] = 1.0;
            b.as_ptr() as usize
        }; // drops back into the pool
        assert_eq!(pool.parked(), 1);
        let b2 = pool.take(1 << 16);
        assert_eq!(b2.as_ptr() as usize, ptr, "same allocation must be reused");
        assert_eq!(b2.len(), 1 << 16);
        // contents are unspecified on reuse (no memset) — b2[0] may be 1.0
        assert_eq!(pool.parked(), 0);
    }

    #[test]
    fn scratch_detach_keeps_buffer_out_of_pool() {
        let pool = ScratchPool::new();
        let v = pool.take(128).detach();
        assert_eq!(v.len(), 128);
        assert_eq!(pool.parked(), 0);
    }

    #[test]
    fn scratch_prefers_largest_parked_buffer() {
        let pool = ScratchPool::new();
        drop(pool.take(16));
        drop(pool.take(4096));
        drop(pool.take(64));
        assert_eq!(pool.parked(), 3);
        let big = pool.take(10);
        assert!(big.v.capacity() >= 4096, "largest buffer should pop first");
    }
}
