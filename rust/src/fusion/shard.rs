//! Fixed-bucket sharded aggregation — the L1/root fold algebra.
//!
//! The aggregator tree partitions parties across L1 shards by **fixed
//! range boundaries over party id**, but the unit of numerical state is
//! not the shard — it is one of [`BUCKETS`] *logical buckets*. A bucket
//! keeps a streaming weighted **sum** (`sum += w·x` in bucket-local
//! arrival order) instead of a running mean, and the root folds bucket
//! sums in ascending bucket id before normalizing once. Because
//!
//!   1. `bucket_of(party)` depends only on `(party, n_parties)` — never
//!      on the deployed shard count,
//!   2. a bucket is never split across shards
//!      (`shard_of_bucket(b, shards) = b·shards / BUCKETS` assigns each
//!      bucket wholly to one shard, contiguous ranges in shard order),
//!   3. per-bucket arrival order is the global deterministic production
//!      order restricted to that bucket (invariant to sharding),
//!
//! the root's fold sequence is *the same f32 operations in the same
//! order* for every shard count 1..=[`BUCKETS`] — bit-identity across
//! `shards(n)` is structural, not a tolerance. This is the fold-plane
//! analogue of [`super::tree_reduce_with`]'s partial-sum trick, promoted
//! from a batch micro-optimisation to the data plane's algebra.

use super::Aggregator;
use crate::fusion::pool::ScratchPool;

/// Number of fixed logical buckets. Shard counts above this are
/// rejected at the session boundary; 64 buckets keep the per-checkpoint
/// metadata trivial while allowing fine-grained shard scaling.
pub const BUCKETS: usize = 64;

/// The logical bucket owning `party` — a contiguous, monotone range
/// partition of `0..n_parties` that never depends on the shard count.
pub fn bucket_of(party: usize, n_parties: usize) -> usize {
    debug_assert!(n_parties > 0);
    let b = party * BUCKETS / n_parties.max(1);
    b.min(BUCKETS - 1)
}

/// The L1 shard owning bucket `b` when `shards` shards are deployed.
/// Monotone in `b`, so each shard owns a contiguous bucket range.
pub fn shard_of_bucket(bucket: usize, shards: usize) -> usize {
    debug_assert!(bucket < BUCKETS && shards > 0);
    bucket * shards / BUCKETS
}

/// The L1 shard owning `party` — composition of the two fixed maps.
pub fn shard_of(party: usize, n_parties: usize, shards: usize) -> usize {
    shard_of_bucket(bucket_of(party, n_parties), shards)
}

/// The contiguous bucket range shard `s` owns (inverse of
/// [`shard_of_bucket`]): `b` is owned by `s` iff
/// `ceil(s·BUCKETS/shards) <= b < ceil((s+1)·BUCKETS/shards)`.
pub fn owned_buckets(shard: usize, shards: usize) -> std::ops::Range<usize> {
    debug_assert!(shard < shards && shards > 0);
    let div_ceil = |a: usize, b: usize| (a + b - 1) / b;
    div_ceil(shard * BUCKETS, shards)..div_ceil((shard + 1) * BUCKETS, shards)
}

/// Checkpoint metadata for one non-empty bucket (the numerical sum
/// itself travels in the checkpoint's `acc` field, concatenated in
/// bucket order).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BucketMeta {
    pub bucket: u32,
    pub weight: f32,
    pub folds: u32,
}

/// One bucket's streaming weighted sum.
#[derive(Clone, Debug)]
pub struct BucketAcc {
    pub bucket: u32,
    pub sum: Vec<f32>,
    pub weight: f32,
    pub folds: u32,
}

/// An L1 shard's partial aggregate: the non-empty buckets it owns,
/// sparse and sorted by bucket id. Folds updates JIT in arrival order;
/// the root combines shards' buckets with [`root_fold`].
#[derive(Clone, Debug)]
pub struct ShardAccum {
    dim: usize,
    pub buckets: Vec<BucketAcc>,
    pub n_merged: usize,
}

impl ShardAccum {
    pub fn new(dim: usize) -> ShardAccum {
        ShardAccum {
            dim,
            buckets: Vec::new(),
            n_merged: 0,
        }
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.n_merged == 0
    }

    /// Total weight folded so far (chained in bucket order, matching
    /// the root fold's weight chain for this shard's slice of it).
    pub fn weight(&self) -> f32 {
        let mut w = 0.0f32;
        for b in &self.buckets {
            w += b.weight;
        }
        w
    }

    /// Fold one party's update into its bucket: `sum += w·x` (assign on
    /// the bucket's first fold so reused scratch never leaks in).
    pub fn fold(&mut self, party: usize, n_parties: usize, data: &[f32], weight: f32) {
        assert_eq!(data.len(), self.dim, "update length mismatch");
        assert!(
            weight > 0.0 && weight.is_finite(),
            "shard fold: weight must be positive and finite, got {weight}"
        );
        let bucket = bucket_of(party, n_parties) as u32;
        let at = match self.buckets.binary_search_by_key(&bucket, |b| b.bucket) {
            Ok(i) => i,
            Err(i) => {
                self.buckets.insert(
                    i,
                    BucketAcc {
                        bucket,
                        sum: vec![0.0; self.dim],
                        weight: 0.0,
                        folds: 0,
                    },
                );
                i
            }
        };
        let b = &mut self.buckets[at];
        if b.folds == 0 {
            for (s, &x) in b.sum.iter_mut().zip(data.iter()) {
                *s = weight * x;
            }
            b.weight = weight;
        } else {
            for (s, &x) in b.sum.iter_mut().zip(data.iter()) {
                *s += weight * x;
            }
            b.weight += weight;
        }
        b.folds += 1;
        self.n_merged += 1;
    }

    /// Flatten to checkpoint parts: `(acc, weight, n_merged, metas)`
    /// where `acc` is the per-bucket sums concatenated in bucket order
    /// (`None` when nothing folded yet).
    pub fn to_parts(&self) -> (Option<Vec<f32>>, f32, usize, Vec<BucketMeta>) {
        if self.n_merged == 0 {
            return (None, 0.0, 0, Vec::new());
        }
        let mut acc = Vec::with_capacity(self.buckets.len() * self.dim);
        let mut metas = Vec::with_capacity(self.buckets.len());
        for b in &self.buckets {
            acc.extend_from_slice(&b.sum);
            metas.push(BucketMeta {
                bucket: b.bucket,
                weight: b.weight,
                folds: b.folds,
            });
        }
        (Some(acc), self.weight(), self.n_merged, metas)
    }

    /// Restore from checkpoint parts (§5.5 per-shard resume). An empty
    /// `metas` with a present `acc` is a legacy single-fold checkpoint
    /// (pre-tree WAL): its running mean de-normalizes into one bucket-0
    /// sum so old logs still resume, best-effort.
    pub fn from_parts(
        dim: usize,
        acc: Option<&[f32]>,
        weight: f32,
        n_merged: usize,
        metas: &[BucketMeta],
    ) -> ShardAccum {
        let mut s = ShardAccum::new(dim);
        let Some(acc) = acc else { return s };
        if metas.is_empty() {
            if n_merged > 0 {
                assert_eq!(acc.len(), dim, "legacy checkpoint length mismatch");
                s.buckets.push(BucketAcc {
                    bucket: 0,
                    sum: acc.iter().map(|&v| v * weight).collect(),
                    weight,
                    folds: n_merged as u32,
                });
                s.n_merged = n_merged;
            }
            return s;
        }
        assert_eq!(
            acc.len(),
            metas.len() * dim,
            "checkpoint acc does not cover its bucket metas"
        );
        for (i, m) in metas.iter().enumerate() {
            s.buckets.push(BucketAcc {
                bucket: m.bucket,
                sum: acc[i * dim..(i + 1) * dim].to_vec(),
                weight: m.weight,
                folds: m.folds,
            });
        }
        s.n_merged = n_merged;
        s
    }
}

/// Root fold: combine shards' buckets in ascending bucket order (shard
/// order × each shard's sorted buckets — globally sorted because bucket
/// ranges are contiguous per shard), normalize once by the chained
/// total weight. The accumulation buffer comes from the global
/// [`ScratchPool`] — zero model-sized allocations after warm-up; the
/// returned [`Aggregator`] finalizes exactly like the single-fold one.
pub fn root_fold(shards: &[&ShardAccum], dim: usize) -> Aggregator {
    root_fold_pooled(ScratchPool::global(), shards, dim)
}

/// [`root_fold`] against an explicit scratch pool.
pub fn root_fold_pooled(scratch: &ScratchPool, shards: &[&ShardAccum], dim: usize) -> Aggregator {
    let mut acc = scratch.take(dim);
    let mut total_weight = 0.0f32;
    let mut n_merged = 0usize;
    let mut seen_first = false;
    let mut last_bucket: Option<u32> = None;
    for s in shards {
        for b in &s.buckets {
            if b.folds == 0 {
                continue; // empty bucket: skipped, identical to it never existing
            }
            if let Some(prev) = last_bucket {
                assert!(
                    b.bucket > prev,
                    "root fold requires ascending bucket order (got {} after {prev})",
                    b.bucket
                );
            }
            last_bucket = Some(b.bucket);
            if !seen_first {
                acc.copy_from_slice(&b.sum);
                seen_first = true;
            } else {
                for (a, &v) in acc.iter_mut().zip(b.sum.iter()) {
                    *a += v;
                }
            }
            total_weight += b.weight;
            n_merged += b.folds as usize;
        }
    }
    if n_merged == 0 {
        return Aggregator::new(dim);
    }
    assert!(
        total_weight > 0.0 && total_weight.is_finite(),
        "root fold: total weight must be positive and finite, got {total_weight}"
    );
    let inv = 1.0 / total_weight;
    let mut mean = Vec::with_capacity(dim);
    mean.extend(acc.iter().map(|&a| a * inv));
    Aggregator::from_parts(mean, total_weight, n_merged)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::Algorithm;
    use crate::util::rng::Rng;

    #[test]
    fn bucket_partition_covers_and_is_monotone() {
        for n_parties in [1usize, 2, 3, 7, 63, 64, 65, 1000] {
            let mut prev = 0usize;
            for p in 0..n_parties {
                let b = bucket_of(p, n_parties);
                assert!(b < BUCKETS);
                assert!(b >= prev, "bucket map must be monotone in party id");
                prev = b;
            }
        }
    }

    #[test]
    fn every_bucket_owned_by_exactly_one_shard_for_all_shard_counts() {
        for shards in 1..=BUCKETS {
            let mut owners = vec![0usize; BUCKETS];
            for s in 0..shards {
                for b in owned_buckets(s, shards) {
                    assert_eq!(shard_of_bucket(b, shards), s, "shards={shards} b={b}");
                    owners[b] += 1;
                }
            }
            assert!(
                owners.iter().all(|&c| c == 1),
                "shards={shards}: every bucket owned exactly once"
            );
        }
    }

    fn synth_updates(n: usize, dim: usize, seed: u64) -> Vec<(Vec<f32>, f32)> {
        let mut rng = Rng::new(seed);
        (0..n)
            .map(|_| {
                let u: Vec<f32> = (0..dim).map(|_| rng.f32() * 2.0 - 1.0).collect();
                let w = 1.0 + rng.f32() * 9.0;
                (u, w)
            })
            .collect()
    }

    /// The tentpole algebra: any shard grouping of the fixed buckets
    /// folds to bit-identical root output.
    #[test]
    fn root_fold_is_bit_identical_across_shard_counts() {
        let n_parties = 23;
        let dim = 65;
        let updates = synth_updates(n_parties, dim, 0xF0CA);
        let fold_with = |shards: usize| -> Aggregator {
            let mut accs: Vec<ShardAccum> =
                (0..shards).map(|_| ShardAccum::new(dim)).collect();
            // global arrival order restricted per shard — exactly what
            // per-shard topics preserve
            for (p, (u, w)) in updates.iter().enumerate() {
                accs[shard_of(p, n_parties, shards)].fold(p, n_parties, u, *w);
            }
            let refs: Vec<&ShardAccum> = accs.iter().collect();
            root_fold(&refs, dim)
        };
        let gold = fold_with(1);
        for shards in [2usize, 3, 7, 16, 64] {
            let got = fold_with(shards);
            assert_eq!(got.weight.to_bits(), gold.weight.to_bits(), "shards={shards}");
            assert_eq!(got.n_merged, gold.n_merged, "shards={shards}");
            for (a, b) in got.acc.iter().zip(gold.acc.iter()) {
                assert_eq!(a.to_bits(), b.to_bits(), "shards={shards}");
            }
        }
    }

    #[test]
    fn root_fold_tracks_weighted_mean_within_tolerance() {
        let n_parties = 9;
        let dim = 33;
        let updates = synth_updates(n_parties, dim, 0xBEE);
        let mut acc = ShardAccum::new(dim);
        for (p, (u, w)) in updates.iter().enumerate() {
            acc.fold(p, n_parties, u, *w);
        }
        let agg = root_fold(&[&acc], dim);
        let refs: Vec<&[f32]> = updates.iter().map(|(u, _)| u.as_slice()).collect();
        let ws: Vec<f32> = updates.iter().map(|(_, w)| *w).collect();
        let gold = crate::fusion::weighted_mean(&refs, &ws);
        for (a, g) in agg.acc.iter().zip(gold.iter()) {
            assert!((a - g).abs() < 1e-4, "{a} vs {g}");
        }
        let model = agg.finalize(Algorithm::FedAvg, None);
        assert_eq!(model.len(), dim);
    }

    #[test]
    fn checkpoint_roundtrip_is_bit_identical() {
        let n_parties = 11;
        let dim = 17;
        let updates = synth_updates(n_parties, dim, 0xC0DE);
        let mut acc = ShardAccum::new(dim);
        for (p, (u, w)) in updates.iter().enumerate().take(7) {
            acc.fold(p, n_parties, u, *w);
        }
        let (bytes, weight, n_merged, metas) = acc.to_parts();
        let restored =
            ShardAccum::from_parts(dim, bytes.as_deref(), weight, n_merged, &metas);
        // continuing the fold after restore ≡ never checkpointing
        let mut cont = restored;
        let mut gold = acc.clone();
        for (p, (u, w)) in updates.iter().enumerate().skip(7) {
            cont.fold(p, n_parties, u, *w);
            gold.fold(p, n_parties, u, *w);
        }
        let a = root_fold(&[&cont], dim);
        let b = root_fold(&[&gold], dim);
        assert_eq!(a.weight.to_bits(), b.weight.to_bits());
        for (x, y) in a.acc.iter().zip(b.acc.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn empty_shards_and_buckets_do_not_wedge_the_root() {
        let dim = 8;
        let empty = ShardAccum::new(dim);
        let agg = root_fold(&[&empty, &empty], dim);
        assert_eq!(agg.n_merged, 0);
        // finalize with a previous global falls back to it upstream; the
        // raw aggregator is simply zero-weight
        assert_eq!(agg.weight, 0.0);

        // one populated shard among empties folds as if alone
        let mut one = ShardAccum::new(dim);
        one.fold(0, 4, &vec![1.0; dim], 2.0);
        let a = root_fold(&[&empty, &one, &empty], dim);
        let b = root_fold(&[&one], dim);
        for (x, y) in a.acc.iter().zip(b.acc.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn legacy_checkpoint_without_metas_still_restores() {
        let dim = 4;
        let mean = vec![0.5f32; dim];
        let s = ShardAccum::from_parts(dim, Some(&mean), 4.0, 2, &[]);
        assert_eq!(s.n_merged, 2);
        let agg = root_fold(&[&s], dim);
        for v in &agg.acc {
            assert!((v - 0.5).abs() < 1e-6);
        }
    }
}
