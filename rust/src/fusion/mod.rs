//! Model-update fusion: the aggregation compute itself.
//!
//! §2.1: aggregation is a coordinate-wise function over flattened update
//! vectors. This module provides:
//!
//! * the three paper algorithms — [`Algorithm::FedAvg`],
//!   [`Algorithm::FedSgd`], [`Algorithm::FedProx`] — all reducible to a
//!   weighted mean (FedProx adds a server-side proximal pull toward the
//!   previous global model, mirroring `python/compile/kernels/fedprox_merge`);
//! * a streaming [`Aggregator`] that folds updates in as they arrive
//!   (eager/JIT) and can checkpoint/restore its partial state (§5.5);
//! * [`tree_reduce`] — the data-parallel reduction used when `N_agg`
//!   containers aggregate in parallel (§5.4);
//! * `t_pair` calibration (§5.4): measure pair-fusion on randomly generated
//!   updates of a zoo model's size.
//!
//! The arithmetic lives in pure-Rust kernels (`pair_merge_into`,
//! `wsum_into`) written to auto-vectorize; the identical math is also
//! available through the XLA artifacts (see `runtime::XlaFusion`), and an
//! integration test pins rust ≡ XLA ≡ (transitively, via pytest) pallas.

pub mod pool;
pub mod shard;

use crate::model::{ModelSpec, ModelUpdate};
use crate::util::rng::Rng;

pub use pool::{ScratchBuf, ScratchPool, WorkerPool};

/// Aggregation algorithm (§6.3 uses FedProx and FedSGD).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Algorithm {
    /// Weighted average of party weights (weights = #samples).
    FedAvg,
    /// Average of party gradients (uniform weights unless given).
    FedSgd,
    /// Weighted average + proximal pull toward the previous global model.
    FedProx { mu: f32 },
}

impl Algorithm {
    /// Parse an algorithm name, case-insensitively. `fedprox` accepts an
    /// optional server-pull coefficient as `fedprox:<mu>` (0 ≤ μ ≤ 1), so
    /// CLI sweeps can vary μ without code changes.
    pub fn parse(s: &str) -> Option<Algorithm> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "fedavg" => Some(Algorithm::FedAvg),
            "fedsgd" => Some(Algorithm::FedSgd),
            "fedprox" => Some(Algorithm::FedProx { mu: 0.1 }),
            _ => {
                let mu = s.strip_prefix("fedprox:")?.parse::<f32>().ok()?;
                if mu.is_finite() && (0.0..=1.0).contains(&mu) {
                    Some(Algorithm::FedProx { mu })
                } else {
                    None
                }
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::FedAvg => "fedavg",
            Algorithm::FedSgd => "fedsgd",
            Algorithm::FedProx { .. } => "fedprox",
        }
    }
}

// ---------------------------------------------------------------------------
// kernels (pure Rust, autovectorizing)
// ---------------------------------------------------------------------------

/// acc ← (w_acc·acc + w_b·b) / (w_acc + w_b), in place. The `t_pair` unit.
///
/// Panics if the combined weight is not positive and finite — a zero total
/// would silently turn the mean into ±inf/NaN garbage.
pub fn pair_merge_into(acc: &mut [f32], w_acc: f32, b: &[f32], w_b: f32) {
    assert_eq!(acc.len(), b.len(), "update length mismatch");
    let total = w_acc + w_b;
    assert!(
        total > 0.0 && total.is_finite(),
        "pair_merge_into: total weight must be positive and finite, got {w_acc} + {w_b}"
    );
    let inv = 1.0 / (w_acc + w_b);
    let ca = w_acc * inv;
    let cb = w_b * inv;
    for (a, &x) in acc.iter_mut().zip(b.iter()) {
        *a = *a * ca + x * cb;
    }
}

/// out ← Σ_k w[k]·u[k], updates as parallel slices (single full pass per
/// update; see `wsum_blocked_into` for the cache-blocked hot path).
pub fn wsum_into(out: &mut [f32], updates: &[&[f32]], w: &[f32]) {
    assert_eq!(updates.len(), w.len());
    out.fill(0.0);
    for (u, &wk) in updates.iter().zip(w.iter()) {
        assert_eq!(u.len(), out.len(), "update length mismatch");
        for (o, &x) in out.iter_mut().zip(u.iter()) {
            *o += wk * x;
        }
    }
}

/// Cache block for the K-way fold: 16k f32 = 64 KiB — the accumulator
/// block stays L1/L2-resident while all K update rows stream through it,
/// so DRAM traffic drops from 3 vectors/update (pair-merge chain) to
/// ~(K+1)/K vectors/update. This is the §Perf L3 fusion optimization;
/// before/after in EXPERIMENTS.md.
pub const FOLD_BLOCK: usize = 16 * 1024;

/// out ← Σ_k w[k]·u[k] with cache blocking. The bulk-fusion hot path used
/// by lazy/JIT aggregation and the tree reduction.
pub fn wsum_blocked_into(out: &mut [f32], updates: &[&[f32]], w: &[f32]) {
    assert_eq!(updates.len(), w.len());
    let d = out.len();
    for u in updates {
        assert_eq!(u.len(), d, "update length mismatch");
    }
    out.fill(0.0);
    let mut off = 0;
    while off < d {
        let end = (off + FOLD_BLOCK).min(d);
        let mut k = 0;
        // 4-row unroll: one load+FMA stream per row, one store stream —
        // 4× fewer passes over the accumulator block and enough ILP to
        // keep the FMA ports busy.
        while k + 4 <= updates.len() {
            let (u0, u1, u2, u3) = (
                &updates[k][off..end],
                &updates[k + 1][off..end],
                &updates[k + 2][off..end],
                &updates[k + 3][off..end],
            );
            let (w0, w1, w2, w3) = (w[k], w[k + 1], w[k + 2], w[k + 3]);
            let ob = &mut out[off..end];
            for i in 0..ob.len() {
                ob[i] += w0 * u0[i] + w1 * u1[i] + w2 * u2[i] + w3 * u3[i];
            }
            k += 4;
        }
        while k < updates.len() {
            let ub = &updates[k][off..end];
            let wk = w[k];
            let ob = &mut out[off..end];
            for (o, &x) in ob.iter_mut().zip(ub.iter()) {
                *o += wk * x;
            }
            k += 1;
        }
        off = end;
    }
}

/// Weighted mean over K updates into a caller-provided buffer — the
/// zero-allocation hot path (cache-blocked; K=2 dispatches to the
/// 3-stream pair merge, which measures faster than a fill+fold there).
///
/// Edge cases are explicit rather than garbage: `updates` empty zeroes
/// `out`; a non-positive or non-finite total weight panics with a clear
/// message (the old behaviour silently produced `1.0/0.0 = inf` means).
pub fn weighted_mean_into(out: &mut [f32], updates: &[&[f32]], w: &[f32]) {
    assert_eq!(updates.len(), w.len(), "weights mismatch");
    if updates.is_empty() {
        out.fill(0.0);
        return;
    }
    let total: f32 = w.iter().sum();
    assert!(
        total > 0.0 && total.is_finite(),
        "weighted_mean: total weight must be positive and finite, got {total}"
    );
    if updates.len() == 2 {
        assert_eq!(out.len(), updates[0].len(), "update length mismatch");
        out.copy_from_slice(updates[0]);
        pair_merge_into(out, w[0], updates[1], w[1]);
        return;
    }
    wsum_blocked_into(out, updates, w);
    let inv = 1.0 / total;
    for o in out.iter_mut() {
        *o *= inv;
    }
}

/// Weighted mean over K updates, freshly allocated (reference path; the
/// hot paths use [`weighted_mean_into`] / [`weighted_mean_pooled`]).
pub fn weighted_mean(updates: &[&[f32]], w: &[f32]) -> Vec<f32> {
    let n = updates.first().map(|u| u.len()).unwrap_or(0);
    let mut out = vec![0.0f32; n];
    weighted_mean_into(&mut out, updates, w);
    out
}

/// Weighted mean into a pooled scratch buffer: after warm-up this performs
/// zero model-sized allocations per call — the buffer returns to `scratch`
/// when the returned guard drops (or is detached).
pub fn weighted_mean_pooled<'p>(
    scratch: &'p ScratchPool,
    updates: &[&[f32]],
    w: &[f32],
) -> ScratchBuf<'p> {
    let n = updates.first().map(|u| u.len()).unwrap_or(0);
    let mut out = scratch.take(n);
    weighted_mean_into(&mut out, updates, w);
    out
}

/// FedProx server merge: (1−μ)·weighted_mean + μ·global.
pub fn fedprox_merge(updates: &[&[f32]], w: &[f32], global: &[f32], mu: f32) -> Vec<f32> {
    let mut out = weighted_mean(updates, w);
    assert_eq!(out.len(), global.len());
    for (o, &g) in out.iter_mut().zip(global.iter()) {
        *o = (1.0 - mu) * *o + mu * g;
    }
    out
}

// ---------------------------------------------------------------------------
// streaming aggregator with checkpoint/restore
// ---------------------------------------------------------------------------

/// Partial aggregation state: a running weighted mean.
///
/// Folding updates one at a time (eager), in batches (batched), or all at
/// once (lazy/JIT) produces identical results — the algebra property the
/// strategies' "same aggregated model" integration test pins down.
#[derive(Clone, Debug)]
pub struct Aggregator {
    pub acc: Vec<f32>,
    pub weight: f32,
    pub n_merged: usize,
}

impl Aggregator {
    pub fn new(dim: usize) -> Aggregator {
        Aggregator {
            acc: vec![0.0; dim],
            weight: 0.0,
            n_merged: 0,
        }
    }

    /// Restore from a checkpoint (§5.5 preemption path).
    pub fn from_parts(acc: Vec<f32>, weight: f32, n_merged: usize) -> Aggregator {
        Aggregator {
            acc,
            weight,
            n_merged,
        }
    }

    /// Fold one update into the running mean.
    pub fn add(&mut self, update: &[f32], weight: f32) {
        if self.n_merged == 0 {
            self.acc.copy_from_slice(update);
            self.weight = weight;
        } else {
            pair_merge_into(&mut self.acc, self.weight, update, weight);
            self.weight += weight;
        }
        self.n_merged += 1;
    }

    /// Fold another partial aggregate in (tree reduction / checkpoint merge).
    pub fn merge(&mut self, other: &Aggregator) {
        if other.n_merged == 0 {
            return;
        }
        if self.n_merged == 0 {
            self.acc.copy_from_slice(&other.acc);
            self.weight = other.weight;
            self.n_merged = other.n_merged;
            return;
        }
        pair_merge_into(&mut self.acc, self.weight, &other.acc, other.weight);
        self.weight += other.weight;
        self.n_merged += other.n_merged;
    }

    /// Rewind to the empty state while keeping the accumulator allocation,
    /// so one `Aggregator` can be reused round after round (the first
    /// `add` after a reset overwrites the stale contents wholesale).
    pub fn reset(&mut self) {
        self.weight = 0.0;
        self.n_merged = 0;
    }

    /// Final global model for `alg` into a caller-provided buffer — the
    /// zero-allocation path (`out`'s capacity is reused across rounds).
    pub fn finalize_into(&self, alg: Algorithm, prev_global: Option<&[f32]>, out: &mut Vec<f32>) {
        out.clear();
        out.extend_from_slice(&self.acc);
        if let Algorithm::FedProx { mu } = alg {
            let g = prev_global.expect("FedProx finalize needs the previous global model");
            assert_eq!(out.len(), g.len(), "global model length mismatch");
            for (o, &gv) in out.iter_mut().zip(g.iter()) {
                *o = (1.0 - mu) * *o + mu * gv;
            }
        }
    }

    /// Final global model for `alg` (FedProx needs the previous global).
    pub fn finalize(&self, alg: Algorithm, prev_global: Option<&[f32]>) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.acc.len());
        self.finalize_into(alg, prev_global, &mut out);
        out
    }
}

/// Data-parallel aggregation: split `updates` across `shards` workers
/// (stand-in for `N_agg` aggregator containers), each folds its shard with
/// the cache-blocked weighted sum, then partials merge pairwise (§5.4's
/// parallel aggregation). Returns a weighted-mean [`Aggregator`] identical
/// (within fp tolerance) to streaming the updates one by one.
///
/// Shards execute on the persistent global [`WorkerPool`] with partial
/// sums drawn from the global [`ScratchPool`] — no OS threads are spawned
/// and no per-shard model-sized vectors are allocated after warm-up.
pub fn tree_reduce(updates: &[ModelUpdate], shards: usize) -> Aggregator {
    tree_reduce_with(WorkerPool::global(), ScratchPool::global(), updates, shards)
}

/// [`tree_reduce`] against explicit pools (tests/benches inject their own).
pub fn tree_reduce_with<'a>(
    workers: &WorkerPool,
    scratch: &'a ScratchPool,
    updates: &'a [ModelUpdate],
    shards: usize,
) -> Aggregator {
    assert!(!updates.is_empty(), "tree_reduce: no updates to aggregate");
    let dim = updates[0].data.len();
    let shards = shards.max(1).min(updates.len());
    let chunk = updates.len().div_ceil(shards);
    // (weighted sum, total weight, count) per shard
    type ShardTask<'t> = Box<dyn FnOnce() -> (ScratchBuf<'t>, f32, usize) + Send + 't>;
    let tasks: Vec<ShardTask<'a>> = updates
        .chunks(chunk)
        .map(|part| {
            Box::new(move || {
                let views: Vec<&[f32]> = part.iter().map(|u| u.data.as_slice()).collect();
                let ws: Vec<f32> = part.iter().map(|u| u.weight).collect();
                let mut sum = scratch.take(dim);
                wsum_blocked_into(&mut sum, &views, &ws);
                (sum, ws.iter().sum::<f32>(), part.len())
            }) as ShardTask<'a>
        })
        .collect();
    let mut partials = workers.run_all(tasks).into_iter();
    // combine partial sums into the first shard's buffer, normalize once
    let (first, mut weight, mut n_merged) = partials.next().expect("at least one shard");
    let mut acc = first.detach();
    for (sum, w, n) in partials {
        for (a, &x) in acc.iter_mut().zip(sum.iter()) {
            *a += x;
        }
        weight += w;
        n_merged += n;
    }
    assert!(
        weight > 0.0 && weight.is_finite(),
        "tree_reduce: total weight must be positive and finite, got {weight}"
    );
    let inv = 1.0 / weight;
    for a in &mut acc {
        *a *= inv;
    }
    Aggregator {
        acc,
        weight,
        n_merged,
    }
}

/// The pre-pool `tree_reduce`: spawns fresh scoped OS threads and
/// allocates per-shard sums on every call. Kept as the measured baseline
/// for `fusion_hot_path` (pool vs per-call spawn) — do not use on the
/// request path.
pub fn tree_reduce_spawning(updates: &[ModelUpdate], shards: usize) -> Aggregator {
    assert!(!updates.is_empty(), "tree_reduce: no updates to aggregate");
    let dim = updates[0].data.len();
    let shards = shards.max(1).min(updates.len());
    let chunk = updates.len().div_ceil(shards);
    let partials: Vec<(Vec<f32>, f32, usize)> = std::thread::scope(|s| {
        let handles: Vec<_> = updates
            .chunks(chunk)
            .map(|part| {
                s.spawn(move || {
                    let views: Vec<&[f32]> = part.iter().map(|u| u.data.as_slice()).collect();
                    let ws: Vec<f32> = part.iter().map(|u| u.weight).collect();
                    let mut sum = vec![0.0f32; dim];
                    wsum_blocked_into(&mut sum, &views, &ws);
                    (sum, ws.iter().sum::<f32>(), part.len())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut acc = vec![0.0f32; dim];
    let mut weight = 0.0f32;
    let mut n_merged = 0usize;
    for (sum, w, n) in &partials {
        for (a, &x) in acc.iter_mut().zip(sum.iter()) {
            *a += x;
        }
        weight += w;
        n_merged += n;
    }
    assert!(
        weight > 0.0 && weight.is_finite(),
        "tree_reduce: total weight must be positive and finite, got {weight}"
    );
    let inv = 1.0 / weight;
    for a in &mut acc {
        *a *= inv;
    }
    Aggregator {
        acc,
        weight,
        n_merged,
    }
}

// ---------------------------------------------------------------------------
// t_pair calibration (§5.4)
// ---------------------------------------------------------------------------

/// Measured pair-fusion cost for a model (seconds), averaged over `reps`.
/// "t_pair … can be easily computed offline … by randomly generating model
/// updates and measuring the time taken to fuse pairs."
pub fn calibrate_t_pair(spec: &ModelSpec, reps: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let a = ModelUpdate::random(spec, &mut rng, 1.0);
    let b = ModelUpdate::random(spec, &mut rng, 1.0);
    let mut acc = a.data.clone();
    // warm-up
    pair_merge_into(&mut acc, 1.0, &b.data, 1.0);
    let start = std::time::Instant::now();
    for i in 0..reps {
        pair_merge_into(&mut acc, 1.0 + i as f32, &b.data, 1.0);
    }
    start.elapsed().as_secs_f64() / reps.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn updates_from(g: &mut prop::Gen, k: usize, d: usize) -> Vec<ModelUpdate> {
        (0..k)
            .map(|_| ModelUpdate {
                data: g.vec_f32(d, 1.0),
                weight: g.f64(0.1, 10.0) as f32,
            })
            .collect()
    }

    fn reference_mean(us: &[ModelUpdate]) -> Vec<f32> {
        // f64 accumulation as the gold standard
        let d = us[0].data.len();
        let mut acc = vec![0.0f64; d];
        let mut tw = 0.0f64;
        for u in us {
            for (a, &x) in acc.iter_mut().zip(u.data.iter()) {
                *a += (u.weight as f64) * (x as f64);
            }
            tw += u.weight as f64;
        }
        acc.iter().map(|a| (*a / tw) as f32).collect()
    }

    #[test]
    fn pair_merge_is_weighted_mean() {
        let mut acc = vec![1.0, 2.0, 3.0];
        pair_merge_into(&mut acc, 3.0, &[5.0, 6.0, 7.0], 1.0);
        assert_eq!(acc, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn wsum_matches_manual() {
        let u1 = [1.0f32, 0.0];
        let u2 = [0.0f32, 2.0];
        let mut out = vec![0.0; 2];
        wsum_into(&mut out, &[&u1, &u2], &[2.0, 3.0]);
        assert_eq!(out, vec![2.0, 6.0]);
    }

    #[test]
    fn streaming_equals_batch_property() {
        prop::check("streaming==batch", prop::default_cases(), |g| {
            let k = g.usize(1, 12);
            let d = g.usize(1, 512);
            let us = updates_from(g, k, d);
            let mut stream = Aggregator::new(d);
            for u in &us {
                stream.add(&u.data, u.weight);
            }
            let views: Vec<&[f32]> = us.iter().map(|u| u.data.as_slice()).collect();
            let ws: Vec<f32> = us.iter().map(|u| u.weight).collect();
            let batch = weighted_mean(&views, &ws);
            for (i, (a, b)) in stream.acc.iter().zip(batch.iter()).enumerate() {
                crate::prop_assert!(
                    prop::close(*a as f64, *b as f64, 1e-4),
                    "elem {i}: stream {a} vs batch {b}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn permutation_invariance_property() {
        prop::check("permutation-invariance", prop::default_cases(), |g| {
            let k = g.usize(2, 10);
            let d = g.usize(1, 256);
            let mut us = updates_from(g, k, d);
            let mut a1 = Aggregator::new(d);
            for u in &us {
                a1.add(&u.data, u.weight);
            }
            g.rng.shuffle(&mut us);
            let mut a2 = Aggregator::new(d);
            for u in &us {
                a2.add(&u.data, u.weight);
            }
            for (x, y) in a1.acc.iter().zip(a2.acc.iter()) {
                crate::prop_assert!(
                    prop::close(*x as f64, *y as f64, 1e-4),
                    "permutation changed result: {x} vs {y}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn tree_reduce_matches_sequential_property() {
        prop::check("tree==sequential", 24, |g| {
            let k = g.usize(1, 24);
            let d = g.usize(1, 300);
            let us = updates_from(g, k, d);
            let tree = tree_reduce(&us, g.usize(1, 6));
            let gold = reference_mean(&us);
            for (x, y) in tree.acc.iter().zip(gold.iter()) {
                crate::prop_assert!(
                    prop::close(*x as f64, *y as f64, 1e-3),
                    "tree {x} vs gold {y}"
                );
            }
            crate::prop_assert!(tree.n_merged == k, "n_merged {} != {k}", tree.n_merged);
            Ok(())
        });
    }

    #[test]
    fn checkpoint_restore_equivalence() {
        // fold 5 updates, checkpoint after 2, restore, fold the rest ==
        // folding straight through (the §5.5 preemption invariant).
        let mut g = prop::Gen::new(0xCAFE, 50);
        let us = updates_from(&mut g, 5, 128);
        let mut straight = Aggregator::new(128);
        for u in &us {
            straight.add(&u.data, u.weight);
        }
        let mut first = Aggregator::new(128);
        first.add(&us[0].data, us[0].weight);
        first.add(&us[1].data, us[1].weight);
        let ckpt = (first.acc.clone(), first.weight, first.n_merged);
        let mut resumed = Aggregator::from_parts(ckpt.0, ckpt.1, ckpt.2);
        for u in &us[2..] {
            resumed.add(&u.data, u.weight);
        }
        for (a, b) in straight.acc.iter().zip(resumed.acc.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
        assert_eq!(straight.n_merged, resumed.n_merged);
    }

    #[test]
    fn fedprox_finalize_pulls_toward_global() {
        let mut agg = Aggregator::new(2);
        agg.add(&[2.0, 2.0], 1.0);
        let global = [0.0f32, 4.0];
        let out = agg.finalize(Algorithm::FedProx { mu: 0.5 }, Some(&global));
        assert_eq!(out, vec![1.0, 3.0]);
        let avg = agg.finalize(Algorithm::FedAvg, None);
        assert_eq!(avg, vec![2.0, 2.0]);
    }

    #[test]
    fn fedprox_merge_fn_matches_finalize() {
        let mut g = prop::Gen::new(7, 50);
        let us = updates_from(&mut g, 4, 64);
        let global = g.vec_f32(64, 1.0);
        let views: Vec<&[f32]> = us.iter().map(|u| u.data.as_slice()).collect();
        let ws: Vec<f32> = us.iter().map(|u| u.weight).collect();
        let direct = fedprox_merge(&views, &ws, &global, 0.3);
        let mut agg = Aggregator::new(64);
        for u in &us {
            agg.add(&u.data, u.weight);
        }
        let via_agg = agg.finalize(Algorithm::FedProx { mu: 0.3 }, Some(&global));
        for (a, b) in direct.iter().zip(via_agg.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn algorithm_parse_roundtrip() {
        for n in ["fedavg", "fedsgd", "fedprox"] {
            assert_eq!(Algorithm::parse(n).unwrap().name(), n);
        }
        assert!(Algorithm::parse("magic").is_none());
    }

    #[test]
    fn algorithm_parse_case_insensitive_and_mu() {
        assert_eq!(Algorithm::parse("FedAvg"), Some(Algorithm::FedAvg));
        assert_eq!(Algorithm::parse(" FEDSGD "), Some(Algorithm::FedSgd));
        assert_eq!(
            Algorithm::parse("FedProx:0.25"),
            Some(Algorithm::FedProx { mu: 0.25 })
        );
        assert_eq!(
            Algorithm::parse("fedprox:0"),
            Some(Algorithm::FedProx { mu: 0.0 })
        );
        assert_eq!(
            Algorithm::parse("fedprox"),
            Some(Algorithm::FedProx { mu: 0.1 })
        );
        assert!(Algorithm::parse("fedprox:1.5").is_none());
        assert!(Algorithm::parse("fedprox:-0.1").is_none());
        assert!(Algorithm::parse("fedprox:nan").is_none());
        assert!(Algorithm::parse("fedprox:").is_none());
    }

    #[test]
    fn weighted_mean_empty_updates_is_empty() {
        let out = weighted_mean(&[], &[]);
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic(expected = "total weight must be positive and finite")]
    fn weighted_mean_zero_total_weight_panics() {
        let u1 = [1.0f32, 2.0];
        let u2 = [3.0f32, 4.0];
        let u3 = [5.0f32, 6.0];
        weighted_mean(&[&u1, &u2, &u3], &[0.0, 0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "total weight must be positive and finite")]
    fn pair_merge_zero_total_weight_panics() {
        let mut acc = vec![1.0f32, 2.0];
        pair_merge_into(&mut acc, 0.0, &[3.0, 4.0], 0.0);
    }

    #[test]
    #[should_panic(expected = "no updates to aggregate")]
    fn tree_reduce_empty_panics_clearly() {
        tree_reduce(&[], 4);
    }

    #[test]
    fn pooled_weighted_mean_matches_fresh_alloc_property() {
        let scratch = pool::ScratchPool::new();
        prop::check("pooled==fresh weighted_mean", prop::default_cases(), |g| {
            let k = g.usize(1, 12);
            let d = g.usize(1, 4096);
            let us = updates_from(g, k, d);
            let views: Vec<&[f32]> = us.iter().map(|u| u.data.as_slice()).collect();
            let ws: Vec<f32> = us.iter().map(|u| u.weight).collect();
            let fresh = weighted_mean(&views, &ws);
            let pooled = weighted_mean_pooled(&scratch, &views, &ws);
            crate::prop_assert!(pooled.len() == fresh.len(), "length mismatch");
            for (i, (a, b)) in pooled.iter().zip(fresh.iter()).enumerate() {
                crate::prop_assert!(
                    (*a == *b) || prop::close(*a as f64, *b as f64, 1e-6),
                    "elem {i}: pooled {a} vs fresh {b}"
                );
            }
            Ok(())
        });
        assert!(
            scratch.parked() >= 1,
            "buffers must return to the pool for reuse"
        );
    }

    #[test]
    fn pool_tree_reduce_matches_spawning_and_sequential_property() {
        let workers = pool::WorkerPool::new(4);
        let scratch = pool::ScratchPool::new();
        prop::check("pool tree==spawn tree==fold", 24, |g| {
            let k = g.usize(1, 80);
            let d = g.usize(1, 300);
            let us = updates_from(g, k, d);
            let shards = g.usize(1, 8);
            let pooled = tree_reduce_with(&workers, &scratch, &us, shards);
            let spawned = tree_reduce_spawning(&us, shards);
            let gold = reference_mean(&us);
            crate::prop_assert!(
                pooled.n_merged == k && spawned.n_merged == k,
                "n_merged {} / {} != {k}",
                pooled.n_merged,
                spawned.n_merged
            );
            for ((i, (p, s)), gref) in pooled
                .acc
                .iter()
                .zip(spawned.acc.iter())
                .enumerate()
                .zip(gold.iter())
            {
                crate::prop_assert!(
                    *p == *s,
                    "elem {i}: pool {p} != spawn {s} (identical shard math must bit-match)"
                );
                crate::prop_assert!(
                    prop::close(*p as f64, *gref as f64, 1e-3),
                    "elem {i}: pool {p} vs reference {gref}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn finalize_into_matches_finalize_and_reset_reuses() {
        let mut g = prop::Gen::new(0xF00D, 60);
        let us = updates_from(&mut g, 5, 96);
        let global = g.vec_f32(96, 1.0);
        let mut agg = Aggregator::new(96);
        for u in &us {
            agg.add(&u.data, u.weight);
        }
        let mut out = Vec::new();
        for alg in [Algorithm::FedAvg, Algorithm::FedProx { mu: 0.3 }] {
            agg.finalize_into(alg, Some(&global), &mut out);
            assert_eq!(out, agg.finalize(alg, Some(&global)));
        }
        // reset + re-add reproduces a fresh aggregator without reallocating
        let cap_ptr = agg.acc.as_ptr();
        agg.reset();
        assert_eq!(agg.n_merged, 0);
        for u in &us {
            agg.add(&u.data, u.weight);
        }
        assert_eq!(agg.acc.as_ptr(), cap_ptr, "reset must keep the allocation");
        let mut fresh = Aggregator::new(96);
        for u in &us {
            fresh.add(&u.data, u.weight);
        }
        assert_eq!(agg.acc, fresh.acc);
    }

    #[test]
    fn calibration_returns_positive_time() {
        let spec = ModelSpec::new("cal", vec![("l", 1 << 16)]);
        let t = calibrate_t_pair(&spec, 3, 42);
        assert!(t > 0.0 && t < 1.0, "t_pair={t}");
    }
}
