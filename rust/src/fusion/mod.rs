//! Model-update fusion: the aggregation compute itself.
//!
//! §2.1: aggregation is a coordinate-wise function over flattened update
//! vectors. This module provides:
//!
//! * the three paper algorithms — [`Algorithm::FedAvg`],
//!   [`Algorithm::FedSgd`], [`Algorithm::FedProx`] — all reducible to a
//!   weighted mean (FedProx adds a server-side proximal pull toward the
//!   previous global model, mirroring `python/compile/kernels/fedprox_merge`);
//! * a streaming [`Aggregator`] that folds updates in as they arrive
//!   (eager/JIT) and can checkpoint/restore its partial state (§5.5);
//! * [`tree_reduce`] — the data-parallel reduction used when `N_agg`
//!   containers aggregate in parallel (§5.4);
//! * `t_pair` calibration (§5.4): measure pair-fusion on randomly generated
//!   updates of a zoo model's size.
//!
//! The arithmetic lives in pure-Rust kernels (`pair_merge_into`,
//! `wsum_into`) written to auto-vectorize; the identical math is also
//! available through the XLA artifacts (see `runtime::XlaFusion`), and an
//! integration test pins rust ≡ XLA ≡ (transitively, via pytest) pallas.

use crate::model::{ModelSpec, ModelUpdate};
use crate::util::rng::Rng;

/// Aggregation algorithm (§6.3 uses FedProx and FedSGD).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Algorithm {
    /// Weighted average of party weights (weights = #samples).
    FedAvg,
    /// Average of party gradients (uniform weights unless given).
    FedSgd,
    /// Weighted average + proximal pull toward the previous global model.
    FedProx { mu: f32 },
}

impl Algorithm {
    pub fn parse(s: &str) -> Option<Algorithm> {
        match s {
            "fedavg" => Some(Algorithm::FedAvg),
            "fedsgd" => Some(Algorithm::FedSgd),
            "fedprox" => Some(Algorithm::FedProx { mu: 0.1 }),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::FedAvg => "fedavg",
            Algorithm::FedSgd => "fedsgd",
            Algorithm::FedProx { .. } => "fedprox",
        }
    }
}

// ---------------------------------------------------------------------------
// kernels (pure Rust, autovectorizing)
// ---------------------------------------------------------------------------

/// acc ← (w_acc·acc + w_b·b) / (w_acc + w_b), in place. The `t_pair` unit.
pub fn pair_merge_into(acc: &mut [f32], w_acc: f32, b: &[f32], w_b: f32) {
    assert_eq!(acc.len(), b.len(), "update length mismatch");
    let inv = 1.0 / (w_acc + w_b);
    let ca = w_acc * inv;
    let cb = w_b * inv;
    for (a, &x) in acc.iter_mut().zip(b.iter()) {
        *a = *a * ca + x * cb;
    }
}

/// out ← Σ_k w[k]·u[k], updates as parallel slices (single full pass per
/// update; see `wsum_blocked_into` for the cache-blocked hot path).
pub fn wsum_into(out: &mut [f32], updates: &[&[f32]], w: &[f32]) {
    assert_eq!(updates.len(), w.len());
    out.fill(0.0);
    for (u, &wk) in updates.iter().zip(w.iter()) {
        assert_eq!(u.len(), out.len(), "update length mismatch");
        for (o, &x) in out.iter_mut().zip(u.iter()) {
            *o += wk * x;
        }
    }
}

/// Cache block for the K-way fold: 16k f32 = 64 KiB — the accumulator
/// block stays L1/L2-resident while all K update rows stream through it,
/// so DRAM traffic drops from 3 vectors/update (pair-merge chain) to
/// ~(K+1)/K vectors/update. This is the §Perf L3 fusion optimization;
/// before/after in EXPERIMENTS.md.
pub const FOLD_BLOCK: usize = 16 * 1024;

/// out ← Σ_k w[k]·u[k] with cache blocking. The bulk-fusion hot path used
/// by lazy/JIT aggregation and the tree reduction.
pub fn wsum_blocked_into(out: &mut [f32], updates: &[&[f32]], w: &[f32]) {
    assert_eq!(updates.len(), w.len());
    let d = out.len();
    for u in updates {
        assert_eq!(u.len(), d, "update length mismatch");
    }
    out.fill(0.0);
    let mut off = 0;
    while off < d {
        let end = (off + FOLD_BLOCK).min(d);
        let mut k = 0;
        // 4-row unroll: one load+FMA stream per row, one store stream —
        // 4× fewer passes over the accumulator block and enough ILP to
        // keep the FMA ports busy.
        while k + 4 <= updates.len() {
            let (u0, u1, u2, u3) = (
                &updates[k][off..end],
                &updates[k + 1][off..end],
                &updates[k + 2][off..end],
                &updates[k + 3][off..end],
            );
            let (w0, w1, w2, w3) = (w[k], w[k + 1], w[k + 2], w[k + 3]);
            let ob = &mut out[off..end];
            for i in 0..ob.len() {
                ob[i] += w0 * u0[i] + w1 * u1[i] + w2 * u2[i] + w3 * u3[i];
            }
            k += 4;
        }
        while k < updates.len() {
            let ub = &updates[k][off..end];
            let wk = w[k];
            let ob = &mut out[off..end];
            for (o, &x) in ob.iter_mut().zip(ub.iter()) {
                *o += wk * x;
            }
            k += 1;
        }
        off = end;
    }
}

/// Weighted mean over K updates (cache-blocked; K=2 dispatches to the
/// 3-stream pair merge, which measures faster than a fill+fold there).
pub fn weighted_mean(updates: &[&[f32]], w: &[f32]) -> Vec<f32> {
    let n = updates.first().map(|u| u.len()).unwrap_or(0);
    if updates.len() == 2 {
        let mut out = updates[0].to_vec();
        pair_merge_into(&mut out, w[0], updates[1], w[1]);
        return out;
    }
    let mut out = vec![0.0f32; n];
    wsum_blocked_into(&mut out, updates, w);
    let total: f32 = w.iter().sum();
    let inv = 1.0 / total;
    for o in &mut out {
        *o *= inv;
    }
    out
}

/// FedProx server merge: (1−μ)·weighted_mean + μ·global.
pub fn fedprox_merge(updates: &[&[f32]], w: &[f32], global: &[f32], mu: f32) -> Vec<f32> {
    let mut out = weighted_mean(updates, w);
    assert_eq!(out.len(), global.len());
    for (o, &g) in out.iter_mut().zip(global.iter()) {
        *o = (1.0 - mu) * *o + mu * g;
    }
    out
}

// ---------------------------------------------------------------------------
// streaming aggregator with checkpoint/restore
// ---------------------------------------------------------------------------

/// Partial aggregation state: a running weighted mean.
///
/// Folding updates one at a time (eager), in batches (batched), or all at
/// once (lazy/JIT) produces identical results — the algebra property the
/// strategies' "same aggregated model" integration test pins down.
#[derive(Clone, Debug)]
pub struct Aggregator {
    pub acc: Vec<f32>,
    pub weight: f32,
    pub n_merged: usize,
}

impl Aggregator {
    pub fn new(dim: usize) -> Aggregator {
        Aggregator {
            acc: vec![0.0; dim],
            weight: 0.0,
            n_merged: 0,
        }
    }

    /// Restore from a checkpoint (§5.5 preemption path).
    pub fn from_parts(acc: Vec<f32>, weight: f32, n_merged: usize) -> Aggregator {
        Aggregator {
            acc,
            weight,
            n_merged,
        }
    }

    /// Fold one update into the running mean.
    pub fn add(&mut self, update: &[f32], weight: f32) {
        if self.n_merged == 0 {
            self.acc.copy_from_slice(update);
            self.weight = weight;
        } else {
            pair_merge_into(&mut self.acc, self.weight, update, weight);
            self.weight += weight;
        }
        self.n_merged += 1;
    }

    /// Fold another partial aggregate in (tree reduction / checkpoint merge).
    pub fn merge(&mut self, other: &Aggregator) {
        if other.n_merged == 0 {
            return;
        }
        if self.n_merged == 0 {
            self.acc.copy_from_slice(&other.acc);
            self.weight = other.weight;
            self.n_merged = other.n_merged;
            return;
        }
        pair_merge_into(&mut self.acc, self.weight, &other.acc, other.weight);
        self.weight += other.weight;
        self.n_merged += other.n_merged;
    }

    /// Final global model for `alg` (FedProx needs the previous global).
    pub fn finalize(&self, alg: Algorithm, prev_global: Option<&[f32]>) -> Vec<f32> {
        match alg {
            Algorithm::FedAvg | Algorithm::FedSgd => self.acc.clone(),
            Algorithm::FedProx { mu } => {
                let g = prev_global.expect("FedProx finalize needs the previous global model");
                let mut out = self.acc.clone();
                for (o, &gv) in out.iter_mut().zip(g.iter()) {
                    *o = (1.0 - mu) * *o + mu * gv;
                }
                out
            }
        }
    }
}

/// Data-parallel aggregation: split `updates` across `shards` workers
/// (threads — stand-in for `N_agg` aggregator containers), each folds its
/// shard with the cache-blocked weighted sum, then partials merge pairwise
/// (§5.4's parallel aggregation). Returns a weighted-mean [`Aggregator`]
/// identical (within fp tolerance) to streaming the updates one by one.
pub fn tree_reduce(updates: &[ModelUpdate], shards: usize) -> Aggregator {
    assert!(!updates.is_empty());
    let dim = updates[0].data.len();
    let shards = shards.max(1).min(updates.len());
    let chunk = updates.len().div_ceil(shards);
    // (weighted sum, total weight, count) per shard
    let partials: Vec<(Vec<f32>, f32, usize)> = std::thread::scope(|s| {
        let handles: Vec<_> = updates
            .chunks(chunk)
            .map(|part| {
                s.spawn(move || {
                    let views: Vec<&[f32]> = part.iter().map(|u| u.data.as_slice()).collect();
                    let ws: Vec<f32> = part.iter().map(|u| u.weight).collect();
                    let mut sum = vec![0.0f32; dim];
                    wsum_blocked_into(&mut sum, &views, &ws);
                    (sum, ws.iter().sum::<f32>(), part.len())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    // combine partial sums, then normalize once
    let mut acc = vec![0.0f32; dim];
    let mut weight = 0.0f32;
    let mut n_merged = 0usize;
    for (sum, w, n) in &partials {
        for (a, &x) in acc.iter_mut().zip(sum.iter()) {
            *a += x;
        }
        weight += w;
        n_merged += n;
    }
    let inv = 1.0 / weight;
    for a in &mut acc {
        *a *= inv;
    }
    Aggregator {
        acc,
        weight,
        n_merged,
    }
}

// ---------------------------------------------------------------------------
// t_pair calibration (§5.4)
// ---------------------------------------------------------------------------

/// Measured pair-fusion cost for a model (seconds), averaged over `reps`.
/// "t_pair … can be easily computed offline … by randomly generating model
/// updates and measuring the time taken to fuse pairs."
pub fn calibrate_t_pair(spec: &ModelSpec, reps: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let a = ModelUpdate::random(spec, &mut rng, 1.0);
    let b = ModelUpdate::random(spec, &mut rng, 1.0);
    let mut acc = a.data.clone();
    // warm-up
    pair_merge_into(&mut acc, 1.0, &b.data, 1.0);
    let start = std::time::Instant::now();
    for i in 0..reps {
        pair_merge_into(&mut acc, 1.0 + i as f32, &b.data, 1.0);
    }
    start.elapsed().as_secs_f64() / reps.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn updates_from(g: &mut prop::Gen, k: usize, d: usize) -> Vec<ModelUpdate> {
        (0..k)
            .map(|_| ModelUpdate {
                data: g.vec_f32(d, 1.0),
                weight: g.f64(0.1, 10.0) as f32,
            })
            .collect()
    }

    fn reference_mean(us: &[ModelUpdate]) -> Vec<f32> {
        // f64 accumulation as the gold standard
        let d = us[0].data.len();
        let mut acc = vec![0.0f64; d];
        let mut tw = 0.0f64;
        for u in us {
            for (a, &x) in acc.iter_mut().zip(u.data.iter()) {
                *a += (u.weight as f64) * (x as f64);
            }
            tw += u.weight as f64;
        }
        acc.iter().map(|a| (*a / tw) as f32).collect()
    }

    #[test]
    fn pair_merge_is_weighted_mean() {
        let mut acc = vec![1.0, 2.0, 3.0];
        pair_merge_into(&mut acc, 3.0, &[5.0, 6.0, 7.0], 1.0);
        assert_eq!(acc, vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn wsum_matches_manual() {
        let u1 = [1.0f32, 0.0];
        let u2 = [0.0f32, 2.0];
        let mut out = vec![0.0; 2];
        wsum_into(&mut out, &[&u1, &u2], &[2.0, 3.0]);
        assert_eq!(out, vec![2.0, 6.0]);
    }

    #[test]
    fn streaming_equals_batch_property() {
        prop::check("streaming==batch", prop::default_cases(), |g| {
            let k = g.usize(1, 12);
            let d = g.usize(1, 512);
            let us = updates_from(g, k, d);
            let mut stream = Aggregator::new(d);
            for u in &us {
                stream.add(&u.data, u.weight);
            }
            let views: Vec<&[f32]> = us.iter().map(|u| u.data.as_slice()).collect();
            let ws: Vec<f32> = us.iter().map(|u| u.weight).collect();
            let batch = weighted_mean(&views, &ws);
            for (i, (a, b)) in stream.acc.iter().zip(batch.iter()).enumerate() {
                crate::prop_assert!(
                    prop::close(*a as f64, *b as f64, 1e-4),
                    "elem {i}: stream {a} vs batch {b}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn permutation_invariance_property() {
        prop::check("permutation-invariance", prop::default_cases(), |g| {
            let k = g.usize(2, 10);
            let d = g.usize(1, 256);
            let mut us = updates_from(g, k, d);
            let mut a1 = Aggregator::new(d);
            for u in &us {
                a1.add(&u.data, u.weight);
            }
            g.rng.shuffle(&mut us);
            let mut a2 = Aggregator::new(d);
            for u in &us {
                a2.add(&u.data, u.weight);
            }
            for (x, y) in a1.acc.iter().zip(a2.acc.iter()) {
                crate::prop_assert!(
                    prop::close(*x as f64, *y as f64, 1e-4),
                    "permutation changed result: {x} vs {y}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn tree_reduce_matches_sequential_property() {
        prop::check("tree==sequential", 24, |g| {
            let k = g.usize(1, 24);
            let d = g.usize(1, 300);
            let us = updates_from(g, k, d);
            let tree = tree_reduce(&us, g.usize(1, 6));
            let gold = reference_mean(&us);
            for (x, y) in tree.acc.iter().zip(gold.iter()) {
                crate::prop_assert!(
                    prop::close(*x as f64, *y as f64, 1e-3),
                    "tree {x} vs gold {y}"
                );
            }
            crate::prop_assert!(tree.n_merged == k, "n_merged {} != {k}", tree.n_merged);
            Ok(())
        });
    }

    #[test]
    fn checkpoint_restore_equivalence() {
        // fold 5 updates, checkpoint after 2, restore, fold the rest ==
        // folding straight through (the §5.5 preemption invariant).
        let mut g = prop::Gen::new(0xCAFE, 50);
        let us = updates_from(&mut g, 5, 128);
        let mut straight = Aggregator::new(128);
        for u in &us {
            straight.add(&u.data, u.weight);
        }
        let mut first = Aggregator::new(128);
        first.add(&us[0].data, us[0].weight);
        first.add(&us[1].data, us[1].weight);
        let ckpt = (first.acc.clone(), first.weight, first.n_merged);
        let mut resumed = Aggregator::from_parts(ckpt.0, ckpt.1, ckpt.2);
        for u in &us[2..] {
            resumed.add(&u.data, u.weight);
        }
        for (a, b) in straight.acc.iter().zip(resumed.acc.iter()) {
            assert!((a - b).abs() < 1e-5);
        }
        assert_eq!(straight.n_merged, resumed.n_merged);
    }

    #[test]
    fn fedprox_finalize_pulls_toward_global() {
        let mut agg = Aggregator::new(2);
        agg.add(&[2.0, 2.0], 1.0);
        let global = [0.0f32, 4.0];
        let out = agg.finalize(Algorithm::FedProx { mu: 0.5 }, Some(&global));
        assert_eq!(out, vec![1.0, 3.0]);
        let avg = agg.finalize(Algorithm::FedAvg, None);
        assert_eq!(avg, vec![2.0, 2.0]);
    }

    #[test]
    fn fedprox_merge_fn_matches_finalize() {
        let mut g = prop::Gen::new(7, 50);
        let us = updates_from(&mut g, 4, 64);
        let global = g.vec_f32(64, 1.0);
        let views: Vec<&[f32]> = us.iter().map(|u| u.data.as_slice()).collect();
        let ws: Vec<f32> = us.iter().map(|u| u.weight).collect();
        let direct = fedprox_merge(&views, &ws, &global, 0.3);
        let mut agg = Aggregator::new(64);
        for u in &us {
            agg.add(&u.data, u.weight);
        }
        let via_agg = agg.finalize(Algorithm::FedProx { mu: 0.3 }, Some(&global));
        for (a, b) in direct.iter().zip(via_agg.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn algorithm_parse_roundtrip() {
        for n in ["fedavg", "fedsgd", "fedprox"] {
            assert_eq!(Algorithm::parse(n).unwrap().name(), n);
        }
        assert!(Algorithm::parse("magic").is_none());
    }

    #[test]
    fn calibration_returns_positive_time() {
        let spec = ModelSpec::new("cal", vec![("l", 1 << 16)]);
        let t = calibrate_t_pair(&spec, 3, 42);
        assert!(t > 0.0 && t < 1.0, "t_pair={t}");
    }
}
