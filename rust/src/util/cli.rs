//! Tiny argument parser (no `clap` offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! arguments and subcommands. Used by the `fljit` binary, the examples and
//! the bench harnesses.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from process args (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else {
                    // `--key value` unless the next token is another flag.
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let v = it.next().unwrap();
                            out.flags.insert(stripped.to_string(), v);
                        }
                        _ => {
                            out.flags.insert(stripped.to_string(), "true".to_string());
                        }
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        let a = parse("simulate fig9 --parties 100 --strategy=jit --verbose");
        assert_eq!(a.subcommand(), Some("simulate"));
        assert_eq!(a.get_u64("parties", 0), 100);
        assert_eq!(a.get("strategy"), Some("jit"));
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional, vec!["simulate", "fig9"]);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_u64("rounds", 50), 50);
        assert_eq!(a.get_or("workload", "cifar100"), "cifar100");
        assert!(!a.get_bool("verbose"));
        assert_eq!(a.get_f64("twait", 600.0), 600.0);
    }

    #[test]
    fn flag_value_vs_boolean() {
        let a = parse("--a --b 5 --c=x --d");
        assert!(a.get_bool("a"));
        assert_eq!(a.get_u64("b", 0), 5);
        assert_eq!(a.get("c"), Some("x"));
        assert!(a.get_bool("d"));
    }
}
