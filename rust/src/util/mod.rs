//! Utility substrates the offline image forces us to carry in-tree:
//! PRNG, JSON, statistics/OLS, CLI parsing, logging, table rendering and a
//! mini property-testing harness. See DESIGN.md §Substrates.

pub mod cli;
pub mod json;
pub mod logging;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
