//! Leveled logger substrate (no `log`/`env_logger` crates offline).
//!
//! Level is process-global, settable via code or the `FLJIT_LOG`
//! environment variable (`error|warn|info|debug|trace`). The macros are
//! zero-cost when the level is filtered out apart from one atomic load.

use std::sync::atomic::{AtomicU8, Ordering};

pub const ERROR: u8 = 0;
pub const WARN: u8 = 1;
pub const INFO: u8 = 2;
pub const DEBUG: u8 = 3;
pub const TRACE: u8 = 4;

static LEVEL: AtomicU8 = AtomicU8::new(INFO);
static INIT: std::sync::Once = std::sync::Once::new();

pub fn init_from_env() {
    INIT.call_once(|| {
        if let Ok(v) = std::env::var("FLJIT_LOG") {
            set_level_str(&v);
        }
    });
}

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

pub fn set_level_str(s: &str) {
    let lvl = match s.to_ascii_lowercase().as_str() {
        "error" => ERROR,
        "warn" => WARN,
        "info" => INFO,
        "debug" => DEBUG,
        "trace" => TRACE,
        _ => INFO,
    };
    set_level(lvl);
}

#[inline]
pub fn enabled(level: u8) -> bool {
    level <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: u8, module: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    let tag = match level {
        ERROR => "ERROR",
        WARN => "WARN ",
        INFO => "INFO ",
        DEBUG => "DEBUG",
        _ => "TRACE",
    };
    eprintln!("[{tag}] {module}: {args}");
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::ERROR, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::WARN, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::INFO, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::DEBUG, module_path!(), format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::TRACE, module_path!(), format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_filtering() {
        set_level(WARN);
        assert!(enabled(ERROR));
        assert!(enabled(WARN));
        assert!(!enabled(INFO));
        set_level(INFO);
        assert!(enabled(INFO));
        assert!(!enabled(DEBUG));
    }

    #[test]
    fn level_parse() {
        set_level_str("trace");
        assert!(enabled(TRACE));
        set_level_str("bogus");
        assert!(enabled(INFO) && !enabled(DEBUG));
    }
}
