//! Aligned ASCII table printer for the bench harnesses — every figure/table
//! regeneration prints its rows through this, mirroring the paper's layout.

#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            width[i] = width[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = width[i] - c.chars().count();
                line.push(' ');
                line.push_str(c);
                line.push_str(&" ".repeat(pad + 1));
                line.push('|');
            }
            line
        };
        let sep = {
            let mut s = String::from("+");
            for w in &width {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers, &width));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds compactly ("1.24s", "843ms").
pub fn fmt_secs(s: f64) -> String {
    if s >= 100.0 {
        format!("{:.0}s", s)
    } else if s >= 1.0 {
        format!("{:.2}s", s)
    } else if s >= 1e-3 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Format a ratio as "12.3%".
pub fn fmt_pct(x: f64) -> String {
    format!("{:.2}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "bbbb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let r = t.render();
        assert!(r.contains("== demo =="));
        let lines: Vec<&str> = r.lines().collect();
        // all body lines equal width
        let w = lines[1].len();
        for l in &lines[1..] {
            assert_eq!(l.len(), w, "line {l:?}");
        }
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_secs(123.0), "123s");
        assert_eq!(fmt_secs(1.5), "1.50s");
        assert_eq!(fmt_secs(0.0125), "12.5ms");
        assert_eq!(fmt_secs(2e-5), "20.0us");
        assert_eq!(fmt_pct(0.1234), "12.34%");
    }
}
