//! Minimal JSON parser + writer.
//!
//! Substrate forced by the offline image (no `serde`). Used for the AOT
//! artifact manifest, FL job specs, experiment configs and result dumps.
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP (sufficient for every file this repo produces or consumes).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ------------------------------------------------------------------
    // accessors
    // ------------------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        const NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ------------------------------------------------------------------
    // builders
    // ------------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    // ------------------------------------------------------------------
    // parse
    // ------------------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ------------------------------------------------------------------
    // print
    // ------------------------------------------------------------------

    pub fn print(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (idx, v) in a.iter().enumerate() {
                    if idx > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (idx, (k, v)) in o.iter().enumerate() {
                    if idx > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.i,
            msg: msg.to_string(),
        }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{}'", s)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 code point
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    if start + len > self.b.len() {
                        return Err(self.err("bad utf-8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("x"));
        assert_eq!(v.get("c"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"s":"he\"llo\n","t":true},"z":null}"#;
        let v = Json::parse(src).unwrap();
        let printed = v.print();
        let v2 = Json::parse(&printed).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn pretty_roundtrip() {
        let v = Json::obj(vec![
            ("x", Json::num(1.0)),
            ("y", Json::arr(vec![Json::str("a"), Json::Bool(false)])),
        ]);
        let v2 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ✓""#).unwrap();
        assert_eq!(v.as_str(), Some("café ✓"));
        let out = Json::Str("tab\there".into()).print();
        assert_eq!(out, r#""tab\there""#);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01a").is_err());
        assert!(Json::parse(r#"{"a":1} trailing"#).is_err());
    }

    #[test]
    fn large_int_precision() {
        let v = Json::parse("1048576").unwrap();
        assert_eq!(v.as_u64(), Some(1048576));
        assert_eq!(v.print(), "1048576");
    }
}
