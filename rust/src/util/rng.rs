//! Deterministic PRNG + distributions.
//!
//! The offline image ships no `rand` crate, so the platform carries its own
//! generator: SplitMix64 for seeding and Xoshiro256++ for the stream (the
//! same construction `rand`'s `SmallRng` family uses). Everything in the
//! repo that needs randomness — party heterogeneity draws, intermittent
//! update times, non-IID Dirichlet partitions, synthetic datasets, random
//! model updates for `t_pair` calibration (§5.4) — goes through this module
//! so every experiment is reproducible from a single `--seed`.

/// SplitMix64: seeds the main generator and is itself a fine 64-bit mixer.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from the Box-Muller pair.
    cached_normal: Option<f64>,
}

impl Rng {
    /// Seed the full 256-bit state from a single u64 via SplitMix64
    /// (the construction recommended by the xoshiro authors).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            cached_normal: None,
        }
    }

    /// Derive an independent child stream (used to give every party its own
    /// deterministic generator regardless of iteration order).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Lemire's method without bias for our use
    /// (n ≪ 2^64, modulo bias is < 2^-40 — accepted and documented).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        self.next_u64() % n
    }

    /// Uniform integer in [lo, hi) .
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick an element uniformly.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.cached_normal.take() {
            return z;
        }
        let (u1, u2) = (self.f64().max(1e-300), self.f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.cached_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate lambda.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Log-normal: exp(N(mu, sigma)).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Gamma(shape k, scale 1) via Marsaglia-Tsang (k >= 0.01).
    pub fn gamma(&mut self, k: f64) -> f64 {
        if k < 1.0 {
            // boost: Gamma(k) = Gamma(k+1) * U^(1/k)
            let g = self.gamma(k + 1.0);
            return g * self.f64().max(1e-300).powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Symmetric Dirichlet(alpha) over n categories — the standard way to
    /// synthesize non-IID federated label distributions (§6.3 "datasets
    /// were partitioned in a realistic non-IID manner").
    pub fn dirichlet(&mut self, alpha: f64, n: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..n).map(|_| self.gamma(alpha)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / n as f64; n];
        }
        for x in &mut g {
            *x /= s;
        }
        g
    }

    /// Zipf-like rank weights (used for heavy-tailed dataset-size draws).
    pub fn zipf_weights(&mut self, n: usize, s: f64) -> Vec<f64> {
        let mut w: Vec<f64> = (1..=n).map(|r| 1.0 / (r as f64).powf(s)).collect();
        let total: f64 = w.iter().sum();
        for x in &mut w {
            *x /= total;
        }
        w
    }

    /// Fill a slice with standard-normal f32s (random model updates for
    /// `t_pair` calibration, §5.4).
    pub fn fill_normal_f32(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal() as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn dirichlet_sums_to_one_and_alpha_controls_skew() {
        let mut r = Rng::new(13);
        let p = r.dirichlet(0.1, 10);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // low alpha -> skewed: max component should dominate
        let skewed_max = p.iter().cloned().fold(0.0, f64::max);
        let q = r.dirichlet(100.0, 10);
        let flat_max = q.iter().cloned().fold(0.0, f64::max);
        assert!(skewed_max > flat_max);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(17);
        let n = 50_000;
        let mean = (0..n).map(|_| r.gamma(3.0)).sum::<f64>() / n as f64;
        assert!((mean - 3.0).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(99);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
