//! Statistics + ordinary least squares.
//!
//! OLS is not just a test helper here: it is the paper's *estimator* —
//! §4.2 uses linear regression to predict epoch times from dataset size
//! (and minibatch times from batch size / hardware), and §5.3 falls back to
//! regression when parties do not report timings directly.

/// Summary statistics over a sample.
#[derive(Clone, Copy, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary::default();
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }

    /// Coefficient of variation — the paper's periodicity claim (Fig 3) is
    /// "epoch times are fairly constant", i.e. CV is small.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.std / self.mean
        }
    }
}

/// Percentile with linear interpolation (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Ordinary least squares fit y = intercept + slope * x.
#[derive(Clone, Copy, Debug)]
pub struct LinearFit {
    pub slope: f64,
    pub intercept: f64,
    /// Coefficient of determination.
    pub r2: f64,
    pub n: usize,
}

impl LinearFit {
    pub fn fit(xs: &[f64], ys: &[f64]) -> Option<LinearFit> {
        let n = xs.len();
        if n < 2 || n != ys.len() {
            return None;
        }
        let nf = n as f64;
        let mx = xs.iter().sum::<f64>() / nf;
        let my = ys.iter().sum::<f64>() / nf;
        let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
        let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
        if sxx == 0.0 {
            return None;
        }
        let slope = sxy / sxx;
        let intercept = my - slope * mx;
        let ss_tot: f64 = ys.iter().map(|y| (y - my) * (y - my)).sum();
        let ss_res: f64 = xs
            .iter()
            .zip(ys)
            .map(|(x, y)| {
                let e = y - (intercept + slope * x);
                e * e
            })
            .sum();
        let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
        Some(LinearFit {
            slope,
            intercept,
            r2,
            n,
        })
    }

    pub fn predict(&self, x: f64) -> f64 {
        self.intercept + self.slope * x
    }
}

/// Incremental (online) OLS — the estimator keeps one of these per party
/// and feeds it (dataset_size, epoch_time) observations as rounds complete.
#[derive(Clone, Debug, Default)]
pub struct OnlineOls {
    n: f64,
    sx: f64,
    sy: f64,
    sxx: f64,
    sxy: f64,
}

impl OnlineOls {
    pub fn add(&mut self, x: f64, y: f64) {
        self.n += 1.0;
        self.sx += x;
        self.sy += y;
        self.sxx += x * x;
        self.sxy += x * y;
    }

    pub fn len(&self) -> usize {
        self.n as usize
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0.0
    }

    pub fn fit(&self) -> Option<(f64, f64)> {
        if self.n < 2.0 {
            return None;
        }
        let det = self.n * self.sxx - self.sx * self.sx;
        if det.abs() < 1e-12 {
            return None;
        }
        let slope = (self.n * self.sxy - self.sx * self.sy) / det;
        let intercept = (self.sy - slope * self.sx) / self.n;
        Some((slope, intercept))
    }

    pub fn predict(&self, x: f64) -> Option<f64> {
        self.fit().map(|(m, b)| b + m * x)
    }
}

/// Exponentially weighted moving average — bandwidth tracking (§5.2's
/// periodic B_u/B_d measurements).
#[derive(Clone, Copy, Debug)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        Self { alpha, value: None }
    }

    pub fn observe(&mut self, x: f64) {
        self.value = Some(match self.value {
            None => x,
            Some(v) => self.alpha * x + (1.0 - self.alpha) * v,
        });
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.min - 1.0).abs() < 1e-12);
        assert!((s.max - 4.0).abs() < 1e-12);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 3.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 5.0).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn exact_linear_fit() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [3.0, 5.0, 7.0, 9.0]; // y = 1 + 2x
        let f = LinearFit::fit(&xs, &ys).unwrap();
        assert!((f.slope - 2.0).abs() < 1e-12);
        assert!((f.intercept - 1.0).abs() < 1e-12);
        assert!((f.r2 - 1.0).abs() < 1e-12);
        assert!((f.predict(10.0) - 21.0).abs() < 1e-12);
    }

    #[test]
    fn noisy_fit_r2_below_one() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| 2.0 * x + 1.0 + if (*x as u64) % 2 == 0 { 0.5 } else { -0.5 })
            .collect();
        let f = LinearFit::fit(&xs, &ys).unwrap();
        assert!((f.slope - 2.0).abs() < 0.01);
        assert!(f.r2 > 0.99 && f.r2 < 1.0);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [1.0, 3.0, 5.0, 7.0, 11.0];
        let ys = [2.1, 6.2, 9.8, 14.1, 22.3];
        let batch = LinearFit::fit(&xs, &ys).unwrap();
        let mut online = OnlineOls::default();
        for (x, y) in xs.iter().zip(ys.iter()) {
            online.add(*x, *y);
        }
        let (slope, intercept) = online.fit().unwrap();
        assert!((slope - batch.slope).abs() < 1e-9);
        assert!((intercept - batch.intercept).abs() < 1e-9);
    }

    #[test]
    fn degenerate_fits_rejected() {
        assert!(LinearFit::fit(&[1.0], &[2.0]).is_none());
        assert!(LinearFit::fit(&[2.0, 2.0, 2.0], &[1.0, 2.0, 3.0]).is_none());
        let mut o = OnlineOls::default();
        o.add(1.0, 1.0);
        assert!(o.fit().is_none());
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.5);
        assert!(e.get().is_none());
        for _ in 0..20 {
            e.observe(10.0);
        }
        assert!((e.get().unwrap() - 10.0).abs() < 1e-3);
    }
}
