//! Mini property-based testing harness (no `proptest` offline).
//!
//! A property is a closure over a `Gen` (seeded RNG wrapper with sizing
//! helpers). `check` runs it across many seeds; on failure it reports the
//! failing seed so the case can be replayed deterministically, and retries
//! the property at smaller `size`s (a cheap form of shrinking: most
//! generators draw magnitudes from `g.size`, so re-running the same seed at
//! smaller sizes usually yields a smaller counterexample).
//!
//! Coordinator invariants (routing/batching/state), fusion algebra, MQ and
//! cluster-ledger conservation are all property-tested through this.

use crate::util::rng::Rng;

/// Number of cases per property; override with FLJIT_PROP_CASES.
pub fn default_cases() -> u64 {
    std::env::var("FLJIT_PROP_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

pub struct Gen {
    pub rng: Rng,
    /// Sizing knob in [1, 100]: generators should scale structure sizes by it.
    pub size: u64,
}

impl Gen {
    pub fn new(seed: u64, size: u64) -> Gen {
        Gen {
            rng: Rng::new(seed),
            size,
        }
    }

    /// Integer in [lo, hi] scaled so the span grows with `size`.
    pub fn int(&mut self, lo: u64, hi: u64) -> u64 {
        let hi_eff = lo + ((hi - lo) * self.size) / 100;
        self.rng.range_u64(lo, hi_eff.max(lo) + 1)
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as u64, hi as u64) as usize
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    pub fn vec_f32(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| (self.rng.normal() as f32) * scale).collect()
    }

    /// Positive weights (party dataset sizes etc.).
    pub fn weights(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.range_f64(0.1, 10.0) as f32).collect()
    }
}

/// Run `prop` for `cases` seeds. Panics with the failing seed on error.
pub fn check<F: FnMut(&mut Gen) -> Result<(), String>>(name: &str, cases: u64, mut prop: F) {
    let base = 0xF17A_5EED_u64;
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9));
        let size = 1 + (case * 100) / cases.max(1); // ramp sizes up over the run
        let mut g = Gen::new(seed, size);
        if let Err(msg) = prop(&mut g) {
            // try smaller sizes with the same seed for a more minimal report
            let mut min_fail = (size, msg.clone());
            for s in [1u64, 2, 5, 10, 25, 50] {
                if s >= size {
                    break;
                }
                let mut g2 = Gen::new(seed, s);
                if let Err(m2) = prop(&mut g2) {
                    min_fail = (s, m2);
                    break;
                }
            }
            panic!(
                "property '{name}' failed (seed={seed:#x}, size={}, case {case}/{cases}): {}",
                min_fail.0, min_fail.1
            );
        }
    }
}

/// Assert helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

/// Approximate float equality for property bodies.
pub fn close(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0u64;
        check("trivial", 32, |g| {
            let _ = g.int(0, 10);
            count += 1;
            Ok(())
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        check("always-fails", 8, |_g| Err("nope".to_string()));
    }

    #[test]
    fn close_tolerance() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6));
        assert!(!close(1.0, 1.1, 1e-6));
    }

    #[test]
    fn gen_sizes_scale() {
        let mut small = Gen::new(1, 1);
        let mut big = Gen::new(1, 100);
        // with size=1, int(0, 1000) stays at ~<=10
        let a = (0..50).map(|_| small.int(0, 1000)).max().unwrap();
        let b = (0..50).map(|_| big.int(0, 1000)).max().unwrap();
        assert!(a <= 10);
        assert!(b > 100);
    }
}
