//! Discrete-event simulation engine.
//!
//! The whole platform (coordinator strategies, cluster, message queue,
//! parties) is written against virtual `Time` and an event queue, so the
//! *same* scheduling code runs in two modes:
//!
//! * **simulated** — the virtual driver pops events immediately and the
//!   clock jumps: the Fig 7/8/9 grids (up to 10 000 parties × 50 rounds ×
//!   4 strategies) execute in milliseconds of wall time;
//! * **live** — the wall-clock driver sleeps to each event's deadline and
//!   wakes on MQ publishes, so the identical queue contents play out in
//!   real time (see `coordinator::driver` for the Driver/Clock pair and
//!   `coordinator::live` for the deployment).
//!
//! Time is `u64` microseconds. Events carry an opaque `EventKind` that the
//! world dispatcher (coordinator::platform) interprets; the engine itself
//! is domain-agnostic, ordered by (time, seq) for determinism.
//!
//! Two interchangeable priority-queue backends share that contract:
//!
//! * [`QueueKind::Heap`] — one global `BinaryHeap`, the reference
//!   implementation.
//! * [`QueueKind::Bucket`] — the default: a two-level calendar queue: a wheel of
//!   δ-tick-sized buckets (each a small heap) plus a `BTreeMap` overflow
//!   for far-future events. Inserts and pops touch one small bucket
//!   instead of a multi-megabyte heap, which is what the cancel/peek-heavy
//!   scheduler profile wants; `scheduler_hot_path` measures both.
//!
//! Cancellation uses lazy deletion: [`EventQueue::cancel`] tombstones the
//! event id and [`EventQueue::next`]/[`EventQueue::peek_time`] skip
//! tombstones on the way out, so cancel is O(1) regardless of backend.

use std::cmp::Ordering;
use std::collections::{BTreeMap, BinaryHeap, HashSet};

/// Virtual time in microseconds.
pub type Time = u64;

/// Identifier of a scheduled event, for [`EventQueue::cancel`]. Ids are
/// never reused within one queue.
pub type EventId = u64;

pub const MICROS: f64 = 1_000_000.0;

/// Convert seconds (f64) to Time.
pub fn secs(s: f64) -> Time {
    debug_assert!(s >= 0.0, "negative duration {s}");
    (s * MICROS).round() as Time
}

/// Convert Time to seconds.
pub fn to_secs(t: Time) -> f64 {
    t as f64 / MICROS
}

/// Domain events dispatched by the platform. The engine never inspects
/// payloads beyond ordering.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A party's model update reaches the message queue. (job, round, party)
    UpdateArrival { job: usize, round: u32, party: usize },
    /// Cluster scheduling tick (every delta seconds, §5.5).
    SchedTick,
    /// JIT deadline timer for a job's aggregation task (Fig 6 TIMER_ALERT).
    TimerAlert { job: usize, round: u32 },
    /// A container finishes its current work item.
    ContainerDone { container: usize },
    /// Start of a round for a job (aggregator sent the global model).
    RoundStart { job: usize, round: u32 },
    /// t_wait expired for a round of an intermittent job.
    RoundTimeout { job: usize, round: u32 },
    /// A job submission reaches the broker (multi-tenant admission).
    JobArrival { job: usize },
    /// Generic user event for tests/extensions.
    Custom { tag: u64 },
}

#[derive(Clone, Debug)]
struct ScheduledEvent {
    time: Time,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for ScheduledEvent {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for ScheduledEvent {}
impl PartialOrd for ScheduledEvent {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for ScheduledEvent {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

// ---------------------------------------------------------------------------
// bucket (calendar) backend
// ---------------------------------------------------------------------------

/// log2 of the bucket width in µs: 2^19 µs ≈ 0.52 s ≈ the δ scheduling
/// tick, so a typical tick's churn lands in one or two buckets.
const BUCKET_WIDTH_LOG2: u32 = 19;
/// Wheel size (power of two): 256 buckets ≈ a 134 s near-future window.
const WHEEL_SIZE: u64 = 256;

/// Two-level bucket queue: a wheel of small per-bucket heaps over the near
/// future plus a `BTreeMap` overflow for everything beyond the window.
///
/// Invariant: every pending event lives in absolute bucket ≥ `base`; an
/// insert whose natural bucket has already been passed is clamped into
/// `base` (its heap still orders it correctly by (time, seq), and every
/// event in bucket `base` sorts before everything in later buckets).
#[derive(Debug)]
struct BucketQueue {
    wheel: Vec<BinaryHeap<ScheduledEvent>>,
    /// Absolute bucket index the wheel cursor is parked on.
    base: u64,
    /// Events in absolute buckets ≥ base + WHEEL_SIZE.
    overflow: BTreeMap<u64, Vec<ScheduledEvent>>,
    len: usize,
    wheel_len: usize,
}

impl BucketQueue {
    fn new() -> BucketQueue {
        BucketQueue {
            wheel: (0..WHEEL_SIZE).map(|_| BinaryHeap::new()).collect(),
            base: 0,
            overflow: BTreeMap::new(),
            len: 0,
            wheel_len: 0,
        }
    }

    fn push(&mut self, ev: ScheduledEvent) {
        let natural = ev.time >> BUCKET_WIDTH_LOG2;
        let ab = natural.max(self.base);
        self.len += 1;
        if ab < self.base + WHEEL_SIZE {
            self.wheel[(ab % WHEEL_SIZE) as usize].push(ev);
            self.wheel_len += 1;
        } else {
            self.overflow.entry(ab).or_default().push(ev);
        }
    }

    /// Move the cursor to the next populated bucket and pull any overflow
    /// buckets that entered the window.
    fn advance(&mut self) {
        if self.wheel_len == 0 {
            // Fast-forward across an empty wheel straight to the overflow.
            let (&k, _) = self
                .overflow
                .iter()
                .next()
                .expect("advance on an empty queue");
            self.base = k;
        } else {
            self.base += 1;
        }
        let horizon = self.base + WHEEL_SIZE;
        loop {
            let Some((&k, _)) = self.overflow.iter().next() else {
                break;
            };
            if k >= horizon {
                break;
            }
            let evs = self.overflow.remove(&k).unwrap();
            let slot = (k % WHEEL_SIZE) as usize;
            self.wheel_len += evs.len();
            for e in evs {
                self.wheel[slot].push(e);
            }
        }
    }

    fn pop(&mut self) -> Option<ScheduledEvent> {
        if self.len == 0 {
            return None;
        }
        loop {
            let slot = (self.base % WHEEL_SIZE) as usize;
            if let Some(ev) = self.wheel[slot].pop() {
                self.len -= 1;
                self.wheel_len -= 1;
                return Some(ev);
            }
            self.advance();
        }
    }

    fn peek(&mut self) -> Option<&ScheduledEvent> {
        if self.len == 0 {
            return None;
        }
        loop {
            let slot = (self.base % WHEEL_SIZE) as usize;
            if !self.wheel[slot].is_empty() {
                break;
            }
            self.advance();
        }
        self.wheel[(self.base % WHEEL_SIZE) as usize].peek()
    }
}

// ---------------------------------------------------------------------------
// the engine
// ---------------------------------------------------------------------------

/// Which priority-queue backend an [`EventQueue`] runs on.
///
/// `Bucket` is the default per the decision rule in EXPERIMENTS.md: the
/// heap ≡ bucket ordering-equivalence property stays pinned at tier-1,
/// and the bucket backend is the one built for the cancel/peek-heavy
/// scheduler profile. `Heap` remains the reference implementation.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum QueueKind {
    /// Single global binary heap (reference implementation).
    Heap,
    /// Two-level bucket/calendar queue (cancel/peek-heavy profile,
    /// default).
    #[default]
    Bucket,
}

#[derive(Debug)]
enum Backend {
    Heap(BinaryHeap<ScheduledEvent>),
    Bucket(BucketQueue),
}

/// Deterministic event queue with a virtual clock. Both backends pop in
/// identical (time, insertion-seq) order — pinned by property test.
#[derive(Debug)]
pub struct EventQueue {
    backend: Backend,
    now: Time,
    seq: u64,
    processed: u64,
    /// Scheduled minus popped minus canceled.
    live: usize,
    /// Lazily deleted event ids, skipped on the way out of the queue.
    canceled: HashSet<EventId>,
    /// One bit per id ever issued: set while the event is pending (not yet
    /// popped or canceled). Makes `cancel` of a fired/duplicate/unknown id
    /// an exact no-op instead of a counter-corrupting guess.
    pending_bits: Vec<u64>,
}

impl Default for EventQueue {
    fn default() -> Self {
        EventQueue::with_kind(QueueKind::default())
    }
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_kind(kind: QueueKind) -> Self {
        EventQueue {
            backend: match kind {
                QueueKind::Heap => Backend::Heap(BinaryHeap::new()),
                QueueKind::Bucket => Backend::Bucket(BucketQueue::new()),
            },
            now: 0,
            seq: 0,
            processed: 0,
            live: 0,
            canceled: HashSet::new(),
            pending_bits: Vec::new(),
        }
    }

    #[inline]
    fn set_pending(&mut self, id: EventId) {
        let (word, bit) = ((id >> 6) as usize, id & 63);
        if word >= self.pending_bits.len() {
            self.pending_bits.resize(word + 1, 0);
        }
        self.pending_bits[word] |= 1 << bit;
    }

    #[inline]
    fn clear_pending(&mut self, id: EventId) {
        let (word, bit) = ((id >> 6) as usize, id & 63);
        if let Some(w) = self.pending_bits.get_mut(word) {
            *w &= !(1 << bit);
        }
    }

    #[inline]
    fn is_pending(&self, id: EventId) -> bool {
        let (word, bit) = ((id >> 6) as usize, id & 63);
        self.pending_bits
            .get(word)
            .is_some_and(|w| w & (1 << bit) != 0)
    }

    pub fn kind(&self) -> QueueKind {
        match self.backend {
            Backend::Heap(_) => QueueKind::Heap,
            Backend::Bucket(_) => QueueKind::Bucket,
        }
    }

    pub fn now(&self) -> Time {
        self.now
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Schedule `kind` at absolute time `at` (clamped to now — scheduling in
    /// the past executes "immediately", preserving causality). Returns the
    /// event's id, usable with [`cancel`](EventQueue::cancel).
    pub fn schedule_at(&mut self, at: Time, kind: EventKind) -> EventId {
        let t = at.max(self.now);
        self.seq += 1;
        let ev = ScheduledEvent {
            time: t,
            seq: self.seq,
            kind,
        };
        match &mut self.backend {
            Backend::Heap(h) => h.push(ev),
            Backend::Bucket(b) => b.push(ev),
        }
        self.live += 1;
        self.set_pending(self.seq);
        self.seq
    }

    /// Schedule `kind` after a relative delay.
    pub fn schedule_in(&mut self, delay: Time, kind: EventKind) -> EventId {
        self.schedule_at(self.now.saturating_add(delay), kind)
    }

    /// Lazily cancel a scheduled event: O(1), the entry is skipped when it
    /// reaches the head of the queue. Canceling an id that already fired,
    /// was already canceled, or was never issued is an exact no-op that
    /// returns false. Returns whether the event was live and is now dead.
    pub fn cancel(&mut self, id: EventId) -> bool {
        if !self.is_pending(id) {
            return false;
        }
        self.clear_pending(id);
        self.canceled.insert(id);
        self.live -= 1;
        true
    }

    /// Pop the next live event, advancing the clock.
    pub fn next(&mut self) -> Option<(Time, EventKind)> {
        loop {
            let ev = match &mut self.backend {
                Backend::Heap(h) => h.pop(),
                Backend::Bucket(b) => b.pop(),
            }?;
            if !self.canceled.is_empty() && self.canceled.remove(&ev.seq) {
                continue; // tombstoned by cancel()
            }
            debug_assert!(ev.time >= self.now, "time went backwards");
            self.clear_pending(ev.seq);
            self.now = ev.time;
            self.processed += 1;
            self.live -= 1;
            return Some((ev.time, ev.kind));
        }
    }

    /// Time of the next live event (purges tombstoned heads on the way).
    pub fn peek_time(&mut self) -> Option<Time> {
        loop {
            let head = match &mut self.backend {
                Backend::Heap(h) => h.peek().map(|e| (e.time, e.seq)),
                Backend::Bucket(b) => b.peek().map(|e| (e.time, e.seq)),
            };
            let (t, seq) = head?;
            if !self.canceled.is_empty() && self.canceled.remove(&seq) {
                let _ = match &mut self.backend {
                    Backend::Heap(h) => h.pop(),
                    Backend::Bucket(b) => b.pop(),
                };
                continue;
            }
            return Some(t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(secs(3.0), EventKind::Custom { tag: 3 });
        q.schedule_at(secs(1.0), EventKind::Custom { tag: 1 });
        q.schedule_at(secs(2.0), EventKind::Custom { tag: 2 });
        let mut tags = Vec::new();
        while let Some((_, EventKind::Custom { tag })) = q.next() {
            tags.push(tag);
        }
        assert_eq!(tags, vec![1, 2, 3]);
        assert_eq!(q.now(), secs(3.0));
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        for kind in [QueueKind::Heap, QueueKind::Bucket] {
            let mut q = EventQueue::with_kind(kind);
            for tag in 0..10 {
                q.schedule_at(secs(1.0), EventKind::Custom { tag });
            }
            let mut tags = Vec::new();
            while let Some((_, EventKind::Custom { tag })) = q.next() {
                tags.push(tag);
            }
            assert_eq!(tags, (0..10).collect::<Vec<_>>(), "{kind:?}");
        }
    }

    #[test]
    fn past_events_clamped_to_now() {
        for kind in [QueueKind::Heap, QueueKind::Bucket] {
            let mut q = EventQueue::with_kind(kind);
            q.schedule_at(secs(5.0), EventKind::Custom { tag: 1 });
            q.next();
            q.schedule_at(secs(1.0), EventKind::Custom { tag: 2 }); // in the past
            let (t, _) = q.next().unwrap();
            assert_eq!(t, secs(5.0), "{kind:?}");
        }
    }

    #[test]
    fn relative_scheduling() {
        let mut q = EventQueue::new();
        q.schedule_in(secs(2.0), EventKind::Custom { tag: 1 });
        let (t, _) = q.next().unwrap();
        assert_eq!(t, secs(2.0));
        q.schedule_in(secs(0.5), EventKind::Custom { tag: 2 });
        let (t2, _) = q.next().unwrap();
        assert_eq!(t2, secs(2.5));
    }

    #[test]
    fn unit_conversions() {
        assert_eq!(secs(1.5), 1_500_000);
        assert!((to_secs(2_250_000) - 2.25).abs() < 1e-12);
    }

    #[test]
    fn throughput_smoke() {
        // engine must sustain ~1M events/s (DESIGN.md §Perf L3); here we
        // just sanity-check that 100k schedule+pop round trips complete.
        for kind in [QueueKind::Heap, QueueKind::Bucket] {
            let mut q = EventQueue::with_kind(kind);
            for i in 0..100_000u64 {
                q.schedule_at(i * 3 % 1_000_000, EventKind::Custom { tag: i });
            }
            let mut n = 0;
            while q.next().is_some() {
                n += 1;
            }
            assert_eq!(n, 100_000, "{kind:?}");
        }
    }

    #[test]
    fn cancel_skips_events_and_updates_len() {
        for kind in [QueueKind::Heap, QueueKind::Bucket] {
            let mut q = EventQueue::with_kind(kind);
            let a = q.schedule_at(secs(1.0), EventKind::Custom { tag: 1 });
            let b = q.schedule_at(secs(2.0), EventKind::Custom { tag: 2 });
            let c = q.schedule_at(secs(3.0), EventKind::Custom { tag: 3 });
            assert_eq!(q.len(), 3);
            assert!(q.cancel(b));
            assert!(!q.cancel(b), "double cancel is a no-op");
            assert!(!q.cancel(9999), "unknown id rejected");
            assert_eq!(q.len(), 2);
            let mut tags = Vec::new();
            while let Some((_, EventKind::Custom { tag })) = q.next() {
                tags.push(tag);
            }
            assert_eq!(tags, vec![1, 3], "{kind:?}");
            assert_eq!(q.processed(), 2);
            let _ = (a, c);
        }
    }

    #[test]
    fn cancel_of_fired_event_is_exact_noop() {
        for kind in [QueueKind::Heap, QueueKind::Bucket] {
            let mut q = EventQueue::with_kind(kind);
            let a = q.schedule_at(secs(1.0), EventKind::Custom { tag: 1 });
            q.schedule_at(secs(2.0), EventKind::Custom { tag: 2 });
            let (t, _) = q.next().unwrap(); // fires `a`
            assert_eq!(t, secs(1.0));
            assert!(!q.cancel(a), "canceling a fired id must be a no-op");
            assert_eq!(q.len(), 1, "len must stay exact after a stale cancel");
            assert!(!q.is_empty());
            let (t2, _) = q.next().unwrap();
            assert_eq!(t2, secs(2.0), "{kind:?}");
            assert!(q.is_empty());
        }
    }

    #[test]
    fn cancel_head_respected_by_peek() {
        for kind in [QueueKind::Heap, QueueKind::Bucket] {
            let mut q = EventQueue::with_kind(kind);
            let a = q.schedule_at(secs(1.0), EventKind::Custom { tag: 1 });
            q.schedule_at(secs(2.0), EventKind::Custom { tag: 2 });
            q.cancel(a);
            assert_eq!(q.peek_time(), Some(secs(2.0)), "{kind:?}");
            let (t, _) = q.next().unwrap();
            assert_eq!(t, secs(2.0));
            assert!(q.is_empty());
        }
    }

    #[test]
    fn far_future_events_cross_the_overflow_boundary() {
        // events far beyond the 256-bucket wheel window must round-trip
        let mut q = EventQueue::with_kind(QueueKind::Bucket);
        q.schedule_at(secs(10_000.0), EventKind::Custom { tag: 3 });
        q.schedule_at(secs(0.1), EventKind::Custom { tag: 1 });
        q.schedule_at(secs(700.0), EventKind::Custom { tag: 2 });
        let mut tags = Vec::new();
        while let Some((_, EventKind::Custom { tag })) = q.next() {
            tags.push(tag);
        }
        assert_eq!(tags, vec![1, 2, 3]);
        assert_eq!(q.now(), secs(10_000.0));
    }

    #[test]
    fn bucket_ordering_equals_heap_ordering_property() {
        // The satellite invariant: both backends emit identical event
        // sequences for any random schedule, including interleaved pops,
        // past-time clamps and cancels.
        prop::check("bucket==heap ordering", prop::default_cases(), |g| {
            let mut heap = EventQueue::with_kind(QueueKind::Heap);
            let mut bucket = EventQueue::with_kind(QueueKind::Bucket);
            let ops = g.usize(1, 120);
            // tag → event id, so pops can retire ids before a cancel picks one
            let mut id_of_tag: std::collections::HashMap<u64, EventId> =
                std::collections::HashMap::new();
            let mut live_ids: Vec<EventId> = Vec::new();
            for i in 0..ops {
                match g.usize(0, 9) {
                    // mostly schedules, with a long-tail time distribution
                    0..=5 => {
                        let t = if g.bool() {
                            g.f64(0.0, 30.0)
                        } else {
                            g.f64(0.0, 5_000.0)
                        };
                        let kind = EventKind::Custom { tag: i as u64 };
                        let id1 = heap.schedule_at(secs(t), kind.clone());
                        let id2 = bucket.schedule_at(secs(t), kind);
                        crate::prop_assert!(id1 == id2, "ids diverged: {id1} vs {id2}");
                        id_of_tag.insert(i as u64, id1);
                        live_ids.push(id1);
                    }
                    6..=7 => {
                        let a = heap.next();
                        let b = bucket.next();
                        crate::prop_assert!(a == b, "pop diverged: {a:?} vs {b:?}");
                        if let Some((_, EventKind::Custom { tag })) = a {
                            if let Some(id) = id_of_tag.remove(&tag) {
                                live_ids.retain(|&x| x != id);
                            }
                        }
                    }
                    _ => {
                        if !live_ids.is_empty() {
                            let at = g.usize(0, live_ids.len() - 1);
                            let id = live_ids.swap_remove(at);
                            let r1 = heap.cancel(id);
                            let r2 = bucket.cancel(id);
                            crate::prop_assert!(r1 == r2, "cancel diverged on {id}");
                        }
                    }
                }
            }
            loop {
                let a = heap.next();
                let b = bucket.next();
                crate::prop_assert!(a == b, "drain diverged: {a:?} vs {b:?}");
                if a.is_none() {
                    break;
                }
            }
            crate::prop_assert!(
                heap.processed() == bucket.processed(),
                "processed diverged"
            );
            Ok(())
        });
    }
}
