//! Discrete-event simulation engine.
//!
//! The whole platform (coordinator strategies, cluster, message queue,
//! parties) is written against virtual `Time` and an event queue, so the
//! *same* scheduling code runs in two modes:
//!
//! * **simulated** — `EventQueue` + virtual clock: the Fig 7/8/9 grids
//!   (up to 10 000 parties × 50 rounds × 4 strategies) execute in
//!   milliseconds of wall time;
//! * **live** — wall-clock: the quickstart / end-to-end examples drive real
//!   XLA aggregation and real local training, reusing the same policy code
//!   (see `coordinator::live`).
//!
//! Time is `u64` microseconds. Events carry an opaque `EventKind` that the
//! world dispatcher (coordinator::platform) interprets; the engine itself
//! is domain-agnostic, ordered by (time, seq) for determinism.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Virtual time in microseconds.
pub type Time = u64;

pub const MICROS: f64 = 1_000_000.0;

/// Convert seconds (f64) to Time.
pub fn secs(s: f64) -> Time {
    debug_assert!(s >= 0.0, "negative duration {s}");
    (s * MICROS).round() as Time
}

/// Convert Time to seconds.
pub fn to_secs(t: Time) -> f64 {
    t as f64 / MICROS
}

/// Domain events dispatched by the platform. The engine never inspects
/// payloads beyond ordering.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A party's model update reaches the message queue. (job, round, party)
    UpdateArrival { job: usize, round: u32, party: usize },
    /// Cluster scheduling tick (every delta seconds, §5.5).
    SchedTick,
    /// JIT deadline timer for a job's aggregation task (Fig 6 TIMER_ALERT).
    TimerAlert { job: usize, round: u32 },
    /// A container finishes its current work item.
    ContainerDone { container: usize },
    /// Start of a round for a job (aggregator sent the global model).
    RoundStart { job: usize, round: u32 },
    /// t_wait expired for a round of an intermittent job.
    RoundTimeout { job: usize, round: u32 },
    /// Generic user event for tests/extensions.
    Custom { tag: u64 },
}

#[derive(Clone, Debug)]
struct ScheduledEvent {
    time: Time,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for ScheduledEvent {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for ScheduledEvent {}
impl PartialOrd for ScheduledEvent {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for ScheduledEvent {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic event queue with a virtual clock.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<ScheduledEvent>,
    now: Time,
    seq: u64,
    processed: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn now(&self) -> Time {
        self.now
    }

    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `kind` at absolute time `at` (clamped to now — scheduling in
    /// the past executes "immediately", preserving causality).
    pub fn schedule_at(&mut self, at: Time, kind: EventKind) {
        let t = at.max(self.now);
        self.seq += 1;
        self.heap.push(ScheduledEvent {
            time: t,
            seq: self.seq,
            kind,
        });
    }

    /// Schedule `kind` after a relative delay.
    pub fn schedule_in(&mut self, delay: Time, kind: EventKind) {
        self.schedule_at(self.now.saturating_add(delay), kind);
    }

    /// Pop the next event, advancing the clock.
    pub fn next(&mut self) -> Option<(Time, EventKind)> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.time >= self.now, "time went backwards");
        self.now = ev.time;
        self.processed += 1;
        Some((ev.time, ev.kind))
    }

    /// Peek at the time of the next event.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|e| e.time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(secs(3.0), EventKind::Custom { tag: 3 });
        q.schedule_at(secs(1.0), EventKind::Custom { tag: 1 });
        q.schedule_at(secs(2.0), EventKind::Custom { tag: 2 });
        let mut tags = Vec::new();
        while let Some((_, EventKind::Custom { tag })) = q.next() {
            tags.push(tag);
        }
        assert_eq!(tags, vec![1, 2, 3]);
        assert_eq!(q.now(), secs(3.0));
        assert_eq!(q.processed(), 3);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        for tag in 0..10 {
            q.schedule_at(secs(1.0), EventKind::Custom { tag });
        }
        let mut tags = Vec::new();
        while let Some((_, EventKind::Custom { tag })) = q.next() {
            tags.push(tag);
        }
        assert_eq!(tags, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn past_events_clamped_to_now() {
        let mut q = EventQueue::new();
        q.schedule_at(secs(5.0), EventKind::Custom { tag: 1 });
        q.next();
        q.schedule_at(secs(1.0), EventKind::Custom { tag: 2 }); // in the past
        let (t, _) = q.next().unwrap();
        assert_eq!(t, secs(5.0));
    }

    #[test]
    fn relative_scheduling() {
        let mut q = EventQueue::new();
        q.schedule_in(secs(2.0), EventKind::Custom { tag: 1 });
        let (t, _) = q.next().unwrap();
        assert_eq!(t, secs(2.0));
        q.schedule_in(secs(0.5), EventKind::Custom { tag: 2 });
        let (t2, _) = q.next().unwrap();
        assert_eq!(t2, secs(2.5));
    }

    #[test]
    fn unit_conversions() {
        assert_eq!(secs(1.5), 1_500_000);
        assert!((to_secs(2_250_000) - 2.25).abs() < 1e-12);
    }

    #[test]
    fn throughput_smoke() {
        // engine must sustain ~1M events/s (DESIGN.md §Perf L3); here we
        // just sanity-check that 100k schedule+pop round trips complete.
        let mut q = EventQueue::new();
        for i in 0..100_000u64 {
            q.schedule_at(i * 3 % 1_000_000, EventKind::Custom { tag: i });
        }
        let mut n = 0;
        while q.next().is_some() {
            n += 1;
        }
        assert_eq!(n, 100_000);
    }
}
