//! The paper's three evaluation workloads (§6.3) as calibrated profiles.
//!
//! | workload | model | dataset | algorithm |
//! |---|---|---|---|
//! | `cifar100-effnet`  | EfficientNet-B7 (66.3M) | CIFAR100 (TFF)   | FedProx |
//! | `rvlcdip-vgg16`    | VGG16 (138.4M)          | RVL-CDIP         | FedSGD  |
//! | `inat-inception`   | InceptionV4 (42.7M)     | iNaturalist (TFF)| FedProx |
//!
//! Each profile carries the timing scales the simulator needs: base epoch
//! time (party side), `t_pair` (aggregator side; re-calibratable on this
//! machine via `fusion::calibrate_t_pair`, §5.4), intra-DC bandwidth, and
//! serverless overheads. Absolute values are calibrated to land in the
//! paper's magnitude bands (Fig 9); EXPERIMENTS.md reports paper-vs-ours
//! per cell.

use crate::estimator::AggCostModel;
use crate::fusion::Algorithm;
use crate::model::{zoo, ModelSpec};
use crate::party::FleetParams;

/// A full workload profile.
#[derive(Clone, Debug)]
pub struct Workload {
    pub name: &'static str,
    pub model: ModelSpec,
    pub algorithm: Algorithm,
    /// Mean local-epoch time on the homogeneous 2-vCPU party (seconds).
    pub base_epoch_secs: f64,
    /// Pair-fusion time on one aggregator core (seconds; §5.4 calibration).
    pub t_pair: f64,
    /// Serverless overheads (seconds): Ray task scheduling + container
    /// attach, and checkpoint write per deployment.
    pub cold_start_secs: f64,
    pub checkpoint_secs: f64,
    /// Intra-datacenter bandwidth (bytes/s) for model state load.
    pub b_dc: f64,
    /// Ancillary services (MongoDB/Kafka/COS) charged per round (§6.2
    /// "includes all the resources used by the ancillary services").
    pub ancillary_cs_per_round: f64,
}

impl Workload {
    /// The three paper workloads.
    pub fn cifar100_effnet() -> Workload {
        Workload {
            name: "cifar100-effnet",
            model: zoo::efficientnet_b7(),
            algorithm: Algorithm::FedProx { mu: 0.1 },
            base_epoch_secs: 26.0,
            t_pair: 0.050,
            cold_start_secs: 0.35,
            checkpoint_secs: 0.18,
            b_dc: 1.25e9, // 10 Gbps
            ancillary_cs_per_round: 1.2,
        }
    }

    pub fn rvlcdip_vgg16() -> Workload {
        Workload {
            name: "rvlcdip-vgg16",
            model: zoo::vgg16(),
            algorithm: Algorithm::FedSgd,
            base_epoch_secs: 30.0,
            t_pair: 0.085,
            cold_start_secs: 0.35,
            checkpoint_secs: 0.30,
            b_dc: 1.25e9,
            ancillary_cs_per_round: 1.2,
        }
    }

    pub fn inat_inception() -> Workload {
        Workload {
            name: "inat-inception",
            model: zoo::inception_v4(),
            algorithm: Algorithm::FedProx { mu: 0.1 },
            base_epoch_secs: 38.0,
            t_pair: 0.034,
            cold_start_secs: 0.35,
            checkpoint_secs: 0.14,
            b_dc: 1.25e9,
            ancillary_cs_per_round: 1.2,
        }
    }

    /// The MLP workload used by the live (real-training) examples.
    pub fn mlp_live() -> Workload {
        Workload {
            name: "mlp-live",
            model: zoo::mlp_default(),
            algorithm: Algorithm::FedAvg,
            base_epoch_secs: 0.5,
            t_pair: 0.002,
            cold_start_secs: 0.05,
            checkpoint_secs: 0.02,
            b_dc: 1.25e9,
            ancillary_cs_per_round: 0.1,
        }
    }

    pub fn all_paper() -> Vec<Workload> {
        vec![
            Self::cifar100_effnet(),
            Self::rvlcdip_vgg16(),
            Self::inat_inception(),
        ]
    }

    pub fn by_name(name: &str) -> Option<Workload> {
        match name {
            "cifar100-effnet" | "cifar100" => Some(Self::cifar100_effnet()),
            "rvlcdip-vgg16" | "rvlcdip" => Some(Self::rvlcdip_vgg16()),
            "inat-inception" | "inat" => Some(Self::inat_inception()),
            "mlp-live" | "mlp" => Some(Self::mlp_live()),
            _ => None,
        }
    }

    /// N_agg scaling rule: one aggregator container per 64 parties, capped —
    /// mirrors the paper's growth of aggregator parallelism with fleet size.
    pub fn n_agg(&self, parties: usize) -> u32 {
        (parties as u32).div_ceil(64).clamp(1, 160)
    }

    /// The §5.4 cost model for a given fleet size.
    pub fn cost_model(&self, parties: usize) -> AggCostModel {
        AggCostModel {
            t_pair: self.t_pair,
            c_agg: 2,
            n_agg: self.n_agg(parties),
            b_dc: self.b_dc,
            model_bytes: self.model.size_bytes(),
        }
    }

    /// Fleet timing parameters for this workload.
    pub fn fleet_params(&self) -> FleetParams {
        FleetParams {
            base_epoch_secs: self.base_epoch_secs,
            ..FleetParams::default()
        }
    }

    /// State-load time for one aggregator deployment (model from MQ/COS).
    pub fn state_load_secs(&self) -> f64 {
        self.model.size_bytes() as f64 / self.b_dc
    }

    /// Replace `t_pair` with a value measured on *this* machine (§5.4).
    pub fn recalibrate_t_pair(&mut self, reps: usize, seed: u64) -> f64 {
        let measured = crate::fusion::calibrate_t_pair(&self.model, reps, seed);
        self.t_pair = measured;
        measured
    }
}

/// Batched-serverless trigger sizes per fleet size (§6.3: "aggregation was
/// triggered every (2,10,100,100) model updates for the (10, 100, 1000,
/// 10000) party scenarios").
pub fn batch_trigger(parties: usize) -> usize {
    match parties {
        0..=10 => 2,
        11..=100 => 10,
        _ => 100,
    }
}

/// t_wait for intermittent scenarios: 10 minutes (within the paper's
/// "minutes or hours" guidance; fixed so results are comparable).
pub const T_WAIT_SECS: f64 = 600.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workloads_resolve_and_match_models() {
        let w = Workload::cifar100_effnet();
        assert_eq!(w.model.total_params(), 66_347_960);
        assert_eq!(w.algorithm.name(), "fedprox");
        let v = Workload::rvlcdip_vgg16();
        assert_eq!(v.model.total_params(), 138_357_544);
        assert_eq!(v.algorithm.name(), "fedsgd");
        let i = Workload::inat_inception();
        assert_eq!(i.model.total_params(), 42_679_816);
        assert_eq!(Workload::all_paper().len(), 3);
    }

    #[test]
    fn by_name_aliases() {
        for n in ["cifar100", "rvlcdip", "inat", "mlp"] {
            assert!(Workload::by_name(n).is_some(), "{n}");
        }
        assert!(Workload::by_name("bogus").is_none());
    }

    #[test]
    fn n_agg_scaling() {
        let w = Workload::cifar100_effnet();
        assert_eq!(w.n_agg(10), 1);
        assert_eq!(w.n_agg(100), 2);
        assert_eq!(w.n_agg(1000), 16);
        assert_eq!(w.n_agg(10000), 157);
    }

    #[test]
    fn batch_triggers_match_paper() {
        assert_eq!(batch_trigger(10), 2);
        assert_eq!(batch_trigger(100), 10);
        assert_eq!(batch_trigger(1000), 100);
        assert_eq!(batch_trigger(10000), 100);
    }

    #[test]
    fn cost_model_plumbs_model_size() {
        let w = Workload::rvlcdip_vgg16();
        let c = w.cost_model(1000);
        assert_eq!(c.model_bytes, 138_357_544 * 4);
        assert_eq!(c.n_agg, 16);
        // state load for 553MB at 10Gbps ≈ 0.44s
        assert!((w.state_load_secs() - 0.4427).abs() < 0.01);
    }

    #[test]
    fn recalibration_updates_t_pair() {
        let mut w = Workload::mlp_live();
        let measured = w.recalibrate_t_pair(2, 7);
        assert!(measured > 0.0);
        assert_eq!(w.t_pair, measured);
    }
}
