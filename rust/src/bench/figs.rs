//! Figure/table regeneration (§6): Fig 3 (periodicity), Fig 4 (linearity),
//! Fig 7/8 (aggregation latency), Fig 9 (container-seconds + cost).
//! `fljit bench-table <fig>` dumps each as `target/repro/<fig>.json`.
//!
//! Grid sweeps fan the independent scenario cells out across the global
//! fusion [`WorkerPool`](crate::fusion::WorkerPool): each cell owns its
//! platform, event queue and seeded RNG, so the parallel sweep is
//! bit-identical to the sequential one — just `threads()`× faster on the
//! 3-workload × 4-fleet-size × 4-strategy grids.

use crate::coordinator::job::FlJobSpec;
use crate::coordinator::platform::run_scenario;
use crate::coordinator::strategies::paper_strategies;
use crate::metrics::{savings_pct, JobReport};
use crate::party::FleetKind;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::workloads::Workload;

/// Party-count axis of the paper's grids.
pub const PARTY_GRID: [usize; 4] = [10, 100, 1000, 10000];

/// Run independent scenario cells on the global worker pool, preserving
/// input order. Every cell is self-contained (own `Platform`, own seeded
/// RNG), so results match the sequential sweep exactly.
pub fn run_cells(cells: Vec<(FlJobSpec, &'static str, u64)>) -> Vec<JobReport> {
    let tasks: Vec<Box<dyn FnOnce() -> JobReport + Send>> = cells
        .into_iter()
        .map(|(spec, strat, seed)| {
            Box::new(move || run_scenario(&spec, strat, seed))
                as Box<dyn FnOnce() -> JobReport + Send>
        })
        .collect();
    crate::fusion::WorkerPool::global().run_all(tasks)
}

/// Latency grid (Fig 7 intermittent / Fig 8 active heterogeneous).
pub struct LatencyGrid {
    pub fleet: FleetKind,
    pub rounds: u32,
    pub seed: u64,
    pub max_parties: usize,
}

impl LatencyGrid {
    pub fn run(&self) -> (Vec<Table>, Json) {
        let workloads = Workload::all_paper();
        let strategies = paper_strategies();
        // Flatten the (workload × parties × strategy) grid into
        // independent cells and sweep them in parallel.
        let mut cells = Vec::new();
        for workload in &workloads {
            for &n in PARTY_GRID.iter().filter(|&&n| n <= self.max_parties) {
                for strat in strategies {
                    cells.push((self.spec(workload, n), *strat, self.seed));
                }
            }
        }
        let mut reports = run_cells(cells).into_iter();
        let mut tables = Vec::new();
        let mut json_rows = Vec::new();
        for workload in &workloads {
            let mut t = Table::new(
                &format!(
                    "{} on {} — mean aggregation latency (s), {} parties",
                    workload.name,
                    self.fleet.name(),
                    "10..10000"
                ),
                &["# parties", "JIT", "Batch λ", "Eager λ", "Eager AO"],
            );
            for &n in PARTY_GRID.iter().filter(|&&n| n <= self.max_parties) {
                let mut row = vec![n.to_string()];
                for _ in strategies {
                    let r = reports.next().expect("one report per grid cell");
                    row.push(format!("{:.2}", r.mean_latency_secs()));
                    json_rows.push(report_json(&r));
                }
                t.row(row);
            }
            tables.push(t);
        }
        (tables, Json::Arr(json_rows))
    }

    fn spec(&self, w: &Workload, n: usize) -> FlJobSpec {
        FlJobSpec::new(w.clone(), self.fleet, n, self.rounds)
    }
}

/// The Fig 9 grid: container-seconds, projected cost, savings — per
/// workload × fleet kind × party count × strategy.
pub struct ResourceGrid {
    pub rounds: u32,
    pub seed: u64,
    pub max_parties: usize,
    /// Restrict to one workload (CLI filter); None = all three.
    pub only_workload: Option<String>,
    pub fleets: Vec<FleetKind>,
}

impl Default for ResourceGrid {
    fn default() -> Self {
        ResourceGrid {
            rounds: 50,
            seed: 0xF19,
            max_parties: 10000,
            only_workload: None,
            fleets: vec![
                FleetKind::ActiveHomogeneous,
                FleetKind::ActiveHeterogeneous,
                FleetKind::IntermittentHeterogeneous,
            ],
        }
    }
}

impl ResourceGrid {
    pub fn run(&self) -> (Vec<Table>, Json) {
        let strategies = paper_strategies();
        let workloads: Vec<Workload> = Workload::all_paper()
            .into_iter()
            .filter(|w| match &self.only_workload {
                None => true,
                Some(only) => w.name == only.as_str(),
            })
            .collect();
        // Flatten the (workload × fleet × parties × strategy) grid into
        // independent cells and sweep them in parallel.
        let mut cells = Vec::new();
        for workload in &workloads {
            for &fleet in &self.fleets {
                for &n in PARTY_GRID.iter().filter(|&&n| n <= self.max_parties) {
                    for strat in strategies {
                        cells.push((
                            FlJobSpec::new(workload.clone(), fleet, n, self.rounds),
                            *strat,
                            self.seed,
                        ));
                    }
                }
            }
        }
        let mut results = run_cells(cells).into_iter();
        let mut tables = Vec::new();
        let mut json_rows = Vec::new();
        for workload in &workloads {
            for &fleet in &self.fleets {
                // the paper's intermittent block skips homogeneous fleets
                let mut t = Table::new(
                    &format!(
                        "Fig 9 — {} ({} aggregation) — {} parties",
                        workload.name,
                        workload.algorithm.name(),
                        fleet.name()
                    ),
                    &[
                        "# parties",
                        "JIT cs",
                        "Batchλ cs",
                        "Eagerλ cs",
                        "EagerAO cs",
                        "JIT $",
                        "AO $",
                        "JIT vs Batchλ",
                        "JIT vs Eagerλ",
                        "JIT vs AO",
                    ],
                );
                for &n in PARTY_GRID.iter().filter(|&&n| n <= self.max_parties) {
                    let reports: Vec<JobReport> = strategies
                        .iter()
                        .map(|_| results.next().expect("one report per grid cell"))
                        .collect();
                    let (jit, batch, eager, ao) =
                        (&reports[0], &reports[1], &reports[2], &reports[3]);
                    t.row(vec![
                        n.to_string(),
                        format!("{:.0}", jit.total_container_seconds()),
                        format!("{:.0}", batch.total_container_seconds()),
                        format!("{:.0}", eager.total_container_seconds()),
                        format!("{:.0}", ao.total_container_seconds()),
                        format!("{:.2}", jit.cost_usd()),
                        format!("{:.2}", ao.cost_usd()),
                        format!("{:.1}%", savings_pct(jit, batch)),
                        format!("{:.1}%", savings_pct(jit, eager)),
                        format!("{:.1}%", savings_pct(jit, ao)),
                    ]);
                    for r in &reports {
                        json_rows.push(report_json(r));
                    }
                }
                tables.push(t);
            }
        }
        (tables, Json::Arr(json_rows))
    }
}

fn report_json(r: &JobReport) -> Json {
    r.to_json()
}

// ---------------------------------------------------------------------------
// Fig 3 / Fig 4: real-training periodicity and linearity via the runtime
// ---------------------------------------------------------------------------

/// Measure `reps` local epochs at fixed shape; returns per-epoch seconds.
/// Requires `make artifacts`.
pub fn measure_epochs(n_minibatches: usize, reps: usize, seed: u64) -> anyhow::Result<Vec<f64>> {
    use crate::party::synth_party_dataset;
    use crate::runtime::{Runtime, Trainer, MLP_CLASSES, MLP_IN};
    let rt = Runtime::with_default_dir()?;
    let (xs, ys) = synth_party_dataset(0, n_minibatches * 32, MLP_IN, MLP_CLASSES, 10.0, seed);
    let mut trainer = Trainer::init(&rt, seed);
    // warm-up compiles the executable
    trainer.epoch(n_minibatches, &xs, &ys, 0.05)?;
    let mut out = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        trainer.epoch(n_minibatches, &xs, &ys, 0.05)?;
        out.push(t0.elapsed().as_secs_f64());
    }
    Ok(out)
}

/// Measure one minibatch step at batch size `b` (must match an artifact).
pub fn measure_minibatch(b: usize, reps: usize, seed: u64) -> anyhow::Result<Vec<f64>> {
    use crate::party::synth_party_dataset;
    use crate::runtime::{Runtime, Trainer, MLP_CLASSES, MLP_IN};
    let rt = Runtime::with_default_dir()?;
    let (xs, ys) = synth_party_dataset(1, b, MLP_IN, MLP_CLASSES, 10.0, seed);
    let mut trainer = Trainer::init(&rt, seed);
    trainer.step(b, &xs, &ys, 0.05)?;
    let mut out = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        trainer.step(b, &xs, &ys, 0.05)?;
        out.push(t0.elapsed().as_secs_f64());
    }
    Ok(out)
}

/// Fig 3: epoch & minibatch times across repetitions — the periodicity
/// claim is CV ≪ 1.
pub fn fig3(reps: usize, seed: u64) -> anyhow::Result<(Table, Json)> {
    let mut t = Table::new(
        "Fig 3 — periodicity: per-epoch / per-minibatch time across repetitions",
        &["measure", "shape", "mean (ms)", "std (ms)", "CV"],
    );
    let mut rows = Vec::new();
    for n in [8usize, 16] {
        let xs = measure_epochs(n, reps, seed)?;
        let s = crate::util::stats::Summary::of(&xs);
        t.row(vec![
            "epoch".into(),
            format!("{n}x32"),
            format!("{:.2}", s.mean * 1e3),
            format!("{:.2}", s.std * 1e3),
            format!("{:.3}", s.cv()),
        ]);
        rows.push(Json::obj(vec![
            ("measure", Json::str("epoch")),
            ("minibatches", Json::num(n as f64)),
            ("mean_secs", Json::num(s.mean)),
            ("cv", Json::num(s.cv())),
        ]));
    }
    for b in [32usize, 64] {
        let xs = measure_minibatch(b, reps, seed)?;
        let s = crate::util::stats::Summary::of(&xs);
        t.row(vec![
            "minibatch".into(),
            format!("b={b}"),
            format!("{:.2}", s.mean * 1e3),
            format!("{:.2}", s.std * 1e3),
            format!("{:.3}", s.cv()),
        ]);
        rows.push(Json::obj(vec![
            ("measure", Json::str("minibatch")),
            ("batch", Json::num(b as f64)),
            ("mean_secs", Json::num(s.mean)),
            ("cv", Json::num(s.cv())),
        ]));
    }
    Ok((t, Json::Arr(rows)))
}

/// Fig 4: minibatch time vs batch size; epoch time vs dataset size — the
/// linearity claim is R² ≈ 1 on the OLS fit.
pub fn fig4(reps: usize, seed: u64) -> anyhow::Result<(Table, Json)> {
    let mut t = Table::new(
        "Fig 4 — linearity: minibatch time vs batch size; epoch time vs dataset size",
        &["sweep", "x", "mean time (ms)"],
    );
    let mut mb_x = Vec::new();
    let mut mb_y = Vec::new();
    for b in [16usize, 32, 64, 128] {
        let xs = measure_minibatch(b, reps, seed)?;
        let mean = crate::util::stats::Summary::of(&xs).mean;
        mb_x.push(b as f64);
        mb_y.push(mean);
        t.row(vec![
            "minibatch-vs-batch".into(),
            b.to_string(),
            format!("{:.3}", mean * 1e3),
        ]);
    }
    let mut ep_x = Vec::new();
    let mut ep_y = Vec::new();
    for n in [2usize, 4, 8, 16, 32] {
        let xs = measure_epochs(n, reps, seed)?;
        let mean = crate::util::stats::Summary::of(&xs).mean;
        ep_x.push((n * 32) as f64);
        ep_y.push(mean);
        t.row(vec![
            "epoch-vs-datasize".into(),
            (n * 32).to_string(),
            format!("{:.3}", mean * 1e3),
        ]);
    }
    let mb_fit = crate::util::stats::LinearFit::fit(&mb_x, &mb_y)
        .ok_or_else(|| anyhow::anyhow!("minibatch fit failed"))?;
    let ep_fit = crate::util::stats::LinearFit::fit(&ep_x, &ep_y)
        .ok_or_else(|| anyhow::anyhow!("epoch fit failed"))?;
    t.row(vec![
        "OLS R² (minibatch)".into(),
        "-".into(),
        format!("{:.4}", mb_fit.r2),
    ]);
    t.row(vec![
        "OLS R² (epoch)".into(),
        "-".into(),
        format!("{:.4}", ep_fit.r2),
    ]);
    let j = Json::obj(vec![
        ("minibatch_r2", Json::num(mb_fit.r2)),
        ("minibatch_slope", Json::num(mb_fit.slope)),
        ("epoch_r2", Json::num(ep_fit.r2)),
        ("epoch_slope", Json::num(ep_fit.slope)),
    ]);
    Ok((t, j))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_grid_small_scale() {
        let grid = LatencyGrid {
            fleet: FleetKind::ActiveHeterogeneous,
            rounds: 2,
            seed: 5,
            max_parties: 10,
        };
        let (tables, json) = grid.run();
        assert_eq!(tables.len(), 3, "one table per workload");
        assert_eq!(json.as_arr().unwrap().len(), 3 * 4, "3 workloads × 4 strategies");
        for t in &tables {
            assert_eq!(t.rows.len(), 1, "only the 10-party row at this cap");
        }
    }

    #[test]
    fn resource_grid_small_scale_orders_strategies() {
        let grid = ResourceGrid {
            rounds: 3,
            seed: 5,
            max_parties: 10,
            only_workload: Some("cifar100-effnet".into()),
            fleets: vec![FleetKind::ActiveHomogeneous],
        };
        let (tables, json) = grid.run();
        assert_eq!(tables.len(), 1);
        let rows = json.as_arr().unwrap();
        assert_eq!(rows.len(), 4);
        let cs = |i: usize| rows[i].get("total_container_seconds").as_f64().unwrap();
        // order: jit, batched, eager-serverless, eager-ao
        assert!(cs(0) < cs(2), "jit < eager λ");
        assert!(cs(2) < cs(3), "eager λ < AO");
    }
}
