//! Robustness matrix — every §3 strategy × the named fault scenarios.
//!
//! Runs the identical scripted live job (instant clock, MQ data plane)
//! under each `(strategy, scenario)` cell, where the scenario is a
//! [`FleetFaults`] preset: heavy-tailed stragglers with a reporting
//! deadline, dropout-with-rejoin churn, diurnal availability waves, and
//! non-IID weight skew. Per cell it reports:
//!
//! * **fidelity** — L2 distance of the cell's final global model to the
//!   *same strategy's* fault-free (baseline-scenario) final model. Lower
//!   is better: it measures how much fleet hostility bent the model away
//!   from the model the strategy would have learned on a healthy fleet.
//! * **latency inflation** — mean round aggregation latency relative to
//!   the strategy's baseline cell.
//! * the engine's degradation counters — updates cut at the straggler
//!   deadline (drop-policy strategies), deadline-missers folded with
//!   decayed weight (`async-stale`), and rounds skipped on starvation.
//!
//! The matrix is the issue's acceptance harness for `async-stale`: in the
//! straggler-heavy cell the drop-at-deadline strategies lose the late
//! parties' data (fidelity grows), while `async-stale` folds it decayed
//! and lands closer to its healthy-fleet model. Dumped to
//! `BENCH_robustness.json` via `fljit robustness`.

use crate::coordinator::job::FlJobSpec;
use crate::coordinator::session::Session;
use crate::coordinator::strategies;
use crate::party::{FleetFaults, FleetKind};
use crate::util::json::Json;
use crate::util::table::Table;
use crate::workloads::Workload;

#[derive(Clone, Debug)]
pub struct RobustnessSweepConfig {
    pub n_parties: usize,
    pub rounds: u32,
    pub seed: u64,
    pub dim: usize,
    /// Mean synthetic epoch time (virtual seconds under the instant
    /// clock; the straggler cutoff scales from it).
    pub epoch_secs: f64,
    /// Strategy names to sweep (default: all six).
    pub strategies: Vec<String>,
    /// Scenario names to sweep (default: all five, see
    /// [`FleetFaults::all_scenarios`]).
    pub scenarios: Vec<String>,
}

impl Default for RobustnessSweepConfig {
    fn default() -> Self {
        RobustnessSweepConfig {
            n_parties: 10,
            rounds: 4,
            seed: 42,
            dim: 64,
            epoch_secs: 0.4,
            strategies: strategies::all_strategies()
                .iter()
                .map(|s| s.to_string())
                .collect(),
            scenarios: FleetFaults::all_scenarios()
                .iter()
                .map(|s| s.to_string())
                .collect(),
        }
    }
}

fn parse_list(raw: Option<&str>, default: &[String]) -> Vec<String> {
    match raw {
        Some(s) => s
            .split(',')
            .map(|x| x.trim().to_string())
            .filter(|x| !x.is_empty())
            .collect(),
        None => default.to_vec(),
    }
}

impl RobustnessSweepConfig {
    pub fn from_args(args: &crate::util::cli::Args) -> RobustnessSweepConfig {
        let d = RobustnessSweepConfig::default();
        RobustnessSweepConfig {
            n_parties: args.get_usize("parties", d.n_parties),
            rounds: args.get_u64("rounds", d.rounds as u64) as u32,
            seed: args.get_u64("seed", d.seed),
            dim: args.get_usize("dim", d.dim),
            epoch_secs: args.get_f64("epoch-secs", d.epoch_secs),
            strategies: parse_list(args.get("strategies"), &d.strategies),
            scenarios: parse_list(args.get("scenarios"), &d.scenarios),
        }
    }
}

/// One cell's raw outcome (before baseline-relative metrics).
#[derive(Clone, Debug)]
struct Cell {
    rounds_done: usize,
    rounds_skipped: u32,
    mean_latency_secs: f64,
    updates_fused: u64,
    updates_dropped: usize,
    updates_decayed: usize,
    final_model: Vec<f32>,
}

fn l2(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

fn run_cell(
    cfg: &RobustnessSweepConfig,
    strategy: &str,
    faults: FleetFaults,
) -> Result<Cell, String> {
    let mut workload = Workload::mlp_live();
    workload.base_epoch_secs = cfg.epoch_secs;
    let spec = FlJobSpec::new(
        workload,
        FleetKind::ActiveHomogeneous,
        cfg.n_parties,
        cfg.rounds,
    );
    let mut s = Session::live().seed(cfg.seed).dim(cfg.dim).faults(faults);
    s.job(spec, strategy);
    let rep = s.run().map_err(|e| format!("{e:#}"))?;
    let o = rep.single();
    Ok(Cell {
        rounds_done: o.records.len(),
        rounds_skipped: o.rounds_skipped,
        mean_latency_secs: o.mean_latency_secs(),
        updates_fused: o.updates_fused,
        updates_dropped: o.updates_dropped,
        updates_decayed: o.updates_decayed,
        final_model: o.final_model.clone(),
    })
}

/// Run the strategy × scenario grid; table + JSON. Every strategy's
/// baseline (fault-free) cell runs even when `baseline` is not in the
/// requested scenario list — it is the fidelity/inflation reference.
pub fn run_sweep(cfg: &RobustnessSweepConfig) -> (Table, Json) {
    let mut t = Table::new(
        &format!(
            "robustness matrix — {} parties × {} rounds, dim {}, seed {}",
            cfg.n_parties, cfg.rounds, cfg.dim, cfg.seed
        ),
        &[
            "strategy",
            "scenario",
            "rounds",
            "skipped",
            "mean lat (ms)",
            "lat ×base",
            "dropped",
            "decayed",
            "fidelity (L2)",
        ],
    );
    let mut cells = Vec::new();
    for strategy in &cfg.strategies {
        let base = run_cell(cfg, strategy, FleetFaults::none());
        for scenario in &cfg.scenarios {
            let outcome = match FleetFaults::scenario(scenario, cfg.epoch_secs) {
                None => Err(format!("unknown scenario {scenario:?}")),
                Some(_) if scenario == "baseline" => base.clone(),
                Some(faults) => run_cell(cfg, strategy, faults),
            };
            match (&outcome, &base) {
                (Ok(c), base) => {
                    // baseline-relative metrics need the reference run
                    let (fidelity, inflation) = match base {
                        Ok(b) => (
                            Some(l2(&c.final_model, &b.final_model)),
                            if b.mean_latency_secs > 0.0 {
                                Some(c.mean_latency_secs / b.mean_latency_secs)
                            } else {
                                None
                            },
                        ),
                        Err(_) => (None, None),
                    };
                    t.row(vec![
                        strategy.clone(),
                        scenario.clone(),
                        c.rounds_done.to_string(),
                        c.rounds_skipped.to_string(),
                        format!("{:.1}", c.mean_latency_secs * 1e3),
                        inflation.map(|x| format!("{x:.2}")).unwrap_or_default(),
                        c.updates_dropped.to_string(),
                        c.updates_decayed.to_string(),
                        fidelity.map(|x| format!("{x:.4}")).unwrap_or_default(),
                    ]);
                    cells.push(Json::obj(vec![
                        ("strategy", Json::str(strategy)),
                        ("scenario", Json::str(scenario)),
                        ("rounds_done", Json::num(c.rounds_done as f64)),
                        ("rounds_skipped", Json::num(c.rounds_skipped as f64)),
                        ("mean_latency_secs", Json::num(c.mean_latency_secs)),
                        (
                            "latency_inflation",
                            inflation.map(Json::num).unwrap_or(Json::Null),
                        ),
                        ("updates_fused", Json::num(c.updates_fused as f64)),
                        ("updates_dropped", Json::num(c.updates_dropped as f64)),
                        ("updates_decayed", Json::num(c.updates_decayed as f64)),
                        (
                            "fidelity_l2",
                            fidelity.map(Json::num).unwrap_or(Json::Null),
                        ),
                    ]));
                }
                (Err(e), _) => {
                    t.row(vec![
                        strategy.clone(),
                        scenario.clone(),
                        format!("failed: {e}"),
                        String::new(),
                        String::new(),
                        String::new(),
                        String::new(),
                        String::new(),
                        String::new(),
                    ]);
                    cells.push(Json::obj(vec![
                        ("strategy", Json::str(strategy)),
                        ("scenario", Json::str(scenario)),
                        ("error", Json::str(e)),
                    ]));
                }
            }
        }
    }
    // the issue's acceptance check, embedded in the dump: in the
    // straggler-heavy cell async-stale must land closer to its healthy
    // model than drop-at-deadline jit does to its own
    let fidelity_of = |strategy: &str, scenario: &str| -> Option<f64> {
        cells.iter().find_map(|c| {
            (c.get("strategy").as_str() == Some(strategy)
                && c.get("scenario").as_str() == Some(scenario))
            .then(|| c.get("fidelity_l2").as_f64())
            .flatten()
        })
    };
    let check = match (fidelity_of("jit", "stragglers"), fidelity_of("async-stale", "stragglers")) {
        (Some(jit), Some(stale)) => Json::obj(vec![
            ("jit_fidelity_l2", Json::num(jit)),
            ("async_stale_fidelity_l2", Json::num(stale)),
            ("async_stale_beats_drop", Json::Bool(stale < jit)),
        ]),
        _ => Json::Null,
    };
    let json = Json::obj(vec![
        ("parties", Json::num(cfg.n_parties as f64)),
        ("rounds", Json::num(cfg.rounds as f64)),
        ("seed", Json::num(cfg.seed as f64)),
        ("dim", Json::num(cfg.dim as f64)),
        ("epoch_secs", Json::num(cfg.epoch_secs)),
        (
            "strategies",
            Json::arr(cfg.strategies.iter().map(|s| Json::str(s))),
        ),
        (
            "scenarios",
            Json::arr(cfg.scenarios.iter().map(|s| Json::str(s))),
        ),
        ("cells", Json::Arr(cells)),
        ("stragglers_check", check),
    ]);
    (t, json)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell<'a>(json: &'a Json, strategy: &str, scenario: &str) -> &'a Json {
        json.get("cells")
            .as_arr()
            .unwrap()
            .iter()
            .find(|c| {
                c.get("strategy").as_str() == Some(strategy)
                    && c.get("scenario").as_str() == Some(scenario)
            })
            .unwrap_or_else(|| panic!("missing cell {strategy}/{scenario}"))
    }

    #[test]
    fn full_matrix_covers_six_strategies_by_five_scenarios() {
        let cfg = RobustnessSweepConfig {
            n_parties: 10,
            rounds: 3,
            dim: 32,
            ..Default::default()
        };
        let (_t, json) = run_sweep(&cfg);
        let cells = json.get("cells").as_arr().unwrap();
        assert_eq!(cells.len(), 6 * 5, "six strategies × five scenarios");
        for c in cells {
            assert!(
                c.get("error").as_str().is_none(),
                "cell {:?}/{:?} failed: {:?}",
                c.get("strategy").as_str(),
                c.get("scenario").as_str(),
                c.get("error")
            );
            assert!(c.get("fidelity_l2").as_f64().unwrap() >= 0.0);
        }
        // baseline cells ARE the reference: fidelity is exactly zero
        for s in strategies::all_strategies() {
            assert_eq!(cell(&json, s, "baseline").get("fidelity_l2").as_f64(), Some(0.0));
        }
        crate::bench::dump("BENCH_robustness", &json);
        let text = std::fs::read_to_string(
            crate::bench::repro_dir().join("BENCH_robustness.json"),
        )
        .unwrap();
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn async_stale_beats_drop_at_deadline_in_the_straggler_cell() {
        let cfg = RobustnessSweepConfig {
            n_parties: 12,
            rounds: 3,
            dim: 32,
            strategies: vec!["jit".into(), "async-stale".into()],
            scenarios: vec!["stragglers".into()],
            ..Default::default()
        };
        let (_t, json) = run_sweep(&cfg);
        let jit = cell(&json, "jit", "stragglers");
        let stale = cell(&json, "async-stale", "stragglers");
        let jit_fid = jit.get("fidelity_l2").as_f64().unwrap();
        let stale_fid = stale.get("fidelity_l2").as_f64().unwrap();
        // identical seed => identical fault draws: jit cuts the late
        // parties at the deadline, async-stale folds them decayed
        assert!(
            jit.get("updates_dropped").as_u64().unwrap() > 0,
            "straggler scenario must cut deadline-missers for jit"
        );
        assert!(
            stale_fid <= jit_fid + 1e-12,
            "decayed folds must not hurt fidelity: async-stale {stale_fid} vs jit {jit_fid}"
        );
        if stale.get("updates_decayed").as_u64().unwrap() > 0 {
            assert!(
                stale_fid < jit_fid,
                "folding late data decayed must beat dropping it: \
                 async-stale {stale_fid} vs jit {jit_fid}"
            );
        }
        let check = json.get("stragglers_check");
        assert_eq!(check.get("async_stale_beats_drop").as_bool(), Some(stale_fid < jit_fid));
    }

    #[test]
    fn arg_lists_parse_and_unknown_scenarios_error_cleanly() {
        let args = crate::util::cli::Args::parse(
            "robustness --strategies jit,async-stale --scenarios baseline,nope \
             --parties 4 --rounds 2 --dim 16 --seed 7"
                .split_whitespace()
                .map(|x| x.to_string()),
        );
        let cfg = RobustnessSweepConfig::from_args(&args);
        assert_eq!(cfg.strategies, vec!["jit", "async-stale"]);
        assert_eq!(cfg.scenarios, vec!["baseline", "nope"]);
        assert_eq!((cfg.n_parties, cfg.rounds, cfg.dim, cfg.seed), (4, 2, 16, 7));
        let (_t, json) = run_sweep(&cfg);
        let bad = cell(&json, "jit", "nope");
        assert!(bad.get("error").as_str().unwrap().contains("unknown scenario"));
        // the well-formed cells still ran
        assert!(cell(&json, "jit", "baseline").get("error").as_str().is_none());
    }
}
