//! Multi-tenant broker sweep: one job-arrival trace replayed under every
//! cross-job arbitration policy on the same shared cluster.
//!
//! Reports, per policy: cluster utilization, total container-seconds,
//! peak job concurrency, mean admission queue wait, and per-job
//! round-latency inflation vs an uncontended solo run. Dumped as
//! `BENCH_broker.json` (CLI `fljit broker`, bench binary `broker_sweep`,
//! and a small-grid smoke under `cargo test`).

use crate::broker::admission::AdmissionConfig;
use crate::broker::arbitration;
use crate::broker::workload::{poisson_trace, JobTrace, TraceConfig};
use crate::coordinator::job::FlJobSpec;
use crate::coordinator::session::Session;
use crate::party::FleetKind;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::table::Table;

/// Sweep shape knobs (CLI flags map 1:1).
#[derive(Clone, Debug)]
pub struct SweepConfig {
    pub jobs: usize,
    /// Largest fleet allowed in the trace (10k = the paper's top scale).
    pub max_parties: usize,
    /// Upper bound on per-job rounds (lower bound stays 2).
    pub rounds: u32,
    /// Shared cluster container capacity — deliberately below the sum of
    /// peak gang sizes so arbitration has something to arbitrate.
    pub capacity: usize,
    /// Admission budget as a multiple of capacity (statistical overcommit
    /// of short-lived JIT gangs; jobs beyond it queue).
    pub admission_overcommit: f64,
    pub seed: u64,
    /// Run each job solo too (latency-inflation baseline).
    pub with_solo: bool,
    /// Pin job 0 to `max_parties` so the top-scale cell is always present.
    pub pin_large: bool,
    pub mean_interarrival_secs: f64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig {
            jobs: 12,
            max_parties: 10_000,
            rounds: 5,
            capacity: 96,
            admission_overcommit: 4.0,
            seed: 0xB40C,
            with_solo: true,
            pin_large: true,
            mean_interarrival_secs: 30.0,
        }
    }
}

impl SweepConfig {
    /// Single flag mapping shared by the `fljit broker` CLI subcommand
    /// and the `broker_sweep` bench binary, so the two can't drift.
    pub fn from_args(args: &Args) -> SweepConfig {
        let d = SweepConfig::default();
        SweepConfig {
            jobs: args.get_usize("jobs", d.jobs),
            max_parties: args.get_usize("max-parties", d.max_parties),
            rounds: args.get_u64("rounds", d.rounds as u64) as u32,
            capacity: args.get_usize("capacity", d.capacity),
            admission_overcommit: args.get_f64("overcommit", d.admission_overcommit),
            seed: args.get_u64("seed", d.seed),
            with_solo: !args.get_bool("no-solo"),
            pin_large: !args.get_bool("no-pin-large"),
            mean_interarrival_secs: args
                .get_f64("interarrival", d.mean_interarrival_secs),
        }
    }
}

/// Build the sweep's arrival trace (deterministic in the seed).
pub fn build_trace(cfg: &SweepConfig) -> JobTrace {
    let mut party_mix: Vec<(usize, f64)> = [(10, 0.4), (100, 0.3), (1000, 0.2), (10_000, 0.1)]
        .into_iter()
        .filter(|&(n, _)| n <= cfg.max_parties)
        .collect();
    if party_mix.is_empty() {
        party_mix = vec![(cfg.max_parties.max(2), 1.0)];
    }
    let tc = TraceConfig {
        n_jobs: cfg.jobs,
        mean_interarrival_secs: cfg.mean_interarrival_secs,
        party_mix,
        rounds_lo: 2,
        rounds_hi: cfg.rounds.max(2),
        seed: cfg.seed,
        ..Default::default()
    };
    let mut trace = poisson_trace(&tc);
    if cfg.pin_large {
        if let Some(a) = trace.arrivals.first_mut() {
            let mut spec = FlJobSpec::new(
                a.spec.workload.clone(),
                FleetKind::ActiveHeterogeneous,
                cfg.max_parties,
                a.spec.rounds,
            );
            spec.t_wait_secs = a.spec.t_wait_secs;
            spec.name = format!("job0-pinned-{}", spec.name);
            a.spec = spec;
        }
    }
    trace
}

fn admission_budget(cfg: &SweepConfig) -> usize {
    ((cfg.capacity as f64) * cfg.admission_overcommit.max(1.0)).round() as usize
}

/// Run the sweep: same trace under each arbitration policy.
pub fn run_sweep(cfg: &SweepConfig) -> (Vec<Table>, Json) {
    let trace = build_trace(cfg);
    let mut tables = Vec::new();
    let mut policies_json = Vec::new();
    let mut summary = Table::new(
        &format!(
            "broker sweep — {} jobs (max {} parties) on {} containers",
            trace.len(),
            trace.max_parties(),
            cfg.capacity
        ),
        &[
            "policy",
            "util %",
            "total cs",
            "peak jobs",
            "mean queue wait (s)",
            "mean latency inflation",
        ],
    );
    for &policy in arbitration::all_policies() {
        let rep = Session::sim()
            .trace(&trace)
            .policy(policy)
            .admission(AdmissionConfig {
                budget: admission_budget(cfg),
                max_jobs: 0,
                autoscale: None,
            })
            .capacity(cfg.capacity)
            .seed(cfg.seed)
            .solo_baselines(cfg.with_solo)
            .run()
            .unwrap_or_else(|e| panic!("policy {policy}: {e:#}"));
        let sum = rep.summary();
        let mut t = Table::new(
            &format!("broker sweep — policy '{policy}'"),
            &[
                "job",
                "class",
                "parties",
                "arrive (s)",
                "queue wait (s)",
                "mean lat (s)",
                "inflation",
                "cs",
            ],
        );
        for o in &sum.jobs {
            t.row(vec![
                o.name.clone(),
                o.class.name().to_string(),
                o.parties.to_string(),
                format!("{:.1}", o.arrival_secs),
                format!("{:.1}", o.queue_wait_secs),
                format!("{:.3}", o.mean_latency_secs()),
                match o.latency_inflation() {
                    Some(v) => format!("{v:.2}x"),
                    None => "-".to_string(),
                },
                format!("{:.1}", o.container_seconds),
            ]);
        }
        tables.push(t);
        summary.row(vec![
            policy.to_string(),
            format!("{:.1}", sum.cluster_utilization * 100.0),
            format!("{:.1}", sum.total_container_seconds),
            sum.max_concurrent_jobs().to_string(),
            format!("{:.1}", sum.mean_queue_wait_secs()),
            match sum.mean_latency_inflation() {
                Some(v) => format!("{v:.2}x"),
                None => "-".to_string(),
            },
        ]);
        policies_json.push(rep.to_json());
    }
    tables.push(summary);
    let json = Json::obj(vec![
        ("bench", Json::str("broker_sweep")),
        ("jobs", Json::num(trace.len() as f64)),
        ("max_parties", Json::num(trace.max_parties() as f64)),
        ("capacity", Json::num(cfg.capacity as f64)),
        ("admission_budget", Json::num(admission_budget(cfg) as f64)),
        ("seed", Json::num(cfg.seed as f64)),
        ("policies", Json::Arr(policies_json)),
    ]);
    (tables, json)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn per_job_cs(policy: &Json) -> Vec<f64> {
        policy
            .get("jobs")
            .as_arr()
            .unwrap()
            .iter()
            .map(|j| j.get("container_seconds").as_f64().unwrap())
            .collect()
    }

    /// The acceptance-criteria sweep at small grid: a 10k-party job among
    /// ≥8 concurrent jobs on a scarce cluster, run to completion under
    /// every policy, emitting BENCH_broker.json.
    #[test]
    fn small_grid_10k_party_8_job_sweep() {
        let cfg = SweepConfig {
            jobs: 8,
            max_parties: 10_000,
            rounds: 2,
            capacity: 64,
            admission_overcommit: 6.0,
            seed: 11,
            with_solo: false,
            pin_large: true,
            mean_interarrival_secs: 3.0,
        };
        let (tables, json) = run_sweep(&cfg);
        crate::bench::dump("BENCH_broker", &json);
        assert_eq!(tables.len(), 4, "three policy tables + summary");
        let pols = json.get("policies").as_arr().unwrap().to_vec();
        assert_eq!(pols.len(), 3);
        for p in &pols {
            let jobs = p.get("jobs").as_arr().unwrap();
            assert_eq!(jobs.len(), 8, "every job reported");
            for j in jobs {
                let rounds = j.get("rounds").as_u64().unwrap();
                assert!(rounds >= 2, "job must finish its rounds");
            }
            assert!(p.get("cluster_utilization").as_f64().unwrap() > 0.0);
            // ≥8 jobs live at once (arrivals are bunched vs job duration)
            let peak = p.get("max_concurrent_jobs").as_u64().unwrap();
            assert!(peak >= 8, "expected ≥8 concurrent jobs, peak={peak}");
            // the pinned 10k-party job is present
            let top = jobs
                .iter()
                .map(|j| j.get("parties").as_u64().unwrap())
                .max()
                .unwrap();
            assert_eq!(top, 10_000);
        }
        // deadline-priority vs weighted-fair-share: measurably different
        // per-job container-second allocations on the same trace
        let deadline = per_job_cs(&pols[0]);
        let wfs = per_job_cs(&pols[2]);
        let delta: f64 = deadline
            .iter()
            .zip(&wfs)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(
            delta > 1e-6,
            "deadline vs wfs should allocate container-seconds differently (Δ={delta})"
        );
    }

    #[test]
    fn build_trace_pins_and_caps_party_counts() {
        let cfg = SweepConfig {
            jobs: 6,
            max_parties: 100,
            seed: 3,
            ..Default::default()
        };
        let t = build_trace(&cfg);
        assert_eq!(t.len(), 6);
        assert_eq!(t.max_parties(), 100, "pinned job at the cap");
        assert!(t.arrivals.iter().all(|a| a.spec.n_parties <= 100));
        // deterministic
        let t2 = build_trace(&cfg);
        assert_eq!(t.arrivals[3].spec.name, t2.arrivals[3].spec.name);
    }
}
