//! Live multi-tenancy sweep — the §6.3 broker job mix replayed on the
//! *live* platform (wall-clock driver, per-job MQ topics, real data-plane
//! folds) instead of virtual time.
//!
//! One deterministic [`JobTrace`] is replayed under one or every
//! cross-job arbitration policy: jobs arrive at their trace times, pass
//! admission control, share one emulated cluster whose starts *and
//! preemptions* follow the policy, and each fold real updates into their
//! own model topic. Reports per job: admission queue wait, mean
//! aggregation latency, busy (container) seconds, deployments and fold
//! counts — the decision inputs for picking a default arbitration policy
//! (see EXPERIMENTS.md "Live multi-tenancy"). Dumped as
//! `BENCH_live_broker.json` via `fljit live-broker` and the tiny-grid CI
//! smoke; the sim-side analogue is `bench::broker` (`BENCH_broker.json`).

use anyhow::{Context, Result};

use crate::broker::admission::AdmissionConfig;
use crate::broker::arbitration;
use crate::broker::workload::{poisson_trace, JobTrace, TraceConfig};
use crate::coordinator::live::PartyBackend;
use crate::coordinator::session::{Session, SessionEvent};
use crate::telemetry::{export, Registry};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::wal::FsyncPolicy;

/// Sweep shape knobs (CLI flags map 1:1).
#[derive(Clone, Debug)]
pub struct LiveBrokerSweepConfig {
    pub jobs: usize,
    /// Upper bound on per-job rounds (lower bound stays 2).
    pub rounds: u32,
    /// Largest fleet drawn into the generated trace.
    pub max_parties: usize,
    /// Shared cluster container capacity.
    pub capacity: usize,
    /// Admission budget (committed container demand; jobs beyond it queue).
    pub budget: usize,
    pub mean_interarrival_secs: f64,
    pub seed: u64,
    /// Update vector length of every job's live data plane.
    pub dim: usize,
    /// One policy name, or `"all"` to sweep every policy over the trace.
    pub policy: String,
    /// Replay a persisted trace (`JobTrace::save` format) instead of
    /// generating one.
    pub trace_path: Option<String>,
    /// Persist the (generated or loaded) trace for later replays/resumes.
    pub save_trace: Option<String>,
    /// Pace on the real wall clock (slow) instead of the instant clock.
    pub wall: bool,
    /// When set, stream telemetry spans into `<dir>/telemetry.jsonl`
    /// during the sweep and write the exposition + Chrome trace after it.
    pub telemetry_dir: Option<String>,
    /// Durable data plane: persist the session's MQ to this dir
    /// (single-policy sweeps only — policies must not share one log).
    pub data_dir: Option<String>,
    /// Fsync policy for `data_dir`.
    pub fsync: FsyncPolicy,
    /// Resume a killed durable run from `data_dir`'s log.
    pub resume: bool,
    /// Durable-log replay bench: GB of inline updates to append + scan
    /// per fsync policy (0 = skip; rows land in the JSON dump).
    pub replay_gb: f64,
    /// Update vector length of the replay bench's synthetic records.
    pub replay_dim: usize,
}

impl Default for LiveBrokerSweepConfig {
    fn default() -> Self {
        LiveBrokerSweepConfig {
            jobs: 4,
            rounds: 2,
            max_parties: 8,
            capacity: 4,
            budget: 8,
            mean_interarrival_secs: 5.0,
            seed: 0xB40C,
            dim: 32,
            policy: "all".to_string(),
            trace_path: None,
            save_trace: None,
            wall: false,
            telemetry_dir: None,
            data_dir: None,
            fsync: FsyncPolicy::default(),
            resume: false,
            replay_gb: 0.0,
            replay_dim: 4096,
        }
    }
}

impl LiveBrokerSweepConfig {
    /// Single flag mapping shared by the `fljit live-broker` CLI
    /// subcommand and tests, so the two can't drift.
    pub fn from_args(args: &Args) -> LiveBrokerSweepConfig {
        let d = LiveBrokerSweepConfig::default();
        LiveBrokerSweepConfig {
            jobs: args.get_usize("jobs", d.jobs),
            rounds: args.get_u64("rounds", d.rounds as u64) as u32,
            max_parties: args.get_usize("max-parties", d.max_parties),
            capacity: args.get_usize("capacity", d.capacity),
            budget: args.get_usize("budget", d.budget),
            mean_interarrival_secs: args.get_f64("interarrival", d.mean_interarrival_secs),
            seed: args.get_u64("seed", d.seed),
            dim: args.get_usize("dim", d.dim),
            policy: args.get_or("policy", &d.policy).to_string(),
            trace_path: args.get("trace").map(|s| s.to_string()),
            save_trace: args.get("save-trace").map(|s| s.to_string()),
            wall: args.get_bool("wall"),
            telemetry_dir: args.get("telemetry-dir").map(|s| s.to_string()),
            data_dir: args.get("data-dir").map(|s| s.to_string()),
            fsync: args
                .get("fsync")
                .and_then(|s| FsyncPolicy::parse(s).ok())
                .unwrap_or_default(),
            resume: args.get_bool("resume"),
            replay_gb: args.get_f64("replay-gb", d.replay_gb),
            replay_dim: args.get_usize("replay-dim", d.replay_dim),
        }
    }

    fn session(&self, trace: &JobTrace, policy: &str) -> Session {
        let s = if self.wall {
            // scripted even at --jobs 1: the sweep is a *trace replay*,
            // wall mode only changes the pacing, never the party model
            Session::wall().backend(PartyBackend::Scripted)
        } else {
            Session::live()
        };
        let mut s = s
            .trace(trace)
            .policy(policy)
            .admission(AdmissionConfig {
                budget: self.budget.max(1),
                max_jobs: 0,
                autoscale: None,
            })
            .capacity(self.capacity)
            .seed(self.seed)
            .dim(self.dim);
        if let Some(dir) = &self.data_dir {
            s = s.data_dir(dir).fsync(self.fsync);
        }
        if self.resume {
            s = s.resume(true);
        }
        s
    }
}

/// The sweep's arrival trace: loaded from disk when `--trace` is given,
/// otherwise generated deterministically from the seed (small fleets —
/// the live path folds real vectors per update).
pub fn build_trace(cfg: &LiveBrokerSweepConfig) -> Result<JobTrace> {
    if let Some(path) = &cfg.trace_path {
        return JobTrace::load(std::path::Path::new(path)).context("loading --trace");
    }
    let hi = cfg.max_parties.max(2);
    let lo = (hi / 2).max(2);
    Ok(poisson_trace(&TraceConfig {
        n_jobs: cfg.jobs.max(1),
        mean_interarrival_secs: cfg.mean_interarrival_secs,
        party_mix: vec![(lo, 0.5), (hi, 0.5)],
        intermittent_frac: 0.25,
        rounds_lo: 2,
        rounds_hi: cfg.rounds.max(2),
        t_wait_secs: 60.0,
        seed: cfg.seed,
        ..Default::default()
    }))
}

/// Replay the trace under the requested policy (or all of them); one
/// per-policy table, a cross-policy summary, and the JSON dump rows
/// (the unified `Report::to_json` schema). Preemption counts come from
/// the streaming [`SessionEvent`] channel.
pub fn run_sweep(cfg: &LiveBrokerSweepConfig) -> Result<(Vec<Table>, Json)> {
    let policies: Vec<String> = if cfg.policy == "all" {
        arbitration::all_policies()
            .iter()
            .map(|s| s.to_string())
            .collect()
    } else {
        vec![cfg.policy.clone()]
    };
    if cfg.data_dir.is_some() && policies.len() > 1 {
        anyhow::bail!(
            "--data-dir needs a single --policy: swept policies replay the \
             same trace and would interleave into one durable log"
        );
    }
    let trace = build_trace(cfg)?;
    if let Some(path) = &cfg.save_trace {
        trace
            .save(std::path::Path::new(path))
            .context("writing --save-trace")?;
    }
    // One registry shared across all swept policies: the per-strategy /
    // per-job label scopes keep the series apart, and the JSONL stream
    // captures the whole sweep as a single timeline.
    let telemetry = match &cfg.telemetry_dir {
        Some(dir) => Registry::with_dir(dir).context("opening --telemetry-dir")?,
        None => Registry::disabled(),
    };
    let mut tables = Vec::new();
    let mut policies_json = Vec::new();
    let mut summary = Table::new(
        &format!(
            "live broker sweep — {} jobs on {} containers (dim {}, {})",
            trace.len(),
            cfg.capacity,
            cfg.dim,
            if cfg.wall { "wall clock" } else { "instant clock" }
        ),
        &[
            "policy",
            "util %",
            "total cs",
            "peak jobs",
            "preempts",
            "mean queue wait (s)",
            "folds",
        ],
    );
    for policy in &policies {
        let mut s = cfg.session(&trace, policy).telemetry(&telemetry);
        let events = s.events();
        let rep = s.run().with_context(|| format!("policy {policy}"))?;
        let preempts = events
            .try_iter()
            .filter(|e| matches!(e, SessionEvent::Preempted { .. }))
            .count();
        let sum = rep.summary();
        let mut t = Table::new(
            &format!("live broker — policy '{policy}'"),
            &[
                "job",
                "class",
                "arrive (s)",
                "queue wait (s)",
                "mean lat (ms)",
                "busy (cs)",
                "deploys",
                "folds",
            ],
        );
        for o in &sum.jobs {
            t.row(vec![
                o.name.clone(),
                o.class.name().to_string(),
                format!("{:.1}", o.arrival_secs),
                format!("{:.1}", o.queue_wait_secs),
                format!("{:.1}", o.mean_latency_secs() * 1e3),
                format!("{:.2}", o.container_seconds),
                o.deployments.to_string(),
                o.updates_folded.to_string(),
            ]);
        }
        tables.push(t);
        summary.row(vec![
            policy.clone(),
            format!("{:.1}", sum.cluster_utilization * 100.0),
            format!("{:.1}", sum.total_container_seconds),
            sum.max_concurrent_jobs().to_string(),
            preempts.to_string(),
            format!("{:.1}", sum.mean_queue_wait_secs()),
            sum.updates_folded.to_string(),
        ]);
        policies_json.push(rep.to_json());
    }
    tables.push(summary);
    if let Some(dir) = &cfg.telemetry_dir {
        export::write_all(&telemetry, dir).context("writing telemetry exports")?;
    }
    let mut fields = vec![
        ("bench", Json::str("live_broker")),
        ("jobs", Json::num(trace.len() as f64)),
        ("capacity", Json::num(cfg.capacity as f64)),
        ("budget", Json::num(cfg.budget as f64)),
        ("seed", Json::num(cfg.seed as f64)),
        ("dim", Json::num(cfg.dim as f64)),
        ("wall", Json::Bool(cfg.wall)),
        ("policies", Json::Arr(policies_json)),
    ];
    if cfg.replay_gb > 0.0 {
        let (t, rows) = replay_bench(cfg.replay_gb, cfg.replay_dim)?;
        tables.push(t);
        fields.push(("replay", rows));
    }
    let json = Json::obj(fields);
    Ok((tables, json))
}

/// Durable-log replay bench: per fsync policy, append `gb` GB of
/// synthetic inline updates (vectors of `dim` f32s) to a fresh WAL, then
/// reopen it and time the recovery scan. The append column is the
/// fsync-policy trade-off the EXPERIMENTS table documents; the scan
/// column is pure sequential mmap read and should be policy-independent.
/// The multi-GB temp dirs are deleted before returning.
pub fn replay_bench(gb: f64, dim: usize) -> Result<(Table, Json)> {
    use crate::mq::{Message, Payload};
    use crate::wal::{RecordRef, Wal, WalConfig};
    let dim = dim.max(1);
    let policies = [
        FsyncPolicy::Always,
        FsyncPolicy::EveryN(256),
        FsyncPolicy::OsOnly,
    ];
    let target_bytes = (gb * 1e9) as u64;
    let mut t = Table::new(
        &format!("durable-log replay bench — {gb} GB of dim-{dim} updates per policy"),
        &[
            "fsync",
            "records",
            "segments",
            "fsyncs",
            "append (s)",
            "append MB/s",
            "scan (s)",
            "scan MB/s",
        ],
    );
    let mut rows = Vec::new();
    for policy in policies {
        let dir = std::env::temp_dir().join(format!(
            "fljit_replay_{}_{}",
            std::process::id(),
            policy.name().replace('=', "")
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let (wal, _, _) = Wal::open(WalConfig::new(&dir).fsync(policy))
            .context("opening replay-bench WAL")?;
        let data: Vec<f32> = (0..dim).map(|i| i as f32 * 0.5).collect();
        let mut written = 0u64;
        let mut records = 0u64;
        let t0 = std::time::Instant::now();
        while written < target_bytes {
            let msg = Message {
                party: (records % 97) as usize,
                round: (records / 97) as u32,
                weight: 1.0,
                enqueued_at: records,
                payload: Payload::Inline(data.clone()),
            };
            let info = wal
                .append(RecordRef::Produce {
                    topic: "replay/updates",
                    msg: &msg,
                })
                .context("replay-bench append")?;
            written += info.bytes as u64;
            records += 1;
        }
        wal.flush().context("replay-bench flush")?;
        let stats = wal.stats();
        let append_secs = t0.elapsed().as_secs_f64();
        drop(wal);
        let t1 = std::time::Instant::now();
        let (reopened, recovered, report) =
            Wal::open(WalConfig::new(&dir).fsync(policy)).context("replay-bench reopen")?;
        let scan_secs = t1.elapsed().as_secs_f64();
        anyhow::ensure!(
            recovered.len() as u64 == records && !report.torn_tail,
            "replay bench lost records: wrote {records}, recovered {} (torn={})",
            recovered.len(),
            report.torn_tail
        );
        drop(recovered);
        drop(reopened);
        let _ = std::fs::remove_dir_all(&dir);
        let mb = written as f64 / 1e6;
        t.row(vec![
            policy.name(),
            records.to_string(),
            stats.segments.to_string(),
            stats.fsyncs.to_string(),
            format!("{append_secs:.2}"),
            format!("{:.1}", mb / append_secs.max(1e-9)),
            format!("{scan_secs:.2}"),
            format!("{:.1}", mb / scan_secs.max(1e-9)),
        ]);
        rows.push(Json::obj(vec![
            ("fsync", Json::str(&policy.name())),
            ("records", Json::num(records as f64)),
            ("bytes", Json::num(written as f64)),
            ("segments", Json::num(stats.segments as f64)),
            ("fsyncs", Json::num(stats.fsyncs as f64)),
            ("append_secs", Json::num(append_secs)),
            ("append_mb_per_sec", Json::num(mb / append_secs.max(1e-9))),
            ("scan_secs", Json::num(scan_secs)),
            ("scan_mb_per_sec", Json::num(mb / scan_secs.max(1e-9))),
        ]));
    }
    Ok((t, Json::Arr(rows)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_sweep_covers_all_policies_and_dumps_json() {
        let cfg = LiveBrokerSweepConfig {
            jobs: 2,
            max_parties: 4,
            capacity: 2,
            budget: 4,
            mean_interarrival_secs: 2.0,
            seed: 13,
            dim: 16,
            ..Default::default()
        };
        let (tables, json) = run_sweep(&cfg).expect("sweep");
        assert_eq!(tables.len(), 4, "three policy tables + summary");
        let pols = json.get("policies").as_arr().unwrap();
        assert_eq!(pols.len(), 3);
        for p in pols {
            let jobs = p.get("jobs").as_arr().unwrap();
            assert_eq!(jobs.len(), 2, "every job reported");
            for j in jobs {
                assert!(
                    j.get("rounds").as_u64().unwrap() >= 2,
                    "job must finish its rounds"
                );
                assert!(j.get("updates_folded").as_u64().unwrap() > 0);
            }
            assert!(p.get("cluster_utilization").as_f64().unwrap() > 0.0);
        }
        crate::bench::dump("BENCH_live_broker", &json);
        let text = std::fs::read_to_string(
            crate::bench::repro_dir().join("BENCH_live_broker.json"),
        )
        .unwrap();
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn build_trace_loads_and_saves_round_trips() {
        let dir = std::env::temp_dir().join("fljit_live_broker_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        let cfg = LiveBrokerSweepConfig {
            jobs: 3,
            seed: 21,
            save_trace: Some(path.to_string_lossy().to_string()),
            ..Default::default()
        };
        // generating with --save-trace persists the trace…
        let (_, _) = run_sweep(&LiveBrokerSweepConfig {
            policy: "deadline".to_string(),
            ..cfg.clone()
        })
        .expect("sweep with save");
        // …and --trace replays the identical job mix
        let loaded = build_trace(&LiveBrokerSweepConfig {
            trace_path: Some(path.to_string_lossy().to_string()),
            ..LiveBrokerSweepConfig::default()
        })
        .expect("load");
        let generated = build_trace(&cfg).expect("generate");
        assert_eq!(loaded.len(), generated.len());
        for (a, b) in loaded.arrivals.iter().zip(&generated.arrivals) {
            assert_eq!(a.at_secs.to_bits(), b.at_secs.to_bits());
            assert_eq!(a.spec.name, b.spec.name);
            assert_eq!(a.strategy, b.strategy);
        }
        assert!(build_trace(&LiveBrokerSweepConfig {
            trace_path: Some(dir.join("missing.json").to_string_lossy().to_string()),
            ..LiveBrokerSweepConfig::default()
        })
        .is_err());
    }

    #[test]
    fn durable_sweep_needs_single_policy_and_persists() {
        let dir = std::env::temp_dir().join(format!("fljit_lb_durable_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = LiveBrokerSweepConfig {
            jobs: 2,
            max_parties: 4,
            capacity: 2,
            budget: 4,
            mean_interarrival_secs: 2.0,
            seed: 13,
            dim: 16,
            data_dir: Some(dir.to_string_lossy().to_string()),
            ..Default::default()
        };
        assert!(run_sweep(&cfg).is_err(), "policy 'all' must not share one log");
        let one = LiveBrokerSweepConfig {
            policy: "deadline".to_string(),
            ..cfg.clone()
        };
        run_sweep(&one).expect("durable single-policy sweep");
        // the data plane survives the sweep: reopening replays its topics
        let q = crate::mq::MessageQueue::durable(crate::wal::WalConfig::new(&dir))
            .expect("reopen");
        assert!(q.produced() > 0, "replay restored the sweep's messages");
        assert!(!q.topic_names().is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn replay_bench_rows_cover_every_fsync_policy() {
        // tiny: 2 MB per policy — the CI-scale invocation
        let (_, rows) = replay_bench(0.002, 64).expect("replay bench");
        let rows = rows.as_arr().unwrap().clone();
        assert_eq!(rows.len(), 3, "always, every=256, os");
        for r in &rows {
            assert!(r.get("records").as_f64().unwrap() > 0.0);
            assert!(r.get("append_mb_per_sec").as_f64().unwrap() > 0.0);
            assert!(r.get("scan_mb_per_sec").as_f64().unwrap() > 0.0);
        }
        assert!(
            rows[0].get("fsyncs").as_f64().unwrap() > rows[2].get("fsyncs").as_f64().unwrap(),
            "fsync=always must sync more often than fsync=os"
        );
    }

    #[test]
    fn unknown_policy_is_rejected() {
        let cfg = LiveBrokerSweepConfig {
            jobs: 2,
            policy: "bogus".to_string(),
            ..Default::default()
        };
        assert!(run_sweep(&cfg).is_err());
    }
}
