//! Adaptive-JIT regret sweep — learned deadlines vs the fixed estimator.
//!
//! Runs the identical scripted live job (instant clock, MQ data plane)
//! under each `(scenario, mode)` cell, where the scenario is a shifting
//! [`FleetFaults`] preset (stragglers and diurnal waves by default — the
//! regimes where the Fig 6 estimator's fixed deadline is most wrong) and
//! the mode is `fixed` (the estimator's `t_rnd − t_agg·(1+margin)` fuse
//! deadline, exactly as every prior PR ran it) or `adaptive`
//! ([`AdaptiveConfig::on`]: the [`crate::adapt`] sketch learns the
//! arrival-lag distribution online and re-arms the deadline, restores
//! degraded quorums, and autoscales admission).
//!
//! Per cell it reports the engine's degradation counters, mean round
//! latency, aggregation container-seconds (the resource axis), and
//! fidelity — L2 distance of the cell's final global model to the same
//! strategy's fault-free final model (the robustness-matrix metric).
//!
//! The dump embeds the PR's acceptance check (`regret_check`): per
//! scenario, adaptive must cut **no more** updates than fixed (the
//! learned deadline only ever extends past the fixed one, so
//! deadline-missers can only shrink), with the resource and fidelity
//! comparisons recorded alongside. Dumped to `BENCH_adaptive.json` via
//! `fljit adaptive`.

use crate::adapt::AdaptiveConfig;
use crate::coordinator::job::FlJobSpec;
use crate::coordinator::session::Session;
use crate::party::{FleetFaults, FleetKind};
use crate::util::json::Json;
use crate::util::table::Table;
use crate::workloads::Workload;

#[derive(Clone, Debug)]
pub struct AdaptiveSweepConfig {
    pub n_parties: usize,
    pub rounds: u32,
    pub seed: u64,
    pub dim: usize,
    /// Mean synthetic epoch time (virtual seconds under the instant
    /// clock; the straggler cutoff scales from it).
    pub epoch_secs: f64,
    /// Strategy under test (any deadline-timer strategy; default `jit`).
    pub strategy: String,
    /// Scenario names to sweep (default: the two shifting-arrival
    /// regimes the adaptive policy targets).
    pub scenarios: Vec<String>,
}

impl Default for AdaptiveSweepConfig {
    fn default() -> Self {
        AdaptiveSweepConfig {
            n_parties: 10,
            rounds: 4,
            seed: 42,
            dim: 64,
            epoch_secs: 0.4,
            strategy: "jit".to_string(),
            scenarios: vec!["stragglers".to_string(), "diurnal".to_string()],
        }
    }
}

impl AdaptiveSweepConfig {
    pub fn from_args(args: &crate::util::cli::Args) -> AdaptiveSweepConfig {
        let d = AdaptiveSweepConfig::default();
        let scenarios = match args.get("scenarios") {
            Some(s) => s
                .split(',')
                .map(|x| x.trim().to_string())
                .filter(|x| !x.is_empty())
                .collect(),
            None => d.scenarios,
        };
        AdaptiveSweepConfig {
            n_parties: args.get_usize("parties", d.n_parties),
            rounds: args.get_u64("rounds", d.rounds as u64) as u32,
            seed: args.get_u64("seed", d.seed),
            dim: args.get_usize("dim", d.dim),
            epoch_secs: args.get_f64("epoch-secs", d.epoch_secs),
            strategy: args
                .get("strategy")
                .map(|s| s.to_string())
                .unwrap_or(d.strategy),
            scenarios,
        }
    }
}

/// One cell's raw outcome.
#[derive(Clone, Debug)]
struct Cell {
    rounds_done: usize,
    rounds_skipped: u32,
    mean_latency_secs: f64,
    container_seconds: f64,
    updates_fused: u64,
    updates_dropped: usize,
    updates_decayed: usize,
    final_model: Vec<f32>,
}

fn l2(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

fn run_cell(
    cfg: &AdaptiveSweepConfig,
    faults: FleetFaults,
    adaptive: AdaptiveConfig,
) -> Result<Cell, String> {
    let mut workload = Workload::mlp_live();
    workload.base_epoch_secs = cfg.epoch_secs;
    let spec = FlJobSpec::new(
        workload,
        FleetKind::ActiveHomogeneous,
        cfg.n_parties,
        cfg.rounds,
    );
    let mut s = Session::live()
        .seed(cfg.seed)
        .dim(cfg.dim)
        .faults(faults)
        .adaptive(adaptive);
    s.job(spec, &cfg.strategy);
    let rep = s.run().map_err(|e| format!("{e:#}"))?;
    let o = rep.single();
    Ok(Cell {
        rounds_done: o.records.len(),
        rounds_skipped: o.rounds_skipped,
        mean_latency_secs: o.mean_latency_secs(),
        container_seconds: o.total_container_seconds(),
        updates_fused: o.updates_fused,
        updates_dropped: o.updates_dropped,
        updates_decayed: o.updates_decayed,
        final_model: o.final_model.clone(),
    })
}

/// Run the scenario × {fixed, adaptive} grid; table + JSON with the
/// embedded regret check.
pub fn run_sweep(cfg: &AdaptiveSweepConfig) -> (Table, Json) {
    let mut t = Table::new(
        &format!(
            "adaptive regret sweep — {} × {} parties × {} rounds, dim {}, seed {}",
            cfg.strategy, cfg.n_parties, cfg.rounds, cfg.dim, cfg.seed
        ),
        &[
            "scenario",
            "mode",
            "rounds",
            "skipped",
            "mean lat (ms)",
            "agg cont-s",
            "dropped",
            "decayed",
            "fidelity (L2)",
        ],
    );
    // the fidelity reference: the strategy's fault-free run (the learned
    // deadline cannot change a healthy-fleet outcome — rounds fuse on
    // full arrival, never on the timer — so one reference serves both
    // modes)
    let base = run_cell(cfg, FleetFaults::none(), AdaptiveConfig::none());
    let mut cells = Vec::new();
    let mut checks = Vec::new();
    for scenario in &cfg.scenarios {
        let Some(faults) = FleetFaults::scenario(scenario, cfg.epoch_secs) else {
            cells.push(Json::obj(vec![
                ("scenario", Json::str(scenario)),
                ("error", Json::str(&format!("unknown scenario {scenario:?}"))),
            ]));
            t.row(vec![
                scenario.clone(),
                "?".into(),
                format!("failed: unknown scenario {scenario:?}"),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
            ]);
            continue;
        };
        let mut by_mode = Vec::new();
        for (mode, acfg) in [
            ("fixed", AdaptiveConfig::none()),
            ("adaptive", AdaptiveConfig::on()),
        ] {
            let outcome = run_cell(cfg, faults.clone(), acfg);
            match outcome {
                Ok(c) => {
                    let fidelity = base
                        .as_ref()
                        .ok()
                        .map(|b| l2(&c.final_model, &b.final_model));
                    t.row(vec![
                        scenario.clone(),
                        mode.to_string(),
                        c.rounds_done.to_string(),
                        c.rounds_skipped.to_string(),
                        format!("{:.1}", c.mean_latency_secs * 1e3),
                        format!("{:.2}", c.container_seconds),
                        c.updates_dropped.to_string(),
                        c.updates_decayed.to_string(),
                        fidelity.map(|x| format!("{x:.4}")).unwrap_or_default(),
                    ]);
                    cells.push(Json::obj(vec![
                        ("scenario", Json::str(scenario)),
                        ("mode", Json::str(mode)),
                        ("rounds_done", Json::num(c.rounds_done as f64)),
                        ("rounds_skipped", Json::num(c.rounds_skipped as f64)),
                        ("mean_latency_secs", Json::num(c.mean_latency_secs)),
                        ("container_seconds", Json::num(c.container_seconds)),
                        ("updates_fused", Json::num(c.updates_fused as f64)),
                        ("updates_dropped", Json::num(c.updates_dropped as f64)),
                        ("updates_decayed", Json::num(c.updates_decayed as f64)),
                        (
                            "fidelity_l2",
                            fidelity.map(Json::num).unwrap_or(Json::Null),
                        ),
                    ]));
                    by_mode.push((mode, c, fidelity));
                }
                Err(e) => {
                    t.row(vec![
                        scenario.clone(),
                        mode.to_string(),
                        format!("failed: {e}"),
                        String::new(),
                        String::new(),
                        String::new(),
                        String::new(),
                        String::new(),
                        String::new(),
                    ]);
                    cells.push(Json::obj(vec![
                        ("scenario", Json::str(scenario)),
                        ("mode", Json::str(mode)),
                        ("error", Json::str(&e)),
                    ]));
                }
            }
        }
        // the embedded acceptance check, per scenario: the learned
        // deadline only ever extends past the fixed one (round-start max,
        // re-arm floored at the fixed defer), so adaptive can never cut
        // more deadline-missers than fixed; resource and fidelity are
        // recorded alongside for the regret accounting
        if let [(_, f, f_fid), (_, a, a_fid)] = &by_mode[..] {
            checks.push(Json::obj(vec![
                ("scenario", Json::str(scenario)),
                ("fixed_dropped", Json::num(f.updates_dropped as f64)),
                ("adaptive_dropped", Json::num(a.updates_dropped as f64)),
                (
                    "adaptive_dropped_le_fixed",
                    Json::Bool(a.updates_dropped <= f.updates_dropped),
                ),
                ("fixed_container_seconds", Json::num(f.container_seconds)),
                ("adaptive_container_seconds", Json::num(a.container_seconds)),
                (
                    "adaptive_resource_le_fixed",
                    Json::Bool(a.container_seconds <= f.container_seconds * 1.001 + 1e-9),
                ),
                (
                    "fixed_fidelity_l2",
                    f_fid.map(Json::num).unwrap_or(Json::Null),
                ),
                (
                    "adaptive_fidelity_l2",
                    a_fid.map(Json::num).unwrap_or(Json::Null),
                ),
                (
                    "adaptive_fidelity_le_fixed",
                    match (f_fid, a_fid) {
                        (Some(f), Some(a)) => Json::Bool(*a <= *f + 1e-9),
                        _ => Json::Null,
                    },
                ),
            ]));
        }
    }
    let json = Json::obj(vec![
        ("strategy", Json::str(&cfg.strategy)),
        ("parties", Json::num(cfg.n_parties as f64)),
        ("rounds", Json::num(cfg.rounds as f64)),
        ("seed", Json::num(cfg.seed as f64)),
        ("dim", Json::num(cfg.dim as f64)),
        ("epoch_secs", Json::num(cfg.epoch_secs)),
        (
            "scenarios",
            Json::arr(cfg.scenarios.iter().map(|s| Json::str(s))),
        ),
        ("cells", Json::Arr(cells)),
        ("regret_check", Json::Arr(checks)),
    ]);
    (t, json)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell<'a>(json: &'a Json, scenario: &str, mode: &str) -> &'a Json {
        json.get("cells")
            .as_arr()
            .unwrap()
            .iter()
            .find(|c| {
                c.get("scenario").as_str() == Some(scenario)
                    && c.get("mode").as_str() == Some(mode)
            })
            .unwrap_or_else(|| panic!("missing cell {scenario}/{mode}"))
    }

    #[test]
    fn sweep_covers_both_modes_and_dumps_parseable_json() {
        let cfg = AdaptiveSweepConfig {
            n_parties: 8,
            rounds: 3,
            dim: 32,
            ..Default::default()
        };
        let (_t, json) = run_sweep(&cfg);
        let cells = json.get("cells").as_arr().unwrap();
        assert_eq!(cells.len(), 2 * 2, "two scenarios × two modes");
        for c in cells {
            assert!(
                c.get("error").as_str().is_none(),
                "cell {:?}/{:?} failed: {:?}",
                c.get("scenario").as_str(),
                c.get("mode").as_str(),
                c.get("error")
            );
            assert!(c.get("fidelity_l2").as_f64().unwrap() >= 0.0);
            assert!(
                c.get("rounds_done").as_u64().unwrap()
                    + c.get("rounds_skipped").as_u64().unwrap() as u64
                    > 0
            );
        }
        crate::bench::dump("BENCH_adaptive", &json);
        let text = std::fs::read_to_string(
            crate::bench::repro_dir().join("BENCH_adaptive.json"),
        )
        .unwrap();
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn adaptive_never_cuts_more_updates_than_fixed() {
        let cfg = AdaptiveSweepConfig {
            n_parties: 12,
            rounds: 3,
            dim: 32,
            ..Default::default()
        };
        let (_t, json) = run_sweep(&cfg);
        let checks = json.get("regret_check").as_arr().unwrap();
        assert_eq!(checks.len(), 2, "one check per scenario");
        for ch in checks {
            let scenario = ch.get("scenario").as_str().unwrap();
            assert_eq!(
                ch.get("adaptive_dropped_le_fixed").as_bool(),
                Some(true),
                "{scenario}: the learned deadline only extends, so adaptive \
                 ({:?}) must cut no more than fixed ({:?})",
                ch.get("adaptive_dropped"),
                ch.get("fixed_dropped"),
            );
        }
        // the straggler scenario actually exercises the deadline: fixed
        // must cut someone, or the comparison is vacuous
        let straggler = checks
            .iter()
            .find(|c| c.get("scenario").as_str() == Some("stragglers"))
            .unwrap();
        assert!(
            straggler.get("fixed_dropped").as_u64().unwrap() > 0,
            "straggler cell must cut deadline-missers under the fixed policy"
        );
    }

    #[test]
    fn adaptive_runs_are_deterministic_per_seed() {
        let cfg = AdaptiveSweepConfig {
            n_parties: 8,
            rounds: 3,
            dim: 16,
            scenarios: vec!["stragglers".to_string()],
            ..Default::default()
        };
        let faults = FleetFaults::scenario("stragglers", cfg.epoch_secs).unwrap();
        let a = run_cell(&cfg, faults.clone(), AdaptiveConfig::on()).unwrap();
        let b = run_cell(&cfg, faults, AdaptiveConfig::on()).unwrap();
        assert_eq!(a.updates_dropped, b.updates_dropped);
        assert_eq!(a.final_model.len(), b.final_model.len());
        for (x, y) in a.final_model.iter().zip(&b.final_model) {
            assert_eq!(x.to_bits(), y.to_bits(), "adaptive runs must replay bit-identically");
        }
    }

    #[test]
    fn args_parse_into_the_sweep_config() {
        let args = crate::util::cli::Args::parse(
            "adaptive --scenarios stragglers --parties 4 --rounds 2 --dim 16 --seed 7 \
             --strategy async-stale"
                .split_whitespace()
                .map(|x| x.to_string()),
        );
        let cfg = AdaptiveSweepConfig::from_args(&args);
        assert_eq!(cfg.scenarios, vec!["stragglers"]);
        assert_eq!(cfg.strategy, "async-stale");
        assert_eq!((cfg.n_parties, cfg.rounds, cfg.dim, cfg.seed), (4, 2, 16, 7));
    }
}
