//! `fljit` CLI dispatch — the leader entrypoint's subcommands.

use crate::bench::figs::{self, LatencyGrid, ResourceGrid};
use crate::coordinator::job::FlJobSpec;
use crate::coordinator::session::{Session, SessionEvent};
use crate::coordinator::timeline;
use crate::model::zoo;
use crate::party::FleetKind;
use crate::telemetry::{export, Registry};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::workloads::Workload;

const USAGE: &str = "\
fljit — Just-in-Time Aggregation for Federated Learning

USAGE: fljit <subcommand> [--flags]

SUBCOMMANDS:
  timeline                         Fig 2 scenario (6 parties, 4+1 options)
  simulate   --workload cifar100 --fleet active-homog --parties 100
             --strategy jit --rounds 50 --seed 7 [--telemetry-dir DIR]
  bench-table <fig3|fig4|fig7|fig8|fig9>  regenerate a paper figure/table
             [--rounds N] [--max-parties N] [--reps N] [--workload W]
  broker     multi-tenant broker sweep: Poisson job arrivals, admission
             control, every arbitration policy on one trace
             [--jobs N] [--capacity N] [--rounds N] [--max-parties N]
             [--interarrival S] [--overcommit X] [--seed N] [--no-solo]
             [--no-pin-large]   (writes BENCH_broker.json dump)
  calibrate  [--reps 5]            offline t_pair per zoo model (§5.4)
  run        --spec job.json       run a JSON job spec end to end (sim)
  live       wall-clock run of ANY strategy on the zero-copy MQ
             --strategy <jit|batched|eager-serverless|eager-ao|lazy|
                         async-stale|all>
             [--parties 4] [--rounds 5] [--seed 42] [--dim 512]
             [--epoch-secs 0.4] [--scripted] [--backend synth|xla]
             [--shards <n|sweep>]  (L1 aggregator tree width, 1..=64;
             the published models are bit-identical for every n;
             'sweep' scales the tree over the jit job -> shard_scaling
             rows in BENCH_live.json)
             [--telemetry-dir DIR]
             [--data-dir DIR] [--fsync always|every=N|os] [--resume]
             [--wall]   (--data-dir makes the MQ durable: a killed run
             resumes bit-identically with --resume; --wall paces the
             scripted backend on the real clock)
             (--strategy all sweeps every strategy -> BENCH_live.json)
  recover    <dir> | --data-dir DIR   open a durable data dir, replay its
             segmented log, and print the recovery report, per-topic
             depths (per-shard topics included), per-job model CRCs,
             and each surviving checkpoint slot's partial-aggregate CRC
  robustness strategy × fault-scenario matrix: every strategy on the
             scripted live platform under injected stragglers / dropout /
             diurnal waves / weight skew; per-cell fidelity-vs-baseline,
             latency inflation, dropped-vs-decayed counts
             [--strategies jit,async-stale,...] (default: all six)
             [--scenarios baseline,stragglers,dropout,diurnal,skew]
             [--parties 10] [--rounds 4] [--seed 42] [--dim 64]
             [--epoch-secs 0.4]   (writes BENCH_robustness.json dump)
  adaptive   adaptive-JIT regret sweep: learned fuse deadlines (online
             arrival sketches, crate::adapt) vs the fixed estimator
             deadline, per fault scenario; embeds the dropped/resource/
             fidelity regret check in the dump
             [--scenarios stragglers,diurnal] [--strategy jit]
             [--parties 10] [--rounds 4] [--seed 42] [--dim 64]
             [--epoch-secs 0.4]   (writes BENCH_adaptive.json dump)
  live-broker  the broker's job mix on the LIVE platform: trace replay
             with admission control + policy-arbitrated preemption,
             per-job MQ topics/checkpoints/models
             --policy <deadline|least-slack|wfs|all>
             [--jobs 4] [--rounds 2] [--max-parties 8] [--capacity 4]
             [--budget 8] [--interarrival 5] [--seed N] [--dim 32]
             [--trace t.json] [--save-trace t.json] [--wall]
             [--telemetry-dir DIR]
             [--data-dir DIR] [--fsync P] [--resume]  (durable data
             plane; needs a single --policy, not 'all')
             [--replay-gb G] [--replay-dim N]  multi-GB durable-log
             replay bench: per-fsync-policy append + recovery-scan
             throughput rows merged into the dump
             (writes BENCH_live_broker.json dump)
  top        <dir>                 summarize a telemetry dir's JSONL trace:
             per-job rounds, fuses, checkpoints, deploys, preemptions,
             admission + party waits (re-run anytime — the JSONL streams
             during the run)
  zoo                              list zoo models

Any run taking --telemetry-dir writes telemetry.jsonl (streamed spans +
final metric samples), exposition.prom (Prometheus text format) and
trace.json (Chrome trace_event; open in chrome://tracing or perfetto).
";

pub fn dispatch(args: &Args) -> i32 {
    match args.subcommand() {
        Some("timeline") => cmd_timeline(args),
        Some("simulate") => cmd_simulate(args),
        Some("bench-table") => cmd_bench_table(args),
        Some("broker") => cmd_broker(args),
        Some("calibrate") => cmd_calibrate(args),
        Some("run") => cmd_run(args),
        Some("live") => cmd_live(args),
        Some("live-broker") => cmd_live_broker(args),
        Some("recover") => cmd_recover(args),
        Some("robustness") => cmd_robustness(args),
        Some("adaptive") => cmd_adaptive(args),
        Some("top") => cmd_top(args),
        Some("zoo") => cmd_zoo(),
        _ => {
            print!("{USAGE}");
            if args.subcommand().is_some() {
                eprintln!("unknown subcommand {:?}", args.subcommand());
                return 2;
            }
            0
        }
    }
}

/// Parse `--fsync` (absent = the durable default policy).
fn fsync_from_args(args: &Args) -> Result<crate::wal::FsyncPolicy, i32> {
    match args.get("fsync") {
        None => Ok(crate::wal::FsyncPolicy::default()),
        Some(s) => crate::wal::FsyncPolicy::parse(s).map_err(|e| {
            eprintln!("bad --fsync: {e}");
            2
        }),
    }
}

/// Open `--telemetry-dir` as a streaming registry. `Ok(None)` = flag
/// absent, telemetry disabled (the default no-op fast path).
fn telemetry_from_args(args: &Args) -> Result<Option<(Registry, String)>, i32> {
    let Some(dir) = args.get("telemetry-dir") else {
        return Ok(None);
    };
    match Registry::with_dir(dir) {
        Ok(reg) => Ok(Some((reg, dir.to_string()))),
        Err(e) => {
            eprintln!("cannot open telemetry dir {dir:?}: {e}");
            Err(1)
        }
    }
}

/// Finalize a run's telemetry dir (all three export formats).
fn export_telemetry(tel: &Option<(Registry, String)>) -> i32 {
    let Some((reg, dir)) = tel else { return 0 };
    if let Err(e) = export::write_all(reg, dir) {
        eprintln!("telemetry export failed: {e}");
        return 1;
    }
    println!(
        "telemetry written to {dir}/ ({}, {}, {})",
        export::JSONL_FILE,
        export::EXPOSITION_FILE,
        export::CHROME_TRACE_FILE
    );
    0
}

fn cmd_timeline(args: &Args) -> i32 {
    let reports = timeline::run_fig2(args.get_u64("seed", 7));
    print!("{}", timeline::render(&reports));
    println!(
        "eager-AO §3 arithmetic: busy 6s of a 21s round -> idle {:.1}%",
        timeline::eager_ao_idle_fraction(6.0, 21.0) * 100.0
    );
    0
}

fn cmd_simulate(args: &Args) -> i32 {
    let Some(workload) = Workload::by_name(args.get_or("workload", "cifar100-effnet")) else {
        eprintln!("unknown workload; see `fljit zoo`");
        return 2;
    };
    let Some(fleet) = FleetKind::parse(args.get_or("fleet", "active-homog")) else {
        eprintln!("unknown fleet kind (active-homog | active-hetero | intermittent)");
        return 2;
    };
    let strategy = args.get_or("strategy", "jit").to_string();
    let parties = args.get_usize("parties", 100);
    let rounds = args.get_u64("rounds", 50) as u32;
    let mut spec = FlJobSpec::new(workload, fleet, parties, rounds);
    spec.t_wait_secs = args.get_f64("twait", crate::workloads::T_WAIT_SECS);
    spec.report_prob = args.get_f64("report-prob", 1.0);
    let tel = match telemetry_from_args(args) {
        Ok(t) => t,
        Err(code) => return code,
    };
    let mut s = Session::sim().seed(args.get_u64("seed", 7));
    if let Some((reg, _)) = &tel {
        s = s.telemetry(reg);
    }
    let h = s.job(spec, &strategy);
    let rep = match s.run() {
        Ok(rep) => rep,
        Err(e) => {
            eprintln!("simulate failed: {e:#}");
            return 1;
        }
    };
    let r = rep.job(h);
    let mut t = Table::new(
        &format!("simulate {} / {} / {}p / {}", r.workload, r.fleet, parties, strategy),
        &["metric", "value"],
    );
    t.row(vec!["rounds".into(), r.records.len().to_string()]);
    t.row(vec![
        "mean agg latency (s)".into(),
        format!("{:.3}", r.mean_latency_secs()),
    ]);
    t.row(vec![
        "p95 agg latency (s)".into(),
        format!("{:.3}", r.latency_p95()),
    ]);
    t.row(vec![
        "container-seconds".into(),
        format!("{:.1}", r.total_container_seconds()),
    ]);
    t.row(vec!["projected cost (USD)".into(), format!("{:.4}", r.cost_usd())]);
    t.row(vec!["deployments".into(), r.deployments.to_string()]);
    t.row(vec!["updates fused".into(), r.updates_fused.to_string()]);
    t.row(vec!["makespan (s)".into(), format!("{:.1}", r.makespan_secs)]);
    t.print();
    crate::bench::dump("simulate", &rep.to_json());
    export_telemetry(&tel)
}

fn cmd_bench_table(args: &Args) -> i32 {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("");
    let rounds = args.get_u64("rounds", 50) as u32;
    let max_parties = args.get_usize("max-parties", 10000);
    let seed = args.get_u64("seed", 0xF19);
    let reps = args.get_usize("reps", 20);
    match which {
        "fig3" => match figs::fig3(reps, seed) {
            Ok((t, j)) => {
                t.print();
                crate::bench::dump("fig3", &j);
                0
            }
            Err(e) => {
                eprintln!("fig3 failed (artifacts built?): {e:#}");
                1
            }
        },
        "fig4" => match figs::fig4(reps, seed) {
            Ok((t, j)) => {
                t.print();
                crate::bench::dump("fig4", &j);
                0
            }
            Err(e) => {
                eprintln!("fig4 failed (artifacts built?): {e:#}");
                1
            }
        },
        "fig7" | "fig8" => {
            let fleet = if which == "fig7" {
                FleetKind::IntermittentHeterogeneous
            } else {
                FleetKind::ActiveHeterogeneous
            };
            let (tables, j) = LatencyGrid {
                fleet,
                rounds,
                seed,
                max_parties,
            }
            .run();
            for t in tables {
                t.print();
            }
            crate::bench::dump(which, &j);
            0
        }
        "fig9" => {
            let (tables, j) = ResourceGrid {
                rounds,
                seed,
                max_parties,
                only_workload: args.get("workload").map(|s| {
                    Workload::by_name(s).map(|w| w.name.to_string()).unwrap_or_else(|| s.to_string())
                }),
                ..Default::default()
            }
            .run();
            for t in tables {
                t.print();
            }
            crate::bench::dump("fig9", &j);
            0
        }
        _ => {
            eprintln!("expected one of fig3|fig4|fig7|fig8|fig9");
            2
        }
    }
}

fn cmd_broker(args: &Args) -> i32 {
    let cfg = crate::bench::broker::SweepConfig::from_args(args);
    let (tables, json) = crate::bench::broker::run_sweep(&cfg);
    for t in tables {
        t.print();
    }
    crate::bench::dump("BENCH_broker", &json);
    0
}

fn cmd_live_broker(args: &Args) -> i32 {
    use crate::broker::arbitration;
    if let Err(code) = fsync_from_args(args) {
        return code;
    }
    let cfg = crate::bench::live_broker::LiveBrokerSweepConfig::from_args(args);
    if cfg.policy != "all" && arbitration::by_name(&cfg.policy).is_none() {
        eprintln!(
            "unknown policy {:?}; expected one of {:?} or 'all'",
            cfg.policy,
            arbitration::all_policies()
        );
        return 2;
    }
    if cfg.data_dir.is_some() && cfg.policy == "all" {
        eprintln!("--data-dir needs a single --policy (swept policies would share one log)");
        return 2;
    }
    match crate::bench::live_broker::run_sweep(&cfg) {
        Ok((tables, json)) => {
            for t in tables {
                t.print();
            }
            crate::bench::dump("BENCH_live_broker", &json);
            0
        }
        Err(e) => {
            eprintln!("live-broker sweep failed: {e:#}");
            1
        }
    }
}

fn cmd_robustness(args: &Args) -> i32 {
    use crate::coordinator::strategies;
    let cfg = crate::bench::robustness::RobustnessSweepConfig::from_args(args);
    for s in &cfg.strategies {
        if strategies::by_name(s).is_none() {
            eprintln!(
                "unknown strategy {s:?}; expected a comma list drawn from {:?}",
                strategies::all_strategies()
            );
            return 2;
        }
    }
    let (t, json) = crate::bench::robustness::run_sweep(&cfg);
    t.print();
    crate::bench::dump("BENCH_robustness", &json);
    0
}

fn cmd_adaptive(args: &Args) -> i32 {
    use crate::coordinator::strategies;
    let cfg = crate::bench::adaptive::AdaptiveSweepConfig::from_args(args);
    if strategies::by_name(&cfg.strategy).is_none() {
        eprintln!(
            "unknown strategy {:?}; expected one of {:?}",
            cfg.strategy,
            strategies::all_strategies()
        );
        return 2;
    }
    let (t, json) = crate::bench::adaptive::run_sweep(&cfg);
    t.print();
    crate::bench::dump("BENCH_adaptive", &json);
    // surface the embedded acceptance verdict on stdout so CI greps can
    // read it without parsing the dump
    for ch in json.get("regret_check").as_arr().into_iter().flatten() {
        println!(
            "regret_check scenario={} dropped {}<=:{} resource<=: {} fidelity<=: {}",
            ch.get("scenario").as_str().unwrap_or("?"),
            ch.get("adaptive_dropped")
                .as_f64()
                .map(|v| v.to_string())
                .unwrap_or_default(),
            ch.get("adaptive_dropped_le_fixed")
                .as_bool()
                .unwrap_or(false),
            ch.get("adaptive_resource_le_fixed")
                .as_bool()
                .unwrap_or(false),
            ch.get("adaptive_fidelity_le_fixed")
                .as_bool()
                .unwrap_or(false),
        );
    }
    0
}

fn cmd_calibrate(args: &Args) -> i32 {
    let reps = args.get_usize("reps", 5);
    let seed = args.get_u64("seed", 42);
    let mut t = Table::new(
        "t_pair calibration (§5.4) — pure-Rust fusion hot path",
        &["model", "params", "MB", "t_pair (ms)", "GB/s"],
    );
    for name in zoo::all_names() {
        let spec = zoo::by_name(name).unwrap();
        let t_pair = crate::fusion::calibrate_t_pair(&spec, reps, seed);
        let mb = spec.size_bytes() as f64 / 1e6;
        t.row(vec![
            name.to_string(),
            spec.total_params().to_string(),
            format!("{:.1}", mb),
            format!("{:.2}", t_pair * 1e3),
            // pair merge streams 2 reads + 1 write of the update
            format!("{:.2}", 3.0 * mb / 1e3 / t_pair),
        ]);
    }
    t.print();
    0
}

fn cmd_run(args: &Args) -> i32 {
    let Some(path) = args.get("spec") else {
        eprintln!("run requires --spec job.json");
        return 2;
    };
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("cannot read {path}: {e}");
            return 1;
        }
    };
    let Ok(v) = Json::parse(&text) else {
        eprintln!("invalid JSON in {path}");
        return 1;
    };
    let Some(spec) = FlJobSpec::from_json(&v) else {
        eprintln!("invalid job spec in {path}");
        return 1;
    };
    let strategy = args.get_or("strategy", "jit").to_string();
    let mut s = Session::sim().seed(args.get_u64("seed", 7));
    s.job(spec, &strategy);
    match s.run() {
        Ok(rep) => {
            println!("{}", rep.to_json().pretty());
            0
        }
        Err(e) => {
            eprintln!("run failed: {e:#}");
            1
        }
    }
}

fn cmd_live(args: &Args) -> i32 {
    use crate::coordinator::live::PartyBackend;
    use crate::coordinator::strategies;
    let strategy = args.get_or("strategy", "jit").to_string();
    let fsync = match fsync_from_args(args) {
        Ok(p) => p,
        Err(code) => return code,
    };
    let data_dir = args.get("data-dir").map(|s| s.to_string());
    if args.get("shards") == Some("sweep") {
        if data_dir.is_some() {
            eprintln!(
                "--shards sweep runs private in-memory sessions; \
                 --data-dir needs a single --shards value"
            );
            return 2;
        }
        match args.get("backend") {
            None | Some("synth") | Some("scripted") => {}
            Some(other) => {
                eprintln!(
                    "--shards sweep runs the synthetic backends only \
                     (synth | scripted), got --backend {other:?}"
                );
                return 2;
            }
        }
        // scale the L1 aggregator tree over the identical jit job; every
        // row must report the same final-model fingerprint
        let cfg = crate::bench::live::LiveSweepConfig::from_args(args);
        let (t, json) = crate::bench::live::run_shard_sweep(&cfg, &[1, 2, 3, 4, 7, 16]);
        t.print();
        crate::bench::dump("BENCH_live", &json);
        return 0;
    }
    let shards = match args.get("shards") {
        None => 1,
        Some(s) => match s.parse::<usize>() {
            Ok(n) if n >= 1 => n,
            _ => {
                eprintln!("bad --shards {s:?}: expected a count >= 1 or 'sweep'");
                return 2;
            }
        },
    };
    if strategy == "all" {
        if data_dir.is_some() {
            eprintln!(
                "--strategy all sweeps private in-memory sessions; \
                 --data-dir needs a single strategy"
            );
            return 2;
        }
        // the live analogue of the Fig 7/9 sweeps: every strategy on the
        // identical job, busy-seconds + latency per strategy
        match args.get("backend") {
            None | Some("synth") | Some("scripted") => {}
            Some(other) => {
                eprintln!(
                    "--strategy all sweeps the synthetic backends only \
                     (synth | scripted), got --backend {other:?}"
                );
                return 2;
            }
        }
        let cfg = crate::bench::live::LiveSweepConfig::from_args(args);
        let (t, json) = crate::bench::live::run_sweep(&cfg);
        t.print();
        crate::bench::dump("BENCH_live", &json);
        return 0;
    }
    if strategies::by_name(&strategy).is_none() {
        eprintln!(
            "unknown strategy {strategy:?}; expected one of {:?} or 'all'",
            strategies::all_strategies()
        );
        return 2;
    }
    let backend = match args.get_or("backend", if args.get_bool("scripted") {
        "scripted"
    } else {
        "synth"
    }) {
        "scripted" => PartyBackend::Scripted,
        "synth" => PartyBackend::SynthThreads,
        "xla" => PartyBackend::XlaThreads,
        other => {
            eprintln!("unknown backend {other:?} (scripted | synth | xla)");
            return 2;
        }
    };
    let mut workload = crate::workloads::Workload::mlp_live();
    workload.base_epoch_secs = args.get_f64("epoch-secs", workload.base_epoch_secs);
    let spec = FlJobSpec::new(
        workload,
        FleetKind::ActiveHomogeneous,
        args.get_usize("parties", 4),
        args.get_u64("rounds", 5) as u32,
    );
    let mut s = match backend {
        // --wall paces even the scripted backend on the real clock — the
        // shape a mid-run `kill -9` + durable resume exercise needs
        PartyBackend::Scripted if args.get_bool("wall") => {
            Session::wall().backend(backend)
        }
        PartyBackend::Scripted => Session::live(),
        PartyBackend::SynthThreads | PartyBackend::XlaThreads => {
            Session::wall().backend(backend)
        }
    };
    s = s
        .seed(args.get_u64("seed", 42))
        .dim(args.get_usize("dim", 512))
        .minibatches(args.get_usize("minibatches", 4))
        .lr(args.get_f64("lr", 0.3) as f32)
        .alpha(args.get_f64("alpha", 0.5))
        .shards(shards);
    if let Some(dir) = &data_dir {
        s = s.data_dir(dir).fsync(fsync);
    }
    if args.get_bool("resume") {
        s = s.resume(true);
    }
    let tel = match telemetry_from_args(args) {
        Ok(t) => t,
        Err(code) => return code,
    };
    if let Some((reg, _)) = &tel {
        s = s.telemetry(reg);
    }
    let h = s.job(spec, &strategy);
    // consume the session's event stream live from a worker thread: each
    // round prints the moment its model is fused, not after the run
    let events = s.events();
    let worker = std::thread::spawn(move || s.run());
    for ev in events.iter() {
        match ev {
            SessionEvent::RoundFused {
                round,
                latency_secs,
                at_secs,
                ..
            } => println!(
                "round {round} fused at t={at_secs:.2}s  (agg latency {:.1} ms)",
                latency_secs * 1e3
            ),
            SessionEvent::Preempted { task, at_secs } => {
                println!("task {task} preempted at t={at_secs:.2}s")
            }
            SessionEvent::Crashed { at_secs } => {
                println!("aggregator crashed at t={at_secs:.2}s (MQ state kept)")
            }
            _ => {}
        }
    }
    let report = match worker.join() {
        Ok(Ok(report)) => report,
        Ok(Err(e)) => {
            eprintln!("live run failed: {e:#}");
            return 1;
        }
        Err(_) => {
            eprintln!("live run panicked");
            return 1;
        }
    };
    let o = report.job(h);
    let mut t = Table::new(
        &format!("live federated run ({} strategy, MQ-backed)", o.strategy),
        &["round", "agg lat (ms)", "complete (s)"],
    );
    for r in &o.records {
        t.row(vec![
            r.round.to_string(),
            format!("{:.1}", r.latency_secs * 1e3),
            format!("{:.2}", r.complete_secs),
        ]);
    }
    t.print();
    for s in &o.stats {
        println!(
            "round {}: train_loss={:.4} eval_loss={:.4} eval_acc={:.3}",
            s.round, s.train_loss, s.eval_loss, s.eval_acc
        );
    }
    println!(
        "busy={:.3}cs  deployments={}  fused={}  mean_lat={:.1}ms  wall={:.2}s",
        o.container_seconds,
        o.deployments,
        o.updates_folded,
        o.mean_latency_secs() * 1e3,
        report.summary().wall_secs
    );
    if o.t_pair_secs > 0.0 {
        println!("t_pair (XLA fusion path, §5.4): {:.3}ms", o.t_pair_secs * 1e3);
    }
    export_telemetry(&tel)
}

/// Open a durable data dir, replay its log, and print what survived:
/// the recovery report, per-topic depths, each job's completed-round
/// count plus a CRC-32 of its latest fused model (the greppable
/// `job=N rounds=R model_crc32=0x...` lines the durability smoke
/// compares across a kill/resume boundary), and checkpoint slots.
fn cmd_recover(args: &Args) -> i32 {
    use crate::mq::MessageQueue;
    use crate::wal::{crc32, FsyncPolicy, WalConfig};
    let dir = args
        .get("data-dir")
        .map(|s| s.to_string())
        .or_else(|| args.positional.get(1).cloned());
    let Some(dir) = dir else {
        eprintln!("recover requires a durable data dir: fljit recover <dir>");
        return 2;
    };
    if !std::path::Path::new(&dir).is_dir() {
        eprintln!("no durable log at {dir:?}: directory does not exist");
        return 1;
    }
    // read-mostly open: OS-paced syncs, we only append telemetry-free
    let q = match MessageQueue::durable(WalConfig::new(&dir).fsync(FsyncPolicy::OsOnly)) {
        Ok(q) => q,
        Err(e) => {
            eprintln!("recovery of {dir} failed: {e}");
            return 1;
        }
    };
    let rec = q.recovery().expect("durable queue always has a recovery report");
    println!(
        "recovered {dir}: segments={} records={} bytes={} torn_tail={} \
         truncated_bytes={} elapsed={:.3}s",
        rec.segments, rec.records, rec.bytes, rec.torn_tail, rec.truncated_bytes,
        rec.elapsed_secs
    );
    let topics = q.topic_names();
    if !topics.is_empty() {
        let mut t = Table::new(&format!("fljit recover — {dir}"), &["topic", "depth"]);
        for name in &topics {
            t.row(vec![name.clone(), q.end_offset(name).to_string()]);
        }
        t.print();
    }
    for name in &topics {
        let Some(job) = name
            .strip_prefix("job")
            .and_then(|r| r.strip_suffix("/models"))
            .and_then(|j| j.parse::<usize>().ok())
        else {
            continue;
        };
        let rounds = q.end_offset(name);
        let crc = q
            .fetch(name, rounds.saturating_sub(1), 1)
            .first()
            .and_then(|m| m.payload.data().map(|d| {
                let mut bytes = Vec::with_capacity(d.len() * 4);
                for v in d {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
                crc32(&bytes)
            }));
        match crc {
            Some(c) => println!("job={job} rounds={rounds} model_crc32=0x{c:08x}"),
            None => println!("job={job} rounds={rounds} model_crc32=none"),
        }
    }
    let slots = q.checkpoint_slots();
    if slots.is_empty() {
        println!("checkpoints: (none)");
    } else {
        println!("checkpoints: {}", slots.join(" "));
        // one greppable line per surviving slot: what the (shard's)
        // partial aggregate looked like at the kill — the shard smoke
        // compares these across a kill/resume boundary
        for slot in &slots {
            let Some(ck) = q.load_checkpoint(slot) else {
                continue;
            };
            let crc = ck.acc.as_ref().map(|d| {
                let mut bytes = Vec::with_capacity(d.len() * 4);
                for v in d {
                    bytes.extend_from_slice(&v.to_le_bytes());
                }
                crc32(&bytes)
            });
            let crc = match crc {
                Some(c) => format!("0x{c:08x}"),
                None => "none".to_string(),
            };
            println!(
                "shard_ckpt slot={slot} consumed_to={} folds={} weight={} \
                 buckets={} partial_crc32={crc}",
                ck.consumed_to,
                ck.n_merged,
                ck.weight,
                ck.buckets.len()
            );
        }
    }
    0
}

fn cmd_top(args: &Args) -> i32 {
    let dir = args
        .get("dir")
        .map(|s| s.to_string())
        .or_else(|| args.positional.get(1).cloned());
    let Some(dir) = dir else {
        eprintln!("top requires a telemetry dir: fljit top <dir>");
        return 2;
    };
    let path = std::path::Path::new(&dir).join(export::JSONL_FILE);
    let body = match std::fs::read_to_string(&path) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("cannot read {}: {e}", path.display());
            return 1;
        }
    };
    let tops = export::summarize_jsonl(&body);
    if tops.is_empty() {
        println!("no spans recorded yet in {}", path.display());
        return 0;
    }
    let mut t = Table::new(
        &format!("fljit top — {}", path.display()),
        &[
            "job",
            "rounds",
            "mean round (s)",
            "fuses",
            "ckpts",
            "deploys",
            "preempts",
            "adm wait (s)",
            "party wait (ms)",
            "arr p90/p99 (s)",
            "deadline (s)",
            "last seen (s)",
        ],
    );
    for top in &tops {
        // adaptive gauges are absent until the first adaptive round (and
        // always, with adaptation off) — render a dash, not fake zeros
        let quants = if top.arrival_p99_secs > 0.0 {
            format!("{:.1}/{:.1}", top.arrival_p90_secs, top.arrival_p99_secs)
        } else {
            "-".to_string()
        };
        let deadline = if top.deadline_secs > 0.0 {
            format!("{:.1}", top.deadline_secs)
        } else {
            "-".to_string()
        };
        t.row(vec![
            top.job.to_string(),
            top.rounds.to_string(),
            format!("{:.2}", top.mean_round_secs()),
            top.fuses.to_string(),
            top.checkpoints.to_string(),
            top.deploys.to_string(),
            top.preempts.to_string(),
            format!("{:.1}", top.admission_wait_secs),
            format!("{:.1}", top.mean_party_wait_secs() * 1e3),
            quants,
            deadline,
            format!("{:.1}", top.last_at_secs),
        ]);
    }
    t.print();
    0
}

fn cmd_zoo() -> i32 {
    let mut t = Table::new("model zoo", &["name", "params", "update MB", "layers"]);
    for name in zoo::all_names() {
        let m = zoo::by_name(name).unwrap();
        t.row(vec![
            name.to_string(),
            m.total_params().to_string(),
            format!("{:.1}", m.size_bytes() as f64 / 1e6),
            m.layers.len().to_string(),
        ]);
    }
    t.print();
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn usage_and_unknown() {
        assert_eq!(dispatch(&args("")), 0);
        assert_eq!(dispatch(&args("frobnicate")), 2);
    }

    #[test]
    fn zoo_and_calibrate_run() {
        assert_eq!(dispatch(&args("zoo")), 0);
        assert_eq!(dispatch(&args("calibrate --reps 1")), 0);
    }

    #[test]
    fn simulate_small() {
        assert_eq!(
            dispatch(&args(
                "simulate --parties 10 --rounds 2 --strategy jit --seed 3"
            )),
            0
        );
        assert_eq!(dispatch(&args("simulate --workload nope")), 2);
        assert_eq!(dispatch(&args("simulate --fleet nope")), 2);
    }

    #[test]
    fn timeline_runs() {
        assert_eq!(dispatch(&args("timeline")), 0);
    }

    #[test]
    fn broker_tiny_grid_runs() {
        assert_eq!(
            dispatch(&args(
                "broker --jobs 3 --capacity 16 --rounds 2 --max-parties 20 \
                 --interarrival 3 --no-solo --seed 5"
            )),
            0
        );
    }

    #[test]
    fn bench_table_validation() {
        assert_eq!(dispatch(&args("bench-table")), 2);
        assert_eq!(dispatch(&args("bench-table fig99")), 2);
    }

    #[test]
    fn live_accepts_every_strategy_name() {
        // acceptance: all six Strategy names run through `fljit live`
        for n in crate::coordinator::strategies::all_strategies() {
            assert_eq!(
                dispatch(&args(&format!(
                    "live --strategy {n} --parties 3 --rounds 1 --dim 16 --scripted"
                ))),
                0,
                "{n}"
            );
        }
        assert_eq!(dispatch(&args("live --strategy nope")), 2);
        assert_eq!(dispatch(&args("live --strategy jit --backend bogus")), 2);
    }

    #[test]
    fn live_broker_tiny_grid_runs_per_policy_and_all() {
        // acceptance: `fljit live-broker --policy <each>` replays a trace
        // with ≥2 concurrent live jobs and emits BENCH_live_broker.json
        for policy in crate::broker::arbitration::all_policies() {
            assert_eq!(
                dispatch(&args(&format!(
                    "live-broker --policy {policy} --jobs 2 --max-parties 4 \
                     --capacity 2 --interarrival 2 --dim 16 --seed 9"
                ))),
                0,
                "{policy}"
            );
        }
        assert_eq!(
            dispatch(&args(
                "live-broker --policy all --jobs 2 --max-parties 4 \
                 --capacity 2 --interarrival 2 --dim 16 --seed 9"
            )),
            0
        );
        assert!(crate::bench::repro_dir().join("BENCH_live_broker.json").exists());
        assert_eq!(dispatch(&args("live-broker --policy nope")), 2);
    }

    #[test]
    fn robustness_tiny_grid_runs_and_dumps() {
        // the CI smoke invocation, verbatim
        assert_eq!(
            dispatch(&args(
                "robustness --strategies jit,async-stale \
                 --scenarios baseline,stragglers --parties 4 --rounds 2 \
                 --dim 32 --seed 7"
            )),
            0
        );
        assert!(crate::bench::repro_dir().join("BENCH_robustness.json").exists());
        assert_eq!(dispatch(&args("robustness --strategies nope")), 2);
    }

    #[test]
    fn recover_roundtrips_a_durable_live_run() {
        let dir = std::env::temp_dir().join(format!("fljit_cli_recover_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let dir_s = dir.to_string_lossy().to_string();
        assert_eq!(dispatch(&args("recover")), 2, "recover needs a dir");
        assert_eq!(dispatch(&args(&format!("recover {dir_s}"))), 1, "missing dir");
        // a durable live run leaves a recoverable log behind…
        assert_eq!(
            dispatch(&args(&format!(
                "live --strategy jit --parties 3 --rounds 2 --dim 16 \
                 --scripted --data-dir {dir_s}"
            ))),
            0
        );
        // …that `fljit recover` replays and reports on
        assert_eq!(dispatch(&args(&format!("recover {dir_s}"))), 0);
        assert_eq!(dispatch(&args(&format!("recover --data-dir {dir_s}"))), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn durable_flag_validation() {
        assert_eq!(dispatch(&args("live --strategy jit --fsync bogus")), 2);
        assert_eq!(dispatch(&args("live --strategy all --data-dir /tmp/x")), 2);
        assert_eq!(
            dispatch(&args("live-broker --policy all --data-dir /tmp/x")),
            2,
            "swept policies must not share one durable log"
        );
        assert_eq!(dispatch(&args("live-broker --policy deadline --fsync bogus")), 2);
    }

    #[test]
    fn live_sharded_runs_and_shard_sweep_dumps() {
        // a sharded live run is just another session shape
        assert_eq!(
            dispatch(&args(
                "live --strategy jit --parties 5 --rounds 1 --dim 16 \
                 --scripted --shards 3"
            )),
            0
        );
        // the shard-scaling sweep dumps shard_scaling rows
        assert_eq!(
            dispatch(&args(
                "live --parties 4 --rounds 1 --dim 16 --scripted --shards sweep"
            )),
            0
        );
        // the dump is valid JSON (other tests may re-dump BENCH_live, so
        // don't pin its keys here; the sweep's own unit test does)
        let text =
            std::fs::read_to_string(crate::bench::repro_dir().join("BENCH_live.json")).unwrap();
        assert!(Json::parse(&text).is_ok());
        assert_eq!(dispatch(&args("live --strategy jit --shards 0")), 2);
        assert_eq!(dispatch(&args("live --strategy jit --shards bogus")), 2);
        assert_eq!(
            dispatch(&args("live --shards sweep --data-dir /tmp/x")),
            2,
            "swept shard counts must not share one durable log"
        );
        assert_eq!(dispatch(&args("live --shards sweep --backend xla")), 2);
    }

    #[test]
    fn live_all_sweeps_and_dumps() {
        assert_eq!(
            dispatch(&args(
                "live --strategy all --parties 3 --rounds 1 --dim 16 --scripted"
            )),
            0
        );
        assert!(crate::bench::repro_dir().join("BENCH_live.json").exists());
        // the sweep runs synthetic backends only — an xla request must be
        // rejected loudly, not silently downgraded
        assert_eq!(dispatch(&args("live --strategy all --backend xla")), 2);
        assert_eq!(
            dispatch(&args(
                "live --strategy all --parties 3 --rounds 1 --dim 16 --backend scripted"
            )),
            0
        );
    }
}
