//! Live strategy sweep — the wall-clock analogue of the Fig 7/9 grids.
//!
//! Runs the *same* job under every §3 strategy on the live platform
//! (wall-clock driver + zero-copy MQ traffic) and reports busy
//! (container) seconds and per-round aggregation latency per strategy —
//! the §6.2 metrics, measured on the real event path instead of virtual
//! time. Dumped to `BENCH_live.json` via `fljit live --strategy all` (or
//! the scripted variant under `cargo test`).

use crate::coordinator::job::FlJobSpec;
use crate::coordinator::session::{Session, SessionEvent};
use crate::coordinator::strategies;
use crate::party::FleetKind;
use crate::util::json::Json;
use crate::util::table::Table;
use crate::workloads::Workload;

#[derive(Clone, Debug)]
pub struct LiveSweepConfig {
    pub n_parties: usize,
    pub rounds: u32,
    pub seed: u64,
    pub dim: usize,
    /// Mean synthetic epoch time (wall seconds; scales the sweep's wall
    /// duration — every strategy pays the same round windows).
    pub epoch_secs: f64,
    /// Thread-backed parties on the real wall clock; `false` = scripted
    /// parties on an instant clock (deterministic, CI-fast, same code
    /// path through the MQ + wall driver).
    pub wall: bool,
    /// L1 aggregator shard count for the strategy sweep (the shard-
    /// scaling sweep varies this itself).
    pub shards: usize,
}

impl Default for LiveSweepConfig {
    fn default() -> Self {
        LiveSweepConfig {
            n_parties: 4,
            rounds: 3,
            seed: 42,
            dim: 512,
            epoch_secs: 0.4,
            wall: true,
            shards: 1,
        }
    }
}

impl LiveSweepConfig {
    pub fn from_args(args: &crate::util::cli::Args) -> LiveSweepConfig {
        let d = LiveSweepConfig::default();
        LiveSweepConfig {
            n_parties: args.get_usize("parties", d.n_parties),
            rounds: args.get_u64("rounds", d.rounds as u64) as u32,
            seed: args.get_u64("seed", d.seed),
            dim: args.get_usize("dim", d.dim),
            epoch_secs: args.get_f64("epoch-secs", d.epoch_secs),
            wall: !args.get_bool("scripted") && args.get("backend") != Some("scripted"),
            shards: match args.get("shards") {
                Some(s) if s != "sweep" => s.parse().unwrap_or(d.shards),
                _ => d.shards,
            },
        }
    }

    fn session(&self, strategy: &str) -> Session {
        let mut workload = Workload::mlp_live();
        workload.base_epoch_secs = self.epoch_secs;
        let spec = FlJobSpec::new(
            workload,
            FleetKind::ActiveHomogeneous,
            self.n_parties,
            self.rounds,
        );
        let mut s = if self.wall {
            Session::wall()
        } else {
            Session::live()
        };
        s = s.seed(self.seed).dim(self.dim).shards(self.shards);
        s.job(spec, strategy);
        s
    }
}

/// CRC32 over a model's raw f32 bytes — the greppable bit-identity
/// fingerprint the shard-scaling rows (and the CI smokes) compare.
fn model_crc(model: &[f32]) -> u32 {
    let bytes: Vec<u8> = model.iter().flat_map(|v| v.to_le_bytes()).collect();
    crate::wal::crc32(&bytes)
}

/// Run every strategy on the identical live job; table + JSON rows.
/// Round latencies and fold counts come from the streaming
/// [`SessionEvent`] channel rather than post-hoc report scraping.
pub fn run_sweep(cfg: &LiveSweepConfig) -> (Table, Json) {
    let mut t = Table::new(
        &format!(
            "live strategy sweep — {} parties × {} rounds, dim {} ({})",
            cfg.n_parties,
            cfg.rounds,
            cfg.dim,
            if cfg.wall { "wall clock" } else { "scripted" }
        ),
        &[
            "strategy",
            "busy (cs)",
            "mean lat (ms)",
            "deployments",
            "fused",
            "wall (s)",
        ],
    );
    let mut rows = Vec::new();
    for name in strategies::all_strategies() {
        let mut s = cfg.session(name);
        let events = s.events();
        match s.run() {
            Ok(rep) => {
                // the §6.2 metrics, read off the event stream as the run
                // produced them
                let mut fused_rounds = 0u64;
                let mut latency_sum = 0.0f64;
                let mut folds = 0u64;
                for ev in events.try_iter() {
                    match ev {
                        SessionEvent::RoundFused { latency_secs, .. } => {
                            fused_rounds += 1;
                            latency_sum += latency_secs;
                        }
                        SessionEvent::CheckpointWritten { folds: n, .. } => folds += n,
                        _ => {}
                    }
                }
                let mean_latency = if fused_rounds > 0 {
                    latency_sum / fused_rounds as f64
                } else {
                    0.0
                };
                let o = rep.single();
                let sum = rep.summary();
                t.row(vec![
                    name.to_string(),
                    format!("{:.3}", o.container_seconds),
                    format!("{:.1}", mean_latency * 1e3),
                    o.deployments.to_string(),
                    folds.to_string(),
                    format!("{:.2}", sum.wall_secs),
                ]);
                rows.push(Json::obj(vec![
                    ("strategy", Json::str(name)),
                    ("busy_secs", Json::num(o.container_seconds)),
                    ("mean_latency_secs", Json::num(mean_latency)),
                    ("deployments", Json::num(o.deployments as f64)),
                    ("updates_fused", Json::num(folds as f64)),
                    ("wall_secs", Json::num(sum.wall_secs)),
                    ("rounds", Json::num(fused_rounds as f64)),
                ]));
            }
            Err(e) => {
                t.row(vec![
                    name.to_string(),
                    format!("failed: {e}"),
                    String::new(),
                    String::new(),
                    String::new(),
                    String::new(),
                ]);
                rows.push(Json::obj(vec![
                    ("strategy", Json::str(name)),
                    ("error", Json::str(&format!("{e:#}"))),
                ]));
            }
        }
    }
    let json = Json::obj(vec![
        ("parties", Json::num(cfg.n_parties as f64)),
        ("rounds", Json::num(cfg.rounds as f64)),
        ("seed", Json::num(cfg.seed as f64)),
        ("dim", Json::num(cfg.dim as f64)),
        ("epoch_secs", Json::num(cfg.epoch_secs)),
        ("wall", Json::Bool(cfg.wall)),
        ("shards", Json::num(cfg.shards as f64)),
        ("strategies", Json::Arr(rows)),
    ]);
    (t, json)
}

/// Shard-scaling sweep: the identical `jit` job under a widening L1
/// aggregator tree. Scaling the tree must change *performance* only —
/// every row reports the final model's CRC32, and all rows carry the
/// same fingerprint (the root fold runs over fixed logical buckets, so
/// the result is bit-identical for every shard count; pinned by
/// `tests/shard_equivalence.rs` and compared by the CI smoke).
pub fn run_shard_sweep(cfg: &LiveSweepConfig, shard_counts: &[usize]) -> (Table, Json) {
    let mut t = Table::new(
        &format!(
            "shard-scaling sweep — jit, {} parties × {} rounds, dim {} ({})",
            cfg.n_parties,
            cfg.rounds,
            cfg.dim,
            if cfg.wall { "wall clock" } else { "scripted" }
        ),
        &[
            "shards",
            "busy (cs)",
            "mean lat (ms)",
            "fused",
            "model crc32",
            "wall (s)",
        ],
    );
    let mut rows = Vec::new();
    for &n in shard_counts {
        let mut scfg = cfg.clone();
        scfg.shards = n;
        let mut s = scfg.session("jit");
        let events = s.events();
        match s.run() {
            Ok(rep) => {
                let mut fused_rounds = 0u64;
                let mut latency_sum = 0.0f64;
                let mut folds = 0u64;
                for ev in events.try_iter() {
                    match ev {
                        SessionEvent::RoundFused { latency_secs, .. } => {
                            fused_rounds += 1;
                            latency_sum += latency_secs;
                        }
                        SessionEvent::CheckpointWritten { folds: k, .. } => folds += k,
                        _ => {}
                    }
                }
                let mean_latency = if fused_rounds > 0 {
                    latency_sum / fused_rounds as f64
                } else {
                    0.0
                };
                let o = rep.single();
                let sum = rep.summary();
                let crc = model_crc(&o.final_model);
                t.row(vec![
                    n.to_string(),
                    format!("{:.3}", o.container_seconds),
                    format!("{:.1}", mean_latency * 1e3),
                    folds.to_string(),
                    format!("{crc:08x}"),
                    format!("{:.2}", sum.wall_secs),
                ]);
                rows.push(Json::obj(vec![
                    ("shards", Json::num(n as f64)),
                    ("busy_secs", Json::num(o.container_seconds)),
                    ("mean_latency_secs", Json::num(mean_latency)),
                    ("updates_fused", Json::num(folds as f64)),
                    ("rounds", Json::num(fused_rounds as f64)),
                    ("model_crc32", Json::str(&format!("{crc:08x}"))),
                    ("wall_secs", Json::num(sum.wall_secs)),
                ]));
            }
            Err(e) => {
                t.row(vec![
                    n.to_string(),
                    format!("failed: {e}"),
                    String::new(),
                    String::new(),
                    String::new(),
                    String::new(),
                ]);
                rows.push(Json::obj(vec![
                    ("shards", Json::num(n as f64)),
                    ("error", Json::str(&format!("{e:#}"))),
                ]));
            }
        }
    }
    let json = Json::obj(vec![
        ("parties", Json::num(cfg.n_parties as f64)),
        ("rounds", Json::num(cfg.rounds as f64)),
        ("seed", Json::num(cfg.seed as f64)),
        ("dim", Json::num(cfg.dim as f64)),
        ("epoch_secs", Json::num(cfg.epoch_secs)),
        ("wall", Json::Bool(cfg.wall)),
        ("shard_scaling", Json::Arr(rows)),
    ]);
    (t, json)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripted_sweep_covers_all_strategies_and_dumps_json() {
        let cfg = LiveSweepConfig {
            n_parties: 3,
            rounds: 2,
            dim: 32,
            wall: false,
            ..Default::default()
        };
        let (_t, json) = run_sweep(&cfg);
        let rows = json.get("strategies").as_arr().unwrap();
        assert_eq!(rows.len(), 6);
        for row in rows {
            assert!(
                row.get("error").as_str().is_none(),
                "strategy {} failed: {:?}",
                row.get("strategy").as_str().unwrap_or("?"),
                row.get("error")
            );
            assert_eq!(row.get("rounds").as_u64(), Some(2));
            assert_eq!(row.get("updates_fused").as_u64(), Some(6));
        }
        crate::bench::dump("BENCH_live", &json);
        let text =
            std::fs::read_to_string(crate::bench::repro_dir().join("BENCH_live.json")).unwrap();
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn shard_sweep_rows_carry_one_model_fingerprint() {
        let cfg = LiveSweepConfig {
            n_parties: 5,
            rounds: 2,
            dim: 32,
            wall: false,
            ..Default::default()
        };
        let (_t, json) = run_shard_sweep(&cfg, &[1, 2, 3, 7]);
        let rows = json.get("shard_scaling").as_arr().unwrap();
        assert_eq!(rows.len(), 4);
        let crc0 = rows[0].get("model_crc32").as_str().unwrap().to_string();
        for row in rows {
            assert!(
                row.get("error").as_str().is_none(),
                "shards={:?} failed: {:?}",
                row.get("shards"),
                row.get("error")
            );
            assert_eq!(row.get("rounds").as_u64(), Some(2));
            assert_eq!(row.get("updates_fused").as_u64(), Some(10));
            assert_eq!(
                row.get("model_crc32").as_str(),
                Some(crc0.as_str()),
                "shards={:?} diverged from the single-fold fingerprint",
                row.get("shards")
            );
        }
    }
}
