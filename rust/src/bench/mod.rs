//! Benchmark/figure harnesses: every table and figure of the paper's
//! evaluation regenerates through this module (used by the `fljit` CLI and
//! the `cargo bench` binaries). Results print as aligned tables mirroring
//! the paper's rows, and are dumped as JSON under `target/repro/`.
//!
//! | module | reproduces | emits |
//! |---|---|---|
//! | [`figs`] | Fig 3/4 (estimator), Fig 7/8 (latency), Fig 9 (cost) | `fig3.json` … `fig9.json` |
//! | [`broker`] | §6.3 multi-job economics, simulated | `BENCH_broker.json` |
//! | [`live`] | Fig 7/9 analogue on the wall-clock path | `BENCH_live.json` |
//! | [`live_broker`] | §6.3 job mix on the *live* platform | `BENCH_live_broker.json` |
//! | [`robustness`] | strategy × fault-scenario degradation matrix | `BENCH_robustness.json` |
//! | [`adaptive`] | learned vs fixed fuse deadlines under shifting arrivals (regret sweep) | `BENCH_adaptive.json` |
//!
//! The perf benches (`cargo bench --bench fusion_hot_path` /
//! `scheduler_hot_path`) additionally emit `BENCH_fusion.json` /
//! `BENCH_scheduler.json`; EXPERIMENTS.md tracks all of them.

pub mod adaptive;
pub mod broker;
pub mod cli;
pub mod figs;
pub mod live;
pub mod live_broker;
pub mod robustness;

use crate::util::json::Json;
use std::path::PathBuf;

/// Where JSON result dumps go.
pub fn repro_dir() -> PathBuf {
    let p = PathBuf::from("target/repro");
    let _ = std::fs::create_dir_all(&p);
    p
}

/// Write a result JSON (best effort; benches still print to stdout).
pub fn dump(name: &str, v: &Json) {
    let path = repro_dir().join(format!("{name}.json"));
    if let Err(e) = std::fs::write(&path, v.pretty()) {
        eprintln!("warn: could not write {path:?}: {e}");
    } else {
        eprintln!("[results written to {path:?}]");
    }
}

/// Wall-clock measurement helper for the perf benches: median + min over
/// `reps` runs of `f` (returns seconds).
pub fn time_median<F: FnMut()>(reps: usize, mut f: F) -> (f64, f64) {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (samples[samples.len() / 2], samples[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_median_orders() {
        let (med, min) = time_median(5, || std::thread::sleep(std::time::Duration::from_micros(200)));
        assert!(min <= med);
        assert!(min >= 0.0001);
    }

    #[test]
    fn dump_writes_json() {
        dump("selftest", &Json::obj(vec![("ok", Json::Bool(true))]));
        let text = std::fs::read_to_string(repro_dir().join("selftest.json")).unwrap();
        assert!(Json::parse(&text).unwrap().get("ok").as_bool().unwrap());
    }
}
