//! Multi-tenant job broker — the control plane between job submission and
//! the execution platforms (the virtual-time
//! [`coordinator::platform`](crate::coordinator::platform) and the
//! wall-clock [`coordinator::live`](crate::coordinator::live)).
//!
//! The paper's economics argument (§1, §6.2–6.3) is about *fleets* of FL
//! jobs sharing cloud aggregation capacity. This subsystem turns the
//! repo's platform from "several independent jobs admitted at t = 0"
//! into that shared cluster:
//!
//! * [`workload`] — job-arrival generation: Poisson/trace-driven
//!   submissions over the three §6.3 workload profiles, mixed
//!   active/intermittent fleets, party counts up to 10k, SLO classes.
//!   Traces persist as JSON ([`JobTrace::save`]/[`JobTrace::load`]), the
//!   on-disk format live resumes re-admit queued jobs from.
//! * [`admission`] — admission control: per-job container-demand quotas
//!   against a budget with SLO-ordered queueing/backpressure, so jobs wait
//!   for headroom instead of oversubscribing the cluster unboundedly.
//! * [`arbitration`] — the pluggable [`ArbitrationPolicy`]
//!   (deadline-priority §5.5 baseline, least-slack-first, weighted fair
//!   share of container-seconds) wired into the cluster's scheduling
//!   decisions on **both sides**: `pick` chooses which job's aggregation
//!   task starts when capacity frees, and `preempt_victim` chooses which
//!   running task is evicted when a pending one needs the slot
//!   (arbitration-aware preemption — deadline keeps the §5.5
//!   latest-deadline victim order, least-slack evicts the slackest task,
//!   wfs the most-overserved tenant's). The non-baseline policies *age*
//!   waiting candidates (`Candidate::waited_secs`), so no tenant starves
//!   behind a stream of fresher, better-scoring tasks; every preemption
//!   decision lands in `Cluster::preemption_log`, pinning bit-identical
//!   replay per (seed, trace, policy).
//!
//! Two replay paths share this control plane:
//!
//! * **Simulated** — `Session::sim().trace(..)` replays one
//!   [`JobTrace`](workload::JobTrace) under one policy in virtual time
//!   and reports per-job queue waits, latency inflation vs an
//!   uncontended solo run, and cluster utilization; `bench::broker`
//!   sweeps the same trace across all policies (`BENCH_broker.json`).
//! * **Live** — `Session::live().trace(..)` replays the same
//!   trace under the wall-clock driver: jobs arrive at their trace
//!   times, pass this module's admission control, share one arbitrated
//!   cluster, and fold *real* updates through per-job MQ topics with
//!   per-job §5.5 checkpoints and model topics; `bench::live_broker`
//!   sweeps it (`BENCH_live_broker.json`, CLI `fljit live-broker`).
//!   Sim and live multi-job reports are bit-identical under an instant
//!   clock with scripted parties (`tests/live_broker_equivalence.rs`).
//!
//! [`ArbitrationPolicy`]: arbitration::ArbitrationPolicy
//! [`JobTrace::save`]: workload::JobTrace::save
//! [`JobTrace::load`]: workload::JobTrace::load

pub mod admission;
pub mod arbitration;
pub mod workload;

use crate::coordinator::platform::{Platform, PlatformConfig};

use admission::AdmissionConfig;
use workload::{JobArrival, JobTrace};

/// Peak number of simultaneously active jobs given `(start, end)`
/// activity intervals in seconds — the "N-concurrent-job" figure of the
/// sweeps, shared by the sim and live broker reports.
pub fn peak_concurrency<I: IntoIterator<Item = (f64, f64)>>(intervals: I) -> usize {
    let mut events: Vec<(f64, i32)> = Vec::new();
    for (start, end) in intervals {
        if end > start {
            events.push((start, 1));
            events.push((end, -1));
        }
    }
    // -1 sorts before +1 at equal times: back-to-back jobs don't overlap
    events.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut cur = 0i32;
    let mut peak = 0i32;
    for (_, d) in events {
        cur += d;
        peak = peak.max(cur);
    }
    peak.max(0) as usize
}

/// Service classes the broker offers (admission order + fair-share weight).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SloClass {
    Premium,
    Standard,
    BestEffort,
}

impl SloClass {
    pub fn name(self) -> &'static str {
        match self {
            SloClass::Premium => "premium",
            SloClass::Standard => "standard",
            SloClass::BestEffort => "best-effort",
        }
    }

    /// Admission-queue rank (smaller admits first).
    pub fn rank(self) -> u8 {
        match self {
            SloClass::Premium => 0,
            SloClass::Standard => 1,
            SloClass::BestEffort => 2,
        }
    }

    /// Fair-share weight for [`arbitration::WeightedFairShare`].
    pub fn weight(self) -> f64 {
        match self {
            SloClass::Premium => 4.0,
            SloClass::Standard => 2.0,
            SloClass::BestEffort => 1.0,
        }
    }

    /// Parse a class name (the on-disk trace format, `workload::JobTrace`).
    pub fn parse(s: &str) -> Option<SloClass> {
        match s {
            "premium" => Some(SloClass::Premium),
            "standard" => Some(SloClass::Standard),
            "best-effort" | "besteffort" => Some(SloClass::BestEffort),
            _ => None,
        }
    }
}

/// The platform derives each job's fleet RNG as `seed ^ job·φ`; folding
/// the broker job index into a solo platform's seed reproduces the exact
/// fleet and arrival randomness for job 0 of that platform.
fn solo_seed(seed: u64, job: usize) -> u64 {
    seed ^ (job as u64).wrapping_mul(0x9E3779B9)
}

/// Uncontended baseline: the same job alone on an amply sized cluster
/// (used by `Session::solo_baselines`).
pub(crate) fn solo_mean_latency(arr: &JobArrival, seed: u64, job: usize) -> f64 {
    let mut pcfg = PlatformConfig {
        seed: solo_seed(seed, job),
        ..Default::default()
    };
    pcfg.cluster.capacity =
        (arr.spec.workload.n_agg(arr.spec.n_parties) as usize * 4).max(64);
    let mut p = Platform::new(pcfg);
    p.admit(arr.spec.clone(), &arr.strategy);
    p.run().remove(0).mean_latency_secs()
}

#[cfg(test)]
mod tests {
    use super::workload::{poisson_trace, TraceConfig};
    use super::*;

    fn tiny_trace(seed: u64) -> JobTrace {
        poisson_trace(&TraceConfig {
            n_jobs: 4,
            mean_interarrival_secs: 10.0,
            party_mix: vec![(6, 0.6), (12, 0.4)],
            intermittent_frac: 0.25,
            rounds_lo: 2,
            rounds_hi: 2,
            t_wait_secs: 60.0,
            seed,
            ..Default::default()
        })
    }

    use crate::coordinator::session::Session;

    #[test]
    fn broker_run_completes_every_job() {
        let trace = tiny_trace(5);
        let rep = Session::sim()
            .trace(&trace)
            .policy("deadline")
            .admission(AdmissionConfig {
                budget: 32,
                max_jobs: 0,
                autoscale: None,
            })
            .capacity(8)
            .seed(77)
            .solo_baselines(true)
            .run()
            .expect("sim trace replay");
        let sum = rep.summary();
        assert_eq!(sum.jobs.len(), 4);
        for o in &sum.jobs {
            assert_eq!(
                o.records.len() as u32,
                trace.arrivals[o.job].spec.rounds,
                "job {} must finish all rounds",
                o.name
            );
            assert!(o.latency_inflation().is_some());
        }
        assert!(sum.cluster_utilization > 0.0);
        assert!(sum.span_secs > 0.0);
        assert!(sum.max_concurrent_jobs() >= 1);
    }

    #[test]
    fn tight_budget_queues_jobs_and_releases_them() {
        let trace = tiny_trace(9);
        // budget 1 admits one job at a time: later arrivals must wait
        let rep = Session::sim()
            .trace(&trace)
            .policy("deadline")
            .admission(AdmissionConfig {
                budget: 1,
                max_jobs: 1,
                autoscale: None,
            })
            .capacity(8)
            .seed(78)
            .run()
            .expect("sim trace replay");
        let sum = rep.summary();
        assert_eq!(sum.jobs.len(), 4);
        for o in &sum.jobs {
            assert_eq!(o.records.len() as u32, trace.arrivals[o.job].spec.rounds);
        }
        assert!(
            sum.jobs.iter().any(|o| o.queue_wait_secs > 1.0),
            "serialized admission must produce queue waits"
        );
        assert_eq!(sum.max_concurrent_jobs(), 1, "max_jobs quota of 1");
    }

    #[test]
    fn slo_weights_and_ranks_are_ordered() {
        assert!(SloClass::Premium.weight() > SloClass::Standard.weight());
        assert!(SloClass::Standard.weight() > SloClass::BestEffort.weight());
        assert!(SloClass::Premium.rank() < SloClass::BestEffort.rank());
        assert_eq!(SloClass::Premium.name(), "premium");
    }

    #[test]
    fn slo_parse_roundtrips_names() {
        for c in [SloClass::Premium, SloClass::Standard, SloClass::BestEffort] {
            assert_eq!(SloClass::parse(c.name()), Some(c));
        }
        assert!(SloClass::parse("gold").is_none());
    }
}
