//! Multi-tenant job broker — the control plane between job submission and
//! the execution platforms (the virtual-time
//! [`coordinator::platform`](crate::coordinator::platform) and the
//! wall-clock [`coordinator::live`](crate::coordinator::live)).
//!
//! The paper's economics argument (§1, §6.2–6.3) is about *fleets* of FL
//! jobs sharing cloud aggregation capacity. This subsystem turns the
//! repo's platform from "several independent jobs admitted at t = 0"
//! into that shared cluster:
//!
//! * [`workload`] — job-arrival generation: Poisson/trace-driven
//!   submissions over the three §6.3 workload profiles, mixed
//!   active/intermittent fleets, party counts up to 10k, SLO classes.
//!   Traces persist as JSON ([`JobTrace::save`]/[`JobTrace::load`]), the
//!   on-disk format live resumes re-admit queued jobs from.
//! * [`admission`] — admission control: per-job container-demand quotas
//!   against a budget with SLO-ordered queueing/backpressure, so jobs wait
//!   for headroom instead of oversubscribing the cluster unboundedly.
//! * [`arbitration`] — the pluggable [`ArbitrationPolicy`]
//!   (deadline-priority §5.5 baseline, least-slack-first, weighted fair
//!   share of container-seconds) wired into the cluster's scheduling
//!   decisions on **both sides**: `pick` chooses which job's aggregation
//!   task starts when capacity frees, and `preempt_victim` chooses which
//!   running task is evicted when a pending one needs the slot
//!   (arbitration-aware preemption — deadline keeps the §5.5
//!   latest-deadline victim order, least-slack evicts the slackest task,
//!   wfs the most-overserved tenant's). The non-baseline policies *age*
//!   waiting candidates (`Candidate::waited_secs`), so no tenant starves
//!   behind a stream of fresher, better-scoring tasks; every preemption
//!   decision lands in `Cluster::preemption_log`, pinning bit-identical
//!   replay per (seed, trace, policy).
//!
//! Two replay paths share this control plane:
//!
//! * **Simulated** — [`run_trace`] replays one
//!   [`JobTrace`](workload::JobTrace) under one policy in virtual time
//!   and reports per-job queue waits, latency inflation vs an
//!   uncontended solo run, and cluster utilization; `bench::broker`
//!   sweeps the same trace across all policies (`BENCH_broker.json`).
//! * **Live** — `coordinator::live::run_live_broker` replays the same
//!   trace under the wall-clock driver: jobs arrive at their trace
//!   times, pass this module's admission control, share one arbitrated
//!   cluster, and fold *real* updates through per-job MQ topics with
//!   per-job §5.5 checkpoints and model topics; `bench::live_broker`
//!   sweeps it (`BENCH_live_broker.json`, CLI `fljit live-broker`).
//!   Sim and live multi-job reports are bit-identical under an instant
//!   clock with scripted parties (`tests/live_broker_equivalence.rs`).
//!
//! [`ArbitrationPolicy`]: arbitration::ArbitrationPolicy
//! [`JobTrace::save`]: workload::JobTrace::save
//! [`JobTrace::load`]: workload::JobTrace::load

pub mod admission;
pub mod arbitration;
pub mod workload;

use crate::coordinator::platform::{Platform, PlatformConfig};
use crate::metrics::JobReport;
use crate::util::json::Json;

use admission::AdmissionConfig;
use workload::{JobArrival, JobTrace};

/// Peak number of simultaneously active jobs given `(start, end)`
/// activity intervals in seconds — the "N-concurrent-job" figure of the
/// sweeps, shared by the sim and live broker reports.
pub fn peak_concurrency<I: IntoIterator<Item = (f64, f64)>>(intervals: I) -> usize {
    let mut events: Vec<(f64, i32)> = Vec::new();
    for (start, end) in intervals {
        if end > start {
            events.push((start, 1));
            events.push((end, -1));
        }
    }
    // -1 sorts before +1 at equal times: back-to-back jobs don't overlap
    events.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut cur = 0i32;
    let mut peak = 0i32;
    for (_, d) in events {
        cur += d;
        peak = peak.max(cur);
    }
    peak.max(0) as usize
}

/// Service classes the broker offers (admission order + fair-share weight).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SloClass {
    Premium,
    Standard,
    BestEffort,
}

impl SloClass {
    pub fn name(self) -> &'static str {
        match self {
            SloClass::Premium => "premium",
            SloClass::Standard => "standard",
            SloClass::BestEffort => "best-effort",
        }
    }

    /// Admission-queue rank (smaller admits first).
    pub fn rank(self) -> u8 {
        match self {
            SloClass::Premium => 0,
            SloClass::Standard => 1,
            SloClass::BestEffort => 2,
        }
    }

    /// Fair-share weight for [`arbitration::WeightedFairShare`].
    pub fn weight(self) -> f64 {
        match self {
            SloClass::Premium => 4.0,
            SloClass::Standard => 2.0,
            SloClass::BestEffort => 1.0,
        }
    }

    /// Parse a class name (the on-disk trace format, `workload::JobTrace`).
    pub fn parse(s: &str) -> Option<SloClass> {
        match s {
            "premium" => Some(SloClass::Premium),
            "standard" => Some(SloClass::Standard),
            "best-effort" | "besteffort" => Some(SloClass::BestEffort),
            _ => None,
        }
    }
}

/// One broker run's configuration.
#[derive(Clone, Debug)]
pub struct BrokerConfig {
    /// Cluster container capacity shared by every admitted job.
    pub capacity: usize,
    pub admission: AdmissionConfig,
    /// Arbitration policy name (see [`arbitration::by_name`]).
    pub policy: String,
    pub seed: u64,
    /// Also run each job solo on an uncontended cluster to measure
    /// latency inflation (doubles the work; off for quick runs).
    pub with_solo: bool,
}

impl Default for BrokerConfig {
    fn default() -> Self {
        BrokerConfig {
            capacity: 96,
            admission: AdmissionConfig::default(),
            policy: "deadline".to_string(),
            seed: 0xB40C,
            with_solo: false,
        }
    }
}

/// One job's outcome in a broker run.
#[derive(Clone, Debug)]
pub struct BrokerJobOutcome {
    pub job: usize,
    pub name: String,
    pub class: SloClass,
    pub arrival_secs: f64,
    /// Admission backpressure: seconds queued before the job started.
    pub queue_wait_secs: f64,
    pub report: JobReport,
    /// Mean aggregation latency of the same job (same fleet, same arrival
    /// randomness) run alone on an uncontended cluster.
    pub solo_mean_latency_secs: Option<f64>,
}

impl BrokerJobOutcome {
    /// Contended / solo mean-latency ratio (1.0 = no inflation).
    pub fn latency_inflation(&self) -> Option<f64> {
        let solo = self.solo_mean_latency_secs?;
        if solo <= 0.0 {
            return None;
        }
        Some(self.report.mean_latency_secs() / solo)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("job", Json::num(self.job as f64)),
            ("name", Json::str(&self.name)),
            ("class", Json::str(self.class.name())),
            ("arrival_secs", Json::num(self.arrival_secs)),
            ("queue_wait_secs", Json::num(self.queue_wait_secs)),
            (
                "solo_mean_latency_secs",
                match self.solo_mean_latency_secs {
                    Some(v) => Json::num(v),
                    None => Json::Null,
                },
            ),
            (
                "latency_inflation",
                match self.latency_inflation() {
                    Some(v) => Json::num(v),
                    None => Json::Null,
                },
            ),
            ("report", self.report.to_json()),
        ])
    }
}

/// A whole broker run's report (one policy over one trace).
#[derive(Clone, Debug)]
pub struct BrokerReport {
    pub policy: String,
    pub capacity: usize,
    pub jobs: Vec<BrokerJobOutcome>,
    /// Σ container-seconds / (capacity × span): how busy the shared
    /// cluster was over the run.
    pub cluster_utilization: f64,
    pub total_container_seconds: f64,
    pub span_secs: f64,
    /// Preemption decisions `(secs, victim task)` in decision order —
    /// the policy-determinism pin for arbitration-aware preemption.
    pub preemptions: Vec<(f64, usize)>,
}

impl BrokerReport {
    pub fn mean_queue_wait_secs(&self) -> f64 {
        if self.jobs.is_empty() {
            return 0.0;
        }
        self.jobs.iter().map(|j| j.queue_wait_secs).sum::<f64>() / self.jobs.len() as f64
    }

    pub fn mean_latency_inflation(&self) -> Option<f64> {
        let vals: Vec<f64> = self.jobs.iter().filter_map(|j| j.latency_inflation()).collect();
        if vals.is_empty() {
            None
        } else {
            Some(vals.iter().sum::<f64>() / vals.len() as f64)
        }
    }

    /// Peak number of jobs simultaneously admitted (running).
    pub fn max_concurrent_jobs(&self) -> usize {
        peak_concurrency(self.jobs.iter().map(|o| {
            (o.arrival_secs + o.queue_wait_secs, o.report.makespan_secs)
        }))
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::str(&self.policy)),
            ("capacity", Json::num(self.capacity as f64)),
            ("cluster_utilization", Json::num(self.cluster_utilization)),
            (
                "total_container_seconds",
                Json::num(self.total_container_seconds),
            ),
            ("span_secs", Json::num(self.span_secs)),
            ("preemptions", Json::num(self.preemptions.len() as f64)),
            (
                "max_concurrent_jobs",
                Json::num(self.max_concurrent_jobs() as f64),
            ),
            ("mean_queue_wait_secs", Json::num(self.mean_queue_wait_secs())),
            (
                "mean_latency_inflation",
                match self.mean_latency_inflation() {
                    Some(v) => Json::num(v),
                    None => Json::Null,
                },
            ),
            (
                "jobs",
                Json::Arr(self.jobs.iter().map(|j| j.to_json()).collect()),
            ),
        ])
    }
}

/// The platform derives each job's fleet RNG as `seed ^ job·φ`; folding
/// the broker job index into a solo platform's seed reproduces the exact
/// fleet and arrival randomness for job 0 of that platform.
fn solo_seed(seed: u64, job: usize) -> u64 {
    seed ^ (job as u64).wrapping_mul(0x9E3779B9)
}

/// Uncontended baseline: the same job alone on an amply sized cluster
/// (used by `Session::solo_baselines` and the `run_trace` shim).
pub(crate) fn solo_mean_latency(arr: &JobArrival, seed: u64, job: usize) -> f64 {
    let mut pcfg = PlatformConfig {
        seed: solo_seed(seed, job),
        ..Default::default()
    };
    pcfg.cluster.capacity =
        (arr.spec.workload.n_agg(arr.spec.n_parties) as usize * 4).max(64);
    let mut p = Platform::new(pcfg);
    p.admit(arr.spec.clone(), &arr.strategy);
    p.run().remove(0).mean_latency_secs()
}

/// Replay `trace` under `cfg`: jobs arrive over time, pass admission
/// control, and share one cluster whose pending queue is ordered by the
/// configured arbitration policy.
#[deprecated(
    since = "0.3.0",
    note = "use coordinator::session::Session::sim() with .trace(..) — this shim maps onto it"
)]
pub fn run_trace(trace: &JobTrace, cfg: &BrokerConfig) -> BrokerReport {
    use crate::coordinator::session::{Report, Session};
    if trace.is_empty() {
        // preserved legacy behavior: an empty trace is an empty report,
        // not an error (Session::run rejects job-less sessions)
        return BrokerReport {
            policy: cfg.policy.clone(),
            capacity: cfg.capacity,
            jobs: Vec::new(),
            cluster_utilization: 0.0,
            total_container_seconds: 0.0,
            span_secs: 0.0,
            preemptions: Vec::new(),
        };
    }
    let rep = Session::sim()
        .trace(trace)
        .policy(&cfg.policy)
        .admission(cfg.admission.clone())
        .capacity(cfg.capacity)
        .seed(cfg.seed)
        .solo_baselines(cfg.with_solo)
        .run()
        .unwrap_or_else(|e| panic!("broker trace replay failed: {e:#}"));
    let (Report::Sim(sum) | Report::Live(sum) | Report::Wall(sum)) = rep;
    BrokerReport {
        policy: sum.policy,
        capacity: cfg.capacity,
        jobs: sum
            .jobs
            .into_iter()
            .map(|o| BrokerJobOutcome {
                job: o.job,
                name: o.name.clone(),
                class: o.class,
                arrival_secs: o.arrival_secs,
                queue_wait_secs: o.queue_wait_secs,
                solo_mean_latency_secs: o.solo_mean_latency_secs,
                report: o.to_job_report(),
            })
            .collect(),
        cluster_utilization: sum.cluster_utilization,
        total_container_seconds: sum.total_container_seconds,
        span_secs: sum.span_secs,
        preemptions: sum.preemptions,
    }
}

#[cfg(test)]
mod tests {
    use super::workload::{poisson_trace, TraceConfig};
    use super::*;

    fn tiny_trace(seed: u64) -> JobTrace {
        poisson_trace(&TraceConfig {
            n_jobs: 4,
            mean_interarrival_secs: 10.0,
            party_mix: vec![(6, 0.6), (12, 0.4)],
            intermittent_frac: 0.25,
            rounds_lo: 2,
            rounds_hi: 2,
            t_wait_secs: 60.0,
            seed,
            ..Default::default()
        })
    }

    use crate::coordinator::session::Session;

    #[test]
    fn broker_run_completes_every_job() {
        let trace = tiny_trace(5);
        let rep = Session::sim()
            .trace(&trace)
            .policy("deadline")
            .admission(AdmissionConfig {
                budget: 32,
                max_jobs: 0,
            })
            .capacity(8)
            .seed(77)
            .solo_baselines(true)
            .run()
            .expect("sim trace replay");
        let sum = rep.summary();
        assert_eq!(sum.jobs.len(), 4);
        for o in &sum.jobs {
            assert_eq!(
                o.records.len() as u32,
                trace.arrivals[o.job].spec.rounds,
                "job {} must finish all rounds",
                o.name
            );
            assert!(o.latency_inflation().is_some());
        }
        assert!(sum.cluster_utilization > 0.0);
        assert!(sum.span_secs > 0.0);
        assert!(sum.max_concurrent_jobs() >= 1);
    }

    #[test]
    fn tight_budget_queues_jobs_and_releases_them() {
        let trace = tiny_trace(9);
        // budget 1 admits one job at a time: later arrivals must wait
        let rep = Session::sim()
            .trace(&trace)
            .policy("deadline")
            .admission(AdmissionConfig {
                budget: 1,
                max_jobs: 1,
            })
            .capacity(8)
            .seed(78)
            .run()
            .expect("sim trace replay");
        let sum = rep.summary();
        assert_eq!(sum.jobs.len(), 4);
        for o in &sum.jobs {
            assert_eq!(o.records.len() as u32, trace.arrivals[o.job].spec.rounds);
        }
        assert!(
            sum.jobs.iter().any(|o| o.queue_wait_secs > 1.0),
            "serialized admission must produce queue waits"
        );
        assert_eq!(sum.max_concurrent_jobs(), 1, "max_jobs quota of 1");
    }

    #[test]
    #[allow(deprecated)]
    fn run_trace_shim_matches_the_session_facade() {
        // the one sanctioned in-tree run_trace call: pin that the shim's
        // legacy BrokerReport projection matches the Session results
        let trace = tiny_trace(5);
        let cfg = BrokerConfig {
            capacity: 8,
            admission: AdmissionConfig {
                budget: 32,
                max_jobs: 0,
            },
            policy: "wfs".into(),
            seed: 77,
            with_solo: false,
        };
        let shim = run_trace(&trace, &cfg);
        let rep = Session::sim()
            .trace(&trace)
            .policy("wfs")
            .admission(cfg.admission.clone())
            .capacity(8)
            .seed(77)
            .run()
            .expect("session run");
        let sum = rep.summary();
        assert_eq!(shim.jobs.len(), sum.jobs.len());
        for (a, b) in shim.jobs.iter().zip(&sum.jobs) {
            assert_eq!(a.report.rounds.len(), b.records.len());
            assert_eq!(a.queue_wait_secs.to_bits(), b.queue_wait_secs.to_bits());
            assert_eq!(a.report.updates_fused, b.updates_fused);
            assert_eq!(a.report.makespan_secs.to_bits(), b.makespan_secs.to_bits());
        }
        assert_eq!(
            shim.total_container_seconds.to_bits(),
            sum.total_container_seconds.to_bits()
        );
        assert_eq!(shim.preemptions, sum.preemptions);
    }

    #[test]
    fn slo_weights_and_ranks_are_ordered() {
        assert!(SloClass::Premium.weight() > SloClass::Standard.weight());
        assert!(SloClass::Standard.weight() > SloClass::BestEffort.weight());
        assert!(SloClass::Premium.rank() < SloClass::BestEffort.rank());
        assert_eq!(SloClass::Premium.name(), "premium");
    }

    #[test]
    fn slo_parse_roundtrips_names() {
        for c in [SloClass::Premium, SloClass::Standard, SloClass::BestEffort] {
            assert_eq!(SloClass::parse(c.name()), Some(c));
        }
        assert!(SloClass::parse("gold").is_none());
    }
}
