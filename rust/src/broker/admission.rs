//! Admission control: capacity quotas, SLO classes and queueing.
//!
//! The broker commits an estimated peak container demand per job (the
//! workload's `N_agg` gang size) against a budget; jobs that do not fit
//! wait in an SLO-then-FIFO queue until running jobs finish and free
//! committed capacity — backpressure instead of unbounded oversubscription.
//! The budget may deliberately exceed the raw cluster capacity
//! (statistical overcommit: JIT gangs are short-lived bursts), in which
//! case the cross-job [`arbitration`](super::arbitration) policy decides
//! who runs when bursts collide.
//!
//! Everything here is a deterministic function of (registration order,
//! arrival order, finish order), so broker runs replay bit-identically.

use std::collections::BTreeSet;

use crate::sim::{to_secs, Time};

use super::SloClass;

#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// Aggregator-container demand the controller may commit concurrently.
    pub budget: usize,
    /// Max concurrently admitted jobs (0 = unlimited).
    pub max_jobs: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            budget: 256,
            max_jobs: 0,
        }
    }
}

/// Per-job admission record (broker bookkeeping + queue-wait metrics).
#[derive(Clone, Debug)]
pub struct JobAdmission {
    /// Committed container demand (clamped into the budget so every job
    /// is eventually admissible).
    pub demand: usize,
    pub class: SloClass,
    pub arrived_at: Option<Time>,
    pub admitted_at: Option<Time>,
    pub finished_at: Option<Time>,
}

/// The admission controller: tracks committed demand and the wait queue.
#[derive(Clone, Debug)]
pub struct AdmissionController {
    cfg: AdmissionConfig,
    jobs: Vec<JobAdmission>,
    committed: usize,
    running: usize,
    /// Waiting jobs ordered by (SLO rank, arrival seq, job): premium
    /// first, FIFO within a class.
    wait: BTreeSet<(u8, u64, usize)>,
    arrival_seq: u64,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> AdmissionController {
        AdmissionController {
            cfg,
            jobs: Vec::new(),
            committed: 0,
            running: 0,
            wait: BTreeSet::new(),
            arrival_seq: 0,
        }
    }

    fn budget(&self) -> usize {
        self.cfg.budget.max(1)
    }

    /// Register a job before the run starts. Jobs must be registered in
    /// platform id order (dense ids).
    pub fn register(&mut self, job: usize, demand: usize, class: SloClass) {
        assert_eq!(job, self.jobs.len(), "register jobs in platform id order");
        let demand = demand.clamp(1, self.budget());
        self.jobs.push(JobAdmission {
            demand,
            class,
            arrived_at: None,
            admitted_at: None,
            finished_at: None,
        });
    }

    /// The job's submission reached the broker; returns every job (possibly
    /// including this one) that may start now.
    pub fn arrive(&mut self, job: usize, now: Time) -> Vec<usize> {
        let seq = self.arrival_seq;
        self.arrival_seq += 1;
        self.jobs[job].arrived_at = Some(now);
        self.wait.insert((self.jobs[job].class.rank(), seq, job));
        self.drain(now)
    }

    /// A running job finished; its committed demand frees, possibly
    /// releasing queued jobs.
    pub fn finish(&mut self, job: usize, now: Time) -> Vec<usize> {
        let j = &mut self.jobs[job];
        if j.admitted_at.is_some() && j.finished_at.is_none() {
            j.finished_at = Some(now);
            self.committed -= j.demand;
            self.running -= 1;
        }
        self.drain(now)
    }

    /// Admit waiting jobs in (SLO rank, FIFO) order while the budget (and
    /// the job-count quota) holds. Head-of-line blocking is deliberate —
    /// no bypass — so admission order is deterministic and every job is
    /// eventually admitted as committed demand drains.
    fn drain(&mut self, now: Time) -> Vec<usize> {
        let mut started = Vec::new();
        loop {
            let Some(&(rank, seq, job)) = self.wait.iter().next() else {
                break;
            };
            let demand = self.jobs[job].demand;
            if self.committed + demand > self.budget() {
                break;
            }
            if self.cfg.max_jobs > 0 && self.running >= self.cfg.max_jobs {
                break;
            }
            self.wait.remove(&(rank, seq, job));
            self.committed += demand;
            self.running += 1;
            self.jobs[job].admitted_at = Some(now);
            started.push(job);
        }
        started
    }

    /// Seconds the job spent queued between arrival and admission.
    pub fn queue_wait_secs(&self, job: usize) -> f64 {
        match self.jobs.get(job) {
            Some(JobAdmission {
                arrived_at: Some(a),
                admitted_at: Some(s),
                ..
            }) => to_secs(s.saturating_sub(*a)),
            _ => 0.0,
        }
    }

    pub fn job(&self, job: usize) -> &JobAdmission {
        &self.jobs[job]
    }

    /// Currently committed container demand.
    pub fn committed(&self) -> usize {
        self.committed
    }

    /// Jobs currently waiting for admission.
    pub fn queued(&self) -> usize {
        self.wait.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::secs;

    #[test]
    fn fifo_admission_within_budget() {
        let mut c = AdmissionController::new(AdmissionConfig {
            budget: 10,
            max_jobs: 0,
        });
        c.register(0, 4, SloClass::Standard);
        c.register(1, 4, SloClass::Standard);
        c.register(2, 4, SloClass::Standard);
        assert_eq!(c.arrive(0, secs(1.0)), vec![0]);
        assert_eq!(c.arrive(1, secs(2.0)), vec![1]);
        // third job would exceed the budget (12 > 10): backpressure
        assert_eq!(c.arrive(2, secs(3.0)), vec![]);
        assert_eq!(c.queued(), 1);
        assert_eq!(c.committed(), 8);
        // job 0 finishing frees demand; job 2 releases
        assert_eq!(c.finish(0, secs(50.0)), vec![2]);
        assert!((c.queue_wait_secs(2) - 47.0).abs() < 1e-9);
        assert_eq!(c.queue_wait_secs(0), 0.0, "admitted instantly");
    }

    #[test]
    fn slo_classes_jump_the_fifo_queue() {
        let mut c = AdmissionController::new(AdmissionConfig {
            budget: 4,
            max_jobs: 0,
        });
        c.register(0, 4, SloClass::BestEffort);
        c.register(1, 4, SloClass::BestEffort);
        c.register(2, 4, SloClass::Premium);
        assert_eq!(c.arrive(0, secs(1.0)), vec![0]);
        assert_eq!(c.arrive(1, secs(2.0)), vec![]);
        assert_eq!(c.arrive(2, secs(3.0)), vec![]);
        // premium (job 2) outranks the earlier best-effort arrival (job 1)
        assert_eq!(c.finish(0, secs(10.0)), vec![2]);
        assert_eq!(c.finish(2, secs(20.0)), vec![1]);
    }

    #[test]
    fn oversized_demand_is_clamped_so_jobs_still_admit() {
        let mut c = AdmissionController::new(AdmissionConfig {
            budget: 8,
            max_jobs: 0,
        });
        c.register(0, 500, SloClass::Standard);
        assert_eq!(c.job(0).demand, 8, "demand clamped into the budget");
        assert_eq!(c.arrive(0, 0), vec![0]);
    }

    #[test]
    fn max_jobs_quota_limits_concurrency() {
        let mut c = AdmissionController::new(AdmissionConfig {
            budget: 100,
            max_jobs: 1,
        });
        c.register(0, 1, SloClass::Standard);
        c.register(1, 1, SloClass::Standard);
        assert_eq!(c.arrive(0, 0), vec![0]);
        assert_eq!(c.arrive(1, 0), vec![], "job quota holds job 1 back");
        assert_eq!(c.finish(0, secs(5.0)), vec![1]);
    }
}
