//! Admission control: capacity quotas, SLO classes and queueing.
//!
//! The broker commits an estimated peak container demand per job (the
//! workload's `N_agg` gang size) against a budget; jobs that do not fit
//! wait in an SLO-then-FIFO queue until running jobs finish and free
//! committed capacity — backpressure instead of unbounded oversubscription.
//! The budget may deliberately exceed the raw cluster capacity
//! (statistical overcommit: JIT gangs are short-lived bursts), in which
//! case the cross-job [`arbitration`](super::arbitration) policy decides
//! who runs when bursts collide.
//!
//! Everything here is a deterministic function of (registration order,
//! arrival order, finish order), so broker runs replay bit-identically.

use std::collections::BTreeSet;

use crate::sim::{to_secs, Time};

use super::SloClass;

#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// Aggregator-container demand the controller may commit concurrently.
    pub budget: usize,
    /// Max concurrently admitted jobs (0 = unlimited).
    pub max_jobs: usize,
    /// Adaptive budget bounds `(min, max)` (PR 10, [`crate::adapt`]):
    /// when set, the effective budget starts at `budget.clamp(min, max)`
    /// and autoscales deterministically with observed backpressure — it
    /// grows by the queued demand when an arrival has to wait (up to
    /// `max`) and shrinks by the freed demand when a job finishes with
    /// nobody waiting (down to `min`). `None` (the default) keeps the
    /// fixed budget, bit-identical to every pre-PR-10 run.
    pub autoscale: Option<(usize, usize)>,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            budget: 256,
            max_jobs: 0,
            autoscale: None,
        }
    }
}

/// Per-job admission record (broker bookkeeping + queue-wait metrics).
#[derive(Clone, Debug)]
pub struct JobAdmission {
    /// Committed container demand (clamped into the budget so every job
    /// is eventually admissible).
    pub demand: usize,
    pub class: SloClass,
    pub arrived_at: Option<Time>,
    pub admitted_at: Option<Time>,
    pub finished_at: Option<Time>,
}

/// The admission controller: tracks committed demand and the wait queue.
#[derive(Clone, Debug)]
pub struct AdmissionController {
    cfg: AdmissionConfig,
    jobs: Vec<JobAdmission>,
    committed: usize,
    running: usize,
    /// Waiting jobs ordered by (SLO rank, arrival seq, job): premium
    /// first, FIFO within a class.
    wait: BTreeSet<(u8, u64, usize)>,
    arrival_seq: u64,
    /// Effective budget under [`AdmissionConfig::autoscale`]; equals
    /// `cfg.budget` (and never moves) when autoscale is off.
    auto_budget: usize,
}

impl AdmissionController {
    pub fn new(cfg: AdmissionConfig) -> AdmissionController {
        let auto_budget = match cfg.autoscale {
            Some((lo, hi)) => cfg.budget.clamp(lo.max(1), hi.max(1)),
            None => cfg.budget,
        };
        AdmissionController {
            cfg,
            jobs: Vec::new(),
            committed: 0,
            running: 0,
            wait: BTreeSet::new(),
            arrival_seq: 0,
            auto_budget,
        }
    }

    fn budget(&self) -> usize {
        self.auto_budget.max(1)
    }

    /// Autoscale step: grow on backpressure (an arrival had to queue),
    /// shrink on idle frees (a finish with an empty wait queue). A pure
    /// function of (config, arrival order, finish order) — no clocks, no
    /// rng — so autoscaled runs replay bit-identically.
    fn autoscale_step(&mut self, pressure_demand: usize, grow: bool) {
        let Some((lo, hi)) = self.cfg.autoscale else {
            return;
        };
        let (lo, hi) = (lo.max(1), hi.max(lo.max(1)));
        self.auto_budget = if grow {
            (self.auto_budget + pressure_demand).min(hi)
        } else {
            self.auto_budget.saturating_sub(pressure_demand).max(lo)
        };
    }

    /// Register a job before the run starts. Jobs must be registered in
    /// platform id order (dense ids).
    pub fn register(&mut self, job: usize, demand: usize, class: SloClass) {
        assert_eq!(job, self.jobs.len(), "register jobs in platform id order");
        // under autoscale, clamp against the cap the budget can grow to
        let cap = match self.cfg.autoscale {
            Some((lo, hi)) => hi.max(lo.max(1)),
            None => self.budget(),
        };
        let demand = demand.clamp(1, cap);
        self.jobs.push(JobAdmission {
            demand,
            class,
            arrived_at: None,
            admitted_at: None,
            finished_at: None,
        });
    }

    /// The job's submission reached the broker; returns every job (possibly
    /// including this one) that may start now.
    pub fn arrive(&mut self, job: usize, now: Time) -> Vec<usize> {
        let seq = self.arrival_seq;
        self.arrival_seq += 1;
        self.jobs[job].arrived_at = Some(now);
        self.wait.insert((self.jobs[job].class.rank(), seq, job));
        let mut started = self.drain(now);
        if self.cfg.autoscale.is_some() && !started.contains(&job) {
            // backpressure observed: grow the budget toward the cap and
            // retry — the arrival (or an earlier queued job) may now fit
            self.autoscale_step(self.jobs[job].demand, true);
            started.extend(self.drain(now));
        }
        started
    }

    /// A running job finished; its committed demand frees, possibly
    /// releasing queued jobs.
    pub fn finish(&mut self, job: usize, now: Time) -> Vec<usize> {
        let mut freed = 0;
        let j = &mut self.jobs[job];
        if j.admitted_at.is_some() && j.finished_at.is_none() {
            j.finished_at = Some(now);
            freed = j.demand;
            self.committed -= j.demand;
            self.running -= 1;
        }
        if freed > 0 && self.wait.is_empty() {
            // idle free: nobody waited on this capacity, so give it back
            self.autoscale_step(freed, false);
        }
        self.drain(now)
    }

    /// Admit waiting jobs in (SLO rank, FIFO) order while the budget (and
    /// the job-count quota) holds. Head-of-line blocking is deliberate —
    /// no bypass — so admission order is deterministic and every job is
    /// eventually admitted as committed demand drains.
    fn drain(&mut self, now: Time) -> Vec<usize> {
        let mut started = Vec::new();
        loop {
            let Some(&(rank, seq, job)) = self.wait.iter().next() else {
                break;
            };
            let demand = self.jobs[job].demand;
            // `committed > 0` guard: a shrunken autoscale budget must not
            // starve the head job forever — an empty controller always
            // admits. Inert without autoscale (register clamps demand
            // into the fixed budget, so an empty controller always fits).
            if self.committed + demand > self.budget() && self.committed > 0 {
                break;
            }
            if self.cfg.max_jobs > 0 && self.running >= self.cfg.max_jobs {
                break;
            }
            self.wait.remove(&(rank, seq, job));
            self.committed += demand;
            self.running += 1;
            self.jobs[job].admitted_at = Some(now);
            started.push(job);
        }
        started
    }

    /// Seconds the job spent queued between arrival and admission.
    pub fn queue_wait_secs(&self, job: usize) -> f64 {
        match self.jobs.get(job) {
            Some(JobAdmission {
                arrived_at: Some(a),
                admitted_at: Some(s),
                ..
            }) => to_secs(s.saturating_sub(*a)),
            _ => 0.0,
        }
    }

    pub fn job(&self, job: usize) -> &JobAdmission {
        &self.jobs[job]
    }

    /// Currently committed container demand.
    pub fn committed(&self) -> usize {
        self.committed
    }

    /// Jobs currently waiting for admission.
    pub fn queued(&self) -> usize {
        self.wait.len()
    }

    /// The budget currently in force — `cfg.budget` without autoscale,
    /// the adapted value (within its bounds) with it.
    pub fn effective_budget(&self) -> usize {
        self.budget()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::secs;

    #[test]
    fn fifo_admission_within_budget() {
        let mut c = AdmissionController::new(AdmissionConfig {
            budget: 10,
            max_jobs: 0,
            autoscale: None,
        });
        c.register(0, 4, SloClass::Standard);
        c.register(1, 4, SloClass::Standard);
        c.register(2, 4, SloClass::Standard);
        assert_eq!(c.arrive(0, secs(1.0)), vec![0]);
        assert_eq!(c.arrive(1, secs(2.0)), vec![1]);
        // third job would exceed the budget (12 > 10): backpressure
        assert_eq!(c.arrive(2, secs(3.0)), vec![]);
        assert_eq!(c.queued(), 1);
        assert_eq!(c.committed(), 8);
        // job 0 finishing frees demand; job 2 releases
        assert_eq!(c.finish(0, secs(50.0)), vec![2]);
        assert!((c.queue_wait_secs(2) - 47.0).abs() < 1e-9);
        assert_eq!(c.queue_wait_secs(0), 0.0, "admitted instantly");
    }

    #[test]
    fn slo_classes_jump_the_fifo_queue() {
        let mut c = AdmissionController::new(AdmissionConfig {
            budget: 4,
            max_jobs: 0,
            autoscale: None,
        });
        c.register(0, 4, SloClass::BestEffort);
        c.register(1, 4, SloClass::BestEffort);
        c.register(2, 4, SloClass::Premium);
        assert_eq!(c.arrive(0, secs(1.0)), vec![0]);
        assert_eq!(c.arrive(1, secs(2.0)), vec![]);
        assert_eq!(c.arrive(2, secs(3.0)), vec![]);
        // premium (job 2) outranks the earlier best-effort arrival (job 1)
        assert_eq!(c.finish(0, secs(10.0)), vec![2]);
        assert_eq!(c.finish(2, secs(20.0)), vec![1]);
    }

    #[test]
    fn oversized_demand_is_clamped_so_jobs_still_admit() {
        let mut c = AdmissionController::new(AdmissionConfig {
            budget: 8,
            max_jobs: 0,
            autoscale: None,
        });
        c.register(0, 500, SloClass::Standard);
        assert_eq!(c.job(0).demand, 8, "demand clamped into the budget");
        assert_eq!(c.arrive(0, 0), vec![0]);
    }

    #[test]
    fn autoscale_grows_on_backpressure_and_shrinks_on_idle_frees() {
        let mut c = AdmissionController::new(AdmissionConfig {
            budget: 4,
            max_jobs: 0,
            autoscale: Some((2, 12)),
        });
        c.register(0, 4, SloClass::Standard);
        c.register(1, 4, SloClass::Standard);
        c.register(2, 4, SloClass::Standard);
        assert_eq!(c.effective_budget(), 4);
        assert_eq!(c.arrive(0, secs(1.0)), vec![0]);
        // job 1 does not fit the fixed budget: the controller grows by
        // the queued demand and admits it in the same arrival
        assert_eq!(c.arrive(1, secs(2.0)), vec![1]);
        assert_eq!(c.effective_budget(), 8);
        assert_eq!(c.arrive(2, secs(3.0)), vec![2]);
        assert_eq!(c.effective_budget(), 12, "grown to the cap");
        // idle finishes shrink back toward the floor
        assert_eq!(c.finish(0, secs(10.0)), vec![]);
        assert_eq!(c.effective_budget(), 8);
        assert_eq!(c.finish(1, secs(11.0)), vec![]);
        assert_eq!(c.finish(2, secs(12.0)), vec![]);
        assert_eq!(c.effective_budget(), 2, "floored at the minimum");
    }

    #[test]
    fn autoscale_replays_bit_identically_and_never_starves_the_head_job() {
        let cfg = AdmissionConfig {
            budget: 2,
            max_jobs: 0,
            autoscale: Some((1, 6)),
        };
        let run = || {
            let mut c = AdmissionController::new(cfg.clone());
            c.register(0, 4, SloClass::Standard);
            c.register(1, 4, SloClass::Standard);
            let mut trace = Vec::new();
            trace.push(c.arrive(0, secs(1.0)));
            trace.push(c.arrive(1, secs(2.0)));
            trace.push(c.finish(0, secs(9.0)));
            trace.push(c.finish(1, secs(10.0)));
            (trace, c.effective_budget())
        };
        let (a, ba) = run();
        let (b, bb) = run();
        assert_eq!(a, b, "deterministic function of arrival/finish order");
        assert_eq!(ba, bb);
        // demand 4 > starting budget 2: the empty-controller guard (and
        // the backpressure growth) still admit job 0 immediately
        assert_eq!(a[0], vec![0]);
        assert!(a.iter().flatten().any(|&j| j == 1), "job 1 eventually admits");
    }

    #[test]
    fn max_jobs_quota_limits_concurrency() {
        let mut c = AdmissionController::new(AdmissionConfig {
            budget: 100,
            max_jobs: 1,
            autoscale: None,
        });
        c.register(0, 1, SloClass::Standard);
        c.register(1, 1, SloClass::Standard);
        assert_eq!(c.arrive(0, 0), vec![0]);
        assert_eq!(c.arrive(1, 0), vec![], "job quota holds job 1 back");
        assert_eq!(c.finish(0, secs(5.0)), vec![1]);
    }
}
