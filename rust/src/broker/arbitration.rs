//! Cross-job aggregation arbitration — which job's pending aggregation
//! task starts when cluster capacity frees.
//!
//! The paper's scheduler (§5.5) orders pending tasks purely by their
//! aggregation deadline (`t_rnd − t_agg`); that is [`DeadlinePriority`],
//! the baseline. Adaptive Aggregation (arXiv 2203.12163) motivates richer
//! cross-job arbitration once many FL jobs share one cluster:
//!
//! * [`LeastSlackFirst`] — classic real-time scheduling: order by
//!   `deadline − now − queued_work`, so a task with a large backlog is
//!   started earlier than its raw deadline suggests.
//! * [`WeightedFairShare`] — order by accumulated container-seconds per
//!   fair-share weight, so a tenant that has consumed little of the
//!   cluster gets the next free slot regardless of deadlines (weights come
//!   from the broker's SLO classes).
//!
//! Both non-baseline policies apply **aging**: a candidate's effective
//! priority improves with the time it has waited startable
//! (`Candidate::waited_secs`, tracked by the cluster from the instant
//! work first lands / the task is preempted back to Pending). Without it
//! a low-weight or high-usage tenant can starve indefinitely behind a
//! stream of fresher, better-scoring tasks; with it every waiting task's
//! score improves without bound, so it is eventually picked — the
//! no-starvation property pinned by this module's tests.
//!
//! Policies order both sides of the scheduling decision: *starts*
//! ([`ArbitrationPolicy::pick`]) and *preemption*
//! ([`ArbitrationPolicy::preempt_victim`], the victim chosen when a
//! pending task needs a slot on a full cluster). The default victim
//! order is the §5.5 baseline — evict the latest-deadline running task —
//! and `DeadlinePriority` keeps it, so the no-policy scheduler is
//! reproduced exactly; `least-slack` evicts the slackest running task
//! and `wfs` the most-overserved tenant's task, each with a guard so a
//! δ-tick preemption only happens when the victim genuinely scores
//! worse than the intruder. A JIT FORCE_TRIGGER (`Cluster::force_start`)
//! must deploy *now*, so there the policy only chooses the victim's
//! identity, never whether to evict.

use crate::cluster::{Priority, TaskId};
use crate::sim::Time;

/// One startable pending task, as the scheduler sees it. Deliberately
/// only the fields a policy reads — the snapshot is rebuilt every
/// arbitrated δ-tick, so dead payload here is hot-path cost.
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    pub task: TaskId,
    /// Owning job (index into `usage_cs` / `weights`).
    pub job: usize,
    /// §5.5 priority: absolute aggregation deadline in µs (smaller =
    /// more urgent).
    pub priority: Priority,
    /// Total queued work duration, seconds (incrementally tracked by the
    /// cluster, not re-summed per tick).
    pub queued_secs: f64,
    /// Seconds this task has been startable (Pending with work) without
    /// being deployed — the aging input. Resets on deploy/preemption.
    pub waited_secs: f64,
}

/// Immutable snapshot handed to a policy at each scheduling decision.
pub struct ArbitrationView<'a> {
    pub now: Time,
    /// Startable pending tasks in ascending `(priority, task)` order —
    /// the §5.5 baseline order.
    pub candidates: &'a [Candidate],
    /// Per-job aggregation container-seconds so far (index = job id).
    pub usage_cs: &'a [f64],
    /// Per-job fair-share weights (index = job id; 1.0 default).
    pub weights: &'a [f64],
}

/// Pluggable cross-job arbitration. Implementations must be deterministic
/// functions of the view (ties broken by the candidates' `(priority,
/// task)` order), so multi-job runs replay bit-identically.
pub trait ArbitrationPolicy: Send + std::fmt::Debug {
    fn name(&self) -> &'static str;

    /// Pick the next pending task to deploy, or `None` to leave the free
    /// capacity idle this tick.
    fn pick(&mut self, view: &ArbitrationView) -> Option<TaskId>;

    /// Choose which running task to evict so a pending one can start.
    /// `view.candidates` are the *preemptible* (Running/Idle) tasks in
    /// ascending `(priority, task)` order; `intruder` is the pending task
    /// that wants the slot, or `None` for a FORCE_TRIGGER deploy (the
    /// deadline is *now*, so a victim must be named whenever one exists —
    /// the policy only decides *who*, not *whether*). Return `None` to
    /// decline preemption this tick (δ-tick path only).
    ///
    /// The default is the §5.5 baseline: evict the latest-deadline task,
    /// and on the δ-tick path only if it is strictly lower priority than
    /// the intruder. Implementations must be deterministic functions of
    /// the view so preemption order replays bit-identically.
    fn preempt_victim(
        &mut self,
        view: &ArbitrationView,
        intruder: Option<&Candidate>,
    ) -> Option<TaskId> {
        let victim = view.candidates.last()?;
        match intruder {
            Some(i) if victim.priority <= i.priority => None,
            _ => Some(victim.task),
        }
    }
}

/// §5.5 baseline: earliest aggregation deadline first. With this policy
/// installed the cluster behaves exactly as with no policy at all.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeadlinePriority;

impl ArbitrationPolicy for DeadlinePriority {
    fn name(&self) -> &'static str {
        "deadline"
    }

    fn pick(&mut self, view: &ArbitrationView) -> Option<TaskId> {
        view.candidates.first().map(|c| c.task)
    }
}

/// Least slack first: `slack = deadline − now − queued_work −
/// aging·waited`. A deep backlog erodes slack, so backlogged tasks start
/// before their raw deadline order; the aging term guarantees a waiting
/// task's effective slack falls below any fixed competitor's eventually.
#[derive(Clone, Copy, Debug)]
pub struct LeastSlackFirst {
    /// Seconds of slack credit per second waited startable (0 = pure LSF).
    pub aging: f64,
}

impl Default for LeastSlackFirst {
    fn default() -> Self {
        LeastSlackFirst { aging: 0.5 }
    }
}

impl LeastSlackFirst {
    /// Effective slack: `deadline − now − queued_work − aging·waited` µs.
    fn slack(&self, c: &Candidate, now: Time) -> i128 {
        let work = crate::sim::secs(c.queued_secs) as i128;
        let age_credit = crate::sim::secs(self.aging * c.waited_secs) as i128;
        c.priority as i128 - now as i128 - work - age_credit
    }
}

impl ArbitrationPolicy for LeastSlackFirst {
    fn name(&self) -> &'static str {
        "least-slack"
    }

    fn pick(&mut self, view: &ArbitrationView) -> Option<TaskId> {
        let mut best: Option<(i128, TaskId)> = None;
        for c in view.candidates {
            let slack = self.slack(c, view.now);
            let replace = match best {
                None => true,
                // strict <: first-seen wins ties, and candidates arrive in
                // (priority, task) order, so ties resolve deterministically
                Some((s, _)) => slack < s,
            };
            if replace {
                best = Some((slack, c.task));
            }
        }
        best.map(|(_, t)| t)
    }

    /// Evict the *slackest* running task — the mirror image of `pick`.
    /// On the δ-tick path the victim must have strictly more effective
    /// slack than the intruder, else nobody is preempted.
    fn preempt_victim(
        &mut self,
        view: &ArbitrationView,
        intruder: Option<&Candidate>,
    ) -> Option<TaskId> {
        let mut worst: Option<(i128, TaskId)> = None;
        for c in view.candidates {
            let slack = self.slack(c, view.now);
            // >= so ties resolve to the latest-deadline candidate (the
            // §5.5 baseline victim order)
            let replace = match worst {
                None => true,
                Some((s, _)) => slack >= s,
            };
            if replace {
                worst = Some((slack, c.task));
            }
        }
        let (slack, task) = worst?;
        match intruder {
            Some(i) if slack <= self.slack(i, view.now) => None,
            _ => Some(task),
        }
    }
}

/// Weighted fair share of container-seconds: the job with the smallest
/// `usage_cs / weight − aging_cs·waited` score gets the next free slot.
/// The aging discount keeps a heavy tenant's queued task from starving
/// behind a stream of fresh low-usage tenants.
#[derive(Clone, Copy, Debug)]
pub struct WeightedFairShare {
    /// Container-second discount per second waited startable (0 = pure
    /// fair share).
    pub aging_cs: f64,
}

impl Default for WeightedFairShare {
    fn default() -> Self {
        WeightedFairShare { aging_cs: 2.0 }
    }
}

impl WeightedFairShare {
    /// Raw tenant share: `usage_cs / weight` — the aging-free fairness
    /// position of a job.
    fn tenant_share(view: &ArbitrationView, job: usize) -> f64 {
        let w = view.weights.get(job).copied().unwrap_or(1.0).max(1e-9);
        let used = view.usage_cs.get(job).copied().unwrap_or(0.0);
        used / w
    }

    /// Fair-share score: `usage_cs / weight − aging_cs·waited` (smaller =
    /// more underserved = runs sooner, survives preemption longer).
    fn score(&self, view: &ArbitrationView, c: &Candidate) -> f64 {
        Self::tenant_share(view, c.job) - self.aging_cs * c.waited_secs
    }
}

impl ArbitrationPolicy for WeightedFairShare {
    fn name(&self) -> &'static str {
        "wfs"
    }

    fn pick(&mut self, view: &ArbitrationView) -> Option<TaskId> {
        let mut best: Option<(f64, TaskId)> = None;
        for c in view.candidates {
            let score = self.score(view, c);
            let replace = match best {
                None => true,
                Some((r, _)) => score < r,
            };
            if replace {
                best = Some((score, c.task));
            }
        }
        best.map(|(_, t)| t)
    }

    /// Evict the most-overserved tenant's task (largest fair-share
    /// score). The δ-tick guard compares *raw* tenant shares — not the
    /// aged score — so fair share never evicts to admit an equally (or
    /// more) served tenant: an aged intruder from the victim's own job
    /// would otherwise buy a pointless checkpoint + redeploy with zero
    /// fairness gain.
    fn preempt_victim(
        &mut self,
        view: &ArbitrationView,
        intruder: Option<&Candidate>,
    ) -> Option<TaskId> {
        let mut worst: Option<(f64, TaskId, usize)> = None;
        for c in view.candidates {
            let score = self.score(view, c);
            // >= so ties resolve to the latest-deadline candidate (the
            // §5.5 baseline victim order)
            let replace = match worst {
                None => true,
                Some((s, _, _)) => score >= s,
            };
            if replace {
                worst = Some((score, c.task, c.job));
            }
        }
        let (_, task, victim_job) = worst?;
        match intruder {
            Some(i)
                if Self::tenant_share(view, victim_job)
                    <= Self::tenant_share(view, i.job) =>
            {
                None
            }
            _ => Some(task),
        }
    }
}

/// Construct a policy by name (accepts short and long spellings).
pub fn by_name(name: &str) -> Option<Box<dyn ArbitrationPolicy>> {
    match name {
        "deadline" | "deadline-priority" => Some(Box::new(DeadlinePriority)),
        "least-slack" | "lsf" | "least-slack-first" => {
            Some(Box::new(LeastSlackFirst::default()))
        }
        "wfs" | "weighted-fair-share" | "fair" => {
            Some(Box::new(WeightedFairShare::default()))
        }
        _ => None,
    }
}

/// Canonical policy names for sweeps (baseline first).
pub fn all_policies() -> &'static [&'static str] {
    &["deadline", "least-slack", "wfs"]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::secs;

    fn cand(task: TaskId, job: usize, deadline_secs: f64, queued_secs: f64) -> Candidate {
        Candidate {
            task,
            job,
            priority: secs(deadline_secs) as Priority,
            queued_secs,
            waited_secs: 0.0,
        }
    }

    #[test]
    fn by_name_resolves_all_policies() {
        for n in all_policies() {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert_eq!(by_name("deadline").unwrap().name(), "deadline");
        assert_eq!(by_name("weighted-fair-share").unwrap().name(), "wfs");
        assert!(by_name("bogus").is_none());
    }

    #[test]
    fn deadline_picks_first_candidate() {
        let cands = [cand(7, 0, 10.0, 1.0), cand(3, 1, 20.0, 1.0)];
        let view = ArbitrationView {
            now: 0,
            candidates: &cands,
            usage_cs: &[0.0, 0.0],
            weights: &[1.0, 1.0],
        };
        assert_eq!(DeadlinePriority.pick(&view), Some(7));
        let empty = ArbitrationView {
            now: 0,
            candidates: &[],
            usage_cs: &[],
            weights: &[],
        };
        assert_eq!(DeadlinePriority.pick(&empty), None);
    }

    #[test]
    fn least_slack_prefers_backlogged_task() {
        // task 1 has a later deadline but 15s of queued work: slack
        // 20−15=5 beats task 0's 10−1=9.
        let cands = [cand(0, 0, 10.0, 1.0), cand(1, 1, 20.0, 15.0)];
        let view = ArbitrationView {
            now: 0,
            candidates: &cands,
            usage_cs: &[0.0, 0.0],
            weights: &[1.0, 1.0],
        };
        assert_eq!(LeastSlackFirst::default().pick(&view), Some(1));
    }

    #[test]
    fn least_slack_aging_eventually_promotes_a_waiting_task() {
        // task 0 has a far deadline (would lose pure LSF forever against
        // an endless stream of tighter tasks); with aging its effective
        // slack drops below the fresh competitor's after a bounded wait
        let mut policy = LeastSlackFirst::default();
        let mut promoted_at = None;
        for waited in 0..4000u64 {
            let mut old = cand(0, 0, 1000.0, 1.0);
            old.waited_secs = waited as f64;
            let fresh = cand(1, 1, 50.0, 1.0);
            let cands = [fresh, old];
            let view = ArbitrationView {
                now: 0,
                candidates: &cands,
                usage_cs: &[0.0, 0.0],
                weights: &[1.0, 1.0],
            };
            if policy.pick(&view) == Some(0) {
                promoted_at = Some(waited);
                break;
            }
        }
        // slack gap is (1000−1) − (50−1) = 950s; at aging 0.5 s/s the
        // strict-< tie-break promotes at 950/0.5 + 1 = 1901s waited
        let w = promoted_at.expect("aging must eventually promote the waiting task");
        assert_eq!(w, 1901, "deterministic promotion bound");
    }

    #[test]
    fn least_slack_aging_bound_is_finite_and_ordered() {
        // with aging disabled the old task NEVER wins — the starvation
        // this satellite exists to fix
        let mut pure = LeastSlackFirst { aging: 0.0 };
        let mut old = cand(0, 0, 1000.0, 1.0);
        old.waited_secs = 1e9;
        let fresh = cand(1, 1, 50.0, 1.0);
        let cands = [fresh, old];
        let view = ArbitrationView {
            now: 0,
            candidates: &cands,
            usage_cs: &[0.0, 0.0],
            weights: &[1.0, 1.0],
        };
        assert_eq!(pure.pick(&view), Some(1), "pure LSF starves the far deadline");
        assert_eq!(
            LeastSlackFirst::default().pick(&view),
            Some(0),
            "aged LSF does not"
        );
    }

    #[test]
    fn wfs_prefers_underserved_weighted_job() {
        // job 0 has consumed 100 cs at weight 1; job 1 consumed 30 cs at
        // weight 2 → ratios 100 vs 15 → job 1's task wins despite a
        // later deadline.
        let cands = [cand(0, 0, 10.0, 1.0), cand(1, 1, 20.0, 1.0)];
        let view = ArbitrationView {
            now: 0,
            candidates: &cands,
            usage_cs: &[100.0, 30.0],
            weights: &[1.0, 2.0],
        };
        assert_eq!(WeightedFairShare::default().pick(&view), Some(1));
        // equal ratios tie-break to the first (earliest-deadline) candidate
        let even = ArbitrationView {
            now: 0,
            candidates: &cands,
            usage_cs: &[10.0, 10.0],
            weights: &[1.0, 1.0],
        };
        assert_eq!(WeightedFairShare::default().pick(&even), Some(0));
    }

    #[test]
    fn deadline_preempt_victim_is_the_baseline_worst_running() {
        let cands = [cand(0, 0, 10.0, 1.0), cand(1, 1, 50.0, 1.0)];
        let view = ArbitrationView {
            now: 0,
            candidates: &cands,
            usage_cs: &[0.0, 0.0],
            weights: &[1.0, 1.0],
        };
        let mut p = DeadlinePriority;
        // δ-tick: latest-deadline victim, guarded by strict priority order
        let urgent = cand(9, 2, 5.0, 1.0);
        assert_eq!(p.preempt_victim(&view, Some(&urgent)), Some(1));
        let lax = cand(9, 2, 99.0, 1.0);
        assert_eq!(p.preempt_victim(&view, Some(&lax)), None, "guard holds");
        // FORCE_TRIGGER: a victim must be named unconditionally
        assert_eq!(p.preempt_victim(&view, None), Some(1));
        let empty = ArbitrationView {
            now: 0,
            candidates: &[],
            usage_cs: &[],
            weights: &[],
        };
        assert_eq!(p.preempt_victim(&empty, None), None, "nobody to evict");
    }

    #[test]
    fn least_slack_evicts_the_slackest_victim() {
        // deep queued work erodes task 1's slack below the earlier-
        // deadline task 0's, so the slack-ordered victim diverges from
        // the deadline baseline's latest-deadline choice
        let mut p = LeastSlackFirst { aging: 0.5 };
        let cands = [cand(0, 0, 10.0, 1.0), cand(1, 1, 50.0, 45.0)];
        let view = ArbitrationView {
            now: 0,
            candidates: &cands,
            usage_cs: &[0.0, 0.0],
            weights: &[1.0, 1.0],
        };
        // slacks: task 0 = 9s, task 1 = 5s → victim is task 0, NOT the
        // baseline's latest-deadline task 1
        assert_eq!(p.preempt_victim(&view, None), Some(0));
        // guard: an intruder with more slack than the victim preempts no one
        let rich = cand(9, 2, 100.0, 1.0);
        assert_eq!(p.preempt_victim(&view, Some(&rich)), None);
        // an intruder with less slack than the victim does
        let poor = cand(9, 2, 3.0, 1.0);
        assert_eq!(p.preempt_victim(&view, Some(&poor)), Some(0));
    }

    #[test]
    fn wfs_evicts_the_most_overserved_tenant() {
        // job 1 consumed far more than its share, so its *earlier-
        // deadline* task is the victim — the deadline baseline would
        // have evicted job 0's later-deadline task instead
        let mut p = WeightedFairShare { aging_cs: 2.0 };
        let cands = [cand(0, 1, 10.0, 1.0), cand(1, 0, 50.0, 1.0)];
        let view = ArbitrationView {
            now: 0,
            candidates: &cands,
            usage_cs: &[5.0, 500.0],
            weights: &[1.0, 1.0],
        };
        // job 1 (task 0) is overserved → victim is task 0, not the
        // baseline's latest-deadline task 1
        assert_eq!(p.preempt_victim(&view, None), Some(0));
        // guard: an intruder from an equally overserved tenant is refused
        let same_tenant = cand(9, 1, 1.0, 1.0);
        assert_eq!(p.preempt_victim(&view, Some(&same_tenant)), None);
        // …even when that intruder has aged: waiting improves its *start*
        // score but buys no fairness from evicting its own tenant's task
        let mut aged_same_tenant = cand(9, 1, 1.0, 1.0);
        aged_same_tenant.waited_secs = 1e6;
        assert_eq!(
            p.preempt_victim(&view, Some(&aged_same_tenant)),
            None,
            "aging must not defeat the equal-tenant guard"
        );
        // an underserved tenant's intruder evicts the overserved one
        let fresh = cand(9, 0, 99.0, 1.0);
        assert_eq!(p.preempt_victim(&view, Some(&fresh)), Some(0));
    }

    #[test]
    fn wfs_aging_pins_no_starvation() {
        // a best-effort tenant with huge historical usage would starve
        // forever under pure fair share while premium tenants keep
        // submitting fresh zero-usage work; the aging discount must
        // promote its waiting task after a bounded wait
        let mut pure = WeightedFairShare { aging_cs: 0.0 };
        let mut aged = WeightedFairShare::default();
        let run = |policy: &mut WeightedFairShare| -> Option<u64> {
            for waited in 0..10_000u64 {
                let mut starving = cand(0, 0, 10.0, 1.0);
                starving.waited_secs = waited as f64;
                let fresh = cand(1, 1, 5.0, 1.0); // always waited 0
                let cands = [starving, fresh];
                let view = ArbitrationView {
                    now: 0,
                    candidates: &cands,
                    usage_cs: &[5000.0, 0.0],
                    weights: &[1.0, 4.0],
                };
                if policy.pick(&view) == Some(0) {
                    return Some(waited);
                }
            }
            None
        };
        assert_eq!(run(&mut pure), None, "pure WFS starves the heavy tenant");
        let w = run(&mut aged).expect("aged WFS must promote the waiting task");
        // crossover: 5000/1 − 2·w ≤ 0 ⇒ w ≥ 2500 (the starving task is
        // first in the candidate list, so a tie resolves in its favor)
        assert_eq!(w, 2500, "deterministic crossover at usage/weight/aging_cs");
    }
}
