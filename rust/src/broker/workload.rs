//! Job-arrival workload generation: the broker's scenarios cover a
//! *living cluster* — FL jobs arriving over time (Poisson or trace-driven)
//! with mixed active/intermittent fleets, party counts up to 10k, the
//! three §6.3 workload profiles and an SLO-class mix — rather than a
//! fixed job set admitted at t = 0.
//!
//! Traces are deterministic functions of the seed, so the same trace can
//! be replayed under every arbitration policy (that is what makes the
//! per-policy comparison in `bench::broker` meaningful).

use crate::coordinator::job::FlJobSpec;
use crate::party::FleetKind;
use crate::util::rng::Rng;
use crate::workloads::Workload;

use super::SloClass;

/// One job submission reaching the broker.
#[derive(Clone, Debug)]
pub struct JobArrival {
    /// Submission time, virtual seconds from trace start.
    pub at_secs: f64,
    pub spec: FlJobSpec,
    pub strategy: String,
    pub class: SloClass,
}

/// A full arrival trace, sorted by submission time.
#[derive(Clone, Debug, Default)]
pub struct JobTrace {
    pub arrivals: Vec<JobArrival>,
}

impl JobTrace {
    /// Trace-driven construction from explicit arrivals (sorted on entry).
    pub fn from_arrivals(mut arrivals: Vec<JobArrival>) -> JobTrace {
        arrivals.sort_by(|a, b| a.at_secs.partial_cmp(&b.at_secs).unwrap());
        JobTrace { arrivals }
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Largest fleet in the trace.
    pub fn max_parties(&self) -> usize {
        self.arrivals.iter().map(|a| a.spec.n_parties).max().unwrap_or(0)
    }
}

/// Poisson-arrival generator configuration.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub n_jobs: usize,
    /// Mean inter-arrival gap of the Poisson process, seconds.
    pub mean_interarrival_secs: f64,
    /// `(party count, draw weight)` mix; includes 10k-party jobs by default.
    pub party_mix: Vec<(usize, f64)>,
    /// Fraction of jobs with intermittent fleets (rest split between
    /// active homogeneous and heterogeneous).
    pub intermittent_frac: f64,
    /// Rounds drawn uniformly in `[rounds_lo, rounds_hi]`.
    pub rounds_lo: u32,
    pub rounds_hi: u32,
    /// Round window for intermittent jobs (short so sweeps stay fast).
    pub t_wait_secs: f64,
    /// `(SLO class, draw weight)` mix.
    pub slo_mix: Vec<(SloClass, f64)>,
    pub strategy: String,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n_jobs: 12,
            mean_interarrival_secs: 30.0,
            party_mix: vec![(10, 0.4), (100, 0.3), (1000, 0.2), (10_000, 0.1)],
            intermittent_frac: 0.3,
            rounds_lo: 2,
            rounds_hi: 5,
            t_wait_secs: 120.0,
            slo_mix: vec![
                (SloClass::Premium, 0.2),
                (SloClass::Standard, 0.5),
                (SloClass::BestEffort, 0.3),
            ],
            strategy: "jit".to_string(),
            seed: 0xB40C,
        }
    }
}

/// Weighted draw from a `(value, weight)` mix (deterministic in the rng
/// stream; the last entry absorbs floating-point remainder).
fn draw_weighted<'a, T>(rng: &mut Rng, mix: &'a [(T, f64)]) -> &'a T {
    debug_assert!(!mix.is_empty(), "empty mix");
    let total: f64 = mix.iter().map(|(_, w)| *w).sum();
    let mut u = rng.f64() * total;
    for (v, w) in mix {
        if u < *w {
            return v;
        }
        u -= *w;
    }
    &mix[mix.len() - 1].0
}

/// Generate a Poisson arrival trace over the three §6.3 workload profiles.
pub fn poisson_trace(cfg: &TraceConfig) -> JobTrace {
    assert!(cfg.n_jobs > 0, "trace needs at least one job");
    assert!(!cfg.party_mix.is_empty(), "party mix must be non-empty");
    assert!(!cfg.slo_mix.is_empty(), "slo mix must be non-empty");
    let mut rng = Rng::new(cfg.seed);
    let workloads = Workload::all_paper();
    let rounds_hi = cfg.rounds_hi.max(cfg.rounds_lo);
    let mut at = 0.0;
    let mut arrivals = Vec::with_capacity(cfg.n_jobs);
    for i in 0..cfg.n_jobs {
        if i > 0 {
            at += rng.exp(1.0 / cfg.mean_interarrival_secs.max(1e-9));
        }
        let workload = workloads[rng.below(workloads.len() as u64) as usize].clone();
        let parties = *draw_weighted(&mut rng, &cfg.party_mix);
        let fleet = if rng.bool(cfg.intermittent_frac) {
            FleetKind::IntermittentHeterogeneous
        } else if rng.bool(0.5) {
            FleetKind::ActiveHeterogeneous
        } else {
            FleetKind::ActiveHomogeneous
        };
        let rounds = rng.range_u64(cfg.rounds_lo as u64, rounds_hi as u64 + 1) as u32;
        let class = *draw_weighted(&mut rng, &cfg.slo_mix);
        let mut spec = FlJobSpec::new(workload, fleet, parties, rounds);
        spec.t_wait_secs = cfg.t_wait_secs;
        spec.name = format!("job{i}-{}", spec.name);
        arrivals.push(JobArrival {
            at_secs: at,
            spec,
            strategy: cfg.strategy.clone(),
            class,
        });
    }
    JobTrace { arrivals }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_trace_is_deterministic_and_sorted() {
        let cfg = TraceConfig {
            n_jobs: 20,
            seed: 7,
            ..Default::default()
        };
        let a = poisson_trace(&cfg);
        let b = poisson_trace(&cfg);
        assert_eq!(a.len(), 20);
        for (x, y) in a.arrivals.iter().zip(&b.arrivals) {
            assert_eq!(x.at_secs, y.at_secs);
            assert_eq!(x.spec.name, y.spec.name);
            assert_eq!(x.spec.n_parties, y.spec.n_parties);
            assert_eq!(x.class, y.class);
        }
        // sorted, starting at 0
        assert_eq!(a.arrivals[0].at_secs, 0.0);
        for w in a.arrivals.windows(2) {
            assert!(w[0].at_secs <= w[1].at_secs);
        }
        // a different seed moves the arrivals
        let c = poisson_trace(&TraceConfig {
            n_jobs: 20,
            seed: 8,
            ..Default::default()
        });
        assert_ne!(a.arrivals[5].at_secs, c.arrivals[5].at_secs);
    }

    #[test]
    fn mix_draws_cover_the_configured_values() {
        let cfg = TraceConfig {
            n_jobs: 200,
            seed: 3,
            ..Default::default()
        };
        let t = poisson_trace(&cfg);
        let counts: std::collections::BTreeSet<usize> =
            t.arrivals.iter().map(|a| a.spec.n_parties).collect();
        assert!(counts.contains(&10) && counts.contains(&10_000), "{counts:?}");
        assert_eq!(t.max_parties(), 10_000);
        let classes: std::collections::BTreeSet<&str> =
            t.arrivals.iter().map(|a| a.class.name()).collect();
        assert_eq!(classes.len(), 3, "all three SLO classes drawn");
        let fleets: std::collections::BTreeSet<&str> =
            t.arrivals.iter().map(|a| a.spec.fleet_kind.name()).collect();
        assert_eq!(fleets.len(), 3, "all three fleet kinds drawn");
    }

    #[test]
    fn trace_driven_arrivals_sort_on_entry() {
        let cfg = TraceConfig {
            n_jobs: 3,
            seed: 1,
            ..Default::default()
        };
        let mut arrivals = poisson_trace(&cfg).arrivals;
        arrivals[0].at_secs = 500.0; // force out-of-order entry
        let t = JobTrace::from_arrivals(arrivals);
        for w in t.arrivals.windows(2) {
            assert!(w[0].at_secs <= w[1].at_secs);
        }
    }
}
