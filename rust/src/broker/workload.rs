//! Job-arrival workload generation: the broker's scenarios cover a
//! *living cluster* — FL jobs arriving over time (Poisson or trace-driven)
//! with mixed active/intermittent fleets, party counts up to 10k, the
//! three §6.3 workload profiles and an SLO-class mix — rather than a
//! fixed job set admitted at t = 0.
//!
//! Traces are deterministic functions of the seed, so the same trace can
//! be replayed under every arbitration policy (that is what makes the
//! per-policy comparison in `bench::broker` meaningful). They also
//! round-trip through JSON ([`JobTrace::save`]/[`JobTrace::load`]), so
//! recorded production workloads can be replayed offline — the format is
//! pinned by a golden file (`rust/tests/data/job_trace.golden.json`).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::job::FlJobSpec;
use crate::party::FleetKind;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::workloads::Workload;

use super::SloClass;

/// One job submission reaching the broker.
#[derive(Clone, Debug)]
pub struct JobArrival {
    /// Submission time, virtual seconds from trace start.
    pub at_secs: f64,
    pub spec: FlJobSpec,
    pub strategy: String,
    pub class: SloClass,
}

/// A full arrival trace, sorted by submission time.
#[derive(Clone, Debug, Default)]
pub struct JobTrace {
    pub arrivals: Vec<JobArrival>,
}

impl JobArrival {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("at_secs", Json::num(self.at_secs)),
            ("spec", self.spec.to_json()),
            ("strategy", Json::str(&self.strategy)),
            ("class", Json::str(self.class.name())),
        ])
    }

    pub fn from_json(v: &Json) -> Option<JobArrival> {
        // strategy is validated at load so a bad trace fails with a file
        // diagnostic instead of panicking mid-replay in JobEngine::new
        let strategy = v.get("strategy").as_str()?.to_string();
        crate::coordinator::strategies::by_name(&strategy)?;
        Some(JobArrival {
            at_secs: v.get("at_secs").as_f64()?,
            spec: FlJobSpec::from_json(v.get("spec"))?,
            strategy,
            class: SloClass::parse(v.get("class").as_str().unwrap_or("standard"))?,
        })
    }
}

impl JobTrace {
    /// Trace-driven construction from explicit arrivals (sorted on entry).
    pub fn from_arrivals(mut arrivals: Vec<JobArrival>) -> JobTrace {
        arrivals.sort_by(|a, b| a.at_secs.partial_cmp(&b.at_secs).unwrap());
        JobTrace { arrivals }
    }

    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Largest fleet in the trace.
    pub fn max_parties(&self) -> usize {
        self.arrivals.iter().map(|a| a.spec.n_parties).max().unwrap_or(0)
    }

    // ------------------------------------------------------------------
    // on-disk format (ROADMAP carried item: replay recorded workloads)
    // ------------------------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::num(1.0)),
            (
                "arrivals",
                Json::Arr(self.arrivals.iter().map(|a| a.to_json()).collect()),
            ),
        ])
    }

    /// Parse a trace; arrivals are re-sorted by submission time, so
    /// hand-edited files need not be ordered.
    pub fn from_json(v: &Json) -> Option<JobTrace> {
        let arrivals = v
            .get("arrivals")
            .as_arr()?
            .iter()
            .map(JobArrival::from_json)
            .collect::<Option<Vec<_>>>()?;
        Some(JobTrace::from_arrivals(arrivals))
    }

    /// Write the trace as pretty JSON.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().pretty())
            .with_context(|| format!("writing trace to {path:?}"))
    }

    /// Load a trace written by [`save`](JobTrace::save) (or by hand).
    pub fn load(path: &Path) -> Result<JobTrace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace from {path:?}"))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("trace {path:?}: {e}"))?;
        JobTrace::from_json(&v)
            .ok_or_else(|| anyhow!("trace {path:?}: malformed arrivals"))
    }
}

/// Poisson-arrival generator configuration.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub n_jobs: usize,
    /// Mean inter-arrival gap of the Poisson process, seconds.
    pub mean_interarrival_secs: f64,
    /// `(party count, draw weight)` mix; includes 10k-party jobs by default.
    pub party_mix: Vec<(usize, f64)>,
    /// Fraction of jobs with intermittent fleets (rest split between
    /// active homogeneous and heterogeneous).
    pub intermittent_frac: f64,
    /// Rounds drawn uniformly in `[rounds_lo, rounds_hi]`.
    pub rounds_lo: u32,
    pub rounds_hi: u32,
    /// Round window for intermittent jobs (short so sweeps stay fast).
    pub t_wait_secs: f64,
    /// `(SLO class, draw weight)` mix.
    pub slo_mix: Vec<(SloClass, f64)>,
    pub strategy: String,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            n_jobs: 12,
            mean_interarrival_secs: 30.0,
            party_mix: vec![(10, 0.4), (100, 0.3), (1000, 0.2), (10_000, 0.1)],
            intermittent_frac: 0.3,
            rounds_lo: 2,
            rounds_hi: 5,
            t_wait_secs: 120.0,
            slo_mix: vec![
                (SloClass::Premium, 0.2),
                (SloClass::Standard, 0.5),
                (SloClass::BestEffort, 0.3),
            ],
            strategy: "jit".to_string(),
            seed: 0xB40C,
        }
    }
}

/// Weighted draw from a `(value, weight)` mix (deterministic in the rng
/// stream; the last entry absorbs floating-point remainder).
fn draw_weighted<'a, T>(rng: &mut Rng, mix: &'a [(T, f64)]) -> &'a T {
    debug_assert!(!mix.is_empty(), "empty mix");
    let total: f64 = mix.iter().map(|(_, w)| *w).sum();
    let mut u = rng.f64() * total;
    for (v, w) in mix {
        if u < *w {
            return v;
        }
        u -= *w;
    }
    &mix[mix.len() - 1].0
}

/// Generate a Poisson arrival trace over the three §6.3 workload profiles.
pub fn poisson_trace(cfg: &TraceConfig) -> JobTrace {
    assert!(cfg.n_jobs > 0, "trace needs at least one job");
    assert!(!cfg.party_mix.is_empty(), "party mix must be non-empty");
    assert!(!cfg.slo_mix.is_empty(), "slo mix must be non-empty");
    let mut rng = Rng::new(cfg.seed);
    let workloads = Workload::all_paper();
    let rounds_hi = cfg.rounds_hi.max(cfg.rounds_lo);
    let mut at = 0.0;
    let mut arrivals = Vec::with_capacity(cfg.n_jobs);
    for i in 0..cfg.n_jobs {
        if i > 0 {
            at += rng.exp(1.0 / cfg.mean_interarrival_secs.max(1e-9));
        }
        let workload = workloads[rng.below(workloads.len() as u64) as usize].clone();
        let parties = *draw_weighted(&mut rng, &cfg.party_mix);
        let fleet = if rng.bool(cfg.intermittent_frac) {
            FleetKind::IntermittentHeterogeneous
        } else if rng.bool(0.5) {
            FleetKind::ActiveHeterogeneous
        } else {
            FleetKind::ActiveHomogeneous
        };
        let rounds = rng.range_u64(cfg.rounds_lo as u64, rounds_hi as u64 + 1) as u32;
        let class = *draw_weighted(&mut rng, &cfg.slo_mix);
        let mut spec = FlJobSpec::new(workload, fleet, parties, rounds);
        spec.t_wait_secs = cfg.t_wait_secs;
        spec.name = format!("job{i}-{}", spec.name);
        arrivals.push(JobArrival {
            at_secs: at,
            spec,
            strategy: cfg.strategy.clone(),
            class,
        });
    }
    JobTrace { arrivals }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_trace_is_deterministic_and_sorted() {
        let cfg = TraceConfig {
            n_jobs: 20,
            seed: 7,
            ..Default::default()
        };
        let a = poisson_trace(&cfg);
        let b = poisson_trace(&cfg);
        assert_eq!(a.len(), 20);
        for (x, y) in a.arrivals.iter().zip(&b.arrivals) {
            assert_eq!(x.at_secs, y.at_secs);
            assert_eq!(x.spec.name, y.spec.name);
            assert_eq!(x.spec.n_parties, y.spec.n_parties);
            assert_eq!(x.class, y.class);
        }
        // sorted, starting at 0
        assert_eq!(a.arrivals[0].at_secs, 0.0);
        for w in a.arrivals.windows(2) {
            assert!(w[0].at_secs <= w[1].at_secs);
        }
        // a different seed moves the arrivals
        let c = poisson_trace(&TraceConfig {
            n_jobs: 20,
            seed: 8,
            ..Default::default()
        });
        assert_ne!(a.arrivals[5].at_secs, c.arrivals[5].at_secs);
    }

    #[test]
    fn mix_draws_cover_the_configured_values() {
        let cfg = TraceConfig {
            n_jobs: 200,
            seed: 3,
            ..Default::default()
        };
        let t = poisson_trace(&cfg);
        let counts: std::collections::BTreeSet<usize> =
            t.arrivals.iter().map(|a| a.spec.n_parties).collect();
        assert!(counts.contains(&10) && counts.contains(&10_000), "{counts:?}");
        assert_eq!(t.max_parties(), 10_000);
        let classes: std::collections::BTreeSet<&str> =
            t.arrivals.iter().map(|a| a.class.name()).collect();
        assert_eq!(classes.len(), 3, "all three SLO classes drawn");
        let fleets: std::collections::BTreeSet<&str> =
            t.arrivals.iter().map(|a| a.spec.fleet_kind.name()).collect();
        assert_eq!(fleets.len(), 3, "all three fleet kinds drawn");
    }

    #[test]
    fn trace_json_roundtrip_preserves_every_field() {
        let cfg = TraceConfig {
            n_jobs: 12,
            seed: 21,
            ..Default::default()
        };
        let a = poisson_trace(&cfg);
        let b = JobTrace::from_json(&a.to_json()).expect("roundtrip parse");
        assert_eq!(a.len(), b.len());
        for (x, y) in a.arrivals.iter().zip(&b.arrivals) {
            assert_eq!(x.at_secs.to_bits(), y.at_secs.to_bits(), "exact times");
            assert_eq!(x.spec.name, y.spec.name);
            assert_eq!(x.spec.workload.name, y.spec.workload.name);
            assert_eq!(x.spec.fleet_kind, y.spec.fleet_kind);
            assert_eq!(x.spec.n_parties, y.spec.n_parties);
            assert_eq!(x.spec.rounds, y.spec.rounds);
            assert_eq!(x.spec.quorum, y.spec.quorum);
            assert_eq!(x.spec.t_wait_secs, y.spec.t_wait_secs);
            assert_eq!(x.strategy, y.strategy);
            assert_eq!(x.class, y.class);
        }
    }

    #[test]
    fn trace_save_load_roundtrip_on_disk() {
        let cfg = TraceConfig {
            n_jobs: 5,
            seed: 33,
            ..Default::default()
        };
        let a = poisson_trace(&cfg);
        let dir = std::env::temp_dir().join("fljit_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        a.save(&path).expect("save");
        let b = JobTrace::load(&path).expect("load");
        assert_eq!(a.len(), b.len());
        for (x, y) in a.arrivals.iter().zip(&b.arrivals) {
            assert_eq!(x.at_secs.to_bits(), y.at_secs.to_bits());
            assert_eq!(x.spec.name, y.spec.name);
        }
        assert!(JobTrace::load(&dir.join("missing.json")).is_err());
    }

    #[test]
    fn malformed_trace_json_is_rejected() {
        let v = Json::parse(r#"{"arrivals":[{"at_secs":1.0,"spec":{"workload":"nope"}}]}"#)
            .unwrap();
        assert!(JobTrace::from_json(&v).is_none(), "unknown workload");
        let v = Json::parse(r#"{"no_arrivals":true}"#).unwrap();
        assert!(JobTrace::from_json(&v).is_none());
        // unknown or missing strategy must fail at load, not at replay
        let v = Json::parse(
            r#"{"arrivals":[{"at_secs":1.0,"strategy":"jot",
                "spec":{"workload":"cifar100"},"class":"standard"}]}"#,
        )
        .unwrap();
        assert!(JobTrace::from_json(&v).is_none(), "unknown strategy");
        let v = Json::parse(
            r#"{"arrivals":[{"at_secs":1.0,
                "spec":{"workload":"cifar100"},"class":"standard"}]}"#,
        )
        .unwrap();
        assert!(JobTrace::from_json(&v).is_none(), "missing strategy");
    }

    #[test]
    fn trace_driven_arrivals_sort_on_entry() {
        let cfg = TraceConfig {
            n_jobs: 3,
            seed: 1,
            ..Default::default()
        };
        let mut arrivals = poisson_trace(&cfg).arrivals;
        arrivals[0].at_secs = 500.0; // force out-of-order entry
        let t = JobTrace::from_arrivals(arrivals);
        for w in t.arrivals.windows(2) {
            assert!(w[0].at_secs <= w[1].at_secs);
        }
    }
}
