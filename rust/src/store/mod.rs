//! Persistence substrates: metadata store + object store.
//!
//! The paper's platform (§5.2) keeps job metadata "in a persistent store
//! like MongoDB" and buffers model state in a cloud object store. We build
//! both in-process:
//!
//! * [`MetaStore`] — versioned document store keyed by collection/id, with
//!   optional JSON-file persistence (compare-and-swap on version numbers so
//!   concurrent aggregator tasks can't clobber each other's job state).
//! * [`ObjectStore`] — content-addressed blob store for model updates and
//!   partial-aggregate checkpoints, with byte-accounting so experiments can
//!   report state-transfer volumes.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Mutex;

use crate::mq::Payload;
use crate::util::json::Json;

/// A versioned document.
#[derive(Clone, Debug, PartialEq)]
pub struct Doc {
    pub version: u64,
    pub body: Json,
}

/// Errors from the metadata store.
#[derive(Debug, PartialEq)]
pub enum StoreError {
    /// CAS failure: expected version does not match current.
    VersionConflict { expected: u64, actual: u64 },
    NotFound,
    Io(String),
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::VersionConflict { expected, actual } => {
                write!(f, "version conflict: expected {expected}, actual {actual}")
            }
            StoreError::NotFound => write!(f, "document not found"),
            StoreError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for StoreError {}

/// MongoDB stand-in: collections of versioned JSON documents.
#[derive(Debug, Default)]
pub struct MetaStore {
    inner: Mutex<BTreeMap<String, BTreeMap<String, Doc>>>,
    persist_path: Option<PathBuf>,
}

impl MetaStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// A store that persists every mutation to a JSON file (durability for
    /// the live platform; the sim grid uses the in-memory form).
    pub fn persistent(path: PathBuf) -> Result<Self, StoreError> {
        let mut s = Self {
            inner: Mutex::new(BTreeMap::new()),
            persist_path: Some(path.clone()),
        };
        if path.exists() {
            let text =
                std::fs::read_to_string(&path).map_err(|e| StoreError::Io(e.to_string()))?;
            if !text.trim().is_empty() {
                s.load_json(&text)?;
            }
        }
        Ok(s)
    }

    fn load_json(&mut self, text: &str) -> Result<(), StoreError> {
        let v = Json::parse(text).map_err(|e| StoreError::Io(e.to_string()))?;
        let mut map = BTreeMap::new();
        if let Some(cols) = v.as_obj() {
            for (col, docs) in cols {
                let mut dm = BTreeMap::new();
                if let Some(docs) = docs.as_obj() {
                    for (id, d) in docs {
                        dm.insert(
                            id.clone(),
                            Doc {
                                version: d.get("version").as_u64().unwrap_or(1),
                                body: d.get("body").clone(),
                            },
                        );
                    }
                }
                map.insert(col.clone(), dm);
            }
        }
        *self.inner.lock().unwrap() = map;
        Ok(())
    }

    fn flush(&self, inner: &BTreeMap<String, BTreeMap<String, Doc>>) -> Result<(), StoreError> {
        let Some(path) = &self.persist_path else {
            return Ok(());
        };
        let mut cols = BTreeMap::new();
        for (col, docs) in inner {
            let mut dm = BTreeMap::new();
            for (id, d) in docs {
                dm.insert(
                    id.clone(),
                    Json::obj(vec![
                        ("version", Json::num(d.version as f64)),
                        ("body", d.body.clone()),
                    ]),
                );
            }
            cols.insert(col.clone(), Json::Obj(dm));
        }
        std::fs::write(path, Json::Obj(cols).print()).map_err(|e| StoreError::Io(e.to_string()))
    }

    /// Insert or replace unconditionally; returns the new version.
    pub fn put(&self, collection: &str, id: &str, body: Json) -> Result<u64, StoreError> {
        let mut inner = self.inner.lock().unwrap();
        let col = inner.entry(collection.to_string()).or_default();
        let version = col.get(id).map(|d| d.version + 1).unwrap_or(1);
        col.insert(id.to_string(), Doc { version, body });
        self.flush(&inner)?;
        Ok(version)
    }

    /// Compare-and-swap on version.
    pub fn cas(
        &self,
        collection: &str,
        id: &str,
        expected_version: u64,
        body: Json,
    ) -> Result<u64, StoreError> {
        let mut inner = self.inner.lock().unwrap();
        let col = inner.entry(collection.to_string()).or_default();
        let actual = col.get(id).map(|d| d.version).unwrap_or(0);
        if actual != expected_version {
            return Err(StoreError::VersionConflict {
                expected: expected_version,
                actual,
            });
        }
        let version = actual + 1;
        col.insert(id.to_string(), Doc { version, body });
        self.flush(&inner)?;
        Ok(version)
    }

    pub fn get(&self, collection: &str, id: &str) -> Option<Doc> {
        self.inner
            .lock()
            .unwrap()
            .get(collection)
            .and_then(|c| c.get(id))
            .cloned()
    }

    pub fn delete(&self, collection: &str, id: &str) -> Result<(), StoreError> {
        let mut inner = self.inner.lock().unwrap();
        let removed = inner
            .get_mut(collection)
            .and_then(|c| c.remove(id))
            .is_some();
        if !removed {
            return Err(StoreError::NotFound);
        }
        self.flush(&inner)?;
        Ok(())
    }

    pub fn list(&self, collection: &str) -> Vec<String> {
        self.inner
            .lock()
            .unwrap()
            .get(collection)
            .map(|c| c.keys().cloned().collect())
            .unwrap_or_default()
    }
}

/// Object store for model blobs (cloud-object-store stand-in).
///
/// By-reference MQ payloads ([`Payload::Ref`]) round-trip through here:
/// [`put_payload`](ObjectStore::put_payload) parks a blob and returns the
/// `Ref` to enqueue, [`resolve`](ObjectStore::resolve) dereferences any
/// payload back to its data. With [`persistent`](ObjectStore::persistent)
/// the blobs live on disk too, so a `Ref` recovered from the WAL after a
/// `kill -9` still dereferences.
#[derive(Debug, Default)]
pub struct ObjectStore {
    inner: Mutex<ObjectStoreInner>,
    blob_dir: Option<PathBuf>,
}

#[derive(Debug, Default)]
struct ObjectStoreInner {
    blobs: BTreeMap<String, Vec<f32>>,
    bytes_put: u64,
    bytes_got: u64,
}

/// Keys may contain path separators; file names must not. Keep the key
/// readable and make it unique with a crc32 suffix.
fn blob_file(dir: &std::path::Path, key: &str) -> PathBuf {
    let safe: String = key
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
        .collect();
    dir.join(format!("{safe}-{:08x}.f32", crate::wal::crc32(key.as_bytes())))
}

impl ObjectStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// A store that mirrors every blob to `<dir>` as little-endian f32
    /// files, and reads back blobs it doesn't hold in memory — the
    /// durable sibling of the in-memory store.
    pub fn persistent<P: Into<PathBuf>>(dir: P) -> Result<Self, StoreError> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir).map_err(|e| StoreError::Io(e.to_string()))?;
        Ok(Self {
            inner: Mutex::new(ObjectStoreInner::default()),
            blob_dir: Some(dir),
        })
    }

    pub fn put(&self, key: &str, data: Vec<f32>) {
        let mut g = self.inner.lock().unwrap();
        g.bytes_put += (data.len() * 4) as u64;
        if let Some(dir) = &self.blob_dir {
            let mut bytes = Vec::with_capacity(data.len() * 4);
            for x in &data {
                bytes.extend_from_slice(&x.to_le_bytes());
            }
            if let Err(e) = std::fs::write(blob_file(dir, key), bytes) {
                panic!("persistent object store write failed for {key:?}: {e}");
            }
        }
        g.blobs.insert(key.to_string(), data);
    }

    /// Park `data` under `key` and return the by-reference payload to
    /// enqueue in its place.
    pub fn put_payload(&self, key: &str, data: Vec<f32>) -> Payload {
        let size_bytes = (data.len() * 4) as u64;
        self.put(key, data);
        Payload::Ref {
            key: key.to_string(),
            size_bytes,
        }
    }

    pub fn get(&self, key: &str) -> Option<Vec<f32>> {
        let mut g = self.inner.lock().unwrap();
        let mut v = g.blobs.get(key).cloned();
        if v.is_none() {
            // Not resident (e.g. a fresh process after a crash): fall
            // back to the blob file and re-admit it.
            if let Some(dir) = &self.blob_dir {
                if let Ok(bytes) = std::fs::read(blob_file(dir, key)) {
                    let data: Vec<f32> = bytes
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    g.blobs.insert(key.to_string(), data.clone());
                    v = Some(data);
                }
            }
        }
        if let Some(ref d) = v {
            g.bytes_got += (d.len() * 4) as u64;
        }
        v
    }

    /// Dereference a payload: inline/mapped data is copied out, `Ref`
    /// fetches the blob, `Sim` has no data.
    pub fn resolve(&self, payload: &Payload) -> Option<Vec<f32>> {
        match payload {
            Payload::Ref { key, .. } => self.get(key),
            p => p.data().map(|d| d.to_vec()),
        }
    }

    pub fn delete(&self, key: &str) -> bool {
        let mut g = self.inner.lock().unwrap();
        let mem = g.blobs.remove(key).is_some();
        let disk = self
            .blob_dir
            .as_ref()
            .map(|dir| std::fs::remove_file(blob_file(dir, key)).is_ok())
            .unwrap_or(false);
        mem || disk
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().blobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (bytes written, bytes read) — used to charge state-transfer time.
    pub fn traffic(&self) -> (u64, u64) {
        let g = self.inner.lock().unwrap();
        (g.bytes_put, g.bytes_got)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_version_increments() {
        let s = MetaStore::new();
        let v1 = s.put("jobs", "j1", Json::num(1.0)).unwrap();
        let v2 = s.put("jobs", "j1", Json::num(2.0)).unwrap();
        assert_eq!((v1, v2), (1, 2));
        let d = s.get("jobs", "j1").unwrap();
        assert_eq!(d.version, 2);
        assert_eq!(d.body, Json::num(2.0));
    }

    #[test]
    fn cas_guards_concurrent_writers() {
        let s = MetaStore::new();
        s.put("jobs", "j1", Json::num(1.0)).unwrap();
        // stale writer (expected v0) loses
        let err = s.cas("jobs", "j1", 0, Json::num(9.0)).unwrap_err();
        assert!(matches!(err, StoreError::VersionConflict { actual: 1, .. }));
        // current writer wins
        let v = s.cas("jobs", "j1", 1, Json::num(3.0)).unwrap();
        assert_eq!(v, 2);
    }

    #[test]
    fn delete_and_list() {
        let s = MetaStore::new();
        s.put("c", "a", Json::Null).unwrap();
        s.put("c", "b", Json::Null).unwrap();
        assert_eq!(s.list("c"), vec!["a".to_string(), "b".to_string()]);
        s.delete("c", "a").unwrap();
        assert_eq!(s.list("c"), vec!["b".to_string()]);
        assert_eq!(s.delete("c", "zz"), Err(StoreError::NotFound));
    }

    #[test]
    fn persistence_roundtrip() {
        let dir = std::env::temp_dir().join(format!("fljit_store_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("meta.json");
        let _ = std::fs::remove_file(&path);
        {
            let s = MetaStore::persistent(path.clone()).unwrap();
            s.put("jobs", "j1", Json::obj(vec![("rounds", Json::num(50.0))]))
                .unwrap();
            s.put("jobs", "j1", Json::obj(vec![("rounds", Json::num(51.0))]))
                .unwrap();
        }
        let s2 = MetaStore::persistent(path.clone()).unwrap();
        let d = s2.get("jobs", "j1").unwrap();
        assert_eq!(d.version, 2);
        assert_eq!(d.body.get("rounds").as_u64(), Some(51));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn object_store_traffic_accounting() {
        let o = ObjectStore::new();
        o.put("m1", vec![0.0; 1024]);
        assert_eq!(o.traffic().0, 4096);
        let got = o.get("m1").unwrap();
        assert_eq!(got.len(), 1024);
        assert_eq!(o.traffic().1, 4096);
        assert!(o.get("missing").is_none());
        assert!(o.delete("m1"));
        assert!(o.is_empty());
    }

    #[test]
    fn ref_payload_roundtrips_through_store_and_queue() {
        use crate::mq::{Message, MessageQueue};
        let o = ObjectStore::new();
        let data = vec![1.0f32, -2.5, 3.25];
        let payload = o.put_payload("job0/round1/p7", data.clone());
        assert_eq!(payload.size_bytes(), 12, "ref carries the blob size");
        let q = MessageQueue::new();
        q.produce(
            "job0/round1/updates",
            Message {
                party: 7,
                round: 1,
                weight: 1.0,
                enqueued_at: 0,
                payload,
            },
        );
        assert_eq!(q.resident_bytes(), 12, "sizing path no longer inert");
        let m = q.fetch("job0/round1/updates", 0, 1).remove(0);
        assert!(m.payload.data().is_none(), "ref has no inline data");
        assert_eq!(o.resolve(&m.payload).unwrap(), data, "deref via the store");
        // resolve is uniform across payload kinds
        assert_eq!(
            o.resolve(&Payload::Inline(vec![9.0])).unwrap(),
            vec![9.0f32]
        );
        assert!(o.resolve(&Payload::Sim { size_bytes: 8 }).is_none());
    }

    #[test]
    fn persistent_object_store_survives_reopen() {
        let dir = std::env::temp_dir().join(format!("fljit_blobs_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let data = vec![0.5f32; 16];
        {
            let o = ObjectStore::persistent(&dir).unwrap();
            let p = o.put_payload("ckpt/partial", data.clone());
            assert_eq!(
                p,
                Payload::Ref {
                    key: "ckpt/partial".into(),
                    size_bytes: 64
                }
            );
        }
        // Fresh store over the same dir (a revived aggregator): the blob
        // comes back from disk on demand.
        let o2 = ObjectStore::persistent(&dir).unwrap();
        assert!(o2.is_empty(), "nothing resident yet");
        assert_eq!(
            o2.resolve(&Payload::Ref {
                key: "ckpt/partial".into(),
                size_bytes: 64
            })
            .unwrap(),
            data
        );
        assert!(o2.delete("ckpt/partial"));
        assert!(o2.get("ckpt/partial").is_none(), "gone from disk too");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
