//! Persistence substrates: metadata store + object store.
//!
//! The paper's platform (§5.2) keeps job metadata "in a persistent store
//! like MongoDB" and buffers model state in a cloud object store. We build
//! both in-process:
//!
//! * [`MetaStore`] — versioned document store keyed by collection/id, with
//!   optional JSON-file persistence (compare-and-swap on version numbers so
//!   concurrent aggregator tasks can't clobber each other's job state).
//! * [`ObjectStore`] — content-addressed blob store for model updates and
//!   partial-aggregate checkpoints, with byte-accounting so experiments can
//!   report state-transfer volumes.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Mutex;

use crate::util::json::Json;

/// A versioned document.
#[derive(Clone, Debug, PartialEq)]
pub struct Doc {
    pub version: u64,
    pub body: Json,
}

/// Errors from the metadata store.
#[derive(Debug, PartialEq)]
pub enum StoreError {
    /// CAS failure: expected version does not match current.
    VersionConflict { expected: u64, actual: u64 },
    NotFound,
    Io(String),
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::VersionConflict { expected, actual } => {
                write!(f, "version conflict: expected {expected}, actual {actual}")
            }
            StoreError::NotFound => write!(f, "document not found"),
            StoreError::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for StoreError {}

/// MongoDB stand-in: collections of versioned JSON documents.
#[derive(Debug, Default)]
pub struct MetaStore {
    inner: Mutex<BTreeMap<String, BTreeMap<String, Doc>>>,
    persist_path: Option<PathBuf>,
}

impl MetaStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// A store that persists every mutation to a JSON file (durability for
    /// the live platform; the sim grid uses the in-memory form).
    pub fn persistent(path: PathBuf) -> Result<Self, StoreError> {
        let mut s = Self {
            inner: Mutex::new(BTreeMap::new()),
            persist_path: Some(path.clone()),
        };
        if path.exists() {
            let text =
                std::fs::read_to_string(&path).map_err(|e| StoreError::Io(e.to_string()))?;
            if !text.trim().is_empty() {
                s.load_json(&text)?;
            }
        }
        Ok(s)
    }

    fn load_json(&mut self, text: &str) -> Result<(), StoreError> {
        let v = Json::parse(text).map_err(|e| StoreError::Io(e.to_string()))?;
        let mut map = BTreeMap::new();
        if let Some(cols) = v.as_obj() {
            for (col, docs) in cols {
                let mut dm = BTreeMap::new();
                if let Some(docs) = docs.as_obj() {
                    for (id, d) in docs {
                        dm.insert(
                            id.clone(),
                            Doc {
                                version: d.get("version").as_u64().unwrap_or(1),
                                body: d.get("body").clone(),
                            },
                        );
                    }
                }
                map.insert(col.clone(), dm);
            }
        }
        *self.inner.lock().unwrap() = map;
        Ok(())
    }

    fn flush(&self, inner: &BTreeMap<String, BTreeMap<String, Doc>>) -> Result<(), StoreError> {
        let Some(path) = &self.persist_path else {
            return Ok(());
        };
        let mut cols = BTreeMap::new();
        for (col, docs) in inner {
            let mut dm = BTreeMap::new();
            for (id, d) in docs {
                dm.insert(
                    id.clone(),
                    Json::obj(vec![
                        ("version", Json::num(d.version as f64)),
                        ("body", d.body.clone()),
                    ]),
                );
            }
            cols.insert(col.clone(), Json::Obj(dm));
        }
        std::fs::write(path, Json::Obj(cols).print()).map_err(|e| StoreError::Io(e.to_string()))
    }

    /// Insert or replace unconditionally; returns the new version.
    pub fn put(&self, collection: &str, id: &str, body: Json) -> Result<u64, StoreError> {
        let mut inner = self.inner.lock().unwrap();
        let col = inner.entry(collection.to_string()).or_default();
        let version = col.get(id).map(|d| d.version + 1).unwrap_or(1);
        col.insert(id.to_string(), Doc { version, body });
        self.flush(&inner)?;
        Ok(version)
    }

    /// Compare-and-swap on version.
    pub fn cas(
        &self,
        collection: &str,
        id: &str,
        expected_version: u64,
        body: Json,
    ) -> Result<u64, StoreError> {
        let mut inner = self.inner.lock().unwrap();
        let col = inner.entry(collection.to_string()).or_default();
        let actual = col.get(id).map(|d| d.version).unwrap_or(0);
        if actual != expected_version {
            return Err(StoreError::VersionConflict {
                expected: expected_version,
                actual,
            });
        }
        let version = actual + 1;
        col.insert(id.to_string(), Doc { version, body });
        self.flush(&inner)?;
        Ok(version)
    }

    pub fn get(&self, collection: &str, id: &str) -> Option<Doc> {
        self.inner
            .lock()
            .unwrap()
            .get(collection)
            .and_then(|c| c.get(id))
            .cloned()
    }

    pub fn delete(&self, collection: &str, id: &str) -> Result<(), StoreError> {
        let mut inner = self.inner.lock().unwrap();
        let removed = inner
            .get_mut(collection)
            .and_then(|c| c.remove(id))
            .is_some();
        if !removed {
            return Err(StoreError::NotFound);
        }
        self.flush(&inner)?;
        Ok(())
    }

    pub fn list(&self, collection: &str) -> Vec<String> {
        self.inner
            .lock()
            .unwrap()
            .get(collection)
            .map(|c| c.keys().cloned().collect())
            .unwrap_or_default()
    }
}

/// Object store for model blobs (cloud-object-store stand-in).
#[derive(Debug, Default)]
pub struct ObjectStore {
    inner: Mutex<ObjectStoreInner>,
}

#[derive(Debug, Default)]
struct ObjectStoreInner {
    blobs: BTreeMap<String, Vec<f32>>,
    bytes_put: u64,
    bytes_got: u64,
}

impl ObjectStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn put(&self, key: &str, data: Vec<f32>) {
        let mut g = self.inner.lock().unwrap();
        g.bytes_put += (data.len() * 4) as u64;
        g.blobs.insert(key.to_string(), data);
    }

    pub fn get(&self, key: &str) -> Option<Vec<f32>> {
        let mut g = self.inner.lock().unwrap();
        let v = g.blobs.get(key).cloned();
        if let Some(ref d) = v {
            g.bytes_got += (d.len() * 4) as u64;
        }
        v
    }

    pub fn delete(&self, key: &str) -> bool {
        self.inner.lock().unwrap().blobs.remove(key).is_some()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().blobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (bytes written, bytes read) — used to charge state-transfer time.
    pub fn traffic(&self) -> (u64, u64) {
        let g = self.inner.lock().unwrap();
        (g.bytes_put, g.bytes_got)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_version_increments() {
        let s = MetaStore::new();
        let v1 = s.put("jobs", "j1", Json::num(1.0)).unwrap();
        let v2 = s.put("jobs", "j1", Json::num(2.0)).unwrap();
        assert_eq!((v1, v2), (1, 2));
        let d = s.get("jobs", "j1").unwrap();
        assert_eq!(d.version, 2);
        assert_eq!(d.body, Json::num(2.0));
    }

    #[test]
    fn cas_guards_concurrent_writers() {
        let s = MetaStore::new();
        s.put("jobs", "j1", Json::num(1.0)).unwrap();
        // stale writer (expected v0) loses
        let err = s.cas("jobs", "j1", 0, Json::num(9.0)).unwrap_err();
        assert!(matches!(err, StoreError::VersionConflict { actual: 1, .. }));
        // current writer wins
        let v = s.cas("jobs", "j1", 1, Json::num(3.0)).unwrap();
        assert_eq!(v, 2);
    }

    #[test]
    fn delete_and_list() {
        let s = MetaStore::new();
        s.put("c", "a", Json::Null).unwrap();
        s.put("c", "b", Json::Null).unwrap();
        assert_eq!(s.list("c"), vec!["a".to_string(), "b".to_string()]);
        s.delete("c", "a").unwrap();
        assert_eq!(s.list("c"), vec!["b".to_string()]);
        assert_eq!(s.delete("c", "zz"), Err(StoreError::NotFound));
    }

    #[test]
    fn persistence_roundtrip() {
        let dir = std::env::temp_dir().join(format!("fljit_store_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("meta.json");
        let _ = std::fs::remove_file(&path);
        {
            let s = MetaStore::persistent(path.clone()).unwrap();
            s.put("jobs", "j1", Json::obj(vec![("rounds", Json::num(50.0))]))
                .unwrap();
            s.put("jobs", "j1", Json::obj(vec![("rounds", Json::num(51.0))]))
                .unwrap();
        }
        let s2 = MetaStore::persistent(path.clone()).unwrap();
        let d = s2.get("jobs", "j1").unwrap();
        assert_eq!(d.version, 2);
        assert_eq!(d.body.get("rounds").as_u64(), Some(51));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn object_store_traffic_accounting() {
        let o = ObjectStore::new();
        o.put("m1", vec![0.0; 1024]);
        assert_eq!(o.traffic().0, 4096);
        let got = o.get("m1").unwrap();
        assert_eq!(got.len(), 1024);
        assert_eq!(o.traffic().1, 4096);
        assert!(o.get("missing").is_none());
        assert!(o.delete("m1"));
        assert!(o.is_empty());
    }
}
