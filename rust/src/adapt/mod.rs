//! # Adaptive JIT — online arrival-distribution estimation (PR 10)
//!
//! The paper's JIT scheduler defers aggregation to a *configured*
//! deadline derived from the §5.4 estimator's fixed predictions. This
//! module makes that deadline *learned* (ROADMAP direction 2, following
//! "Adaptive Aggregation for Federated Learning"): a per-job
//! [`AdaptivePolicy`] maintains an online sketch of the job's
//! update-arrival lag distribution — fed from the same `UpdateArrival`
//! bookkeeping [`JobEngine`](crate::coordinator::driver::JobEngine)
//! already does in both regimes — and converts its quantiles into three
//! live control signals:
//!
//! 1. **Fuse deadline** — the JIT / async-stale deadline timer for the
//!    next round is re-armed (`EventQueue::cancel` + re-insert) to
//!    `max(fixed defer, pN arrival lag × (1 + margin) + drift)`. The
//!    `max` is deliberate: the learned deadline only ever *defers
//!    further* than the estimator's fixed prediction, so aggregator
//!    spin-up is never earlier (resource usage ≤ fixed) while straggler
//!    updates get a deadline that tracks the observed tail.
//! 2. **Straggler cutoff / quorum** — on `FleetFaults`-degraded rounds
//!    the engine lowers its quorum to the expected on-time count; the
//!    policy *restores* it toward the configured base when the observed
//!    arrival rate shows the fleet actually delivers more (never below
//!    the degraded value, never above what the round can deliver).
//! 3. **Admission budget autoscaling** — bounded min/max budget for the
//!    broker's [`AdmissionController`](crate::broker::admission), which
//!    grows toward the head-of-line job's demand and shrinks back when
//!    the queue drains (see `AdmissionConfig::autoscale`).
//!
//! ## The sketch
//!
//! [`ArrivalSketch`] is a fixed-size (256-bin) log-bucketed quantile
//! sketch with bounded *relative* error (DDSketch-style bucketing; the
//! same fixed-footprint mergeable-sketch family as GK, chosen over
//! P²/GK because its merge is an element-wise counter add — **exactly**
//! associative and commutative, bit-for-bit, which is what lets shard-
//! or regime-split observation streams fold to one identical state).
//! It consumes **no randomness**: every operation is a pure function of
//! the observed lags, so feeding it inside `JobEngine::handle_update`
//! leaves the engine's seeded rng stream untouched and every existing
//! bit-identity pin (sim ≡ live, kill/resume, replay fast-forward)
//! holds with adaptation on or off.
//!
//! ## Checkpointing
//!
//! Policy state serializes to a flat `Vec<f32>` ([`AdaptivePolicy::
//! to_f32s`]) carried in the existing WAL-framed
//! [`CheckpointState`](crate::mq::CheckpointState) records under
//! [`adapt_slot`](crate::mq::adapt_slot), written at round completion.
//! A resumed aggregator reloads the sketch as of the last completed
//! round and replays the open round's logged arrivals through the same
//! `handle_update` path, so the resumed policy state is bit-identical
//! to the uninterrupted run's.

use crate::util::stats::Ewma;

/// Number of log-spaced buckets in an [`ArrivalSketch`].
pub const SKETCH_BINS: usize = 256;
/// Lags at or below this many seconds collapse into bucket 0.
pub const SKETCH_MIN_LAG: f64 = 1e-3;
/// Geometric bucket growth factor: relative quantile error is bounded
/// by `(GAMMA - 1) / (GAMMA + 1)` ≈ 3.8%, and 256 buckets cover
/// `1 ms … ~3.6e5 s` (≈ 100 hours) — far past any round deadline.
pub const SKETCH_GAMMA: f64 = 1.08;

/// Fixed-size mergeable quantile sketch over positive arrival lags
/// (seconds). Deterministic, rng-free, exactly associative under
/// [`merge`](ArrivalSketch::merge).
#[derive(Clone, Debug, PartialEq)]
pub struct ArrivalSketch {
    bins: Vec<u64>,
    count: u64,
}

impl Default for ArrivalSketch {
    fn default() -> Self {
        Self::new()
    }
}

impl ArrivalSketch {
    pub fn new() -> Self {
        Self { bins: vec![0; SKETCH_BINS], count: 0 }
    }

    /// Bucket index for a lag: bucket 0 holds `(-inf, MIN_LAG]`, bucket
    /// `i ≥ 1` holds `(MIN_LAG·γ^(i-1), MIN_LAG·γ^i]`, the last bucket
    /// absorbs the overflow tail.
    fn bin_of(lag_secs: f64) -> usize {
        if !(lag_secs > SKETCH_MIN_LAG) {
            return 0;
        }
        let i = ((lag_secs / SKETCH_MIN_LAG).ln() / SKETCH_GAMMA.ln()).ceil() as usize;
        i.min(SKETCH_BINS - 1)
    }

    /// Representative lag of a bucket (its geometric midpoint).
    fn value_of(bin: usize) -> f64 {
        if bin == 0 {
            return SKETCH_MIN_LAG * 0.5;
        }
        // midpoint of (MIN·γ^(bin-1), MIN·γ^bin]
        SKETCH_MIN_LAG * SKETCH_GAMMA.powi(bin as i32 - 1) * (1.0 + SKETCH_GAMMA) / 2.0
    }

    pub fn observe(&mut self, lag_secs: f64) {
        self.bins[Self::bin_of(lag_secs)] += 1;
        self.count += 1;
    }

    /// Element-wise counter add — exactly associative and commutative.
    pub fn merge(&mut self, other: &ArrivalSketch) {
        for (a, b) in self.bins.iter_mut().zip(&other.bins) {
            *a += *b;
        }
        self.count += other.count;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn clear(&mut self) {
        self.bins.iter_mut().for_each(|b| *b = 0);
        self.count = 0;
    }

    /// The `q`-quantile (`0 ≤ q ≤ 1`) of the observed lags, within
    /// ±3.8% relative error (plus the 1 ms bucket-0 floor). Returns
    /// 0.0 on an empty sketch.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Self::value_of(i);
            }
        }
        Self::value_of(SKETCH_BINS - 1)
    }

    /// Counts as exact `f32`s (counts stay far below 2^24 for any
    /// realistic parties × rounds product; debug-asserted).
    pub fn to_f32s(&self) -> Vec<f32> {
        self.bins
            .iter()
            .map(|&c| {
                debug_assert!(c < (1u64 << 24), "sketch bin count exceeds exact f32 range");
                c as f32
            })
            .collect()
    }

    pub fn from_f32s(data: &[f32]) -> Option<Self> {
        if data.len() != SKETCH_BINS {
            return None;
        }
        let bins: Vec<u64> = data.iter().map(|&c| c as u64).collect();
        let count = bins.iter().sum();
        Some(Self { bins, count })
    }
}

/// Knobs of the adaptive subsystem. Off by default — the zero-cost
/// opt-in follows the `FleetFaults::is_none()` pattern: a disabled
/// config means no sketch exists, no observation happens, no rng is
/// consumed, and every pre-existing bit-identity pin passes unchanged.
#[derive(Clone, Debug, PartialEq)]
pub struct AdaptiveConfig {
    pub enabled: bool,
    /// Arrival-lag quantile the learned fuse deadline targets.
    pub deadline_quantile: f64,
    /// Safety margin multiplied onto the learned lag quantile.
    pub margin: f64,
    /// EWMA weight of the round-over-round quantile drift term.
    pub drift_alpha: f64,
    /// Completed rounds observed before the policy starts steering.
    pub warmup_rounds: u32,
    /// Mid-round re-arm hysteresis: the armed deadline is only pulled
    /// in when the live estimate undercuts it by more than this
    /// fraction (prevents timer churn on every arrival).
    pub rearm_threshold: f64,
    /// Restore `FleetFaults`-degraded quorums toward the configured
    /// base when the observed arrival rate supports it.
    pub adapt_quorum: bool,
    /// Admission budget autoscale bounds; `(0, 0)` leaves the broker
    /// budget fixed.
    pub admission_min: usize,
    pub admission_max: usize,
}

impl Default for AdaptiveConfig {
    fn default() -> Self {
        Self::none()
    }
}

impl AdaptiveConfig {
    /// Adaptation disabled (the default): zero-cost, bit-identical to
    /// a build without the subsystem.
    pub fn none() -> Self {
        Self {
            enabled: false,
            deadline_quantile: 0.90,
            margin: 0.05,
            drift_alpha: 0.3,
            warmup_rounds: 1,
            rearm_threshold: 0.10,
            adapt_quorum: true,
            admission_min: 0,
            admission_max: 0,
        }
    }

    /// Adaptation on with the documented defaults (p90 deadline, 5%
    /// margin, quorum restore, no admission autoscale).
    pub fn on() -> Self {
        Self { enabled: true, ..Self::none() }
    }

    pub fn is_none(&self) -> bool {
        !self.enabled
    }

    /// Admission autoscale bounds, normalized: `None` unless both
    /// bounds are set and ordered.
    pub fn admission_bounds(&self) -> Option<(usize, usize)> {
        if self.enabled && self.admission_max > 0 && self.admission_min <= self.admission_max
        {
            Some((self.admission_min.max(1), self.admission_max))
        } else {
            None
        }
    }
}

/// Per-job online arrival estimator + control policy. Owned by the
/// `JobEngine` (one per job, identical in sim and live), fed a lag
/// sample per delivered update, rolled over per completed round.
#[derive(Clone, Debug)]
pub struct AdaptivePolicy {
    pub cfg: AdaptiveConfig,
    /// Lag distribution across all completed rounds.
    cum: ArrivalSketch,
    /// Lag distribution of the in-flight round (merged into `cum` at
    /// [`end_round`](Self::end_round)).
    round: ArrivalSketch,
    /// EWMA of the round-over-round drift of the target quantile —
    /// a positive drift (fleet slowing down) pads the deadline.
    drift: Ewma,
    rounds_observed: u32,
    /// Target quantile of the previous completed round (NaN = none).
    last_round_q: f64,
}

impl AdaptivePolicy {
    pub fn new(cfg: AdaptiveConfig) -> Self {
        let drift = Ewma::new(cfg.drift_alpha);
        Self {
            cfg,
            cum: ArrivalSketch::new(),
            round: ArrivalSketch::new(),
            drift,
            rounds_observed: 0,
            last_round_q: f64::NAN,
        }
    }

    /// Feed one update's arrival lag (seconds since round start).
    pub fn observe(&mut self, lag_secs: f64) {
        self.round.observe(lag_secs);
    }

    /// Roll the in-flight round into the cumulative state: update the
    /// drift EWMA from the per-round target quantile, merge, reset.
    pub fn end_round(&mut self) {
        if !self.round.is_empty() {
            let q_now = self.round.quantile(self.cfg.deadline_quantile);
            if self.last_round_q.is_finite() {
                self.drift.observe(q_now - self.last_round_q);
            }
            self.last_round_q = q_now;
            self.cum.merge(&self.round);
            self.round.clear();
        }
        self.rounds_observed += 1;
    }

    pub fn rounds_observed(&self) -> u32 {
        self.rounds_observed
    }

    fn warmed_up(&self) -> bool {
        self.rounds_observed >= self.cfg.warmup_rounds && !self.cum.is_empty()
    }

    fn defer_from(&self, sketch: &ArrivalSketch) -> f64 {
        let q = sketch.quantile(self.cfg.deadline_quantile);
        let drift = self.drift.get().unwrap_or(0.0).max(0.0);
        q * (1.0 + self.cfg.margin) + drift
    }

    /// Learned defer (seconds from round start) from completed rounds,
    /// or `None` during warm-up.
    pub fn learned_defer(&self) -> Option<f64> {
        if !self.warmed_up() {
            return None;
        }
        Some(self.defer_from(&self.cum))
    }

    /// Signal (a), round-start form: the fuse defer for the next round.
    /// Never earlier than the estimator's fixed prediction — adaptation
    /// only defers aggregator spin-up further, it never advances it.
    pub fn deadline_defer(&self, fixed_defer: f64) -> f64 {
        match self.learned_defer() {
            Some(learned) => fixed_defer.max(learned),
            None => fixed_defer,
        }
    }

    /// Signal (a), mid-round form: the live defer estimate including
    /// the in-flight round's arrivals. `Some(new_defer)` when the armed
    /// defer should be pulled in (shortened) past the re-arm
    /// hysteresis; still floored at `fixed_defer`.
    pub fn rearm_defer(&self, fixed_defer: f64, armed_defer: f64) -> Option<f64> {
        if !self.warmed_up() || self.round.is_empty() {
            return None;
        }
        let mut live = self.cum.clone();
        live.merge(&self.round);
        let target = self.defer_from(&live).max(fixed_defer);
        if armed_defer - target > self.cfg.rearm_threshold * armed_defer.max(f64::EPSILON) {
            Some(target)
        } else {
            None
        }
    }

    /// Signal (b): quorum for a `FleetFaults`-degraded round. Restores
    /// from the degraded value toward `base` when the mean observed
    /// arrivals per completed round support it; monotone in
    /// `[degraded, base]`, clamped by `deliverable` (updates the round
    /// can actually produce — restoring past that would starve it).
    pub fn quorum_for(&self, degraded: usize, base: usize, deliverable: usize) -> usize {
        if !self.cfg.adapt_quorum || !self.warmed_up() || self.rounds_observed == 0 {
            return degraded;
        }
        let per_round = (self.cum.count() / self.rounds_observed as u64) as usize;
        degraded.max(base.min(per_round)).min(deliverable.max(degraded))
    }

    /// Live quantiles (p50, p90, p99) of the cumulative lag sketch —
    /// the telemetry gauge payload.
    pub fn quantiles(&self) -> (f64, f64, f64) {
        (self.cum.quantile(0.50), self.cum.quantile(0.90), self.cum.quantile(0.99))
    }

    /// Flat checkpoint payload (carried in `CheckpointState::acc`):
    /// `[version, rounds_observed, last_round_q, drift, cum bins…,
    /// round bins…]`. Counts are exact in f32 (< 2^24).
    pub fn to_f32s(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(4 + 2 * SKETCH_BINS);
        out.push(1.0);
        out.push(self.rounds_observed as f32);
        out.push(self.last_round_q as f32);
        out.push(self.drift.get().map(|v| v as f32).unwrap_or(f32::NAN));
        out.extend(self.cum.to_f32s());
        out.extend(self.round.to_f32s());
        out
    }

    /// Rebuild from a checkpoint payload; config comes from the
    /// session (it is not part of the durable state). Returns `None`
    /// on a malformed or version-mismatched payload.
    pub fn from_f32s(cfg: AdaptiveConfig, data: &[f32]) -> Option<Self> {
        if data.len() != 4 + 2 * SKETCH_BINS || data[0] != 1.0 {
            return None;
        }
        let mut drift = Ewma::new(cfg.drift_alpha);
        if data[3].is_finite() {
            // the first observe sets the EWMA to the raw value exactly
            drift.observe(data[3] as f64);
        }
        Some(Self {
            cfg,
            rounds_observed: data[1] as u32,
            last_round_q: data[2] as f64,
            drift,
            cum: ArrivalSketch::from_f32s(&data[4..4 + SKETCH_BINS])?,
            round: ArrivalSketch::from_f32s(&data[4 + SKETCH_BINS..])?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic inverse-CDF samples of a distribution.
    fn samples(n: usize, inv_cdf: impl Fn(f64) -> f64) -> Vec<f64> {
        (0..n).map(|i| inv_cdf((i as f64 + 0.5) / n as f64)).collect()
    }

    fn exact_quantile(sorted: &[f64], q: f64) -> f64 {
        let rank = ((q * sorted.len() as f64).ceil() as usize).max(1) - 1;
        sorted[rank.min(sorted.len() - 1)]
    }

    #[test]
    fn sketch_quantile_error_bounds_on_known_distributions() {
        let uniform = samples(5000, |u| u * 120.0); // U(0, 120s)
        let exponential = samples(5000, |u| -20.0 * (1.0 - u).ln()); // Exp(mean 20s)
        let lognormal = samples(5000, |u| {
            // lognormal via a rational approximation of probit — heavy
            // tail like the straggler scenarios
            let z = (u - 0.5) * 6.0; // crude but monotone; exactness irrelevant
            (1.0f64 + 0.8 * z).exp()
        });
        for data in [uniform, exponential, lognormal] {
            let mut sorted = data.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let mut s = ArrivalSketch::new();
            for &x in &data {
                s.observe(x);
            }
            for q in [0.5, 0.9, 0.99] {
                let exact = exact_quantile(&sorted, q);
                let est = s.quantile(q);
                let rel_bound = (SKETCH_GAMMA - 1.0) / (SKETCH_GAMMA + 1.0) + 0.02;
                assert!(
                    (est - exact).abs() <= exact.abs() * rel_bound + SKETCH_MIN_LAG,
                    "q{q}: est {est} vs exact {exact}"
                );
            }
        }
    }

    #[test]
    fn merge_is_exactly_associative_and_commutative() {
        let mk = |lo: usize| {
            let mut s = ArrivalSketch::new();
            for x in samples(500, |u| u * 10.0 + lo as f64) {
                s.observe(x);
            }
            s
        };
        let (a, b, c) = (mk(0), mk(7), mk(40));
        // (a ∪ b) ∪ c
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        // a ∪ (b ∪ c)
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left, right);
        // commutes, and equals the single-stream sketch
        let mut rev = c.clone();
        rev.merge(&b);
        rev.merge(&a);
        assert_eq!(left, rev);
        let mut one = ArrivalSketch::new();
        for s in [&a, &b, &c] {
            one.merge(s);
        }
        assert_eq!(left, one);
    }

    #[test]
    fn checkpoint_roundtrip_is_bit_identical_and_resumable() {
        let mut p = AdaptivePolicy::new(AdaptiveConfig::on());
        for r in 0..4 {
            for x in samples(60, |u| u * 30.0 + r as f64) {
                p.observe(x);
            }
            p.end_round();
        }
        let blob = p.to_f32s();
        let mut q = AdaptivePolicy::from_f32s(AdaptiveConfig::on(), &blob)
            .expect("roundtrip decodes");
        assert_eq!(q.to_f32s(), blob);
        assert_eq!(q.rounds_observed(), p.rounds_observed());
        assert_eq!(q.quantiles(), p.quantiles());
        assert_eq!(q.learned_defer(), p.learned_defer());
        // continuing both policies in lockstep stays identical
        for x in samples(60, |u| u * 45.0) {
            p.observe(x);
            q.observe(x);
        }
        p.end_round();
        q.end_round();
        assert_eq!(q.to_f32s(), p.to_f32s());
        // malformed payloads refuse cleanly
        assert!(AdaptivePolicy::from_f32s(AdaptiveConfig::on(), &blob[1..]).is_none());
    }

    #[test]
    fn disabled_config_is_inert_and_deadline_never_beats_fixed() {
        assert!(AdaptiveConfig::none().is_none());
        assert!(AdaptiveConfig::default().is_none());
        assert!(!AdaptiveConfig::on().is_none());
        let mut p = AdaptivePolicy::new(AdaptiveConfig::on());
        // warm-up: fixed passes through
        assert_eq!(p.deadline_defer(12.5), 12.5);
        for x in samples(200, |u| u * 4.0) {
            p.observe(x);
        }
        p.end_round();
        // learned p90 ≈ 3.6s·1.05 < fixed 12.5 ⇒ fixed wins (never earlier)
        assert_eq!(p.deadline_defer(12.5), 12.5);
        // slow fleet ⇒ learned extends past fixed
        let mut slow = AdaptivePolicy::new(AdaptiveConfig::on());
        for x in samples(200, |u| 40.0 + u * 20.0) {
            slow.observe(x);
        }
        slow.end_round();
        let d = slow.deadline_defer(12.5);
        assert!(d > 40.0, "learned defer {d} should track the slow tail");
    }

    #[test]
    fn rearm_only_shortens_and_respects_hysteresis_and_floor() {
        let mut p = AdaptivePolicy::new(AdaptiveConfig::on());
        for x in samples(100, |u| 30.0 + u * 10.0) {
            p.observe(x);
        }
        p.end_round();
        let armed = p.deadline_defer(5.0);
        assert!(armed > 38.0);
        // fast in-flight round pulls the live estimate down
        for x in samples(400, |u| u * 2.0) {
            p.observe(x);
        }
        let shortened = p.rearm_defer(5.0, armed).expect("live estimate undercuts armed");
        assert!(shortened < armed);
        assert!(shortened >= 5.0, "floored at the fixed defer");
        // no-op within hysteresis: re-arming to ~the same deadline
        assert!(p.rearm_defer(5.0, shortened).is_none());
        // floor: armed at the fixed defer itself never shortens below it
        assert!(p.rearm_defer(armed, armed).is_none() || p.rearm_defer(armed, armed).unwrap() >= armed);
    }

    #[test]
    fn quorum_restores_toward_base_never_below_degraded() {
        let mut p = AdaptivePolicy::new(AdaptiveConfig::on());
        // 3 rounds × 8 observed arrivals per round
        for _ in 0..3 {
            for x in samples(8, |u| u * 5.0) {
                p.observe(x);
            }
            p.end_round();
        }
        // degraded 4, base 10, 8 deliverable ⇒ restore to min(base, 8) = 8
        assert_eq!(p.quorum_for(4, 10, 8), 8);
        // never below degraded even if observations are sparse
        assert_eq!(p.quorum_for(6, 10, 5), 6);
        // clamped by base
        assert_eq!(p.quorum_for(2, 6, 100), 6);
        // disabled knob passes degraded through
        let mut cfg = AdaptiveConfig::on();
        cfg.adapt_quorum = false;
        let q = AdaptivePolicy::from_f32s(cfg, &p.to_f32s()).unwrap();
        assert_eq!(q.quorum_for(4, 10, 8), 4);
    }

    #[test]
    fn same_observations_yield_bit_identical_state() {
        let run = || {
            let mut p = AdaptivePolicy::new(AdaptiveConfig::on());
            for r in 0..5 {
                for x in samples(37, |u| (u * 17.0) + (r % 3) as f64) {
                    p.observe(x);
                }
                p.end_round();
            }
            p.to_f32s()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn admission_bounds_normalize() {
        let mut cfg = AdaptiveConfig::on();
        assert_eq!(cfg.admission_bounds(), None);
        cfg.admission_min = 2;
        cfg.admission_max = 8;
        assert_eq!(cfg.admission_bounds(), Some((2, 8)));
        cfg.admission_min = 9; // inverted bounds refuse
        assert_eq!(cfg.admission_bounds(), None);
        let mut off = AdaptiveConfig::none();
        off.admission_min = 2;
        off.admission_max = 8;
        assert_eq!(off.admission_bounds(), None, "disabled config never autoscales");
    }
}
