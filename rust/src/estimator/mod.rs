//! Training-time estimation — the paper's core enabler (§4, §5.3, §5.4).
//!
//! * **Periodicity** (§4.1, Fig 3): epoch/minibatch times at a party are
//!   ~constant absent data/hardware changes → [`PeriodicityTracker`] keeps a
//!   windowed history per party and predicts the next epoch time as the
//!   mean, exposing the CV as a confidence signal.
//! * **Linearity** (§4.2, Fig 4): epoch time ∝ dataset size, minibatch time
//!   ∝ batch size → [`OnlineOls`]-backed regressors predict times for
//!   parties that only report hardware/data-size (§5.3 fallback).
//! * **t_comm** (§5.3): model_size/B_d + model_size/B_u with EWMA-tracked
//!   bandwidths (§5.2's periodic measurements).
//! * **t_agg** (§5.4): N·t_pair/(C_agg·N_agg) + M/B_dc, with t_pair from
//!   offline calibration (`fusion::calibrate_t_pair`).
//! * [`estimate_round`] = Fig 6 lines 6–13: per-party `t_upd`, round bound
//!   `t_rnd = max t_upd`, and the JIT start time `t_rnd − t_agg`.

use crate::sim::{secs, Time};
use crate::util::stats::{Ewma, OnlineOls, Summary};

/// How a party participates (§2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Active,
    Intermittent,
}

/// What a party reports at job setup (§5.2 "Additional Input Needed From
/// Parties"). All optional except `mode`; the estimator uses the best
/// available source per Fig 6 line 7.
#[derive(Clone, Debug)]
pub struct PartyInfo {
    pub mode: Mode,
    /// Measured epoch time, if the party shares it (seconds).
    pub t_epoch: Option<f64>,
    /// Measured minibatch time, if shared (seconds).
    pub t_minibatch: Option<f64>,
    /// Dataset size in items (for the linearity regressor).
    pub dataset_items: Option<f64>,
    /// Hardware capability score (vcpus × clock; regression feature).
    pub hw_score: Option<f64>,
    /// party → aggregator bandwidth, bytes/s.
    pub bw_up: f64,
    /// aggregator → party bandwidth, bytes/s.
    pub bw_down: f64,
}

/// Aggregation frequency for a job (§5.1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AggFrequency {
    /// Fuse once per local epoch (the common case).
    PerEpoch,
    /// Fuse every N minibatches.
    PerMinibatches(u32),
}

/// Periodicity tracker: windowed epoch-time history per party.
#[derive(Clone, Debug, Default)]
pub struct PeriodicityTracker {
    window: Vec<f64>,
    cap: usize,
}

impl PeriodicityTracker {
    pub fn new(cap: usize) -> Self {
        PeriodicityTracker {
            window: Vec::new(),
            cap: cap.max(2),
        }
    }

    pub fn observe(&mut self, epoch_secs: f64) {
        if self.window.len() == self.cap {
            self.window.remove(0);
        }
        self.window.push(epoch_secs);
    }

    /// Predicted next epoch time (mean of the window).
    pub fn predict(&self) -> Option<f64> {
        if self.window.is_empty() {
            None
        } else {
            Some(self.window.iter().sum::<f64>() / self.window.len() as f64)
        }
    }

    /// Coefficient of variation — small CV validates the periodicity
    /// assumption (Fig 3).
    pub fn cv(&self) -> f64 {
        Summary::of(&self.window).cv()
    }

    pub fn len(&self) -> usize {
        self.window.len()
    }

    pub fn is_empty(&self) -> bool {
        self.window.is_empty()
    }
}

/// Cross-party linearity regressors (§4.2): predict a party's epoch time
/// from dataset size, or minibatch time from a hardware score, using
/// observations from *other* parties/rounds.
#[derive(Clone, Debug, Default)]
pub struct LinearityModel {
    /// epoch_time ~ dataset_items
    pub epoch_vs_data: OnlineOls,
    /// minibatch_time ~ 1/hw_score (heavier hardware → faster)
    pub mb_vs_inv_hw: OnlineOls,
}

impl LinearityModel {
    pub fn observe_epoch(&mut self, dataset_items: f64, epoch_secs: f64) {
        self.epoch_vs_data.add(dataset_items, epoch_secs);
    }

    pub fn observe_minibatch(&mut self, hw_score: f64, mb_secs: f64) {
        if hw_score > 0.0 {
            self.mb_vs_inv_hw.add(1.0 / hw_score, mb_secs);
        }
    }

    pub fn predict_epoch(&self, dataset_items: f64) -> Option<f64> {
        self.epoch_vs_data.predict(dataset_items).map(|t| t.max(0.0))
    }

    pub fn predict_minibatch(&self, hw_score: f64) -> Option<f64> {
        if hw_score <= 0.0 {
            return None;
        }
        self.mb_vs_inv_hw.predict(1.0 / hw_score).map(|t| t.max(0.0))
    }
}

/// Bandwidth tracker per party (§5.2).
#[derive(Clone, Debug)]
pub struct BandwidthTracker {
    pub up: Ewma,
    pub down: Ewma,
}

impl Default for BandwidthTracker {
    fn default() -> Self {
        BandwidthTracker {
            up: Ewma::new(0.3),
            down: Ewma::new(0.3),
        }
    }
}

/// Job-level aggregation-cost parameters (§5.4).
#[derive(Clone, Copy, Debug)]
pub struct AggCostModel {
    /// Offline-calibrated pair-fusion time on one core (seconds).
    pub t_pair: f64,
    /// Usable cores per aggregator container.
    pub c_agg: u32,
    /// Parallel aggregator containers.
    pub n_agg: u32,
    /// Intra-datacenter bandwidth (bytes/s) for state load.
    pub b_dc: f64,
    /// Model size in bytes (M).
    pub model_bytes: u64,
}

impl AggCostModel {
    /// t_agg = N·t_pair/(C_agg·N_agg) + M/B_dc  (Fig 6 line 13).
    pub fn t_agg(&self, n_parties: usize) -> f64 {
        let compute = n_parties as f64 * self.t_pair / (self.c_agg as f64 * self.n_agg as f64);
        compute + self.model_bytes as f64 / self.b_dc
    }

    /// Per-update service time inside one container (work-item duration).
    pub fn item_secs(&self) -> f64 {
        self.t_pair / self.c_agg as f64
    }
}

/// The per-round prediction (Fig 6 lines 6–13).
#[derive(Clone, Debug)]
pub struct RoundEstimate {
    /// Estimated update arrival offset per party (from round start).
    pub t_upd: Vec<f64>,
    /// max_i t_upd — estimated end of the round's update stream.
    pub t_rnd: f64,
    /// Estimated aggregation duration.
    pub t_agg: f64,
}

impl RoundEstimate {
    /// The JIT defer point: aggregation "can be safely deferred … until
    /// t_rnd − t_agg" (§5.5). Clamped at 0 (aggregate immediately if the
    /// round is shorter than aggregation).
    pub fn start_offset(&self) -> f64 {
        (self.t_rnd - self.t_agg).max(0.0)
    }

    pub fn start_offset_time(&self) -> Time {
        secs(self.start_offset())
    }

    /// The margin-padded defer point `t_rnd − t_agg·(1+margin)` the JIT
    /// strategy arms its fuse timer at, clamped at 0. This is the
    /// *fixed* §5.4-style prediction; the adaptive policy
    /// ([`crate::adapt`]) treats it as the floor its learned deadline
    /// may never undercut.
    pub fn defer_secs(&self, jit_margin: f64) -> f64 {
        (self.t_rnd - self.t_agg * (1.0 + jit_margin)).max(0.0)
    }
}

/// Per-party t_train per Fig 6 line 7.
pub fn estimate_t_train(
    info: &PartyInfo,
    freq: AggFrequency,
    t_wait: f64,
    history: Option<&PeriodicityTracker>,
    linearity: &LinearityModel,
) -> f64 {
    if info.mode == Mode::Intermittent {
        return t_wait;
    }
    // Periodicity first: observed history beats static reports.
    if let Some(h) = history {
        if let Some(p) = h.predict() {
            return scale_for_freq(p, info, freq);
        }
    }
    match freq {
        AggFrequency::PerEpoch => {
            if let Some(t) = info.t_epoch {
                return t;
            }
            if let Some(tmb) = info.t_minibatch {
                // epochs = items / batch; approximate with dataset if known
                if let (Some(items), Some(_)) = (info.dataset_items, info.hw_score) {
                    // assume batch 32 when unreported — documented default
                    return tmb * (items / 32.0).max(1.0);
                }
                return tmb;
            }
            if let Some(items) = info.dataset_items {
                if let Some(t) = linearity.predict_epoch(items) {
                    return t;
                }
            }
            if let Some(hw) = info.hw_score {
                if let Some(tmb) = linearity.predict_minibatch(hw) {
                    let items = info.dataset_items.unwrap_or(320.0);
                    return tmb * (items / 32.0).max(1.0);
                }
            }
            // last resort: t_wait bound
            t_wait
        }
        AggFrequency::PerMinibatches(n) => {
            let tmb = info
                .t_minibatch
                .or_else(|| info.hw_score.and_then(|h| linearity.predict_minibatch(h)))
                .unwrap_or(t_wait / n as f64);
            tmb * n as f64
        }
    }
}

fn scale_for_freq(epoch_pred: f64, info: &PartyInfo, freq: AggFrequency) -> f64 {
    match freq {
        AggFrequency::PerEpoch => epoch_pred,
        AggFrequency::PerMinibatches(n) => {
            let items = info.dataset_items.unwrap_or(320.0);
            let mb_per_epoch = (items / 32.0).max(1.0);
            epoch_pred * n as f64 / mb_per_epoch
        }
    }
}

/// t_comm = M/B_d + M/B_u (§5.3).
pub fn t_comm(model_bytes: u64, info: &PartyInfo) -> f64 {
    let m = model_bytes as f64;
    m / info.bw_down.max(1.0) + m / info.bw_up.max(1.0)
}

/// Fig 6 lines 6–13 for a whole job round.
pub fn estimate_round(
    parties: &[PartyInfo],
    freq: AggFrequency,
    t_wait: f64,
    cost: &AggCostModel,
    histories: Option<&[PeriodicityTracker]>,
    linearity: &LinearityModel,
) -> RoundEstimate {
    let t_upd: Vec<f64> = parties
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let h = histories.and_then(|hs| hs.get(i));
            estimate_t_train(p, freq, t_wait, h, linearity) + t_comm(cost.model_bytes, p)
        })
        .collect();
    let t_rnd = t_upd.iter().cloned().fold(0.0, f64::max);
    RoundEstimate {
        t_rnd,
        t_agg: cost.t_agg(parties.len()),
        t_upd,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn active(t_epoch: f64) -> PartyInfo {
        PartyInfo {
            mode: Mode::Active,
            t_epoch: Some(t_epoch),
            t_minibatch: None,
            dataset_items: Some(320.0),
            hw_score: Some(2.0),
            bw_up: 100e6,
            bw_down: 100e6,
        }
    }

    #[test]
    fn periodicity_tracker_mean_and_cv() {
        let mut t = PeriodicityTracker::new(5);
        assert!(t.predict().is_none());
        for x in [10.0, 10.2, 9.8, 10.1, 9.9] {
            t.observe(x);
        }
        let p = t.predict().unwrap();
        assert!((p - 10.0).abs() < 0.01);
        assert!(t.cv() < 0.02);
        // window slides
        for _ in 0..5 {
            t.observe(20.0);
        }
        assert!((t.predict().unwrap() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn linearity_predicts_epoch_from_data() {
        let mut m = LinearityModel::default();
        // epoch = 0.1 * items
        for items in [100.0, 200.0, 400.0, 800.0] {
            m.observe_epoch(items, 0.1 * items);
        }
        let p = m.predict_epoch(600.0).unwrap();
        assert!((p - 60.0).abs() < 1e-6, "p={p}");
    }

    #[test]
    fn linearity_predicts_minibatch_from_hw() {
        let mut m = LinearityModel::default();
        // mb = 2 / hw
        for hw in [1.0, 2.0, 4.0] {
            m.observe_minibatch(hw, 2.0 / hw);
        }
        let p = m.predict_minibatch(8.0).unwrap();
        assert!((p - 0.25).abs() < 1e-6, "p={p}");
    }

    #[test]
    fn t_train_prefers_history_then_report_then_regression() {
        let lin = {
            let mut m = LinearityModel::default();
            m.observe_epoch(100.0, 10.0);
            m.observe_epoch(200.0, 20.0);
            m
        };
        let info = active(33.0);
        // 1) history wins
        let mut h = PeriodicityTracker::new(4);
        h.observe(40.0);
        h.observe(40.0);
        let t = estimate_t_train(&info, AggFrequency::PerEpoch, 600.0, Some(&h), &lin);
        assert!((t - 40.0).abs() < 1e-9);
        // 2) report next
        let t = estimate_t_train(&info, AggFrequency::PerEpoch, 600.0, None, &lin);
        assert!((t - 33.0).abs() < 1e-9);
        // 3) regression fallback
        let mut anon = info.clone();
        anon.t_epoch = None;
        anon.t_minibatch = None;
        anon.dataset_items = Some(320.0);
        let t = estimate_t_train(&anon, AggFrequency::PerEpoch, 600.0, None, &lin);
        assert!((t - 32.0).abs() < 1e-6, "t={t}");
    }

    #[test]
    fn intermittent_uses_t_wait() {
        let mut info = active(33.0);
        info.mode = Mode::Intermittent;
        let lin = LinearityModel::default();
        let t = estimate_t_train(&info, AggFrequency::PerEpoch, 600.0, None, &lin);
        assert!((t - 600.0).abs() < 1e-9);
    }

    #[test]
    fn agg_cost_formula() {
        let c = AggCostModel {
            t_pair: 0.2,
            c_agg: 2,
            n_agg: 5,
            b_dc: 1.25e9, // 10 Gbps
            model_bytes: 250_000_000,
        };
        // 100 * 0.2 / 10 + 0.25/1.25 = 2.0 + 0.2 = 2.2
        assert!((c.t_agg(100) - 2.2).abs() < 1e-9);
        assert!((c.item_secs() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn round_estimate_and_defer_point() {
        let cost = AggCostModel {
            t_pair: 1.0,
            c_agg: 1,
            n_agg: 1,
            b_dc: f64::INFINITY,
            model_bytes: 0,
        };
        let parties: Vec<PartyInfo> = (1..=6).map(|i| active(i as f64 * 3.0)).collect();
        let lin = LinearityModel::default();
        let est = estimate_round(&parties, AggFrequency::PerEpoch, 600.0, &cost, None, &lin);
        assert_eq!(est.t_upd.len(), 6);
        assert!((est.t_rnd - 18.0).abs() < 1e-9);
        assert!((est.t_agg - 6.0).abs() < 1e-9);
        assert!((est.start_offset() - 12.0).abs() < 1e-9);
        // aggregation longer than round -> start immediately
        let cost2 = AggCostModel { t_pair: 100.0, ..cost };
        let est2 = estimate_round(&parties, AggFrequency::PerEpoch, 600.0, &cost2, None, &lin);
        assert_eq!(est2.start_offset(), 0.0);
    }

    #[test]
    fn t_comm_both_directions() {
        let info = active(1.0);
        let t = t_comm(200_000_000, &info);
        assert!((t - 4.0).abs() < 1e-9); // 2s down + 2s up at 100 MB/s
    }
}
