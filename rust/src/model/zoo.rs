//! The model zoo: the paper's three evaluation models (§6.3) plus the MLP
//! used for real local training.
//!
//! VGG16 uses the exact published layer table (138,357,544 parameters).
//! EfficientNet-B7 and InceptionV4 use representative per-stage layer
//! tables normalized to the published totals (66.3M / 42.7M) — aggregation
//! only sees flattened per-layer vectors, so stage-level granularity is
//! faithful for everything this system measures (update size, fusion time,
//! transfer time).

use super::ModelSpec;

/// VGG16 (Simonyan & Zisserman) — exact layer table, 138,357,544 params.
pub fn vgg16() -> ModelSpec {
    ModelSpec::new(
        "vgg16",
        vec![
            ("conv1_1", 1_792),
            ("conv1_2", 36_928),
            ("conv2_1", 73_856),
            ("conv2_2", 147_584),
            ("conv3_1", 295_168),
            ("conv3_2", 590_080),
            ("conv3_3", 590_080),
            ("conv4_1", 1_180_160),
            ("conv4_2", 2_359_808),
            ("conv4_3", 2_359_808),
            ("conv5_1", 2_359_808),
            ("conv5_2", 2_359_808),
            ("conv5_3", 2_359_808),
            ("fc6", 102_764_544),
            ("fc7", 16_781_312),
            ("fc8", 4_097_000),
        ],
    )
}

/// EfficientNet-B7 — stage-level table normalized to 66,347,960 params.
pub fn efficientnet_b7() -> ModelSpec {
    ModelSpec::new(
        "efficientnet-b7",
        vec![
            ("stem", 186_000),
            ("block1", 1_320_000),
            ("block2", 3_100_000),
            ("block3", 5_440_000),
            ("block4", 9_660_000),
            ("block5", 13_240_000),
            ("block6", 18_900_000),
            ("block7", 9_200_000),
            ("head_conv", 2_560_000),
            ("classifier", 2_741_960),
        ],
    )
}

/// InceptionV4 — stage-level table normalized to 42,679,816 params.
pub fn inception_v4() -> ModelSpec {
    ModelSpec::new(
        "inception-v4",
        vec![
            ("stem", 1_050_000),
            ("inception_a", 3_310_000),
            ("reduction_a", 2_630_000),
            ("inception_b", 12_300_000),
            ("reduction_b", 3_770_000),
            ("inception_c", 16_400_000),
            ("avgpool_dropout", 0),
            ("classifier", 3_219_816),
        ],
    )
}

/// The MLP trained for real in the end-to-end example. Mirrors
/// `python/compile/model.py::param_shapes` (i=64, h=256, c=10).
pub fn mlp(i: usize, h: usize, c: usize) -> ModelSpec {
    ModelSpec::new(
        "mlp",
        vec![
            ("w1", i * h),
            ("b1", h),
            ("w2", h * h),
            ("b2", h),
            ("w3", h * c),
            ("b3", c),
        ],
    )
}

/// Default MLP matching the AOT artifacts.
pub fn mlp_default() -> ModelSpec {
    mlp(64, 256, 10)
}

/// Look up a zoo model by name (CLI/bench parameter).
pub fn by_name(name: &str) -> Option<ModelSpec> {
    match name {
        "vgg16" => Some(vgg16()),
        "efficientnet-b7" | "effnet-b7" | "efficientnet" => Some(efficientnet_b7()),
        "inception-v4" | "inceptionv4" => Some(inception_v4()),
        "mlp" => Some(mlp_default()),
        _ => None,
    }
}

pub fn all_names() -> &'static [&'static str] {
    &["efficientnet-b7", "vgg16", "inception-v4", "mlp"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vgg16_exact_total() {
        assert_eq!(vgg16().total_params(), 138_357_544);
    }

    #[test]
    fn effnet_total_matches_published() {
        assert_eq!(efficientnet_b7().total_params(), 66_347_960);
    }

    #[test]
    fn inception_total_matches_published() {
        assert_eq!(inception_v4().total_params(), 42_679_816);
    }

    #[test]
    fn mlp_matches_python_param_shapes() {
        let m = mlp_default();
        let (i, h, c) = (64, 256, 10);
        assert_eq!(m.total_params(), i * h + h + h * h + h + h * c + c);
        assert_eq!(m.layers.len(), 6);
        assert_eq!(m.layers[0].name, "w1");
    }

    #[test]
    fn lookup_by_name() {
        for n in all_names() {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert!(by_name("resnet-9000").is_none());
        assert_eq!(by_name("effnet-b7").unwrap().name, "efficientnet-b7");
    }
}
