//! Model updates and the model zoo.
//!
//! §2.1: "A model update … is flattened, and represented as a list of
//! one-dimensional vectors, with each vector corresponding to a layer."
//! [`ModelSpec`] carries that per-layer layout; [`ModelUpdate`] is the
//! flattened weight vector plus its aggregation weight (#samples).
//!
//! The zoo provides the three evaluation models (§6.3) at their real
//! parameter counts — EfficientNet-B7 (66.3M), VGG16 (138.4M, exact layer
//! table), InceptionV4 (42.7M) — so update sizes, transfer times and
//! `t_pair` calibration operate on realistic vectors, plus the small MLP
//! whose layout mirrors `python/compile/model.py::param_shapes` for the
//! real-training path.

pub mod zoo;

use crate::util::rng::Rng;

/// One flattened layer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct LayerSpec {
    pub name: String,
    pub numel: usize,
}

/// Architecture-level description of a model's update vector.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: String,
    pub layers: Vec<LayerSpec>,
}

impl ModelSpec {
    pub fn new(name: &str, layers: Vec<(&str, usize)>) -> ModelSpec {
        ModelSpec {
            name: name.to_string(),
            layers: layers
                .into_iter()
                .map(|(n, numel)| LayerSpec {
                    name: n.to_string(),
                    numel,
                })
                .collect(),
        }
    }

    pub fn total_params(&self) -> usize {
        self.layers.iter().map(|l| l.numel).sum()
    }

    /// f32 payload size — the `M` of §5.3/§5.4 (transfer + state times).
    pub fn size_bytes(&self) -> u64 {
        (self.total_params() * 4) as u64
    }

    /// Offset of each layer in the flattened vector.
    pub fn offsets(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.layers.len());
        let mut acc = 0;
        for l in &self.layers {
            out.push(acc);
            acc += l.numel;
        }
        out
    }
}

/// A party's flattened model update.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelUpdate {
    /// Flattened weights (layer-major, per ModelSpec order).
    pub data: Vec<f32>,
    /// Aggregation weight — #samples at the party (FedAvg weighting).
    pub weight: f32,
}

impl ModelUpdate {
    pub fn zeros(n: usize) -> ModelUpdate {
        ModelUpdate {
            data: vec![0.0; n],
            weight: 0.0,
        }
    }

    /// Random update for offline `t_pair` calibration (§5.4: "randomly
    /// generating model updates … and measuring the time taken to fuse
    /// pairs").
    pub fn random(spec: &ModelSpec, rng: &mut Rng, weight: f32) -> ModelUpdate {
        let mut data = vec![0.0f32; spec.total_params()];
        rng.fill_normal_f32(&mut data);
        ModelUpdate { data, weight }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Split back into per-layer views.
    pub fn layer_views<'a>(&'a self, spec: &ModelSpec) -> Vec<&'a [f32]> {
        assert_eq!(self.data.len(), spec.total_params(), "layout mismatch");
        let mut out = Vec::with_capacity(spec.layers.len());
        let mut off = 0;
        for l in &spec.layers {
            out.push(&self.data[off..off + l.numel]);
            off += l.numel;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelSpec {
        ModelSpec::new("tiny", vec![("a", 3), ("b", 5), ("c", 2)])
    }

    #[test]
    fn totals_and_offsets() {
        let m = tiny();
        assert_eq!(m.total_params(), 10);
        assert_eq!(m.size_bytes(), 40);
        assert_eq!(m.offsets(), vec![0, 3, 8]);
    }

    #[test]
    fn layer_views_partition_data() {
        let m = tiny();
        let u = ModelUpdate {
            data: (0..10).map(|i| i as f32).collect(),
            weight: 1.0,
        };
        let views = u.layer_views(&m);
        assert_eq!(views.len(), 3);
        assert_eq!(views[0], &[0.0, 1.0, 2.0]);
        assert_eq!(views[1], &[3.0, 4.0, 5.0, 6.0, 7.0]);
        assert_eq!(views[2], &[8.0, 9.0]);
    }

    #[test]
    #[should_panic(expected = "layout mismatch")]
    fn layer_views_check_layout() {
        let u = ModelUpdate::zeros(7);
        u.layer_views(&tiny());
    }

    #[test]
    fn random_updates_differ_and_are_seeded() {
        let m = tiny();
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        let a = ModelUpdate::random(&m, &mut r1, 1.0);
        let b = ModelUpdate::random(&m, &mut r2, 1.0);
        assert_eq!(a, b);
        let c = ModelUpdate::random(&m, &mut r1, 1.0);
        assert_ne!(a, c);
    }
}
