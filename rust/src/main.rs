//! `fljit` CLI — leader entrypoint for the JIT-aggregation platform.
//!
//! Every subcommand that runs jobs goes through the one
//! `coordinator::session::Session` façade (sim, live and wall-clock
//! regimes alike) and consumes its streaming event channel where live
//! progress is useful (`live` prints each round as it fuses).
//!
//! Subcommands:
//!   * `timeline`  — the Fig 2 scenario: four design options on a 6-party
//!                   round; prints the busy/idle/overhead timeline.
//!   * `simulate`  — one scenario (workload × parties × strategy) in
//!                   simulated time; prints latency + container-seconds.
//!   * `bench-table <fig3|fig4|fig7|fig8|fig9>` — regenerate a paper
//!                   figure/table.
//!   * `calibrate` — offline t_pair calibration on zoo models (§5.4).
//!   * `zoo`       — list zoo models.
//!   * `run`       — run an FL job spec (JSON) on the live platform with
//!                   real XLA aggregation.
//!   * `live`      — wall-clock run of any strategy on the zero-copy MQ.
//!   * `broker`    — multi-tenant arbitration sweep in simulated time.
//!   * `live-broker` — the broker's job mix on the live platform
//!                   (admission + policy-arbitrated preemption + per-job
//!                   data planes).
//!   * `robustness` — strategy × fault-scenario degradation matrix
//!                   (stragglers, dropout, diurnal waves, weight skew)
//!                   with per-cell fidelity and dropped-vs-decayed counts.

use fljit::util::cli::Args;

fn main() {
    fljit::util::logging::init_from_env();
    let args = Args::from_env();
    let code = fljit::bench::cli::dispatch(&args);
    std::process::exit(code);
}
