//! Message queue substrate (Kafka stand-in).
//!
//! All dynamic aggregation strategies (§3) require model updates to be
//! "buffered somewhere in the datacenter, e.g., a message queue like Kafka
//! or a cloud object store". This module provides that buffer:
//!
//! * append-only **topics** with monotone offsets,
//! * **consumer groups** with committed offsets (an aggregator deployment
//!   resumes exactly where the previous one left off),
//! * **checkpoint slots** for partially aggregated state — §5.5: "lower
//!   priority aggregators are preempted by checkpointing partially
//!   aggregated model updates using the message queue".
//!
//! Payloads either carry real update data inline / by object-store
//! reference (live mode) or just a byte size (simulated mode); the queue
//! semantics are identical in both.

use std::collections::BTreeMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::sim::Time;
use crate::telemetry::{Registry, Scope};

/// What a message carries.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Live mode: flattened update inline.
    Inline(Vec<f32>),
    /// Live mode: key into the ObjectStore.
    Ref(String),
    /// Sim mode: only the size matters (transfer-time accounting).
    Sim { size_bytes: u64 },
}

impl Payload {
    pub fn size_bytes(&self) -> u64 {
        match self {
            Payload::Inline(v) => (v.len() * 4) as u64,
            Payload::Ref(_) => 0,
            Payload::Sim { size_bytes } => *size_bytes,
        }
    }

    /// Inline update data, if this payload carries any.
    pub fn data(&self) -> Option<&[f32]> {
        match self {
            Payload::Inline(v) => Some(v),
            _ => None,
        }
    }
}

/// Zero-copy message view handed to consumers: the topic log and every
/// consumer share one refcounted allocation, so fetching an inline
/// model update never clones its `Vec<f32>`.
pub type MessageView = Arc<Message>;

/// A model-update (or checkpoint) message.
#[derive(Clone, Debug, PartialEq)]
pub struct Message {
    /// Producing party (or aggregator id for checkpoints).
    pub party: usize,
    /// FL synchronization round.
    pub round: u32,
    /// Aggregation weight (= #samples at the party for FedAvg/FedProx).
    pub weight: f32,
    /// Enqueue timestamp (virtual or wall).
    pub enqueued_at: Time,
    pub payload: Payload,
}

#[derive(Debug, Default)]
struct Topic {
    log: Vec<MessageView>,
    /// committed offset per consumer group
    commits: BTreeMap<String, usize>,
    /// round → offsets of that round's messages, so round-scoped consumers
    /// jump straight to their slice instead of scanning from offset 0.
    by_round: BTreeMap<u32, Vec<usize>>,
}

/// The queue. Cheap to share behind `&` thanks to interior mutability.
#[derive(Debug, Default)]
pub struct MessageQueue {
    topics: Mutex<BTreeMap<String, Topic>>,
    /// Checkpoint slots: job/round keyed partial aggregates (latest wins).
    checkpoints: Mutex<BTreeMap<String, CheckpointState>>,
    /// Global produce counter + condvar: wall-clock consumers (the live
    /// driver) sleep here instead of polling, and every `produce` wakes
    /// them. Purely additive — virtual-time consumers never touch it.
    produce_sig: (Mutex<u64>, Condvar),
    /// Optional telemetry handle (disabled by default — the clone out of
    /// the mutex is an `Option<Arc>` copy, and a disabled registry makes
    /// every record a no-op). Strictly observational: never affects
    /// offsets, wakeups, or message contents.
    telemetry: Mutex<Registry>,
}

/// A partially aggregated state parked by a preempted aggregator (§5.5).
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointState {
    /// Weighted-mean accumulator (live mode) or None in sim mode.
    pub acc: Option<Vec<f32>>,
    /// Total weight folded into the accumulator so far.
    pub weight: f32,
    /// Number of updates folded in.
    pub n_merged: usize,
    /// Offset in the update topic up to which merging is complete.
    pub consumed_to: usize,
    pub saved_at: Time,
}

impl MessageQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Attach a telemetry registry: produce/consume counters, per-topic
    /// depth gauges, and the `wait_produce` wait-time histogram record
    /// into it. Pass `Registry::disabled()` to detach.
    pub fn set_telemetry(&self, reg: &Registry) {
        *self.telemetry.lock().unwrap() = reg.clone();
    }

    fn reg(&self) -> Registry {
        self.telemetry.lock().unwrap().clone()
    }

    /// Append a message; returns its offset. Wakes any wall-clock
    /// consumer blocked in [`wait_produce`](MessageQueue::wait_produce).
    pub fn produce(&self, topic: &str, msg: Message) -> usize {
        let off = {
            let mut topics = self.topics.lock().unwrap();
            let t = topics.entry(topic.to_string()).or_default();
            let off = t.log.len();
            t.by_round.entry(msg.round).or_default().push(off);
            t.log.push(Arc::new(msg));
            off
        };
        let reg = self.reg();
        if reg.on() {
            reg.counter_add("mq_messages_produced_total", &Scope::none(), 1);
            reg.gauge_set(
                "mq_topic_depth",
                &Scope::label("topic", topic),
                (off + 1) as f64,
            );
        }
        let (lock, cvar) = &self.produce_sig;
        *lock.lock().unwrap() += 1;
        cvar.notify_all();
        off
    }

    /// Total messages produced across all topics since creation — the
    /// wake counter for [`wait_produce`](MessageQueue::wait_produce).
    pub fn produced(&self) -> u64 {
        *self.produce_sig.0.lock().unwrap()
    }

    /// Block until the produce counter exceeds `seen` or `timeout`
    /// elapses; returns the current counter. The live wall-clock driver
    /// parks here between event deadlines so a party's publish wakes it
    /// immediately.
    pub fn wait_produce(&self, seen: u64, timeout: Duration) -> u64 {
        let t0 = Instant::now();
        let (lock, cvar) = &self.produce_sig;
        let deadline = t0 + timeout;
        let mut n = lock.lock().unwrap();
        while *n <= seen {
            let rem = deadline.saturating_duration_since(Instant::now());
            if rem.is_zero() {
                break;
            }
            let (guard, res) = cvar.wait_timeout(n, rem).unwrap();
            n = guard;
            if res.timed_out() {
                break;
            }
        }
        let out = *n;
        drop(n);
        let reg = self.reg();
        if reg.on() {
            // Wall-side observation only (the wait itself is wall time);
            // recording it perturbs nothing the seeded streams see.
            reg.histogram_observe(
                "mq_wait_produce_secs",
                &Scope::none(),
                t0.elapsed().as_secs_f64(),
                &crate::telemetry::LATENCY_BUCKETS_SECS,
            );
        }
        out
    }

    /// Messages in [from, from+max) — non-consuming, zero-copy read: the
    /// returned views share the log's allocations (cloning an `Arc`, not
    /// the payload).
    pub fn fetch(&self, topic: &str, from: usize, max: usize) -> Vec<MessageView> {
        let batch: Vec<MessageView> = {
            let topics = self.topics.lock().unwrap();
            match topics.get(topic) {
                None => Vec::new(),
                Some(t) => t.log.iter().skip(from).take(max).cloned().collect(),
            }
        };
        if !batch.is_empty() {
            let reg = self.reg();
            if reg.on() {
                reg.counter_add(
                    "mq_messages_fetched_total",
                    &Scope::none(),
                    batch.len() as u64,
                );
            }
        }
        batch
    }

    /// All of one round's messages, via the round index — O(messages in
    /// the round), not O(log length). Zero-copy like [`fetch`].
    pub fn fetch_round(&self, topic: &str, round: u32) -> Vec<MessageView> {
        let topics = self.topics.lock().unwrap();
        match topics.get(topic) {
            None => Vec::new(),
            Some(t) => t
                .by_round
                .get(&round)
                .map(|offs| offs.iter().map(|&o| Arc::clone(&t.log[o])).collect())
                .unwrap_or_default(),
        }
    }

    /// Consume for a group: fetch up to `max` messages past the group's
    /// committed offset and advance the commit past them, atomically.
    /// Zero-copy like [`fetch`].
    pub fn poll(&self, topic: &str, group: &str, max: usize) -> Vec<MessageView> {
        let mut topics = self.topics.lock().unwrap();
        let Some(t) = topics.get_mut(topic) else {
            return Vec::new();
        };
        let from = t.commits.get(group).copied().unwrap_or(0);
        let batch: Vec<MessageView> = t.log.iter().skip(from).take(max).cloned().collect();
        if !batch.is_empty() {
            t.commits.insert(group.to_string(), from + batch.len());
        }
        batch
    }

    /// End offset (= number of messages produced so far).
    pub fn end_offset(&self, topic: &str) -> usize {
        self.topics
            .lock()
            .unwrap()
            .get(topic)
            .map(|t| t.log.len())
            .unwrap_or(0)
    }

    /// Committed offset of a consumer group (0 if never committed).
    pub fn committed(&self, topic: &str, group: &str) -> usize {
        self.topics
            .lock()
            .unwrap()
            .get(topic)
            .and_then(|t| t.commits.get(group).copied())
            .unwrap_or(0)
    }

    /// Commit a consumer-group offset. Offsets are monotone: committing
    /// backwards is a no-op (idempotent redelivery semantics).
    pub fn commit(&self, topic: &str, group: &str, offset: usize) {
        let mut topics = self.topics.lock().unwrap();
        let t = topics.entry(topic.to_string()).or_default();
        let e = t.commits.entry(group.to_string()).or_insert(0);
        if offset > *e {
            *e = offset;
        }
    }

    /// Uncommitted backlog for a group.
    pub fn backlog(&self, topic: &str, group: &str) -> usize {
        self.end_offset(topic) - self.committed(topic, group)
    }

    // ------------------------------------------------------------------
    // checkpoint slots
    // ------------------------------------------------------------------

    pub fn save_checkpoint(&self, slot: &str, state: CheckpointState) {
        self.checkpoints
            .lock()
            .unwrap()
            .insert(slot.to_string(), state);
    }

    pub fn load_checkpoint(&self, slot: &str) -> Option<CheckpointState> {
        self.checkpoints.lock().unwrap().get(slot).cloned()
    }

    pub fn clear_checkpoint(&self, slot: &str) -> bool {
        self.checkpoints.lock().unwrap().remove(slot).is_some()
    }

    /// Total bytes resident across topics (capacity accounting).
    pub fn resident_bytes(&self) -> u64 {
        let topics = self.topics.lock().unwrap();
        topics
            .values()
            .flat_map(|t| t.log.iter())
            .map(|m| m.payload.size_bytes())
            .sum()
    }

    /// Drop a whole topic (round GC after aggregation completes).
    pub fn drop_topic(&self, topic: &str) -> usize {
        let n = self
            .topics
            .lock()
            .unwrap()
            .remove(topic)
            .map(|t| t.log.len())
            .unwrap_or(0);
        if n > 0 {
            let reg = self.reg();
            if reg.on() {
                reg.gauge_set("mq_topic_depth", &Scope::label("topic", topic), 0.0);
            }
        }
        n
    }
}

/// Conventional topic name for a job's round updates.
pub fn update_topic(job: usize, round: u32) -> String {
    format!("job{job}/round{round}/updates")
}

/// Conventional checkpoint slot for a job's round.
pub fn checkpoint_slot(job: usize, round: u32) -> String {
    format!("job{job}/round{round}/ckpt")
}

/// Conventional topic for a job's published (fused) global models — one
/// message per completed round, so offset == completed-round count. The
/// live runner treats this log as the job's durable model state: a
/// restarted aggregator derives "which round am I in" and "what is the
/// current global" from it (§5.5 checkpoint/resume).
pub fn model_topic(job: usize) -> String {
    format!("job{job}/models")
}

/// Conventional topic for live party-side metrics (training losses).
pub fn metrics_topic(job: usize) -> String {
    format!("job{job}/metrics")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(party: usize, round: u32) -> Message {
        Message {
            party,
            round,
            weight: 1.0,
            enqueued_at: 0,
            payload: Payload::Sim { size_bytes: 100 },
        }
    }

    #[test]
    fn offsets_monotone() {
        let q = MessageQueue::new();
        assert_eq!(q.produce("t", msg(0, 0)), 0);
        assert_eq!(q.produce("t", msg(1, 0)), 1);
        assert_eq!(q.end_offset("t"), 2);
    }

    #[test]
    fn fetch_window() {
        let q = MessageQueue::new();
        for p in 0..5 {
            q.produce("t", msg(p, 0));
        }
        let w = q.fetch("t", 1, 2);
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].party, 1);
        assert_eq!(w[1].party, 2);
        assert!(q.fetch("t", 10, 5).is_empty());
        assert!(q.fetch("missing", 0, 5).is_empty());
    }

    #[test]
    fn consumer_group_commit_and_backlog() {
        let q = MessageQueue::new();
        for p in 0..4 {
            q.produce("t", msg(p, 0));
        }
        assert_eq!(q.backlog("t", "agg"), 4);
        q.commit("t", "agg", 3);
        assert_eq!(q.committed("t", "agg"), 3);
        assert_eq!(q.backlog("t", "agg"), 1);
        // backwards commit ignored
        q.commit("t", "agg", 1);
        assert_eq!(q.committed("t", "agg"), 3);
        // independent group
        assert_eq!(q.backlog("t", "other"), 4);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let q = MessageQueue::new();
        let slot = checkpoint_slot(3, 7);
        assert!(q.load_checkpoint(&slot).is_none());
        q.save_checkpoint(
            &slot,
            CheckpointState {
                acc: Some(vec![1.0, 2.0]),
                weight: 5.0,
                n_merged: 3,
                consumed_to: 3,
                saved_at: 123,
            },
        );
        let st = q.load_checkpoint(&slot).unwrap();
        assert_eq!(st.n_merged, 3);
        assert_eq!(st.acc.as_ref().unwrap().len(), 2);
        assert!(q.clear_checkpoint(&slot));
        assert!(!q.clear_checkpoint(&slot));
    }

    #[test]
    fn resident_bytes_and_gc() {
        let q = MessageQueue::new();
        for p in 0..10 {
            q.produce("a", msg(p, 0));
        }
        q.produce(
            "b",
            Message {
                payload: Payload::Inline(vec![0.0; 25]),
                ..msg(0, 0)
            },
        );
        assert_eq!(q.resident_bytes(), 10 * 100 + 100);
        assert_eq!(q.drop_topic("a"), 10);
        assert_eq!(q.resident_bytes(), 100);
    }

    #[test]
    fn topic_naming() {
        assert_eq!(update_topic(2, 5), "job2/round5/updates");
        assert_eq!(checkpoint_slot(2, 5), "job2/round5/ckpt");
        assert_eq!(model_topic(2), "job2/models");
        assert_eq!(metrics_topic(2), "job2/metrics");
    }

    #[test]
    fn produce_counter_counts_across_topics() {
        let q = MessageQueue::new();
        assert_eq!(q.produced(), 0);
        q.produce("a", msg(0, 0));
        q.produce("b", msg(1, 0));
        assert_eq!(q.produced(), 2);
        // already-satisfied wait returns immediately
        let n = q.wait_produce(1, Duration::from_secs(5));
        assert_eq!(n, 2);
        // unsatisfied wait times out (short) and returns the counter
        let n = q.wait_produce(2, Duration::from_millis(10));
        assert_eq!(n, 2);
    }

    #[test]
    fn wait_produce_woken_by_concurrent_producer() {
        let q = Arc::new(MessageQueue::new());
        let seen = q.produced();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.produce("t", msg(0, 0));
        });
        let t0 = Instant::now();
        let n = q.wait_produce(seen, Duration::from_secs(5));
        h.join().unwrap();
        assert!(n > seen);
        assert!(t0.elapsed() < Duration::from_secs(2), "wake, not timeout");
    }

    #[test]
    fn fetch_round_uses_index_not_scan() {
        let q = MessageQueue::new();
        for r in 0..4u32 {
            for p in 0..3 {
                q.produce("t", msg(p, r));
            }
        }
        let r2 = q.fetch_round("t", 2);
        assert_eq!(r2.len(), 3);
        assert!(r2.iter().all(|m| m.round == 2));
        assert_eq!(
            r2.iter().map(|m| m.party).collect::<Vec<_>>(),
            vec![0, 1, 2],
            "round fetch preserves production order"
        );
        assert!(q.fetch_round("t", 99).is_empty());
        assert!(q.fetch_round("missing", 0).is_empty());
    }

    #[test]
    fn inline_payload_reads_are_zero_copy() {
        let q = MessageQueue::new();
        let data = vec![1.0f32; 1024];
        q.produce(
            "t",
            Message {
                payload: Payload::Inline(data),
                ..msg(0, 0)
            },
        );
        let a = q.fetch("t", 0, 1).remove(0);
        let b = q.fetch_round("t", 0).remove(0);
        let pa = a.payload.data().unwrap().as_ptr();
        let pb = b.payload.data().unwrap().as_ptr();
        assert_eq!(pa, pb, "both views must share the log's allocation");
        assert!(Arc::ptr_eq(&a, &b), "fetch must hand out the same Arc");
    }

    #[test]
    fn poll_advances_commit_and_shares_data() {
        let q = MessageQueue::new();
        for p in 0..5 {
            q.produce("t", msg(p, 0));
        }
        let first = q.poll("t", "agg", 2);
        assert_eq!(first.len(), 2);
        assert_eq!(q.committed("t", "agg"), 2);
        let rest = q.poll("t", "agg", 10);
        assert_eq!(rest.len(), 3);
        assert_eq!(rest[0].party, 2);
        assert_eq!(q.committed("t", "agg"), 5);
        assert!(q.poll("t", "agg", 10).is_empty());
        assert!(q.poll("missing", "agg", 10).is_empty());
    }

    #[test]
    fn telemetry_counts_traffic_and_detaches_cleanly() {
        let q = MessageQueue::new();
        q.produce("t", msg(0, 0)); // before attach: invisible
        let reg = Registry::enabled();
        q.set_telemetry(&reg);
        q.produce("t", msg(1, 0));
        q.produce("u", msg(2, 0));
        assert_eq!(q.fetch("t", 0, 10).len(), 2);
        q.wait_produce(q.produced(), Duration::from_millis(1));
        let (counters, gauges, histograms, _) = reg.snapshot();
        assert_eq!(
            counters.get(&("mq_messages_produced_total".to_string(), String::new())),
            Some(&2),
            "only post-attach produces count"
        );
        assert_eq!(
            counters.get(&("mq_messages_fetched_total".to_string(), String::new())),
            Some(&2)
        );
        assert_eq!(
            gauges.get(&("mq_topic_depth".to_string(), "topic=\"t\"".to_string())),
            Some(&2.0),
            "depth gauge tracks the topic's end offset"
        );
        assert_eq!(
            gauges.get(&("mq_topic_depth".to_string(), "topic=\"u\"".to_string())),
            Some(&1.0)
        );
        let waits = histograms
            .get(&("mq_wait_produce_secs".to_string(), String::new()))
            .expect("wait histogram recorded");
        assert_eq!(waits.count, 1);

        // detaching stops recording without touching what's there
        q.set_telemetry(&Registry::disabled());
        q.produce("t", msg(3, 0));
        let (counters, _, _, _) = reg.snapshot();
        assert_eq!(
            counters.get(&("mq_messages_produced_total".to_string(), String::new())),
            Some(&2)
        );
    }
}
