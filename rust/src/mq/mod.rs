//! Message queue substrate (Kafka stand-in).
//!
//! All dynamic aggregation strategies (§3) require model updates to be
//! "buffered somewhere in the datacenter, e.g., a message queue like Kafka
//! or a cloud object store". This module provides that buffer:
//!
//! * append-only **topics** with monotone offsets,
//! * **consumer groups** with committed offsets (an aggregator deployment
//!   resumes exactly where the previous one left off),
//! * **checkpoint slots** for partially aggregated state — §5.5: "lower
//!   priority aggregators are preempted by checkpointing partially
//!   aggregated model updates using the message queue".
//!
//! Payloads either carry real update data inline / by object-store
//! reference (live mode) or just a byte size (simulated mode); the queue
//! semantics are identical in both.
//!
//! **Two log kinds, one behavior.** [`MessageQueue::new`] is the
//! in-memory queue ([`LogKind::Mem`]); [`MessageQueue::durable`] backs
//! the same structures with the segmented mmap WAL in [`crate::wal`]
//! ([`LogKind::Disk`]): every produce, checkpoint, commit and topic drop
//! is also framed into the log, and reopening the same data dir replays
//! the log — including truncating a torn final record — back into an
//! identical queue, so a `kill -9`'d session resumes from disk to a
//! bit-identical model. The in-memory index is the read path in both
//! kinds (recovered inline payloads become zero-copy mmap-backed views),
//! which is what pins `Mem` ≡ `Disk` bit-identity: the WAL is purely a
//! durability side-channel.
//!
//! **Locking.** Topics are individually locked (`RwLock` map of
//! per-topic mutexes) so contended topics — many parties publishing into
//! different rounds/jobs — no longer serialize on one queue-wide lock.
//! Lock order is always map → topic cell → WAL.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::time::{Duration, Instant};

pub use crate::fusion::shard::BucketMeta;
use crate::sim::Time;
use crate::telemetry::{Registry, Scope, SpanKind};
use crate::wal::{self, RecordRef, RecoveryReport, Wal, WalConfig, WalError, WalStats};

/// What a message carries.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Live mode: flattened update inline.
    Inline(Vec<f32>),
    /// Recovered inline data: a zero-copy view into a mapped WAL
    /// segment. Behaves exactly like `Inline` through [`Payload::data`].
    Mapped(wal::MappedSlice),
    /// Live mode: key into the ObjectStore, plus the blob's size so
    /// transfer/capacity accounting works without dereferencing it.
    Ref { key: String, size_bytes: u64 },
    /// Sim mode: only the size matters (transfer-time accounting).
    Sim { size_bytes: u64 },
}

impl Payload {
    pub fn size_bytes(&self) -> u64 {
        match self {
            Payload::Inline(v) => (v.len() * 4) as u64,
            Payload::Mapped(m) => (m.len() * 4) as u64,
            Payload::Ref { size_bytes, .. } => *size_bytes,
            Payload::Sim { size_bytes } => *size_bytes,
        }
    }

    /// Inline update data, if this payload carries any.
    pub fn data(&self) -> Option<&[f32]> {
        match self {
            Payload::Inline(v) => Some(v),
            Payload::Mapped(m) => Some(m.as_f32s()),
            _ => None,
        }
    }
}

/// `Inline` and `Mapped` compare by contents — a recovered message
/// equals the message that was produced.
impl PartialEq for Payload {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (
                Payload::Ref {
                    key: ka,
                    size_bytes: sa,
                },
                Payload::Ref {
                    key: kb,
                    size_bytes: sb,
                },
            ) => ka == kb && sa == sb,
            (Payload::Sim { size_bytes: a }, Payload::Sim { size_bytes: b }) => a == b,
            (a, b) => match (a.data(), b.data()) {
                (Some(x), Some(y)) => x == y,
                _ => false,
            },
        }
    }
}

/// Zero-copy message view handed to consumers: the topic log and every
/// consumer share one refcounted allocation, so fetching an inline
/// model update never clones its `Vec<f32>`.
pub type MessageView = Arc<Message>;

/// A model-update (or checkpoint) message.
#[derive(Clone, Debug, PartialEq)]
pub struct Message {
    /// Producing party (or aggregator id for checkpoints).
    pub party: usize,
    /// FL synchronization round.
    pub round: u32,
    /// Aggregation weight (= #samples at the party for FedAvg/FedProx).
    pub weight: f32,
    /// Enqueue timestamp (virtual or wall).
    pub enqueued_at: Time,
    pub payload: Payload,
}

/// Which storage engine sits under the queue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LogKind {
    /// In-memory only (dies with the process).
    Mem,
    /// Backed by the segmented mmap WAL; survives `kill -9`.
    Disk,
}

#[derive(Debug, Default)]
struct Topic {
    log: Vec<MessageView>,
    /// committed offset per consumer group
    commits: BTreeMap<String, usize>,
    /// round → offsets of that round's messages, so round-scoped consumers
    /// jump straight to their slice instead of scanning from offset 0.
    by_round: BTreeMap<u32, Vec<usize>>,
    /// Set when the topic is GC'd out of the map: a writer that raced
    /// the drop retries against a fresh cell instead of mutating an
    /// orphan (which the WAL replay would otherwise resurrect).
    dropped: bool,
}

/// One topic behind its own lock.
#[derive(Debug, Default)]
struct TopicCell(Mutex<Topic>);

/// The queue. Cheap to share behind `&` thanks to interior mutability.
#[derive(Debug, Default)]
pub struct MessageQueue {
    topics: RwLock<BTreeMap<String, Arc<TopicCell>>>,
    /// Checkpoint slots: job/round keyed partial aggregates (latest wins).
    checkpoints: Mutex<BTreeMap<String, CheckpointState>>,
    /// Global produce counter + condvar: wall-clock consumers (the live
    /// driver) sleep here instead of polling, and every `produce` wakes
    /// them. Purely additive — virtual-time consumers never touch it.
    produce_sig: (Mutex<u64>, Condvar),
    /// Optional telemetry handle (disabled by default — the clone out of
    /// the mutex is an `Option<Arc>` copy, and a disabled registry makes
    /// every record a no-op). Strictly observational: never affects
    /// offsets, wakeups, or message contents.
    telemetry: Mutex<Registry>,
    /// Present iff [`LogKind::Disk`].
    wal: Option<Wal>,
    /// What recovery found when the durable queue was opened.
    recovery: Option<RecoveryReport>,
    recovery_reported: AtomicBool,
}

/// A partially aggregated state parked by a preempted aggregator (§5.5).
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointState {
    /// Accumulator payload (live mode) or None in sim mode. With the
    /// bucketed fold plane this is the non-empty buckets' weighted sums
    /// concatenated in bucket order (`buckets.len() * dim` values); a
    /// legacy record with no bucket metas is a pre-tree running mean.
    pub acc: Option<Vec<f32>>,
    /// Total weight folded into the accumulator so far.
    pub weight: f32,
    /// Number of updates folded in.
    pub n_merged: usize,
    /// Offset in the update topic up to which merging is complete.
    pub consumed_to: usize,
    pub saved_at: Time,
    /// Per-bucket metadata describing `acc`'s layout (empty = legacy).
    pub buckets: Vec<BucketMeta>,
}

impl MessageQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Open (or create) a durable queue on `cfg.dir`: every mutation is
    /// WAL-framed, and any existing log — including one left by a
    /// `kill -9` — is replayed back into the in-memory index first.
    /// Mid-log corruption is a hard error; a torn final record is
    /// truncated (and reported via [`recovery`](MessageQueue::recovery)).
    pub fn durable(cfg: WalConfig) -> Result<MessageQueue, WalError> {
        let (wal, records, report) = Wal::open(cfg)?;
        let q = MessageQueue {
            wal: Some(wal),
            recovery: Some(report),
            ..Default::default()
        };
        let mut topics: BTreeMap<String, Arc<TopicCell>> = BTreeMap::new();
        let mut replayed_msgs = 0u64;
        for rec in records {
            match rec {
                wal::Record::Produce { topic, msg } => {
                    let mut t = topics.entry(topic).or_default().0.lock().unwrap();
                    let off = t.log.len();
                    t.by_round.entry(msg.round).or_default().push(off);
                    t.log.push(Arc::new(msg));
                    replayed_msgs += 1;
                }
                wal::Record::Checkpoint { slot, state } => {
                    q.checkpoints.lock().unwrap().insert(slot, state);
                }
                wal::Record::Commit {
                    topic,
                    group,
                    offset,
                } => {
                    let mut t = topics.entry(topic).or_default().0.lock().unwrap();
                    let e = t.commits.entry(group).or_insert(0);
                    *e = (*e).max(offset as usize);
                }
                wal::Record::DropTopic { topic } => {
                    topics.remove(&topic);
                }
                wal::Record::ClearCheckpoint { slot } => {
                    q.checkpoints.lock().unwrap().remove(&slot);
                }
            }
        }
        *q.topics.write().unwrap() = topics;
        // The wake counter restarts at the replayed message count so
        // `produced()` keeps meaning "messages in the queue's history".
        *q.produce_sig.0.lock().unwrap() = replayed_msgs;
        Ok(q)
    }

    /// Which storage engine this queue runs on.
    pub fn log_kind(&self) -> LogKind {
        if self.wal.is_some() {
            LogKind::Disk
        } else {
            LogKind::Mem
        }
    }

    /// Data directory of a durable queue.
    pub fn data_dir(&self) -> Option<&Path> {
        self.wal.as_ref().map(|w| w.dir())
    }

    /// Recovery report from opening a durable queue (None for `Mem`).
    pub fn recovery(&self) -> Option<RecoveryReport> {
        self.recovery.clone()
    }

    /// WAL append/sync/rollover counters (None for `Mem`).
    pub fn wal_stats(&self) -> Option<WalStats> {
        self.wal.as_ref().map(|w| w.stats())
    }

    /// Force-flush the log to disk regardless of fsync policy. No-op
    /// for `Mem`.
    pub fn sync(&self) {
        if let Some(w) = &self.wal {
            if let Err(e) = w.flush() {
                panic!("durable mq flush failed: {e}");
            }
        }
    }

    /// Attach a telemetry registry: produce/consume counters, per-topic
    /// depth gauges, the `wait_produce` wait-time histogram and (for
    /// durable queues) `wal_*` counters record into it. Pass
    /// `Registry::disabled()` to detach.
    pub fn set_telemetry(&self, reg: &Registry) {
        *self.telemetry.lock().unwrap() = reg.clone();
        if !reg.on() {
            return;
        }
        // Report what recovery did, once, to the first live registry.
        if let Some(rep) = &self.recovery {
            if !self.recovery_reported.swap(true, Ordering::Relaxed) {
                reg.counter_add("wal_recovered_records_total", &Scope::none(), rep.records);
                reg.counter_add("wal_recovered_bytes_total", &Scope::none(), rep.bytes);
                if rep.torn_tail {
                    reg.counter_add("wal_torn_tail_truncations_total", &Scope::none(), 1);
                }
                reg.gauge_set("wal_segments", &Scope::none(), rep.segments.max(1) as f64);
                let end = ((rep.elapsed_secs * 1e6) as Time).max(1);
                reg.span_begin(SpanKind::Recovery, 0, 0, rep.records, 0);
                reg.span_end(SpanKind::Recovery, 0, 0, rep.records, end);
            }
        }
    }

    fn reg(&self) -> Registry {
        self.telemetry.lock().unwrap().clone()
    }

    /// Frame a mutation into the WAL (durable queues only). Append
    /// failure means acknowledged durability would be a lie — panic
    /// rather than silently degrade to `Mem` semantics.
    fn wal_write(&self, rec: RecordRef<'_>) -> Option<wal::AppendInfo> {
        let wal = self.wal.as_ref()?;
        match wal.append(rec) {
            Ok(info) => Some(info),
            Err(e) => panic!("durable mq append failed: {e}"),
        }
    }

    fn record_wal(&self, reg: &Registry, info: &wal::AppendInfo) {
        reg.counter_add("wal_records_appended_total", &Scope::none(), 1);
        reg.counter_add("wal_bytes_appended_total", &Scope::none(), info.bytes as u64);
        if info.synced {
            reg.counter_add("wal_fsyncs_total", &Scope::none(), 1);
        }
        if info.rolled {
            reg.counter_add("wal_segments_rolled_total", &Scope::none(), 1);
        }
        reg.gauge_set("wal_segments", &Scope::none(), info.segments as f64);
    }

    /// Existing cell for a topic, if any.
    fn cell(&self, topic: &str) -> Option<Arc<TopicCell>> {
        self.topics.read().unwrap().get(topic).cloned()
    }

    /// Cell for a topic, creating it if missing (read-lock fast path).
    fn cell_or_create(&self, topic: &str) -> Arc<TopicCell> {
        if let Some(c) = self.cell(topic) {
            return c;
        }
        Arc::clone(
            self.topics
                .write()
                .unwrap()
                .entry(topic.to_string())
                .or_default(),
        )
    }

    /// Lock a live (non-dropped) cell for writing, retrying if a
    /// concurrent [`drop_topic`](MessageQueue::drop_topic) GC'd the cell
    /// between lookup and lock. Returns the guard via the callback to
    /// keep lifetimes simple.
    fn with_topic_mut<R>(&self, topic: &str, f: impl FnOnce(&mut Topic) -> R) -> R {
        loop {
            let cell = self.cell_or_create(topic);
            let mut t = cell.0.lock().unwrap();
            if t.dropped {
                continue;
            }
            return f(&mut t);
        }
    }

    /// Append a message; returns its offset. Wakes any wall-clock
    /// consumer blocked in [`wait_produce`](MessageQueue::wait_produce).
    pub fn produce(&self, topic: &str, msg: Message) -> usize {
        let (off, wrote) = self.with_topic_mut(topic, |t| {
            let off = t.log.len();
            // WAL append under the topic lock: per-topic file order ==
            // offset order, which is what replay relies on.
            let wrote = self.wal_write(RecordRef::Produce { topic, msg: &msg });
            t.by_round.entry(msg.round).or_default().push(off);
            t.log.push(Arc::new(msg));
            (off, wrote)
        });
        let reg = self.reg();
        if reg.on() {
            reg.counter_add("mq_messages_produced_total", &Scope::none(), 1);
            reg.gauge_set(
                "mq_topic_depth",
                &Scope::label("topic", topic),
                (off + 1) as f64,
            );
            if let Some(info) = &wrote {
                self.record_wal(&reg, info);
            }
        }
        let (lock, cvar) = &self.produce_sig;
        *lock.lock().unwrap() += 1;
        cvar.notify_all();
        off
    }

    /// Total messages produced across all topics in this queue's history
    /// (including WAL-replayed ones) — the wake counter for
    /// [`wait_produce`](MessageQueue::wait_produce).
    pub fn produced(&self) -> u64 {
        *self.produce_sig.0.lock().unwrap()
    }

    /// Block until the produce counter exceeds `seen` or `timeout`
    /// elapses; returns the current counter. The live wall-clock driver
    /// parks here between event deadlines so a party's publish wakes it
    /// immediately.
    pub fn wait_produce(&self, seen: u64, timeout: Duration) -> u64 {
        let t0 = Instant::now();
        let (lock, cvar) = &self.produce_sig;
        let deadline = t0 + timeout;
        let mut n = lock.lock().unwrap();
        while *n <= seen {
            let rem = deadline.saturating_duration_since(Instant::now());
            if rem.is_zero() {
                break;
            }
            let (guard, res) = cvar.wait_timeout(n, rem).unwrap();
            n = guard;
            if res.timed_out() {
                break;
            }
        }
        let out = *n;
        drop(n);
        let reg = self.reg();
        if reg.on() {
            // Wall-side observation only (the wait itself is wall time);
            // recording it perturbs nothing the seeded streams see.
            reg.histogram_observe(
                "mq_wait_produce_secs",
                &Scope::none(),
                t0.elapsed().as_secs_f64(),
                &crate::telemetry::LATENCY_BUCKETS_SECS,
            );
        }
        out
    }

    /// Messages in [from, from+max) — non-consuming, zero-copy read: the
    /// returned views share the log's allocations (cloning an `Arc`, not
    /// the payload).
    pub fn fetch(&self, topic: &str, from: usize, max: usize) -> Vec<MessageView> {
        let batch: Vec<MessageView> = match self.cell(topic) {
            None => Vec::new(),
            Some(c) => {
                let t = c.0.lock().unwrap();
                t.log.iter().skip(from).take(max).cloned().collect()
            }
        };
        if !batch.is_empty() {
            let reg = self.reg();
            if reg.on() {
                reg.counter_add(
                    "mq_messages_fetched_total",
                    &Scope::none(),
                    batch.len() as u64,
                );
            }
        }
        batch
    }

    /// All of one round's messages, via the round index — O(messages in
    /// the round), not O(log length). Zero-copy like [`fetch`].
    pub fn fetch_round(&self, topic: &str, round: u32) -> Vec<MessageView> {
        match self.cell(topic) {
            None => Vec::new(),
            Some(c) => {
                let t = c.0.lock().unwrap();
                t.by_round
                    .get(&round)
                    .map(|offs| offs.iter().map(|&o| Arc::clone(&t.log[o])).collect())
                    .unwrap_or_default()
            }
        }
    }

    /// Consume for a group: fetch up to `max` messages past the group's
    /// committed offset and advance the commit past them, atomically.
    /// Zero-copy like [`fetch`].
    pub fn poll(&self, topic: &str, group: &str, max: usize) -> Vec<MessageView> {
        let Some(cell) = self.cell(topic) else {
            return Vec::new();
        };
        let mut t = cell.0.lock().unwrap();
        if t.dropped {
            return Vec::new();
        }
        let from = t.commits.get(group).copied().unwrap_or(0);
        let batch: Vec<MessageView> = t.log.iter().skip(from).take(max).cloned().collect();
        if !batch.is_empty() {
            let to = from + batch.len();
            let _ = self.wal_write(RecordRef::Commit {
                topic,
                group,
                offset: to as u64,
            });
            t.commits.insert(group.to_string(), to);
        }
        batch
    }

    /// Names of every live (non-dropped) topic, sorted.
    pub fn topic_names(&self) -> Vec<String> {
        self.topics.read().unwrap().keys().cloned().collect()
    }

    /// Names of every populated checkpoint slot, sorted.
    pub fn checkpoint_slots(&self) -> Vec<String> {
        self.checkpoints.lock().unwrap().keys().cloned().collect()
    }

    /// End offset (= number of messages produced so far).
    pub fn end_offset(&self, topic: &str) -> usize {
        self.cell(topic)
            .map(|c| c.0.lock().unwrap().log.len())
            .unwrap_or(0)
    }

    /// Committed offset of a consumer group (0 if never committed).
    pub fn committed(&self, topic: &str, group: &str) -> usize {
        self.cell(topic)
            .and_then(|c| c.0.lock().unwrap().commits.get(group).copied())
            .unwrap_or(0)
    }

    /// Commit a consumer-group offset. Offsets are monotone: committing
    /// backwards is a no-op (idempotent redelivery semantics).
    pub fn commit(&self, topic: &str, group: &str, offset: usize) {
        self.with_topic_mut(topic, |t| {
            let e = t.commits.entry(group.to_string()).or_insert(0);
            if offset > *e {
                let _ = self.wal_write(RecordRef::Commit {
                    topic,
                    group,
                    offset: offset as u64,
                });
                *e = offset;
            }
        });
    }

    /// Uncommitted backlog for a group.
    pub fn backlog(&self, topic: &str, group: &str) -> usize {
        self.end_offset(topic) - self.committed(topic, group)
    }

    // ------------------------------------------------------------------
    // checkpoint slots
    // ------------------------------------------------------------------

    pub fn save_checkpoint(&self, slot: &str, state: CheckpointState) {
        let mut ckpts = self.checkpoints.lock().unwrap();
        let wrote = self.wal_write(RecordRef::Checkpoint { slot, state: &state });
        ckpts.insert(slot.to_string(), state);
        drop(ckpts);
        if let Some(info) = wrote {
            let reg = self.reg();
            if reg.on() {
                self.record_wal(&reg, &info);
            }
        }
    }

    pub fn load_checkpoint(&self, slot: &str) -> Option<CheckpointState> {
        self.checkpoints.lock().unwrap().get(slot).cloned()
    }

    pub fn clear_checkpoint(&self, slot: &str) -> bool {
        let mut ckpts = self.checkpoints.lock().unwrap();
        let existed = ckpts.remove(slot).is_some();
        if existed {
            let _ = self.wal_write(RecordRef::ClearCheckpoint { slot });
        }
        existed
    }

    /// Total bytes resident across topics (capacity accounting).
    pub fn resident_bytes(&self) -> u64 {
        let cells: Vec<Arc<TopicCell>> = self.topics.read().unwrap().values().cloned().collect();
        cells
            .iter()
            .map(|c| {
                c.0.lock()
                    .unwrap()
                    .log
                    .iter()
                    .map(|m| m.payload.size_bytes())
                    .sum::<u64>()
            })
            .sum()
    }

    /// Drop a whole topic (round GC after aggregation completes). The
    /// WAL gets a tombstone so replay drops it too.
    pub fn drop_topic(&self, topic: &str) -> usize {
        let n = {
            let mut topics = self.topics.write().unwrap();
            match topics.remove(topic) {
                None => 0,
                Some(cell) => {
                    let mut t = cell.0.lock().unwrap();
                    t.dropped = true;
                    let _ = self.wal_write(RecordRef::DropTopic { topic });
                    t.log.len()
                }
            }
        };
        if n > 0 {
            let reg = self.reg();
            if reg.on() {
                reg.gauge_set("mq_topic_depth", &Scope::label("topic", topic), 0.0);
            }
        }
        n
    }
}

/// Conventional topic name for a job's round updates.
pub fn update_topic(job: usize, round: u32) -> String {
    format!("job{job}/round{round}/updates")
}

/// Conventional checkpoint slot for a job's round.
pub fn checkpoint_slot(job: usize, round: u32) -> String {
    format!("job{job}/round{round}/ckpt")
}

/// Conventional topic for one L1 aggregator shard's round updates.
/// Shard 0 of a single-shard plane uses [`update_topic`] — the tree
/// with one shard IS the flat plane, topic names included.
pub fn shard_topic(job: usize, round: u32, shard: usize) -> String {
    format!("job{job}/round{round}/shard{shard}/updates")
}

/// Conventional checkpoint slot for one L1 shard's partial aggregate.
pub fn shard_checkpoint_slot(job: usize, round: u32, shard: usize) -> String {
    format!("job{job}/round{round}/shard{shard}/ckpt")
}

/// The topic shard `shard` of `shards` consumes for `(job, round)` —
/// collapses to the flat [`update_topic`] when the plane is unsharded.
pub fn shard_topic_for(job: usize, round: u32, shard: usize, shards: usize) -> String {
    if shards <= 1 {
        update_topic(job, round)
    } else {
        shard_topic(job, round, shard)
    }
}

/// The checkpoint slot shard `shard` of `shards` writes for `(job,
/// round)` — collapses to the flat [`checkpoint_slot`] when unsharded.
pub fn shard_slot_for(job: usize, round: u32, shard: usize, shards: usize) -> String {
    if shards <= 1 {
        checkpoint_slot(job, round)
    } else {
        shard_checkpoint_slot(job, round, shard)
    }
}

/// Conventional checkpoint slot for a job's adaptive-policy state
/// (PR 10): the arrival sketch + drift term serialized to `acc`,
/// written at round completion, reloaded on §5.5 resume. One slot per
/// job — each write supersedes the last (the sketch is cumulative).
pub fn adapt_slot(job: usize) -> String {
    format!("job{job}/adapt")
}

/// Conventional topic for a job's published (fused) global models — one
/// message per completed round, so offset == completed-round count. The
/// live runner treats this log as the job's durable model state: a
/// restarted aggregator derives "which round am I in" and "what is the
/// current global" from it (§5.5 checkpoint/resume).
pub fn model_topic(job: usize) -> String {
    format!("job{job}/models")
}

/// Conventional topic for live party-side metrics (training losses).
pub fn metrics_topic(job: usize) -> String {
    format!("job{job}/metrics")
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn msg(party: usize, round: u32) -> Message {
        Message {
            party,
            round,
            weight: 1.0,
            enqueued_at: 0,
            payload: Payload::Sim { size_bytes: 100 },
        }
    }

    fn tmp(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("fljit_mq_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn offsets_monotone() {
        let q = MessageQueue::new();
        assert_eq!(q.produce("t", msg(0, 0)), 0);
        assert_eq!(q.produce("t", msg(1, 0)), 1);
        assert_eq!(q.end_offset("t"), 2);
        assert_eq!(q.log_kind(), LogKind::Mem);
        assert!(q.data_dir().is_none());
        assert!(q.wal_stats().is_none());
    }

    #[test]
    fn fetch_window() {
        let q = MessageQueue::new();
        for p in 0..5 {
            q.produce("t", msg(p, 0));
        }
        let w = q.fetch("t", 1, 2);
        assert_eq!(w.len(), 2);
        assert_eq!(w[0].party, 1);
        assert_eq!(w[1].party, 2);
        assert!(q.fetch("t", 10, 5).is_empty());
        assert!(q.fetch("missing", 0, 5).is_empty());
    }

    #[test]
    fn consumer_group_commit_and_backlog() {
        let q = MessageQueue::new();
        for p in 0..4 {
            q.produce("t", msg(p, 0));
        }
        assert_eq!(q.backlog("t", "agg"), 4);
        q.commit("t", "agg", 3);
        assert_eq!(q.committed("t", "agg"), 3);
        assert_eq!(q.backlog("t", "agg"), 1);
        // backwards commit ignored
        q.commit("t", "agg", 1);
        assert_eq!(q.committed("t", "agg"), 3);
        // independent group
        assert_eq!(q.backlog("t", "other"), 4);
    }

    #[test]
    fn checkpoint_roundtrip() {
        let q = MessageQueue::new();
        let slot = checkpoint_slot(3, 7);
        assert!(q.load_checkpoint(&slot).is_none());
        q.save_checkpoint(
            &slot,
            CheckpointState {
                acc: Some(vec![1.0, 2.0]),
                weight: 5.0,
                n_merged: 3,
                consumed_to: 3,
                saved_at: 123,
                buckets: vec![BucketMeta {
                    bucket: 4,
                    weight: 5.0,
                    folds: 3,
                }],
            },
        );
        let st = q.load_checkpoint(&slot).unwrap();
        assert_eq!(st.n_merged, 3);
        assert_eq!(st.acc.as_ref().unwrap().len(), 2);
        assert_eq!(st.buckets.len(), 1);
        assert_eq!(st.buckets[0].bucket, 4);
        assert!(q.clear_checkpoint(&slot));
        assert!(!q.clear_checkpoint(&slot));
    }

    #[test]
    fn resident_bytes_and_gc() {
        let q = MessageQueue::new();
        for p in 0..10 {
            q.produce("a", msg(p, 0));
        }
        q.produce(
            "b",
            Message {
                payload: Payload::Inline(vec![0.0; 25]),
                ..msg(0, 0)
            },
        );
        assert_eq!(q.resident_bytes(), 10 * 100 + 100);
        assert_eq!(q.drop_topic("a"), 10);
        assert_eq!(q.resident_bytes(), 100);
    }

    #[test]
    fn ref_payload_sizes_and_compares() {
        let p = Payload::Ref {
            key: "blob/1".into(),
            size_bytes: 4096,
        };
        assert_eq!(p.size_bytes(), 4096, "by-ref payloads count their blob size");
        assert!(p.data().is_none());
        assert_eq!(
            p,
            Payload::Ref {
                key: "blob/1".into(),
                size_bytes: 4096
            }
        );
        assert_ne!(
            p,
            Payload::Ref {
                key: "blob/2".into(),
                size_bytes: 4096
            }
        );
        let q = MessageQueue::new();
        q.produce(
            "t",
            Message {
                payload: p,
                ..msg(0, 0)
            },
        );
        assert_eq!(q.resident_bytes(), 4096);
    }

    #[test]
    fn topic_naming() {
        assert_eq!(update_topic(2, 5), "job2/round5/updates");
        assert_eq!(checkpoint_slot(2, 5), "job2/round5/ckpt");
        assert_eq!(model_topic(2), "job2/models");
        assert_eq!(metrics_topic(2), "job2/metrics");
    }

    #[test]
    fn produce_counter_counts_across_topics() {
        let q = MessageQueue::new();
        assert_eq!(q.produced(), 0);
        q.produce("a", msg(0, 0));
        q.produce("b", msg(1, 0));
        assert_eq!(q.produced(), 2);
        // already-satisfied wait returns immediately
        let n = q.wait_produce(1, Duration::from_secs(5));
        assert_eq!(n, 2);
        // unsatisfied wait times out (short) and returns the counter
        let n = q.wait_produce(2, Duration::from_millis(10));
        assert_eq!(n, 2);
    }

    #[test]
    fn wait_produce_woken_by_concurrent_producer() {
        let q = Arc::new(MessageQueue::new());
        let seen = q.produced();
        let q2 = Arc::clone(&q);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.produce("t", msg(0, 0));
        });
        let t0 = Instant::now();
        let n = q.wait_produce(seen, Duration::from_secs(5));
        h.join().unwrap();
        assert!(n > seen);
        assert!(t0.elapsed() < Duration::from_secs(2), "wake, not timeout");
    }

    #[test]
    fn fetch_round_uses_index_not_scan() {
        let q = MessageQueue::new();
        for r in 0..4u32 {
            for p in 0..3 {
                q.produce("t", msg(p, r));
            }
        }
        let r2 = q.fetch_round("t", 2);
        assert_eq!(r2.len(), 3);
        assert!(r2.iter().all(|m| m.round == 2));
        assert_eq!(
            r2.iter().map(|m| m.party).collect::<Vec<_>>(),
            vec![0, 1, 2],
            "round fetch preserves production order"
        );
        assert!(q.fetch_round("t", 99).is_empty());
        assert!(q.fetch_round("missing", 0).is_empty());
    }

    #[test]
    fn inline_payload_reads_are_zero_copy() {
        let q = MessageQueue::new();
        let data = vec![1.0f32; 1024];
        q.produce(
            "t",
            Message {
                payload: Payload::Inline(data),
                ..msg(0, 0)
            },
        );
        let a = q.fetch("t", 0, 1).remove(0);
        let b = q.fetch_round("t", 0).remove(0);
        let pa = a.payload.data().unwrap().as_ptr();
        let pb = b.payload.data().unwrap().as_ptr();
        assert_eq!(pa, pb, "both views must share the log's allocation");
        assert!(Arc::ptr_eq(&a, &b), "fetch must hand out the same Arc");
    }

    #[test]
    fn poll_advances_commit_and_shares_data() {
        let q = MessageQueue::new();
        for p in 0..5 {
            q.produce("t", msg(p, 0));
        }
        let first = q.poll("t", "agg", 2);
        assert_eq!(first.len(), 2);
        assert_eq!(q.committed("t", "agg"), 2);
        let rest = q.poll("t", "agg", 10);
        assert_eq!(rest.len(), 3);
        assert_eq!(rest[0].party, 2);
        assert_eq!(q.committed("t", "agg"), 5);
        assert!(q.poll("t", "agg", 10).is_empty());
        assert!(q.poll("missing", "agg", 10).is_empty());
    }

    #[test]
    fn telemetry_counts_traffic_and_detaches_cleanly() {
        let q = MessageQueue::new();
        q.produce("t", msg(0, 0)); // before attach: invisible
        let reg = Registry::enabled();
        q.set_telemetry(&reg);
        q.produce("t", msg(1, 0));
        q.produce("u", msg(2, 0));
        assert_eq!(q.fetch("t", 0, 10).len(), 2);
        q.wait_produce(q.produced(), Duration::from_millis(1));
        let (counters, gauges, histograms, _) = reg.snapshot();
        assert_eq!(
            counters.get(&("mq_messages_produced_total".to_string(), String::new())),
            Some(&2),
            "only post-attach produces count"
        );
        assert_eq!(
            counters.get(&("mq_messages_fetched_total".to_string(), String::new())),
            Some(&2)
        );
        assert_eq!(
            gauges.get(&("mq_topic_depth".to_string(), "topic=\"t\"".to_string())),
            Some(&2.0),
            "depth gauge tracks the topic's end offset"
        );
        assert_eq!(
            gauges.get(&("mq_topic_depth".to_string(), "topic=\"u\"".to_string())),
            Some(&1.0)
        );
        let waits = histograms
            .get(&("mq_wait_produce_secs".to_string(), String::new()))
            .expect("wait histogram recorded");
        assert_eq!(waits.count, 1);

        // detaching stops recording without touching what's there
        q.set_telemetry(&Registry::disabled());
        q.produce("t", msg(3, 0));
        let (counters, _, _, _) = reg.snapshot();
        assert_eq!(
            counters.get(&("mq_messages_produced_total".to_string(), String::new())),
            Some(&2)
        );
    }

    // ------------------------------------------------------------------
    // durable (LogKind::Disk) behavior
    // ------------------------------------------------------------------

    #[test]
    fn durable_queue_replays_to_identical_state() {
        let dir = tmp("replay");
        {
            let q = MessageQueue::durable(WalConfig::new(&dir)).unwrap();
            assert_eq!(q.log_kind(), LogKind::Disk);
            assert_eq!(q.data_dir().unwrap(), dir.as_path());
            for r in 0..3u32 {
                for p in 0..2 {
                    q.produce(
                        "t",
                        Message {
                            payload: Payload::Inline(vec![p as f32 + r as f32; 4]),
                            ..msg(p, r)
                        },
                    );
                }
            }
            q.commit("t", "agg", 4);
            q.save_checkpoint(
                &checkpoint_slot(0, 2),
                CheckpointState {
                    acc: Some(vec![0.5; 4]),
                    weight: 2.0,
                    n_merged: 2,
                    consumed_to: 4,
                    saved_at: 42,
                    buckets: vec![BucketMeta {
                        bucket: 0,
                        weight: 2.0,
                        folds: 2,
                    }],
                },
            );
            q.produce("gone", msg(0, 0));
            q.drop_topic("gone");
        }
        let q = MessageQueue::durable(WalConfig::new(&dir)).unwrap();
        let rep = q.recovery().unwrap();
        assert!(!rep.torn_tail);
        assert_eq!(q.end_offset("t"), 6);
        assert_eq!(q.produced(), 7, "history counter includes dropped topics");
        assert_eq!(q.end_offset("gone"), 0, "tombstone replayed");
        assert_eq!(q.committed("t", "agg"), 4);
        let ck = q.load_checkpoint(&checkpoint_slot(0, 2)).unwrap();
        assert_eq!(ck.n_merged, 2);
        assert_eq!(ck.acc.as_deref(), Some(&[0.5f32; 4][..]));
        // replayed messages read back bit-identical, through the same API
        let r1 = q.fetch_round("t", 1);
        assert_eq!(r1.len(), 2);
        assert_eq!(r1[0].payload.data().unwrap(), &[1.0; 4]);
        assert_eq!(r1[1].payload.data().unwrap(), &[2.0; 4]);
        // offsets continue past the replayed log
        assert_eq!(q.produce("t", msg(9, 3)), 6);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn durable_empty_dir_recovers_to_empty_queue() {
        let dir = tmp("empty");
        std::fs::create_dir_all(&dir).unwrap();
        let q = MessageQueue::durable(WalConfig::new(&dir)).unwrap();
        let rep = q.recovery().unwrap();
        assert_eq!(rep.records, 0);
        assert!(!rep.torn_tail);
        assert_eq!(q.produced(), 0);
        assert_eq!(q.produce("t", msg(0, 0)), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn durable_poll_commits_survive_reopen() {
        let dir = tmp("poll");
        {
            let q = MessageQueue::durable(WalConfig::new(&dir)).unwrap();
            for p in 0..5 {
                q.produce("t", msg(p, 0));
            }
            assert_eq!(q.poll("t", "agg", 3).len(), 3);
        }
        let q = MessageQueue::durable(WalConfig::new(&dir)).unwrap();
        assert_eq!(q.committed("t", "agg"), 3, "poll's commit was framed");
        let rest = q.poll("t", "agg", 10);
        assert_eq!(rest.len(), 2);
        assert_eq!(rest[0].party, 3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn durable_wal_telemetry_counts_appends() {
        let dir = tmp("tel");
        let q = MessageQueue::durable(WalConfig::new(&dir)).unwrap();
        let reg = Registry::enabled();
        q.set_telemetry(&reg);
        q.produce("t", msg(0, 0));
        q.produce("t", msg(1, 0));
        q.save_checkpoint(
            &checkpoint_slot(0, 0),
            CheckpointState {
                acc: None,
                weight: 0.0,
                n_merged: 0,
                consumed_to: 0,
                saved_at: 0,
                buckets: Vec::new(),
            },
        );
        let (counters, gauges, _, _) = reg.snapshot();
        assert_eq!(
            counters.get(&("wal_records_appended_total".to_string(), String::new())),
            Some(&3)
        );
        assert!(
            counters
                .get(&("wal_bytes_appended_total".to_string(), String::new()))
                .copied()
                .unwrap_or(0)
                > 0
        );
        assert_eq!(
            counters.get(&("wal_recovered_records_total".to_string(), String::new())),
            Some(&0),
            "fresh dir recovery reported"
        );
        assert_eq!(
            gauges.get(&("wal_segments".to_string(), String::new())),
            Some(&1.0)
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
