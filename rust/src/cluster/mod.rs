//! Serverless cluster substrate: containers, cold starts, priorities,
//! preemption, and the container-seconds ledger.
//!
//! Models the paper's execution environment (§3, §5.5, §6.1-6.2): Ray
//! serverless executors in Docker containers on Kubernetes. What the
//! evaluation measures — *container seconds* and *aggregation latency* —
//! depends only on when containers are alive and what they are doing, which
//! is exactly what this module tracks:
//!
//! * **Deployment overheads** (orange in Fig 2): cold start (scheduling +
//!   boot) and state load (pull model/partial aggregate from the MQ /
//!   object store); **checkpoint** cost on exit or preemption.
//! * **Priority scheduling every δ** (§5.5): pending aggregation tasks are
//!   started in priority order (smaller value = more urgent = earlier
//!   deadline `t_rnd − t_agg`) whenever capacity allows, at tick
//!   granularity; `force_start` models the JIT deadline timer's
//!   FORCE_TRIGGER which bypasses the tick.
//! * **Preemption with work conservation**: a preempted task checkpoints
//!   its partial aggregate (completed merges are conserved at work-item
//!   granularity; the in-flight merge is redone on resume) and re-enters
//!   the pending queue with its priority retained. With an arbitration
//!   policy installed, the *victim* is the policy's choice too
//!   (`ArbitrationPolicy::preempt_victim`): deadline keeps the §5.5
//!   latest-deadline order, least-slack evicts the slackest running
//!   task, wfs the most-overserved tenant's. Every preemption decision
//!   is appended to [`Cluster::preemption_log`], so the order replays
//!   bit-identically for a given seed + trace.
//! * **Ledger**: every container incarnation's [start, end) interval with
//!   job attribution — container-seconds, the paper's §6.2 metric.
//!
//! A task with `keep_alive` set models the Eager Always-On aggregator: its
//! container idles between updates instead of exiting, accruing the idle
//! container-seconds Fig 2 shades in light grey.

use std::collections::{BTreeSet, VecDeque};

use crate::broker::arbitration::{ArbitrationPolicy, ArbitrationView, Candidate};
use crate::sim::{EventKind, EventQueue, Time};

pub type TaskId = usize;

/// Scheduling priority: smaller = higher priority. JIT sets this to the
/// aggregation deadline `t_rnd − t_agg` in micros (§5.5).
pub type Priority = i64;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Waiting for capacity / a scheduling tick.
    Pending,
    /// Cold start + state load in progress.
    Starting,
    /// Processing a work item.
    Running,
    /// Alive with an empty work queue (always-on aggregators).
    Idle,
    /// Writing the (partial) aggregate back before exit/preemption.
    Checkpointing,
    Done,
}

#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub job: usize,
    pub round: u32,
    pub priority: Priority,
    /// Cold-start (scheduler + boot) time for each deployment.
    pub cold_start: Time,
    /// State-load time for each deployment (model / checkpoint pull).
    pub state_load: Time,
    /// Checkpoint time on exit or preemption.
    pub checkpoint: Time,
    /// Keep the container alive when the work queue drains (Eager AO).
    pub keep_alive: bool,
}

#[derive(Debug)]
struct Task {
    spec: TaskSpec,
    phase: Phase,
    work: VecDeque<Time>,
    /// Σ durations in `work`, maintained incrementally so arbitration
    /// snapshots never re-sum the deque (items leave only on completed
    /// merges — a preempted in-flight item stays queued and is redone).
    queued_time: Time,
    /// Token guarding scheduled phase-end events (stale events are ignored).
    token: u64,
    /// When the task last became *startable* (Pending with work, or
    /// preempted back to Pending) — the arbitration aging input. Cleared
    /// on deploy.
    pending_since: Option<Time>,
    finish_requested: bool,
    /// Set while checkpointing because of preemption (→ Pending after).
    preempting: bool,
    deployments: u32,
    /// Ledger index of the live deployment.
    live_deployment: Option<usize>,
    work_done: u64,
    /// Index keys currently held in the scheduler sets (hot-path index).
    pending_key: Option<(Priority, TaskId)>,
    active_key: Option<(Priority, TaskId)>,
}

/// One container incarnation's lifetime.
#[derive(Clone, Debug)]
pub struct Deployment {
    pub job: usize,
    pub task: TaskId,
    pub start: Time,
    pub end: Option<Time>,
}

/// What `advance` tells the platform/strategy.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Notification {
    /// Cold start + state load finished; container now live.
    Deployed { task: TaskId },
    /// One work item (one update merge) completed.
    WorkItemDone { task: TaskId },
    /// Work queue drained (and container stays alive: keep_alive or
    /// awaiting finish request).
    WorkDrained { task: TaskId },
    /// Task exited cleanly (after checkpoint).
    TaskExited { task: TaskId },
    /// Task was preempted; it is pending again with work conserved.
    TaskPreempted { task: TaskId },
}

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Max concurrently deployed containers.
    pub capacity: usize,
    /// δ — scheduling decision interval (§5.5).
    pub delta_tick: Time,
    /// Only start pending tasks that have queued work (JIT defers empty
    /// aggregators "while retaining their priority").
    pub start_only_with_work: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            capacity: 64,
            delta_tick: crate::sim::secs(0.5),
            start_only_with_work: true,
        }
    }
}

/// Incremental per-job container-seconds (O(1) usage queries for the
/// cross-job arbitration policies; the ledger stays the reporting truth).
#[derive(Clone, Copy, Debug, Default)]
struct JobUsage {
    closed_cs: f64,
    open_count: u64,
    /// Σ start times of the job's live deployments, so charging them up
    /// to `now` is `open_count·now − open_starts_sum`.
    open_starts_sum: Time,
}

impl JobUsage {
    fn cs(&self, now: Time) -> f64 {
        self.closed_cs
            + crate::sim::to_secs((self.open_count * now).saturating_sub(self.open_starts_sum))
    }
}

#[derive(Debug)]
pub struct Cluster {
    pub cfg: ClusterConfig,
    tasks: Vec<Task>,
    ledger: Vec<Deployment>,
    next_token: u64,
    /// token -> task resolution for in-flight phase-end events.
    token_owner: Vec<TaskId>,
    /// Startable pending tasks ordered by (priority, id) — O(log n) ticks
    /// instead of scanning every task ever submitted (DESIGN.md §Perf L3).
    pending_idx: BTreeSet<(Priority, TaskId)>,
    /// Preemptible (Running/Idle) tasks by (priority, id).
    active_idx: BTreeSet<(Priority, TaskId)>,
    /// Live container count (capacity checks without scanning).
    deployed: usize,
    /// Per-job incremental container-seconds (arbitration input).
    usage: Vec<JobUsage>,
    /// Per-job fair-share weights (broker SLO classes; 1.0 default).
    weights: Vec<f64>,
    /// Cross-job arbitration policy; `None` = §5.5 deadline-priority order.
    policy: Option<Box<dyn ArbitrationPolicy>>,
    /// Every preemption decision `(when, victim)` in the order it was
    /// made — the determinism pin for arbitration-aware preemption.
    preemptions: Vec<(Time, TaskId)>,
}

impl Cluster {
    pub fn new(cfg: ClusterConfig) -> Self {
        Cluster {
            cfg,
            tasks: Vec::new(),
            ledger: Vec::new(),
            next_token: 0,
            token_owner: Vec::new(),
            pending_idx: BTreeSet::new(),
            active_idx: BTreeSet::new(),
            deployed: 0,
            usage: Vec::new(),
            weights: Vec::new(),
            policy: None,
            preemptions: Vec::new(),
        }
    }

    fn ensure_job(&mut self, job: usize) {
        if job >= self.usage.len() {
            self.usage.resize(job + 1, JobUsage::default());
            self.weights.resize(job + 1, 1.0);
        }
    }

    /// Install a cross-job arbitration policy (broker control plane):
    /// pending starts *and* preemption victims then follow the policy
    /// (`pick` / `preempt_victim`). `DeadlinePriority` reproduces the
    /// no-policy §5.5 scheduler exactly, on both sides of the decision.
    pub fn set_policy(&mut self, policy: Box<dyn ArbitrationPolicy>) {
        self.policy = Some(policy);
    }

    /// Preemption decisions `(time, victim task)` in decision order —
    /// deterministic for a given seed + trace + policy (pinned by the
    /// broker's policy-determinism tests).
    pub fn preemption_log(&self) -> &[(Time, TaskId)] {
        &self.preemptions
    }

    /// Fair-share weight for a job (broker SLO class; ignored unless a
    /// weight-aware policy is installed).
    pub fn set_job_weight(&mut self, job: usize, weight: f64) {
        self.ensure_job(job);
        self.weights[job] = if weight > 0.0 { weight } else { 1.0 };
    }

    /// Container-seconds charged to `job` so far — O(1), incremental.
    pub fn job_usage_cs(&self, job: usize, now: Time) -> f64 {
        self.usage.get(job).map_or(0.0, |u| u.cs(now))
    }

    /// Recompute a task's membership in the scheduler indices after any
    /// phase/work/priority mutation.
    fn reindex(&mut self, task: TaskId) {
        let t = &self.tasks[task];
        let want_pending = t.phase == Phase::Pending
            && (!self.cfg.start_only_with_work || !t.work.is_empty());
        let want_active = matches!(t.phase, Phase::Running | Phase::Idle);
        let key = (t.spec.priority, task);
        let old_p = self.tasks[task].pending_key;
        if old_p != want_pending.then_some(key) {
            if let Some(k) = old_p {
                self.pending_idx.remove(&k);
            }
            if want_pending {
                self.pending_idx.insert(key);
            }
            self.tasks[task].pending_key = want_pending.then_some(key);
        }
        let old_a = self.tasks[task].active_key;
        if old_a != want_active.then_some(key) {
            if let Some(k) = old_a {
                self.active_idx.remove(&k);
            }
            if want_active {
                self.active_idx.insert(key);
            }
            self.tasks[task].active_key = want_active.then_some(key);
        }
    }

    // ------------------------------------------------------------------
    // queries
    // ------------------------------------------------------------------

    pub fn phase(&self, task: TaskId) -> Phase {
        self.tasks[task].phase
    }

    pub fn pending_work(&self, task: TaskId) -> usize {
        self.tasks[task].work.len()
    }

    pub fn deployments_of(&self, task: TaskId) -> u32 {
        self.tasks[task].deployments
    }

    pub fn deployed_count(&self) -> usize {
        self.deployed
    }

    pub fn has_capacity(&self) -> bool {
        self.deployed_count() < self.cfg.capacity
    }

    pub fn ledger(&self) -> &[Deployment] {
        &self.ledger
    }

    /// Total container-seconds attributed to `job` (§6.2). Open deployments
    /// are charged up to `now`.
    pub fn container_seconds(&self, job: usize, now: Time) -> f64 {
        self.ledger
            .iter()
            .filter(|d| d.job == job)
            .map(|d| crate::sim::to_secs(d.end.unwrap_or(now).saturating_sub(d.start)))
            .sum()
    }

    /// Container-seconds across all jobs.
    pub fn total_container_seconds(&self, now: Time) -> f64 {
        self.ledger
            .iter()
            .map(|d| crate::sim::to_secs(d.end.unwrap_or(now).saturating_sub(d.start)))
            .sum()
    }

    // ------------------------------------------------------------------
    // task lifecycle
    // ------------------------------------------------------------------

    /// Register a task (Pending). It will start at a tick, or immediately
    /// via `force_start`.
    pub fn submit(&mut self, spec: TaskSpec) -> TaskId {
        let id = self.tasks.len();
        self.ensure_job(spec.job);
        self.tasks.push(Task {
            spec,
            phase: Phase::Pending,
            work: VecDeque::new(),
            queued_time: 0,
            token: u64::MAX,
            pending_since: None,
            finish_requested: false,
            preempting: false,
            deployments: 0,
            live_deployment: None,
            work_done: 0,
            pending_key: None,
            active_key: None,
        });
        self.reindex(id);
        id
    }

    /// Append work items (one per update merge; duration = t_pair / C_agg).
    pub fn push_work(&mut self, q: &mut EventQueue, task: TaskId, items: &[Time]) {
        self.tasks[task].work.extend(items.iter().copied());
        self.tasks[task].queued_time += items.iter().sum::<Time>();
        // Work arriving at a Pending task makes it startable: the aging
        // clock for arbitration starts now (first work only).
        if self.tasks[task].phase == Phase::Pending
            && !items.is_empty()
            && self.tasks[task].pending_since.is_none()
        {
            self.tasks[task].pending_since = Some(q.now());
        }
        // An idle (kept-alive) container picks work up immediately.
        if self.tasks[task].phase == Phase::Idle && !items.is_empty() {
            self.begin_next_work(q, task);
        }
        self.reindex(task);
    }

    /// Ask the task to checkpoint + exit once its queue drains.
    pub fn request_finish(&mut self, q: &mut EventQueue, task: TaskId) {
        let t = &mut self.tasks[task];
        t.finish_requested = true;
        if t.phase == Phase::Idle {
            self.begin_checkpoint(q, task, false);
        }
        self.reindex(task);
    }

    /// Adjust priority (JIT re-estimates as updates arrive).
    pub fn set_priority(&mut self, task: TaskId, priority: Priority) {
        self.tasks[task].spec.priority = priority;
        self.reindex(task);
    }

    /// δ-tick: start pending tasks while capacity lasts — in §5.5 priority
    /// order, or by the installed arbitration policy — then, at capacity,
    /// preempt a victim: the §5.5 latest-deadline task when no policy is
    /// installed, otherwise whoever the policy's `preempt_victim` names.
    pub fn on_tick(&mut self, q: &mut EventQueue) {
        if self.policy.is_some() {
            self.on_tick_arbitrated(q);
            return;
        }
        loop {
            let Some(best) = self.best_pending() else { break };
            if self.has_capacity() {
                self.deploy(q, best);
                continue;
            }
            // Preempt the worst-priority preemptible task if strictly worse.
            let Some(victim) = self.worst_running() else { break };
            if self.tasks[victim].spec.priority <= self.tasks[best].spec.priority {
                break;
            }
            self.begin_checkpoint(q, victim, true);
            // Capacity frees only when the victim's checkpoint completes;
            // the pending task starts on a later tick.
            break;
        }
    }

    /// δ-tick with an arbitration policy installed: the policy picks which
    /// startable pending task deploys into each free slot.
    fn on_tick_arbitrated(&mut self, q: &mut EventQueue) {
        let mut policy = self.policy.take().expect("checked by on_tick");
        let now = q.now();
        // Loop-invariant within one tick: a deploy at `now` removes
        // exactly the picked task from the pending set and charges zero
        // container-seconds at `now`, so the snapshot and usage vector
        // are computed once instead of once per filled slot.
        let mut candidates = self.startable_candidates(now);
        let usage_cs: Vec<f64> = self.usage.iter().map(|u| u.cs(now)).collect();
        loop {
            if candidates.is_empty() {
                break;
            }
            if self.has_capacity() {
                let view = ArbitrationView {
                    now,
                    candidates: &candidates,
                    usage_cs: &usage_cs,
                    weights: &self.weights,
                };
                let Some(task) = policy.pick(&view) else { break };
                let at = candidates
                    .iter()
                    .position(|c| c.task == task)
                    .unwrap_or_else(|| {
                        panic!("arbitration policy picked non-candidate task {task}")
                    });
                candidates.remove(at);
                debug_assert!(self.tasks[task].pending_key.is_some());
                self.deploy(q, task);
                continue;
            }
            // At capacity: the policy names the intruder (who should run)
            // and the victim (who gets evicted) — arbitration-aware
            // preemption, not hard-coded deadline order.
            let intruder_view = ArbitrationView {
                now,
                candidates: &candidates,
                usage_cs: &usage_cs,
                weights: &self.weights,
            };
            let Some(want) = policy.pick(&intruder_view) else { break };
            let Some(intruder) = candidates.iter().find(|c| c.task == want).copied()
            else {
                break;
            };
            let running = self.preemptible_candidates(now);
            let victim_view = ArbitrationView {
                now,
                candidates: &running,
                usage_cs: &usage_cs,
                weights: &self.weights,
            };
            let Some(victim) = policy.preempt_victim(&victim_view, Some(&intruder))
            else {
                break;
            };
            self.begin_checkpoint(q, victim, true);
            // Capacity frees only when the victim's checkpoint completes;
            // the pending task starts on a later tick.
            break;
        }
        self.policy = Some(policy);
    }

    /// Snapshot of startable pending tasks in ascending (priority, id)
    /// order — the arbitration policies' candidate list. O(pending) via
    /// the incremental `queued_time` counters (no deque re-summing).
    fn startable_candidates(&self, now: Time) -> Vec<Candidate> {
        self.pending_idx
            .iter()
            .map(|&(priority, task)| {
                let t = &self.tasks[task];
                Candidate {
                    task,
                    job: t.spec.job,
                    priority,
                    queued_secs: crate::sim::to_secs(t.queued_time),
                    waited_secs: crate::sim::to_secs(
                        now.saturating_sub(t.pending_since.unwrap_or(now)),
                    ),
                }
            })
            .collect()
    }

    /// Snapshot of preemptible (Running/Idle) tasks in ascending
    /// (priority, id) order — the candidate list for
    /// `ArbitrationPolicy::preempt_victim`. Running tasks are never
    /// "waiting startable", so `waited_secs` is 0.
    fn preemptible_candidates(&self, _now: Time) -> Vec<Candidate> {
        self.active_idx
            .iter()
            .map(|&(priority, task)| {
                let t = &self.tasks[task];
                Candidate {
                    task,
                    job: t.spec.job,
                    priority,
                    queued_secs: crate::sim::to_secs(t.queued_time),
                    waited_secs: 0.0,
                }
            })
            .collect()
    }

    /// Pick a preemption victim for a FORCE_TRIGGER deploy: the policy's
    /// unconditional choice when one is installed, the §5.5
    /// latest-deadline task otherwise.
    fn forced_victim(&mut self, now: Time) -> Option<TaskId> {
        let mut policy = self.policy.take()?;
        let running = self.preemptible_candidates(now);
        let usage_cs: Vec<f64> = self.usage.iter().map(|u| u.cs(now)).collect();
        let view = ArbitrationView {
            now,
            candidates: &running,
            usage_cs: &usage_cs,
            weights: &self.weights,
        };
        let victim = policy.preempt_victim(&view, None);
        self.policy = Some(policy);
        victim
    }

    /// FORCE_TRIGGER (Fig 6 line 21): deadline reached — deploy now,
    /// preempting if necessary.
    pub fn force_start(&mut self, q: &mut EventQueue, task: TaskId) {
        if self.tasks[task].phase != Phase::Pending {
            return;
        }
        if !self.has_capacity() {
            let victim = if self.policy.is_some() {
                self.forced_victim(q.now())
            } else {
                self.worst_running()
            };
            if let Some(victim) = victim {
                if victim != task {
                    self.begin_checkpoint(q, victim, true);
                }
            }
        }
        // Deploy regardless — force means the deadline is *now*; momentary
        // over-capacity while the victim checkpoints is accepted (matches
        // Kubernetes behaviour of starting a pod while another terminates).
        self.deploy(q, task);
    }

    fn best_pending(&self) -> Option<TaskId> {
        self.pending_idx.iter().next().map(|&(_, t)| t)
    }

    fn worst_running(&self) -> Option<TaskId> {
        self.active_idx.iter().next_back().map(|&(_, t)| t)
    }

    fn new_token(&mut self, task: TaskId) -> u64 {
        let tok = self.next_token;
        self.next_token += 1;
        self.token_owner.push(task);
        tok
    }

    fn schedule_phase_end(&mut self, q: &mut EventQueue, task: TaskId, dur: Time) {
        let tok = self.new_token(task);
        self.tasks[task].token = tok;
        q.schedule_in(
            dur,
            EventKind::ContainerDone {
                container: tok as usize,
            },
        );
    }

    fn deploy(&mut self, q: &mut EventQueue, task: TaskId) {
        let now = q.now();
        let t = &mut self.tasks[task];
        debug_assert_eq!(t.phase, Phase::Pending);
        t.phase = Phase::Starting;
        t.deployments += 1;
        t.preempting = false;
        t.pending_since = None;
        let job = t.spec.job;
        let dep = Deployment {
            job,
            task,
            start: now,
            end: None,
        };
        let dur = t.spec.cold_start + t.spec.state_load;
        self.ledger.push(dep);
        self.deployed += 1;
        self.usage[job].open_count += 1;
        self.usage[job].open_starts_sum += now;
        self.tasks[task].live_deployment = Some(self.ledger.len() - 1);
        self.schedule_phase_end(q, task, dur);
        self.reindex(task);
    }

    fn begin_next_work(&mut self, q: &mut EventQueue, task: TaskId) {
        let t = &mut self.tasks[task];
        debug_assert!(!t.work.is_empty());
        t.phase = Phase::Running;
        let dur = t.work[0];
        self.schedule_phase_end(q, task, dur);
        self.reindex(task);
    }

    fn begin_checkpoint(&mut self, q: &mut EventQueue, task: TaskId, preempting: bool) {
        if preempting {
            self.preemptions.push((q.now(), task));
        }
        let dur = self.tasks[task].spec.checkpoint;
        let t = &mut self.tasks[task];
        t.phase = Phase::Checkpointing;
        t.preempting = preempting;
        self.schedule_phase_end(q, task, dur);
        self.reindex(task);
    }

    fn end_deployment(&mut self, now: Time, task: TaskId) {
        if let Some(di) = self.tasks[task].live_deployment.take() {
            self.ledger[di].end = Some(now);
            self.deployed -= 1;
            let (job, start) = (self.ledger[di].job, self.ledger[di].start);
            let u = &mut self.usage[job];
            u.open_count -= 1;
            u.open_starts_sum -= start;
            u.closed_cs += crate::sim::to_secs(now - start);
        }
    }

    /// Advance the task owning `token` past its completed phase.
    /// Returns None for stale tokens (preempted/rescheduled phases).
    pub fn advance(&mut self, q: &mut EventQueue, token: usize) -> Option<Notification> {
        let task = *self.token_owner.get(token)?;
        if self.tasks[task].token != token as u64 {
            return None; // stale
        }
        let now = q.now();
        let note = match self.tasks[task].phase {
            Phase::Starting => {
                if !self.tasks[task].work.is_empty() {
                    self.begin_next_work(q, task);
                } else if self.tasks[task].finish_requested && !self.tasks[task].spec.keep_alive {
                    self.begin_checkpoint(q, task, false);
                } else {
                    self.tasks[task].phase = Phase::Idle;
                }
                Some(Notification::Deployed { task })
            }
            Phase::Running => {
                if let Some(d) = self.tasks[task].work.pop_front() {
                    self.tasks[task].queued_time -= d;
                }
                self.tasks[task].work_done += 1;
                if !self.tasks[task].work.is_empty() {
                    self.begin_next_work(q, task);
                    Some(Notification::WorkItemDone { task })
                } else if self.tasks[task].finish_requested && !self.tasks[task].spec.keep_alive {
                    self.begin_checkpoint(q, task, false);
                    Some(Notification::WorkItemDone { task })
                } else {
                    self.tasks[task].phase = Phase::Idle;
                    Some(Notification::WorkDrained { task })
                }
            }
            Phase::Checkpointing => {
                self.end_deployment(now, task);
                if self.tasks[task].preempting {
                    self.tasks[task].phase = Phase::Pending;
                    self.tasks[task].preempting = false;
                    // aging restarts from the preemption instant
                    self.tasks[task].pending_since = Some(now);
                    Some(Notification::TaskPreempted { task })
                } else {
                    self.tasks[task].phase = Phase::Done;
                    Some(Notification::TaskExited { task })
                }
            }
            _ => None,
        };
        self.reindex(task);
        note
    }

    /// Work items completed by a task (monotone; conserved across preemption).
    pub fn work_done(&self, task: TaskId) -> u64 {
        self.tasks[task].work_done
    }

    /// Owning job of a task (event routing in the multi-job platform).
    pub fn job_of(&self, task: TaskId) -> usize {
        self.tasks[task].spec.job
    }

    /// Cancel a still-Pending task (JIT shard that never received work).
    /// No deployment, no cost. Returns false if the task already started.
    pub fn cancel(&mut self, task: TaskId) -> bool {
        if self.tasks[task].phase == Phase::Pending {
            self.tasks[task].phase = Phase::Done;
            self.reindex(task);
            true
        } else {
            false
        }
    }

    /// Total merges completed by all of a job's tasks.
    pub fn job_work_done(&self, job: usize) -> u64 {
        self.tasks
            .iter()
            .filter(|t| t.spec.job == job)
            .map(|t| t.work_done)
            .sum()
    }

    /// Deployments (container incarnations) attributed to a job.
    pub fn job_deployments(&self, job: usize) -> u64 {
        self.ledger.iter().filter(|d| d.job == job).count() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{secs, to_secs};

    fn spec(job: usize, priority: Priority) -> TaskSpec {
        TaskSpec {
            job,
            round: 0,
            priority,
            cold_start: secs(0.3),
            state_load: secs(0.2),
            checkpoint: secs(0.2),
            keep_alive: false,
        }
    }

    /// Drive all events, collecting notifications. Ticks the scheduler
    /// after every event so pending tasks get started as capacity frees.
    fn drain(c: &mut Cluster, q: &mut EventQueue) -> Vec<Notification> {
        let mut notes = Vec::new();
        while let Some((_, ev)) = q.next() {
            match ev {
                EventKind::ContainerDone { container } => {
                    if let Some(n) = c.advance(q, container) {
                        notes.push(n);
                    }
                }
                EventKind::SchedTick => {
                    c.on_tick(q);
                }
                _ => {}
            }
            c.on_tick(q);
        }
        notes
    }

    #[test]
    fn lifecycle_and_ledger() {
        let mut q = EventQueue::new();
        let mut c = Cluster::new(ClusterConfig::default());
        let t = c.submit(spec(0, 100));
        c.push_work(&mut q, t, &[secs(1.0), secs(1.0)]);
        c.request_finish(&mut q, t);
        c.force_start(&mut q, t);
        let notes = drain(&mut c, &mut q);
        assert!(notes.contains(&Notification::Deployed { task: t }));
        assert!(notes.contains(&Notification::TaskExited { task: t }));
        assert_eq!(c.phase(t), Phase::Done);
        assert_eq!(c.work_done(t), 2);
        // 0.5 start + 2.0 work + 0.2 checkpoint
        let cs = c.container_seconds(0, q.now());
        assert!((cs - 2.7).abs() < 1e-6, "cs={cs}");
        assert_eq!(c.ledger().len(), 1);
        assert!(c.ledger()[0].end.is_some());
    }

    #[test]
    fn keep_alive_idles_instead_of_exiting() {
        let mut q = EventQueue::new();
        let mut c = Cluster::new(ClusterConfig::default());
        let mut s = spec(0, 10);
        s.keep_alive = true;
        let t = c.submit(s);
        c.push_work(&mut q, t, &[secs(1.0)]);
        c.force_start(&mut q, t);
        drain(&mut c, &mut q);
        assert_eq!(c.phase(t), Phase::Idle);
        // still accruing container time
        let cs_now = c.container_seconds(0, q.now() + secs(10.0));
        assert!(cs_now > to_secs(secs(11.0)) - 1e-6, "cs={cs_now}");
        // new work wakes it without a new deployment
        c.push_work(&mut q, t, &[secs(0.5)]);
        drain(&mut c, &mut q);
        assert_eq!(c.deployments_of(t), 1);
        assert_eq!(c.work_done(t), 2);
    }

    #[test]
    fn tick_starts_by_priority_under_capacity() {
        let mut q = EventQueue::new();
        let mut c = Cluster::new(ClusterConfig {
            capacity: 1,
            ..Default::default()
        });
        let lo = c.submit(spec(0, 1000));
        let hi = c.submit(spec(1, 10));
        c.push_work(&mut q, lo, &[secs(1.0)]);
        c.push_work(&mut q, hi, &[secs(1.0)]);
        c.on_tick(&mut q);
        assert_eq!(c.phase(hi), Phase::Starting);
        assert_eq!(c.phase(lo), Phase::Pending);
    }

    #[test]
    fn start_only_with_work_defers_empty_tasks() {
        let mut q = EventQueue::new();
        let mut c = Cluster::new(ClusterConfig::default());
        let t = c.submit(spec(0, 1));
        c.on_tick(&mut q);
        assert_eq!(c.phase(t), Phase::Pending, "empty task must stay deferred");
        c.push_work(&mut q, t, &[secs(1.0)]);
        c.on_tick(&mut q);
        assert_eq!(c.phase(t), Phase::Starting);
    }

    #[test]
    fn preemption_conserves_work() {
        let mut q = EventQueue::new();
        let mut c = Cluster::new(ClusterConfig {
            capacity: 1,
            ..Default::default()
        });
        let lo = c.submit(spec(0, 1000));
        c.push_work(&mut q, lo, &[secs(5.0), secs(5.0), secs(5.0)]);
        c.on_tick(&mut q);
        // run until the low-priority task starts its first item
        for _ in 0..2 {
            if let Some((_, EventKind::ContainerDone { container })) = q.next() {
                c.advance(&mut q, container);
            }
        }
        assert_eq!(c.phase(lo), Phase::Running);
        // a high-priority task arrives and forces in
        let hi = c.submit(spec(1, 1));
        c.push_work(&mut q, hi, &[secs(1.0)]);
        c.request_finish(&mut q, hi);
        c.on_tick(&mut q); // preempts lo (begins checkpoint)
        assert_eq!(c.phase(lo), Phase::Checkpointing);
        let notes = drain(&mut c, &mut q);
        assert!(notes.contains(&Notification::TaskPreempted { task: lo }));
        assert!(notes.contains(&Notification::TaskExited { task: hi }));
        // lo conserved: the ticking drain redeployed it after hi freed
        // capacity and it completed all 3 items (the interrupted one redone)
        assert_eq!(c.phase(lo), Phase::Idle);
        assert_eq!(c.work_done(lo), 3);
        c.request_finish(&mut q, lo);
        drain(&mut c, &mut q);
        assert_eq!(c.phase(lo), Phase::Done);
        assert_eq!(c.deployments_of(lo), 2);
    }

    #[test]
    fn force_start_preempts_worst() {
        let mut q = EventQueue::new();
        let mut c = Cluster::new(ClusterConfig {
            capacity: 1,
            ..Default::default()
        });
        let lo = c.submit(spec(0, 1000));
        c.push_work(&mut q, lo, &[secs(50.0)]);
        c.on_tick(&mut q);
        while c.phase(lo) != Phase::Running {
            if let Some((_, EventKind::ContainerDone { container })) = q.next() {
                c.advance(&mut q, container);
            } else {
                break;
            }
        }
        let hi = c.submit(spec(1, 1));
        c.push_work(&mut q, hi, &[secs(1.0)]);
        c.force_start(&mut q, hi);
        assert_eq!(c.phase(hi), Phase::Starting);
        assert_eq!(c.phase(lo), Phase::Checkpointing);
    }

    #[test]
    fn ledger_conservation_property() {
        // Σ per-job container-seconds == total, and every closed deployment
        // has end >= start.
        crate::util::prop::check("ledger-conservation", 32, |g| {
            let mut q = EventQueue::new();
            let mut c = Cluster::new(ClusterConfig {
                capacity: g.usize(1, 4),
                ..Default::default()
            });
            let njobs = g.usize(1, 5);
            let ntasks = g.usize(1, 10);
            for i in 0..ntasks {
                let job = i % njobs;
                let t = c.submit(spec(job, g.int(0, 1000) as Priority));
                let items: Vec<Time> =
                    (0..g.usize(1, 5)).map(|_| crate::sim::secs(g.f64(0.1, 2.0))).collect();
                c.push_work(&mut q, t, &items);
                c.request_finish(&mut q, t);
            }
            for _ in 0..200 {
                c.on_tick(&mut q);
                let Some((_, ev)) = q.next() else { break };
                if let EventKind::ContainerDone { container } = ev {
                    c.advance(&mut q, container);
                }
            }
            // drive to completion
            while let Some((_, ev)) = q.next() {
                if let EventKind::ContainerDone { container } = ev {
                    c.advance(&mut q, container);
                }
                c.on_tick(&mut q);
            }
            let now = q.now();
            let total = c.total_container_seconds(now);
            let per_job: f64 = (0..njobs).map(|j| c.container_seconds(j, now)).sum();
            crate::prop_assert!(
                crate::util::prop::close(total, per_job, 1e-9),
                "total {total} != sum {per_job}"
            );
            for d in c.ledger() {
                if let Some(e) = d.end {
                    crate::prop_assert!(e >= d.start, "deployment ends before start");
                }
            }
            // incremental per-job usage must agree with the ledger scan
            for j in 0..njobs {
                crate::prop_assert!(
                    crate::util::prop::close(
                        c.job_usage_cs(j, now),
                        c.container_seconds(j, now),
                        1e-9
                    ),
                    "incremental usage diverged from ledger for job {j}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn candidates_carry_waited_secs_for_aging() {
        // a probe policy records the waited_secs the cluster reports —
        // pins the pending_since plumbing behind arbitration aging
        #[derive(Debug)]
        struct Probe {
            seen: std::sync::Arc<std::sync::Mutex<Vec<f64>>>,
        }
        impl crate::broker::arbitration::ArbitrationPolicy for Probe {
            fn name(&self) -> &'static str {
                "probe"
            }
            fn pick(
                &mut self,
                view: &crate::broker::arbitration::ArbitrationView,
            ) -> Option<usize> {
                let mut seen = self.seen.lock().unwrap();
                for c in view.candidates {
                    seen.push(c.waited_secs);
                }
                view.candidates.first().map(|c| c.task)
            }
        }
        let seen = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut q = EventQueue::new();
        let mut c = Cluster::new(ClusterConfig {
            capacity: 2,
            ..Default::default()
        });
        c.set_policy(Box::new(Probe {
            seen: std::sync::Arc::clone(&seen),
        }));
        let busy = c.submit(spec(0, 1));
        c.push_work(&mut q, busy, &[secs(30.0)]);
        c.on_tick(&mut q); // deploys `busy`; `waiter` not submitted yet
        let waiter = c.submit(spec(1, 2));
        c.push_work(&mut q, waiter, &[secs(1.0)]); // pending_since = now (0)
        // advance virtual time via a far event, then tick again
        q.schedule_at(secs(4.0), EventKind::Custom { tag: 0 });
        while let Some((t, _)) = q.next() {
            if t >= secs(4.0) {
                break;
            }
        }
        c.on_tick(&mut q);
        let seen = seen.lock().unwrap();
        assert!(
            seen.iter().any(|&w| w >= 4.0),
            "waiter must report ≥4s waited, saw {seen:?}"
        );
    }

    #[test]
    fn incremental_usage_charges_open_deployments() {
        let mut q = EventQueue::new();
        let mut c = Cluster::new(ClusterConfig::default());
        let t = c.submit(spec(0, 10));
        c.push_work(&mut q, t, &[secs(5.0)]);
        c.force_start(&mut q, t);
        // container still open: usage charged up to `now`, like the ledger
        let later = q.now() + secs(2.0);
        assert!(
            (c.job_usage_cs(0, later) - c.container_seconds(0, later)).abs() < 1e-9
        );
        assert!(c.job_usage_cs(0, later) > 1.9);
    }

    #[test]
    fn deadline_policy_matches_default_tick_order() {
        // DeadlinePriority must reproduce the §5.5 baseline exactly: same
        // deployments, same ledger, same phases on an identical workload.
        use crate::broker::arbitration::DeadlinePriority;
        let run = |with_policy: bool| {
            let mut q = EventQueue::new();
            let mut c = Cluster::new(ClusterConfig {
                capacity: 2,
                ..Default::default()
            });
            if with_policy {
                c.set_policy(Box::new(DeadlinePriority));
            }
            for i in 0..6usize {
                let t = c.submit(spec(i % 3, (i as Priority) * 31 % 7));
                c.push_work(&mut q, t, &[secs(0.7), secs(0.4)]);
                c.request_finish(&mut q, t);
            }
            c.on_tick(&mut q); // seed the first deployments
            let notes = drain(&mut c, &mut q);
            let ledger: Vec<(usize, Time, Option<Time>)> = c
                .ledger()
                .iter()
                .map(|d| (d.job, d.start, d.end))
                .collect();
            (notes, ledger, q.now())
        };
        let (n0, l0, t0) = run(false);
        let (n1, l1, t1) = run(true);
        assert_eq!(n0, n1, "notifications diverged");
        assert_eq!(l0, l1, "ledger diverged");
        assert_eq!(t0, t1, "clock diverged");
    }

    #[test]
    fn policy_chooses_the_preemption_victim() {
        // An overserved job's *earlier-deadline* running task: the §5.5
        // baseline (and DeadlinePriority) refuses to preempt it for a
        // later-deadline newcomer, while wfs evicts it — preemption order
        // is the policy's call now, not hard-coded deadline order.
        use crate::broker::arbitration::{DeadlinePriority, WeightedFairShare};
        let run = |wfs: bool| {
            let mut q = EventQueue::new();
            let mut c = Cluster::new(ClusterConfig {
                capacity: 1,
                ..Default::default()
            });
            if wfs {
                c.set_policy(Box::new(WeightedFairShare::default()));
            } else {
                c.set_policy(Box::new(DeadlinePriority));
            }
            let hog = c.submit(spec(0, 10)); // earliest deadline, job 0
            c.push_work(&mut q, hog, &[secs(30.0)]);
            c.on_tick(&mut q);
            while c.phase(hog) != Phase::Running {
                let Some((_, EventKind::ContainerDone { container })) = q.next() else {
                    panic!("hog never deployed");
                };
                c.advance(&mut q, container);
            }
            // an underserved job's later-deadline task arrives
            let newcomer = c.submit(spec(1, 1000));
            c.push_work(&mut q, newcomer, &[secs(1.0)]);
            // advance virtual time so job 0 accrues container-seconds
            q.schedule_at(secs(10.0), EventKind::Custom { tag: 0 });
            while let Some((t, _)) = q.next() {
                if t >= secs(10.0) {
                    break;
                }
            }
            c.on_tick(&mut q);
            (c.phase(hog), c.preemption_log().to_vec())
        };
        let (phase_deadline, log_deadline) = run(false);
        assert_eq!(
            phase_deadline,
            Phase::Running,
            "deadline policy must not evict the earlier-deadline task"
        );
        assert!(log_deadline.is_empty());
        let (phase_wfs, log_wfs) = run(true);
        assert_eq!(
            phase_wfs,
            Phase::Checkpointing,
            "wfs must evict the overserved tenant's task"
        );
        assert_eq!(log_wfs, vec![(secs(10.0), 0)], "preemption logged");
    }

    #[test]
    fn wfs_policy_balances_jobs_under_scarcity() {
        // Two jobs, one slot: job 0's tasks all have earlier deadlines, but
        // after job 0 consumes container time the weighted-fair-share
        // policy must alternate to job 1 instead of draining job 0 first.
        use crate::broker::arbitration::WeightedFairShare;
        let mut q = EventQueue::new();
        let mut c = Cluster::new(ClusterConfig {
            capacity: 1,
            ..Default::default()
        });
        c.set_policy(Box::new(WeightedFairShare::default()));
        let mut tasks = Vec::new();
        for i in 0..4usize {
            // job 0 gets priorities 0..1, job 1 gets 100.. — deadline
            // order would run both job-0 tasks first
            let job = i % 2;
            let t = c.submit(spec(job, (job as Priority) * 100 + i as Priority));
            c.push_work(&mut q, t, &[secs(1.0)]);
            c.request_finish(&mut q, t);
            tasks.push(t);
        }
        c.on_tick(&mut q); // seed the first deployment
        let _ = drain(&mut c, &mut q);
        // all four ran to completion under the policy
        for &t in &tasks {
            assert_eq!(c.phase(t), Phase::Done);
        }
        // deployment order from the ledger: after job 0's first container
        // accrues time, job 1 must get the next slot (usage 0 beats >0)
        let order: Vec<usize> = c.ledger().iter().map(|d| d.job).collect();
        assert_eq!(order.len(), 4);
        assert_eq!(
            &order[..2],
            &[0, 1],
            "fair share must alternate jobs, got {order:?}"
        );
    }
}
