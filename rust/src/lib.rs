//! # fljit — Just-in-Time Aggregation for Federated Learning
//!
//! Full-system reproduction of *"Just-in-Time Aggregation for Federated
//! Learning"* (Jayaram, Verma, Thomas, Muthusamy — IBM Research AI, 2022):
//! a cloud-hosted FL aggregation platform in which aggregators are **not**
//! always-on. The platform predicts when each party's model update will
//! arrive (periodicity + linearity of training times, §4), estimates the
//! aggregation time (§5.4), and defers aggregation until `t_rnd − t_agg`
//! with an opportunistic priority and a hard deadline timer (§5.5).
//!
//! Three-layer architecture (DESIGN.md):
//! * **L1** Pallas kernels (python, build-time): fused update merging.
//! * **L2** JAX graphs (python, build-time): fusion entry points + the MLP
//!   local-training substrate, AOT-lowered to HLO text in `artifacts/`.
//! * **L3** this crate: the coordinator — strategies, JIT scheduler,
//!   serverless cluster, message queue, stores, party emulation, metrics —
//!   executing fusion through PJRT ([`runtime`]) or pure Rust ([`fusion`]).
//!
//! Python never runs on the request path.
//!
//! **Entry point**: [`coordinator::session::Session`] — the one
//! builder-style façade over simulation, live and wall-clock execution
//! (single jobs and broker job mixes alike), returning one unified
//! [`Report`](coordinator::session::Report) and a streaming
//! [`SessionEvent`](coordinator::session::SessionEvent) channel.

pub mod adapt;
pub mod bench;
pub mod broker;
pub mod cluster;
pub mod coordinator;
pub mod estimator;
pub mod fusion;
pub mod metrics;
pub mod model;
pub mod mq;
pub mod party;
pub mod runtime;
pub mod sim;
pub mod store;
pub mod telemetry;
pub mod util;
pub mod wal;
pub mod workloads;
