//! The Driver/Clock pair: one event-driven strategy implementation,
//! two time regimes.
//!
//! The platform's control loop is written against an [`EventQueue`] whose
//! clock is *advanced by popping events*. What differs between simulation
//! and live deployment is only **who is allowed to pop when**:
//!
//! * [`VirtualDriver`] — pops immediately; virtual time jumps from event
//!   to event. This is the Fig 7/8/9 grid regime (10k parties × 50 rounds
//!   in milliseconds of wall time).
//! * [`WallDriver`] — holds a [`Clock`] and an [`UpdateSource`]; before
//!   releasing the next queued event it *waits to that deadline* on the
//!   wall clock, waking early whenever a party publishes a model update
//!   into the zero-copy MQ. Fresh MQ messages are ingested as
//!   `UpdateArrival` events, so the same `Strategy` code observes live
//!   traffic exactly the way it observes simulated traffic.
//!
//! The wall driver *multiplexes jobs*: it keeps one topic watch per
//! admitted job ([`WallDriver::watch_round`] is keyed by job id), so N
//! concurrent live jobs share a single sleep-to-deadline loop — every
//! party publish, whichever job's topic it lands in, wakes the same
//! condvar and is routed to the owning engine as an `UpdateArrival`
//! tagged with its job id. `coordinator::live` drives one engine or a
//! whole broker-admitted job mix this way
//! (`Session::live()` / `Session::live().trace(..)`).
//!
//! [`JobEngine`] is the single-job state machine both regimes drive: round
//! estimation (§4–§5.4), arrival bookkeeping, estimator feeding, strategy
//! dispatch and round completion. `coordinator::platform` wraps a vector
//! of engines (multi-tenant, virtual time); `coordinator::live` wraps one
//! or more engines plus a real fusion data plane (wall time). The six
//! `Strategy` implementations run unmodified under either driver — that
//! is the whole point of the redesign.
//!
//! The engine also owns the **fault/degradation state machine**
//! ([`crate::party::FleetFaults`]): per-round fault-aware arrival draws,
//! the quorum floor + round-skip-on-starvation rules, the straggler
//! cutoff, and the [`StalePolicy`] routing of deadline-missers (drop vs
//! exponentially decayed fold). Both drivers call the same
//! `start_round`/`handle_update`, so sim and live degrade identically.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::adapt::{AdaptiveConfig, AdaptivePolicy};
use crate::cluster::{Cluster, Notification};
use crate::coordinator::job::{FlJobSpec, JobParams};
use crate::coordinator::strategies::{self, Ctx, StalePolicy, Strategy};
use crate::estimator::{
    estimate_round, LinearityModel, PeriodicityTracker, RoundEstimate,
};
use crate::metrics::RoundRecord;
use crate::mq::{self, CheckpointState, Message, MessageQueue, Payload};
use crate::party::{FaultState, Fleet, FleetFaults, RoundDraw};
use crate::sim::{secs, to_secs, EventKind, EventQueue, Time};
use crate::telemetry::{Registry, Scope, SpanKind};
use crate::util::rng::Rng;

// ---------------------------------------------------------------------------
// clocks
// ---------------------------------------------------------------------------

/// A source of time for a [`WallDriver`]. `Time` is µs since the clock's
/// epoch (job start), the same unit as the event queue's virtual clock.
pub trait Clock {
    fn now(&mut self) -> Time;

    /// Block until `t`, or until the MQ has seen a produce beyond `seen`
    /// (whichever first), and return the time actually reached. Virtual
    /// clocks jump straight to `t`.
    fn wait_until(&mut self, t: Time, mq: &MessageQueue, seen: u64) -> Time;
}

/// Mock wall clock for deterministic tests: never sleeps, jumps to every
/// requested deadline. A [`WallDriver`] over an `InstantClock` executes
/// the *live code path* (MQ ingest, wall pacing logic) in virtual time —
/// the sim/live equivalence tests are built on this.
#[derive(Clone, Copy, Debug, Default)]
pub struct InstantClock {
    now: Time,
}

impl Clock for InstantClock {
    fn now(&mut self) -> Time {
        self.now
    }

    fn wait_until(&mut self, t: Time, _mq: &MessageQueue, _seen: u64) -> Time {
        self.now = self.now.max(t);
        self.now
    }
}

/// Cloneable wall-time reference shared with party threads, so every
/// `enqueued_at` stamp in the MQ is on the same µs axis as the driver.
#[derive(Clone, Copy, Debug)]
pub struct WallTimer {
    start: Instant,
}

impl WallTimer {
    pub fn new() -> WallTimer {
        WallTimer {
            start: Instant::now(),
        }
    }

    pub fn now(&self) -> Time {
        self.start.elapsed().as_micros() as Time
    }

    /// Sleep this thread until wall time `t`.
    pub fn sleep_until(&self, t: Time) {
        let now = self.now();
        if t > now {
            std::thread::sleep(Duration::from_micros(t - now));
        }
    }
}

impl Default for WallTimer {
    fn default() -> Self {
        WallTimer::new()
    }
}

/// Real wall clock: sleeps on the MQ's produce condvar so a party's
/// publish wakes the driver immediately instead of at the next deadline.
#[derive(Clone, Copy, Debug)]
pub struct WallClock {
    pub timer: WallTimer,
}

impl WallClock {
    pub fn new() -> WallClock {
        WallClock {
            timer: WallTimer::new(),
        }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock::new()
    }
}

impl Clock for WallClock {
    fn now(&mut self) -> Time {
        self.timer.now()
    }

    fn wait_until(&mut self, t: Time, mq: &MessageQueue, seen: u64) -> Time {
        loop {
            let now = self.timer.now();
            if now >= t || mq.produced() > seen {
                return self.timer.now();
            }
            mq.wait_produce(seen, Duration::from_micros(t - now));
        }
    }
}

// ---------------------------------------------------------------------------
// drivers
// ---------------------------------------------------------------------------

/// The event source abstraction: where the control loop gets its next
/// event and how time passes before the event is released.
pub trait Driver {
    fn next_event(
        &mut self,
        q: &mut EventQueue,
        mq: &MessageQueue,
    ) -> Option<(Time, EventKind)>;
}

/// Virtual-time driver: pop immediately, the queue's clock jumps.
#[derive(Clone, Copy, Debug, Default)]
pub struct VirtualDriver;

impl Driver for VirtualDriver {
    fn next_event(
        &mut self,
        q: &mut EventQueue,
        _mq: &MessageQueue,
    ) -> Option<(Time, EventKind)> {
        q.next()
    }
}

/// Where a wall-clock run's model updates come from. The engine still
/// draws per-round arrival offsets (keeping its rng stream identical to
/// the simulator's); scripted sources publish at exactly those offsets,
/// thread-backed sources ignore them and publish when real local training
/// finishes.
pub trait UpdateSource {
    /// A round began for `job`: deliver the global model to `parties` (a
    /// subset on §5.5 resume — parties whose update already sits in the
    /// topic log are replayed from it, not re-trained). `offsets` is
    /// indexed by party id. Multi-job sources route publishes to
    /// `mq::update_topic(job, round)`; single-job sources receive 0.
    #[allow(clippy::too_many_arguments)]
    fn begin_round(
        &mut self,
        job: usize,
        round: u32,
        model: &Arc<Vec<f32>>,
        parties: &[usize],
        offsets: &[Time],
        now: Time,
        mq: &MessageQueue,
    ) -> Result<()>;

    /// Publish anything due at or before `now` (scripted sources; thread
    /// sources publish from their own threads and only surface failures
    /// here). An `Err` aborts the run with the source's failure attached.
    fn pump(&mut self, now: Time, mq: &MessageQueue) -> Result<()>;

    /// Earliest future publish, if statically known (scripted sources).
    /// `None` means "wait on the MQ condvar" (thread sources).
    fn next_due(&self) -> Option<Time>;

    /// True when this source will never publish again without a new
    /// `begin_round` — lets the driver distinguish "idle, waiting on real
    /// threads" from "nothing will ever happen".
    fn exhausted(&self) -> bool;

    /// A fatal party-side failure, if one occurred (thread sources set
    /// this when a party thread errors or dies unexpectedly).
    fn failure(&self) -> Option<String> {
        None
    }

    /// Stop party threads / drop pending publishes.
    fn shutdown(&mut self, _mq: &MessageQueue) {}
}

/// One job's topic-watch cursor inside a [`WallDriver`].
#[derive(Clone, Debug)]
struct RoundWatch {
    round: u32,
    /// Per-shard topic offsets up to which this round's messages were
    /// ingested (one entry on the unsharded plane).
    ingested: Vec<usize>,
}

/// Wall-clock driver: sleeps to the next deadline (queued event or
/// scripted publish), ingesting externally produced MQ updates as
/// `UpdateArrival` events the moment they land.
///
/// The driver watches one round topic *per job* — `watch_round(job, r)`
/// points job `job`'s cursor at `mq::update_topic(job, r)` — so several
/// concurrent live jobs multiplex over a single sleep/wake loop. Until a
/// job's first `watch_round` there is no topic to ingest for it
/// (prevents double-ingesting a resumed round's log).
pub struct WallDriver<C: Clock, S: UpdateSource> {
    pub clock: C,
    pub source: S,
    /// Per-job round watches, iterated in job order at each ingest.
    watches: std::collections::BTreeMap<usize, RoundWatch>,
    /// MQ produce counter at the last ingest (condvar wake threshold).
    seen: u64,
    /// Consecutive idle wait accumulated while neither the queue nor the
    /// source had a deadline (thread sources only); bail past the budget.
    idle: Duration,
    /// Watchdog for stalled thread sources.
    pub idle_budget: Duration,
    /// L1 aggregator shard count: >1 watches one topic per shard per job.
    shards: usize,
}

impl<C: Clock, S: UpdateSource> WallDriver<C, S> {
    pub fn new(clock: C, source: S) -> WallDriver<C, S> {
        WallDriver {
            clock,
            source,
            watches: std::collections::BTreeMap::new(),
            seen: 0,
            idle: Duration::ZERO,
            idle_budget: Duration::from_secs(60),
            shards: 1,
        }
    }

    /// Watch `n` per-shard topics per job instead of the flat round
    /// topic (the aggregator-tree data plane).
    pub fn with_shards(mut self, n: usize) -> WallDriver<C, S> {
        self.shards = n.max(1);
        self
    }

    /// Point `job`'s ingest cursor at a (new or resumed) round's topic.
    /// On resume the whole topic log replays into arrival events —
    /// exactly the §5.5 story: updates persist in the MQ across
    /// aggregator restarts, so a fresh deployment reconstructs the round
    /// from the log.
    pub fn watch_round(&mut self, job: usize, round: u32) {
        self.watches.insert(
            job,
            RoundWatch {
                round,
                ingested: vec![0; self.shards],
            },
        );
    }

    /// Stop watching a finished job's topics (its engine is done; any
    /// straggler re-publish is garbage-collected, not dispatched).
    pub fn unwatch(&mut self, job: usize) {
        self.watches.remove(&job);
    }

    /// Schedule `UpdateArrival` events for every not-yet-ingested message
    /// in every watched round topic. Events carry the message's enqueue
    /// time, so with an [`InstantClock`] and a scripted source the
    /// arrival times are bit-identical to the simulator's pre-scheduled
    /// ones.
    fn ingest(&mut self, q: &mut EventQueue, mq: &MessageQueue) {
        for (&job, w) in self.watches.iter_mut() {
            if self.shards <= 1 {
                let topic = mq::update_topic(job, w.round);
                loop {
                    let batch = mq.fetch(&topic, w.ingested[0], 64);
                    if batch.is_empty() {
                        break;
                    }
                    for m in &batch {
                        q.schedule_at(
                            m.enqueued_at,
                            EventKind::UpdateArrival {
                                job,
                                round: m.round,
                                party: m.party,
                            },
                        );
                    }
                    w.ingested[0] += batch.len();
                }
            } else {
                // Sharded plane: drain every shard topic, then schedule
                // the union in (enqueued_at, party) order — exactly the
                // order the flat topic interleaves same-µs publishes in
                // (the pump produces ascending by (due, job, party)), so
                // the engine's estimator sees an identical event stream
                // regardless of the shard count.
                let mut fresh: Vec<Message> = Vec::new();
                for s in 0..self.shards {
                    let topic = mq::shard_topic(job, w.round, s);
                    loop {
                        let batch = mq.fetch(&topic, w.ingested[s], 64);
                        if batch.is_empty() {
                            break;
                        }
                        w.ingested[s] += batch.len();
                        fresh.extend(batch);
                    }
                }
                fresh.sort_by_key(|m| (m.enqueued_at, m.party));
                for m in &fresh {
                    q.schedule_at(
                        m.enqueued_at,
                        EventKind::UpdateArrival {
                            job,
                            round: m.round,
                            party: m.party,
                        },
                    );
                }
            }
        }
        self.seen = mq.produced();
    }
}

impl<C: Clock, S: UpdateSource> Driver for WallDriver<C, S> {
    fn next_event(
        &mut self,
        q: &mut EventQueue,
        mq: &MessageQueue,
    ) -> Option<(Time, EventKind)> {
        loop {
            let now = self.clock.now();
            if self.source.pump(now, mq).is_err() {
                return None;
            }
            self.ingest(q, mq);
            let next_q = q.peek_time();
            let next_src = self.source.next_due();
            let target = match (next_q, next_src) {
                (Some(a), Some(b)) => a.min(b),
                (Some(a), None) => a,
                (None, Some(b)) => b,
                (None, None) => {
                    if self.source.exhausted() {
                        return None;
                    }
                    // Real threads may still publish: wait on the MQ
                    // condvar with a poll fallback, give up past budget.
                    let step = Duration::from_millis(100);
                    if self.idle >= self.idle_budget {
                        return None;
                    }
                    let before = mq.produced();
                    mq.wait_produce(self.seen, step);
                    if mq.produced() == before {
                        self.idle += step;
                    } else {
                        self.idle = Duration::ZERO;
                    }
                    continue;
                }
            };
            self.idle = Duration::ZERO;
            let reached = self.clock.wait_until(target, mq, self.seen);
            if mq.produced() > self.seen {
                continue; // new publish: ingest before releasing events
            }
            if let Some(tq) = q.peek_time() {
                if tq <= reached {
                    return q.next();
                }
            }
            // else: a scripted publish was due first — loop pumps it.
        }
    }
}

// ---------------------------------------------------------------------------
// the single-job engine
// ---------------------------------------------------------------------------

/// How a round's party arrivals reach the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalMode {
    /// Simulated: the engine schedules `UpdateArrival` events itself from
    /// the fleet model's drawn offsets.
    Schedule,
    /// Live: parties publish into the MQ and the [`WallDriver`] injects
    /// the arrival events; the engine only hands the drawn offsets back
    /// to the caller (for scripted sources) and does not produce sim
    /// payloads.
    External,
}

/// One round's start plan, as handed to the driver by
/// [`JobEngine::start_round`].
#[derive(Clone, Debug, Default)]
pub struct RoundPlan {
    /// Drawn arrival offsets (µs from round start), indexed by party id —
    /// including absent parties (their slot is drawn but undelivered so
    /// the rng stream stays state-independent).
    pub offsets: Vec<Time>,
    /// Parties that will actually publish this round: present ones, minus
    /// deadline-missers under [`StalePolicy::Drop`] (those are cut at the
    /// source and counted in `updates_dropped`). Under `Decay` the late
    /// parties stay in — they publish at their true late time and fold
    /// with decayed weight.
    pub parties: Vec<usize>,
}

/// One FL job's runtime state machine — shared verbatim between the
/// multi-tenant simulation platform and the live runner.
pub struct JobEngine {
    pub spec: FlJobSpec,
    pub params: JobParams,
    pub fleet: Fleet,
    pub strategy: Box<dyn Strategy>,
    pub rng: Rng,
    pub round: u32,
    pub round_start: Time,
    pub arrived: usize,
    /// Periodicity histories per party (fed with observed timings).
    pub histories: Vec<PeriodicityTracker>,
    pub linearity: LinearityModel,
    pub records: Vec<RoundRecord>,
    pub done: bool,
    pub finished_at: Time,
    /// Broker path: round 0 is gated on a JobArrival event + admission
    /// control instead of starting at t = 0.
    pub deferred: bool,
    /// Fault-injection knobs (default: all off — bit-compat fast path).
    pub faults: FleetFaults,
    /// Round-to-round fault bookkeeping (who is dropped out until when).
    pub fault_state: FaultState,
    /// The spec quorum before any per-round degradation shrink.
    pub base_quorum: usize,
    /// Updates cut at the straggler deadline under [`StalePolicy::Drop`],
    /// or lost because their payload vanished before a decayed fold.
    pub updates_dropped: usize,
    /// Deadline-missers folded with decayed weight (`async-stale`).
    pub updates_decayed: usize,
    /// Rounds skipped because expected on-time arrivals starved below the
    /// quorum floor.
    pub rounds_skipped: u32,
    /// L1 aggregator shard count for this job's data plane (1 = the flat
    /// single-fold plane; >1 routes updates to per-shard topics by the
    /// fixed party-id range boundaries in [`crate::fusion::shard`]).
    pub shards: usize,
    /// Telemetry handle (disabled by default; the platform/live loops
    /// attach an enabled registry via [`JobEngine::set_telemetry`]).
    /// Strictly observational — never touches `rng` or the event queue.
    pub telemetry: Registry,
    /// Label scope for this engine's metric samples (job + strategy).
    pub tel_scope: Scope,
    /// Adaptive-JIT knobs (PR 10; default off — the zero-cost bit-compat
    /// fast path, same pattern as `faults`).
    pub adaptive: AdaptiveConfig,
    /// Online arrival estimator + control policy, `Some` iff adaptation
    /// is enabled. Consumes **no rng** — a pure function of observed
    /// arrival lags — so the engine's seeded stream (and every
    /// bit-identity pin built on it) is untouched either way.
    pub adapt: Option<AdaptivePolicy>,
    /// The fixed §5.4 defer (seconds) of the in-flight round — the floor
    /// the adaptive deadline may never undercut.
    adapt_fixed_defer: f64,
    /// (round, party) pairs already delivered to the strategy — dedupes
    /// the engine's self-scheduled stale deliveries against the driver's
    /// ingested ones.
    delivered: std::collections::HashSet<(u32, usize)>,
    /// Whether `on_job_start` ran (guards `on_job_end` when every round
    /// starved before round 0 ever started).
    started: bool,
}

impl JobEngine {
    /// Build a job engine. `seed` is the platform seed; the per-job fleet
    /// rng folds the job id in exactly like the pre-driver platform did,
    /// so existing seeds reproduce bit-identically.
    pub fn new(job: usize, spec: FlJobSpec, strategy_name: &str, seed: u64) -> JobEngine {
        JobEngine::with_faults(job, spec, strategy_name, seed, FleetFaults::none())
    }

    /// Build a job engine with fault injection. The weight-skew knob is
    /// applied to the fleet right after generation, from the same engine
    /// rng, so a resumed engine reconstructs the identical skewed fleet.
    pub fn with_faults(
        job: usize,
        spec: FlJobSpec,
        strategy_name: &str,
        seed: u64,
        faults: FleetFaults,
    ) -> JobEngine {
        let params = JobParams::derive(job, &spec);
        let mut rng = Rng::new(seed ^ (job as u64).wrapping_mul(0x9E3779B9));
        let mut fleet = Fleet::generate(
            spec.fleet_kind,
            spec.n_parties,
            spec.workload.fleet_params(),
            &mut rng,
        );
        if let Some(alpha) = faults.weight_skew_alpha {
            fleet.apply_weight_skew(alpha, &mut rng);
        }
        let strategy = strategies::by_name(strategy_name)
            .unwrap_or_else(|| panic!("unknown strategy '{strategy_name}'"));
        let histories = vec![PeriodicityTracker::new(8); spec.n_parties];
        let base_quorum = params.quorum;
        JobEngine {
            params,
            fleet,
            strategy,
            rng,
            round: 0,
            round_start: 0,
            arrived: 0,
            histories,
            linearity: LinearityModel::default(),
            records: Vec::new(),
            done: false,
            finished_at: 0,
            deferred: false,
            faults,
            fault_state: FaultState::new(spec.n_parties),
            base_quorum,
            updates_dropped: 0,
            updates_decayed: 0,
            rounds_skipped: 0,
            shards: 1,
            telemetry: Registry::disabled(),
            tel_scope: Scope::job(job),
            adaptive: AdaptiveConfig::none(),
            adapt: None,
            adapt_fixed_defer: 0.0,
            delivered: std::collections::HashSet::new(),
            started: false,
            spec,
        }
    }

    /// Attach a telemetry registry. The engine records per-job /
    /// per-strategy counters (`rounds_started_total`, `updates_*`) and
    /// `party_wait` spans (round start → each party's arrival) into it.
    pub fn set_telemetry(&mut self, reg: &Registry, strategy_name: &str) {
        self.telemetry = reg.clone();
        self.tel_scope = Scope::job_strategy(self.params.job, strategy_name);
    }

    /// Enable adaptive JIT control ([`crate::adapt`], PR 10). Off by
    /// default; both regimes call this identically (the sim platform and
    /// the live loop), so sim ≡ live bit-identity holds with adaptation
    /// on as well as off.
    pub fn set_adaptive(&mut self, cfg: AdaptiveConfig) {
        self.adapt = if cfg.is_none() {
            None
        } else {
            Some(AdaptivePolicy::new(cfg.clone()))
        };
        self.adaptive = cfg;
    }

    /// §5.5 resume: reload the adaptive-policy state checkpointed at the
    /// last completed round from the MQ's WAL-framed checkpoint records.
    /// No-op when adaptation is off or no checkpoint exists (a fresh
    /// policy warms up from scratch — exactly what the pre-kill run did).
    pub fn restore_adaptive(&mut self, mq: &MessageQueue) {
        if self.adaptive.is_none() {
            return;
        }
        if let Some(state) = mq.load_checkpoint(&mq::adapt_slot(self.params.job)) {
            if let Some(p) = state
                .acc
                .as_deref()
                .and_then(|a| AdaptivePolicy::from_f32s(self.adaptive.clone(), a))
            {
                self.adapt = Some(p);
            }
        }
    }

    /// The Fig 6 lines 6–13 prediction for the upcoming round.
    pub fn estimate(&mut self) -> RoundEstimate {
        let infos = self.fleet.infos(self.spec.report_prob, &mut self.rng);
        let cost = self.spec.workload.cost_model(self.spec.n_parties);
        estimate_round(
            &infos,
            self.spec.agg_frequency,
            self.spec.t_wait_secs,
            &cost,
            Some(&self.histories),
            &self.linearity,
        )
    }

    /// One fault-aware arrival draw for the engine's current round —
    /// *the* single draw point shared by sim, live and the §5.5 resume
    /// replay, so all three consume the identical rng stream.
    fn draw_round(&mut self) -> RoundDraw {
        let model_bytes = self.spec.workload.model.size_bytes();
        self.fleet.faulty_arrival_offsets(
            model_bytes,
            self.spec.t_wait_secs,
            &self.faults,
            self.round,
            &mut self.fault_state,
            &mut self.rng,
        )
    }

    /// Minimum on-time arrivals for a round to be worth running: the
    /// quorum floor (fraction of the spec quorum, never below 1).
    fn quorum_floor(&self) -> usize {
        ((self.base_quorum as f64 * self.faults.quorum_floor_frac).ceil() as usize)
            .clamp(1, self.base_quorum)
    }

    /// Begin the engine's current round at `q.now()`: estimate, draw the
    /// fleet's fault-aware arrival offsets, apply the degradation rules
    /// (quorum shrink / round skip on starvation), dispatch the strategy
    /// hooks. Returns the round plan — [`ArrivalMode::Schedule`] also
    /// queues the deliverable arrivals as events; [`ArrivalMode::External`]
    /// leaves publishing to the caller's party source (which may ignore
    /// the offsets: real threads publish when actual training finishes).
    ///
    /// Starved rounds (expected on-time arrivals below the quorum floor)
    /// are skipped *inside* this call, deterministically: the skipped
    /// round consumes its estimate + draw and the loop retries the next
    /// index at the same instant. If every remaining round starves, the
    /// engine marks itself `done` and returns an empty plan — callers
    /// must check [`JobEngine::done`] after this returns.
    pub fn start_round(
        &mut self,
        q: &mut EventQueue,
        cluster: &mut Cluster,
        mq: &MessageQueue,
        mode: ArrivalMode,
    ) -> RoundPlan {
        let now = q.now();
        let (est, draw) = loop {
            let est = self.estimate();
            let draw = self.draw_round();
            if self.faults.is_none() {
                break (est, draw);
            }
            let expected = draw.expected_on_time();
            if expected >= self.quorum_floor() {
                // graceful degradation: wait only for what can arrive
                self.params.quorum = expected.min(self.base_quorum);
                break (est, draw);
            }
            // starvation: skip this round rather than hang on a quorum
            // that cannot be met
            self.rounds_skipped += 1;
            self.telemetry
                .counter_add("rounds_skipped_total", &self.tel_scope, 1);
            if self.round + 1 >= self.spec.rounds {
                self.done = true;
                self.finished_at = now;
                if self.started {
                    let params = self.params.clone();
                    let mut ctx = Ctx {
                        q,
                        cluster,
                        mq,
                        params: &params,
                    };
                    self.strategy.on_job_end(&mut ctx);
                }
                return RoundPlan::default();
            }
            self.round += 1;
        };
        let round = self.round;
        self.round_start = now;
        self.arrived = 0;
        let job = self.params.job;
        let decay = matches!(self.strategy.stale_policy(), StalePolicy::Decay { .. });
        let mut parties = Vec::new();
        for party in 0..draw.offsets.len() {
            if !draw.present[party] {
                continue; // dropped out: neither trains nor publishes
            }
            if !draw.on_time[party] && !decay {
                // misses the reporting deadline and the strategy drops
                // deadline-missers: cut at the source, in both regimes
                self.updates_dropped += 1;
                self.telemetry
                    .counter_add("updates_dropped_total", &self.tel_scope, 1);
                continue;
            }
            parties.push(party);
            let off = draw.offsets[party];
            match mode {
                ArrivalMode::Schedule => {
                    q.schedule_at(now + off, EventKind::UpdateArrival { job, round, party });
                }
                ArrivalMode::External => {
                    if !draw.on_time[party] {
                        // The fuse drops the round topic, so the wall
                        // driver will never ingest this late publish —
                        // self-schedule its delivery 1µs after the
                        // publish lands (at exact ties the driver
                        // releases queue events before pumping the due
                        // publish; the epsilon guarantees the payload is
                        // in the log when the stale fold fetches it).
                        q.schedule_at(
                            now + off + 1,
                            EventKind::UpdateArrival { job, round, party },
                        );
                    }
                }
            }
        }
        // adaptive signal (b): restore a FleetFaults-degraded quorum
        // toward the configured base when the observed arrival rate
        // supports it — never below the degraded value, never past what
        // this round can actually deliver
        if !self.faults.is_none() {
            if let Some(a) = self.adapt.as_ref() {
                self.params.quorum =
                    a.quorum_for(self.params.quorum, self.base_quorum, parties.len());
            }
        }
        let params = self.params.clone();
        let mut ctx = Ctx {
            q,
            cluster,
            mq,
            params: &params,
        };
        if !self.started {
            self.started = true;
            self.strategy.on_job_start(&mut ctx);
        }
        self.strategy.on_round_start(&mut ctx, round, &est);
        // adaptive signal (a): move the fuse deadline to the learned
        // arrival quantile. The learned defer is floored at the fixed
        // §5.4 prediction — adaptation only ever defers aggregator
        // spin-up further, it never advances it below the fixed plan.
        if self.adapt.is_some() {
            self.adapt_fixed_defer = est.defer_secs(self.params.jit_margin);
            let target = match (&self.adapt, self.strategy.armed_deadline()) {
                (Some(a), Some(_)) => {
                    let t = a.deadline_defer(self.adapt_fixed_defer);
                    (t > self.adapt_fixed_defer).then_some(t)
                }
                _ => None,
            };
            if let Some(t) = target {
                self.strategy.rearm_deadline(&mut ctx, round, now + secs(t));
            }
        }
        if self.telemetry.on() {
            self.telemetry
                .counter_add("rounds_started_total", &self.tel_scope, 1);
            // one party_wait span per expected publisher, closed by
            // handle_update when the arrival lands
            for &party in &parties {
                self.telemetry
                    .span_begin(SpanKind::PartyWait, job, round, party as u64, now);
            }
        }
        RoundPlan {
            offsets: draw.offsets,
            parties,
        }
    }

    /// §5.5 resume fast-forward: consume exactly the rng draws the
    /// pre-kill engine consumed for its `completed` *fused* rounds —
    /// including any starved rounds it skipped along the way (skips
    /// consume an estimate + draw but publish no model, so the completed
    /// count from the model-topic log is not a round index). Leaves
    /// `round` at the first not-yet-fused round.
    pub fn replay_completed(&mut self, completed: u32) {
        let mut fused = 0;
        while fused < completed && self.round < self.spec.rounds {
            let _ = self.estimate();
            let draw = self.draw_round();
            if self.faults.is_none() || draw.expected_on_time() >= self.quorum_floor() {
                if !self.faults.is_none() {
                    self.params.quorum = draw.expected_on_time().min(self.base_quorum);
                }
                fused += 1;
            } else {
                self.rounds_skipped += 1;
            }
            self.round += 1;
        }
    }

    /// A deadline-missed update from an already-fused `round` arrived at
    /// `now`: drop it or fold it into the *current* round with
    /// exponentially decayed weight, per the strategy's [`StalePolicy`].
    fn handle_stale(
        &mut self,
        q: &mut EventQueue,
        cluster: &mut Cluster,
        mq: &MessageQueue,
        round: u32,
        party: usize,
        mode: ArrivalMode,
        now: Time,
    ) {
        let lambda = match self.strategy.stale_policy() {
            StalePolicy::Drop => {
                self.updates_dropped += 1;
                self.telemetry
                    .counter_add("updates_dropped_total", &self.tel_scope, 1);
                return;
            }
            StalePolicy::Decay { lambda } => lambda,
        };
        if !self.delivered.insert((round, party)) {
            return; // already delivered (normal-path ingest beat us here)
        }
        let age = (self.round - round) as f64;
        let weight =
            (self.fleet.parties[party].dataset_items * (-lambda * age).exp()) as f32;
        let job = self.params.job;
        // the party's shard owns it in every round — stale re-produces
        // land in the same shard's current-round topic
        let shard = crate::fusion::shard::shard_of(party, self.spec.n_parties, self.shards);
        let cur_topic = mq::shard_topic_for(job, self.round, shard, self.shards);
        match mode {
            ArrivalMode::Schedule => {
                mq.produce(
                    &cur_topic,
                    Message {
                        party,
                        round,
                        weight,
                        enqueued_at: now,
                        payload: Payload::Sim {
                            size_bytes: self.spec.workload.model.size_bytes(),
                        },
                    },
                );
            }
            ArrivalMode::External => {
                // The real payload sits in the original round's topic log
                // (the late publish recreated it after the fuse dropped
                // it). Re-produce it into the current round's topic with
                // the decayed weight so the folder fuses it durably; the
                // copy keeps the original round, so its ingest echo
                // routes back here and dedupes.
                let old =
                    mq.fetch(&mq::shard_topic_for(job, round, shard, self.shards), 0, usize::MAX);
                let Some(m) = old.iter().find(|m| m.party == party) else {
                    self.updates_dropped += 1; // payload gone — give up
                    self.telemetry
                        .counter_add("updates_dropped_total", &self.tel_scope, 1);
                    return;
                };
                mq.produce(
                    &cur_topic,
                    Message {
                        party,
                        round,
                        weight,
                        enqueued_at: now,
                        payload: m.payload.clone(),
                    },
                );
            }
        }
        self.updates_decayed += 1;
        self.telemetry
            .counter_add("updates_decayed_total", &self.tel_scope, 1);
        self.arrived += 1;
        let arrived = self.arrived;
        let params = self.params.clone();
        let mut ctx = Ctx {
            q,
            cluster,
            mq,
            params: &params,
        };
        self.strategy.on_update(&mut ctx, self.round, party, arrived);
    }

    /// A party's update arrived (event popped at `q.now()`): feed the
    /// estimator with the observed timing and dispatch the strategy. In
    /// [`ArrivalMode::Schedule`] the engine also produces the sim payload
    /// into the MQ; in `External` the real message is already in the
    /// topic log (that is where the arrival event came from). Arrivals
    /// from an already-fused round take the stale path (drop or decayed
    /// fold, per the strategy's [`StalePolicy`]).
    pub fn handle_update(
        &mut self,
        q: &mut EventQueue,
        cluster: &mut Cluster,
        mq: &MessageQueue,
        round: u32,
        party: usize,
        mode: ArrivalMode,
    ) {
        let now = q.now();
        if self.done || round > self.round {
            return;
        }
        if round < self.round {
            self.handle_stale(q, cluster, mq, round, party, mode, now);
            return;
        }
        if !self.delivered.insert((round, party)) {
            return; // engine-scheduled stale event echoing a live ingest
        }
        self.arrived += 1;
        if self.telemetry.on() {
            self.telemetry
                .counter_add("updates_arrived_total", &self.tel_scope, 1);
            self.telemetry.span_end(
                SpanKind::PartyWait,
                self.params.job,
                round,
                party as u64,
                now,
            );
        }
        let arrived = self.arrived;
        // adaptive bookkeeping: every delivered current-round update
        // feeds the arrival-lag sketch — rng-free and identical in both
        // regimes (the event carries the same arrival time in sim and
        // live, so the sketches agree bit-for-bit)
        if let Some(a) = self.adapt.as_mut() {
            a.observe(to_secs(now - self.round_start));
        }
        // feed the estimator with the *observed* timing (active parties):
        // train_time ≈ arrival_offset − estimated transfer time (§5.3)
        let p = &self.fleet.parties[party];
        if p.mode == crate::estimator::Mode::Active {
            let off = to_secs(now - self.round_start);
            let observed_train =
                (off - p.comm_secs(self.spec.workload.model.size_bytes())).max(0.0);
            self.histories[party].observe(observed_train);
            self.linearity.observe_epoch(p.dataset_items, observed_train);
            let mb = observed_train / (p.dataset_items / 32.0).max(1.0);
            self.linearity.observe_minibatch(p.hardware.score(), mb);
        }
        if mode == ArrivalMode::Schedule {
            // buffer in the MQ (sim payload: size only; the sim plane is
            // unsharded so this collapses to the flat round topic)
            let shard = crate::fusion::shard::shard_of(party, self.spec.n_parties, self.shards);
            mq.produce(
                &mq::shard_topic_for(self.params.job, round, shard, self.shards),
                Message {
                    party,
                    round,
                    weight: p.dataset_items as f32,
                    enqueued_at: now,
                    payload: Payload::Sim {
                        size_bytes: self.spec.workload.model.size_bytes(),
                    },
                },
            );
        }
        let params = self.params.clone();
        let mut ctx = Ctx {
            q,
            cluster,
            mq,
            params: &params,
        };
        self.strategy.on_update(&mut ctx, round, party, arrived);
        // adaptive signal (a), mid-round form: when the live estimate
        // (completed rounds ∪ in-flight arrivals) undercuts the armed
        // deadline past the re-arm hysteresis, pull the fuse in — the
        // superseded timer is canceled inside `rearm_deadline`
        // (`EventQueue::cancel` + re-insert), never left to fire a
        // spurious fuse. Floored at the fixed §5.4 defer.
        if self.adapt.is_some() {
            let rearm = match (&self.adapt, self.strategy.armed_deadline()) {
                (Some(a), Some(armed)) => {
                    let armed_defer = to_secs(armed.saturating_sub(self.round_start));
                    a.rearm_defer(self.adapt_fixed_defer, armed_defer)
                }
                _ => None,
            };
            if let Some(d) = rearm {
                self.strategy
                    .rearm_deadline(&mut ctx, round, self.round_start + secs(d));
            }
        }
    }

    /// Dispatch a deadline-timer alert to the strategy.
    pub fn on_timer(
        &mut self,
        q: &mut EventQueue,
        cluster: &mut Cluster,
        mq: &MessageQueue,
        round: u32,
    ) {
        if self.done {
            return;
        }
        let params = self.params.clone();
        let mut ctx = Ctx {
            q,
            cluster,
            mq,
            params: &params,
        };
        self.strategy.on_timer(&mut ctx, round);
    }

    /// Dispatch a cluster notification to the strategy.
    pub fn on_note(
        &mut self,
        q: &mut EventQueue,
        cluster: &mut Cluster,
        mq: &MessageQueue,
        note: &Notification,
    ) {
        let params = self.params.clone();
        let mut ctx = Ctx {
            q,
            cluster,
            mq,
            params: &params,
        };
        self.strategy.on_note(&mut ctx, note);
    }

    /// Dispatch a keep-warm linger expiry to the strategy.
    pub fn on_linger(
        &mut self,
        q: &mut EventQueue,
        cluster: &mut Cluster,
        mq: &MessageQueue,
        task: usize,
    ) {
        if self.done {
            return;
        }
        let params = self.params.clone();
        let mut ctx = Ctx {
            q,
            cluster,
            mq,
            params: &params,
        };
        self.strategy.on_linger(&mut ctx, task);
    }

    /// Completed-round record from the strategy, if one finished.
    pub fn take_completed(&mut self) -> Option<RoundRecord> {
        self.strategy.take_completed()
    }

    /// Bookkeep a completed round: record it, release the strategy at job
    /// end, or schedule the next `RoundStart` (intermittent fleets pace
    /// rounds by `t_wait`, §4.3). Returns true when the job just finished.
    pub fn finish_round(
        &mut self,
        q: &mut EventQueue,
        cluster: &mut Cluster,
        mq: &MessageQueue,
        rec: RoundRecord,
    ) -> bool {
        let now = q.now();
        let round = rec.round;
        // adaptive roll-over: merge the round's arrival sketch into the
        // cumulative state, checkpoint it through the existing WAL
        // checkpoint records (so §5.5 kill/resume reloads it at exactly
        // this round boundary), and publish the live quantile gauges
        if let Some(a) = self.adapt.as_mut() {
            a.end_round();
            mq.save_checkpoint(
                &mq::adapt_slot(self.params.job),
                CheckpointState {
                    acc: Some(a.to_f32s()),
                    weight: 0.0,
                    n_merged: a.rounds_observed() as usize,
                    consumed_to: round as usize,
                    saved_at: now,
                    buckets: Vec::new(),
                },
            );
            if self.telemetry.on() {
                let (p50, p90, p99) = a.quantiles();
                self.telemetry
                    .gauge_set("adaptive_arrival_p50_secs", &self.tel_scope, p50);
                self.telemetry
                    .gauge_set("adaptive_arrival_p90_secs", &self.tel_scope, p90);
                self.telemetry
                    .gauge_set("adaptive_arrival_p99_secs", &self.tel_scope, p99);
                self.telemetry.gauge_set(
                    "adaptive_deadline_secs",
                    &self.tel_scope,
                    a.deadline_defer(self.adapt_fixed_defer),
                );
            }
        }
        self.telemetry
            .counter_add("rounds_fused_total", &self.tel_scope, 1);
        self.telemetry.histogram_observe(
            "round_latency_secs",
            &self.tel_scope,
            rec.latency_secs,
            &crate::telemetry::LATENCY_BUCKETS_SECS,
        );
        self.records.push(rec);
        if round + 1 >= self.spec.rounds {
            self.done = true;
            self.finished_at = now;
            let params = self.params.clone();
            let mut ctx = Ctx {
                q,
                cluster,
                mq,
                params: &params,
            };
            self.strategy.on_job_end(&mut ctx);
            return true;
        }
        self.round = round + 1;
        // pacing: active jobs start the next round as soon as the fused
        // model is out; intermittent jobs run fixed t_wait windows (§4.3)
        let next_at = match self.spec.fleet_kind {
            crate::party::FleetKind::IntermittentHeterogeneous => {
                (self.round_start + self.params.t_wait).max(now)
            }
            _ => now,
        };
        q.schedule_at(
            next_at,
            EventKind::RoundStart {
                job: self.params.job,
                round: round + 1,
            },
        );
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::party::FleetKind;
    use crate::workloads::Workload;

    #[test]
    fn instant_clock_jumps_and_never_rewinds() {
        let mq = MessageQueue::new();
        let mut c = InstantClock::default();
        assert_eq!(c.now(), 0);
        assert_eq!(c.wait_until(5_000, &mq, 0), 5_000);
        assert_eq!(c.wait_until(1_000, &mq, 0), 5_000, "no rewind");
        assert_eq!(c.now(), 5_000);
    }

    #[test]
    fn wall_clock_wakes_on_produce() {
        let mq = Arc::new(MessageQueue::new());
        let mut clock = WallClock::new();
        let seen = mq.produced();
        let mq2 = Arc::clone(&mq);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            mq2.produce(
                "t",
                Message {
                    party: 0,
                    round: 0,
                    weight: 1.0,
                    enqueued_at: 0,
                    payload: Payload::Sim { size_bytes: 1 },
                },
            );
        });
        // deadline 5s away, but the produce at ~30ms must wake us
        let t0 = Instant::now();
        clock.wait_until(crate::sim::secs(5.0), &mq, seen);
        let waited = t0.elapsed();
        h.join().unwrap();
        assert!(mq.produced() > seen);
        assert!(
            waited < Duration::from_secs(2),
            "produce must interrupt the sleep (waited {waited:?})"
        );
    }

    #[test]
    fn virtual_driver_is_a_plain_pop() {
        let mq = MessageQueue::new();
        let mut q = EventQueue::new();
        q.schedule_at(crate::sim::secs(1.0), EventKind::Custom { tag: 9 });
        let mut d = VirtualDriver;
        let (t, ev) = d.next_event(&mut q, &mq).unwrap();
        assert_eq!(t, crate::sim::secs(1.0));
        assert_eq!(ev, EventKind::Custom { tag: 9 });
        assert!(d.next_event(&mut q, &mq).is_none());
    }

    #[test]
    fn engine_round_zero_runs_job_start_hook() {
        let spec = FlJobSpec::new(
            Workload::cifar100_effnet(),
            FleetKind::ActiveHomogeneous,
            4,
            2,
        );
        let mut e = JobEngine::new(0, spec, "eager-ao", 7);
        let mut q = EventQueue::new();
        let mut cluster = Cluster::new(crate::cluster::ClusterConfig::default());
        let mq = MessageQueue::new();
        let plan = e.start_round(&mut q, &mut cluster, &mq, ArrivalMode::Schedule);
        assert_eq!(plan.offsets.len(), 4);
        assert_eq!(plan.parties, vec![0, 1, 2, 3], "fault-free: all deliver");
        // AO's on_job_start deployed its long-lived fleet immediately
        assert_eq!(cluster.job_deployments(0), 1);
        // arrivals were scheduled
        assert!(q.len() >= 4);
    }

    #[test]
    fn external_mode_schedules_no_arrivals_and_skips_sim_produce() {
        let spec = FlJobSpec::new(
            Workload::cifar100_effnet(),
            FleetKind::ActiveHomogeneous,
            3,
            1,
        );
        let mut e = JobEngine::new(0, spec, "lazy", 7);
        let mut q = EventQueue::new();
        let mut cluster = Cluster::new(crate::cluster::ClusterConfig::default());
        let mq = MessageQueue::new();
        let plan = e.start_round(&mut q, &mut cluster, &mq, ArrivalMode::External);
        assert_eq!(plan.offsets.len(), 3);
        assert!(q.is_empty(), "external mode must not pre-schedule arrivals");
        e.handle_update(&mut q, &mut cluster, &mq, 0, 0, ArrivalMode::External);
        assert_eq!(
            mq.end_offset(&mq::update_topic(0, 0)),
            0,
            "external mode must not double-produce"
        );
        assert_eq!(e.arrived, 1);
    }

    fn faulty_engine(strategy: &str, faults: FleetFaults, seed: u64, n: usize) -> JobEngine {
        let spec = FlJobSpec::new(
            Workload::cifar100_effnet(),
            FleetKind::ActiveHomogeneous,
            n,
            3,
        );
        JobEngine::with_faults(0, spec, strategy, seed, faults)
    }

    #[test]
    fn fault_free_engine_plan_matches_legacy_offsets() {
        // the faults=none constructor must consume the identical rng
        // stream as the pre-fault engine: compare against a hand-rolled
        // replica of the old draw sequence
        let spec = FlJobSpec::new(
            Workload::cifar100_effnet(),
            FleetKind::ActiveHeterogeneous,
            5,
            2,
        );
        let mut e = JobEngine::new(0, spec.clone(), "jit", 99);
        let mut rng = Rng::new(99);
        let fleet = Fleet::generate(
            spec.fleet_kind,
            spec.n_parties,
            spec.workload.fleet_params(),
            &mut rng,
        );
        let _ = fleet.infos(spec.report_prob, &mut rng); // estimate's draw
        let legacy = fleet.arrival_offsets(
            spec.workload.model.size_bytes(),
            spec.t_wait_secs,
            &mut rng,
        );
        let mut q = EventQueue::new();
        let mut cluster = Cluster::new(crate::cluster::ClusterConfig::default());
        let mq = MessageQueue::new();
        let plan = e.start_round(&mut q, &mut cluster, &mq, ArrivalMode::External);
        assert_eq!(plan.offsets, legacy, "fault-free rng stream must not move");
    }

    #[test]
    fn drop_strategy_cuts_deadline_missers_at_the_source() {
        let faults = FleetFaults {
            straggler_prob: 1.0,
            straggler_alpha: 1.1,
            straggler_cutoff_secs: Some(60.0),
            quorum_floor_frac: 0.0,
            ..FleetFaults::default()
        };
        let mut e = faulty_engine("jit", faults, 0xD0, 12);
        let mut q = EventQueue::new();
        let mut cluster = Cluster::new(crate::cluster::ClusterConfig::default());
        let mq = MessageQueue::new();
        let plan = e.start_round(&mut q, &mut cluster, &mq, ArrivalMode::External);
        assert!(
            e.updates_dropped > 0,
            "with everyone stalled some parties must miss the 60s cutoff"
        );
        assert_eq!(plan.parties.len() + e.updates_dropped, 12);
        assert_eq!(e.params.quorum, plan.parties.len(), "quorum degrades");
    }

    #[test]
    fn decay_strategy_keeps_late_parties_and_self_schedules_delivery() {
        let faults = FleetFaults {
            straggler_prob: 1.0,
            straggler_alpha: 1.1,
            straggler_cutoff_secs: Some(60.0),
            quorum_floor_frac: 0.0,
            ..FleetFaults::default()
        };
        // same seed as the jit engine above: identical draw, different policy
        let mut e = faulty_engine("async-stale", faults, 0xD0, 12);
        let mut q = EventQueue::new();
        let mut cluster = Cluster::new(crate::cluster::ClusterConfig::default());
        let mq = MessageQueue::new();
        let plan = e.start_round(&mut q, &mut cluster, &mq, ArrivalMode::External);
        assert_eq!(plan.parties.len(), 12, "decay policy never cuts at source");
        assert_eq!(e.updates_dropped, 0);
        assert!(
            q.len() > 0,
            "late parties need engine-scheduled stale deliveries in live mode"
        );
    }

    #[test]
    fn starved_rounds_are_skipped_and_total_starvation_finishes_the_job() {
        let faults = FleetFaults {
            dropout_prob: 0.95, // clamp ceiling: nearly everyone out
            rejoin_after: 0,
            quorum_floor_frac: 1.0,
            ..FleetFaults::default()
        };
        let mut e = faulty_engine("jit", faults, 0xD1, 6);
        let mut q = EventQueue::new();
        let mut cluster = Cluster::new(crate::cluster::ClusterConfig::default());
        let mq = MessageQueue::new();
        let plan = e.start_round(&mut q, &mut cluster, &mq, ArrivalMode::External);
        assert!(e.done, "every round starves below a full-quorum floor");
        assert!(plan.parties.is_empty());
        assert_eq!(e.rounds_skipped, 3);
        assert!(e.records.is_empty(), "skipped rounds publish nothing");
    }

    #[test]
    fn stale_update_is_dropped_or_decayed_by_policy() {
        let faults = FleetFaults {
            dropout_prob: 0.01,
            ..FleetFaults::default()
        };
        for (name, expect_decay) in [("jit", false), ("async-stale", true)] {
            let mut e = faulty_engine(name, faults, 0xD2, 6);
            let mut q = EventQueue::new();
            let mut cluster = Cluster::new(crate::cluster::ClusterConfig::default());
            let mq = MessageQueue::new();
            let _ = e.start_round(&mut q, &mut cluster, &mq, ArrivalMode::Schedule);
            e.round = 2; // pretend rounds 0..1 fused; round-0 update is stale
            e.handle_update(&mut q, &mut cluster, &mq, 0, 3, ArrivalMode::Schedule);
            if expect_decay {
                assert_eq!(e.updates_decayed, 1, "{name}");
                assert_eq!(e.updates_dropped, 0, "{name}");
                let msgs = mq.fetch(&mq::update_topic(0, 2), 0, usize::MAX);
                assert_eq!(msgs.len(), 1, "{name}: decayed copy in current topic");
                let expected_w = (e.fleet.parties[3].dataset_items
                    * (-crate::coordinator::strategies::async_stale::DECAY_LAMBDA
                        * 2.0)
                        .exp()) as f32;
                assert!((msgs[0].weight - expected_w).abs() < 1e-6, "{name}");
                // a second delivery of the same (round, party) is a no-op
                e.handle_update(&mut q, &mut cluster, &mq, 0, 3, ArrivalMode::Schedule);
                assert_eq!(e.updates_decayed, 1, "{name}: deduped");
            } else {
                assert_eq!(e.updates_dropped, 1, "{name}");
                assert_eq!(e.updates_decayed, 0, "{name}");
            }
        }
    }

    #[test]
    fn replay_matches_live_skip_accounting() {
        // replay_completed must consume exactly the draws start_round
        // consumed, leaving the rng aligned for post-resume rounds (a
        // floor of 1 keeps every round viable so the fused count is
        // deterministic regardless of who drops)
        let faults = FleetFaults {
            dropout_prob: 0.3,
            rejoin_after: 0,
            quorum_floor_frac: 0.0,
            ..FleetFaults::default()
        };
        let mut live = faulty_engine("jit", faults, 0xD3, 12);
        let mut q = EventQueue::new();
        let mut cluster = Cluster::new(crate::cluster::ClusterConfig::default());
        let mq = MessageQueue::new();
        let mut fused = 0u32;
        while !live.done && live.round < live.spec.rounds {
            let plan = live.start_round(&mut q, &mut cluster, &mq, ArrivalMode::External);
            if live.done {
                break;
            }
            assert!(!plan.parties.is_empty());
            fused += 1;
            if live.round + 1 >= live.spec.rounds {
                break;
            }
            live.round += 1;
        }
        let mut replayed = faulty_engine("jit", faults, 0xD3, 12);
        replayed.replay_completed(fused);
        assert_eq!(replayed.round, live.round + u32::from(!live.done));
        assert_eq!(replayed.rounds_skipped, live.rounds_skipped);
        let mut a = live.rng.clone();
        let mut b = replayed.rng.clone();
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64(), "rng streams diverged");
        }
    }

    #[test]
    fn adaptive_engine_consumes_no_rng_and_checkpoints_through_the_mq() {
        let spec = FlJobSpec::new(
            Workload::cifar100_effnet(),
            FleetKind::ActiveHomogeneous,
            5,
            2,
        );
        let mut plain = JobEngine::new(0, spec.clone(), "jit", 99);
        let mut adaptive = JobEngine::new(0, spec.clone(), "jit", 99);
        adaptive.set_adaptive(AdaptiveConfig::on());
        let mut q = EventQueue::new();
        let mut cluster = Cluster::new(crate::cluster::ClusterConfig::default());
        let mq = MessageQueue::new();
        let p0 = plain.start_round(&mut q, &mut cluster, &mq, ArrivalMode::External);
        let mut q2 = EventQueue::new();
        let mut c2 = Cluster::new(crate::cluster::ClusterConfig::default());
        let mq2 = MessageQueue::new();
        let p1 = adaptive.start_round(&mut q2, &mut c2, &mq2, ArrivalMode::External);
        assert_eq!(
            p0.offsets, p1.offsets,
            "the adaptive policy must consume no rng — same seed, same draw"
        );
        // deliver the round and finish it: the sketch observes every
        // arrival and the adapt slot gets a WAL-framed checkpoint
        for &party in &p1.parties {
            adaptive.handle_update(&mut q2, &mut c2, &mq2, 0, party, ArrivalMode::External);
        }
        let fused = adaptive.finish_round(
            &mut q2,
            &mut c2,
            &mq2,
            RoundRecord {
                round: 0,
                latency_secs: 0.5,
                last_arrival_secs: 1.0,
                complete_secs: 1.5,
            },
        );
        assert!(!fused, "rounds=2: not done yet");
        let a = adaptive.adapt.as_ref().unwrap();
        assert_eq!(a.rounds_observed(), 1);
        let saved = mq2
            .load_checkpoint(&mq::adapt_slot(0))
            .expect("finish_round checkpoints the adaptive state");
        assert_eq!(saved.acc.as_deref(), Some(a.to_f32s().as_slice()));
        // a restarted engine restores the identical policy state
        let mut resumed = JobEngine::new(0, spec, "jit", 99);
        resumed.set_adaptive(AdaptiveConfig::on());
        resumed.restore_adaptive(&mq2);
        assert_eq!(
            resumed.adapt.as_ref().unwrap().to_f32s(),
            a.to_f32s(),
            "resume must reload the checkpointed sketch bit-for-bit"
        );
        // disabled config stays inert
        let mut off = JobEngine::new(1, plain.spec.clone(), "jit", 99);
        off.set_adaptive(AdaptiveConfig::none());
        assert!(off.adapt.is_none());
    }
}
