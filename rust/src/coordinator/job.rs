//! FL job specification (§5.1) and the derived per-job parameters the
//! strategies operate on.

use crate::estimator::AggFrequency;
use crate::fusion::Algorithm;
use crate::party::FleetKind;
use crate::sim::{secs, Time};
use crate::util::json::Json;
use crate::workloads::Workload;

/// The "FL Job Specification" parties agree on and send to the aggregation
/// service (§5.1): model, fusion algorithm, hyperparameters, quorum,
/// t_wait, plus the per-party inputs of §5.2 (collected separately).
#[derive(Clone, Debug)]
pub struct FlJobSpec {
    pub name: String,
    pub workload: Workload,
    pub fleet_kind: FleetKind,
    pub n_parties: usize,
    pub rounds: u32,
    pub agg_frequency: AggFrequency,
    /// Minimum updates needed for a round to succeed (§5.1). Defaults to
    /// all parties.
    pub quorum: usize,
    /// Round window for intermittent parties (seconds, §4.3).
    pub t_wait_secs: f64,
    /// Probability a party shares its timing measurements (§5.2); below
    /// 1.0 exercises the regression fallback of §5.3.
    pub report_prob: f64,
}

impl FlJobSpec {
    pub fn new(workload: Workload, fleet_kind: FleetKind, n_parties: usize, rounds: u32) -> Self {
        FlJobSpec {
            name: format!("{}-{}-{}p", workload.name, fleet_kind.name(), n_parties),
            workload,
            fleet_kind,
            n_parties,
            rounds,
            agg_frequency: AggFrequency::PerEpoch,
            quorum: n_parties,
            t_wait_secs: crate::workloads::T_WAIT_SECS,
            report_prob: 1.0,
        }
    }

    /// Set the round quorum, clamped to the fleet size (builder-style;
    /// used by the live runner's spec construction).
    pub fn with_quorum(mut self, quorum: usize) -> FlJobSpec {
        self.quorum = quorum.min(self.n_parties);
        self
    }

    pub fn algorithm(&self) -> Algorithm {
        self.workload.algorithm
    }

    /// Parse a job spec from JSON (CLI `run --spec job.json`).
    pub fn from_json(v: &Json) -> Option<FlJobSpec> {
        let workload = Workload::by_name(v.get("workload").as_str()?)?;
        let fleet_kind = FleetKind::parse(v.get("fleet").as_str().unwrap_or("active-homog"))?;
        let n_parties = v.get("parties").as_usize().unwrap_or(10);
        let rounds = v.get("rounds").as_u64().unwrap_or(50) as u32;
        let mut spec = FlJobSpec::new(workload, fleet_kind, n_parties, rounds);
        if let Some(q) = v.get("quorum").as_usize() {
            spec.quorum = q.min(n_parties);
        }
        if let Some(t) = v.get("t_wait_secs").as_f64() {
            spec.t_wait_secs = t;
        }
        if let Some(p) = v.get("report_prob").as_f64() {
            spec.report_prob = p.clamp(0.0, 1.0);
        }
        if let Some(name) = v.get("name").as_str() {
            spec.name = name.to_string();
        }
        Some(spec)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("workload", Json::str(self.workload.name)),
            ("fleet", Json::str(self.fleet_kind.name())),
            ("parties", Json::num(self.n_parties as f64)),
            ("rounds", Json::num(self.rounds as f64)),
            ("quorum", Json::num(self.quorum as f64)),
            ("t_wait_secs", Json::num(self.t_wait_secs)),
            ("report_prob", Json::num(self.report_prob)),
        ])
    }
}

/// Derived per-job constants the strategies consume every event — all in
/// sim Time units, precomputed once at job admission.
#[derive(Clone, Debug)]
pub struct JobParams {
    pub job: usize,
    pub n_parties: usize,
    pub quorum: usize,
    pub rounds: u32,
    /// Serverless per-update merge duration: t_pair / C_agg (update fetch
    /// from the MQ is pipelined with compute; DESIGN.md §3).
    pub item: Time,
    /// Always-on per-update service: serial ingest (M / B_ingest) + merge —
    /// always-on servers receive updates themselves rather than through the
    /// distributed MQ (one ingest stream per AO container).
    pub ao_item: Time,
    pub cold_start: Time,
    pub state_load: Time,
    pub checkpoint: Time,
    /// Keep-warm linger after a serverless container drains its queue.
    pub linger: Time,
    /// Parallel aggregator containers (N_agg, §5.4).
    pub n_agg: usize,
    /// Batched-serverless trigger size (§6.3).
    pub batch: usize,
    pub t_wait: Time,
    /// Safety margin on the JIT defer point: start at
    /// t_rnd − t_agg·(1+margin).
    pub jit_margin: f64,
    /// Allow opportunistic early starts when a full shard of work is
    /// already buffered (§5.5 priorities; the deadline timer is always on).
    pub opportunistic: bool,
}

/// Always-on ingress bandwidth per aggregator server (bytes/s). The AO
/// deployment receives its shard's updates itself (no MQ in front), so at
/// scale serial ingest stretches its rounds — one of the effects that
/// balloons Eager AO's container-seconds in Fig 9 (the other being that
/// its whole fleet idles through every round window).
pub const AO_INGRESS_BPS: f64 = 1.25e9; // 10 Gbps

impl JobParams {
    pub fn derive(job: usize, spec: &FlJobSpec) -> JobParams {
        let w = &spec.workload;
        let cost = w.cost_model(spec.n_parties);
        let m = w.model.size_bytes() as f64;
        // Serverless state load: partial aggregates / model state come from
        // the co-located object store with cache locality — charged at a
        // discounted effective transfer (DESIGN.md §3 calibration).
        let state_load = 0.02 + m / (5.0 * w.b_dc);
        JobParams {
            job,
            n_parties: spec.n_parties,
            quorum: spec.quorum,
            rounds: spec.rounds,
            item: secs(cost.item_secs()),
            ao_item: secs(cost.item_secs() + m / AO_INGRESS_BPS),
            cold_start: secs(w.cold_start_secs),
            state_load: secs(state_load),
            checkpoint: secs(w.checkpoint_secs),
            linger: secs(0.5),
            n_agg: cost.n_agg as usize,
            batch: crate::workloads::batch_trigger(spec.n_parties),
            t_wait: secs(spec.t_wait_secs),
            jit_margin: 0.10,
            opportunistic: true,
        }
    }

    /// Work shard sizes for splitting N updates over n_agg tasks.
    pub fn shard_sizes(&self) -> Vec<usize> {
        let n = self.n_parties;
        let k = self.n_agg.max(1).min(n.max(1));
        let base = n / k;
        let rem = n % k;
        (0..k).map(|i| base + usize::from(i < rem)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::party::FleetKind;

    fn spec() -> FlJobSpec {
        FlJobSpec::new(
            Workload::cifar100_effnet(),
            FleetKind::ActiveHomogeneous,
            100,
            50,
        )
    }

    #[test]
    fn params_derive_consistently() {
        let p = JobParams::derive(3, &spec());
        assert_eq!(p.job, 3);
        assert_eq!(p.n_parties, 100);
        assert_eq!(p.batch, 10);
        assert_eq!(p.n_agg, 2);
        assert!(p.ao_item > p.item, "AO ingest must dominate serverless item");
        // item = t_pair / 2 cores
        let want = crate::sim::secs(Workload::cifar100_effnet().t_pair / 2.0);
        assert_eq!(p.item, want);
    }

    #[test]
    fn shards_partition_parties() {
        let mut p = JobParams::derive(0, &spec());
        p.n_agg = 3;
        let shards = p.shard_sizes();
        assert_eq!(shards.len(), 3);
        assert_eq!(shards.iter().sum::<usize>(), 100);
        assert!(shards.iter().all(|&s| s == 33 || s == 34));
        // more shards than parties
        p.n_agg = 7;
        p.n_parties = 3;
        let shards = p.shard_sizes();
        assert_eq!(shards.iter().sum::<usize>(), 3);
        assert_eq!(shards.len(), 3);
    }

    #[test]
    fn with_quorum_clamps_to_fleet() {
        let s = spec().with_quorum(17);
        assert_eq!(s.quorum, 17);
        let s = spec().with_quorum(5000);
        assert_eq!(s.quorum, 100, "clamped to n_parties");
    }

    #[test]
    fn spec_json_roundtrip() {
        let s = spec();
        let j = s.to_json();
        let s2 = FlJobSpec::from_json(&j).unwrap();
        assert_eq!(s2.name, s.name);
        assert_eq!(s2.n_parties, 100);
        assert_eq!(s2.rounds, 50);
        assert_eq!(s2.workload.name, "cifar100-effnet");
        assert_eq!(s2.fleet_kind, FleetKind::ActiveHomogeneous);
    }

    #[test]
    fn spec_json_defaults_and_validation() {
        let v = Json::parse(r#"{"workload":"rvlcdip","fleet":"intermittent","quorum":9999}"#)
            .unwrap();
        let s = FlJobSpec::from_json(&v).unwrap();
        assert_eq!(s.n_parties, 10);
        assert_eq!(s.quorum, 10, "quorum clamped to fleet size");
        assert_eq!(s.fleet_kind, FleetKind::IntermittentHeterogeneous);
        assert!(FlJobSpec::from_json(&Json::parse(r#"{"workload":"nope"}"#).unwrap()).is_none());
    }
}
