//! The coordinator: FL jobs, aggregation strategies, the JIT scheduler and
//! the platform drivers. This is the paper's system contribution (§3, §5)
//! — everything else in the crate is substrate.
//!
//! One event-driven implementation, two time regimes ([`driver`]):
//! [`platform`] pulls the per-job [`driver::JobEngine`]s with the virtual
//! driver (simulation grids, multi-tenant broker), [`live`] pulls them
//! with the wall-clock driver over real MQ traffic — one engine
//! (`live::run_live`) or a whole broker-admitted job mix sharing one
//! arbitrated cluster (`live::run_live_broker`). The five
//! [`strategies`] run unmodified under both.

pub mod driver;
pub mod job;
pub mod live;
pub mod platform;
pub mod strategies;
pub mod timeline;
