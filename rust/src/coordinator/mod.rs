//! The coordinator: FL jobs, aggregation strategies, the JIT scheduler and
//! the platform drivers. This is the paper's system contribution (§3, §5)
//! — everything else in the crate is substrate.
//!
//! **Run things through [`session::Session`]** — the single builder-style
//! façade over every execution regime: `Session::sim()` (virtual time,
//! the Fig 7/8/9 grids), `Session::live()` (the real MQ data plane on an
//! instant clock — deterministic, bit-identical to sim) and
//! `Session::wall()` (the real wall clock with thread-backed parties).
//! One job or a whole broker-admitted job mix, one unified
//! [`session::Report`], and a streaming [`session::SessionEvent`] channel.
//!
//! Underneath: one event-driven implementation, two time regimes
//! ([`driver`]) — [`platform`] pulls the per-job [`driver::JobEngine`]s
//! with the virtual driver, [`live`] pulls them with the wall-clock
//! driver over real MQ traffic through one multi-job control loop (a
//! single live job is its N = 1 case). The six [`strategies`] run
//! unmodified under both, fault injection ([`crate::party::FleetFaults`])
//! included.

pub mod driver;
pub mod job;
pub mod live;
pub mod platform;
pub mod session;
pub mod strategies;
pub mod timeline;
