//! The coordinator: FL jobs, aggregation strategies, the JIT scheduler and
//! the platform drivers. This is the paper's system contribution (§3, §5)
//! — everything else in the crate is substrate.
//!
//! One event-driven implementation, two time regimes ([`driver`]):
//! [`platform`] pulls the per-job [`driver::JobEngine`]s with the virtual
//! driver (simulation grids, multi-tenant broker), [`live`] pulls one
//! engine with the wall-clock driver over real MQ traffic. The five
//! [`strategies`] run unmodified under both.

pub mod driver;
pub mod job;
pub mod live;
pub mod platform;
pub mod strategies;
pub mod timeline;
