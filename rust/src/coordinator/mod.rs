//! The coordinator: FL jobs, aggregation strategies, the JIT scheduler and
//! the platform drivers (simulated + live). This is the paper's system
//! contribution (§3, §5) — everything else in the crate is substrate.

pub mod job;
pub mod live;
pub mod platform;
pub mod strategies;
pub mod timeline;
