//! The multi-tenant simulation platform: drives FL jobs, parties, the
//! cluster, the MQ and the strategies through the discrete-event engine.
//!
//! This is the "JIT scheduler" box of Fig 5 plus the experiment driver of
//! §6: admits one or more [`FlJobSpec`]s, generates party fleets, runs
//! every round (arrival events → strategy → cluster), feeds the estimator
//! with observed timings (periodicity histories + the cross-party
//! linearity regressors), and produces a [`JobReport`] per job.
//!
//! Per-job round logic lives in [`JobEngine`] (`coordinator::driver`);
//! this module adds the multi-job concerns — admission control, event
//! routing by job id, broker arbitration — and pulls events through a
//! [`Driver`]. The default is the [`VirtualDriver`] (virtual time); the
//! *identical* engine + strategy code runs under `coordinator::live`'s
//! wall-clock driver with real MQ traffic.

use crate::broker::admission::AdmissionController;
use crate::cluster::{Cluster, ClusterConfig};
use crate::coordinator::driver::{ArrivalMode, Driver, JobEngine, VirtualDriver};
use crate::coordinator::job::FlJobSpec;
use crate::coordinator::session::{EventSink, SessionEvent};
use crate::coordinator::strategies::Strategy;
use crate::metrics::JobReport;
use crate::mq::{self, MessageQueue};
use crate::party::FleetFaults;
use crate::sim::{secs, to_secs, EventKind, EventQueue, Time};
use crate::telemetry::{Registry, Scope, SpanKind};

/// Platform configuration.
#[derive(Clone, Debug)]
pub struct PlatformConfig {
    pub cluster: ClusterConfig,
    pub seed: u64,
    /// Disable JIT opportunism (pure deadline-timer JIT).
    pub opportunistic: bool,
    /// Override the JIT safety margin on t_agg (default 0.10) — ablation.
    pub jit_margin: Option<f64>,
    /// Override the batched-serverless trigger size — ablation.
    pub batch_override: Option<usize>,
    /// Fleet fault injection, applied to every admitted job (default:
    /// all knobs off — the bit-compat fast path).
    pub faults: FleetFaults,
    /// Adaptive JIT control (PR 10, [`crate::adapt`]), applied to every
    /// admitted job (default: disabled — the bit-compat fast path, same
    /// contract as `faults`).
    pub adaptive: crate::adapt::AdaptiveConfig,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        PlatformConfig {
            cluster: ClusterConfig {
                capacity: 4096,
                ..Default::default()
            },
            seed: 0xF17A,
            opportunistic: true,
            jit_margin: None,
            batch_override: None,
            faults: FleetFaults::none(),
            adaptive: crate::adapt::AdaptiveConfig::none(),
        }
    }
}

pub struct Platform {
    cfg: PlatformConfig,
    q: EventQueue,
    cluster: Cluster,
    mq: MessageQueue,
    jobs: Vec<JobEngine>,
    tick_scheduled: bool,
    /// Broker admission control; `None` = every job starts unconditionally.
    admission: Option<AdmissionController>,
    /// Streaming observer channel (`Session::events()`); inactive by
    /// default, so the grid hot paths pay one `Option` check per emit.
    events: EventSink,
    /// Telemetry registry (`Session::telemetry()`); disabled by default.
    telemetry: Registry,
    /// Jobs currently held in the admission queue — drives the
    /// `admission_wait` span pairing (begin at queue, end at release).
    admission_waiting: Vec<bool>,
}

/// End-of-run aggregates for the broker (`run_with_stats`).
#[derive(Debug)]
pub struct RunStats {
    /// Virtual time when the last event fired, seconds.
    pub end_secs: f64,
    /// Container-seconds across all jobs (aggregation only).
    pub total_container_seconds: f64,
    /// The admission controller handed back (queue-wait metrics).
    pub admission: Option<AdmissionController>,
    /// Preemption decisions `(secs, victim task)` in decision order —
    /// deterministic per (seed, trace, policy).
    pub preemptions: Vec<(f64, usize)>,
    /// Per-job fault accounting `(updates_dropped, updates_decayed,
    /// rounds_skipped)` — all zeros without [`PlatformConfig::faults`].
    pub fault_counts: Vec<(usize, usize, u32)>,
}

impl Platform {
    pub fn new(cfg: PlatformConfig) -> Platform {
        Platform {
            cluster: Cluster::new(cfg.cluster.clone()),
            q: EventQueue::new(),
            mq: MessageQueue::new(),
            jobs: Vec::new(),
            tick_scheduled: false,
            admission: None,
            events: EventSink::none(),
            telemetry: Registry::disabled(),
            admission_waiting: Vec::new(),
            cfg,
        }
    }

    /// Admit a job with the given strategy. Returns the job id.
    pub fn admit(&mut self, spec: FlJobSpec, strategy_name: &str) -> usize {
        let job = self.jobs.len();
        let mut engine =
            JobEngine::with_faults(job, spec, strategy_name, self.cfg.seed, self.cfg.faults);
        engine.params.opportunistic = self.cfg.opportunistic;
        if let Some(m) = self.cfg.jit_margin {
            engine.params.jit_margin = m;
        }
        if let Some(b) = self.cfg.batch_override {
            engine.params.batch = b.max(1);
        }
        engine.set_adaptive(self.cfg.adaptive.clone());
        engine.set_telemetry(&self.telemetry, strategy_name);
        self.jobs.push(engine);
        self.admission_waiting.push(false);
        job
    }

    /// Broker path: submit a job that *arrives* at virtual time `at` and
    /// must pass the admission controller before its first round starts.
    pub fn submit_at(&mut self, spec: FlJobSpec, strategy_name: &str, at: Time) -> usize {
        let job = self.admit(spec, strategy_name);
        self.jobs[job].deferred = true;
        self.q.schedule_at(at, EventKind::JobArrival { job });
        job
    }

    /// Install the broker's admission controller (see `broker::admission`).
    pub fn set_admission(&mut self, ctrl: AdmissionController) {
        self.admission = Some(ctrl);
    }

    /// Mutable cluster access for the broker control plane (arbitration
    /// policy installation, per-job fair-share weights).
    pub fn cluster_mut(&mut self) -> &mut Cluster {
        &mut self.cluster
    }

    /// Install the session's streaming observer channel: the run emits
    /// [`SessionEvent`]s (job admitted/queued, round started/fused,
    /// preemption decisions) as it executes.
    pub fn set_event_sink(&mut self, sink: EventSink) {
        self.events = sink;
    }

    /// Install a telemetry registry and propagate it to the MQ and to
    /// every already-admitted engine (engines admitted later pick it up
    /// in [`admit`](Platform::admit)). Strictly passive: timestamps are
    /// the virtual times the run already computes.
    pub fn set_telemetry(&mut self, reg: &Registry) {
        self.telemetry = reg.clone();
        self.mq.set_telemetry(reg);
        for engine in &mut self.jobs {
            let strategy = engine.strategy.name().to_string();
            engine.set_telemetry(reg, &strategy);
        }
    }

    /// A job cleared admission (or has no controller): start round 0 now.
    fn release_job(&mut self, job: usize) {
        let now = self.q.now();
        if self.admission_waiting[job] {
            self.admission_waiting[job] = false;
            self.telemetry
                .span_end(SpanKind::AdmissionWait, job, 0, 0, now);
        }
        self.events.emit(SessionEvent::JobAdmitted {
            job,
            at_secs: to_secs(now),
        });
        self.q
            .schedule_at(now, EventKind::RoundStart { job, round: 0 });
    }

    fn on_job_arrival(&mut self, job: usize) {
        let now = self.q.now();
        self.events.emit(SessionEvent::JobSubmitted {
            job,
            at_secs: to_secs(now),
        });
        let started = match self.admission.as_mut() {
            Some(ctrl) => ctrl.arrive(job, now),
            None => vec![job],
        };
        if self.admission.is_some() && !started.contains(&job) {
            self.events.emit(SessionEvent::JobQueued {
                job,
                at_secs: to_secs(now),
            });
            if self.telemetry.on() {
                self.admission_waiting[job] = true;
                self.telemetry
                    .span_begin(SpanKind::AdmissionWait, job, 0, 0, now);
                self.telemetry
                    .counter_add("jobs_queued_total", &Scope::job(job), 1);
            }
        }
        for j in started {
            self.release_job(j);
        }
    }

    fn start_round(&mut self, job: usize) {
        let round_before = self.jobs[job].round;
        self.jobs[job].start_round(
            &mut self.q,
            &mut self.cluster,
            &self.mq,
            ArrivalMode::Schedule,
        );
        self.emit_skipped_rounds(job, round_before);
        if self.jobs[job].done {
            // every remaining round starved below the quorum floor: the
            // engine skipped to the end without starting anything
            self.job_finished(job);
            return;
        }
        let round = self.jobs[job].round;
        let now = self.q.now();
        self.events.emit(SessionEvent::RoundStarted {
            job,
            round,
            at_secs: to_secs(now),
        });
        self.telemetry.span_begin(SpanKind::Round, job, round, 0, now);
        self.ensure_tick();
    }

    /// `JobEngine::start_round` silently advances past rounds that starve
    /// below the quorum floor; surface each one as a
    /// [`SessionEvent::RoundSkipped`] so the event stream stays a faithful
    /// account of round numbering under faults.
    fn emit_skipped_rounds(&mut self, job: usize, round_before: u32) {
        if !self.events.active() {
            return;
        }
        let settled = self.jobs[job].round;
        let end = if self.jobs[job].done {
            self.jobs[job].spec.rounds
        } else {
            settled
        };
        let at_secs = to_secs(self.q.now());
        for round in round_before..end {
            self.events
                .emit(SessionEvent::RoundSkipped { job, round, at_secs });
        }
    }

    /// Emit the finish event and release admission demand a finished job
    /// held (queued jobs may start now — broker backpressure path).
    fn job_finished(&mut self, job: usize) {
        let now = self.q.now();
        self.events.emit(SessionEvent::JobFinished {
            job,
            at_secs: to_secs(now),
        });
        if let Some(ctrl) = self.admission.as_mut() {
            let released = ctrl.finish(job, now);
            for j in released {
                self.release_job(j);
            }
        }
    }

    fn ensure_tick(&mut self) {
        if !self.tick_scheduled {
            self.tick_scheduled = true;
            self.q
                .schedule_in(self.cluster.cfg.delta_tick, EventKind::SchedTick);
        }
    }

    fn poll_round_completion(&mut self, job: usize) {
        let Some(rec) = self.jobs[job].take_completed() else {
            return;
        };
        let now = self.q.now();
        self.events.emit(SessionEvent::RoundFused {
            job,
            round: rec.round,
            latency_secs: rec.latency_secs,
            at_secs: to_secs(now),
        });
        self.telemetry
            .span_end(SpanKind::Round, job, rec.round, 0, now);
        // GC the round's MQ topic
        self.mq.drop_topic(&mq::update_topic(job, rec.round));
        let finished =
            self.jobs[job].finish_round(&mut self.q, &mut self.cluster, &self.mq, rec);
        if finished {
            self.job_finished(job);
        }
    }

    fn all_done(&self) -> bool {
        self.jobs.iter().all(|j| j.done)
    }

    /// Run every admitted job to completion; returns one report per job.
    pub fn run(self) -> Vec<JobReport> {
        self.run_with_stats().0
    }

    /// Like [`run`](Platform::run), but also returns end-of-run aggregates
    /// (span, total container-seconds, the admission controller) for the
    /// broker's utilization and queue-wait reporting.
    pub fn run_with_stats(self) -> (Vec<JobReport>, RunStats) {
        self.run_with_driver(&mut VirtualDriver)
    }

    /// Run the platform pulling events through an explicit [`Driver`] —
    /// the virtual driver for simulation (the default), or any other
    /// pacing regime a caller wants to impose on the same control loop.
    pub fn run_with_driver<D: Driver>(mut self, driver: &mut D) -> (Vec<JobReport>, RunStats) {
        // kick off round 0 of every non-deferred job; deferred jobs wait
        // for their JobArrival event + admission
        for job in 0..self.jobs.len() {
            if !self.jobs[job].deferred {
                self.q.schedule_at(0, EventKind::RoundStart { job, round: 0 });
            }
        }
        let mut safety: u64 = 0;
        // preemption decisions already streamed as events
        let mut preempt_seen: usize = 0;
        while let Some((_, ev)) = driver.next_event(&mut self.q, &self.mq) {
            safety += 1;
            debug_assert!(safety < 500_000_000, "runaway simulation");
            match ev {
                EventKind::RoundStart { job, round } => {
                    if !self.jobs[job].done && self.jobs[job].round == round {
                        self.start_round(job);
                    }
                }
                EventKind::UpdateArrival { job, round, party } => {
                    self.jobs[job].handle_update(
                        &mut self.q,
                        &mut self.cluster,
                        &self.mq,
                        round,
                        party,
                        ArrivalMode::Schedule,
                    );
                    self.poll_round_completion(job);
                }
                EventKind::TimerAlert { job, round } => {
                    self.jobs[job].on_timer(&mut self.q, &mut self.cluster, &self.mq, round);
                    self.poll_round_completion(job);
                }
                EventKind::ContainerDone { container } => {
                    if let Some(note) = self.cluster.advance(&mut self.q, container) {
                        let task = match &note {
                            crate::cluster::Notification::Deployed { task }
                            | crate::cluster::Notification::WorkItemDone { task }
                            | crate::cluster::Notification::WorkDrained { task }
                            | crate::cluster::Notification::TaskExited { task }
                            | crate::cluster::Notification::TaskPreempted { task } => *task,
                        };
                        let job = self.cluster.job_of(task);
                        self.jobs[job].on_note(&mut self.q, &mut self.cluster, &self.mq, &note);
                        self.poll_round_completion(job);
                    }
                }
                EventKind::Custom { tag } => {
                    // linger timer: tag = task id
                    let task = tag as usize;
                    let job = self.cluster.job_of(task);
                    self.jobs[job].on_linger(&mut self.q, &mut self.cluster, &self.mq, task);
                    self.poll_round_completion(job);
                }
                EventKind::SchedTick => {
                    self.cluster.on_tick(&mut self.q);
                    self.tick_scheduled = false;
                    if !self.all_done() {
                        self.ensure_tick();
                    }
                }
                EventKind::JobArrival { job } => {
                    self.on_job_arrival(job);
                }
                EventKind::RoundTimeout { .. } => {}
            }
            // stream any preemption decisions this dispatch produced
            self.events.stream_preemptions(&self.cluster, &mut preempt_seen);
        }
        let now = self.q.now();
        if self.telemetry.on() {
            // deploy/preempt spans come off the cluster's own records, so
            // recording them post-loop perturbs nothing and misses nothing
            for d in self.cluster.ledger() {
                self.telemetry
                    .span_begin(SpanKind::Deploy, d.job, 0, d.task as u64, d.start);
                self.telemetry
                    .span_end(SpanKind::Deploy, d.job, 0, d.task as u64, d.end.unwrap_or(now));
                self.telemetry
                    .counter_add("deployments_total", &Scope::job(d.job), 1);
            }
            for &(t, task) in self.cluster.preemption_log() {
                let job = self.cluster.job_of(task);
                self.telemetry
                    .span_instant(SpanKind::Preempt, job, 0, task as u64, t);
                self.telemetry
                    .counter_add("preemptions_total", &Scope::job(job), 1);
            }
            self.telemetry.flush();
        }
        let reports: Vec<JobReport> = self
            .jobs
            .iter()
            .enumerate()
            .map(|(job, j)| JobReport {
                strategy: j.strategy.name().to_string(),
                workload: j.spec.workload.name.to_string(),
                fleet: j.spec.fleet_kind.name().to_string(),
                parties: j.spec.n_parties,
                rounds: j.records.clone(),
                container_seconds: self.cluster.container_seconds(job, now),
                ancillary_seconds: j.spec.workload.ancillary_cs_per_round
                    * j.records.len() as f64,
                deployments: self.cluster.job_deployments(job),
                updates_fused: self.cluster.job_work_done(job),
                makespan_secs: to_secs(j.finished_at),
            })
            .collect();
        let stats = RunStats {
            end_secs: to_secs(now),
            total_container_seconds: self.cluster.total_container_seconds(now),
            admission: self.admission.take(),
            preemptions: self
                .cluster
                .preemption_log()
                .iter()
                .map(|&(t, task)| (to_secs(t), task))
                .collect(),
            fault_counts: self
                .jobs
                .iter()
                .map(|j| (j.updates_dropped, j.updates_decayed, j.rounds_skipped))
                .collect(),
        };
        (reports, stats)
    }
}

/// One-call scenario runner used by benches, examples and the CLI: one job,
/// one strategy, simulated fleet.
pub fn run_scenario(
    spec: &FlJobSpec,
    strategy: &str,
    seed: u64,
) -> JobReport {
    let mut cfg = PlatformConfig {
        seed,
        ..Default::default()
    };
    // capacity: always-on fleets + serverless shards for this job, plus slack
    cfg.cluster.capacity = scenario_capacity(spec);
    let mut p = Platform::new(cfg);
    p.admit(spec.clone(), strategy);
    p.run().remove(0)
}

/// The cluster capacity `run_scenario` provisions for a single job — also
/// used by the live runner so sim/live comparisons share one cluster
/// configuration.
pub fn scenario_capacity(spec: &FlJobSpec) -> usize {
    (spec.workload.n_agg(spec.n_parties) as usize * 4).max(64)
}

/// δ for scheduling decisions (§5.5) — re-exported for tests.
pub fn default_delta() -> Time {
    secs(0.5)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::party::FleetKind;
    use crate::workloads::Workload;

    fn spec(kind: FleetKind, n: usize, rounds: u32) -> FlJobSpec {
        FlJobSpec::new(Workload::cifar100_effnet(), kind, n, rounds)
    }

    #[test]
    fn jit_runs_all_rounds_with_low_latency() {
        let r = run_scenario(&spec(FleetKind::ActiveHomogeneous, 10, 5), "jit", 1);
        assert_eq!(r.rounds.len(), 5);
        assert!(r.mean_latency_secs() < 3.0, "latency {}", r.mean_latency_secs());
        assert_eq!(r.updates_fused, 50);
        assert!(r.container_seconds > 0.0);
    }

    #[test]
    fn strategies_complete_and_rank_by_cost() {
        let s = spec(FleetKind::ActiveHomogeneous, 10, 5);
        let jit = run_scenario(&s, "jit", 1);
        let batch = run_scenario(&s, "batched", 1);
        let eager = run_scenario(&s, "eager-serverless", 1);
        let ao = run_scenario(&s, "eager-ao", 1);
        for r in [&jit, &batch, &eager, &ao] {
            assert_eq!(r.rounds.len(), 5, "{} rounds", r.strategy);
            assert_eq!(r.updates_fused, 50, "{} fused", r.strategy);
        }
        // Fig 9 ordering
        assert!(
            jit.total_container_seconds() < eager.total_container_seconds(),
            "jit {} !< eager {}",
            jit.total_container_seconds(),
            eager.total_container_seconds()
        );
        assert!(
            eager.total_container_seconds() < ao.total_container_seconds(),
            "eager {} !< ao {}",
            eager.total_container_seconds(),
            ao.total_container_seconds()
        );
        assert!(
            jit.total_container_seconds() <= batch.total_container_seconds() * 1.05,
            "jit {} !<= batch {}",
            jit.total_container_seconds(),
            batch.total_container_seconds()
        );
    }

    #[test]
    fn intermittent_ao_pays_the_window() {
        let s = {
            let mut s = spec(FleetKind::IntermittentHeterogeneous, 10, 3);
            s.t_wait_secs = 120.0;
            s
        };
        let ao = run_scenario(&s, "eager-ao", 2);
        let jit = run_scenario(&s, "jit", 2);
        assert_eq!(ao.rounds.len(), 3);
        assert_eq!(jit.rounds.len(), 3);
        // AO holds containers through each 120s window
        assert!(
            ao.container_seconds > 3.0 * 100.0,
            "ao cs {}",
            ao.container_seconds
        );
        let sav = crate::metrics::savings_pct(&jit, &ao);
        assert!(sav > 90.0, "JIT vs AO savings {sav}%");
        assert!(jit.mean_latency_secs() < 5.0, "{}", jit.mean_latency_secs());
    }

    #[test]
    fn multi_job_sharing_one_cluster() {
        let mut p = Platform::new(PlatformConfig::default());
        p.admit(spec(FleetKind::ActiveHomogeneous, 8, 3), "jit");
        p.admit(spec(FleetKind::ActiveHomogeneous, 8, 3), "jit");
        let reports = p.run();
        assert_eq!(reports.len(), 2);
        for r in reports {
            assert_eq!(r.rounds.len(), 3);
            assert_eq!(r.updates_fused, 24);
        }
    }

    #[test]
    fn heterogeneous_estimates_still_accurate() {
        let r = run_scenario(&spec(FleetKind::ActiveHeterogeneous, 20, 5), "jit", 3);
        assert_eq!(r.rounds.len(), 5);
        // the paper's thesis: JIT latency stays eager-like even under
        // heterogeneity because training time is predictable
        assert!(r.mean_latency_secs() < 5.0, "latency {}", r.mean_latency_secs());
    }

    #[test]
    fn faulty_sim_runs_are_bit_identical_per_seed() {
        // satellite: same seed + same FleetFaults ⇒ bit-identical report
        let s = spec(FleetKind::ActiveHomogeneous, 10, 4);
        let run = |seed: u64, scenario: &str| {
            let mut cfg = PlatformConfig {
                seed,
                ..Default::default()
            };
            cfg.cluster.capacity = scenario_capacity(&s);
            cfg.faults = FleetFaults::scenario(scenario, 30.0).unwrap();
            let mut p = Platform::new(cfg);
            p.admit(s.clone(), "jit");
            p.run().remove(0)
        };
        for scenario in FleetFaults::all_scenarios() {
            let a = run(0xAB, scenario);
            let b = run(0xAB, scenario);
            assert_eq!(a.rounds.len(), b.rounds.len(), "{scenario}");
            for (x, y) in a.rounds.iter().zip(&b.rounds) {
                assert_eq!(
                    x.latency_secs.to_bits(),
                    y.latency_secs.to_bits(),
                    "{scenario} round {}",
                    x.round
                );
                assert_eq!(x.complete_secs.to_bits(), y.complete_secs.to_bits());
            }
            assert_eq!(a.updates_fused, b.updates_fused, "{scenario}");
            assert_eq!(a.deployments, b.deployments, "{scenario}");
        }
    }

    #[test]
    fn dropout_faults_shrink_fused_updates() {
        let s = spec(FleetKind::ActiveHomogeneous, 10, 4);
        let run = |faults: FleetFaults| {
            let mut cfg = PlatformConfig {
                seed: 0xF5,
                ..Default::default()
            };
            cfg.cluster.capacity = scenario_capacity(&s);
            cfg.faults = faults;
            let mut p = Platform::new(cfg);
            p.admit(s.clone(), "jit");
            p.run_with_stats()
        };
        let (clean, _) = run(FleetFaults::none());
        let (faulty, stats) = run(FleetFaults::scenario("dropout", 30.0).unwrap());
        assert_eq!(clean[0].updates_fused, 40, "10 parties × 4 rounds");
        assert!(
            faulty[0].updates_fused < clean[0].updates_fused,
            "dropped-out parties must not fuse ({} vs {})",
            faulty[0].updates_fused,
            clean[0].updates_fused
        );
        assert_eq!(stats.fault_counts.len(), 1);
    }

    #[test]
    fn explicit_virtual_driver_matches_default_run() {
        let s = spec(FleetKind::ActiveHomogeneous, 10, 3);
        let a = run_scenario(&s, "jit", 4);
        let mut cfg = PlatformConfig {
            seed: 4,
            ..Default::default()
        };
        cfg.cluster.capacity = scenario_capacity(&s);
        let mut p = Platform::new(cfg);
        p.admit(s, "jit");
        let b = p.run_with_driver(&mut VirtualDriver).0.remove(0);
        assert_eq!(a.rounds.len(), b.rounds.len());
        assert_eq!(a.updates_fused, b.updates_fused);
        assert_eq!(a.deployments, b.deployments);
        for (x, y) in a.rounds.iter().zip(&b.rounds) {
            assert_eq!(x.latency_secs, y.latency_secs);
            assert_eq!(x.complete_secs, y.complete_secs);
        }
    }
}
