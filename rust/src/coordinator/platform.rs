//! The multi-tenant simulation platform: drives FL jobs, parties, the
//! cluster, the MQ and the strategies through the discrete-event engine.
//!
//! This is the "JIT scheduler" box of Fig 5 plus the experiment driver of
//! §6: admits one or more [`FlJobSpec`]s, generates party fleets, runs
//! every round (arrival events → strategy → cluster), feeds the estimator
//! with observed timings (periodicity histories + the cross-party
//! linearity regressors), and produces a [`JobReport`] per job.
//!
//! Identical strategy code runs here (virtual time) and in
//! `coordinator::live` (wall time + real XLA fusion).

use crate::broker::admission::AdmissionController;
use crate::cluster::{Cluster, ClusterConfig};
use crate::coordinator::job::{FlJobSpec, JobParams};
use crate::coordinator::strategies::{self, Ctx, Strategy};
use crate::estimator::{
    estimate_round, LinearityModel, PeriodicityTracker, RoundEstimate,
};
use crate::metrics::{JobReport, RoundRecord};
use crate::mq::{self, MessageQueue, Message, Payload};
use crate::party::Fleet;
use crate::sim::{secs, to_secs, EventKind, EventQueue, Time};
use crate::util::rng::Rng;

/// One admitted job's runtime state.
struct JobState {
    spec: FlJobSpec,
    params: JobParams,
    fleet: Fleet,
    strategy: Box<dyn Strategy>,
    rng: Rng,
    round: u32,
    round_start: Time,
    arrived: usize,
    /// Periodicity histories per party (fed with observed timings).
    histories: Vec<PeriodicityTracker>,
    linearity: LinearityModel,
    records: Vec<RoundRecord>,
    done: bool,
    finished_at: Time,
    /// Broker path: round 0 is gated on a JobArrival event + admission
    /// control instead of starting at t = 0.
    deferred: bool,
}

/// Platform configuration.
#[derive(Clone, Debug)]
pub struct PlatformConfig {
    pub cluster: ClusterConfig,
    pub seed: u64,
    /// Disable JIT opportunism (pure deadline-timer JIT).
    pub opportunistic: bool,
    /// Override the JIT safety margin on t_agg (default 0.10) — ablation.
    pub jit_margin: Option<f64>,
    /// Override the batched-serverless trigger size — ablation.
    pub batch_override: Option<usize>,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        PlatformConfig {
            cluster: ClusterConfig {
                capacity: 4096,
                ..Default::default()
            },
            seed: 0xF17A,
            opportunistic: true,
            jit_margin: None,
            batch_override: None,
        }
    }
}

pub struct Platform {
    cfg: PlatformConfig,
    q: EventQueue,
    cluster: Cluster,
    mq: MessageQueue,
    jobs: Vec<JobState>,
    tick_scheduled: bool,
    /// Broker admission control; `None` = every job starts unconditionally.
    admission: Option<AdmissionController>,
}

/// End-of-run aggregates for the broker (`run_with_stats`).
#[derive(Debug)]
pub struct RunStats {
    /// Virtual time when the last event fired, seconds.
    pub end_secs: f64,
    /// Container-seconds across all jobs (aggregation only).
    pub total_container_seconds: f64,
    /// The admission controller handed back (queue-wait metrics).
    pub admission: Option<AdmissionController>,
}

impl Platform {
    pub fn new(cfg: PlatformConfig) -> Platform {
        Platform {
            cluster: Cluster::new(cfg.cluster.clone()),
            q: EventQueue::new(),
            mq: MessageQueue::new(),
            jobs: Vec::new(),
            tick_scheduled: false,
            admission: None,
            cfg,
        }
    }

    /// Admit a job with the given strategy. Returns the job id.
    pub fn admit(&mut self, spec: FlJobSpec, strategy_name: &str) -> usize {
        let job = self.jobs.len();
        let mut params = JobParams::derive(job, &spec);
        params.opportunistic = self.cfg.opportunistic;
        if let Some(m) = self.cfg.jit_margin {
            params.jit_margin = m;
        }
        if let Some(b) = self.cfg.batch_override {
            params.batch = b.max(1);
        }
        let mut rng = Rng::new(self.cfg.seed ^ (job as u64).wrapping_mul(0x9E3779B9));
        let fleet = Fleet::generate(
            spec.fleet_kind,
            spec.n_parties,
            spec.workload.fleet_params(),
            &mut rng,
        );
        let strategy = strategies::by_name(strategy_name)
            .unwrap_or_else(|| panic!("unknown strategy '{strategy_name}'"));
        let histories = vec![PeriodicityTracker::new(8); spec.n_parties];
        self.jobs.push(JobState {
            spec,
            params,
            fleet,
            strategy,
            rng,
            round: 0,
            round_start: 0,
            arrived: 0,
            histories,
            linearity: LinearityModel::default(),
            records: Vec::new(),
            done: false,
            finished_at: 0,
            deferred: false,
        });
        job
    }

    /// Broker path: submit a job that *arrives* at virtual time `at` and
    /// must pass the admission controller before its first round starts.
    pub fn submit_at(&mut self, spec: FlJobSpec, strategy_name: &str, at: Time) -> usize {
        let job = self.admit(spec, strategy_name);
        self.jobs[job].deferred = true;
        self.q.schedule_at(at, EventKind::JobArrival { job });
        job
    }

    /// Install the broker's admission controller (see `broker::admission`).
    pub fn set_admission(&mut self, ctrl: AdmissionController) {
        self.admission = Some(ctrl);
    }

    /// Mutable cluster access for the broker control plane (arbitration
    /// policy installation, per-job fair-share weights).
    pub fn cluster_mut(&mut self) -> &mut Cluster {
        &mut self.cluster
    }

    /// A job cleared admission (or has no controller): start round 0 now.
    fn release_job(&mut self, job: usize) {
        let now = self.q.now();
        self.q
            .schedule_at(now, EventKind::RoundStart { job, round: 0 });
    }

    fn on_job_arrival(&mut self, job: usize) {
        let now = self.q.now();
        let started = match self.admission.as_mut() {
            Some(ctrl) => ctrl.arrive(job, now),
            None => vec![job],
        };
        for j in started {
            self.release_job(j);
        }
    }

    fn estimate_for(&mut self, job: usize) -> RoundEstimate {
        let j = &mut self.jobs[job];
        let infos = j.fleet.infos(j.spec.report_prob, &mut j.rng);
        let cost = j.spec.workload.cost_model(j.spec.n_parties);
        estimate_round(
            &infos,
            j.spec.agg_frequency,
            j.spec.t_wait_secs,
            &cost,
            Some(&j.histories),
            &j.linearity,
        )
    }

    fn start_round(&mut self, job: usize) {
        let now = self.q.now();
        let est = self.estimate_for(job);
        let j = &mut self.jobs[job];
        let round = j.round;
        j.round_start = now;
        j.arrived = 0;
        // draw and schedule the actual arrivals
        let model_bytes = j.spec.workload.model.size_bytes();
        let offsets = j
            .fleet
            .arrival_offsets(model_bytes, j.spec.t_wait_secs, &mut j.rng);
        for (party, &off) in offsets.iter().enumerate() {
            self.q.schedule_at(
                now + off,
                EventKind::UpdateArrival { job, round, party },
            );
        }
        let params = j.params.clone();
        let mut ctx = Ctx {
            q: &mut self.q,
            cluster: &mut self.cluster,
            mq: &self.mq,
            params: &params,
        };
        if round == 0 {
            self.jobs[job].strategy.on_job_start(&mut ctx);
        }
        self.jobs[job].strategy.on_round_start(&mut ctx, round, &est);
        self.ensure_tick();
    }

    fn ensure_tick(&mut self) {
        if !self.tick_scheduled {
            self.tick_scheduled = true;
            self.q
                .schedule_in(self.cluster.cfg.delta_tick, EventKind::SchedTick);
        }
    }

    fn handle_update(&mut self, job: usize, round: u32, party: usize) {
        let now = self.q.now();
        let j = &mut self.jobs[job];
        if j.done || round != j.round {
            return; // stale arrival from a quorum-completed round
        }
        j.arrived += 1;
        let arrived = j.arrived;
        // feed the estimator with the *observed* timing (active parties):
        // train_time ≈ arrival_offset − estimated transfer time (§5.3)
        let p = &j.fleet.parties[party];
        if p.mode == crate::estimator::Mode::Active {
            let off = to_secs(now - j.round_start);
            let observed_train = (off - p.comm_secs(j.spec.workload.model.size_bytes())).max(0.0);
            j.histories[party].observe(observed_train);
            j.linearity.observe_epoch(p.dataset_items, observed_train);
            let mb = observed_train / (p.dataset_items / 32.0).max(1.0);
            j.linearity.observe_minibatch(p.hardware.score(), mb);
        }
        // buffer in the MQ (sim payload: size only)
        self.mq.produce(
            &mq::update_topic(job, round),
            Message {
                party,
                round,
                weight: p.dataset_items as f32,
                enqueued_at: now,
                payload: Payload::Sim {
                    size_bytes: j.spec.workload.model.size_bytes(),
                },
            },
        );
        let params = j.params.clone();
        let mut ctx = Ctx {
            q: &mut self.q,
            cluster: &mut self.cluster,
            mq: &self.mq,
            params: &params,
        };
        self.jobs[job].strategy.on_update(&mut ctx, round, party, arrived);
    }

    fn poll_round_completion(&mut self, job: usize) {
        let Some(rec) = self.jobs[job].strategy.take_completed() else {
            return;
        };
        let now = self.q.now();
        let j = &mut self.jobs[job];
        let round = rec.round;
        j.records.push(rec);
        // GC the round's MQ topic
        self.mq.drop_topic(&mq::update_topic(job, round));
        if round + 1 >= j.spec.rounds {
            j.done = true;
            j.finished_at = now;
            let params = j.params.clone();
            let mut ctx = Ctx {
                q: &mut self.q,
                cluster: &mut self.cluster,
                mq: &self.mq,
                params: &params,
            };
            self.jobs[job].strategy.on_job_end(&mut ctx);
            // a finished job frees committed admission demand: queued
            // jobs may start now (broker backpressure path)
            if let Some(ctrl) = self.admission.as_mut() {
                let released = ctrl.finish(job, now);
                for j in released {
                    self.release_job(j);
                }
            }
            return;
        }
        j.round = round + 1;
        // pacing: active jobs start the next round as soon as the fused
        // model is out; intermittent jobs run fixed t_wait windows (§4.3)
        let next_at = match j.spec.fleet_kind {
            crate::party::FleetKind::IntermittentHeterogeneous => {
                (j.round_start + j.params.t_wait).max(now)
            }
            _ => now,
        };
        self.q
            .schedule_at(next_at, EventKind::RoundStart { job, round: round + 1 });
    }

    fn all_done(&self) -> bool {
        self.jobs.iter().all(|j| j.done)
    }

    /// Run every admitted job to completion; returns one report per job.
    pub fn run(self) -> Vec<JobReport> {
        self.run_with_stats().0
    }

    /// Like [`run`](Platform::run), but also returns end-of-run aggregates
    /// (span, total container-seconds, the admission controller) for the
    /// broker's utilization and queue-wait reporting.
    pub fn run_with_stats(mut self) -> (Vec<JobReport>, RunStats) {
        // kick off round 0 of every non-deferred job; deferred jobs wait
        // for their JobArrival event + admission
        for job in 0..self.jobs.len() {
            if !self.jobs[job].deferred {
                self.q.schedule_at(0, EventKind::RoundStart { job, round: 0 });
            }
        }
        let mut safety: u64 = 0;
        while let Some((_, ev)) = self.q.next() {
            safety += 1;
            debug_assert!(safety < 500_000_000, "runaway simulation");
            match ev {
                EventKind::RoundStart { job, round } => {
                    if !self.jobs[job].done && self.jobs[job].round == round {
                        self.start_round(job);
                    }
                }
                EventKind::UpdateArrival { job, round, party } => {
                    self.handle_update(job, round, party);
                    self.poll_round_completion(job);
                }
                EventKind::TimerAlert { job, round } => {
                    if !self.jobs[job].done {
                        let params = self.jobs[job].params.clone();
                        let mut ctx = Ctx {
                            q: &mut self.q,
                            cluster: &mut self.cluster,
                            mq: &self.mq,
                            params: &params,
                        };
                        self.jobs[job].strategy.on_timer(&mut ctx, round);
                        self.poll_round_completion(job);
                    }
                }
                EventKind::ContainerDone { container } => {
                    if let Some(note) = self.cluster.advance(&mut self.q, container) {
                        let task = match &note {
                            crate::cluster::Notification::Deployed { task }
                            | crate::cluster::Notification::WorkItemDone { task }
                            | crate::cluster::Notification::WorkDrained { task }
                            | crate::cluster::Notification::TaskExited { task }
                            | crate::cluster::Notification::TaskPreempted { task } => *task,
                        };
                        let job = self.cluster.job_of(task);
                        let params = self.jobs[job].params.clone();
                        let mut ctx = Ctx {
                            q: &mut self.q,
                            cluster: &mut self.cluster,
                            mq: &self.mq,
                            params: &params,
                        };
                        self.jobs[job].strategy.on_note(&mut ctx, &note);
                        self.poll_round_completion(job);
                    }
                }
                EventKind::Custom { tag } => {
                    // linger timer: tag = task id
                    let task = tag as usize;
                    let job = self.cluster.job_of(task);
                    if !self.jobs[job].done {
                        let params = self.jobs[job].params.clone();
                        let mut ctx = Ctx {
                            q: &mut self.q,
                            cluster: &mut self.cluster,
                            mq: &self.mq,
                            params: &params,
                        };
                        self.jobs[job].strategy.on_linger(&mut ctx, task);
                        self.poll_round_completion(job);
                    }
                }
                EventKind::SchedTick => {
                    self.cluster.on_tick(&mut self.q);
                    self.tick_scheduled = false;
                    if !self.all_done() {
                        self.ensure_tick();
                    }
                }
                EventKind::JobArrival { job } => {
                    self.on_job_arrival(job);
                }
                EventKind::RoundTimeout { .. } => {}
            }
        }
        let now = self.q.now();
        let reports: Vec<JobReport> = self
            .jobs
            .iter()
            .enumerate()
            .map(|(job, j)| JobReport {
                strategy: j.strategy.name().to_string(),
                workload: j.spec.workload.name.to_string(),
                fleet: j.spec.fleet_kind.name().to_string(),
                parties: j.spec.n_parties,
                rounds: j.records.clone(),
                container_seconds: self.cluster.container_seconds(job, now),
                ancillary_seconds: j.spec.workload.ancillary_cs_per_round
                    * j.records.len() as f64,
                deployments: self.cluster.job_deployments(job),
                updates_fused: self.cluster.job_work_done(job),
                makespan_secs: to_secs(j.finished_at),
            })
            .collect();
        let stats = RunStats {
            end_secs: to_secs(now),
            total_container_seconds: self.cluster.total_container_seconds(now),
            admission: self.admission.take(),
        };
        (reports, stats)
    }
}

/// One-call scenario runner used by benches, examples and the CLI: one job,
/// one strategy, simulated fleet.
pub fn run_scenario(
    spec: &FlJobSpec,
    strategy: &str,
    seed: u64,
) -> JobReport {
    let mut cfg = PlatformConfig {
        seed,
        ..Default::default()
    };
    // capacity: always-on fleets + serverless shards for this job, plus slack
    cfg.cluster.capacity = (spec.workload.n_agg(spec.n_parties) as usize * 4).max(64);
    let mut p = Platform::new(cfg);
    p.admit(spec.clone(), strategy);
    p.run().remove(0)
}

/// δ for scheduling decisions (§5.5) — re-exported for tests.
pub fn default_delta() -> Time {
    secs(0.5)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::party::FleetKind;
    use crate::workloads::Workload;

    fn spec(kind: FleetKind, n: usize, rounds: u32) -> FlJobSpec {
        FlJobSpec::new(Workload::cifar100_effnet(), kind, n, rounds)
    }

    #[test]
    fn jit_runs_all_rounds_with_low_latency() {
        let r = run_scenario(&spec(FleetKind::ActiveHomogeneous, 10, 5), "jit", 1);
        assert_eq!(r.rounds.len(), 5);
        assert!(r.mean_latency_secs() < 3.0, "latency {}", r.mean_latency_secs());
        assert_eq!(r.updates_fused, 50);
        assert!(r.container_seconds > 0.0);
    }

    #[test]
    fn strategies_complete_and_rank_by_cost() {
        let s = spec(FleetKind::ActiveHomogeneous, 10, 5);
        let jit = run_scenario(&s, "jit", 1);
        let batch = run_scenario(&s, "batched", 1);
        let eager = run_scenario(&s, "eager-serverless", 1);
        let ao = run_scenario(&s, "eager-ao", 1);
        for r in [&jit, &batch, &eager, &ao] {
            assert_eq!(r.rounds.len(), 5, "{} rounds", r.strategy);
            assert_eq!(r.updates_fused, 50, "{} fused", r.strategy);
        }
        // Fig 9 ordering
        assert!(
            jit.total_container_seconds() < eager.total_container_seconds(),
            "jit {} !< eager {}",
            jit.total_container_seconds(),
            eager.total_container_seconds()
        );
        assert!(
            eager.total_container_seconds() < ao.total_container_seconds(),
            "eager {} !< ao {}",
            eager.total_container_seconds(),
            ao.total_container_seconds()
        );
        assert!(
            jit.total_container_seconds() <= batch.total_container_seconds() * 1.05,
            "jit {} !<= batch {}",
            jit.total_container_seconds(),
            batch.total_container_seconds()
        );
    }

    #[test]
    fn intermittent_ao_pays_the_window() {
        let s = {
            let mut s = spec(FleetKind::IntermittentHeterogeneous, 10, 3);
            s.t_wait_secs = 120.0;
            s
        };
        let ao = run_scenario(&s, "eager-ao", 2);
        let jit = run_scenario(&s, "jit", 2);
        assert_eq!(ao.rounds.len(), 3);
        assert_eq!(jit.rounds.len(), 3);
        // AO holds containers through each 120s window
        assert!(
            ao.container_seconds > 3.0 * 100.0,
            "ao cs {}",
            ao.container_seconds
        );
        let sav = crate::metrics::savings_pct(&jit, &ao);
        assert!(sav > 90.0, "JIT vs AO savings {sav}%");
        assert!(jit.mean_latency_secs() < 5.0, "{}", jit.mean_latency_secs());
    }

    #[test]
    fn multi_job_sharing_one_cluster() {
        let mut p = Platform::new(PlatformConfig::default());
        p.admit(spec(FleetKind::ActiveHomogeneous, 8, 3), "jit");
        p.admit(spec(FleetKind::ActiveHomogeneous, 8, 3), "jit");
        let reports = p.run();
        assert_eq!(reports.len(), 2);
        for r in reports {
            assert_eq!(r.rounds.len(), 3);
            assert_eq!(r.updates_fused, 24);
        }
    }

    #[test]
    fn heterogeneous_estimates_still_accurate() {
        let r = run_scenario(&spec(FleetKind::ActiveHeterogeneous, 20, 5), "jit", 3);
        assert_eq!(r.rounds.len(), 5);
        // the paper's thesis: JIT latency stays eager-like even under
        // heterogeneity because training time is predictable
        assert!(r.mean_latency_secs() < 5.0, "latency {}", r.mean_latency_secs());
    }
}
