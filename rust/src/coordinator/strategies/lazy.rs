//! Lazy Dynamic/Serverless (§3): schedule the aggregator for *all* updates
//! only after the last one arrives.
//!
//! Optimal cluster utilization, worst aggregation latency — the whole
//! N-update fusion (plus deployment overhead) happens after `t_rnd`, so
//! latency grows linearly with the fleet ("aggregation latency grows
//! quickly as the number of parties increases"; for some jobs aggregation
//! can dominate training). Included for the Fig 2 timeline and the
//! ablation bench; the paper's Fig 7-9 grids compare the other four.
//! Runs unmodified under the live wall-clock driver (`fljit live
//! --strategy lazy`).

use super::{Ctx, RoundTracker, Strategy};
use crate::cluster::{Notification, TaskSpec};
use crate::metrics::RoundRecord;

#[derive(Default)]
pub struct Lazy {
    tracker: RoundTracker,
}

impl Strategy for Lazy {
    fn name(&self) -> &'static str {
        "lazy"
    }

    fn on_round_start(&mut self, ctx: &mut Ctx, round: u32, _est: &crate::estimator::RoundEstimate) {
        self.tracker.begin(round, ctx.q.now());
    }

    fn on_update(&mut self, ctx: &mut Ctx, _round: u32, _party: usize, arrived: usize) {
        self.tracker.note_arrival(ctx.q.now());
        if arrived < ctx.params.quorum {
            return;
        }
        // Last update in: deploy n_agg containers over sharded work.
        for shard in ctx.params.shard_sizes() {
            if shard == 0 {
                continue;
            }
            let task = ctx.cluster.submit(TaskSpec {
                job: ctx.params.job,
                round: self.tracker.round,
                priority: 0,
                cold_start: ctx.params.cold_start,
                state_load: ctx.params.state_load,
                checkpoint: ctx.params.checkpoint,
                keep_alive: false,
            });
            ctx.cluster.push_work(ctx.q, task, &vec![ctx.params.item; shard]);
            ctx.cluster.request_finish(ctx.q, task);
            ctx.cluster.force_start(ctx.q, task);
            self.tracker.open_tasks.push(task);
        }
    }

    fn on_note(&mut self, ctx: &mut Ctx, note: &Notification) {
        match note {
            Notification::WorkItemDone { .. } => self.tracker.note_fused(),
            Notification::TaskExited { task } => {
                self.tracker.close_task(*task);
                self.tracker.maybe_complete(ctx.params.quorum, ctx.q.now());
            }
            _ => {}
        }
    }

    fn take_completed(&mut self) -> Option<RoundRecord> {
        self.tracker.completed.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterConfig};
    use crate::coordinator::job::{FlJobSpec, JobParams};
    use crate::coordinator::strategies::testutil::pump;
    use crate::mq::MessageQueue;
    use crate::party::FleetKind;
    use crate::sim::{secs, to_secs, EventQueue};
    use crate::workloads::Workload;

    #[test]
    fn deploys_only_after_last_update_and_latency_scales() {
        let spec = FlJobSpec::new(
            Workload::cifar100_effnet(),
            FleetKind::ActiveHomogeneous,
            20,
            1,
        );
        let mut params = JobParams::derive(0, &spec);
        params.n_agg = 1;
        let mut q = EventQueue::new();
        let mut cluster = Cluster::new(ClusterConfig::default());
        let mq = MessageQueue::new();
        let mut s = Lazy::default();
        let est = crate::estimator::RoundEstimate {
            t_upd: vec![],
            t_rnd: 0.0,
            t_agg: 0.0,
        };
        {
            let mut ctx = Ctx {
                q: &mut q,
                cluster: &mut cluster,
                mq: &mq,
                params: &params,
            };
            s.on_round_start(&mut ctx, 0, &est);
        }
        for i in 0..20 {
            q.schedule_at(
                secs(i as f64),
                crate::sim::EventKind::UpdateArrival {
                    job: 0,
                    round: 0,
                    party: i,
                },
            );
        }
        let mut arrived = 0;
        let mut records = Vec::new();
        while let Some((_, ev)) = q.next() {
            match ev {
                crate::sim::EventKind::UpdateArrival { party, .. } => {
                    arrived += 1;
                    assert_eq!(cluster.job_deployments(0), 0, "nothing before last update");
                    let mut ctx = Ctx {
                        q: &mut q,
                        cluster: &mut cluster,
                        mq: &mq,
                        params: &params,
                    };
                    s.on_update(&mut ctx, 0, party, arrived);
                }
                crate::sim::EventKind::ContainerDone { container } => {
                    if let Some(n) = cluster.advance(&mut q, container) {
                        let mut ctx = Ctx {
                            q: &mut q,
                            cluster: &mut cluster,
                            mq: &mq,
                            params: &params,
                        };
                        s.on_note(&mut ctx, &n);
                    }
                }
                _ => {}
            }
            if let Some(r) = s.take_completed() {
                records.push(r);
            }
        }
        assert_eq!(records.len(), 1);
        assert_eq!(cluster.job_deployments(0), 1);
        // latency = overheads + 20 merges + checkpoint, all after t_rnd
        let expect = to_secs(params.cold_start + params.state_load + params.checkpoint)
            + 20.0 * to_secs(params.item);
        assert!(
            (records[0].latency_secs - expect).abs() < 0.01,
            "latency {} vs expected {}",
            records[0].latency_secs,
            expect
        );
    }

    #[test]
    fn shards_across_n_agg() {
        let spec = FlJobSpec::new(
            Workload::cifar100_effnet(),
            FleetKind::ActiveHomogeneous,
            12,
            1,
        );
        let mut params = JobParams::derive(0, &spec);
        params.n_agg = 4;
        let mut q = EventQueue::new();
        let mut cluster = Cluster::new(ClusterConfig::default());
        let mq = MessageQueue::new();
        let mut s = Lazy::default();
        let est = crate::estimator::RoundEstimate {
            t_upd: vec![],
            t_rnd: 0.0,
            t_agg: 0.0,
        };
        {
            let mut ctx = Ctx {
                q: &mut q,
                cluster: &mut cluster,
                mq: &mq,
                params: &params,
            };
            s.on_round_start(&mut ctx, 0, &est);
            for i in 0..12 {
                s.on_update(&mut ctx, 0, i, i + 1);
            }
        }
        let mut records = Vec::new();
        pump(&mut q, &mut cluster, &mq, &params, &mut s, &mut records);
        assert_eq!(records.len(), 1);
        assert_eq!(cluster.job_deployments(0), 4, "one per shard");
        assert_eq!(cluster.job_work_done(0), 12);
    }
}
