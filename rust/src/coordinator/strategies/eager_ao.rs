//! Eager Always-On (§3): the IBM-FL / FATE / NVFLARE deployment model.
//!
//! A fleet of `n_agg` long-lived aggregator containers per job, deployed at
//! job admission and alive until the job ends — busy while updates stream
//! in, idle the rest of the time (the light-grey stretches of Fig 2).
//! Each container receives its shard of updates itself (serial ingress —
//! no MQ buffering in front), so each update costs
//! `ao_item = M/B_ingress + t_pair/C_agg`. Two effects balloon AO's
//! container-seconds in Fig 9: the fleet idles through every round window
//! (the whole `t_wait` for intermittent jobs), and at scale serial ingest
//! stretches the rounds themselves.
//!
//! Latency semantics (§6.2): latency is measured from the *reception* of
//! the last update; the always-on server merges each update right after
//! receiving it, so its per-round latency is just the final merge,
//! `t_pair/C_agg` — minimal, which is the one thing AO is good at.
//!
//! Runs unmodified under the live wall-clock driver (`fljit live
//! --strategy eager-ao`): the long-lived container idles through real
//! round windows, which is exactly the busy-second baseline the live
//! JIT savings are measured against.

use super::{Ctx, RoundTracker, Strategy};
use crate::cluster::{Notification, TaskId, TaskSpec};
use crate::metrics::RoundRecord;
use crate::sim::to_secs;

#[derive(Default)]
pub struct EagerAlwaysOn {
    fleet: Vec<TaskId>,
    tracker: RoundTracker,
    /// Updates fused across the whole job (AO work queues span rounds).
    fused_total: u64,
    round_target: u64,
    rr: usize,
}

impl Strategy for EagerAlwaysOn {
    fn name(&self) -> &'static str {
        "eager-ao"
    }

    fn on_job_start(&mut self, ctx: &mut Ctx) {
        // Deployed continuously throughout the FL job (one per shard).
        for _ in 0..ctx.params.n_agg.max(1) {
            let task = ctx.cluster.submit(TaskSpec {
                job: ctx.params.job,
                round: 0,
                priority: 0, // always-on: effectively unpreemptible foreground
                cold_start: ctx.params.cold_start,
                state_load: ctx.params.state_load,
                checkpoint: ctx.params.checkpoint,
                keep_alive: true,
            });
            ctx.cluster.force_start(ctx.q, task);
            self.fleet.push(task);
        }
    }

    fn on_round_start(&mut self, ctx: &mut Ctx, round: u32, _est: &crate::estimator::RoundEstimate) {
        self.tracker.begin(round, ctx.q.now());
        self.round_target = self.fused_total + ctx.params.quorum as u64;
    }

    fn on_update(&mut self, ctx: &mut Ctx, _round: u32, _party: usize, _arrived: usize) {
        self.tracker.note_arrival(ctx.q.now());
        let task = self.fleet[self.rr % self.fleet.len()];
        self.rr += 1;
        ctx.cluster.push_work(ctx.q, task, &[ctx.params.ao_item]);
    }

    fn on_note(&mut self, ctx: &mut Ctx, note: &Notification) {
        // The queue-draining item surfaces as WorkDrained, not WorkItemDone.
        if let Notification::WorkItemDone { task } | Notification::WorkDrained { task } = note {
            if !self.fleet.contains(task) {
                return;
            }
            self.fused_total += 1;
            self.tracker.note_fused();
            if self.fused_total >= self.round_target && !self.tracker.done {
                self.tracker.done = true;
                // Reception of the last update is the end of its ingest;
                // the merge component after reception is the latency.
                let merge = to_secs(ctx.params.item);
                let now = ctx.q.now();
                self.tracker.completed = Some(RoundRecord {
                    round: self.tracker.round,
                    latency_secs: merge,
                    last_arrival_secs: to_secs(now) - merge,
                    complete_secs: to_secs(now),
                });
            }
        }
    }

    fn on_job_end(&mut self, ctx: &mut Ctx) {
        for &task in &self.fleet {
            ctx.cluster.request_finish(ctx.q, task);
        }
    }

    fn take_completed(&mut self) -> Option<RoundRecord> {
        self.tracker.completed.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterConfig};
    use crate::coordinator::job::{FlJobSpec, JobParams};
    use crate::mq::MessageQueue;
    use crate::party::FleetKind;
    use crate::sim::{EventKind, EventQueue};
    use crate::workloads::Workload;

    fn setup() -> (EventQueue, Cluster, MessageQueue, JobParams) {
        let spec = FlJobSpec::new(
            Workload::cifar100_effnet(),
            FleetKind::ActiveHomogeneous,
            4,
            2,
        );
        (
            EventQueue::new(),
            Cluster::new(ClusterConfig::default()),
            MessageQueue::new(),
            JobParams::derive(0, &spec),
        )
    }

    #[test]
    fn single_container_spans_rounds() {
        let (mut q, mut cluster, mq, params) = setup();
        assert_eq!(params.n_agg, 1);
        let mut s = EagerAlwaysOn::default();
        let est = crate::estimator::RoundEstimate {
            t_upd: vec![],
            t_rnd: 0.0,
            t_agg: 0.0,
        };
        {
            let mut ctx = Ctx {
                q: &mut q,
                cluster: &mut cluster,
                mq: &mq,
                params: &params,
            };
            s.on_job_start(&mut ctx);
            s.on_round_start(&mut ctx, 0, &est);
            for i in 0..4 {
                s.on_update(&mut ctx, 0, i, i + 1);
            }
        }
        // drive events
        let mut records = Vec::new();
        while let Some((_, ev)) = q.next() {
            if let EventKind::ContainerDone { container } = ev {
                let note = cluster.advance(&mut q, container);
                if let Some(n) = note {
                    let mut ctx = Ctx {
                        q: &mut q,
                        cluster: &mut cluster,
                        mq: &mq,
                        params: &params,
                    };
                    s.on_note(&mut ctx, &n);
                    if let Some(r) = s.take_completed() {
                        records.push(r);
                    }
                }
            }
        }
        assert_eq!(records.len(), 1);
        assert_eq!(cluster.job_deployments(0), 1, "one long-lived container");
        // latency is the merge component only
        assert!(records[0].latency_secs <= crate::sim::to_secs(params.item) + 1e-9);
        // container still alive (idle) until job end
        assert_eq!(cluster.phase(s.fleet[0]), crate::cluster::Phase::Idle);
        // AO item includes ingest: slower than the serverless item
        assert!(params.ao_item > params.item);
    }

    #[test]
    fn fleet_scales_with_n_agg() {
        let spec = FlJobSpec::new(
            Workload::cifar100_effnet(),
            FleetKind::ActiveHomogeneous,
            200,
            1,
        );
        let params = JobParams::derive(0, &spec);
        assert!(params.n_agg > 1);
        let mut q = EventQueue::new();
        let mut cluster = Cluster::new(ClusterConfig {
            capacity: 1024,
            ..Default::default()
        });
        let mq = MessageQueue::new();
        let mut s = EagerAlwaysOn::default();
        let mut ctx = Ctx {
            q: &mut q,
            cluster: &mut cluster,
            mq: &mq,
            params: &params,
        };
        s.on_job_start(&mut ctx);
        assert_eq!(s.fleet.len(), params.n_agg);
        assert_eq!(cluster.job_deployments(0) as usize, params.n_agg);
    }
}
