//! Eager Serverless (§3, "Eager λ"): deploy an aggregator dynamically for
//! every update (or contiguous backlog of updates).
//!
//! Updates buffer in the MQ; each arrival either joins a live container's
//! queue or triggers a fresh deployment (cold start + state load). Drained
//! containers keep warm for a short linger, then checkpoint and exit —
//! at high arrival rates updates bunch onto live containers, which is how
//! the real Ray-based implementation amortizes deployments too. Up to
//! `n_agg` containers run concurrently. Runs unmodified under the live
//! wall-clock driver (`fljit live --strategy eager-serverless`).

use super::{Ctx, RoundTracker, Strategy};
use crate::cluster::{Notification, Phase, TaskId, TaskSpec};
use crate::metrics::RoundRecord;
use crate::sim::EventKind;

#[derive(Default)]
pub struct EagerServerless {
    tracker: RoundTracker,
    /// Live (or starting) containers, newest last.
    pool: Vec<TaskId>,
    rr: usize,
}

impl EagerServerless {
    fn live_target(&mut self, ctx: &mut Ctx) -> TaskId {
        // Prune exited containers so the pool stays O(n_agg) even when a
        // round sees thousands of deployments (10k-party grids).
        {
            let cluster = &*ctx.cluster;
            self.pool.retain(|&t| {
                !matches!(cluster.phase(t), Phase::Done | Phase::Checkpointing)
            });
        }
        // Prefer a container that is already up; round-robin for balance.
        let live: Vec<TaskId> = self
            .pool
            .iter()
            .copied()
            .filter(|&t| {
                matches!(
                    ctx.cluster.phase(t),
                    Phase::Pending | Phase::Starting | Phase::Running | Phase::Idle
                )
            })
            .collect();
        if !live.is_empty() && (live.len() >= ctx.params.n_agg || !ctx.cluster.has_capacity()) {
            self.rr = (self.rr + 1) % live.len();
            return live[self.rr];
        }
        if let Some(&t) = live.iter().find(|&&t| ctx.cluster.pending_work(t) == 0) {
            // an idle container takes the update without a new deployment
            return t;
        }
        if live.len() >= ctx.params.n_agg {
            self.rr = (self.rr + 1) % live.len();
            return live[self.rr];
        }
        // fresh deployment
        let task = ctx.cluster.submit(TaskSpec {
            job: ctx.params.job,
            round: self.tracker.round,
            priority: 0,
            cold_start: ctx.params.cold_start,
            state_load: ctx.params.state_load,
            checkpoint: ctx.params.checkpoint,
            keep_alive: false,
        });
        ctx.cluster.force_start(ctx.q, task);
        self.pool.push(task);
        self.tracker.open_tasks.push(task);
        task
    }
}

impl Strategy for EagerServerless {
    fn name(&self) -> &'static str {
        "eager-serverless"
    }

    fn on_round_start(&mut self, ctx: &mut Ctx, round: u32, _est: &crate::estimator::RoundEstimate) {
        self.tracker.begin(round, ctx.q.now());
        self.pool.clear();
    }

    fn on_update(&mut self, ctx: &mut Ctx, _round: u32, _party: usize, _arrived: usize) {
        self.tracker.note_arrival(ctx.q.now());
        let task = self.live_target(ctx);
        ctx.cluster.push_work(ctx.q, task, &[ctx.params.item]);
    }

    fn on_note(&mut self, ctx: &mut Ctx, note: &Notification) {
        match note {
            Notification::WorkItemDone { .. } => {
                self.tracker.note_fused();
            }
            Notification::WorkDrained { task } => {
                self.tracker.note_fused();
                // keep warm for `linger`, then exit if still idle
                ctx.q.schedule_in(
                    ctx.params.linger,
                    EventKind::Custom { tag: *task as u64 },
                );
            }
            Notification::TaskExited { task } => {
                self.tracker.close_task(*task);
                self.tracker.maybe_complete(ctx.params.quorum, ctx.q.now());
            }
            _ => {}
        }
    }

    fn on_linger(&mut self, ctx: &mut Ctx, task: TaskId) {
        if ctx.cluster.phase(task) == Phase::Idle && ctx.cluster.pending_work(task) == 0 {
            ctx.cluster.request_finish(ctx.q, task);
        }
    }

    fn take_completed(&mut self) -> Option<RoundRecord> {
        self.tracker.completed.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterConfig};
    use crate::coordinator::job::{FlJobSpec, JobParams};
    use crate::mq::MessageQueue;
    use crate::party::FleetKind;
    use crate::sim::{secs, EventQueue};
    use crate::workloads::Workload;
    use crate::coordinator::strategies::testutil::pump;

    #[test]
    fn bunched_updates_share_deployments() {
        let spec = FlJobSpec::new(
            Workload::cifar100_effnet(),
            FleetKind::ActiveHomogeneous,
            8,
            1,
        );
        let params = JobParams::derive(0, &spec);
        let mut q = EventQueue::new();
        let mut cluster = Cluster::new(ClusterConfig::default());
        let mq = MessageQueue::new();
        let mut s = EagerServerless::default();
        {
            let mut ctx = Ctx {
                q: &mut q,
                cluster: &mut cluster,
                mq: &mq,
                params: &params,
            };
            let est = crate::estimator::RoundEstimate {
                t_upd: vec![],
                t_rnd: 0.0,
                t_agg: 0.0,
            };
            s.on_round_start(&mut ctx, 0, &est);
            // all 8 updates arrive at once: they should share far fewer
            // than 8 deployments (n_agg=1 here)
            for i in 0..8 {
                s.on_update(&mut ctx, 0, i, i + 1);
            }
        }
        let mut records = Vec::new();
        pump(&mut q, &mut cluster, &mq, &params, &mut s, &mut records);
        assert_eq!(records.len(), 1, "round completes");
        assert!(
            cluster.job_deployments(0) <= 2,
            "bunched arrivals reuse containers: {} deployments",
            cluster.job_deployments(0)
        );
        // all 8 fused
        assert_eq!(cluster.job_work_done(0), 8);
        // latency small: last update merges soon after arrival
        assert!(records[0].latency_secs < 3.0, "{}", records[0].latency_secs);
    }

    #[test]
    fn spread_updates_cause_multiple_deployments() {
        let spec = FlJobSpec::new(
            Workload::cifar100_effnet(),
            FleetKind::ActiveHomogeneous,
            6,
            1,
        );
        let params = JobParams::derive(0, &spec);
        let mut q = EventQueue::new();
        let mut cluster = Cluster::new(ClusterConfig::default());
        let mq = MessageQueue::new();
        let mut s = EagerServerless::default();
        let est = crate::estimator::RoundEstimate {
            t_upd: vec![],
            t_rnd: 0.0,
            t_agg: 0.0,
        };
        {
            let mut ctx = Ctx {
                q: &mut q,
                cluster: &mut cluster,
                mq: &mq,
                params: &params,
            };
            s.on_round_start(&mut ctx, 0, &est);
        }
        let mut records = Vec::new();
        // arrivals 10s apart — far beyond the linger window
        for i in 0..6 {
            q.schedule_at(secs(10.0 * (i + 1) as f64), crate::sim::EventKind::UpdateArrival {
                job: 0,
                round: 0,
                party: i,
            });
        }
        while let Some((_, ev)) = q.next() {
            match ev {
                crate::sim::EventKind::UpdateArrival { party, .. } => {
                    let mut ctx = Ctx {
                        q: &mut q,
                        cluster: &mut cluster,
                        mq: &mq,
                        params: &params,
                    };
                    s.on_update(&mut ctx, 0, party, party + 1);
                }
                crate::sim::EventKind::ContainerDone { container } => {
                    if let Some(n) = cluster.advance(&mut q, container) {
                        let mut ctx = Ctx {
                            q: &mut q,
                            cluster: &mut cluster,
                            mq: &mq,
                            params: &params,
                        };
                        s.on_note(&mut ctx, &n);
                    }
                }
                crate::sim::EventKind::Custom { tag } => {
                    let mut ctx = Ctx {
                        q: &mut q,
                        cluster: &mut cluster,
                        mq: &mq,
                        params: &params,
                    };
                    s.on_linger(&mut ctx, tag as usize);
                }
                _ => {}
            }
            if let Some(r) = s.take_completed() {
                records.push(r);
            }
        }
        assert_eq!(records.len(), 1);
        assert_eq!(
            cluster.job_deployments(0),
            6,
            "spread arrivals each need a deployment"
        );
    }
}
