//! Just-in-Time aggregation (§5, Fig 6) — the paper's contribution.
//!
//! Per round:
//! 1. `on_round_start` receives the Fig 6 lines 6-13 estimate: per-party
//!    `t_upd`, `t_rnd = max t_upd`, `t_agg`. It submits `n_agg` aggregation
//!    tasks with **priority = t_rnd − t_agg** (absolute deadline; smaller =
//!    more urgent) and **SET_TIMER** at the same instant (lines 17-18).
//! 2. Updates buffer in the MQ as they arrive. The strategy holds their
//!    work back until either (a) the deadline timer fires — `FORCE_TRIGGER`
//!    (lines 19-21) deploys every task with its backlog; or (b)
//!    *opportunistically* (§5.5 "we would like to be greedy and use the
//!    cluster if it is idle"), a task's full work shard is already buffered
//!    — then the work is released and the δ-tick scheduler may start it
//!    early, in priority order, if the cluster has idle capacity. A task
//!    with no released work is never deployed ("if there are no pending
//!    updates to aggregate, the JIT scheduler defers aggregation tasks,
//!    while retaining their priority").
//! 3. Stragglers past the estimate stream into the already-live containers;
//!    once the quorum has arrived and all work is released, tasks are asked
//!    to finish (checkpoint publishes the fused model). Tasks whose shard
//!    never materialized are cancelled without ever deploying.
//!
//! The aggregation latency this yields is the tail merge + checkpoint —
//! eager-class latency at lazy-class cost.
//!
//! Time-regime agnostic: the deadline timer is an event scheduled at an
//! absolute `Time`, so under the live wall-clock driver it fires at the
//! real deadline and the identical policy runs in production mode
//! (`fljit live --strategy jit`).

use super::{Ctx, RoundTracker, Strategy};
use crate::cluster::{Notification, Phase, TaskId, TaskSpec};
use crate::estimator::RoundEstimate;
use crate::metrics::RoundRecord;
use crate::sim::{secs, EventId, EventKind, Time};

#[derive(Default)]
pub struct Jit {
    tracker: RoundTracker,
    /// This round's aggregation tasks (one per work shard / N_agg).
    tasks: Vec<TaskId>,
    /// Shard capacity per task.
    shard: Vec<usize>,
    /// Work buffered (held back) per task.
    buffered: Vec<usize>,
    /// Work released to the cluster per task.
    released: Vec<usize>,
    /// Whether the deadline timer fired already.
    triggered: bool,
    rr: usize,
    /// Live deadline-timer event for this round, canceled (O(1) lazy
    /// deletion) once the round completes instead of left to fire stale.
    timer: Option<EventId>,
    /// Deadline offsets measured for introspection/tests.
    pub last_deadline: Time,
}

impl Jit {
    fn release(&mut self, ctx: &mut Ctx, i: usize) {
        let n = self.buffered[i];
        if n == 0 {
            return;
        }
        self.buffered[i] = 0;
        self.released[i] += n;
        let task = self.tasks[i];
        ctx.cluster.push_work(ctx.q, task, &vec![ctx.params.item; n]);
    }

    fn release_all(&mut self, ctx: &mut Ctx) {
        for i in 0..self.tasks.len() {
            self.release(ctx, i);
        }
    }

    /// Ask finished-looking tasks to exit; cancel never-needed ones.
    fn finish_if_done(&mut self, ctx: &mut Ctx) {
        if !self.tracker.all_arrived(ctx.params.quorum) {
            return;
        }
        self.release_all(ctx);
        for (i, &task) in self.tasks.iter().enumerate() {
            if self.released[i] == 0 {
                // shard never got work — cancel without deploying
                if ctx.cluster.cancel(task) {
                    self.tracker.close_task(task);
                }
            } else {
                ctx.cluster.request_finish(ctx.q, task);
                // if it was deferred past its backlog (never started), the
                // deadline may already be here — make sure it runs now
                if self.triggered && ctx.cluster.phase(task) == Phase::Pending {
                    ctx.cluster.force_start(ctx.q, task);
                }
            }
        }
        self.tracker.maybe_complete(ctx.params.quorum, ctx.q.now());
        self.cancel_timer_if_done(ctx);
    }

    /// ROADMAP carried item: once the round has produced its record, the
    /// pending deadline timer is dead weight — cancel it in the engine
    /// rather than letting it fire as a stale no-op.
    fn cancel_timer_if_done(&mut self, ctx: &mut Ctx) {
        if self.tracker.done {
            if let Some(id) = self.timer.take() {
                ctx.q.cancel(id);
            }
        }
    }
}

impl Strategy for Jit {
    fn name(&self) -> &'static str {
        "jit"
    }

    fn on_round_start(&mut self, ctx: &mut Ctx, round: u32, est: &RoundEstimate) {
        self.tracker.begin(round, ctx.q.now());
        self.tasks.clear();
        self.shard = ctx.params.shard_sizes();
        self.buffered = vec![0; self.shard.len()];
        self.released = vec![0; self.shard.len()];
        self.triggered = false;
        self.rr = 0;

        // Defer point with safety margin: t_rnd − t_agg·(1+margin).
        let defer = est.defer_secs(ctx.params.jit_margin);
        let deadline_abs = ctx.q.now() + secs(defer);
        self.last_deadline = deadline_abs;

        // CREATE_AGGREGATORS + SET_PRIORITY (Fig 6 lines 15-17).
        // The N_agg shards deploy as one gang: the scheduler batches the
        // pod creations and the container image is pulled once per node,
        // so only the first shard pays the full cold start (the rest pay
        // an eighth — attach + namespace setup).
        for i in 0..self.shard.len() {
            let cold = if i == 0 {
                ctx.params.cold_start
            } else {
                ctx.params.cold_start / 8
            };
            let task = ctx.cluster.submit(TaskSpec {
                job: ctx.params.job,
                round,
                priority: deadline_abs as i64,
                cold_start: cold,
                state_load: ctx.params.state_load,
                checkpoint: ctx.params.checkpoint,
                keep_alive: false,
            });
            self.tasks.push(task);
            self.tracker.open_tasks.push(task);
        }
        // SET_TIMER (line 18). A previous round's timer that somehow
        // survived is stale by definition — cancel before re-arming.
        if let Some(id) = self.timer.take() {
            ctx.q.cancel(id);
        }
        self.timer = Some(ctx.q.schedule_at(
            deadline_abs,
            EventKind::TimerAlert {
                job: ctx.params.job,
                round,
            },
        ));
    }

    fn on_update(&mut self, ctx: &mut Ctx, _round: u32, _party: usize, _arrived: usize) {
        self.tracker.note_arrival(ctx.q.now());
        // Round-robin updates over shards.
        let i = self.rr % self.tasks.len();
        self.rr += 1;
        self.buffered[i] += 1;
        if self.triggered {
            self.release(ctx, i);
        } else if ctx.params.opportunistic
            && self.buffered[i] >= self.shard[i].max(1)
        {
            // A full shard is waiting: release it so the δ-tick scheduler
            // can start this task early if the cluster is idle (§5.5).
            self.release(ctx, i);
        }
        // A task that has received its entire shard will never get more
        // work — let it drain, checkpoint and exit rather than idle.
        if self.released[i] >= self.shard[i].max(1) {
            ctx.cluster.request_finish(ctx.q, self.tasks[i]);
        }
        self.finish_if_done(ctx);
    }

    fn armed_deadline(&self) -> Option<Time> {
        self.timer.map(|_| self.last_deadline)
    }

    /// Adaptive re-arm (PR 10): cancel the superseded deadline timer and
    /// insert a fresh one at `deadline_abs` (clamped at `now` — a
    /// learned deadline already in the past fires immediately, it never
    /// rewinds the clock). A round that already fused or force-triggered
    /// keeps its state: there is no timer left worth moving.
    fn rearm_deadline(&mut self, ctx: &mut Ctx, round: u32, deadline_abs: Time) {
        if round != self.tracker.round || self.tracker.done || self.triggered {
            return;
        }
        let Some(id) = self.timer.take() else {
            return;
        };
        ctx.q.cancel(id);
        let at = deadline_abs.max(ctx.q.now());
        self.last_deadline = at;
        self.timer = Some(ctx.q.schedule_at(
            at,
            EventKind::TimerAlert {
                job: ctx.params.job,
                round,
            },
        ));
    }

    fn on_timer(&mut self, ctx: &mut Ctx, round: u32) {
        if round == self.tracker.round {
            // this round's timer just fired; nothing left to cancel
            self.timer = None;
        }
        if round != self.tracker.round || self.triggered {
            return;
        }
        // TIMER_ALERT → FORCE_TRIGGER for tasks not already executing
        // (Fig 6 lines 19-21).
        self.triggered = true;
        self.release_all(ctx);
        for (i, &task) in self.tasks.iter().enumerate() {
            if self.released[i] > 0 && ctx.cluster.phase(task) == Phase::Pending {
                ctx.cluster.force_start(ctx.q, task);
            }
        }
        self.finish_if_done(ctx);
    }

    fn on_note(&mut self, ctx: &mut Ctx, note: &Notification) {
        match note {
            Notification::WorkItemDone { .. } | Notification::WorkDrained { .. } => {
                self.tracker.note_fused();
                self.tracker.maybe_complete(ctx.params.quorum, ctx.q.now());
                self.cancel_timer_if_done(ctx);
            }
            Notification::TaskExited { task } => {
                self.tracker.close_task(*task);
                self.tracker.maybe_complete(ctx.params.quorum, ctx.q.now());
                self.cancel_timer_if_done(ctx);
            }
            Notification::TaskPreempted { .. } => {
                // Work is conserved by the cluster; the task resumes by
                // priority at a later tick. Nothing to do.
            }
            _ => {}
        }
    }

    fn take_completed(&mut self) -> Option<RoundRecord> {
        self.tracker.completed.take()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterConfig};
    use crate::coordinator::job::{FlJobSpec, JobParams};
    use crate::mq::MessageQueue;
    use crate::party::FleetKind;
    use crate::sim::{to_secs, EventQueue};
    use crate::workloads::Workload;

    fn run_round(
        n: usize,
        arrivals: &[f64],
        est: RoundEstimate,
        opportunistic: bool,
    ) -> (Vec<RoundRecord>, Cluster, Jit, EventQueue) {
        let spec = FlJobSpec::new(
            Workload::cifar100_effnet(),
            FleetKind::ActiveHomogeneous,
            n,
            1,
        );
        let mut params = JobParams::derive(0, &spec);
        params.opportunistic = opportunistic;
        let mut q = EventQueue::new();
        let mut cluster = Cluster::new(ClusterConfig::default());
        let mq = MessageQueue::new();
        let mut s = Jit::default();
        {
            let mut ctx = Ctx {
                q: &mut q,
                cluster: &mut cluster,
                mq: &mq,
                params: &params,
            };
            s.on_round_start(&mut ctx, 0, &est);
        }
        for (i, &a) in arrivals.iter().enumerate() {
            q.schedule_at(
                crate::sim::secs(a),
                EventKind::UpdateArrival {
                    job: 0,
                    round: 0,
                    party: i,
                },
            );
        }
        // recurring δ-tick
        q.schedule_in(cluster.cfg.delta_tick, EventKind::SchedTick);
        let mut arrived = 0;
        let mut records = Vec::new();
        let mut ticks = 0;
        while let Some((_, ev)) = q.next() {
            match ev {
                EventKind::UpdateArrival { party, .. } => {
                    arrived += 1;
                    let mut ctx = Ctx {
                        q: &mut q,
                        cluster: &mut cluster,
                        mq: &mq,
                        params: &params,
                    };
                    s.on_update(&mut ctx, 0, party, arrived);
                }
                EventKind::TimerAlert { round, .. } => {
                    let mut ctx = Ctx {
                        q: &mut q,
                        cluster: &mut cluster,
                        mq: &mq,
                        params: &params,
                    };
                    s.on_timer(&mut ctx, round);
                }
                EventKind::ContainerDone { container } => {
                    if let Some(note) = cluster.advance(&mut q, container) {
                        let mut ctx = Ctx {
                            q: &mut q,
                            cluster: &mut cluster,
                            mq: &mq,
                            params: &params,
                        };
                        s.on_note(&mut ctx, &note);
                    }
                }
                EventKind::SchedTick => {
                    cluster.on_tick(&mut q);
                    ticks += 1;
                    if ticks < 10_000 && records.is_empty() {
                        q.schedule_in(cluster.cfg.delta_tick, EventKind::SchedTick);
                    }
                }
                _ => {}
            }
            if let Some(r) = s.take_completed() {
                records.push(r);
            }
        }
        (records, cluster, s, q)
    }

    fn exact_estimate(arrivals: &[f64], t_agg: f64) -> RoundEstimate {
        RoundEstimate {
            t_upd: arrivals.to_vec(),
            t_rnd: arrivals.iter().cloned().fold(0.0, f64::max),
            t_agg,
        }
    }

    #[test]
    fn single_deferred_deployment_with_exact_estimates() {
        // Fig 2 scenario: 6 parties over 20s, aggregation deferred.
        let arrivals: Vec<f64> = (1..=6).map(|i| i as f64 * 20.0 / 6.0).collect();
        let est = exact_estimate(&arrivals, 2.0);
        let (records, cluster, s, _q) = run_round(6, &arrivals, est, false);
        assert_eq!(records.len(), 1);
        assert_eq!(cluster.job_deployments(0), 1, "one just-in-time deployment");
        assert_eq!(cluster.job_work_done(0), 6);
        // deadline = 20 − 2·1.1 = 17.8s
        assert!((to_secs(s.last_deadline) - 17.8).abs() < 0.01);
        // latency: tail merges + checkpoint, well under eager-AO round time
        assert!(
            records[0].latency_secs < 1.5,
            "latency {}",
            records[0].latency_secs
        );
    }

    #[test]
    fn container_seconds_far_below_always_on() {
        let arrivals: Vec<f64> = (1..=10).map(|i| i as f64 * 2.0).collect();
        let est = exact_estimate(&arrivals, 1.0);
        let (records, cluster, _s, q) = run_round(10, &arrivals, est, false);
        assert_eq!(records.len(), 1);
        let cs = cluster.container_seconds(0, q.now());
        // AO would hold a container for the full ~20s round.
        assert!(cs < 5.0, "JIT used {cs} container-seconds");
    }

    #[test]
    fn late_stragglers_stream_into_live_container() {
        // estimate says 10s, but one party is 5s late
        let arrivals = vec![2.0, 4.0, 6.0, 8.0, 15.0];
        let est = RoundEstimate {
            t_upd: vec![2.0, 4.0, 6.0, 8.0, 10.0],
            t_rnd: 10.0,
            t_agg: 1.0,
        };
        let (records, cluster, _s, _q) = run_round(5, &arrivals, est, false);
        assert_eq!(records.len(), 1);
        assert_eq!(cluster.job_work_done(0), 5, "straggler still fused");
        // single deployment despite the misprediction
        assert_eq!(cluster.job_deployments(0), 1);
        // latency still tail-merge sized
        assert!(records[0].latency_secs < 1.5);
    }

    #[test]
    fn early_arrivals_with_opportunism_start_before_deadline() {
        // all updates arrive by t=3 but the estimate defers to ~18
        let arrivals = vec![1.0, 2.0, 3.0];
        let est = RoundEstimate {
            t_upd: vec![18.0, 19.0, 20.0],
            t_rnd: 20.0,
            t_agg: 2.0,
        };
        let (records, _cluster, _s, q) = run_round(3, &arrivals, est.clone(), true);
        assert_eq!(records.len(), 1);
        // completes well before the deadline would have fired
        assert!(
            records[0].complete_secs < 10.0,
            "opportunistic run finished at {}",
            records[0].complete_secs
        );
        assert!(q.now() < crate::sim::secs(30.0));
    }

    #[test]
    fn without_opportunism_waits_for_deadline() {
        let arrivals = vec![1.0, 2.0, 3.0];
        let est = RoundEstimate {
            t_upd: vec![18.0, 19.0, 20.0],
            t_rnd: 20.0,
            t_agg: 2.0,
        };
        let (records, _cluster, _s, _q) = run_round(3, &arrivals, est, false);
        assert_eq!(records.len(), 1);
        // quorum reached at t=3 releases work and finishes; pure-JIT would
        // have deployed at the deadline otherwise. Either way the round
        // completes; here all-arrived forces completion promptly.
        assert!(records[0].complete_secs <= 20.0);
    }

    #[test]
    fn completed_round_cancels_deadline_timer() {
        // All updates land early; the round completes long before the
        // 17.8s deadline. The timer must be canceled — the drain must
        // never pop a TimerAlert, so the sim clock never reaches the
        // deadline and the queue ends empty.
        let arrivals = vec![1.0, 2.0, 3.0];
        let est = RoundEstimate {
            t_upd: vec![18.0, 19.0, 20.0],
            t_rnd: 20.0,
            t_agg: 2.0,
        };
        let (records, _cluster, s, q) = run_round(3, &arrivals, est, true);
        assert_eq!(records.len(), 1);
        assert!(s.timer.is_none(), "completed round must cancel its timer");
        assert!(q.is_empty(), "no live events may remain after the drain");
        assert!(
            to_secs(q.now()) < 17.0,
            "canceled deadline timer fired anyway (clock at {})",
            to_secs(q.now())
        );
    }

    /// PR 2's canceled-timer guarantee, extended to PR 10's re-arming:
    /// when the adaptive policy moves a deadline mid-round, the
    /// superseded timer must be canceled via `EventQueue::cancel` and
    /// never fire a spurious fuse — exactly one `TimerAlert` (the
    /// re-armed one) may ever pop, and the drain must end before the
    /// original deadline.
    #[test]
    fn rearmed_deadline_cancels_superseded_timer_no_spurious_fuse() {
        let spec = FlJobSpec::new(
            Workload::cifar100_effnet(),
            FleetKind::ActiveHomogeneous,
            3,
            1,
        );
        let params = JobParams::derive(0, &spec);
        let mut q = EventQueue::new();
        let mut cluster = Cluster::new(ClusterConfig::default());
        let mq = MessageQueue::new();
        let mut s = Jit::default();
        // fixed estimate arms the fuse at 20 − 2·1.1 = 17.8s
        let est = RoundEstimate {
            t_upd: vec![18.0, 19.0, 20.0],
            t_rnd: 20.0,
            t_agg: 2.0,
        };
        {
            let mut ctx = Ctx {
                q: &mut q,
                cluster: &mut cluster,
                mq: &mq,
                params: &params,
            };
            s.on_round_start(&mut ctx, 0, &est);
        }
        let armed = s.armed_deadline().expect("jit arms a deadline timer");
        assert!((to_secs(armed) - 17.8).abs() < 0.01);
        // adaptive shortening: the learned estimate pulls the fuse in to 9s
        {
            let mut ctx = Ctx {
                q: &mut q,
                cluster: &mut cluster,
                mq: &mq,
                params: &params,
            };
            s.rearm_deadline(&mut ctx, 0, crate::sim::secs(9.0));
        }
        assert_eq!(s.armed_deadline(), Some(crate::sim::secs(9.0)));
        // arrivals land after the re-armed fuse but before the original
        // one — only the 9s timer may trigger them into the containers
        for (i, a) in [12.0, 13.0, 14.0].iter().enumerate() {
            q.schedule_at(
                crate::sim::secs(*a),
                EventKind::UpdateArrival {
                    job: 0,
                    round: 0,
                    party: i,
                },
            );
        }
        q.schedule_in(cluster.cfg.delta_tick, EventKind::SchedTick);
        let mut arrived = 0;
        let mut records = Vec::new();
        let mut timer_pops = 0;
        let mut ticks = 0;
        while let Some((_, ev)) = q.next() {
            match ev {
                EventKind::UpdateArrival { party, .. } => {
                    arrived += 1;
                    let mut ctx = Ctx {
                        q: &mut q,
                        cluster: &mut cluster,
                        mq: &mq,
                        params: &params,
                    };
                    s.on_update(&mut ctx, 0, party, arrived);
                }
                EventKind::TimerAlert { round, .. } => {
                    timer_pops += 1;
                    let mut ctx = Ctx {
                        q: &mut q,
                        cluster: &mut cluster,
                        mq: &mq,
                        params: &params,
                    };
                    s.on_timer(&mut ctx, round);
                }
                EventKind::ContainerDone { container } => {
                    if let Some(note) = cluster.advance(&mut q, container) {
                        let mut ctx = Ctx {
                            q: &mut q,
                            cluster: &mut cluster,
                            mq: &mq,
                            params: &params,
                        };
                        s.on_note(&mut ctx, &note);
                    }
                }
                EventKind::SchedTick => {
                    cluster.on_tick(&mut q);
                    ticks += 1;
                    if ticks < 10_000 && records.is_empty() {
                        q.schedule_in(cluster.cfg.delta_tick, EventKind::SchedTick);
                    }
                }
                _ => {}
            }
            if let Some(r) = s.take_completed() {
                records.push(r);
            }
        }
        assert_eq!(records.len(), 1, "round completes off the re-armed fuse");
        assert_eq!(
            timer_pops, 1,
            "exactly the re-armed timer fires; the superseded 17.8s one was canceled"
        );
        assert!(s.timer.is_none());
        assert!(q.is_empty(), "no live events may remain after the drain");
        assert!(
            to_secs(q.now()) < 17.0,
            "superseded deadline timer fired anyway (clock at {})",
            to_secs(q.now())
        );
        // re-arming a completed or force-triggered round is a no-op
        {
            let mut ctx = Ctx {
                q: &mut q,
                cluster: &mut cluster,
                mq: &mq,
                params: &params,
            };
            s.rearm_deadline(&mut ctx, 0, crate::sim::secs(30.0));
        }
        assert!(s.timer.is_none() && q.is_empty(), "no resurrection after done");
    }

    #[test]
    fn zero_work_shards_are_cancelled_not_deployed() {
        // n_agg larger than parties: extra shards must never deploy
        let spec = FlJobSpec::new(
            Workload::cifar100_effnet(),
            FleetKind::ActiveHomogeneous,
            2,
            1,
        );
        let mut params = JobParams::derive(0, &spec);
        params.n_agg = 4; // > parties... shard_sizes caps at n
        assert_eq!(params.shard_sizes().len(), 2);
        let arrivals = vec![1.0, 2.0];
        let est = exact_estimate(&arrivals, 0.5);
        let (records, cluster, _s, _q) = run_round(2, &arrivals, est, false);
        assert_eq!(records.len(), 1);
        assert!(cluster.job_deployments(0) <= 2);
    }
}
