//! The aggregation design options of §3, all implemented against the same
//! cluster/MQ substrates so their measured differences are *strategy*
//! differences, not implementation artifacts:
//!
//! * [`eager_ao::EagerAlwaysOn`] — IBM-FL-style long-lived aggregator.
//! * [`eager_serverless::EagerServerless`] — deploy per update/backlog.
//! * [`batched::BatchedServerless`] — deploy per batch of updates.
//! * [`lazy::Lazy`] — deploy once, after the last update.
//! * [`jit::Jit`] — the paper's contribution: deadline timer at
//!   `t_rnd − t_agg` + opportunistic priorities (§5.5, Fig 6).
//! * [`async_stale::AsyncStale`] — JIT's deploy schedule, but updates
//!   that miss the fuse deadline are folded with exponentially decayed
//!   weight instead of dropped ([`StalePolicy::Decay`]; the engine owns
//!   the decayed folds so both drivers share the state machine).
//!
//! A strategy is a pure event-driven policy: it never reads a clock or
//! sleeps, it only reacts to events and schedules future ones through
//! [`Ctx`]. That makes every implementation *time-regime agnostic* — the
//! same code runs under the simulator's virtual driver (Fig 7/8/9 grids)
//! and under the live wall-clock driver with real MQ traffic
//! (`coordinator::driver` has the Driver/Clock pair, `coordinator::live`
//! the wall deployment). `Ctx.q` is both the event scheduler and the
//! clock: `q.now()` is virtual µs in sim and wall µs live; an event
//! scheduled at `t` fires when the driver's clock reaches `t`.

pub mod async_stale;
pub mod batched;
pub mod eager_ao;
pub mod eager_serverless;
pub mod jit;
pub mod lazy;

use crate::cluster::{Cluster, Notification, TaskId};
use crate::coordinator::job::JobParams;
use crate::estimator::RoundEstimate;
use crate::metrics::RoundRecord;
use crate::mq::MessageQueue;
use crate::sim::{to_secs, EventQueue, Time};

/// Everything a strategy may touch while handling an event.
pub struct Ctx<'a> {
    /// The event queue *and clock* of the current time regime: virtual
    /// under the simulator's driver, wall-paced under the live driver.
    pub q: &'a mut EventQueue,
    /// The (emulated) serverless cluster the strategy deploys into.
    pub cluster: &'a mut Cluster,
    /// The zero-copy MQ buffering this job's updates — live mode's real
    /// transport, simulation's accounting substrate.
    pub mq: &'a MessageQueue,
    pub params: &'a JobParams,
}

/// What the engine does with an update that arrives after its round
/// already completed (it missed the fuse deadline).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum StalePolicy {
    /// Drop it — the classical synchronous-FL behavior (all strategies
    /// except `async-stale`).
    Drop,
    /// Fold it into the *current* round's aggregate with exponentially
    /// decayed weight `w · e^(−lambda · age_rounds)` (FedAsync-style
    /// staleness discounting).
    Decay { lambda: f64 },
}

/// The strategy interface — the platform routes events here.
pub trait Strategy {
    fn name(&self) -> &'static str;

    /// How the engine treats updates that miss the fuse deadline.
    /// Default: drop them (`async-stale` overrides with decay).
    fn stale_policy(&self) -> StalePolicy {
        StalePolicy::Drop
    }

    /// Job admitted (before round 0). AO deploys its long-lived container.
    fn on_job_start(&mut self, _ctx: &mut Ctx) {}

    /// A round began; `est` is the Fig 6 prediction for it.
    fn on_round_start(&mut self, ctx: &mut Ctx, round: u32, est: &RoundEstimate);

    /// A model update reached the MQ. `arrived` counts this round so far.
    fn on_update(&mut self, ctx: &mut Ctx, round: u32, party: usize, arrived: usize);

    /// JIT deadline timer (Fig 6 TIMER_ALERT). Others ignore it.
    fn on_timer(&mut self, _ctx: &mut Ctx, _round: u32) {}

    /// The absolute time this strategy's live fuse-deadline timer is
    /// armed at, if it runs one (`jit` / `async-stale`). The engine's
    /// adaptive policy (PR 10, [`crate::adapt`]) reads this to decide
    /// whether a learned deadline should supersede the fixed one.
    /// Default: no deadline timer.
    fn armed_deadline(&self) -> Option<Time> {
        None
    }

    /// Adaptive control: move the live fuse deadline to `deadline_abs`
    /// — the superseded timer MUST be canceled via `EventQueue::cancel`
    /// (never left to fire a spurious fuse) and a fresh one inserted.
    /// Strategies without a deadline timer ignore the signal (default
    /// no-op).
    fn rearm_deadline(&mut self, _ctx: &mut Ctx, _round: u32, _deadline_abs: Time) {}

    /// Keep-warm linger expired for `task`.
    fn on_linger(&mut self, _ctx: &mut Ctx, _task: TaskId) {}

    /// Cluster notification for one of this job's tasks.
    fn on_note(&mut self, ctx: &mut Ctx, note: &Notification);

    /// All rounds done — release long-lived resources.
    fn on_job_end(&mut self, _ctx: &mut Ctx) {}

    /// Completed-round record, if one finished since the last poll.
    fn take_completed(&mut self) -> Option<RoundRecord>;
}

/// Construct a strategy by name.
pub fn by_name(name: &str) -> Option<Box<dyn Strategy>> {
    match name {
        "jit" => Some(Box::new(jit::Jit::default())),
        "batched" | "batch" => Some(Box::new(batched::BatchedServerless::default())),
        "eager-serverless" | "eager" => {
            Some(Box::new(eager_serverless::EagerServerless::default()))
        }
        "eager-ao" | "ao" => Some(Box::new(eager_ao::EagerAlwaysOn::default())),
        "lazy" => Some(Box::new(lazy::Lazy::default())),
        "async-stale" | "async" => Some(Box::new(async_stale::AsyncStale::default())),
        _ => None,
    }
}

/// The strategy names of the Fig 7/8/9 comparison, paper order.
pub fn paper_strategies() -> &'static [&'static str] {
    &["jit", "batched", "eager-serverless", "eager-ao"]
}

/// Every strategy, paper order plus `lazy` and the staleness-tolerant
/// `async-stale` — all six run both simulated and live
/// (`fljit live --strategy <any of these>`).
pub fn all_strategies() -> &'static [&'static str] {
    &[
        "jit",
        "batched",
        "eager-serverless",
        "eager-ao",
        "lazy",
        "async-stale",
    ]
}

/// Shared per-round bookkeeping for the serverless strategies.
#[derive(Clone, Debug, Default)]
pub struct RoundTracker {
    pub round: u32,
    pub round_start: Time,
    pub arrived: usize,
    pub last_arrival: Time,
    pub fused: usize,
    /// Tasks opened for this round that have not exited yet.
    pub open_tasks: Vec<TaskId>,
    pub completed: Option<RoundRecord>,
    /// Set once the round has produced its record (guards duplicates from
    /// late notifications after `take_completed`).
    pub done: bool,
}

impl RoundTracker {
    pub fn begin(&mut self, round: u32, now: Time) {
        *self = RoundTracker {
            round,
            round_start: now,
            ..Default::default()
        };
    }

    pub fn note_arrival(&mut self, now: Time) {
        self.arrived += 1;
        self.last_arrival = now;
    }

    pub fn all_arrived(&mut self, quorum: usize) -> bool {
        self.arrived >= quorum
    }

    pub fn note_fused(&mut self) {
        self.fused += 1;
    }

    pub fn close_task(&mut self, task: TaskId) {
        self.open_tasks.retain(|&t| t != task);
    }

    /// Serverless completion: every expected update fused and every task
    /// exited (the final checkpoint published the fused model).
    pub fn maybe_complete(&mut self, quorum: usize, now: Time) {
        if !self.done && self.fused >= quorum && self.open_tasks.is_empty() {
            self.done = true;
            self.completed = Some(RoundRecord {
                round: self.round,
                latency_secs: to_secs(now.saturating_sub(self.last_arrival)),
                last_arrival_secs: to_secs(self.last_arrival),
                complete_secs: to_secs(now),
            });
        }
    }
}

/// Shared event pump for strategy unit tests.
#[cfg(test)]
pub mod testutil {
    use super::*;
    use crate::cluster::Cluster;
    use crate::metrics::RoundRecord;

    pub fn pump(
        q: &mut EventQueue,
        cluster: &mut Cluster,
        mq: &MessageQueue,
        params: &JobParams,
        s: &mut dyn Strategy,
        records: &mut Vec<RoundRecord>,
    ) {
        while let Some((_, ev)) = q.next() {
            match ev {
                crate::sim::EventKind::ContainerDone { container } => {
                    if let Some(n) = cluster.advance(q, container) {
                        let mut ctx = Ctx {
                            q,
                            cluster,
                            mq,
                            params,
                        };
                        s.on_note(&mut ctx, &n);
                    }
                }
                crate::sim::EventKind::Custom { tag } => {
                    let mut ctx = Ctx {
                        q,
                        cluster,
                        mq,
                        params,
                    };
                    s.on_linger(&mut ctx, tag as usize);
                }
                crate::sim::EventKind::SchedTick => {
                    cluster.on_tick(q);
                }
                crate::sim::EventKind::TimerAlert { round, .. } => {
                    let mut ctx = Ctx {
                        q,
                        cluster,
                        mq,
                        params,
                    };
                    s.on_timer(&mut ctx, round);
                }
                _ => {}
            }
            if let Some(r) = s.take_completed() {
                records.push(r);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_covers_paper_strategies() {
        for n in paper_strategies() {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert!(by_name("lazy").is_some());
        assert!(by_name("nope").is_none());
        assert_eq!(by_name("jit").unwrap().name(), "jit");
    }

    #[test]
    fn all_strategies_resolve_and_are_exactly_six() {
        assert_eq!(all_strategies().len(), 6);
        for n in all_strategies() {
            assert_eq!(by_name(n).unwrap().name(), *n, "{n}");
        }
    }

    #[test]
    fn only_async_stale_decays_stale_updates() {
        for n in all_strategies() {
            let s = by_name(n).unwrap();
            match s.stale_policy() {
                StalePolicy::Decay { lambda } => {
                    assert_eq!(*n, "async-stale");
                    assert!(lambda > 0.0);
                }
                StalePolicy::Drop => assert_ne!(*n, "async-stale"),
            }
        }
    }

    #[test]
    fn tracker_lifecycle() {
        let mut t = RoundTracker::default();
        t.begin(3, 100);
        t.note_arrival(200);
        t.note_arrival(500);
        assert!(t.all_arrived(2));
        assert!(!t.all_arrived(3));
        t.open_tasks.push(7);
        t.note_fused();
        t.note_fused();
        t.maybe_complete(2, 900);
        assert!(t.completed.is_none(), "task still open");
        t.close_task(7);
        t.maybe_complete(2, 900);
        let rec = t.completed.clone().unwrap();
        assert_eq!(rec.round, 3);
        assert!((rec.latency_secs - crate::sim::to_secs(400)).abs() < 1e-9);
    }
}
