//! Staleness-tolerant JIT (`async-stale`) — the sixth strategy.
//!
//! Deploy scheduling is *identical* to [`super::jit::Jit`]: defer the
//! aggregator gang to `t_rnd − t_agg·(1+margin)`, arm the deadline timer,
//! release opportunistically. The sole behavioral difference is the
//! [`StalePolicy`]: where every other strategy lets the engine **drop**
//! updates that arrive after their round already fused, `async-stale`
//! asks the engine to **fold them into the current round with
//! exponentially decayed weight** `w · e^(−λ · age_rounds)`
//! (FedAsync-style staleness discounting).
//!
//! The decayed fold itself lives in `JobEngine::handle_update`, not here
//! — the strategy only declares the policy — so the sim driver and the
//! live wall-clock driver share the degradation state machine verbatim.
//! On a healthy fleet (no late arrivals) `async-stale` is bit-identical
//! to `jit`.

use super::jit::Jit;
use super::{Ctx, StalePolicy, Strategy};
use crate::cluster::{Notification, TaskId};
use crate::estimator::RoundEstimate;
use crate::metrics::RoundRecord;

/// Decay rate λ for stale-update weights: one round of staleness keeps
/// ~50% of the update's weight, two rounds ~25%.
pub const DECAY_LAMBDA: f64 = 0.7;

/// JIT's deploy schedule + decayed folding of deadline-missers.
#[derive(Default)]
pub struct AsyncStale {
    inner: Jit,
}

impl Strategy for AsyncStale {
    fn name(&self) -> &'static str {
        "async-stale"
    }

    fn stale_policy(&self) -> StalePolicy {
        StalePolicy::Decay {
            lambda: DECAY_LAMBDA,
        }
    }

    fn on_job_start(&mut self, ctx: &mut Ctx) {
        self.inner.on_job_start(ctx);
    }

    fn on_round_start(&mut self, ctx: &mut Ctx, round: u32, est: &RoundEstimate) {
        self.inner.on_round_start(ctx, round, est);
    }

    fn on_update(&mut self, ctx: &mut Ctx, round: u32, party: usize, arrived: usize) {
        self.inner.on_update(ctx, round, party, arrived);
    }

    fn on_timer(&mut self, ctx: &mut Ctx, round: u32) {
        self.inner.on_timer(ctx, round);
    }

    fn armed_deadline(&self) -> Option<crate::sim::Time> {
        self.inner.armed_deadline()
    }

    fn rearm_deadline(&mut self, ctx: &mut Ctx, round: u32, deadline_abs: crate::sim::Time) {
        self.inner.rearm_deadline(ctx, round, deadline_abs);
    }

    fn on_linger(&mut self, ctx: &mut Ctx, task: TaskId) {
        self.inner.on_linger(ctx, task);
    }

    fn on_note(&mut self, ctx: &mut Ctx, note: &Notification) {
        self.inner.on_note(ctx, note);
    }

    fn on_job_end(&mut self, ctx: &mut Ctx) {
        self.inner.on_job_end(ctx);
    }

    fn take_completed(&mut self) -> Option<RoundRecord> {
        self.inner.take_completed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{Cluster, ClusterConfig};
    use crate::coordinator::job::{FlJobSpec, JobParams};
    use crate::mq::MessageQueue;
    use crate::party::FleetKind;
    use crate::sim::{EventKind, EventQueue};
    use crate::workloads::Workload;

    fn run_round(strategy: &mut dyn Strategy, arrivals: &[f64]) -> Vec<RoundRecord> {
        let spec = FlJobSpec::new(
            Workload::cifar100_effnet(),
            FleetKind::ActiveHomogeneous,
            arrivals.len(),
            1,
        );
        let params = JobParams::derive(0, &spec);
        let mut q = EventQueue::new();
        let mut cluster = Cluster::new(ClusterConfig::default());
        let mq = MessageQueue::new();
        let est = RoundEstimate {
            t_upd: arrivals.to_vec(),
            t_rnd: arrivals.iter().cloned().fold(0.0, f64::max),
            t_agg: 1.0,
        };
        {
            let mut ctx = Ctx {
                q: &mut q,
                cluster: &mut cluster,
                mq: &mq,
                params: &params,
            };
            strategy.on_round_start(&mut ctx, 0, &est);
        }
        for (i, &a) in arrivals.iter().enumerate() {
            q.schedule_at(
                crate::sim::secs(a),
                EventKind::UpdateArrival {
                    job: 0,
                    round: 0,
                    party: i,
                },
            );
        }
        q.schedule_in(cluster.cfg.delta_tick, EventKind::SchedTick);
        let mut arrived = 0;
        let mut records = Vec::new();
        let mut ticks = 0;
        while let Some((_, ev)) = q.next() {
            match ev {
                EventKind::UpdateArrival { party, .. } => {
                    arrived += 1;
                    let mut ctx = Ctx {
                        q: &mut q,
                        cluster: &mut cluster,
                        mq: &mq,
                        params: &params,
                    };
                    strategy.on_update(&mut ctx, 0, party, arrived);
                }
                EventKind::TimerAlert { round, .. } => {
                    let mut ctx = Ctx {
                        q: &mut q,
                        cluster: &mut cluster,
                        mq: &mq,
                        params: &params,
                    };
                    strategy.on_timer(&mut ctx, round);
                }
                EventKind::ContainerDone { container } => {
                    if let Some(note) = cluster.advance(&mut q, container) {
                        let mut ctx = Ctx {
                            q: &mut q,
                            cluster: &mut cluster,
                            mq: &mq,
                            params: &params,
                        };
                        strategy.on_note(&mut ctx, &note);
                    }
                }
                EventKind::SchedTick => {
                    cluster.on_tick(&mut q);
                    ticks += 1;
                    if ticks < 10_000 && records.is_empty() {
                        q.schedule_in(cluster.cfg.delta_tick, EventKind::SchedTick);
                    }
                }
                _ => {}
            }
            if let Some(r) = strategy.take_completed() {
                records.push(r);
            }
        }
        records
    }

    #[test]
    fn declares_decay_policy() {
        let s = AsyncStale::default();
        match s.stale_policy() {
            StalePolicy::Decay { lambda } => assert!((lambda - DECAY_LAMBDA).abs() < 1e-12),
            StalePolicy::Drop => panic!("async-stale must decay, not drop"),
        }
    }

    #[test]
    fn completes_rounds_exactly_like_jit_on_healthy_fleet() {
        let arrivals: Vec<f64> = (1..=6).map(|i| i as f64 * 3.0).collect();
        let a = run_round(&mut AsyncStale::default(), &arrivals);
        let mut jit = crate::coordinator::strategies::jit::Jit::default();
        let j = run_round(&mut jit, &arrivals);
        assert_eq!(a.len(), 1);
        assert_eq!(j.len(), 1);
        assert_eq!(
            a[0].latency_secs.to_bits(),
            j[0].latency_secs.to_bits(),
            "healthy-fleet async-stale must be bit-identical to jit"
        );
        assert_eq!(a[0].complete_secs.to_bits(), j[0].complete_secs.to_bits());
    }
}
